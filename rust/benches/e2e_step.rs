//! Bench: one full training iteration per schedule — the end-to-end step
//! that Fig. 3's per-step run-time panels report. Also prints the hwsim
//! decomposition so real CPU time and simulated accelerator time can be
//! compared side by side, and writes `BENCH_e2e.json` (name, wall times,
//! rollout throughput per arm) so the perf trajectory is machine-readable
//! across PRs.
//!
//! The `workers > 1` arms exercise the real rollout thread pool (one
//! engine replica per worker thread); the `pipelined` arm additionally
//! overlaps generation of the next iteration with the current update on
//! this host's cores, and the `fleet` arm deepens that overlap to the
//! staleness-K ready-batch queue (R=2 replicas, K=2) so the pool rides
//! through each batch's straggler tail.

use pods::coordinator::scheduler::Trainer;
use pods::exp::CfgBuilder;
use pods::hwsim::FleetSection;
use pods::util::bench::{bench, BenchReport};

#[allow(clippy::too_many_arguments)]
fn mk_trainer(
    kind: &str,
    n: usize,
    m: Option<usize>,
    workers: usize,
    schedule: &str,
    decode_chunk: usize,
    refill: &str,
    rule: &str,
    online_prune: bool,
    replay: bool,
    share_kv: bool,
    prompts: usize,
    fleet_rk: Option<(usize, usize)>,
) -> anyhow::Result<Trainer> {
    let mut fleet = FleetSection::default();
    if let Some((r, k)) = fleet_rk {
        fleet.inference_replicas = r;
        fleet.max_staleness = Some(k);
    }
    let cfg = CfgBuilder {
        name: format!("bench_{kind}_{n}_{workers}w_{schedule}"),
        profile: "base".into(),
        task: "arith".into(),
        iterations: 1,
        prompts_per_iter: prompts,
        eval_problems: 16,
        kind: kind.into(),
        n,
        m,
        rule: rule.into(),
        lr: 1e-4,
        workers,
        schedule: schedule.into(),
        decode_chunk,
        refill: refill.into(),
        online_prune,
        share_prompt_kv: share_kv,
        replay_enabled: replay,
        fleet,
        out_dir: std::env::temp_dir().join("pods_bench").to_string_lossy().into_owned(),
        ..Default::default()
    }
    .build()?;
    let mut tr = Trainer::new(&pods::default_artifacts_dir(), cfg)?;
    tr.engine.quiet = true;
    Ok(tr)
}

fn main() -> anyhow::Result<()> {
    let dir = pods::default_artifacts_dir();
    if !dir.join("base/meta.json").exists() {
        eprintln!("skipping: base artifacts missing (run `make artifacts`)");
        return Ok(());
    }
    // the online-prune arms cap rollouts at a quarter of the generation
    // budget; read G from the profile so the cap tracks the artifacts
    let g = pods::runtime::Engine::load(&dir, "base")?.meta.gen_len;
    let prune_rule = format!("prune(max_tokens={}) | max_variance", (g / 4).max(1));
    // (label, kind, n, m, workers, schedule, decode_chunk, refill) — the
    // selection rule and online-prune flag are derived from the label
    // below: arms whose label contains "prune" run the token-budget rule,
    // and only "online-prune" turns mid-decode aborts on.
    // The "full-G batch" arm decodes every rollout to the budget with no
    // mid-batch refill — the closest stand-in for the old monolithic
    // decode path; the default arms use chunked early exit (C=16,
    // continuous refill). Their throughput ratio is the acceptance
    // number, as is the online-prune arm's ratio over the identical
    // token-budget pipeline with pruning off.
    let arms = [
        ("grpo (n=m=16)", "grpo", 16usize, None, 1usize, "sync", 16usize, "continuous"),
        ("pods (n=64 -> m=16)", "pods", 64, Some(16), 1, "sync", 16, "continuous"),
        ("pods full-G batch (no early exit)", "pods", 64, Some(16), 1, "sync", 64, "batch"),
        ("ga   (n=64, train all)", "ga", 64, None, 1, "sync", 16, "continuous"),
        ("pods real-threads (4w)", "pods", 64, Some(16), 4, "sync", 16, "continuous"),
        ("pods pipelined (4w)", "pods", 64, Some(16), 4, "pipelined", 16, "continuous"),
        // staleness-K fleet schedule: two generation batches in flight
        // (R=2, K=2) over the same 4-worker pool; compared against the
        // depth-1 pipelined arm by `pods bench-check --min-fleet-speedup`
        ("pods fleet (r=2, k=2, 4w)", "pods", 64, Some(16), 4, "pipelined", 16, "continuous"),
        ("pods distributed (8w)", "pods", 64, Some(16), 8, "sync", 16, "continuous"),
        ("ga   distributed (8w)", "ga", 64, None, 8, "sync", 16, "continuous"),
        ("pods prune-rule (online off)", "pods", 64, Some(16), 1, "sync", 16, "continuous"),
        ("pods online-prune (same rule)", "pods", 64, Some(16), 1, "sync", 16, "continuous"),
        // replay mixing at the default quota: stored rows skip inference,
        // so this arm's throughput must stay within tolerance of the plain
        // PODS arm (`pods bench-check --min-replay-speedup`)
        ("pods + replay (mix=0.25)", "pods", 64, Some(16), 1, "sync", 16, "continuous"),
        // group-shared prompt KV vs per-row prefill over the identical
        // 4-group workload: streams are bit-identical (kv_golden.rs); the
        // shared arm re-runs prefill once per group instead of once per
        // refill event (`pods bench-check --min-kv-speedup`)
        ("pods per-row-prefill (n=64, m=8)", "pods", 64, Some(8), 1, "sync", 16, "continuous"),
        ("pods shared-kv (n=64, m=8)", "pods", 64, Some(8), 1, "sync", 16, "continuous"),
    ];
    let mut report = BenchReport::new();
    for (label, kind, n, m, workers, schedule, chunk, refill) in arms {
        // the two prune arms share the token-budget rule; everything else
        // runs the paper's max_variance selection
        let rule = if label.contains("prune") { prune_rule.as_str() } else { "max_variance" };
        let online = label.contains("online-prune");
        let replay = label.contains("replay");
        let share_kv = label.contains("shared-kv");
        // the KV comparison arms run 4 prompt groups so prefill sharing
        // has sibling groups to straddle; everything else keeps 1
        let prompts = if label.contains("(n=64, m=8)") { 4 } else { 1 };
        let fleet_rk = if label.contains("fleet") { Some((2usize, 2usize)) } else { None };
        let mut tr = mk_trainer(
            kind,
            n,
            m,
            workers,
            schedule,
            chunk,
            refill,
            rule,
            online,
            replay,
            share_kv,
            prompts,
            fleet_rk,
        )?;
        let pipelined = schedule == "pipelined";
        let mut it = 0usize;
        let res = bench(&format!("e2e step {label}"), Some(4), || {
            // pipelined arms keep a prefetch in flight every step so the
            // bench measures the steady-state overlapped iteration
            tr.step(it, pipelined).unwrap();
            it += 1;
        });
        let last = tr.recorder.iters.last().unwrap();
        println!(
            "  real {:.2}s | sim {:.1}s charged (inf {:.1}s + upd {:.1}s, \
             {:.1}s hidden, {} micro-steps) | decoded {} tok ({} wasted, \
             {} pruned over {} rows) | prefill {} (saved {})",
            res.median_ns / 1e9,
            last.sim_step_time,
            last.sim_inference_time,
            last.sim_update_time,
            last.sim_overlap_saved,
            last.micro_steps,
            last.gen_tokens_decoded,
            last.gen_tokens_wasted,
            last.gen_tokens_pruned,
            last.rows_pruned_online,
            last.prefill_calls,
            last.prefill_calls_saved
        );
        let rollouts_per_sec = last.rollouts_generated as f64 / (res.median_ns / 1e9);
        report.push_with_throughput(res, rollouts_per_sec);
    }
    report.write_json(std::path::Path::new("BENCH_e2e.json"))?;
    Ok(())
}
