//! Bench: one full training iteration per schedule — the end-to-end step
//! that Fig. 3's per-step run-time panels report. Also prints the hwsim
//! decomposition so real CPU time and simulated accelerator time can be
//! compared side by side.

use pods::coordinator::scheduler::Trainer;
use pods::exp::CfgBuilder;
use pods::util::bench::bench;

fn mk_trainer(kind: &str, n: usize, m: Option<usize>, workers: usize) -> anyhow::Result<Trainer> {
    let cfg = CfgBuilder {
        name: format!("bench_{kind}_{n}"),
        profile: "base".into(),
        task: "arith".into(),
        iterations: 1,
        prompts_per_iter: 1,
        eval_problems: 16,
        kind: kind.into(),
        n,
        m,
        lr: 1e-4,
        workers,
        out_dir: std::env::temp_dir().join("pods_bench").to_string_lossy().into_owned(),
        ..Default::default()
    }
    .build()?;
    let mut tr = Trainer::new(&pods::default_artifacts_dir(), cfg)?;
    tr.engine.quiet = true;
    Ok(tr)
}

fn main() -> anyhow::Result<()> {
    let dir = pods::default_artifacts_dir();
    if !dir.join("base/meta.json").exists() {
        eprintln!("skipping: base artifacts missing (run `make artifacts`)");
        return Ok(());
    }
    let arms = [
        ("grpo (n=m=16)", "grpo", 16usize, None, 1usize),
        ("pods (n=64 -> m=16)", "pods", 64, Some(16), 1),
        ("ga   (n=64, train all)", "ga", 64, None, 1),
        ("pods distributed (8w)", "pods", 64, Some(16), 8),
        ("ga   distributed (8w)", "ga", 64, None, 8),
    ];
    for (label, kind, n, m, workers) in arms {
        let mut tr = mk_trainer(kind, n, m, workers)?;
        let mut it = 0usize;
        let res = bench(&format!("e2e step {label}"), Some(4), || {
            tr.train_iteration(it).unwrap();
            it += 1;
        });
        let last = tr.recorder.iters.last().unwrap();
        println!(
            "  real {:.2}s | sim {:.1}s (inference {:.1}s + update {:.1}s, {} micro-steps)",
            res.median_ns / 1e9,
            last.sim_inference_time + last.sim_update_time,
            last.sim_inference_time,
            last.sim_update_time,
            last.micro_steps
        );
    }
    Ok(())
}
