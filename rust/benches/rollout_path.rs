//! Bench: the inference phase — rollout generation (KV-cache decode inside
//! the AOT artifact), reward verification, the per-rollout cost that
//! Fig. 1 (bottom) amortizes with batching, and the real thread-pool
//! speedup of the exec RolloutEngine (`hwsim.workers > 1` = that many
//! engine replicas decoding concurrently on this host).

use pods::coordinator::exec::{GenBatch, RolloutEngine};
use pods::reward::{score_rollout, RewardWeights};
use pods::rollout::{generate_group, prompt_batch, GenRequest};
use pods::runtime::Engine;
use pods::tasks::{Split, TaskKind};
use pods::util::bench::{bench, black_box};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let dir = pods::default_artifacts_dir();
    if !dir.join("base/meta.json").exists() {
        eprintln!("skipping: base artifacts missing (run `make artifacts`)");
        return Ok(());
    }
    let mut engine = Engine::load(&dir, "base")?;
    engine.quiet = true;
    let params = engine.init(1)?;
    let problem = TaskKind::Arith.generate(Split::Train, 0);
    let (prompts, pads) = prompt_batch(&engine, &problem.prompt)?;
    let br = engine.meta.config.rollout_batch;

    let mut seed = 0u32;
    let res = bench(&format!("rollout call (B_r={br}, G=64, sampled)"), Some(10), || {
        seed += 1;
        black_box(engine.rollout(&params, None, &prompts, &pads, seed, 1.0).unwrap());
    });
    println!(
        "  -> {:.1} ms/rollout on one CPU device",
        res.median_ns / 1e6 / br as f64
    );
    bench("rollout call greedy (eval path)", Some(10), || {
        black_box(engine.rollout(&params, None, &prompts, &pads, 0, 0.0).unwrap());
    });

    let out = engine.rollout(&params, None, &prompts, &pads, 3, 1.0)?;
    let t = engine.meta.config.seq_len;
    let p = engine.meta.config.prompt_len;
    let row: Vec<i32> = out.tokens.data[..t].to_vec();
    bench("reward verification per rollout", None, || {
        black_box(score_rollout(black_box(&row), p, TaskKind::Arith, &problem));
    });

    let req = GenRequest {
        params: &params,
        lora: None,
        ref_params: None,
        ref_lora: None,
        n: 64,
        temperature: 1.0,
        run_seed: 9,
        iter: 0,
        weights: RewardWeights::default(),
    };
    bench("generate_group n=64 (4 calls + verify)", Some(5), || {
        black_box(generate_group(&engine, &req, TaskKind::Arith, &problem).unwrap());
    });

    // Real multi-threaded generation: the same 4-prompt iteration fanned
    // over 1/2/4 worker threads (each its own engine replica). Results
    // are bit-identical across pool sizes; only wall time changes.
    let problems: Vec<_> =
        (0..4u64).map(|i| TaskKind::Arith.generate(Split::Train, i)).collect();
    let shared_problems = Arc::new(problems);
    let shared_params = Arc::new(params.clone());
    for workers in [1usize, 2, 4] {
        let mut pool = RolloutEngine::new(dir.clone(), "base", workers);
        let mut iter = 0u64;
        bench(&format!("parallel generate 4 prompts x n=16 ({workers}w)"), Some(5), || {
            iter += 1;
            let batch = GenBatch {
                params: Arc::clone(&shared_params),
                lora: None,
                ref_params: None,
                ref_lora: None,
                problems: Arc::clone(&shared_problems),
                n: 16,
                temperature: 1.0,
                run_seed: 9,
                iter,
                task: TaskKind::Arith,
                weights: RewardWeights::default(),
            };
            black_box(pool.generate(&engine, batch).unwrap());
        });
    }
    Ok(())
}
