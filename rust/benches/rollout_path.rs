//! Bench: the inference phase — monolithic full-`G` decode vs the chunked
//! early-exit driver (prefill + decode_chunk with continuous slot refill),
//! reward verification, and the real thread-pool speedup of the exec
//! RolloutEngine (`hwsim.workers > 1` = that many engine replicas decoding
//! concurrently on this host). The monolithic-vs-chunked arms are the
//! ground truth behind the BENCH_e2e.json throughput acceptance.

use pods::coordinator::exec::{GenBatch, RolloutEngine};
use pods::coordinator::select::online::GroupVerdicts;
use pods::coordinator::select::Pipeline;
use pods::reward::{score_rollout, RewardWeights};
use pods::rollout::{
    execute_rows, generate_group, plan_rows, prompt_batch, GenRequest, KvPolicy, RefillMode,
};
use pods::runtime::Engine;
use pods::tasks::{Split, TaskKind};
use pods::util::bench::{bench, black_box};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let dir = pods::default_artifacts_dir();
    if !dir.join("base/meta.json").exists() {
        eprintln!("skipping: base artifacts missing (run `make artifacts`)");
        return Ok(());
    }
    let mut engine = Engine::load(&dir, "base")?;
    engine.quiet = true;
    let params = engine.init(1)?;
    let problem = TaskKind::Arith.generate(Split::Train, 0);
    let (prompts, pads) = prompt_batch(&engine, &problem.prompt)?;
    let br = engine.meta.config.rollout_batch;
    let g = engine.meta.gen_len;

    // ---- monolithic reference: always decodes B_r x G ------------------
    let mut base_seed = 0i32;
    let res = bench(&format!("rollout monolithic (B_r={br}, G={g}, sampled)"), Some(10), || {
        base_seed += br as i32;
        let seeds: Vec<i32> = (0..br as i32).map(|i| base_seed + i).collect();
        black_box(engine.rollout(&params, None, &prompts, &pads, &seeds, 1.0).unwrap());
    });
    println!(
        "  -> {:.1} ms/rollout on one CPU device",
        res.median_ns / 1e6 / br as f64
    );

    // ---- chunked early-exit driver over the same work ------------------
    // n = B_r rollouts of the same prompt: identical sampled streams, but
    // decode stops at ceil(longest rollout / C) chunks.
    for chunk in engine.meta.decode_chunks.clone() {
        let mut iter = 0u64;
        bench(&format!("rollout chunked C={chunk} (n={br}, early exit)"), Some(10), || {
            iter += 1;
            let req = GenRequest {
                params: &params,
                lora: None,
                ref_params: None,
                ref_lora: None,
                n: br,
                temperature: 1.0,
                run_seed: 9,
                iter,
                weights: RewardWeights::default(),
                decode_chunk: chunk,
                refill: RefillMode::Continuous,
                kv: KvPolicy::default(),
            };
            black_box(generate_group(&engine, &req, TaskKind::Arith, &problem).unwrap());
        });
    }

    let seeds: Vec<i32> = (0..br as i32).collect();
    let out = engine.rollout(&params, None, &prompts, &pads, &seeds, 1.0)?;
    let t = engine.meta.config.seq_len;
    let p = engine.meta.config.prompt_len;
    let row: Vec<i32> = out.tokens.data[..t].to_vec();
    bench("reward verification per rollout", None, || {
        black_box(score_rollout(black_box(&row), p, TaskKind::Arith, &problem));
    });

    // generate_group with continuous refill: 64 rows through B_r slots
    let req = GenRequest {
        params: &params,
        lora: None,
        ref_params: None,
        ref_lora: None,
        n: 64,
        temperature: 1.0,
        run_seed: 9,
        iter: 0,
        weights: RewardWeights::default(),
        decode_chunk: 16,
        refill: RefillMode::Continuous,
        kv: KvPolicy::default(),
    };
    bench("generate_group n=64 (chunked refill + verify)", Some(5), || {
        black_box(generate_group(&engine, &req, TaskKind::Arith, &problem).unwrap());
    });

    // Online selection-aware pruning: the same 2-prompt x n=32 decode with
    // a token-budget pipeline, with and without mid-decode aborts. The
    // pruned arm stops paying for rollouts that provably cannot survive
    // prune(max_tokens=G/4) | max_variance; streams of surviving rollouts
    // are bit-identical between the arms (the doom-only contract).
    let cap = (g / 4).max(1);
    let prune_pipeline =
        Pipeline::parse_default(&format!("prune(max_tokens={cap}) | max_variance"))?;
    let prune_problems: Vec<_> =
        (0..2u64).map(|i| TaskKind::Arith.generate(Split::Train, i)).collect();
    for online in [false, true] {
        let label = if online {
            format!("rollout chunked pruned (cap={cap}, C=16)")
        } else {
            format!("rollout chunked unpruned (cap={cap}, C=16)")
        };
        let mut iter = 0u64;
        let mut last_stats = pods::rollout::InferenceStats::default();
        bench(&label, Some(10), || {
            iter += 1;
            let rows = plan_rows(&prune_problems, 32, 9, iter);
            // fresh verdict state per iteration, exactly like the executor
            let verdicts = online.then(|| {
                GroupVerdicts::new(
                    &prune_pipeline,
                    prune_problems.len(),
                    32,
                    8,
                    &RewardWeights::default(),
                )
            });
            let (kept, stats) = execute_rows(
                &engine,
                &params,
                None,
                None,
                None,
                1.0,
                16,
                RefillMode::Continuous,
                &rows,
                &prune_problems,
                TaskKind::Arith,
                &RewardWeights::default(),
                verdicts.as_ref(),
                KvPolicy::default(),
            )
            .unwrap();
            last_stats = stats;
            black_box(kept);
        });
        println!(
            "  -> decoded {} tok, pruned budget {} over {} rows",
            last_stats.gen_tokens_decoded, last_stats.gen_tokens_pruned, last_stats.rows_pruned
        );
    }

    // Real multi-threaded generation: the same 4-prompt iteration fanned
    // over 1/2/4 worker threads (each its own engine replica, each running
    // the chunked driver over its row shard). Results are bit-identical
    // across pool sizes; only wall time changes.
    let problems: Vec<_> =
        (0..4u64).map(|i| TaskKind::Arith.generate(Split::Train, i)).collect();
    let shared_problems = Arc::new(problems);
    let shared_params = Arc::new(params.clone());
    for workers in [1usize, 2, 4] {
        let mut pool = RolloutEngine::new(dir.clone(), "base", workers);
        let mut iter = 0u64;
        bench(&format!("parallel generate 4 prompts x n=16 ({workers}w)"), Some(5), || {
            iter += 1;
            let batch = GenBatch {
                params: Arc::clone(&shared_params),
                lora: None,
                ref_params: None,
                ref_lora: None,
                problems: Arc::clone(&shared_problems),
                n: 16,
                temperature: 1.0,
                run_seed: 9,
                iter,
                task: TaskKind::Arith,
                weights: RewardWeights::default(),
                decode_chunk: 16,
                refill: RefillMode::Continuous,
                online: None,
                kv: KvPolicy::default(),
            };
            black_box(pool.generate(&engine, batch).unwrap());
        });
    }

    // Group-shared prompt prefill under a constrained paged KV pool: the
    // same 2-prompt x n=32 decode, with the pool sized to hold only half
    // the slots' reservations — admission queues at the pool gate
    // (vLLM-style) and sibling rows admit from the group's prompt
    // snapshot. Streams stay bit-identical to the unshared arms above.
    let hw = pods::hwsim::HwModel::default();
    let kv_problems: Vec<_> =
        (0..2u64).map(|i| TaskKind::Arith.generate(Split::Train, i)).collect();
    let full_slot = hw.kv_bytes(p, g);
    for (label, pool_bytes) in [
        ("rollout shared-kv unbounded (n=32, C=16)", 0u64),
        ("rollout shared-kv constrained (n=32, C=16)", full_slot * (br as u64 / 2).max(1)),
    ] {
        let mut iter = 0u64;
        let mut last_stats = pods::rollout::InferenceStats::default();
        bench(label, Some(10), || {
            iter += 1;
            let rows = plan_rows(&kv_problems, 32, 9, iter);
            let mut kv = KvPolicy::from_model(&hw, true, p, g);
            kv.pool_bytes = pool_bytes;
            let (kept, stats) = execute_rows(
                &engine,
                &params,
                None,
                None,
                None,
                1.0,
                16,
                RefillMode::Continuous,
                &rows,
                &kv_problems,
                TaskKind::Arith,
                &RewardWeights::default(),
                None,
                kv,
            )
            .unwrap();
            last_stats = stats;
            black_box(kept);
        });
        println!(
            "  -> prefill calls {} (saved {}), kv peak {} B",
            last_stats.prefill_calls, last_stats.prefill_calls_saved, last_stats.kv_peak_bytes
        );
    }
    Ok(())
}
