//! Bench: the policy-update phase — grad micro-batch, gradient
//! accumulation, AdamW apply, and the sharded update engine end to end
//! (monolithic vs sharded topologies). These are the
//! memory/serialization-bound costs the paper's Fig. 1 (top) decomposes;
//! here measured for real on the base-profile artifacts (one CPU device).

use pods::coordinator::accum::GradAccumulator;
use pods::coordinator::exec::{ShardPlan, UpdateEngine};
use pods::coordinator::group::{PromptGroup, RolloutRecord, SelectedRollout};
use pods::exp::CfgBuilder;
use pods::reward::RewardBreakdown;
use pods::rollout::prompt_batch;
use pods::runtime::{Engine, MicroBatch, ParamStore, TensorF, TensorI};
use pods::tasks::{Split, TaskKind};
use pods::util::bench::{bench, black_box};

fn main() -> anyhow::Result<()> {
    let dir = pods::default_artifacts_dir();
    if !dir.join("base/meta.json").exists() {
        eprintln!("skipping: base artifacts missing (run `make artifacts`)");
        return Ok(());
    }
    let mut engine = Engine::load(&dir, "base")?;
    engine.quiet = true;
    let mut store = ParamStore::new(engine.init(1)?);
    let problem = TaskKind::Arith.generate(Split::Train, 0);
    let (prompts, pads) = prompt_batch(&engine, &problem.prompt)?;
    let seeds: Vec<i32> = (0..engine.meta.config.rollout_batch as i32).collect();
    let out = engine.rollout(&store.params, None, &prompts, &pads, &seeds, 1.0)?;
    let bu = engine.meta.config.update_batch;
    let t = engine.meta.config.seq_len;
    let g = engine.meta.gen_len;
    let mb = MicroBatch {
        tokens: TensorI::new(out.tokens.data[..bu * t].to_vec(), &[bu, t])?,
        pad_len: pads[..bu].to_vec(),
        gen_mask: TensorF::new(out.gen_mask.data[..bu * g].to_vec(), &[bu, g])?,
        old_lp: TensorF::new(out.logprobs.data[..bu * g].to_vec(), &[bu, g])?,
        adv: vec![0.5; bu],
        ref_lp: TensorF::new(vec![0.0; bu * g], &[bu, g])?,
    };
    let grad_out = engine.grad(&store.params, None, &mb, 0.0)?;

    bench(&format!("grad micro-batch (B_u={bu}, fwd+bwd)"), Some(12), || {
        black_box(engine.grad(&store.params, None, &mb, 0.0).unwrap());
    });
    bench("grad micro-batch with KL term", Some(12), || {
        black_box(engine.grad(&store.params, None, &mb, 0.04).unwrap());
    });

    let n = store.len();
    let mut acc = GradAccumulator::new(n);
    bench(&format!("grad accumulate ({} f32)", n), None, || {
        acc.add(black_box(&grad_out.grads), 8.0);
    });
    acc.reset();
    acc.add(&grad_out.grads, 8.0);
    bench("grad mean/finalize", None, || {
        black_box(acc.mean(8));
    });

    bench("adamw update (fused kernel via PJRT)", Some(12), || {
        engine.update(&mut store, &grad_out.grads, 1e-4).unwrap();
    });

    // ---- sharded vs monolithic: the full UpdateEngine path -----------
    // one real prompt group built from the rollout above; train on every
    // row so both topologies pack identical micro-batches
    let br = engine.meta.config.rollout_batch;
    let rollouts: Vec<RolloutRecord> = (0..br)
        .map(|b| RolloutRecord {
            pruned: false,
            tokens: out.tokens.data[b * t..(b + 1) * t].to_vec(),
            pad_len: pads[b],
            gen_mask: out.gen_mask.data[b * g..(b + 1) * g].to_vec(),
            old_lp: out.logprobs.data[b * g..(b + 1) * g].to_vec(),
            ref_lp: vec![0.0; g],
            gen_len: out.gen_len[b],
            reward: RewardBreakdown { accuracy: 0.0, format: 0.0, tag_count: 0.0 },
            total_reward: 0.0,
        })
        .collect();
    let groups = vec![PromptGroup { problem: problem.clone(), rollouts }];
    let selected: Vec<SelectedRollout> = (0..br)
        .map(|i| SelectedRollout { group_idx: 0, rollout_idx: i, advantage: 0.5 })
        .collect();
    for (label, shards, micro_batch) in [
        ("update engine monolithic (S=1, full B_u)", 1usize, 0usize),
        ("update engine sharded (S=4, micro_batch=B_u/2)", 4, bu / 2),
    ] {
        let cfg = CfgBuilder {
            name: "bench_upd".into(),
            iterations: 1,
            kind: "pods".into(),
            n: br,
            m: Some(br),
            upd_shards: shards,
            upd_micro_batch: micro_batch,
            ..Default::default()
        }
        .build()?;
        let mut upd = UpdateEngine::new(store.len());
        bench(label, Some(8), || {
            let out = upd.run(&engine, &mut store, None, &groups, &selected, &[], &cfg).unwrap();
            black_box(out);
        });
    }
    let plan = ShardPlan::new(br, 4, bu / 2);
    println!(
        "sharded plan: {} rollouts -> {} micro-batches over {} shards \
         ({} steps on the busiest shard)",
        br,
        plan.slots.len(),
        plan.shards,
        plan.max_steps_per_shard()
    );

    // the PODS trade at a glance: micro-steps for m=16 vs n=64 per prompt
    println!("\nupdate-phase calls per prompt: PODS m=16 -> {} grad calls; GA n=64 -> {} grad calls", 16usize.div_ceil(bu), 64usize.div_ceil(bu));
    Ok(())
}
