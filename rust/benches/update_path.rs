//! Bench: the policy-update phase — grad micro-batch, gradient
//! accumulation, AdamW apply. These are the memory/serialization-bound
//! costs the paper's Fig. 1 (top) decomposes; here measured for real on
//! the base-profile artifacts (one CPU device).

use pods::coordinator::accum::GradAccumulator;
use pods::rollout::prompt_batch;
use pods::runtime::{Engine, MicroBatch, ParamStore, TensorF, TensorI};
use pods::tasks::{Split, TaskKind};
use pods::util::bench::{bench, black_box};

fn main() -> anyhow::Result<()> {
    let dir = pods::default_artifacts_dir();
    if !dir.join("base/meta.json").exists() {
        eprintln!("skipping: base artifacts missing (run `make artifacts`)");
        return Ok(());
    }
    let mut engine = Engine::load(&dir, "base")?;
    engine.quiet = true;
    let mut store = ParamStore::new(engine.init(1)?);
    let problem = TaskKind::Arith.generate(Split::Train, 0);
    let (prompts, pads) = prompt_batch(&engine, &problem.prompt)?;
    let seeds: Vec<i32> = (0..engine.meta.config.rollout_batch as i32).collect();
    let out = engine.rollout(&store.params, None, &prompts, &pads, &seeds, 1.0)?;
    let bu = engine.meta.config.update_batch;
    let t = engine.meta.config.seq_len;
    let g = engine.meta.gen_len;
    let mb = MicroBatch {
        tokens: TensorI::new(out.tokens.data[..bu * t].to_vec(), &[bu, t])?,
        pad_len: pads[..bu].to_vec(),
        gen_mask: TensorF::new(out.gen_mask.data[..bu * g].to_vec(), &[bu, g])?,
        old_lp: TensorF::new(out.logprobs.data[..bu * g].to_vec(), &[bu, g])?,
        adv: vec![0.5; bu],
        ref_lp: TensorF::new(vec![0.0; bu * g], &[bu, g])?,
    };
    let grad_out = engine.grad(&store.params, None, &mb, 0.0)?;

    bench(&format!("grad micro-batch (B_u={bu}, fwd+bwd)"), Some(12), || {
        black_box(engine.grad(&store.params, None, &mb, 0.0).unwrap());
    });
    bench("grad micro-batch with KL term", Some(12), || {
        black_box(engine.grad(&store.params, None, &mb, 0.04).unwrap());
    });

    let n = store.len();
    let mut acc = GradAccumulator::new(n);
    bench(&format!("grad accumulate ({} f32)", n), None, || {
        acc.add(black_box(&grad_out.grads), 8.0);
    });
    acc.reset();
    acc.add(&grad_out.grads, 8.0);
    bench("grad mean/finalize", None, || {
        black_box(acc.mean(8));
    });

    bench("adamw update (fused kernel via PJRT)", Some(12), || {
        engine.update(&mut store, &grad_out.grads, 1e-4).unwrap();
    });

    // the PODS trade at a glance: micro-steps for m=16 vs n=64 per prompt
    println!("\nupdate-phase calls per prompt: PODS m=16 -> {} grad calls; GA n=64 -> {} grad calls", 16usize.div_ceil(bu), 64usize.div_ceil(bu));
    Ok(())
}
