//! Bench: selection kernels and pipelines (the paper's O(n log n) claim,
//! Theorem 1, plus the selector-subsystem overhead).
//!
//! Verifies the complexity class empirically (time vs n for max-variance),
//! compares every registered selection pipeline at the paper's production
//! shape — including the context-aware `drop_zero_variance` and `prune`
//! stages — and pits Algorithm 2 against the exhaustive oracle at small n.

use pods::coordinator::downsample::{max_variance, subset_variance};
use pods::coordinator::group::PromptGroup;
use pods::coordinator::select::{Pipeline, SelectionContext};
use pods::util::bench::{bench, black_box};
use pods::util::rng::Rng;

fn rewards(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    // discrete RLVR-like rewards (accuracy + format + tags)
    (0..n)
        .map(|_| [0.0, 0.25, 0.5, 1.0, 2.0, 2.25, 3.0][rng.below(7)])
        .collect()
}

/// Synthetic prompt group with RLVR-like rewards and spread-out lengths.
fn group(n: usize, seed: u64) -> PromptGroup {
    let mut rng = Rng::seed_from_u64(seed ^ 0x9E37);
    let lens: Vec<i32> = (0..n).map(|_| rng.gen_range_inclusive(8, 512) as i32).collect();
    PromptGroup::synthetic(0, &rewards(n, seed), Some(&lens))
}

/// Exhaustive oracle (for the asymptotic comparison at tiny n).
fn oracle(rewards: &[f32], m: usize) -> f64 {
    fn rec(r: &[f32], start: usize, left: usize, cur: &mut Vec<usize>, best: &mut f64) {
        if left == 0 {
            let v = subset_variance(r, cur);
            if v > *best {
                *best = v;
            }
            return;
        }
        if r.len() - start < left {
            return;
        }
        for i in start..r.len() {
            cur.push(i);
            rec(r, i + 1, left - 1, cur, best);
            cur.pop();
        }
    }
    let mut best = f64::NEG_INFINITY;
    rec(rewards, 0, m, &mut Vec::new(), &mut best);
    best
}

fn main() {
    println!("== downsample: Algorithm 2 scaling (m = n/4) ==");
    let mut med = Vec::new();
    for n in [64usize, 256, 1024, 4096, 16384, 65536] {
        let r = rewards(n, n as u64);
        let m = n / 4;
        let res = bench(&format!("max_variance n={n}"), None, || {
            black_box(max_variance(black_box(&r), m).unwrap());
        });
        med.push((n, res.median_ns));
    }
    // empirical exponent: should be ~1 (n log n is near-linear over this range)
    let (n0, t0) = med[1];
    let (n1, t1) = med[med.len() - 1];
    let slope = (t1 / t0).log2() / ((n1 as f64 / n0 as f64)).log2();
    println!("empirical scaling exponent (expect ~1.0-1.2 for n log n): {slope:.2}\n");

    println!("== selection pipelines at the paper's production shape (n=512, m=128) ==");
    let g = group(512, 7);
    let specs = [
        "max_variance",
        "max_reward",
        "random",
        "percentile",
        "first",
        "drop_zero_variance | max_variance",
        "prune(quantile=0.75) | max_variance",
        "prune(budget=16384) | percentile",
    ];
    for spec in specs {
        let pipeline = Pipeline::parse_default(spec).unwrap();
        let ctx = SelectionContext::new(&g, 128, 0, 0);
        bench(&format!("pipeline [{spec}] n=512 m=128"), None, || {
            black_box(pipeline.select(black_box(&ctx)).unwrap());
        });
    }

    println!("\n== exhaustive oracle vs Algorithm 2 (n=22, m=6) ==");
    let r = rewards(22, 3);
    bench("oracle C(22,6)", Some(20), || {
        black_box(oracle(black_box(&r), 6));
    });
    bench("algorithm2 n=22 m=6", None, || {
        black_box(max_variance(black_box(&r), 6).unwrap());
    });
}
