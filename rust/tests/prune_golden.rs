//! Goldens for online selection-aware rollout pruning.
//!
//! The load-bearing invariant (docs/DETERMINISM.md): because verdicts are
//! doom-only — a row is aborted only when it provably cannot survive the
//! selection pipeline under *any* completion of its group — the final
//! selection over the pruned groups (kept indices, advantages, and hence
//! the trained parameters) is **bit-identical** to post-hoc selection on
//! fully-decoded rollouts.
//!
//! The property suite drives the real [`OnlineSelector`] analysis through
//! randomized decode schedules (chunk sizes, staggered admissions, poll
//! orders) over random groups and pipelines, gives aborted rows
//! *adversarial* truncated rewards, and checks the two worlds select
//! identically. The trainer-level golden (artifact-gated, skipped without
//! `make artifacts`) runs the full stack twice — `online_prune` on and
//! off — and compares post-training parameters bitwise.

mod common;

use pods::coordinator::advantage::NormMode;
use pods::coordinator::group::{build_update_batch, PromptGroup};
use pods::coordinator::select::{OnlineSelector, Pipeline, Verdict};
use pods::util::prop::for_cases;
use pods::util::rng::Rng;

/// Generation budget of the simulated profile.
const G: usize = 64;

/// One synthetic rollout: the fully-decoded outcome plus an adversarial
/// reward the verifier would compute on a truncated stream.
#[derive(Debug, Clone, Copy)]
struct SimRow {
    final_len: usize,
    final_reward: f32,
    trunc_reward: f32,
}

/// Rewards on the rule-based model's 0.25 grid in [0, 3].
fn grid_reward(rng: &mut Rng) -> f32 {
    0.25 * rng.below(13) as f32
}

fn sim_rows(rng: &mut Rng, n: usize) -> Vec<SimRow> {
    (0..n)
        .map(|_| SimRow {
            final_len: 1 + rng.below(G),
            final_reward: grid_reward(rng),
            trunc_reward: grid_reward(rng),
        })
        .collect()
}

/// Simulate one group's chunked decode under a *randomized* schedule:
/// each boundary advances a random subset of live rows by `chunk` (rows
/// waiting in the refill queue advance nothing), retires rows reaching
/// their final length (observing their true reward), then polls the live
/// rows in random order and aborts doomed ones — exactly the driver's
/// retire-then-abort boundary order. Returns per-row (decoded length,
/// aborted flag).
fn simulate(
    rows: &[SimRow],
    pipeline: &Pipeline,
    m: usize,
    chunk: usize,
    rng: &mut Rng,
) -> (Vec<usize>, Vec<bool>) {
    let n = rows.len();
    let mut sel = OnlineSelector::new(pipeline.stage_bounds(), n, m, 0.0, 3.0);
    let mut decoded = vec![0usize; n];
    let mut live = vec![true; n];
    let mut aborted = vec![false; n];
    let chunk = chunk.max(1);
    while live.iter().any(|&l| l) {
        // advance a random subset; force progress when the draw stalls
        let mut advanced = false;
        for i in 0..n {
            if live[i] && rng.gen_bool(0.7) {
                decoded[i] = (decoded[i] + chunk).min(rows[i].final_len);
                advanced = true;
            }
        }
        if !advanced {
            for i in 0..n {
                if live[i] {
                    decoded[i] = (decoded[i] + chunk).min(rows[i].final_len);
                }
            }
        }
        // retire finished rows first, as the driver does
        for i in 0..n {
            if live[i] && decoded[i] >= rows[i].final_len {
                live[i] = false;
                sel.observe_finished(i, rows[i].final_reward, rows[i].final_len);
            }
        }
        // poll the live rows in a random order
        let mut order: Vec<usize> = (0..n).filter(|&i| live[i]).collect();
        rng.shuffle(&mut order);
        for i in order {
            sel.observe_len(i, decoded[i]);
            sel.poll();
            if sel.verdict(i) == Verdict::Doomed {
                live[i] = false;
                aborted[i] = true;
            }
        }
    }
    (decoded, aborted)
}

/// Both worlds' groups: the post-hoc world decodes everything to
/// completion; the online world records truncated lengths and adversarial
/// rewards for aborted rows.
fn two_worlds(
    rows: &[SimRow],
    decoded: &[usize],
    aborted: &[bool],
    problem_idx: u64,
) -> (PromptGroup, PromptGroup) {
    let full_rewards: Vec<f32> = rows.iter().map(|r| r.final_reward).collect();
    let full_lens: Vec<i32> = rows.iter().map(|r| r.final_len as i32).collect();
    let online_rewards: Vec<f32> = rows
        .iter()
        .zip(aborted)
        .map(|(r, &a)| if a { r.trunc_reward } else { r.final_reward })
        .collect();
    let online_lens: Vec<i32> = rows
        .iter()
        .zip(decoded)
        .zip(aborted)
        .map(|((r, &d), &a)| if a { d as i32 } else { r.final_len as i32 })
        .collect();
    (
        PromptGroup::synthetic(problem_idx, &full_rewards, Some(&full_lens)),
        PromptGroup::synthetic(problem_idx, &online_rewards, Some(&online_lens)),
    )
}

/// Tentpole proptest: for random groups, pipelines, chunk sizes and decode
/// schedules, online pruning yields a bit-identical selection (kept rows
/// and advantages) to post-hoc selection on the fully-decoded group — and
/// never keeps an aborted row.
#[test]
fn online_pruning_selection_is_bit_identical_to_post_hoc() {
    let pool = [
        "prune(max_tokens=8) | max_variance",
        "prune(max_tokens=16) | max_variance",
        "prune(max_tokens=16) | percentile",
        "prune(max_tokens=16)",
        "prune(max_tokens=32) | max_reward",
        "max_variance",
        "drop_zero_variance | max_variance",
        "prune(quantile=0.75) | max_variance",
        "random",
    ];
    let total_aborts = std::cell::Cell::new(0usize);
    let cases_with_aborts = std::cell::Cell::new(0usize);
    for_cases(400, |rng| {
        let n = 2 + rng.below(15);
        let m = 1 + rng.below(n);
        let chunk = [1usize, 2, 4, 8, 16][rng.below(5)];
        let spec = pool[rng.below(pool.len())];
        let pipeline = Pipeline::parse_default(spec).unwrap();
        let rows = sim_rows(rng, n);
        let (decoded, aborted) = simulate(&rows, &pipeline, m, chunk, rng);
        let problem_idx = rng.below(1000) as u64;
        let (full, online) = two_worlds(&rows, &decoded, &aborted, problem_idx);
        let run_seed = rng.next_u64();
        let iter = rng.below(100) as u64;
        let (want, want_stats) = build_update_batch(
            std::slice::from_ref(&full),
            &pipeline,
            Some(m),
            NormMode::After,
            run_seed,
            iter,
        )
        .unwrap();
        let (got, got_stats) = build_update_batch(
            std::slice::from_ref(&online),
            &pipeline,
            Some(m),
            NormMode::After,
            run_seed,
            iter,
        )
        .unwrap();
        assert_eq!(
            want.len(),
            got.len(),
            "{spec:?} n={n} m={m} C={chunk}: kept-set size drifted (aborted: {aborted:?})"
        );
        for (w, o) in want.iter().zip(&got) {
            assert_eq!(
                (w.group_idx, w.rollout_idx),
                (o.group_idx, o.rollout_idx),
                "{spec:?} n={n} m={m} C={chunk}: kept indices drifted"
            );
            assert_eq!(
                w.advantage.to_bits(),
                o.advantage.to_bits(),
                "{spec:?} n={n} m={m} C={chunk}: advantage of row {} drifted",
                w.rollout_idx
            );
            assert!(
                !aborted[o.rollout_idx],
                "{spec:?} n={n} m={m} C={chunk}: kept an aborted row"
            );
        }
        assert_eq!(want_stats.groups_dropped, got_stats.groups_dropped, "{spec:?}");
        let aborts = aborted.iter().filter(|&&a| a).count();
        total_aborts.set(total_aborts.get() + aborts);
        if aborts > 0 {
            cases_with_aborts.set(cases_with_aborts.get() + 1);
        }
    });
    // the suite must actually exercise pruning, not vacuously pass
    assert!(
        cases_with_aborts.get() > 20,
        "only {} of 400 cases aborted anything ({} rows) — the generator no longer \
         exercises the doom paths",
        cases_with_aborts.get(),
        total_aborts.get()
    );
}

/// Pipelines made only of stages without a sound bound must never abort a
/// row, whatever the schedule observes — never prune speculatively.
#[test]
fn unknown_only_pipelines_never_abort() {
    let opaque = [
        "percentile",
        "random",
        "first",
        "max_reward",
        "drop_zero_variance | percentile",
        "prune(quantile=0.5)",
        "prune(budget=64)",
        "prune(max_tokens=8, quantile=0.5) | max_reward",
    ];
    for_cases(120, |rng| {
        let n = 2 + rng.below(15);
        let m = 1 + rng.below(n);
        let spec = opaque[rng.below(opaque.len())];
        let pipeline = Pipeline::parse_default(spec).unwrap();
        let rows = sim_rows(rng, n);
        let (_, aborted) = simulate(&rows, &pipeline, m, 4, rng);
        assert!(
            aborted.iter().all(|&a| !a),
            "{spec:?} aborted a row despite having no sound bound"
        );
    });
}

/// The length-cap bound fires where it should: on a deterministic
/// lockstep schedule (the `exp prune` simulator), a token-budget pipeline
/// over a tail-heavy group prunes exactly the over-cap rows, each shortly
/// after it provably crossed the cap.
#[test]
fn token_budget_pipelines_prune_the_over_cap_tail() {
    use pods::exp::prune::{simulate_group, SimRow as ExpRow};
    let pipeline = Pipeline::parse_default("prune(max_tokens=16) | max_variance").unwrap();
    let rows: Vec<ExpRow> = (0..8)
        .map(|i| ExpRow {
            // half the group finishes inside the cap, half rambles to G
            final_len: if i % 2 == 0 { 4 + i } else { G },
            final_reward: if i % 2 == 0 { 3.0 } else { 0.0 },
        })
        .collect();
    let sim = simulate_group(&rows, &pipeline, 2, 4);
    for (i, r) in rows.iter().enumerate() {
        if r.final_len > 16 {
            assert!(sim.aborted[i], "over-cap row {i} must be pruned");
            assert!(sim.decoded_len[i] < r.final_len, "abort must save decode work");
            assert!(sim.decoded_len[i] > 16, "doomed only after provably crossing the cap");
        } else {
            assert!(!sim.aborted[i], "in-cap row {i} must never be pruned");
            assert_eq!(sim.decoded_len[i], r.final_len);
        }
    }
}

/// Trainer-level golden (artifact-gated): `online_prune = true` trains
/// bit-identical parameters to the post-hoc path on the same seed and
/// token-budget pipeline, while recording the pruning telemetry.
#[test]
fn online_prune_trains_bit_identical_params() {
    let Some(dir) = common::artifacts() else { return };
    let g = pods::runtime::Engine::load(&dir, "base").unwrap().meta.gen_len;
    let rule = format!("prune(max_tokens={}) | max_variance", (g / 4).max(1));
    let run = |online_prune: bool| {
        let mut b =
            common::tiny_builder(&format!("prune_golden_{online_prune}"), "pods_prune_golden");
        b.rule = rule.clone();
        b.decode_chunk = 4;
        b.online_prune = online_prune;
        common::train(&dir, b.build().unwrap(), 2)
    };
    let posthoc = run(false);
    let online = run(true);
    assert_eq!(
        posthoc.store.params, online.store.params,
        "online pruning changed trained parameters — the doom-only contract is broken"
    );
    for (a, b) in posthoc.recorder.iters.iter().zip(&online.recorder.iters) {
        // identical selections and updates; only decode/dropped-row
        // telemetry may move (kept rows are never aborted, so the kept
        // token budget is pinned too)
        assert_eq!(a.rollouts_trained, b.rollouts_trained);
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.sel_variance, b.sel_variance);
        assert_eq!(a.sel_tokens_kept, b.sel_tokens_kept);
        assert_eq!(a.gen_tokens_pruned, 0, "pruning off must record zero");
        assert!(
            b.sim_inference_time <= a.sim_inference_time + 1e-9,
            "pruned inference charge must never exceed the unpruned one"
        );
        if b.rows_pruned_online > 0 {
            assert!(b.gen_tokens_pruned > 0);
            assert!(
                b.sim_inference_time < a.sim_inference_time,
                "pruned rows must cheapen the simulated inference phase"
            );
        }
    }
}
