//! Runtime round-trip tests: load the `micro` artifacts, execute every
//! program through PJRT, and check the cross-layer contracts (shapes,
//! determinism, masking, gradient/optimizer semantics) from the Rust side.
//!
//! Requires `make artifacts` (micro profile). Tests are skipped with a
//! notice when artifacts are absent so `cargo test` stays green pre-build.

use pods::reward::RewardWeights;
use pods::rollout::{generate_group, prompt_batch, GenRequest, KvPolicy, RefillMode};
use pods::runtime::{Engine, MicroBatch, ParamStore, TensorF, TensorI};
use pods::tasks::tokenizer as tok;
use pods::tasks::{Split, TaskKind};

fn engine() -> Option<Engine> {
    let dir = pods::default_artifacts_dir();
    if !dir.join("micro/meta.json").exists() {
        eprintln!("skipping: micro artifacts missing (run `make artifacts`)");
        return None;
    }
    let mut e = Engine::load(&dir, "micro").expect("engine load");
    e.quiet = true;
    Some(e)
}

#[test]
fn init_is_deterministic_and_padded() {
    let Some(e) = engine() else { return };
    let p1 = e.init(7).unwrap();
    let p2 = e.init(7).unwrap();
    assert_eq!(p1.len(), e.meta.param_count);
    assert_eq!(p1, p2);
    let p3 = e.init(8).unwrap();
    assert_ne!(p1, p3);
    // padded tail is zero
    let used = e.meta.param_spec.used;
    assert!(p1[used..].iter().all(|&x| x == 0.0));
    // layernorm scales are 1.0 at their recorded offsets
    let lnf = e
        .meta
        .param_spec
        .entries
        .iter()
        .find(|s| s.name == "lnf_s")
        .unwrap();
    assert!(p1[lnf.offset..lnf.offset + lnf.size].iter().all(|&x| x == 1.0));
}

#[test]
fn rollout_contract() {
    let Some(e) = engine() else { return };
    let params = e.init(1).unwrap();
    let problem = TaskKind::Arith.generate(Split::Train, 0);
    // micro profile has prompt_len 8; clip the prompt to fit
    let short: Vec<i32> = problem.prompt.iter().copied().take(8).collect();
    let (prompts, pads) = prompt_batch(&e, &short).unwrap();
    let b = e.meta.config.rollout_batch;
    let seeds: Vec<i32> = (0..b as i32).map(|i| 11_000 + i).collect();
    let out = e.rollout(&params, None, &prompts, &pads, &seeds, 1.0).unwrap();
    let t = e.meta.config.seq_len;
    let g = e.meta.gen_len;
    let p = e.meta.config.prompt_len;
    assert_eq!(out.tokens.dims, vec![b, t]);
    assert_eq!(out.logprobs.dims, vec![b, g]);
    // prompt region is echoed verbatim
    for row in 0..b {
        for j in 0..p {
            assert_eq!(out.tokens.at2(row, j), prompts.at2(row, j));
        }
    }
    // determinism + seed sensitivity
    let out2 = e.rollout(&params, None, &prompts, &pads, &seeds, 1.0).unwrap();
    assert_eq!(out.tokens.data, out2.tokens.data);
    let seeds3: Vec<i32> = (0..b as i32).map(|i| 12_000 + i).collect();
    let out3 = e.rollout(&params, None, &prompts, &pads, &seeds3, 1.0).unwrap();
    assert_ne!(out.tokens.data, out3.tokens.data);
    // mask/EOS/PAD contract per row
    for row in 0..b {
        let len = out.gen_len[row] as usize;
        for j in 0..g {
            let m = out.gen_mask.at2(row, j);
            assert_eq!(m, if j < len { 1.0 } else { 0.0 });
            if j >= len {
                assert_eq!(out.tokens.at2(row, p + j), tok::PAD);
                assert_eq!(out.logprobs.at2(row, j), 0.0);
            } else {
                assert!(out.logprobs.at2(row, j) <= 1e-6, "logprob must be <= 0");
            }
        }
    }
    // greedy decode is deterministic regardless of seed
    let g1 = e.rollout(&params, None, &prompts, &pads, &seeds, 0.0).unwrap();
    let g2 = e.rollout(&params, None, &prompts, &pads, &seeds3, 0.0).unwrap();
    assert_eq!(g1.tokens.data, g2.tokens.data);
}

#[test]
fn score_matches_rollout_behaviour_logprobs() {
    let Some(e) = engine() else { return };
    let params = e.init(2).unwrap();
    let problem = TaskKind::Mcq.generate(Split::Train, 1);
    let short: Vec<i32> = problem.prompt.iter().copied().take(8).collect();
    let (prompts, pads) = prompt_batch(&e, &short).unwrap();
    let b = e.meta.config.rollout_batch;
    let seeds: Vec<i32> = (0..b as i32).map(|i| 3_000 + i).collect();
    let out = e.rollout(&params, None, &prompts, &pads, &seeds, 1.0).unwrap();
    let scored = e.score(&params, None, &out.tokens, &pads).unwrap();
    let g = e.meta.gen_len;
    for row in 0..b {
        for j in 0..g {
            if out.gen_mask.at2(row, j) > 0.5 {
                let a = out.logprobs.at2(row, j);
                let s = scored.at2(row, j);
                assert!(
                    (a - s).abs() < 2e-3,
                    "row {row} pos {j}: rollout {a} vs score {s}"
                );
            }
        }
    }
}

#[test]
fn grad_zero_at_zero_advantage_and_update_applies() {
    let Some(e) = engine() else { return };
    let mut store = ParamStore::new(e.init(3).unwrap());
    let problem = TaskKind::Arith.generate(Split::Train, 2);
    let short: Vec<i32> = problem.prompt.iter().copied().take(8).collect();
    let (prompts, pads) = prompt_batch(&e, &short).unwrap();
    let br = e.meta.config.rollout_batch;
    let seeds: Vec<i32> = (0..br as i32).map(|i| 5_000 + i).collect();
    let out = e.rollout(&store.params, None, &prompts, &pads, &seeds, 1.0).unwrap();
    let bu = e.meta.config.update_batch;
    let t = e.meta.config.seq_len;
    let g = e.meta.gen_len;
    let mk_mb = |adv: Vec<f32>| MicroBatch {
        tokens: TensorI::new(out.tokens.data[..bu * t].to_vec(), &[bu, t]).unwrap(),
        pad_len: pads[..bu].to_vec(),
        gen_mask: TensorF::new(out.gen_mask.data[..bu * g].to_vec(), &[bu, g]).unwrap(),
        old_lp: TensorF::new(out.logprobs.data[..bu * g].to_vec(), &[bu, g]).unwrap(),
        adv,
        ref_lp: TensorF::new(vec![0.0; bu * g], &[bu, g]).unwrap(),
    };
    // zero advantages -> exactly zero gradient and loss
    let out0 = e.grad(&store.params, None, &mk_mb(vec![0.0; bu]), 0.0).unwrap();
    assert!(out0.grads.iter().all(|&x| x.abs() < 1e-7));
    assert!(out0.loss.abs() < 1e-6);
    // nonzero advantages -> nonzero gradient; update changes params
    let mut adv = vec![0.0; bu];
    adv[0] = 1.0;
    if bu > 1 {
        adv[1] = -1.0;
    }
    let out1 = e.grad(&store.params, None, &mk_mb(adv), 0.0).unwrap();
    let gnorm: f32 = out1.grads.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!(gnorm > 1e-4, "gradient norm {gnorm}");
    let before = store.params.clone();
    e.update(&mut store, &out1.grads, 1e-3).unwrap();
    assert_eq!(store.step, 1);
    let delta: f32 = store
        .params
        .iter()
        .zip(&before)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(delta > 1e-6 && delta <= 1.3e-3, "max param delta {delta}");
}

#[test]
fn sft_learns_a_constant_sequence() {
    let Some(e) = engine() else { return };
    let mut store = ParamStore::new(e.init(4).unwrap());
    let bu = e.meta.config.update_batch;
    let t = e.meta.config.seq_len;
    // teach it to repeat digit 5 forever
    let tokens = TensorI::new(vec![tok::DIGIT0 + 5; bu * t], &[bu, t]).unwrap();
    let mask = TensorF::new(vec![1.0; bu * t], &[bu, t]).unwrap();
    let pads = vec![0i32; bu];
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for i in 0..30 {
        let loss = e.sft_step(&mut store, &tokens, &pads, &mask, 5e-3).unwrap();
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first * 0.5, "SFT loss did not drop: {first} -> {last}");
    assert_eq!(store.step, 30);
}

#[test]
fn generate_group_end_to_end() {
    let Some(e) = engine() else { return };
    let params = e.init(5).unwrap();
    // arith prompts can exceed micro's prompt_len=8; build a tiny custom one
    let problem = {
        let mut p = TaskKind::Arith.generate(Split::Train, 3);
        p.prompt.truncate(8);
        p
    };
    let req = GenRequest {
        params: &params,
        lora: None,
        ref_params: None,
        ref_lora: None,
        n: 10, // 10 rows through B_r = 4 slots with continuous refill
        temperature: 1.0,
        run_seed: 42,
        iter: 0,
        weights: RewardWeights::default(),
        decode_chunk: 4,
        refill: RefillMode::Continuous,
        kv: KvPolicy::default(),
    };
    let (group, stats) = generate_group(&e, &req, TaskKind::Arith, &problem).unwrap();
    assert_eq!(group.rollouts.len(), 10);
    // at least the initial prefill and one decode chunk ran
    assert!(stats.calls >= 2, "calls = {}", stats.calls);
    assert!(stats.total_gen_tokens > 0);
    assert!(stats.gen_tokens_decoded >= stats.total_gen_tokens);
    assert_eq!(
        stats.gen_tokens_wasted,
        stats.gen_tokens_decoded - stats.total_gen_tokens
    );
    for r in &group.rollouts {
        assert_eq!(r.tokens.len(), e.meta.config.seq_len);
        assert_eq!(r.gen_mask.len(), e.meta.gen_len);
        assert!(r.total_reward >= 0.0);
    }
}

#[test]
fn kl_reference_scoring_path() {
    let Some(e) = engine() else { return };
    let params = e.init(6).unwrap();
    let ref_params = e.init(60).unwrap();
    let problem = {
        let mut p = TaskKind::Mcq.generate(Split::Train, 4);
        p.prompt.truncate(8);
        p
    };
    let req = GenRequest {
        params: &params,
        lora: None,
        ref_params: Some(&ref_params),
        ref_lora: None,
        n: 4,
        temperature: 1.0,
        run_seed: 1,
        iter: 0,
        weights: RewardWeights::default(),
        decode_chunk: 4,
        refill: RefillMode::Continuous,
        kv: KvPolicy::default(),
    };
    let (group, _) = generate_group(&e, &req, TaskKind::Mcq, &problem).unwrap();
    // ref_lp must differ from old_lp (different parameters)
    let any_diff = group.rollouts.iter().any(|r| {
        r.old_lp
            .iter()
            .zip(&r.ref_lp)
            .zip(&r.gen_mask)
            .any(|((o, f), m)| *m > 0.5 && (o - f).abs() > 1e-3)
    });
    assert!(any_diff, "reference scoring should use the reference params");
}
