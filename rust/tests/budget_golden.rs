//! Goldens for adaptive per-prompt rollout budgets (`[budget]`).
//!
//! The allocator's determinism contract (docs/DETERMINISM.md):
//!
//! * **Disabled budgeting is the baseline.** With `budget.enabled =
//!   false` the trained parameters and every training-CSV column (modulo
//!   the real wall-clock column) are bit-identical whatever the other
//!   budget knobs say, and the budget telemetry columns are pinned at
//!   zero.
//! * **Allocation is history, not partition.** With budgeting enabled,
//!   the probe barrier makes the allocation sequence — and hence the
//!   extra rows, the assembled groups, and the trained parameters — a
//!   pure function of `(run_seed, probe outcomes)`: 1 worker and a
//!   4-worker pool, and different decode-chunk sizes, land on bit-
//!   identical state.
//! * **Budget is conserved.** Over random specs, observation histories
//!   and observation orders, the allocator never grants more than
//!   `(n − n_probe) × |groups|` extra slots, never takes a prompt past
//!   `max_per_prompt`, assigns contiguous rollout indices from
//!   `n_probe`, and returns the identical sequence for any reordering
//!   of the same history.
//!
//! The allocator-level property suite runs everywhere; the trainer
//! goldens are skipped when artifacts are absent (CI without
//! `make artifacts`).

mod common;

use pods::coordinator::scheduler::{BudgetAllocator, BudgetSpec, Trainer};
use pods::metrics::CsvRow;
use pods::util::prop::for_cases;

/// Rewards on the rule-based model's 0.25 grid in [0, 3].
fn grid_reward(rng: &mut pods::util::rng::Rng) -> f32 {
    0.25 * rng.below(13) as f32
}

/// Budget conservation and history purity over random `(groups, spec,
/// history, schedule)` draws: the grant sequence respects both caps,
/// assigns contiguous per-group rollout indices starting at `n_probe`,
/// and is bit-identical under any reordering of the same observations —
/// the property behind worker-partition and refill-order invariance.
#[test]
fn allocation_conserves_budget_and_ignores_observation_order() {
    for_cases(300, |rng| {
        let groups = 1 + rng.below(12);
        let n = 1 + rng.below(64);
        let n_probe = 1 + rng.below(n);
        let max_per_prompt = n_probe + rng.below(2 * n + 1);
        let width_threshold = 0.25 * rng.below(8) as f64;
        let spec = BudgetSpec { n, n_probe, max_per_prompt, width_threshold };
        // a random probe history: some groups rich, some thin, some empty
        let mut history: Vec<(usize, f32)> = Vec::new();
        for g in 0..groups {
            for _ in 0..rng.below(n_probe + 1) {
                history.push((g, grid_reward(rng)));
            }
        }
        let mut alloc = BudgetAllocator::new(spec, groups);
        for &(g, r) in &history {
            alloc.observe(g, r);
        }
        let grants = alloc.allocate();

        // conservation: never more than the released slots in total
        assert!(
            grants.len() <= (n - n_probe) * groups,
            "granted {} of at most {} slots ({spec:?})",
            grants.len(),
            (n - n_probe) * groups
        );
        // per-prompt cap, and contiguous indices from n_probe per group
        let mut per = vec![n_probe; groups];
        for &(g, r) in &grants {
            assert_eq!(r as usize, per[g], "rollout indices must be contiguous from n_probe");
            per[g] += 1;
            assert!(per[g] <= max_per_prompt, "group {g} exceeded max_per_prompt ({spec:?})");
        }
        // saturated groups (incl. never-observed ones) get nothing extra
        for g in 0..groups {
            if alloc.is_saturated(g) {
                assert_eq!(per[g], n_probe, "saturated group {g} was granted extras ({spec:?})");
            }
        }
        // history purity: any observation order yields the same sequence
        // (this is what a different worker partition or refill order is)
        let mut shuffled = history.clone();
        rng.shuffle(&mut shuffled);
        let mut alloc2 = BudgetAllocator::new(spec, groups);
        for &(g, r) in &shuffled {
            alloc2.observe(g, r);
        }
        assert_eq!(grants, alloc2.allocate(), "allocation depended on observation order");
    });
}

/// Disabled budgeting is the baseline: moving every other `[budget]`
/// knob changes nothing — parameters bitwise, every training-CSV row
/// bitwise (modulo the real wall-clock column), budget telemetry pinned
/// at zero.
#[test]
fn disabled_budget_is_bitwise_identical_to_fixed_n() {
    let Some(dir) = common::artifacts() else { return };
    let run = |name: &str, n_probe: usize, width_threshold: f64| {
        let mut b = common::tiny_builder(name, "pods_budget_golden");
        b.budget_n_probe = n_probe;
        b.budget_width_threshold = width_threshold;
        common::train(&dir, b.build().unwrap(), 2)
    };
    let base = run("budget_off_a", 8, 0.25);
    let moved = run("budget_off_b", 2, 9.0);
    assert_eq!(
        base.store.params, moved.store.params,
        "disabled budget must be bit-identical whatever the other budget knobs say"
    );
    let csv = |tr: &Trainer| {
        tr.recorder
            .iters
            .iter()
            .map(|r| {
                let mut r = r.clone();
                r.real_time = 0.0; // the only column allowed to move
                r.csv_row()
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(csv(&base), csv(&moved), "disabled budget must leave the training CSV bitwise");
    for r in &base.recorder.iters {
        assert_eq!(r.budget_extra_rows, 0, "disabled budget must grant nothing");
        assert_eq!(r.budget_saturated_groups, 0, "disabled budget must observe nothing");
    }
}

/// Allocation is history, not partition: with budgeting enabled, the
/// worker-pool size and the decode-chunk size change neither the
/// allocation sequence (telemetry columns) nor the trained parameters.
/// At `width_threshold = 0` every observed group stays in the heap, so
/// the full released budget is always granted (non-vacuity) and the
/// decoded row set equals the fixed-`n` run's — which pins the adaptive
/// path's parameters against the baseline too.
#[test]
fn enabled_allocation_is_invariant_to_workers_and_chunk() {
    let Some(dir) = common::artifacts() else { return };
    let iters = 2;
    let run = |name: &str, enabled: bool, workers: usize, chunk: usize| {
        let mut b = common::tiny_builder(name, "pods_budget_golden");
        b.workers = workers;
        b.decode_chunk = chunk;
        b.schedule = "sync".into();
        b.budget_enabled = enabled;
        b.budget_n_probe = 4;
        b.budget_width_threshold = 0.0;
        common::train(&dir, b.build().unwrap(), iters)
    };
    let w1 = run("budget_w1_c4", true, 1, 4);
    let w4 = run("budget_w4_c4", true, 4, 4);
    let c8 = run("budget_w1_c8", true, 1, 8);
    assert_eq!(
        w1.store.params, w4.store.params,
        "worker count changed trained parameters under budgeting"
    );
    assert_eq!(
        w1.store.params, c8.store.params,
        "decode-chunk size changed trained parameters under budgeting"
    );
    let alloc_trace = |tr: &Trainer| {
        tr.recorder
            .iters
            .iter()
            .map(|r| (r.rollouts_generated, r.budget_extra_rows, r.budget_saturated_groups))
            .collect::<Vec<_>>()
    };
    assert_eq!(alloc_trace(&w1), alloc_trace(&w4), "allocation must be partition-invariant");
    assert_eq!(alloc_trace(&w1), alloc_trace(&c8), "allocation must be chunk-invariant");
    // non-vacuity: at threshold 0 the probe wave observes every group,
    // so the full released budget is granted every iteration
    for r in &w1.recorder.iters {
        // the fixture runs 2 groups at n = 16 with n_probe = 4: the
        // allocator must release and grant exactly (16 − 4) × 2 slots
        assert_eq!(r.budget_extra_rows, 24, "the full released budget must be granted");
        assert_eq!(r.rollouts_generated, 32, "probe + extras must equal n × |groups|");
        assert_eq!(r.budget_saturated_groups, 0, "threshold 0 saturates nothing observed");
    }
    // threshold 0 grants every group back to exactly n rollouts: the
    // decoded row set (and its per-row seeds) equals the fixed-n run's,
    // so the adaptive path must train the baseline's exact parameters
    let fixed = run("budget_fixed_n", false, 1, 4);
    assert_eq!(
        w1.store.params, fixed.store.params,
        "threshold-0 budgeting must reproduce the fixed-n parameters bitwise"
    );
    assert_eq!(
        alloc_trace(&w1)
            .iter()
            .map(|&(gen, _, _)| gen)
            .collect::<Vec<_>>(),
        alloc_trace(&fixed)
            .iter()
            .map(|&(gen, _, _)| gen)
            .collect::<Vec<_>>(),
        "threshold-0 budgeting must decode the fixed-n rollout count"
    );
}
