//! Shared fixtures for the trainer-level golden tests.
//!
//! Every `*_golden.rs` suite that drives the real [`Trainer`] needs the
//! same three pieces: the artifact gate (skip cleanly when `make
//! artifacts` has not run), the standard tiny config (2-prompt
//! iterations of the `pods` kind on the `base` profile, `n = 16 → m =
//! 4`), and the quiet train-N-iterations runner. They used to be
//! copy-pasted per file; this module is the single source so a fixture
//! change (a new required config knob, a different artifact layout)
//! lands in one place.
//!
//! Each test binary compiles its own copy of this module and rarely uses
//! every helper, hence the file-level `dead_code` allow.

#![allow(dead_code)]

use pods::config::RunConfig;
use pods::coordinator::scheduler::Trainer;
use pods::exp::CfgBuilder;
use std::path::{Path, PathBuf};

/// Artifact gate for trainer-level goldens: `Some(dir)` when the `base`
/// profile's artifacts exist, `None` (after printing the standard skip
/// line) otherwise. Callers `let Some(dir) = artifacts() else { return }`.
pub fn artifacts() -> Option<PathBuf> {
    let dir = pods::default_artifacts_dir();
    if dir.join("base/meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: base artifacts missing (run `make artifacts`)");
        None
    }
}

/// The standard tiny trainer fixture: 2 iterations × 2 prompts of the
/// `pods` kind on the `base` arith profile, `n = 16 → m = 4`, eval out
/// of the way. Returns the builder so each suite can move the knobs it
/// is actually testing before `.build()`.
pub fn tiny_builder(name: &str, out_subdir: &str) -> CfgBuilder {
    CfgBuilder {
        name: name.into(),
        profile: "base".into(),
        task: "arith".into(),
        iterations: 2,
        prompts_per_iter: 2,
        eval_every: 10,
        eval_problems: 8,
        kind: "pods".into(),
        n: 16,
        m: Some(4),
        lr: 1e-4,
        out_dir: std::env::temp_dir().join(out_subdir).to_string_lossy().into_owned(),
        ..Default::default()
    }
}

/// Build a trainer on `cfg`, silence the engine, and run `iters`
/// training iterations — the body every trainer golden repeats.
pub fn train(dir: &Path, cfg: RunConfig, iters: usize) -> Trainer {
    let mut tr = Trainer::new(dir, cfg).unwrap();
    tr.engine.quiet = true;
    for it in 0..iters {
        tr.train_iteration(it).unwrap();
    }
    tr
}
