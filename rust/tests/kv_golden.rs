//! Goldens for group-shared prompt KV and the paged-pool admission model.
//!
//! The load-bearing invariant (docs/DETERMINISM.md): because per-row RNG
//! is counter-based and attention is row-local, **prefilling a group's
//! prompt once and admitting sibling rows from the on-device snapshot is
//! bit-identical to per-row prefill** — same tokens, logprobs, gen_mask,
//! lengths — whatever the chunk size, refill mode, queue order, pool
//! capacity, or worker count. Sharing and admission gating may only move
//! *cost telemetry* (prefill_calls, kv_peak_bytes), never a stream.
//!
//! Runs on the `micro` artifacts (the trainer golden on `base`); skipped
//! when absent.

use pods::hwsim::HwModel;
use pods::rollout::{decode_rows_kv, plan_rows, KvPolicy, RefillMode, RowOut, RowSpec};
use pods::runtime::Engine;
use pods::tasks::{Split, TaskKind};
use pods::util::prop::for_cases;

fn engine() -> Option<Engine> {
    let dir = pods::default_artifacts_dir();
    if !dir.join("micro/meta.json").exists() {
        eprintln!("skipping: micro artifacts missing (run `make artifacts`)");
        return None;
    }
    let mut e = Engine::load(&dir, "micro").expect("engine load");
    e.quiet = true;
    Some(e)
}

/// Micro-profile problems with prompts clipped to prompt_len.
fn problems(e: &Engine, k: usize) -> Vec<pods::tasks::Problem> {
    let p = e.meta.config.prompt_len;
    (0..k as u64)
        .map(|i| {
            let mut pr = TaskKind::Arith.generate(Split::Train, i);
            pr.prompt.truncate(p);
            pr
        })
        .collect()
}

/// The sharing policy the executor builds for this engine's profile
/// (unbounded pool unless the test overrides it).
fn shared_policy(e: &Engine) -> KvPolicy {
    let hw = HwModel::default();
    KvPolicy::from_model(&hw, true, e.meta.config.prompt_len, e.meta.gen_len)
}

/// Key rows by (group, rollout) for order-independent comparison.
fn by_identity(outs: &[RowOut]) -> Vec<(usize, usize, &RowOut)> {
    let mut v: Vec<_> = outs.iter().map(|r| (r.group_idx, r.rollout_idx, r)).collect();
    v.sort_by_key(|(g, j, _)| (*g, *j));
    v
}

fn assert_streams_equal(a: &[RowOut], b: &[RowOut], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: row count");
    for ((ga, ja, ra), (gb, jb, rb)) in by_identity(a).into_iter().zip(by_identity(b)) {
        assert_eq!((ga, ja), (gb, jb), "{what}: row identity");
        assert_eq!(ra.tokens, rb.tokens, "{what}: tokens of ({ga},{ja})");
        assert_eq!(ra.logprobs, rb.logprobs, "{what}: logprobs of ({ga},{ja})");
        assert_eq!(ra.gen_mask, rb.gen_mask, "{what}: gen_mask of ({ga},{ja})");
        assert_eq!(ra.gen_len, rb.gen_len, "{what}: gen_len of ({ga},{ja})");
        assert_eq!(ra.pad_len, rb.pad_len, "{what}: pad_len of ({ga},{ja})");
    }
}

/// Tentpole golden: shared prefill reproduces the per-row-prefill streams
/// bit for bit on a multi-group queue, for every chunk size and refill
/// mode — while paying at most one prefill per group (the queue is
/// group-major) and serving at least one refill event from the snapshot.
#[test]
fn shared_prefill_streams_bit_identical_across_chunks_and_refill() {
    let Some(e) = engine() else { return };
    let params = e.init(2).unwrap();
    let ps = problems(&e, 3);
    let rows = plan_rows(&ps, 6, 11, 3); // 18 rows through 4 slots
    let chunks = e.meta.decode_chunks.clone();
    let (reference, ref_stats) = decode_rows_kv(
        &e, &params, None, 1.0, chunks[0], RefillMode::Continuous, &rows, &ps, None,
        KvPolicy::default(),
    )
    .unwrap();
    assert_eq!(ref_stats.prefill_calls_saved, 0, "legacy policy must never share");
    assert_eq!(ref_stats.kv_peak_bytes, 0, "legacy policy models no pages");
    for &chunk in &chunks {
        for refill in [RefillMode::Continuous, RefillMode::Batch] {
            let (outs, stats) = decode_rows_kv(
                &e, &params, None, 1.0, chunk, refill, &rows, &ps, None, shared_policy(&e),
            )
            .unwrap();
            let what = format!("C={chunk} refill={}", refill.name());
            assert_streams_equal(&reference, &outs, &what);
            assert!(
                stats.prefill_calls <= ps.len(),
                "{what}: {} prefills for {} groups — sharing must pay at most one \
                 prompt pass per group on a group-major queue",
                stats.prefill_calls,
                ps.len()
            );
            // every group (6 rows) outlives the 4 slots, so refill events
            // within a group exist and must ride the snapshot
            assert!(stats.prefill_calls_saved > 0, "{what}: no refill used the snapshot");
            assert!(stats.kv_peak_bytes > 0, "{what}: pool accounting never ran");
        }
    }
}

/// Queue (admission) order cannot change any row's stream under sharing:
/// shuffled queues break group adjacency — costing extra prefills — but
/// the per-rollout outputs stay identical to the legacy path.
#[test]
fn shared_streams_invariant_to_refill_order() {
    let Some(e) = engine() else { return };
    let params = e.init(3).unwrap();
    let ps = problems(&e, 2);
    let rows = plan_rows(&ps, 5, 5, 1); // 10 rows, 4 slots
    let (reference, _) = decode_rows_kv(
        &e, &params, None, 1.2, 4, RefillMode::Continuous, &rows, &ps, None, KvPolicy::default(),
    )
    .unwrap();
    let mut rng = pods::util::rng::Rng::seed_from_u64(99);
    for case in 0..4 {
        let mut shuffled: Vec<RowSpec> = rows.clone();
        for i in (1..shuffled.len()).rev() {
            let j = (rng.next_u64() % (i as u64 + 1)) as usize;
            shuffled.swap(i, j);
        }
        let (outs, _) = decode_rows_kv(
            &e, &params, None, 1.2, 4, RefillMode::Continuous, &shuffled, &ps, None,
            shared_policy(&e),
        )
        .unwrap();
        assert_streams_equal(&reference, &outs, &format!("shuffle case {case}"));
    }
}

/// A bounded pool queues admissions (vLLM-style) without changing any
/// stream, and its high-water mark respects the configured capacity —
/// with sharing on (prompt pages counted once per resident group) and
/// off (prompt pages counted per row).
#[test]
fn bounded_pool_queues_admissions_without_changing_streams() {
    let Some(e) = engine() else { return };
    let params = e.init(4).unwrap();
    let ps = problems(&e, 3);
    let rows = plan_rows(&ps, 6, 7, 2);
    let (reference, _) = decode_rows_kv(
        &e, &params, None, 1.0, 4, RefillMode::Continuous, &rows, &ps, None, KvPolicy::default(),
    )
    .unwrap();
    let base = shared_policy(&e);
    // shared: one group prompt resident + two generation reservations;
    // unshared: two full rows. Both force admission stalls (4 slots).
    let arms = [
        (true, base.prompt_bytes + 2 * base.gen_bytes),
        (false, 2 * (base.prompt_bytes + base.gen_bytes)),
    ];
    for (share, pool_bytes) in arms {
        let kv = KvPolicy { share_prompt_kv: share, pool_bytes, ..base };
        let (outs, stats) = decode_rows_kv(
            &e, &params, None, 1.0, 4, RefillMode::Continuous, &rows, &ps, None, kv,
        )
        .unwrap();
        let what = format!("share={share} pool={pool_bytes}");
        assert_streams_equal(&reference, &outs, &what);
        assert!(stats.kv_peak_bytes > 0, "{what}: pool accounting never ran");
        assert!(
            stats.kv_peak_bytes <= pool_bytes,
            "{what}: peak {} exceeded the modeled capacity",
            stats.kv_peak_bytes
        );
    }
}

/// A pool too small for even one decode row must fail loudly, naming the
/// knob to raise — never deadlock or silently drop rows.
#[test]
fn starved_pool_fails_with_a_descriptive_error() {
    let Some(e) = engine() else { return };
    let params = e.init(5).unwrap();
    let ps = problems(&e, 1);
    let rows = plan_rows(&ps, 4, 1, 0);
    let kv = KvPolicy { pool_bytes: 1, ..shared_policy(&e) };
    let err = decode_rows_kv(
        &e, &params, None, 1.0, 4, RefillMode::Continuous, &rows, &ps, None, kv,
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("kv_pool_bytes"), "unhelpful starvation error: {msg}");
}

/// Property suite: for random group counts, group sizes, chunk sizes,
/// refill modes, queue orders and pool capacities, the shared-prefill
/// driver's streams are bit-identical to the legacy per-row-prefill
/// reference on the same planned rows.
#[test]
fn shared_prefill_is_bit_identical_under_random_schedules() {
    let Some(e) = engine() else { return };
    let params = e.init(6).unwrap();
    let chunks = e.meta.decode_chunks.clone();
    let base = shared_policy(&e);
    let shared_events = std::cell::Cell::new(0usize);
    for_cases(16, |rng| {
        let groups = 1 + rng.below(3);
        let n = 1 + rng.below(8);
        let ps = problems(&e, groups);
        let rows = plan_rows(&ps, n, rng.next_u64(), rng.below(10) as u64);
        let (reference, _) = decode_rows_kv(
            &e, &params, None, 1.0, chunks[0], RefillMode::Continuous, &rows, &ps, None,
            KvPolicy::default(),
        )
        .unwrap();
        let chunk = chunks[rng.below(chunks.len())];
        let refill = if rng.gen_bool(0.5) { RefillMode::Continuous } else { RefillMode::Batch };
        let mut queue = rows.clone();
        if rng.gen_bool(0.5) {
            rng.shuffle(&mut queue);
        }
        // unbounded, or bounded but able to hold at least one row in
        // either accounting mode (prompt pages + generation reservation)
        let min_pool = base.prompt_bytes + base.gen_bytes;
        let pool_bytes =
            if rng.gen_bool(0.5) { 0 } else { min_pool + rng.below(4) as u64 * base.gen_bytes };
        let kv = KvPolicy { share_prompt_kv: true, pool_bytes, ..base };
        let (outs, stats) = decode_rows_kv(
            &e, &params, None, 1.0, chunk, refill, &queue, &ps, None, kv,
        )
        .unwrap();
        let what = format!("groups={groups} n={n} C={chunk} pool={pool_bytes}");
        assert_streams_equal(&reference, &outs, &what);
        if pool_bytes > 0 {
            assert!(stats.kv_peak_bytes <= pool_bytes, "{what}: pool overflowed");
        }
        shared_events.set(shared_events.get() + stats.prefill_calls_saved);
    });
    // the generator must actually exercise snapshot admissions, not
    // vacuously pass on single-admission queues
    assert!(
        shared_events.get() > 0,
        "no case admitted a row from the shared snapshot — the generator no \
         longer exercises prefill sharing"
    );
}

/// Worker-pool determinism: shared-KV generation through the rollout
/// thread pool is bit-identical across worker counts, and identical to
/// the per-row-prefill pool (each worker shard holds its own pool and
/// snapshot; sharding never changes a stream).
#[test]
fn pool_generation_with_shared_kv_is_invariant_across_worker_counts() {
    use pods::coordinator::exec::{GenBatch, RolloutEngine};
    use pods::reward::RewardWeights;
    use std::sync::Arc;
    let Some(e) = engine() else { return };
    let dir = pods::default_artifacts_dir();
    let params = Arc::new(e.init(7).unwrap());
    let ps = Arc::new(problems(&e, 3));
    let gen_with = |workers: usize, kv: KvPolicy| {
        let mut pool = RolloutEngine::new(dir.clone(), "micro", workers);
        let batch = GenBatch {
            params: Arc::clone(&params),
            lora: None,
            ref_params: None,
            ref_lora: None,
            problems: Arc::clone(&ps),
            n: 10, // not a multiple of B_r: slots refill across groups
            temperature: 1.0,
            run_seed: 13,
            iter: 2,
            task: TaskKind::Arith,
            weights: RewardWeights::default(),
            decode_chunk: 4,
            refill: RefillMode::Continuous,
            online: None,
            kv,
        };
        pool.generate(&e, batch).unwrap()
    };
    let (legacy, _) = gen_with(1, KvPolicy::default());
    for workers in [1usize, 3] {
        let (shared, stats) = gen_with(workers, shared_policy(&e));
        assert_eq!(legacy.len(), shared.len());
        for (a, b) in legacy.iter().zip(&shared) {
            assert_eq!(a.problem.id, b.problem.id);
            assert_eq!(a.rollouts.len(), b.rollouts.len());
            for (ra, rb) in a.rollouts.iter().zip(&b.rollouts) {
                assert_eq!(ra.tokens, rb.tokens, "{workers}w sharing changed sampled tokens");
                assert_eq!(ra.old_lp, rb.old_lp);
                assert_eq!(ra.total_reward, rb.total_reward);
                assert_eq!(ra.gen_len, rb.gen_len);
            }
        }
        assert!(stats.prefill_calls > 0);
        assert!(stats.kv_peak_bytes > 0, "{workers}w: pool accounting never ran");
    }
}

/// Trainer-level golden (artifact-gated): `share_prompt_kv = true` trains
/// bit-identical parameters to the per-row-prefill path on the same seed,
/// while paying at most one prefill per prompt group and recording the
/// sharing telemetry in the iteration rows.
#[test]
fn shared_prefill_trains_bit_identical_params() {
    use pods::exp::CfgBuilder;
    let dir = pods::default_artifacts_dir();
    if !dir.join("base/meta.json").exists() {
        eprintln!("skipping: base artifacts missing (run `make artifacts`)");
        return;
    }
    let run = |share_prompt_kv: bool| {
        let cfg = CfgBuilder {
            name: format!("kv_golden_{share_prompt_kv}"),
            profile: "base".into(),
            task: "arith".into(),
            iterations: 2,
            prompts_per_iter: 2,
            eval_every: 10,
            eval_problems: 8,
            kind: "pods".into(),
            n: 32, // 2 groups of 32 through B_r = 16 slots: real refill traffic
            m: Some(4),
            lr: 1e-4,
            decode_chunk: 4,
            share_prompt_kv,
            out_dir: std::env::temp_dir().join("pods_kv_golden").to_string_lossy().into_owned(),
            ..Default::default()
        }
        .build()
        .unwrap();
        let mut tr = pods::coordinator::scheduler::Trainer::new(&dir, cfg).unwrap();
        tr.engine.quiet = true;
        for it in 0..2 {
            tr.train_iteration(it).unwrap();
        }
        tr
    };
    let perrow = run(false);
    let shared = run(true);
    assert_eq!(
        perrow.store.params, shared.store.params,
        "prompt-KV sharing changed trained parameters — the bit-identity \
         contract is broken"
    );
    for (a, b) in perrow.recorder.iters.iter().zip(&shared.recorder.iters) {
        // identical rollouts, selections and updates; only prefill/pool
        // telemetry and the inference-time charge may move
        assert_eq!(a.rollouts_trained, b.rollouts_trained);
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.sel_variance, b.sel_variance);
        assert_eq!(a.gen_tokens_decoded, b.gen_tokens_decoded);
        assert_eq!(a.prefill_calls_saved, 0, "sharing off must record zero");
        assert!(
            b.prefill_calls <= 2,
            "shared arm ran {} prefills for 2 prompt groups — must be at most \
             one per admitted group",
            b.prefill_calls
        );
        assert!(
            b.prefill_calls < a.prefill_calls,
            "sharing must eliminate refill-event prefills ({} vs {})",
            b.prefill_calls,
            a.prefill_calls
        );
        assert!(b.prefill_calls_saved > 0, "refill events must ride the snapshot");
        assert!(b.kv_peak_bytes > 0, "shared arm must account pool pages");
    }
}
