//! Golden tests for the fault-tolerance layer (ISSUE #8).
//!
//! * The fault schedule is history, not partition: the same seed loses the
//!   same rows and injects the same faults for any worker-pool size.
//! * Transient faults with enough retry budget reproduce the fault-free
//!   trained parameters bitwise — retried rows replay identical tokens.
//! * `enabled = true` with all-zero rates is bit-identical to a disabled
//!   section, clock included.
//! * A run killed at a snapshot boundary and resumed with `--resume`
//!   lands on the uninterrupted run's parameters, clock and CSVs — for
//!   both executor schedules.
//!
//! Trainer-level tests are skipped when artifacts are absent (CI without
//! `make artifacts`); the plan-level property test always runs.

use pods::config::{CkptSection, RunConfig};
use pods::coordinator::scheduler::Trainer;
use pods::exp::CfgBuilder;
use pods::hwsim::FaultSection;
use pods::metrics::CsvRow;
use pods::util::prop;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pods::default_artifacts_dir();
    if dir.join("base/meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: base artifacts missing (run `make artifacts`)");
        None
    }
}

/// A small-but-real run config: 2 prompts x n=16 rollouts per iteration.
/// `out_sub` isolates each arm's CSVs and resume snapshot; the directory
/// is wiped so stale state from an earlier test run cannot leak in.
fn cfg(
    name: &str,
    schedule: &str,
    workers: usize,
    iterations: usize,
    faults: FaultSection,
    ckpt_every: usize,
    out_sub: &str,
) -> RunConfig {
    let out = std::env::temp_dir().join("pods_fault_golden").join(out_sub);
    std::fs::remove_dir_all(&out).ok();
    CfgBuilder {
        name: name.into(),
        profile: "base".into(),
        task: "arith".into(),
        iterations,
        prompts_per_iter: 2,
        eval_every: 2,
        eval_problems: 16,
        kind: "pods".into(),
        n: 16,
        m: Some(4),
        lr: 1e-4,
        workers,
        schedule: schedule.into(),
        faults,
        ckpt: CkptSection { every: ckpt_every, path: None },
        out_dir: out.to_string_lossy().into_owned(),
        ..Default::default()
    }
    .build()
    .unwrap()
}

/// One CSV row with the wall-clock column blanked — `real_time` (index 2
/// in both schemas) measures this process, not the simulated run, so it
/// is the one column resume cannot and need not reproduce.
fn strip_realtime(row: &str) -> String {
    row.split(',')
        .enumerate()
        .map(|(i, f)| if i == 2 { "_" } else { f })
        .collect::<Vec<_>>()
        .join(",")
}

/// Tentpole golden (a): the set of injected faults, the rows lost after
/// retries, and the trained parameters are bit-identical across
/// worker-pool sizes. Only physical shard-retry counts may move with the
/// partition.
#[test]
fn fault_schedule_is_pool_size_invariant() {
    let Some(dir) = artifacts() else { return };
    let faults = FaultSection {
        enabled: true,
        crash_rate: 0.08,
        transient_rate: 0.08,
        oom_rate: 0.04,
        straggler_rate: 0.1,
        max_retries: 2,
        ..FaultSection::default()
    };
    let iters = 2;
    let run = |workers: usize| {
        let c = cfg("golden_pool_faults", "sync", workers, iters, faults.clone(), 0, "pool");
        let mut tr = Trainer::new(&dir, c).unwrap();
        tr.engine.quiet = true;
        let stats: Vec<_> = (0..iters).map(|it| tr.train_iteration(it).unwrap()).collect();
        (tr, stats)
    };
    let (tr1, s1) = run(1);
    let (tr4, s4) = run(4);
    assert_eq!(
        tr1.store.params, tr4.store.params,
        "worker-pool size changed trained parameters under fault injection"
    );
    let mut injected = 0usize;
    for (a, b) in s1.iter().zip(&s4) {
        assert_eq!(a.faults_injected, b.faults_injected, "fault schedule moved with the pool");
        assert_eq!(a.rows_lost, b.rows_lost, "row losses moved with the pool");
        assert_eq!(
            a.retry_time.to_bits(),
            b.retry_time.to_bits(),
            "retry bill must be partition-invariant"
        );
        assert_eq!(a.rollouts_generated, b.rollouts_generated);
        assert_eq!(a.loss, b.loss);
        injected += a.faults_injected;
    }
    assert!(injected > 0, "the golden needs a non-trivial fault schedule to pin anything");
}

/// Golden (b): transient faults that all succeed on retry are invisible
/// to training — parameters match the fault-free run bitwise; only the
/// simulated clock pays (backoff).
#[test]
fn transient_retries_reproduce_fault_free_params() {
    let Some(dir) = artifacts() else { return };
    let iters = 2;
    let faulty = FaultSection {
        enabled: true,
        transient_rate: 0.25,
        max_retries: 10, // per-row loss odds 0.25^11: retries always win
        ..FaultSection::default()
    };
    let run = |faults: FaultSection, sub: &str| {
        let c = cfg("golden_transient", "sync", 2, iters, faults, 0, sub);
        let mut tr = Trainer::new(&dir, c).unwrap();
        tr.engine.quiet = true;
        let stats: Vec<_> = (0..iters).map(|it| tr.train_iteration(it).unwrap()).collect();
        (tr, stats)
    };
    let (clean, _) = run(FaultSection::default(), "transient_clean");
    let (fault, stats) = run(faulty, "transient_fault");
    let injected: usize = stats.iter().map(|s| s.faults_injected).sum();
    let lost: usize = stats.iter().map(|s| s.rows_lost).sum();
    assert!(injected > 0, "transient rate 0.25 over 64 row-slots must inject");
    assert_eq!(lost, 0, "a 10-retry budget must recover every transient fault");
    assert_eq!(
        clean.store.params, fault.store.params,
        "recovered transient faults leaked into training"
    );
    assert!(
        fault.clock.now() > clean.clock.now(),
        "retries must bill simulated backoff time ({} vs {})",
        fault.clock.now(),
        clean.clock.now()
    );
    assert!(stats.iter().any(|s| s.retry_time > 0.0));
}

/// Golden (c): `[faults] enabled = true` with every rate at zero is
/// bit-identical to the disabled default — parameters, simulated clock
/// and both CSVs (modulo the process-wall-clock column).
#[test]
fn zero_rate_faults_are_bit_identical_to_disabled() {
    let Some(dir) = artifacts() else { return };
    let run = |faults: FaultSection, sub: &str| {
        let c = cfg("golden_zero_rate", "sync", 1, 2, faults, 0, sub);
        let mut tr = Trainer::new(&dir, c).unwrap();
        tr.engine.quiet = true;
        tr.run().unwrap();
        tr
    };
    let off = run(FaultSection::default(), "zero_off");
    let on = run(FaultSection { enabled: true, ..FaultSection::default() }, "zero_on");
    assert_eq!(off.store.params, on.store.params);
    assert_eq!(off.clock.now().to_bits(), on.clock.now().to_bits());
    assert_eq!(off.clock.overlap_saved().to_bits(), on.clock.overlap_saved().to_bits());
    assert_eq!(off.recorder.iters.len(), on.recorder.iters.len());
    for (a, b) in off.recorder.iters.iter().zip(&on.recorder.iters) {
        assert_eq!(strip_realtime(&a.csv_row()), strip_realtime(&b.csv_row()));
    }
    assert_eq!(off.recorder.evals.len(), on.recorder.evals.len());
    for (a, b) in off.recorder.evals.iter().zip(&on.recorder.evals) {
        assert_eq!(strip_realtime(&a.csv_row()), strip_realtime(&b.csv_row()));
    }
}

/// Golden (d): kill at a snapshot boundary, resume, and land bitwise on
/// the uninterrupted run — parameters, clock, overlap accounting and both
/// recorder CSVs (modulo `real_time`). Fault injection stays on so the
/// snapshot also has to reproduce the retry bill.
fn resume_roundtrip(schedule: &str) {
    let Some(dir) = artifacts() else { return };
    let iters = 4;
    let kill_at = 2;
    let faults = FaultSection {
        enabled: true,
        crash_rate: 0.05,
        transient_rate: 0.05,
        max_retries: 2,
        ..FaultSection::default()
    };
    // arm A: uninterrupted
    let ca = cfg(
        "golden_resume",
        schedule,
        1,
        iters,
        faults.clone(),
        kill_at,
        &format!("resume_full_{schedule}"),
    );
    let mut a = Trainer::new(&dir, ca).unwrap();
    a.engine.quiet = true;
    a.run().unwrap();

    // arm B: run to the boundary, "crash" (drop the trainer), resume
    let cb = cfg(
        "golden_resume",
        schedule,
        1,
        iters,
        faults,
        kill_at,
        &format!("resume_kill_{schedule}"),
    );
    let mut b = Trainer::new(&dir, cb.clone()).unwrap();
    b.engine.quiet = true;
    b.run_span(kill_at).unwrap();
    drop(b);

    let resume = cb.ckpt.resume_path(&cb.run.out_dir, &cb.run.name);
    assert!(
        std::path::Path::new(&resume).exists(),
        "run_span({kill_at}) must leave a snapshot at {resume}"
    );
    let mut b2 = Trainer::new(&dir, cb).unwrap();
    b2.engine.quiet = true;
    b2.resume_from(std::path::Path::new(&resume)).unwrap();
    b2.run().unwrap();

    assert_eq!(
        a.store.params, b2.store.params,
        "{schedule}: resumed parameters diverged from the uninterrupted run"
    );
    assert_eq!(
        a.clock.now().to_bits(),
        b2.clock.now().to_bits(),
        "{schedule}: resumed clock diverged ({} vs {})",
        a.clock.now(),
        b2.clock.now()
    );
    assert_eq!(a.clock.overlap_saved().to_bits(), b2.clock.overlap_saved().to_bits());
    assert_eq!(a.recorder.iters.len(), b2.recorder.iters.len(), "{schedule}: iter rows");
    for (ra, rb) in a.recorder.iters.iter().zip(&b2.recorder.iters) {
        assert_eq!(
            strip_realtime(&ra.csv_row()),
            strip_realtime(&rb.csv_row()),
            "{schedule}: iter CSV rows diverged after resume"
        );
    }
    assert_eq!(a.recorder.evals.len(), b2.recorder.evals.len(), "{schedule}: eval rows");
    for (ra, rb) in a.recorder.evals.iter().zip(&b2.recorder.evals) {
        assert_eq!(
            strip_realtime(&ra.csv_row()),
            strip_realtime(&rb.csv_row()),
            "{schedule}: eval CSV rows diverged after resume"
        );
    }
}

#[test]
fn resume_after_kill_is_bit_identical_sync() {
    resume_roundtrip("sync");
}

/// The pipelined arm additionally round-trips the in-flight prefetch: at
/// the kill boundary a generation for iteration `kill_at` is already
/// pending, so the snapshot must capture and the resume must rebuild it.
#[test]
fn resume_after_kill_is_bit_identical_pipelined() {
    resume_roundtrip("pipelined");
}

/// Property (always runs, no artifacts): the fault plan is a pure
/// function of its coordinates — two independently built plans agree draw
/// for draw — and the executor's physical retry loop reaches exactly the
/// verdict `row_lost` computes from schedule arithmetic.
#[test]
fn fault_plan_matches_physical_retry_verdicts() {
    prop::for_cases(64, |rng| {
        let sec = FaultSection {
            enabled: true,
            crash_rate: rng.f64() * 0.3,
            transient_rate: rng.f64() * 0.3,
            oom_rate: rng.f64() * 0.2,
            straggler_rate: rng.f64() * 0.5,
            max_retries: rng.below(4),
            ..FaultSection::default()
        };
        sec.validate().unwrap();
        let seed = rng.next_u64();
        let a = sec.plan(seed).unwrap();
        let b = sec.plan(seed).unwrap();
        for iter in 0..3u64 {
            for prompt in 0..3u64 {
                for idx in 0..4u64 {
                    for attempt in 0..=sec.max_retries {
                        assert_eq!(
                            a.row_fault(iter, prompt, idx, attempt),
                            b.row_fault(iter, prompt, idx, attempt),
                            "plan draws must be deterministic"
                        );
                    }
                    assert_eq!(
                        a.row_straggler(iter, prompt, idx),
                        b.row_straggler(iter, prompt, idx)
                    );
                    // physically retry until success or budget exhaustion
                    let mut attempt = 0usize;
                    let lost = loop {
                        match a.row_fault(iter, prompt, idx, attempt) {
                            None => break false,
                            Some(_) if attempt < sec.max_retries => attempt += 1,
                            Some(_) => break true,
                        }
                    };
                    assert_eq!(
                        lost,
                        a.row_lost(iter, prompt, idx),
                        "retry loop and schedule arithmetic disagree"
                    );
                }
            }
        }
    });
}
