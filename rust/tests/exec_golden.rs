//! Golden tests for the staged executor (coordinator::exec).
//!
//! * The sync schedule must reproduce the sequential reference — the seed
//!   trainer's inference phase (`generate_group` prompt-by-prompt), its
//!   selections, losses, parameter updates and simulated times — exactly.
//! * Pool generation must be bit-deterministic across worker counts.
//! * The pipelined schedule must report strictly lower simulated
//!   wall-clock than sync at equal iteration count, with the overlap
//!   identity `now() + overlap_saved() == sequential total` intact.
//!
//! Skipped when artifacts are absent (CI without `make artifacts`).

use pods::config::RunConfig;
use pods::coordinator::exec::{GenBatch, RolloutEngine, UpdateEngine};
use pods::coordinator::group::build_update_batch;
use pods::coordinator::scheduler::Trainer;
use pods::exp::CfgBuilder;
use pods::reward::RewardWeights;
use pods::rollout::{generate_group, GenRequest, KvPolicy, RefillMode};
use pods::runtime::ParamStore;
use pods::tasks::{Split, TaskKind};
use std::sync::Arc;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pods::default_artifacts_dir();
    if dir.join("base/meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: base artifacts missing (run `make artifacts`)");
        None
    }
}

fn cfg(name: &str, schedule: &str, workers: usize, iterations: usize) -> RunConfig {
    CfgBuilder {
        name: name.into(),
        profile: "base".into(),
        task: "arith".into(),
        iterations,
        prompts_per_iter: 2,
        eval_every: iterations.max(1),
        eval_problems: 16,
        kind: "pods".into(),
        n: 16,
        m: Some(4),
        lr: 1e-4,
        workers,
        schedule: schedule.into(),
        out_dir: std::env::temp_dir().join("pods_exec_golden").to_string_lossy().into_owned(),
        ..Default::default()
    }
    .build()
    .unwrap()
}

/// The sync executor's first iteration equals a hand-run of the seed
/// trainer's sequential semantics: same rollouts (via `generate_group`
/// prompt-by-prompt), same selection, same loss, same post-update
/// parameters, same simulated phase times.
#[test]
fn sync_executor_reproduces_sequential_reference() {
    let Some(dir) = artifacts() else { return };
    let c = cfg("golden_sync", "sync", 1, 1);
    let mut tr = Trainer::new(&dir, c.clone()).unwrap();
    tr.engine.quiet = true;

    // ---- sequential reference, from the same initial parameters -------
    let params0 = tr.store.params.clone();
    let problems = TaskKind::Arith.batch(Split::Train, 0, c.run.prompts_per_iter);
    let mut groups = Vec::new();
    let mut total_gen_tokens = 0usize;
    for problem in &problems {
        let req = GenRequest {
            params: &params0,
            lora: None,
            ref_params: None,
            ref_lora: None,
            n: c.algo.n,
            temperature: c.algo.temperature as f32,
            run_seed: c.run.seed,
            iter: 0,
            weights: RewardWeights::default(),
            decode_chunk: c.rollout.decode_chunk,
            refill: c.rollout.refill,
            kv: KvPolicy::default(),
        };
        let (group, stats) = generate_group(&tr.engine, &req, TaskKind::Arith, problem).unwrap();
        total_gen_tokens += stats.total_gen_tokens;
        groups.push(group);
    }
    let rollouts_generated: usize = groups.iter().map(|g| g.rollouts.len()).sum();
    let gen_lens: Vec<usize> = groups
        .iter()
        .flat_map(|g| g.rollouts.iter().map(|r| r.gen_len as usize))
        .collect();
    assert_eq!(total_gen_tokens, gen_lens.iter().sum::<usize>(), "stats vs records drifted");
    let want_sim_inference = c.hwsim.chunked_inference_time(&gen_lens, c.rollout.decode_chunk);
    let (selected, _) = build_update_batch(
        &groups,
        &c.selector(),
        c.algo.m,
        c.norm_mode(),
        c.run.seed,
        0,
    )
    .unwrap();
    let mut ref_store = ParamStore::new(params0);
    let mut ref_update = UpdateEngine::new(ref_store.len());
    let want = ref_update
        .run(&tr.engine, &mut ref_store, None, &groups, &selected, &[], None, &c)
        .unwrap();

    // ---- the executor ------------------------------------------------
    let stats = tr.train_iteration(0).unwrap();
    assert_eq!(stats.rollouts_generated, rollouts_generated);
    assert_eq!(stats.rollouts_trained, want.rollouts_trained);
    assert_eq!(stats.micro_steps, want.micro_steps);
    assert_eq!(stats.loss, want.loss, "sync loss must replay the sequential reference");
    assert_eq!(stats.clip_frac, want.clip_frac);
    assert_eq!(stats.sim_inference, want_sim_inference, "sim inference time drifted");
    assert_eq!(stats.sim_update, want.sim_update, "sim update time drifted");
    assert_eq!(
        stats.sim_step,
        stats.sim_inference + stats.sim_update,
        "sync must charge the phase sum"
    );
    assert_eq!(stats.sim_overlap_saved, 0.0);
    assert_eq!(tr.clock.overlap_saved(), 0.0);
    assert_eq!(tr.store.params, ref_store.params, "post-update parameters must be identical");
}

/// Tentpole golden: the sharded update engine is bit-identical to the
/// monolithic one — same rollouts, same selection, same grad program —
/// for any shard count, while the simulated phase cost moves with the
/// topology (shards add communication, micro-batching adds steps).
#[test]
fn sharded_update_is_bit_identical_to_monolithic() {
    let Some(dir) = artifacts() else { return };
    let c = cfg("golden_shard", "sync", 1, 1);
    let mut tr = Trainer::new(&dir, c.clone()).unwrap();
    tr.engine.quiet = true;
    let params0 = tr.store.params.clone();

    // one iteration's worth of groups + selection, shared by every arm
    let problems = TaskKind::Arith.batch(Split::Train, 0, c.run.prompts_per_iter);
    let mut groups = Vec::new();
    for problem in &problems {
        let req = GenRequest {
            params: &params0,
            lora: None,
            ref_params: None,
            ref_lora: None,
            n: c.algo.n,
            temperature: c.algo.temperature as f32,
            run_seed: c.run.seed,
            iter: 0,
            weights: RewardWeights::default(),
            decode_chunk: c.rollout.decode_chunk,
            refill: c.rollout.refill,
            kv: KvPolicy::default(),
        };
        let (group, _) = generate_group(&tr.engine, &req, TaskKind::Arith, problem).unwrap();
        groups.push(group);
    }
    let (selected, _) =
        build_update_batch(&groups, &c.selector(), c.algo.m, c.norm_mode(), c.run.seed, 0).unwrap();
    assert!(!selected.is_empty());

    let run_with = |shards: usize| {
        let mut cfg_s = c.clone();
        cfg_s.update.shards = shards;
        // micro-batches of 2 rows -> a multi-call plan (4 calls for the 8
        // selected rollouts), so the shard arms genuinely partition the
        // micro-batch sequence instead of collapsing to one call
        cfg_s.update.micro_batch = 2;
        let mut store = ParamStore::new(params0.clone());
        let mut upd = UpdateEngine::new(store.len());
        let out = upd.run(&tr.engine, &mut store, None, &groups, &selected, &[], &cfg_s).unwrap();
        (store, out)
    };
    let (mono_store, mono) = run_with(1);
    assert!(
        mono.micro_steps > 1,
        "the golden needs a multi-micro-batch plan to exercise sharding \
         (got {} call)",
        mono.micro_steps
    );
    for shards in [2usize, 4, 8] {
        let (store, out) = run_with(shards);
        assert_eq!(
            store.params, mono_store.params,
            "shards={shards} changed trained parameters — the shard-invariance \
             contract is broken"
        );
        assert_eq!(out.loss, mono.loss);
        assert_eq!(out.micro_steps, mono.micro_steps, "packing must be shard-agnostic");
        assert!(out.sim_comm > 0.0, "multi-shard update must pay communication");
        assert!(
            out.sim_comm > mono.sim_comm,
            "communication must grow from the monolithic baseline"
        );
    }
    assert_eq!(mono.sim_comm, 0.0, "single shard has nothing to all-reduce");
}

/// Pool generation is deterministic: 1 worker (inline) and 4 workers
/// (thread pool with engine replicas) produce bit-identical rollouts.
#[test]
fn pool_generation_is_deterministic_across_worker_counts() {
    let Some(dir) = artifacts() else { return };
    let mut engine = pods::runtime::Engine::load(&dir, "base").unwrap();
    engine.quiet = true;
    let params = Arc::new(engine.init(3).unwrap());
    let problems = Arc::new(TaskKind::Arith.batch(Split::Train, 0, 3));
    let gen_with = |workers: usize| {
        let mut pool = RolloutEngine::new(dir.clone(), "base", workers);
        let batch = GenBatch {
            params: Arc::clone(&params),
            lora: None,
            ref_params: None,
            ref_lora: None,
            problems: Arc::clone(&problems),
            n: 12, // not a multiple of B_r: slots refill across groups
            temperature: 1.0,
            run_seed: 11,
            iter: 2,
            task: TaskKind::Arith,
            weights: RewardWeights::default(),
            decode_chunk: 16,
            refill: RefillMode::Continuous,
            online: None,
            kv: KvPolicy::default(),
        };
        pool.generate(&engine, batch).unwrap()
    };
    let (g1, s1) = gen_with(1);
    let (g4, s4) = gen_with(4);
    assert_eq!(s1.rollouts, s4.rollouts);
    assert_eq!(s1.total_gen_tokens, s4.total_gen_tokens);
    assert_eq!(g1.len(), g4.len());
    for (a, b) in g1.iter().zip(&g4) {
        assert_eq!(a.problem.id, b.problem.id);
        assert_eq!(a.rollouts.len(), b.rollouts.len());
        for (ra, rb) in a.rollouts.iter().zip(&b.rollouts) {
            assert_eq!(ra.tokens, rb.tokens, "worker count changed sampled tokens");
            assert_eq!(ra.old_lp, rb.old_lp);
            assert_eq!(ra.total_reward, rb.total_reward);
            assert_eq!(ra.gen_len, rb.gen_len);
        }
    }
}

/// Acceptance: pipelined reports strictly lower simulated wall-clock than
/// sync at equal iteration count, the overlap identity holds, and the
/// pipelined run is itself replayable.
#[test]
fn pipelined_beats_sync_simulated_wall_clock() {
    let Some(dir) = artifacts() else { return };
    let iters = 3;
    let run = |schedule: &str| {
        let mut tr = Trainer::new(&dir, cfg("golden_sched", schedule, 1, iters)).unwrap();
        tr.engine.quiet = true;
        for it in 0..iters {
            tr.train_iteration(it).unwrap();
        }
        tr
    };
    let sync = run("sync");
    let pipe = run("pipelined");
    assert!(
        pipe.clock.now() < sync.clock.now(),
        "pipelined {:.2}s must beat sync {:.2}s at {iters} iterations",
        pipe.clock.now(),
        sync.clock.now()
    );
    assert!(pipe.clock.overlap_saved() > 0.0);
    // identity: hidden time + charged time == the run's sequential total
    let seq_total: f64 = pipe
        .recorder
        .iters
        .iter()
        .map(|r| r.sim_inference_time + r.sim_update_time)
        .sum();
    assert!(
        (pipe.clock.now() + pipe.clock.overlap_saved() - seq_total).abs() < 1e-9,
        "overlap accounting leaked time"
    );
    // every iteration's row carries the schedule + step columns
    for r in &pipe.recorder.iters {
        assert_eq!(r.schedule, "pipelined");
        assert!(
            (r.sim_step_time + r.sim_overlap_saved
                - (r.sim_inference_time + r.sim_update_time))
                .abs()
                < 1e-9
        );
    }
    // iteration 0 pays its inference un-overlapped; later ones hide some
    assert_eq!(pipe.recorder.iters[0].sim_overlap_saved, 0.0);
    assert!(pipe.recorder.iters[1].sim_overlap_saved > 0.0);
    // replayable: a second pipelined run lands on identical parameters
    let pipe2 = run("pipelined");
    assert_eq!(pipe.store.params, pipe2.store.params);
    assert_eq!(pipe.clock.now(), pipe2.clock.now());
}
