//! Goldens for the staleness-K two-fleet schedule (`[fleet]`).
//!
//! * The legacy schedules are special cases of the unified executor, not
//!   parallel code paths: an explicit `[fleet]` section pinning K=0
//!   reproduces the sync schedule, and (R=1, K=1) reproduces the
//!   pipelined schedule, bit for bit — trained parameters, simulated
//!   clock, and both CSVs (modulo the process-wall-clock column).
//! * Realized staleness is bounded by K, and queue admission order is a
//!   pure function of generation history (docs/DETERMINISM.md): trained
//!   parameters and the per-iteration staleness/queue-depth telemetry
//!   are bit-invariant to worker-pool size and replica count — only
//!   clock *accounting* may move with R.
//! * Every train-CSV row survives a header-faithful `from_csv_row`
//!   round trip bitwise, for real runs and for randomized rows.
//!
//! Trainer-level tests are skipped when artifacts are absent (CI without
//! `make artifacts`); the CSV row property always runs.

mod common;

use pods::config::RunConfig;
use pods::coordinator::scheduler::Trainer;
use pods::hwsim::FleetSection;
use pods::metrics::{CsvRow, IterRow};
use pods::util::prop;

/// A small-but-real run config on the shared tiny fixture, with the
/// schedule/worker/fleet knobs this suite exercises. `out_sub` isolates
/// each arm's CSVs; the directory is wiped so stale state cannot leak.
fn cfg(
    name: &str,
    schedule: &str,
    workers: usize,
    iterations: usize,
    fleet: FleetSection,
    out_sub: &str,
) -> RunConfig {
    let out = std::env::temp_dir().join("pods_fleet_golden").join(out_sub);
    std::fs::remove_dir_all(&out).ok();
    let mut b = common::tiny_builder(name, "pods_fleet_golden");
    b.schedule = schedule.into();
    b.workers = workers;
    b.iterations = iterations;
    b.fleet = fleet;
    b.out_dir = out.to_string_lossy().into_owned();
    b.build().unwrap()
}

/// One CSV row with the wall-clock column blanked — `real_time` (index 2)
/// measures this process, not the simulated run, so it is the one column
/// two equivalent runs cannot and need not reproduce.
fn strip_realtime(row: &str) -> String {
    row.split(',')
        .enumerate()
        .map(|(i, f)| if i == 2 { "_" } else { f })
        .collect::<Vec<_>>()
        .join(",")
}

/// Assert two trainers landed on identical parameters, simulated clock
/// and CSVs (modulo `real_time`).
fn assert_runs_bit_identical(a: &Trainer, b: &Trainer, what: &str) {
    assert_eq!(a.store.params, b.store.params, "{what}: trained parameters diverged");
    assert_eq!(
        a.clock.now().to_bits(),
        b.clock.now().to_bits(),
        "{what}: simulated clock diverged ({} vs {})",
        a.clock.now(),
        b.clock.now()
    );
    assert_eq!(
        a.clock.overlap_saved().to_bits(),
        b.clock.overlap_saved().to_bits(),
        "{what}: overlap accounting diverged"
    );
    assert_eq!(a.recorder.iters.len(), b.recorder.iters.len(), "{what}: iter rows");
    for (ra, rb) in a.recorder.iters.iter().zip(&b.recorder.iters) {
        assert_eq!(
            strip_realtime(&ra.csv_row()),
            strip_realtime(&rb.csv_row()),
            "{what}: iter CSV row {} diverged",
            ra.iter
        );
    }
    assert_eq!(a.recorder.evals.len(), b.recorder.evals.len(), "{what}: eval rows");
    for (ra, rb) in a.recorder.evals.iter().zip(&b.recorder.evals) {
        assert_eq!(strip_realtime(&ra.csv_row()), strip_realtime(&rb.csv_row()), "{what}: eval");
    }
}

/// Tentpole golden (a): pinning `max_staleness = 0` explicitly is the
/// sync schedule — the derived and the explicit config run the identical
/// executor path, bit for bit.
#[test]
fn explicit_k0_reproduces_sync_bitwise() {
    let Some(dir) = common::artifacts() else { return };
    let legacy = cfg("fleet_sync_legacy", "sync", 1, 2, FleetSection::default(), "sync_legacy");
    let pinned = FleetSection { max_staleness: Some(0), ..FleetSection::default() };
    let explicit = cfg("fleet_sync_k0", "sync", 1, 2, pinned, "sync_k0");
    let a = common::train(&dir, legacy, 2);
    let b = common::train(&dir, explicit, 2);
    assert_runs_bit_identical(&a, &b, "sync vs explicit K=0");
    assert!(
        a.recorder.iters.iter().all(|r| r.fleet_staleness == 0 && r.fleet_queue_depth == 0),
        "the sync schedule must realize zero staleness and keep the queue empty"
    );
}

/// Tentpole golden (b): (R=1, K=1) is the pipelined schedule — the old
/// single-slot prefetch is the depth-1 special case of the ready-batch
/// queue, not a parallel code path.
#[test]
fn explicit_r1_k1_reproduces_pipelined_bitwise() {
    let Some(dir) = common::artifacts() else { return };
    let legacy = cfg("fleet_pipe", "pipelined", 1, 3, FleetSection::default(), "pipe_legacy");
    let pinned = FleetSection {
        inference_replicas: 1,
        max_staleness: Some(1),
        ..FleetSection::default()
    };
    let explicit = cfg("fleet_pipe_k1", "pipelined", 1, 3, pinned, "pipe_k1");
    let a = common::train(&dir, legacy, 3);
    let b = common::train(&dir, explicit, 3);
    assert_runs_bit_identical(&a, &b, "pipelined vs explicit (R=1, K=1)");
    assert!(
        a.recorder.iters.iter().all(|r| r.fleet_staleness <= 1),
        "pipelined realized staleness must stay within K = 1"
    );
    assert!(
        a.recorder.iters.iter().any(|r| r.fleet_staleness == 1),
        "steady-state pipelined steps must consume one-step-stale batches"
    );
}

/// Property: realized staleness never exceeds K, and queue admission
/// order is a pure function of generation history — trained parameters
/// and the staleness/queue-depth telemetry are bit-invariant to the
/// worker-pool size and to the replica count. (The simulated clock is
/// *meant* to move with both — that is the cost model — so it is
/// deliberately not compared across the grid.)
#[test]
fn staleness_bounded_and_admission_order_is_history_not_partition() {
    let Some(dir) = common::artifacts() else { return };
    let k = 2usize;
    let iters = 4usize;
    let run = |workers: usize, replicas: usize| {
        let fl = FleetSection {
            inference_replicas: replicas,
            max_staleness: Some(k),
            ..FleetSection::default()
        };
        let sub = format!("grid_{workers}w_{replicas}r");
        let c = cfg("fleet_grid", "pipelined", workers, iters, fl, &sub);
        common::train(&dir, c, iters)
    };
    let reference = run(1, 1);
    assert!(
        reference.recorder.iters.iter().all(|r| r.fleet_staleness <= k),
        "realized staleness exceeded the configured bound K = {k}"
    );
    assert!(
        reference.recorder.iters.iter().any(|r| r.fleet_staleness > 1),
        "a depth-{k} queue must realize staleness beyond the pipelined 1 at steady state"
    );
    for (workers, replicas) in [(4, 1), (1, 2), (4, 2)] {
        let other = run(workers, replicas);
        let what = format!("{workers} workers, R={replicas}");
        assert_eq!(
            reference.store.params, other.store.params,
            "{what}: partition/replica count changed trained parameters"
        );
        for (ra, rb) in reference.recorder.iters.iter().zip(&other.recorder.iters) {
            assert_eq!(
                (ra.fleet_staleness, ra.fleet_queue_depth),
                (rb.fleet_staleness, rb.fleet_queue_depth),
                "{what}: admission history moved with the partition at iter {}",
                ra.iter
            );
        }
    }
}

/// Every recorded train-CSV row from a real staleness-K run parses back
/// through [`IterRow::from_csv_row`] and re-serializes bitwise, and the
/// row column count matches the header.
#[test]
fn real_run_csv_rows_roundtrip_bitwise() {
    let Some(dir) = common::artifacts() else { return };
    let fl = FleetSection { max_staleness: Some(2), ..FleetSection::default() };
    let c = cfg("fleet_csv", "pipelined", 1, 3, fl, "csv_roundtrip");
    let tr = common::train(&dir, c, 3);
    let n_cols = IterRow::csv_header().split(',').count();
    assert!(!tr.recorder.iters.is_empty());
    for row in &tr.recorder.iters {
        let line = row.csv_row();
        assert_eq!(line.split(',').count(), n_cols, "row/header column mismatch: {line}");
        let parsed = IterRow::from_csv_row(&line).expect("recorded row must parse");
        assert_eq!(parsed.csv_row(), line, "CSV row did not round-trip bitwise");
    }
}

/// The same round trip as a pure property over randomized rows — runs
/// without artifacts, covering the fleet telemetry columns' full f64
/// range rather than just the values a tiny run happens to produce.
#[test]
fn randomized_csv_rows_roundtrip_bitwise() {
    let n_cols = IterRow::csv_header().split(',').count();
    prop::for_cases(64, |rng| {
        let row = IterRow {
            iter: rng.below(10_000),
            sim_time: rng.f64() * 1e4,
            real_time: rng.f64(),
            sim_inference_time: rng.f64() * 100.0,
            sim_update_time: rng.f64() * 10.0,
            train_reward: rng.f64() as f32,
            fleet_replicas: 1 + rng.below(8),
            fleet_staleness: rng.below(5),
            fleet_mean_staleness: rng.f64() * 4.0,
            fleet_max_staleness: rng.below(5),
            fleet_queue_depth: rng.below(9),
            fleet_queue_block_time: rng.f64() * 50.0,
            fleet_inf_util: rng.f64(),
            fleet_upd_util: rng.f64(),
            ..IterRow::default()
        };
        let line = row.csv_row();
        assert_eq!(line.split(',').count(), n_cols, "row/header column mismatch: {line}");
        let parsed = IterRow::from_csv_row(&line).expect("randomized row must parse");
        assert_eq!(parsed.csv_row(), line, "CSV row did not round-trip bitwise");
    });
}
