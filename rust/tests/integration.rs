//! End-to-end integration: the full Trainer loop (SFT warm-up -> rollouts
//! -> verify -> down-sample -> micro-batched grad -> AdamW) over the real
//! base-profile artifacts, plus cross-module contracts that don't need the
//! engine. Skipped when artifacts are absent.

use pods::config::RunConfig;
use pods::coordinator::scheduler::Trainer;
use pods::exp::CfgBuilder;
use pods::tasks::{Split, TaskKind};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = pods::default_artifacts_dir();
    if dir.join("base/meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: base artifacts missing (run `make artifacts`)");
        None
    }
}

fn tiny_cfg(name: &str, kind: &str, n: usize, m: Option<usize>) -> RunConfig {
    CfgBuilder {
        name: name.into(),
        profile: "base".into(),
        task: "arith".into(),
        iterations: 2,
        prompts_per_iter: 1,
        eval_every: 2,
        eval_problems: 16,
        kind: kind.into(),
        n,
        m,
        lr: 1e-4,
        sft_steps: 4,
        sft_lr: 2e-3,
        out_dir: std::env::temp_dir()
            .join("pods_itest")
            .to_string_lossy()
            .into_owned(),
        ..Default::default()
    }
    .build()
    .unwrap()
}

#[test]
fn full_pods_training_loop() {
    let Some(dir) = artifacts() else { return };
    let mut tr = Trainer::new(&dir, tiny_cfg("itest_pods", "pods", 16, Some(4))).unwrap();
    tr.engine.quiet = true;
    tr.run().unwrap();
    assert_eq!(tr.recorder.iters.len(), 2);
    let it = &tr.recorder.iters[0];
    assert_eq!(it.rollouts_generated, 16);
    assert_eq!(it.rollouts_trained, 4);
    assert_eq!(it.micro_steps, 1); // 4 rollouts fit one B_u=8 micro-batch
    assert!(it.sim_inference_time > 0.0 && it.sim_update_time > 0.0);
    assert!(tr.clock.now() > 0.0);
    // two optimizer steps happened (params moved twice)
    assert_eq!(tr.store.step, 2 + 4); // 4 SFT + 2 RL
    // eval rows recorded: initial + final
    assert!(tr.recorder.evals.len() >= 2);
    // CSVs written
    let out = std::path::Path::new(&tr.cfg.run.out_dir);
    assert!(out.join("itest_pods_train.csv").exists());
    assert!(out.join("itest_pods_eval.csv").exists());
}

#[test]
fn ga_schedule_runs_more_micro_steps_than_pods() {
    let Some(dir) = artifacts() else { return };
    let mut ga = Trainer::new(&dir, tiny_cfg("itest_ga", "ga", 16, None)).unwrap();
    ga.engine.quiet = true;
    ga.sft_warmup().unwrap();
    let ga_stats = ga.train_iteration(0).unwrap();
    assert_eq!(ga_stats.rollouts_trained, 16);
    assert_eq!(ga_stats.micro_steps, 2); // 16 rollouts / B_u=8

    let mut pods_tr = Trainer::new(&dir, tiny_cfg("itest_pods2", "pods", 16, Some(8))).unwrap();
    pods_tr.engine.quiet = true;
    pods_tr.sft_warmup().unwrap();
    let pods_stats = pods_tr.train_iteration(0).unwrap();
    assert_eq!(pods_stats.rollouts_trained, 8);
    assert_eq!(pods_stats.micro_steps, 1);
    assert!(pods_stats.sim_update < ga_stats.sim_update);
    // same inference phase (both generated n = 16)
    assert_eq!(pods_stats.rollouts_generated, ga_stats.rollouts_generated);
}

#[test]
fn trainer_is_replayable() {
    let Some(dir) = artifacts() else { return };
    let run = |seed: u64| {
        let mut cfg = tiny_cfg("itest_replay", "pods", 8, Some(4));
        cfg.run.seed = seed;
        let mut tr = Trainer::new(&dir, cfg).unwrap();
        tr.engine.quiet = true;
        tr.run().unwrap();
        (
            tr.recorder.iters.iter().map(|i| i.train_reward).collect::<Vec<_>>(),
            tr.store.params.iter().take(64).copied().collect::<Vec<f32>>(),
        )
    };
    let (r1, p1) = run(7);
    let (r2, p2) = run(7);
    assert_eq!(r1, r2, "same seed must replay identically");
    assert_eq!(p1, p2);
    let (r3, _) = run(8);
    assert!(r1 != r3 || true, "different seed (may coincide, no assert)");
}

#[test]
fn config_files_parse_and_validate() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let configs = std::fs::read_dir(root.join("configs")).unwrap();
    let mut count = 0;
    for entry in configs {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) == Some("toml") {
            let cfg = RunConfig::from_path(&path)
                .unwrap_or_else(|e| panic!("config {path:?} invalid: {e}"));
            assert!(!cfg.run.name.is_empty());
            count += 1;
        }
    }
    assert!(count >= 7, "expected the Table-1 setting configs, found {count}");
}

#[test]
fn tokenizer_matches_all_profile_metas() {
    let dir = pods::default_artifacts_dir();
    let mut checked = 0;
    for profile in ["micro", "base", "lora", "big"] {
        let meta_path = dir.join(profile).join("meta.json");
        if !meta_path.exists() {
            continue;
        }
        let meta = pods::runtime::Meta::load(&meta_path).unwrap();
        pods::tasks::tokenizer::verify_against_meta(&meta.vocab).unwrap();
        checked += 1;
    }
    assert!(checked > 0 || !dir.exists(), "no profiles found to check");
}

#[test]
fn eval_problems_are_disjoint_from_training_cursor() {
    // splits must not leak: the first 10k train ids and test ids share no
    // (prompt, answer) pair on the arith generator
    let train: std::collections::HashSet<Vec<i32>> = (0..2000)
        .map(|i| TaskKind::Arith.generate(Split::Train, i).prompt)
        .collect();
    let mut overlap = 0;
    for i in 0..200 {
        let t = TaskKind::Arith.generate(Split::Test, i);
        if train.contains(&t.prompt) {
            overlap += 1;
        }
    }
    // the task space is small; some prompt collisions are expected, but the
    // split seeding must not make test a subset of train
    assert!(overlap < 150, "test split nearly contained in train ({overlap}/200)");
}
