//! Golden tests for cross-iteration rollout replay (coordinator::replay).
//!
//! The `[replay]` determinism contract (docs/DETERMINISM.md):
//!
//! * **Disabled replay is the baseline.** With `replay.enabled = false`
//!   the trained parameters and every training-CSV column are bit-
//!   identical whatever the other replay knobs say, the store stays
//!   empty, and the replay telemetry columns are all zero. (The sync
//!   executor itself — replay disabled — is pinned against the
//!   sequential reference by `exec_golden.rs`.)
//! * **Store evolution is partition-invariant.** With replay enabled,
//!   the store's contents, the drawn rows and the trained parameters are
//!   a pure function of `(run_seed, rollout history)`: 1 worker and a
//!   4-worker pool land on bit-identical state. (The pipelined schedule
//!   legitimately changes the rollout history itself — generation of
//!   `t+1` runs under the pre-update policy — so schedule equality is
//!   *not* part of the contract.)
//! * **Eviction and draw orders are golden.** Staleness-then-score with
//!   `RowId` tie-breaks, replayed through the executor's exact phase
//!   order (evict, draw, offer).
//!
//! The store-only goldens run everywhere; the trainer goldens are
//! skipped when artifacts are absent (CI without `make artifacts`).

mod common;

use pods::config::{ReplaySection, RunConfig};
use pods::coordinator::advantage::NormMode;
use pods::coordinator::group::{build_update_batch, PromptGroup, SelectedRollout};
use pods::coordinator::replay::ReplayStore;
use pods::coordinator::scheduler::Trainer;
use pods::coordinator::select::Pipeline;

fn cfg(
    name: &str,
    workers: usize,
    iterations: usize,
    replay: Option<(f64, usize, usize)>,
) -> RunConfig {
    let mut b = common::tiny_builder(name, "pods_replay_golden");
    b.iterations = iterations;
    b.eval_every = iterations.max(1);
    b.eval_problems = 16;
    b.workers = workers;
    b.schedule = "sync".into();
    if let Some((mix, staleness, capacity)) = replay {
        b.replay_enabled = true;
        b.replay_mix_fraction = mix;
        b.replay_staleness = staleness;
        b.replay_capacity = capacity;
    }
    b.build().unwrap()
}

/// One synthetic single-prompt group; `max_variance` with m = 2 keeps the
/// reward extremes, so indices 1 and 2 are the dropped (offered) rows.
fn synth(rewards: &[f32]) -> Vec<PromptGroup> {
    vec![PromptGroup::synthetic(5, rewards, None)]
}

fn select2(groups: &[PromptGroup]) -> Vec<SelectedRollout> {
    let p = Pipeline::parse_default("max_variance").unwrap();
    build_update_batch(groups, &p, Some(2), NormMode::After, 0, 0).unwrap().0
}

/// Eviction-order golden: the store replayed through the executor's exact
/// phase order (evict stale, draw, offer) over four iterations. Draws
/// consume highest-score-first with `RowId` ascending ties; per-prompt
/// capacity evicts stalest-first, then lowest score — a fresher low-score
/// row outlives a staler high-score one.
#[test]
fn executor_order_store_evolution_is_golden() {
    // dropped rows (indices 1, 2) per iteration and their bracket scores:
    // iter 0 -> {1.0, 2.0} scores {1.0, 1.0};  iter 1 -> {0.5, 2.5} scores
    // {0.5, 0.5};  iter 2 -> {1.4, 1.6} scores {1.4, 1.4};  iter 3 ->
    // {0.2, 2.9} scores {0.2, 0.1}
    let rewards: [&[f32]; 4] = [
        &[0.0, 1.0, 2.0, 3.0],
        &[0.0, 0.5, 2.5, 3.0],
        &[0.0, 1.4, 1.6, 3.0],
        &[0.0, 0.2, 2.9, 3.0],
    ];
    let rp = ReplaySection {
        enabled: true,
        mix_fraction: 0.25,
        staleness: 2,
        capacity_per_prompt: 2,
        rho_max: 2.0,
    };
    let mut store = ReplayStore::new();
    let mut drawn_log: Vec<Vec<(u64, u32)>> = Vec::new();
    for (it, r) in rewards.iter().enumerate() {
        let groups = synth(r);
        let selected = select2(&groups);
        store.evict_stale(it as u64, rp.staleness);
        let drawn = store.draw(1);
        drawn_log.push(drawn.iter().map(|d| (d.id.iter, d.id.rollout_idx)).collect());
        store.offer(it as u64, &groups, &selected, &rp);
    }
    // iter 0 draws from an empty store; each later draw takes the
    // smaller-RowId member of that iteration's score tie
    assert_eq!(drawn_log, vec![vec![], vec![(0, 1)], vec![(1, 1)], vec![(2, 1)]]);
    // final capacity squeeze: iter-3 rows (scores 0.2 / 0.1) both survive,
    // the staler iter-2 row (score 1.4) is evicted — staleness beats score
    let end: Vec<(u64, u32)> =
        store.contents().iter().map(|r| (r.id.iter, r.id.rollout_idx)).collect();
    assert_eq!(end, vec![(3, 1), (3, 2)], "capacity eviction must prefer fresher rows");
}

/// The staleness window slides with the iteration counter, and replaying
/// the same history lands on a bit-identical store (scores and advantages
/// compared by bit pattern).
#[test]
fn staleness_window_slides_and_history_replays_bit_identical() {
    let rp = ReplaySection {
        enabled: true,
        mix_fraction: 0.25,
        staleness: 1,
        capacity_per_prompt: 64,
        rho_max: 2.0,
    };
    let run_trace = || {
        let mut store = ReplayStore::new();
        for it in 0..5u64 {
            let groups = synth(&[0.0, 1.0, 2.0, 3.0]);
            let selected = select2(&groups);
            store.evict_stale(it, rp.staleness);
            store.offer(it, &groups, &selected, &rp);
        }
        store
    };
    let store = run_trace();
    let iters: Vec<u64> = store.contents().iter().map(|r| r.id.iter).collect();
    assert_eq!(iters, vec![3, 3, 4, 4], "staleness 1 keeps the last two iterations");
    let sig = |s: &ReplayStore| {
        s.contents()
            .iter()
            .map(|r| (r.id, r.score.to_bits(), r.advantage.to_bits()))
            .collect::<Vec<_>>()
    };
    assert_eq!(sig(&store), sig(&run_trace()), "same history must rebuild the same store");
}

/// Disabled replay is the baseline: moving every other `[replay]` knob
/// changes nothing — parameters bitwise, per-iteration losses bitwise,
/// replay telemetry columns pinned at zero, store untouched.
#[test]
fn disabled_replay_is_bitwise_identical() {
    let Some(dir) = common::artifacts() else { return };
    let iters = 2;
    let run = |c: RunConfig| common::train(&dir, c, iters);
    let base = run(cfg("golden_replay_off_a", 1, iters, None));
    let mut moved_cfg = cfg("golden_replay_off_b", 1, iters, None);
    moved_cfg.replay.mix_fraction = 1.0;
    moved_cfg.replay.staleness = 7;
    moved_cfg.replay.capacity_per_prompt = 64;
    moved_cfg.replay.rho_max = 13.0;
    let moved = run(moved_cfg);
    assert_eq!(
        base.store.params, moved.store.params,
        "disabled replay must be bit-identical whatever the other replay knobs say"
    );
    assert_eq!(base.recorder.iters.len(), moved.recorder.iters.len());
    for (a, b) in base.recorder.iters.iter().zip(&moved.recorder.iters) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.clip_frac.to_bits(), b.clip_frac.to_bits());
        assert_eq!(a.rollouts_trained, b.rollouts_trained);
        assert_eq!(a.replay_rows_used, 0, "disabled replay must never mix rows in");
        assert_eq!(a.replay_store_size, 0, "disabled replay must never admit rows");
        assert_eq!(a.replay_mean_staleness, 0.0);
    }
    assert!(base.exec.replay_store().is_empty());
    assert!(moved.exec.replay_store().is_empty());
}

/// With replay enabled, the store contents, the replay telemetry and the
/// trained parameters are invariant to the worker-pool size — the
/// partition-invariance axis of the (run_seed, history) purity contract.
#[test]
fn replay_store_and_params_invariant_across_worker_pool_sizes() {
    let Some(dir) = common::artifacts() else { return };
    let iters = 3;
    let run = |name: &str, workers: usize| {
        common::train(&dir, cfg(name, workers, iters, Some((0.5, 2, 4))), iters)
    };
    let w1 = run("golden_replay_w1", 1);
    let w4 = run("golden_replay_w4", 4);
    assert_eq!(
        w1.store.params, w4.store.params,
        "worker count changed trained parameters under replay"
    );
    let sig = |tr: &Trainer| {
        tr.exec
            .replay_store()
            .contents()
            .iter()
            .map(|r| (r.id, r.score.to_bits(), r.advantage.to_bits()))
            .collect::<Vec<_>>()
    };
    assert_eq!(sig(&w1), sig(&w4), "replay store contents must be partition-invariant");
    let cols = |tr: &Trainer| {
        tr.recorder
            .iters
            .iter()
            .map(|r| (r.replay_rows_used, r.replay_store_size, r.replay_mean_staleness))
            .collect::<Vec<_>>()
    };
    assert_eq!(cols(&w1), cols(&w4), "replay telemetry columns must be partition-invariant");
    // non-vacuity: the store filled at iteration 0 and was drawn from later
    assert!(
        w1.recorder.iters.iter().any(|r| r.replay_rows_used > 0),
        "replay never fired — the invariance golden is vacuous"
    );
    for r in &w1.recorder.iters {
        if r.replay_rows_used > 0 {
            assert!(
                r.replay_mean_staleness >= 1.0,
                "a replayed row is at least one iteration old (got {})",
                r.replay_mean_staleness
            );
        }
    }
}
