//! Golden tests: the four legacy rule strings, parsed through the selector
//! registry, produce selections identical to the seed `downsample`
//! implementation on fixed reward vectors.
//!
//! The seed exposed `Rule::{MaxVariance,MaxReward,Random,Percentile}`
//! calling the kernels in `coordinator::downsample`; the selector
//! subsystem wraps those exact kernels, so a one-stage pipeline must
//! reproduce their output *byte-for-byte* (same indices, same order).
//! `random` is compared against the kernel driven by an RNG seeded the
//! documented way — from `group_seed(run_seed, iter, prompt_id)`.

use pods::coordinator::downsample as ds;
use pods::coordinator::group::PromptGroup;
use pods::coordinator::select::{group_seed, Pipeline, SelectionContext};
use pods::util::rng::Rng;

fn group(problem_idx: u64, rewards: &[f32]) -> PromptGroup {
    PromptGroup::synthetic(problem_idx, rewards, None)
}

/// Fixed reward vectors covering ties, negatives, constants, binary
/// rewards and a singleton.
const VECTORS: &[&[f32]] = &[
    &[3.0, 0.0, 2.0, 2.0, 0.25, 3.0, 1.0, 0.5, 2.0, 0.0, 3.0, 0.25],
    &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0],
    &[1.0, 1.0, 0.0, 0.0, 1.0, 0.0],
    &[-2.5, 4.0, -2.5, 0.0, 4.0],
    &[2.0, 2.0, 2.0, 2.0],
    &[0.0, 1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 0.0, 0.0, 1.0],
    &[0.7],
];

fn ms_for(n: usize) -> Vec<usize> {
    let mut ms = vec![1, 2, n / 2, n.saturating_sub(1), n];
    ms.retain(|&m| (1..=n).contains(&m));
    ms.dedup();
    ms
}

#[test]
fn max_variance_spec_matches_seed_kernel() {
    let p = Pipeline::parse_default("max_variance").unwrap();
    for rewards in VECTORS {
        for m in ms_for(rewards.len()) {
            let g = group(0, rewards);
            let got = p.select(&SelectionContext::new(&g, m, 0, 0)).unwrap().kept;
            let want = ds::max_variance(rewards, m).unwrap();
            assert_eq!(got, want, "rewards {rewards:?} m={m}");
        }
    }
}

#[test]
fn max_reward_spec_matches_seed_kernel() {
    let p = Pipeline::parse_default("max_reward").unwrap();
    for rewards in VECTORS {
        for m in ms_for(rewards.len()) {
            let g = group(0, rewards);
            let got = p.select(&SelectionContext::new(&g, m, 0, 0)).unwrap().kept;
            let want = ds::max_reward(rewards, m).unwrap();
            assert_eq!(got, want, "rewards {rewards:?} m={m}");
        }
    }
}

#[test]
fn percentile_spec_matches_seed_kernel() {
    let p = Pipeline::parse_default("percentile").unwrap();
    for rewards in VECTORS {
        for m in ms_for(rewards.len()) {
            let g = group(0, rewards);
            let got = p.select(&SelectionContext::new(&g, m, 0, 0)).unwrap().kept;
            let want = ds::percentile(rewards, m).unwrap();
            assert_eq!(got, want, "rewards {rewards:?} m={m}");
        }
    }
}

#[test]
fn random_spec_matches_seed_kernel_under_documented_seeding() {
    let p = Pipeline::parse_default("random").unwrap();
    for (pi, rewards) in VECTORS.iter().enumerate() {
        for m in ms_for(rewards.len()) {
            for (run_seed, iter) in [(0u64, 0u64), (7, 3), (123456789, 42)] {
                let g = group(pi as u64, rewards);
                let got =
                    p.select(&SelectionContext::new(&g, m, run_seed, iter)).unwrap().kept;
                let mut rng =
                    Rng::seed_from_u64(group_seed(run_seed, iter, g.problem.id));
                let want = ds::random(rewards.len(), m, &mut rng).unwrap();
                assert_eq!(got, want, "rewards {rewards:?} m={m} seed=({run_seed},{iter})");
            }
        }
    }
}

/// Hard-coded expectations (independent of the kernels) pinning the seed
/// behaviour: these are the exact selections the seed implementation
/// produced for these inputs.
#[test]
fn pinned_seed_selections() {
    let cases: &[(&str, &[f32], usize, &[usize])] = &[
        // max_variance on 0..=3 with m=2: the two extremes, low block first
        ("max_variance", &[0.0, 1.0, 2.0, 3.0], 2, &[0, 3]),
        // binary 6+6 with m=4: 2 zeros then 2 ones; ties sort by index, so
        // the low block is the first zeros and the high block the *last*
        // ones of the stable order
        (
            "max_variance",
            &[1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
            4,
            &[6, 7, 4, 5],
        ),
        // max_reward: ascending-by-reward order of the top block
        ("max_reward", &[0.1, 3.0, 2.0, -1.0, 2.5], 2, &[4, 1]),
        // percentile over 0..100-like ramp: the (i+0.5)/m quantiles
        ("percentile", &[5.0, 1.0, 3.0, 2.0], 4, &[1, 3, 2, 0]),
        // percentile all-ties: canonical sorted positions via index ties
        ("percentile", &[1.0, 1.0, 1.0, 1.0], 2, &[1, 3]),
    ];
    for &(spec, rewards, m, want) in cases {
        let p = Pipeline::parse_default(spec).unwrap();
        let g = group(0, rewards);
        let got = p.select(&SelectionContext::new(&g, m, 0, 0)).unwrap().kept;
        assert_eq!(got, want, "{spec} on {rewards:?} m={m}");
    }
}

/// The composed pipelines exercised by fig5 / the example run end-to-end
/// over the public API and keep ≤ m informative rollouts.
#[test]
fn new_selectors_run_end_to_end() {
    let rewards: Vec<f32> = (0..16).map(|i| (i % 4) as f32).collect();
    let g = group(0, &rewards);
    for spec in [
        "drop_zero_variance | max_variance",
        "prune(quantile=0.75) | max_variance",
        "prune(max_tokens=4096) | percentile",
    ] {
        let p = Pipeline::parse_default(spec).unwrap();
        let sel = p.select(&SelectionContext::new(&g, 4, 9, 1)).unwrap();
        assert_eq!(sel.kept.len(), 4, "{spec}");
        assert!(sel.diag.reward_variance > 0.0, "{spec}");
    }
    // and a zero-signal group is dropped by the filter but not the rules
    let flat = group(1, &[1.0; 8]);
    let filt = Pipeline::parse_default("drop_zero_variance | max_variance").unwrap();
    assert!(filt.select(&SelectionContext::new(&flat, 4, 0, 0)).unwrap().kept.is_empty());
    let plain = Pipeline::parse_default("max_variance").unwrap();
    assert_eq!(plain.select(&SelectionContext::new(&flat, 4, 0, 0)).unwrap().kept.len(), 4);
}
