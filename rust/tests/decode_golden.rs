//! Decode-equivalence goldens for the chunked early-exit driver.
//!
//! Pins the property the whole rollout engine rests on: **per-rollout
//! token/logprob/gen_mask streams are bit-identical across chunk sizes,
//! refill modes and refill (queue) orders**, and identical to the
//! monolithic `rollout` program — because RNG is per-row counter-based
//! and attention is row-local. Also pins the greedy eval path: the
//! chunked driver reproduces the monolithic greedy decode exactly.
//!
//! Runs on the `micro` artifacts; skipped when absent.

use pods::rollout::{decode_rows, plan_rows, prompt_batch, RefillMode, RowOut, RowSpec};
use pods::runtime::Engine;
use pods::tasks::{Split, TaskKind};

fn engine() -> Option<Engine> {
    let dir = pods::default_artifacts_dir();
    if !dir.join("micro/meta.json").exists() {
        eprintln!("skipping: micro artifacts missing (run `make artifacts`)");
        return None;
    }
    let mut e = Engine::load(&dir, "micro").expect("engine load");
    e.quiet = true;
    Some(e)
}

/// Micro-profile problems with prompts clipped to prompt_len.
fn problems(e: &Engine, k: usize) -> Vec<pods::tasks::Problem> {
    let p = e.meta.config.prompt_len;
    (0..k as u64)
        .map(|i| {
            let mut pr = TaskKind::Arith.generate(Split::Train, i);
            pr.prompt.truncate(p);
            pr
        })
        .collect()
}

/// Key rows by (group, rollout) for order-independent comparison.
fn by_identity(outs: &[RowOut]) -> Vec<(usize, usize, &RowOut)> {
    let mut v: Vec<_> = outs.iter().map(|r| (r.group_idx, r.rollout_idx, r)).collect();
    v.sort_by_key(|(g, j, _)| (*g, *j));
    v
}

fn assert_streams_equal(a: &[RowOut], b: &[RowOut], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: row count");
    for ((ga, ja, ra), (gb, jb, rb)) in by_identity(a).into_iter().zip(by_identity(b)) {
        assert_eq!((ga, ja), (gb, jb), "{what}: row identity");
        assert_eq!(ra.tokens, rb.tokens, "{what}: tokens of ({ga},{ja})");
        assert_eq!(ra.logprobs, rb.logprobs, "{what}: logprobs of ({ga},{ja})");
        assert_eq!(ra.gen_mask, rb.gen_mask, "{what}: gen_mask of ({ga},{ja})");
        assert_eq!(ra.gen_len, rb.gen_len, "{what}: gen_len of ({ga},{ja})");
        assert_eq!(ra.pad_len, rb.pad_len, "{what}: pad_len of ({ga},{ja})");
    }
}

/// The chunked driver replays the monolithic `rollout` program bit for
/// bit when fed the same per-row seeds (one full batch, no refill).
#[test]
fn chunked_driver_matches_monolithic_program() {
    let Some(e) = engine() else { return };
    let params = e.init(1).unwrap();
    let br = e.meta.config.rollout_batch;
    let t = e.meta.config.seq_len;
    let g = e.meta.gen_len;
    let ps = problems(&e, 1);
    let rows = plan_rows(&ps, br, 7, 0);
    let seeds: Vec<i32> = rows.iter().map(|r| r.seed).collect();
    let (prompts, pads) = prompt_batch(&e, &ps[0].prompt).unwrap();
    let mono = e.rollout(&params, None, &prompts, &pads, &seeds, 1.0).unwrap();
    for &chunk in &e.meta.decode_chunks.clone() {
        let (outs, stats) = decode_rows(
            &e, &params, None, 1.0, chunk, RefillMode::Continuous, &rows, &ps,
        )
        .unwrap();
        assert_eq!(outs.len(), br);
        for (b, r) in outs.iter().enumerate() {
            assert_eq!(r.tokens, mono.tokens.data[b * t..(b + 1) * t].to_vec(), "C={chunk} row {b}");
            assert_eq!(r.logprobs, mono.logprobs.data[b * g..(b + 1) * g].to_vec());
            assert_eq!(r.gen_mask, mono.gen_mask.data[b * g..(b + 1) * g].to_vec());
            assert_eq!(r.gen_len, mono.gen_len[b]);
        }
        // early exit: physical decode work never exceeds the monolithic
        // B_r x G, and respects chunk rounding
        assert!(stats.gen_tokens_decoded <= br * g, "C={chunk} decoded {}", stats.gen_tokens_decoded);
        assert_eq!(stats.gen_tokens_decoded % (br * chunk), 0);
    }
}

/// Acceptance golden: every chunk size and refill mode produces identical
/// per-rollout streams on a multi-group queue that forces retirements and
/// admissions.
#[test]
fn streams_invariant_to_chunk_size_and_refill_mode() {
    let Some(e) = engine() else { return };
    let params = e.init(2).unwrap();
    let ps = problems(&e, 3);
    let rows = plan_rows(&ps, 6, 11, 3); // 18 rows through 4 slots
    let chunks = e.meta.decode_chunks.clone();
    let (reference, _) = decode_rows(
        &e, &params, None, 1.0, chunks[0], RefillMode::Continuous, &rows, &ps,
    )
    .unwrap();
    for &chunk in &chunks {
        for refill in [RefillMode::Continuous, RefillMode::Batch] {
            let (outs, _) =
                decode_rows(&e, &params, None, 1.0, chunk, refill, &rows, &ps).unwrap();
            assert_streams_equal(
                &reference,
                &outs,
                &format!("C={chunk} refill={}", refill.name()),
            );
        }
    }
}

/// Acceptance golden: admission (queue) order cannot change any row's
/// stream — shuffled queues produce the same per-rollout outputs.
#[test]
fn streams_invariant_to_refill_order() {
    let Some(e) = engine() else { return };
    let params = e.init(3).unwrap();
    let ps = problems(&e, 2);
    let rows = plan_rows(&ps, 5, 5, 1); // 10 rows, 4 slots
    let (reference, _) =
        decode_rows(&e, &params, None, 1.2, 4, RefillMode::Continuous, &rows, &ps).unwrap();
    // deterministic pseudo-shuffles of the queue
    let mut rng = pods::util::rng::Rng::seed_from_u64(99);
    for case in 0..4 {
        let mut shuffled: Vec<RowSpec> = rows.clone();
        for i in (1..shuffled.len()).rev() {
            let j = (rng.next_u64() % (i as u64 + 1)) as usize;
            shuffled.swap(i, j);
        }
        let (outs, _) =
            decode_rows(&e, &params, None, 1.2, 4, RefillMode::Continuous, &shuffled, &ps)
                .unwrap();
        assert_streams_equal(&reference, &outs, &format!("shuffle case {case}"));
    }
}

/// Satellite pin: the greedy eval path on the chunked driver reproduces
/// the monolithic greedy decode exactly, for every chunk size.
#[test]
fn greedy_eval_outputs_unchanged_by_chunking() {
    let Some(e) = engine() else { return };
    let params = e.init(4).unwrap();
    let t = e.meta.config.seq_len;
    let ps = problems(&e, 3);
    // monolithic greedy reference, one batched call per problem
    let mut mono_rows = Vec::new();
    for pr in &ps {
        let (prompts, pads) = prompt_batch(&e, &pr.prompt).unwrap();
        let seeds = vec![0i32; e.meta.config.rollout_batch];
        let out = e.rollout(&params, None, &prompts, &pads, &seeds, 0.0).unwrap();
        mono_rows.push(out.tokens.data[..t].to_vec()); // row 0 (all rows identical)
    }
    for &chunk in &e.meta.decode_chunks.clone() {
        let rows: Vec<RowSpec> = (0..ps.len())
            .map(|i| RowSpec { group_idx: i, rollout_idx: 0, seed: 0 })
            .collect();
        let (outs, _) =
            decode_rows(&e, &params, None, 0.0, chunk, RefillMode::Continuous, &rows, &ps)
                .unwrap();
        for (i, r) in outs.iter().enumerate() {
            assert_eq!(r.tokens, mono_rows[i], "greedy problem {i} at C={chunk}");
        }
    }
    // and the public eval entry point is chunk-invariant (micro's tiny
    // prompt budget can reject real task prompts; only check when they fit)
    let chunks = e.meta.decode_chunks.clone();
    let fits = TaskKind::Arith
        .batch(Split::Test, 0, 8)
        .iter()
        .all(|p| p.prompt.len() <= e.meta.config.prompt_len);
    if fits {
        let weights = pods::reward::RewardWeights::default();
        let a = pods::eval::evaluate(
            &e, &params, None, TaskKind::Arith, Split::Test, 8, &weights, chunks[0],
        )
        .unwrap();
        for &c in &chunks[1..] {
            let b = pods::eval::evaluate(
                &e, &params, None, TaskKind::Arith, Split::Test, 8, &weights, c,
            )
            .unwrap();
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.mean_len, b.mean_len);
            assert_eq!(a.problems, b.problems);
        }
    }
}
