//! PJRT runtime: load AOT artifacts, compile once, execute from the hot path.
//!
//! This wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (see python/compile/aot.py for why).
//!
//! One [`Engine`] owns the PJRT client plus the lazily-compiled executables
//! of a single artifact profile, and exposes typed wrappers for each program
//! (`rollout`, `grad`, `update`, ...). Python never runs at this layer —
//! after `make artifacts` the binary is self-contained.

pub mod meta;
pub mod params;
pub mod tensor;

pub use meta::{Meta, ProfileConfig};
pub use params::ParamStore;
pub use tensor::{TensorF, TensorI};

use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use tensor::{lit_f32, lit_f32_scalar, lit_i32, lit_i32_scalar, lit_u32_scalar, to_vec_f32, to_vec_i32};

/// Wall-clock telemetry for one program's executions.
#[derive(Debug, Clone, Copy, Default)]
pub struct CallStats {
    /// Executions so far.
    pub calls: u64,
    /// Total wall-clock spent executing.
    pub total_secs: f64,
}

/// The PJRT execution engine for one artifact profile.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// The profile's `meta.json` bindings.
    pub meta: Meta,
    exes: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<HashMap<String, CallStats>>,
    /// Suppress compile-time log lines (tests/benches).
    pub quiet: bool,
}

/// Outputs of the `rollout` program (the inference phase).
#[derive(Debug, Clone)]
pub struct RolloutOut {
    /// i32[B, T]: prompt + generation, PAD after EOS.
    pub tokens: TensorI,
    /// f32[B, G]: behaviour log-probs of sampled tokens (π_fixed).
    pub logprobs: TensorF,
    /// f32[B, G]: 1.0 through EOS, 0.0 after.
    pub gen_mask: TensorF,
    /// i32[B]: generated length incl. EOS.
    pub gen_len: Vec<i32>,
}

/// Carried decode state between `decode_chunk` calls: the KV caches and
/// next-token logits stay as XLA literals end to end — slot-admission
/// merges run on device too ([`Engine::admit_merge`]), so the host never
/// materializes a cache.
pub struct DecodeState {
    /// f32[L, B, H, T, dh]
    pub cache_k: xla::Literal,
    /// f32[L, B, H, T, dh]
    pub cache_v: xla::Literal,
    /// f32[B, V] — next-token logits for every slot.
    pub logits: xla::Literal,
}

/// Host-side outputs of one `decode_chunk` call (the carried buffers stay
/// in the returned [`DecodeState`]).
#[derive(Debug, Clone)]
pub struct ChunkOut {
    /// i32[B, C] sampled tokens (PAD on done rows).
    pub tokens: Vec<i32>,
    /// f32[B, C] behaviour log-probs (0 on done rows).
    pub logprobs: Vec<f32>,
    /// f32[B, C] 1.0 through EOS, 0.0 after.
    pub mask: Vec<f32>,
    /// i32[B] decode steps executed per row — `>=` the row's generated
    /// tokens (it keeps advancing past EOS within a chunk); monotone
    /// across calls. Use the mask to count generated tokens.
    pub step: Vec<i32>,
    /// i32[B] per-row done flags.
    pub done: Vec<i32>,
}

/// Outputs of the `grad` program (one policy-update micro-batch).
#[derive(Debug, Clone)]
pub struct GradOut {
    /// Mean gradient over the micro-batch's `B_u` slots.
    pub grads: Vec<f32>,
    /// Mean clipped-surrogate loss.
    pub loss: f32,
    /// Fraction of clipped ratio terms.
    pub clip_frac: f32,
    /// Mean KL-to-reference estimate.
    pub kl: f32,
}

/// Inputs to one `grad` micro-batch, shaped [B_u, ...].
#[derive(Debug, Clone)]
pub struct MicroBatch {
    /// i32[B_u, T] full token rows.
    pub tokens: TensorI,
    /// i32[B_u] left-padding lengths.
    pub pad_len: Vec<i32>,
    /// f32[B_u, G] 1.0 through EOS.
    pub gen_mask: TensorF,
    /// f32[B_u, G] behaviour log-probs.
    pub old_lp: TensorF,
    /// f32[B_u] per-rollout advantages (0 on padded slots).
    pub adv: Vec<f32>,
    /// f32[B_u, G] reference log-probs (zeros when KL is off).
    pub ref_lp: TensorF,
}

impl Engine {
    /// Load a profile from `<artifacts_dir>/<profile>/`. Compilation of the
    /// individual programs is lazy (first call), so tools that only need one
    /// program don't pay for all six.
    pub fn load(artifacts_dir: &Path, profile: &str) -> Result<Self> {
        let dir = artifacts_dir.join(profile);
        let meta = Meta::load(&dir.join("meta.json"))
            .with_context(|| format!("profile {profile:?}: did you run `make artifacts`?"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            dir,
            meta,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
            quiet: false,
        })
    }

    fn exe(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp)?);
        if !self.quiet {
            eprintln!(
                "[runtime] compiled {}/{name} in {:.2}s",
                self.meta.profile,
                t0.elapsed().as_secs_f64()
            );
        }
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Force-compile a set of programs up front (e.g. before timing loops).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.exe(n)?;
        }
        Ok(())
    }

    /// Execute `name` with positional literals; returns the decomposed tuple.
    pub fn call(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let sig = self.meta.program(name)?;
        if sig.inputs.len() != inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                sig.inputs.len(),
                inputs.len()
            ));
        }
        let exe = self.exe(name)?;
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(name.to_string()).or_default();
        s.calls += 1;
        s.total_secs += dt;
        if outs.len() != sig.outputs.len() {
            return Err(anyhow!(
                "{name}: expected {} outputs, got {}",
                sig.outputs.len(),
                outs.len()
            ));
        }
        Ok(outs)
    }

    /// Per-program wall-clock stats accumulated so far.
    pub fn call_stats(&self) -> HashMap<String, CallStats> {
        self.stats.borrow().clone()
    }

    // ---- typed program wrappers --------------------------------------

    /// `init`: seed → fresh trainable vector (full params, or the LoRA
    /// vector in LoRA profiles).
    pub fn init(&self, seed: u32) -> Result<Vec<f32>> {
        let outs = self.call("init", &[lit_u32_scalar(seed)?])?;
        to_vec_f32(&outs[0])
    }

    /// `sft`: one fused supervised step. Returns loss; state is updated
    /// in-place in `store`.
    pub fn sft_step(
        &self,
        store: &mut ParamStore,
        tokens: &TensorI,
        pad_len: &[i32],
        loss_mask: &TensorF,
        lr: f32,
    ) -> Result<f32> {
        let outs = self.call(
            "sft",
            &[
                lit_f32(&store.params, &[store.params.len()])?,
                lit_f32(&store.m, &[store.m.len()])?,
                lit_f32(&store.v, &[store.v.len()])?,
                lit_i32_scalar(store.step),
                lit_i32(&tokens.data, &tokens.dims)?,
                lit_i32(pad_len, &[pad_len.len()])?,
                lit_f32(&loss_mask.data, &loss_mask.dims)?,
                lit_f32_scalar(lr),
            ],
        )?;
        let p = to_vec_f32(&outs[0])?;
        let m = to_vec_f32(&outs[1])?;
        let v = to_vec_f32(&outs[2])?;
        let loss = tensor::to_f32_scalar(&outs[3])?;
        store.adopt(p, m, v);
        Ok(loss)
    }

    /// Push the (base, [lora]) parameter literals shared by every
    /// inference-phase program.
    fn param_inputs(&self, base: &[f32], lora: Option<&[f32]>) -> Result<Vec<xla::Literal>> {
        let mut inputs = vec![lit_f32(base, &[base.len()])?];
        match (self.meta.is_lora(), lora) {
            (true, Some(l)) => inputs.push(lit_f32(l, &[l.len()])?),
            (false, None) => {}
            (true, None) => return Err(anyhow!("LoRA profile requires a lora vector")),
            (false, Some(_)) => return Err(anyhow!("non-LoRA profile got a lora vector")),
        }
        Ok(inputs)
    }

    /// `rollout`: the monolithic reference decode (prefill + one chunk of
    /// G inside a single program). `base` is the full-parameter vector;
    /// `lora` must be Some(trainable) in LoRA profiles and None otherwise.
    /// `seeds` are per-row RNG seeds (counter-based streams — a row's
    /// tokens depend only on its own seed). `temperature <= 0` decodes
    /// greedily. The production path is [`Self::prefill`] +
    /// [`Self::decode_chunk`]; this program remains the equivalence oracle
    /// and the no-early-exit baseline.
    pub fn rollout(
        &self,
        base: &[f32],
        lora: Option<&[f32]>,
        prompts: &TensorI,
        pad_len: &[i32],
        seeds: &[i32],
        temperature: f32,
    ) -> Result<RolloutOut> {
        let mut inputs = self.param_inputs(base, lora)?;
        inputs.push(lit_i32(&prompts.data, &prompts.dims)?);
        inputs.push(lit_i32(pad_len, &[pad_len.len()])?);
        inputs.push(lit_i32(seeds, &[seeds.len()])?);
        inputs.push(lit_f32_scalar(temperature));
        let outs = self.call("rollout", &inputs)?;
        let b = self.meta.config.rollout_batch;
        let t = self.meta.config.seq_len;
        let g = self.meta.gen_len;
        Ok(RolloutOut {
            tokens: TensorI::new(to_vec_i32(&outs[0])?, &[b, t])?,
            logprobs: TensorF::new(to_vec_f32(&outs[1])?, &[b, g])?,
            gen_mask: TensorF::new(to_vec_f32(&outs[2])?, &[b, g])?,
            gen_len: to_vec_i32(&outs[3])?,
        })
    }

    /// `prefill`: run the prompt pass and return the carried decode state
    /// (seeded KV caches + last prompt logits) for [`Self::decode_chunk`].
    pub fn prefill(
        &self,
        base: &[f32],
        lora: Option<&[f32]>,
        prompts: &TensorI,
        pad_len: &[i32],
    ) -> Result<DecodeState> {
        let mut inputs = self.param_inputs(base, lora)?;
        inputs.push(lit_i32(&prompts.data, &prompts.dims)?);
        inputs.push(lit_i32(pad_len, &[pad_len.len()])?);
        let mut outs = self.call("prefill", &inputs)?;
        if outs.len() != 3 {
            return Err(anyhow!("prefill returned {} outputs, expected 3", outs.len()));
        }
        let logits = outs.pop().expect("len checked");
        let cache_v = outs.pop().expect("len checked");
        let cache_k = outs.pop().expect("len checked");
        Ok(DecodeState { cache_k, cache_v, logits })
    }

    /// `prefill_shared`: [`Self::prefill`] that returns the prompt state
    /// twice — a working copy to decode with plus an immutable snapshot
    /// for later sibling admissions ([`Self::admit_share`]). The group's
    /// prompt pass runs **once**; every sibling row admitted afterwards
    /// replicates the snapshot on device instead of re-running prefill.
    pub fn prefill_shared(
        &self,
        base: &[f32],
        lora: Option<&[f32]>,
        prompts: &TensorI,
        pad_len: &[i32],
    ) -> Result<(DecodeState, DecodeState)> {
        let mut inputs = self.param_inputs(base, lora)?;
        inputs.push(lit_i32(&prompts.data, &prompts.dims)?);
        inputs.push(lit_i32(pad_len, &[pad_len.len()])?);
        let mut outs = self.call("prefill_shared", &inputs)?;
        if outs.len() != 6 {
            return Err(anyhow!("prefill_shared returned {} outputs, expected 6", outs.len()));
        }
        let snap_logits = outs.pop().expect("len checked");
        let snap_v = outs.pop().expect("len checked");
        let snap_k = outs.pop().expect("len checked");
        let logits = outs.pop().expect("len checked");
        let cache_v = outs.pop().expect("len checked");
        let cache_k = outs.pop().expect("len checked");
        Ok((
            DecodeState { cache_k, cache_v, logits },
            DecodeState { cache_k: snap_k, cache_v: snap_v, logits: snap_logits },
        ))
    }

    /// `admit_share`: sibling admission from a group's shared prompt
    /// snapshot — slots with `admit[b] != 0` take `snap`'s prompt state
    /// (every snapshot slot holds the same group prompt), the rest keep
    /// `live`'s carried decode state, and the snapshot passes through the
    /// call for reuse by the group's next admission. [`Self::admit_merge`]
    /// generalized to a source state that must outlive the merge; no
    /// transformer forward runs. Consumes both states, returns
    /// `(merged, snapshot)`.
    pub fn admit_share(
        &self,
        live: DecodeState,
        snap: DecodeState,
        admit: &[i32],
    ) -> Result<(DecodeState, DecodeState)> {
        let inputs = vec![
            live.cache_k,
            live.cache_v,
            live.logits,
            snap.cache_k,
            snap.cache_v,
            snap.logits,
            lit_i32(admit, &[admit.len()])?,
        ];
        let mut outs = self.call("admit_share", &inputs)?;
        if outs.len() != 6 {
            return Err(anyhow!("admit_share returned {} outputs, expected 6", outs.len()));
        }
        let snap_logits = outs.pop().expect("len checked");
        let snap_v = outs.pop().expect("len checked");
        let snap_k = outs.pop().expect("len checked");
        let logits = outs.pop().expect("len checked");
        let cache_v = outs.pop().expect("len checked");
        let cache_k = outs.pop().expect("len checked");
        Ok((
            DecodeState { cache_k, cache_v, logits },
            DecodeState { cache_k: snap_k, cache_v: snap_v, logits: snap_logits },
        ))
    }

    /// `admit_merge`: slot-admission merge on device — slots with
    /// `admit[b] != 0` take `fresh`'s prefill state, the rest keep
    /// `live`'s carried decode state. Consumes both states.
    pub fn admit_merge(
        &self,
        live: DecodeState,
        fresh: DecodeState,
        admit: &[i32],
    ) -> Result<DecodeState> {
        let inputs = vec![
            live.cache_k,
            live.cache_v,
            live.logits,
            fresh.cache_k,
            fresh.cache_v,
            fresh.logits,
            lit_i32(admit, &[admit.len()])?,
        ];
        let mut outs = self.call("admit_merge", &inputs)?;
        if outs.len() != 3 {
            return Err(anyhow!("admit_merge returned {} outputs, expected 3", outs.len()));
        }
        let logits = outs.pop().expect("len checked");
        let cache_v = outs.pop().expect("len checked");
        let cache_k = outs.pop().expect("len checked");
        Ok(DecodeState { cache_k, cache_v, logits })
    }

    /// `decode_chunk<chunk>`: decode `chunk` tokens for every slot,
    /// carrying the KV caches/logits across calls. Consumes `state` (the
    /// literals move into the call) and returns the updated state plus the
    /// host-side chunk outputs.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_chunk(
        &self,
        chunk: usize,
        base: &[f32],
        lora: Option<&[f32]>,
        state: DecodeState,
        seeds: &[i32],
        step: &[i32],
        done: &[i32],
        pad_len: &[i32],
        temperature: f32,
    ) -> Result<(DecodeState, ChunkOut)> {
        let name = format!("decode_chunk{chunk}");
        if !self.meta.programs.contains_key(&name) {
            return Err(anyhow!(
                "profile {} has no decode_chunk program for chunk size {chunk} \
                 (available: {:?}; re-run `make artifacts` if the list is empty)",
                self.meta.profile,
                self.meta.decode_chunks
            ));
        }
        let mut inputs = self.param_inputs(base, lora)?;
        inputs.push(state.cache_k);
        inputs.push(state.cache_v);
        inputs.push(state.logits);
        inputs.push(lit_i32(seeds, &[seeds.len()])?);
        inputs.push(lit_i32(step, &[step.len()])?);
        inputs.push(lit_i32(done, &[done.len()])?);
        inputs.push(lit_i32(pad_len, &[pad_len.len()])?);
        inputs.push(lit_f32_scalar(temperature));
        let mut outs = self.call(&name, &inputs)?;
        if outs.len() != 8 {
            return Err(anyhow!("{name} returned {} outputs, expected 8", outs.len()));
        }
        // outputs: tokens, logprobs, mask, cache_k, cache_v, logits, step, done
        let done_l = outs.pop().expect("len checked");
        let step_l = outs.pop().expect("len checked");
        let logits = outs.pop().expect("len checked");
        let cache_v = outs.pop().expect("len checked");
        let cache_k = outs.pop().expect("len checked");
        let out = ChunkOut {
            tokens: to_vec_i32(&outs[0])?,
            logprobs: to_vec_f32(&outs[1])?,
            mask: to_vec_f32(&outs[2])?,
            step: to_vec_i32(&step_l)?,
            done: to_vec_i32(&done_l)?,
        };
        Ok((DecodeState { cache_k, cache_v, logits }, out))
    }

    /// `grad`: one GRPO-PODS policy-update micro-batch.
    /// `trainable` is what the optimizer updates; `base` the frozen full
    /// vector in LoRA mode (None otherwise).
    pub fn grad(
        &self,
        trainable: &[f32],
        base: Option<&[f32]>,
        mb: &MicroBatch,
        kl_coef: f32,
    ) -> Result<GradOut> {
        let mut inputs = vec![lit_f32(trainable, &[trainable.len()])?];
        match (self.meta.is_lora(), base) {
            (true, Some(b)) => inputs.push(lit_f32(b, &[b.len()])?),
            (false, None) => {}
            (true, None) => return Err(anyhow!("LoRA profile requires a base vector")),
            (false, Some(_)) => return Err(anyhow!("non-LoRA profile got a base vector")),
        }
        inputs.push(lit_i32(&mb.tokens.data, &mb.tokens.dims)?);
        inputs.push(lit_i32(&mb.pad_len, &[mb.pad_len.len()])?);
        inputs.push(lit_f32(&mb.gen_mask.data, &mb.gen_mask.dims)?);
        inputs.push(lit_f32(&mb.old_lp.data, &mb.old_lp.dims)?);
        inputs.push(lit_f32(&mb.adv, &[mb.adv.len()])?);
        inputs.push(lit_f32(&mb.ref_lp.data, &mb.ref_lp.dims)?);
        inputs.push(lit_f32_scalar(kl_coef));
        let outs = self.call("grad", &inputs)?;
        Ok(GradOut {
            grads: to_vec_f32(&outs[0])?,
            loss: tensor::to_f32_scalar(&outs[1])?,
            clip_frac: tensor::to_f32_scalar(&outs[2])?,
            kl: tensor::to_f32_scalar(&outs[3])?,
        })
    }

    /// `update`: apply accumulated grads with fused AdamW; bumps `store.step`.
    pub fn update(&self, store: &mut ParamStore, grads: &[f32], lr: f32) -> Result<()> {
        let outs = self.call(
            "update",
            &[
                lit_f32(&store.params, &[store.params.len()])?,
                lit_f32(&store.m, &[store.m.len()])?,
                lit_f32(&store.v, &[store.v.len()])?,
                lit_i32_scalar(store.step),
                lit_f32(grads, &[grads.len()])?,
                lit_f32_scalar(lr),
            ],
        )?;
        let p = to_vec_f32(&outs[0])?;
        let m = to_vec_f32(&outs[1])?;
        let v = to_vec_f32(&outs[2])?;
        store.adopt(p, m, v);
        Ok(())
    }

    /// `score`: teacher-forced log-probs of the generated region under the
    /// given parameters (the KL reference policy path).
    pub fn score(
        &self,
        base: &[f32],
        lora: Option<&[f32]>,
        tokens: &TensorI,
        pad_len: &[i32],
    ) -> Result<TensorF> {
        let mut inputs = vec![lit_f32(base, &[base.len()])?];
        if self.meta.is_lora() {
            let l = lora.ok_or_else(|| anyhow!("LoRA profile requires a lora vector"))?;
            inputs.push(lit_f32(l, &[l.len()])?);
        }
        inputs.push(lit_i32(&tokens.data, &tokens.dims)?);
        inputs.push(lit_i32(pad_len, &[pad_len.len()])?);
        let outs = self.call("score", &inputs)?;
        let b = self.meta.config.rollout_batch;
        let g = self.meta.gen_len;
        TensorF::new(to_vec_f32(&outs[0])?, &[b, g])
    }
}
