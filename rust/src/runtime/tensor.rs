//! Minimal host-side tensors + literal marshalling helpers.
//!
//! The runtime deals in three dtypes only (f32 / i32 / u32 scalars), so a
//! tiny enum-free design keeps the hot path allocation-predictable: every
//! tensor is a flat `Vec` plus dims, and conversion to/from `xla::Literal`
//! is a single memcpy.

use anyhow::{anyhow, Result};

/// Host tensor of f32 values.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorF {
    /// Row-major elements.
    pub data: Vec<f32>,
    /// Dimensions.
    pub dims: Vec<usize>,
}

/// Host tensor of i32 values.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorI {
    /// Row-major elements.
    pub data: Vec<i32>,
    /// Dimensions.
    pub dims: Vec<usize>,
}

impl TensorF {
    /// Wrap `data` as shape `dims`; errors on element-count mismatch.
    pub fn new(data: Vec<f32>, dims: &[usize]) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(anyhow!("TensorF: {} elements for dims {dims:?}", data.len()));
        }
        Ok(Self { data, dims: dims.to_vec() })
    }

    /// All-zero tensor of shape `dims`.
    pub fn zeros(dims: &[usize]) -> Self {
        let n = dims.iter().product();
        Self { data: vec![0.0; n], dims: dims.to_vec() }
    }

    /// Row-major 2-D accessor (debug/test convenience).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.dims.len(), 2);
        self.data[i * self.dims[1] + j]
    }
}

impl TensorI {
    /// Wrap `data` as shape `dims`; errors on element-count mismatch.
    pub fn new(data: Vec<i32>, dims: &[usize]) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(anyhow!("TensorI: {} elements for dims {dims:?}", data.len()));
        }
        Ok(Self { data, dims: dims.to_vec() })
    }

    /// All-zero tensor of shape `dims`.
    pub fn zeros(dims: &[usize]) -> Self {
        let n = dims.iter().product();
        Self { data: vec![0; n], dims: dims.to_vec() }
    }

    /// Row-major 2-D accessor (debug/test convenience).
    pub fn at2(&self, i: usize, j: usize) -> i32 {
        debug_assert_eq!(self.dims.len(), 2);
        self.data[i * self.dims[1] + j]
    }
}

// ---- literal construction -------------------------------------------------

/// Build an f32 literal from host data (one memcpy).
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

/// Build an i32 literal from host data (one memcpy).
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        dims,
        bytes,
    )?)
}

/// Scalar f32 literal.
pub fn lit_f32_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Scalar i32 literal.
pub fn lit_i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Scalar u32 literal (RNG seeds).
pub fn lit_u32_scalar(v: u32) -> Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U32,
        &[],
        &v.to_le_bytes(),
    )?)
}

// ---- literal extraction ---------------------------------------------------

/// Copy an f32 literal back to host.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Copy an i32 literal back to host.
pub fn to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

/// Read a scalar f32 literal.
pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_validation() {
        assert!(TensorF::new(vec![1.0, 2.0], &[3]).is_err());
        let t = TensorF::new(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(t.at2(1, 0), 3.0);
        let ti = TensorI::zeros(&[4, 5]);
        assert_eq!(ti.data.len(), 20);
        assert_eq!(ti.at2(3, 4), 0);
    }

    #[test]
    fn literal_roundtrip() {
        let data = vec![1.5f32, -2.0, 0.25, 7.0, 0.0, 3.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
        let ints = vec![1i32, -5, 7];
        let lit = lit_i32(&ints, &[3]).unwrap();
        assert_eq!(to_vec_i32(&lit).unwrap(), ints);
        let s = lit_u32_scalar(0xdeadbeef).unwrap();
        assert_eq!(s.to_vec::<u32>().unwrap(), vec![0xdeadbeef]);
    }
}
