//! Flat parameter / optimizer-state store + checkpoint format.
//!
//! The L2 packer lays every model parameter into one flat f32 vector (padded
//! to the AdamW kernel's block multiple), so the training state the Rust
//! side owns is exactly: `params`, Adam `m`, Adam `v`, and the step counter.
//! In LoRA profiles there is additionally a frozen `base` vector.
//!
//! Checkpoint format (`.pods.ckpt`): a one-line JSON header (versioned,
//! records profile + lengths + step) followed by the raw little-endian f32
//! payloads in order. Written atomically via a temp file + rename.

use crate::util::json::{obj, Json};
use anyhow::{anyhow, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Trainable state: the vector the optimizer updates + Adam moments.
#[derive(Debug, Clone)]
pub struct ParamStore {
    /// The optimized flat vector.
    pub params: Vec<f32>,
    /// Adam first moments.
    pub m: Vec<f32>,
    /// Adam second moments.
    pub v: Vec<f32>,
    /// Optimizer step counter (bias correction).
    pub step: i32,
}

impl ParamStore {
    /// Fresh store: zero moments, step 0.
    pub fn new(params: Vec<f32>) -> Self {
        let n = params.len();
        Self { params, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }

    /// Trainable-vector length.
    pub fn len(&self) -> usize {
        self.params.len()
    }

    /// Whether the store holds no parameters.
    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    /// Adopt the three vectors returned by the `update`/`sft` programs.
    pub fn adopt(&mut self, params: Vec<f32>, m: Vec<f32>, v: Vec<f32>) {
        debug_assert_eq!(params.len(), self.params.len());
        self.params = params;
        self.m = m;
        self.v = v;
        self.step += 1;
    }
}

#[derive(Debug)]
struct CkptHeader {
    magic: String,
    version: u32,
    profile: String,
    step: i32,
    sections: Vec<(String, usize)>, // (name, f32 length) in payload order
}

impl CkptHeader {
    fn to_json(&self) -> Json {
        obj(vec![
            ("magic", Json::Str(self.magic.clone())),
            ("version", Json::Num(self.version as f64)),
            ("profile", Json::Str(self.profile.clone())),
            ("step", Json::Num(self.step as f64)),
            (
                "sections",
                Json::Arr(
                    self.sections
                        .iter()
                        .map(|(n, l)| {
                            Json::Arr(vec![Json::Str(n.clone()), Json::Num(*l as f64)])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        let sections = j
            .get("sections")?
            .arr()?
            .iter()
            .map(|e| {
                let pair = e.arr()?;
                Ok((pair[0].str()?.to_string(), pair[1].usize()?))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            magic: j.get("magic")?.str()?.to_string(),
            version: j.get("version")?.usize()? as u32,
            profile: j.get("profile")?.str()?.to_string(),
            step: j.get("step")?.i64()? as i32,
            sections,
        })
    }
}

const MAGIC: &str = "pods-ckpt";

/// Write `sections` (name -> f32 slice) with a JSON header line.
pub fn save_checkpoint(
    path: &Path,
    profile: &str,
    step: i32,
    sections: &[(&str, &[f32])],
) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let f = std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
        let mut w = BufWriter::new(f);
        let header = CkptHeader {
            magic: MAGIC.into(),
            version: 1,
            profile: profile.into(),
            step,
            sections: sections.iter().map(|(n, d)| (n.to_string(), d.len())).collect(),
        };
        w.write_all(header.to_json().dump().as_bytes())?;
        w.write_all(b"\n")?;
        for (_, data) in sections {
            let bytes = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            w.write_all(bytes)?;
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?}"))?;
    Ok(())
}

/// Load a checkpoint; returns (profile, step, sections).
pub fn load_checkpoint(path: &Path) -> Result<(String, i32, Vec<(String, Vec<f32>)>)> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut header_line = Vec::new();
    loop {
        let mut b = [0u8; 1];
        r.read_exact(&mut b)?;
        if b[0] == b'\n' {
            break;
        }
        header_line.push(b[0]);
        if header_line.len() > 1 << 20 {
            return Err(anyhow!("checkpoint header too large"));
        }
    }
    let header = CkptHeader::from_json(&Json::parse(std::str::from_utf8(&header_line)?)?)?;
    if header.magic != MAGIC {
        return Err(anyhow!("not a pods checkpoint: {path:?}"));
    }
    let mut out = Vec::new();
    for (name, len) in header.sections {
        let mut bytes = vec![0u8; len * 4];
        r.read_exact(&mut bytes)
            .with_context(|| format!("reading section {name} ({len} f32)"))?;
        let mut data = vec![0f32; len];
        for (i, c) in bytes.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        out.push((name, data));
    }
    Ok((header.profile, header.step, out))
}

/// Convenience: save a ParamStore (plus optional frozen base).
pub fn save_store(path: &Path, profile: &str, store: &ParamStore, base: Option<&[f32]>) -> Result<()> {
    let mut sections: Vec<(&str, &[f32])> = vec![
        ("params", &store.params),
        ("m", &store.m),
        ("v", &store.v),
    ];
    if let Some(b) = base {
        sections.push(("base", b));
    }
    save_checkpoint(path, profile, store.step, &sections)
}

/// Convenience: load a ParamStore (plus optional base) saved by `save_store`.
pub fn load_store(path: &Path) -> Result<(String, ParamStore, Option<Vec<f32>>)> {
    let (profile, step, sections) = load_checkpoint(path)?;
    let mut params = None;
    let mut m = None;
    let mut v = None;
    let mut base = None;
    for (name, data) in sections {
        match name.as_str() {
            "params" => params = Some(data),
            "m" => m = Some(data),
            "v" => v = Some(data),
            "base" => base = Some(data),
            other => return Err(anyhow!("unknown checkpoint section {other:?}")),
        }
    }
    let params = params.ok_or_else(|| anyhow!("checkpoint missing params"))?;
    let n = params.len();
    let store = ParamStore {
        params,
        m: m.unwrap_or_else(|| vec![0.0; n]),
        v: v.unwrap_or_else(|| vec![0.0; n]),
        step,
    };
    Ok((profile, store, base))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("t.pods.ckpt");
        let mut store = ParamStore::new(vec![1.0, -2.5, 3.25, 0.0]);
        store.m[1] = 9.0;
        store.step = 42;
        save_store(&path, "micro", &store, Some(&[7.0, 8.0])).unwrap();
        let (profile, loaded, base) = load_store(&path).unwrap();
        assert_eq!(profile, "micro");
        assert_eq!(loaded.step, 42);
        assert_eq!(loaded.params, store.params);
        assert_eq!(loaded.m, store.m);
        assert_eq!(loaded.v, store.v);
        assert_eq!(base.unwrap(), vec![7.0, 8.0]);
    }

    #[test]
    fn adopt_bumps_step() {
        let mut s = ParamStore::new(vec![0.0; 3]);
        s.adopt(vec![1.0; 3], vec![2.0; 3], vec![3.0; 3]);
        assert_eq!(s.step, 1);
        assert_eq!(s.params, vec![1.0; 3]);
    }

    #[test]
    fn rejects_garbage() {
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("bad.ckpt");
        std::fs::write(&path, b"{\"magic\":\"nope\",\"version\":1,\"profile\":\"x\",\"step\":0,\"sections\":[]}\n").unwrap();
        assert!(load_checkpoint(&path).is_err());
    }
}
