//! Typed bindings for `artifacts/<profile>/meta.json`.
//!
//! The AOT pipeline (python/compile/aot.py) emits, next to the HLO text of
//! every program, a JSON description of the profile: model dimensions, the
//! flat-parameter offset table, the shared vocabulary, and the exact
//! input/output signature of each program. The runtime validates every call
//! against these signatures so shape drift between the Python and Rust
//! halves fails loudly instead of corrupting buffers.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Static model/program dimensions of one artifact profile
/// (mirror of python `ModelConfig`).
#[derive(Debug, Clone)]
pub struct ProfileConfig {
    /// Vocabulary size.
    pub vocab: usize,
    /// Residual width.
    pub d_model: usize,
    /// Transformer layers.
    pub layers: usize,
    /// Attention heads.
    pub heads: usize,
    /// Feed-forward width.
    pub d_ff: usize,
    /// Full sequence length `T` (prompt + generation).
    pub seq_len: usize,
    /// Prompt region length `P`.
    pub prompt_len: usize,
    /// Rows per rollout/decode call (`B_r`).
    pub rollout_batch: usize,
    /// Rows per grad micro-batch (`B_u`).
    pub update_batch: usize,
    /// LoRA rank (0 = full-parameter profile).
    pub lora_rank: usize,
    /// LoRA scaling alpha.
    pub lora_alpha: f64,
    /// PPO/GRPO ratio clipping epsilon.
    pub clip_eps: f64,
    /// AdamW weight decay.
    pub weight_decay: f64,
    /// Flat-parameter padding block multiple.
    pub pad_multiple: usize,
}

impl ProfileConfig {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            vocab: j.get("vocab")?.usize()?,
            d_model: j.get("d_model")?.usize()?,
            layers: j.get("layers")?.usize()?,
            heads: j.get("heads")?.usize()?,
            d_ff: j.get("d_ff")?.usize()?,
            seq_len: j.get("seq_len")?.usize()?,
            prompt_len: j.get("prompt_len")?.usize()?,
            rollout_batch: j.get("rollout_batch")?.usize()?,
            update_batch: j.get("update_batch")?.usize()?,
            lora_rank: j.get("lora_rank")?.usize()?,
            lora_alpha: j.get("lora_alpha")?.f64()?,
            clip_eps: j.get("clip_eps")?.f64()?,
            weight_decay: j.get("weight_decay")?.f64()?,
            pad_multiple: j.get("pad_multiple")?.usize()?,
        })
    }
}

/// One entry of the flat-parameter offset table.
#[derive(Debug, Clone)]
pub struct SpecEntry {
    /// Parameter name (python-side identifier).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Offset into the flat vector.
    pub offset: usize,
    /// Element count (`shape.product()`).
    pub size: usize,
}

/// Flat-parameter layout: where every tensor lives in the packed vector.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    /// Per-tensor entries, offset order.
    pub entries: Vec<SpecEntry>,
    /// Elements actually used by tensors.
    pub used: usize,
    /// Total vector length incl. block padding.
    pub padded: usize,
}

impl ParamSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let entries = j
            .get("entries")?
            .arr()?
            .iter()
            .map(|e| {
                Ok(SpecEntry {
                    name: e.get("name")?.str()?.to_string(),
                    shape: e.get("shape")?.arr()?.iter().map(|s| s.usize()).collect::<Result<_>>()?,
                    offset: e.get("offset")?.usize()?,
                    size: e.get("size")?.usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            entries,
            used: j.get("used")?.usize()?,
            padded: j.get("padded")?.usize()?,
        })
    }
}

/// The shared token vocabulary (single source of truth is
/// python/compile/vocab.py; `tasks::tokenizer` cross-checks its Rust mirror
/// against this at engine load).
#[derive(Debug, Clone)]
pub struct VocabMeta {
    /// Display strings, indexed by token id.
    pub tokens: Vec<String>,
    /// Number of tokens.
    pub vocab_size: usize,
    /// `<pad>` id.
    pub pad: i32,
    /// `<bos>` id.
    pub bos: i32,
    /// `<eos>` id.
    pub eos: i32,
    /// Newline id.
    pub nl: i32,
    /// `<think>` id.
    pub think_open: i32,
    /// `</think>` id.
    pub think_close: i32,
    /// `<answer>` id.
    pub answer_open: i32,
    /// `</answer>` id.
    pub answer_close: i32,
    /// Id of digit `0` (digits are contiguous).
    pub digit0: i32,
}

impl VocabMeta {
    fn from_json(j: &Json) -> Result<Self> {
        let tok = |k: &str| -> Result<i32> { Ok(j.get(k)?.i64()? as i32) };
        Ok(Self {
            tokens: j.get("tokens")?.arr()?.iter().map(|t| Ok(t.str()?.to_string())).collect::<Result<_>>()?,
            vocab_size: j.get("vocab_size")?.usize()?,
            pad: tok("pad")?,
            bos: tok("bos")?,
            eos: tok("eos")?,
            nl: tok("nl")?,
            think_open: tok("think_open")?,
            think_close: tok("think_close")?,
            answer_open: tok("answer_open")?,
            answer_close: tok("answer_close")?,
            digit0: tok("digit0")?,
        })
    }
}

/// Declared shape/dtype of one program input or output.
#[derive(Debug, Clone)]
pub struct TensorSig {
    /// Tensor name in the program signature.
    pub name: String,
    /// Element dtype (`f32` | `i32` | `u32`).
    pub dtype: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
}

impl TensorSig {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.get("name")?.str()?.to_string(),
            dtype: j.get("dtype")?.str()?.to_string(),
            shape: j.get("shape")?.arr()?.iter().map(|s| s.usize()).collect::<Result<_>>()?,
        })
    }

    /// Product of the shape dims.
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Input/output signature of one AOT program.
#[derive(Debug, Clone)]
pub struct ProgramSig {
    /// Positional inputs.
    pub inputs: Vec<TensorSig>,
    /// Tuple outputs, in order.
    pub outputs: Vec<TensorSig>,
}

/// Everything `meta.json` records about one artifact profile.
#[derive(Debug, Clone)]
pub struct Meta {
    /// Profile name (micro | base | lora | big).
    pub profile: String,
    /// Model/program dimensions.
    pub config: ProfileConfig,
    /// Generation budget `G` per rollout.
    pub gen_len: usize,
    /// Chunk sizes the AOT pipeline lowered `decode_chunk<C>` programs for
    /// (empty for artifacts predating the chunked decode path).
    pub decode_chunks: Vec<usize>,
    /// Full-parameter vector length.
    pub param_count: usize,
    /// LoRA adapter vector length (0 when full-parameter).
    pub lora_count: usize,
    /// Length of the vector the optimizer updates.
    pub trainable_count: usize,
    /// Layout of the full-parameter vector.
    pub param_spec: ParamSpec,
    /// Layout of the adapter vector (LoRA profiles).
    pub lora_spec: Option<ParamSpec>,
    /// The shared token vocabulary.
    pub vocab: VocabMeta,
    /// Signature of every lowered program, by name.
    pub programs: HashMap<String, ProgramSig>,
}

impl Meta {
    /// Parse a profile's `meta.json`.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let mut programs = HashMap::new();
        for (name, sig) in j.get("programs")?.obj()? {
            let inputs = sig.get("inputs")?.arr()?.iter().map(TensorSig::from_json).collect::<Result<_>>()?;
            let outputs = sig.get("outputs")?.arr()?.iter().map(TensorSig::from_json).collect::<Result<_>>()?;
            programs.insert(name.clone(), ProgramSig { inputs, outputs });
        }
        Ok(Self {
            profile: j.get("profile")?.str()?.to_string(),
            config: ProfileConfig::from_json(j.get("config")?)?,
            gen_len: j.get("gen_len")?.usize()?,
            decode_chunks: match j.opt("decode_chunks") {
                Some(arr) => arr.arr()?.iter().map(|c| c.usize()).collect::<Result<_>>()?,
                None => Vec::new(),
            },
            param_count: j.get("param_count")?.usize()?,
            lora_count: j.get("lora_count")?.usize()?,
            trainable_count: j.get("trainable_count")?.usize()?,
            param_spec: ParamSpec::from_json(j.get("param_spec")?)?,
            lora_spec: match j.opt("lora_spec") {
                Some(ls) => Some(ParamSpec::from_json(ls)?),
                None => None,
            },
            vocab: VocabMeta::from_json(j.get("vocab")?)?,
            programs,
        })
    }

    /// Signature of program `name`, or a descriptive error.
    pub fn program(&self, name: &str) -> Result<&ProgramSig> {
        self.programs
            .get(name)
            .ok_or_else(|| anyhow!("profile {} has no program {name:?}", self.profile))
    }

    /// Whether this profile trains LoRA adapters over a frozen base.
    pub fn is_lora(&self) -> bool {
        self.config.lora_rank > 0
    }

    /// Preferred decode chunk when the caller has no run config (the eval
    /// CLI): 16 when lowered, else the largest available size.
    pub fn default_decode_chunk(&self) -> Option<usize> {
        if self.decode_chunks.contains(&16) {
            Some(16)
        } else {
            self.decode_chunks.iter().copied().max()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_micro_meta_when_present() {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/micro/meta.json");
        if !p.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Meta::load(&p).unwrap();
        assert_eq!(m.profile, "micro");
        assert!(m.param_count % m.config.pad_multiple == 0);
        assert_eq!(m.vocab.vocab_size, m.config.vocab);
        let r = m.program("rollout").unwrap();
        assert_eq!(r.outputs.len(), 4);
        assert_eq!(r.outputs[0].shape, vec![m.config.rollout_batch, m.config.seq_len]);
        // offset table is contiguous
        let mut off = 0;
        for e in &m.param_spec.entries {
            assert_eq!(e.offset, off);
            assert_eq!(e.size, e.shape.iter().product::<usize>());
            off += e.size;
        }
        assert_eq!(off, m.param_spec.used);
        assert!(m.param_spec.padded >= off);
    }
}
