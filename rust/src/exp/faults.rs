//! Fault-tolerance study — graceful degradation under deterministic fault
//! injection, swept over `fault rate × max_retries`.
//!
//! Not a paper figure: this driver quantifies what the `[faults]` retry
//! layer buys. It runs entirely on the cost model (no artifacts): the
//! seeded [`FaultPlan`] schedule is evaluated over a synthetic workload of
//! `ITERS × GROUPS` prompt groups of `N` rollouts each, replaying exactly
//! the per-row-attempt draws the executor would make, and each cell prices
//! its retry bill (backoff seconds + crash-wasted tokens) against the
//! healthy decode bill from the same [`HwModel`].
//!
//! Shapes that must reproduce (asserted by this module's tests):
//!
//! * **no cliff for down-sampling**: with the default 2 retries, PODS'
//!   selection fill (`mean min(survivors, m) / m`) stays ≥ 0.9 of its
//!   fault-free value up to a 10% per-attempt fault rate — losing rows
//!   barely matters while every group still has ≥ m survivors;
//! * **the cliff exists for full-batch**: the fraction of groups keeping
//!   all `n` rollouts (what a no-down-sampling consumer needs) collapses
//!   as the rate grows, at every retry budget;
//! * **retries rescue rows**: at a fixed rate, `rows_lost_frac` shrinks
//!   roughly geometrically in `max_retries`.

use crate::hwsim::{FaultKind, FaultPlan, FaultSection, HwModel};
use crate::metrics::{ascii_plot, write_csv_rows, CsvRow};
use anyhow::Result;
use std::path::Path;

/// Rollouts generated per prompt (the paper's default n).
const N: usize = 64;
/// Rollouts kept per prompt by down-sampling (the paper's default m).
const M: usize = 16;
/// Prompt groups per simulated iteration.
const GROUPS: usize = 8;
/// Simulated iterations.
const ITERS: usize = 50;
/// Generation budget G of the simulated profile (crash waste per attempt).
const G: usize = 64;
/// Seed of the deterministic fault schedule.
const SIM_SEED: u64 = 0x5EED_FA17;
/// Per-attempt fault rates swept (total across the three fault kinds).
const RATE_SWEEP: [f64; 5] = [0.0, 0.02, 0.05, 0.10, 0.15];
/// Retry budgets swept.
const RETRY_SWEEP: [usize; 4] = [0, 1, 2, 3];

/// Split one total fault rate across the three kinds the way a mixed
/// failure domain would see them: crashes dominate, OOMs are rare.
fn section(rate: f64, retries: usize) -> FaultSection {
    FaultSection {
        enabled: true,
        crash_rate: rate * 0.5,
        transient_rate: rate * 0.3,
        oom_rate: rate * 0.2,
        max_retries: retries,
        ..Default::default()
    }
}

/// One (rate, retries) cell of the sweep.
#[derive(Debug, Clone)]
pub struct FaultCell {
    /// Total per-row-attempt fault rate of the cell.
    pub fault_rate: f64,
    /// Retry budget of the cell.
    pub max_retries: usize,
    /// Rows simulated (iters × groups × n).
    pub rows: usize,
    /// Faults injected across all attempts.
    pub faults_injected: usize,
    /// Physical retries (faulted attempts that had budget left).
    pub retries: usize,
    /// Rows lost after exhausting the retry budget.
    pub rows_lost: usize,
    /// `rows_lost / rows`.
    pub rows_lost_frac: f64,
    /// PODS selection fill: mean over groups of `min(survivors, m) / m`.
    pub pods_fill: f64,
    /// Full-batch fill: fraction of groups keeping all `n` survivors.
    pub full_batch_fill: f64,
    /// Groups that fell below the `min_group_survivors` floor.
    pub floor_violations: usize,
    /// Simulated retry bill: backoff seconds + crash-wasted token time.
    pub retry_time: f64,
    /// `retry_time` over the healthy decode bill of the same workload.
    pub overhead_frac: f64,
}

impl CsvRow for FaultCell {
    fn csv_header() -> &'static str {
        "fault_rate,max_retries,rows,faults_injected,retries,rows_lost,\
         rows_lost_frac,pods_fill,full_batch_fill,floor_violations,\
         retry_time,overhead_frac"
    }

    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{},{},{},{}",
            self.fault_rate,
            self.max_retries,
            self.rows,
            self.faults_injected,
            self.retries,
            self.rows_lost,
            self.rows_lost_frac,
            self.pods_fill,
            self.full_batch_fill,
            self.floor_violations,
            self.retry_time,
            self.overhead_frac
        )
    }
}

/// Evaluate one cell by replaying the executor's per-row-attempt schedule
/// arithmetic (`FaultPlan::row_fault` at attempt 0..=max_retries, charging
/// backoff on every faulted attempt with budget left and `G` wasted tokens
/// on every crash).
pub fn eval_cell(hw: &HwModel, rate: f64, retries: usize) -> FaultCell {
    let sec = section(rate, retries);
    let plan = FaultPlan::new(SIM_SEED, sec.clone());
    let tok_time = hw.per_token_time(1);
    let mut cell = FaultCell {
        fault_rate: rate,
        max_retries: retries,
        rows: ITERS * GROUPS * N,
        faults_injected: 0,
        retries: 0,
        rows_lost: 0,
        rows_lost_frac: 0.0,
        pods_fill: 0.0,
        full_batch_fill: 0.0,
        floor_violations: 0,
        retry_time: 0.0,
        overhead_frac: 0.0,
    };
    let mut healthy_tokens = 0usize;
    let mut groups = 0usize;
    for iter in 0..ITERS as u64 {
        for g in 0..GROUPS as u64 {
            let prompt_id = iter * GROUPS as u64 + g;
            let mut survivors = 0usize;
            for idx in 0..N as u64 {
                let mut lost = true;
                for attempt in 0..=retries {
                    match plan.row_fault(iter, prompt_id, idx, attempt) {
                        None => {
                            lost = false;
                            survivors += 1;
                            healthy_tokens += G;
                            break;
                        }
                        Some(kind) => {
                            cell.faults_injected += 1;
                            if kind == FaultKind::Crash {
                                cell.retry_time += G as f64 * tok_time;
                            }
                            if attempt < retries {
                                cell.retries += 1;
                                cell.retry_time += plan.backoff(attempt);
                            }
                        }
                    }
                }
                if lost {
                    cell.rows_lost += 1;
                }
            }
            groups += 1;
            cell.pods_fill += survivors.min(M) as f64 / M as f64;
            if survivors == N {
                cell.full_batch_fill += 1.0;
            }
            if survivors < sec.min_group_survivors {
                cell.floor_violations += 1;
            }
        }
    }
    cell.rows_lost_frac = cell.rows_lost as f64 / cell.rows as f64;
    cell.pods_fill /= groups as f64;
    cell.full_batch_fill /= groups as f64;
    let base_time = healthy_tokens as f64 * tok_time;
    cell.overhead_frac = cell.retry_time / base_time.max(1e-12);
    cell
}

/// Build the sweep grid (row-major: retries, then rate ascending).
/// Deterministic: pure schedule arithmetic, same seed every run.
pub fn sweep(hw: &HwModel) -> Vec<FaultCell> {
    let mut out = Vec::with_capacity(RETRY_SWEEP.len() * RATE_SWEEP.len());
    for &retries in &RETRY_SWEEP {
        for &rate in &RATE_SWEEP {
            out.push(eval_cell(hw, rate, retries));
        }
    }
    out
}

/// Run the study: write `<out_dir>/faults.csv` and print the degradation
/// curves (PODS fill vs rate, one curve per retry budget) plus the table.
pub fn run(out_dir: &str) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let hw = HwModel::default();
    let cells = sweep(&hw);
    write_csv_rows(Path::new(&format!("{out_dir}/faults.csv")), &cells)?;

    let curves: Vec<(String, Vec<(f64, f64)>)> = RETRY_SWEEP
        .iter()
        .map(|&r| {
            let pts: Vec<(f64, f64)> = cells
                .iter()
                .filter(|c| c.max_retries == r)
                .map(|c| (c.fault_rate, c.pods_fill))
                .collect();
            (format!("retries={r}"), pts)
        })
        .collect();
    let series: Vec<(&str, &[(f64, f64)])> =
        curves.iter().map(|(n, p)| (n.as_str(), p.as_slice())).collect();
    println!(
        "Fault study: PODS selection fill (min(survivors, m)/m) vs fault rate \
         (n = {N}, m = {M}, {GROUPS} groups x {ITERS} iters, G = {G})"
    );
    println!("{}", ascii_plot(&series, 64, 14));
    for c in &cells {
        println!(
            "  rate={:<5} retries={} | faults {:>5} retries {:>5} lost {:>4} \
             ({:>6.3}) | pods fill {:.3} full-batch fill {:.3} | \
             retry {:>7.2}s ({:>5.1}% overhead)",
            c.fault_rate,
            c.max_retries,
            c.faults_injected,
            c.retries,
            c.rows_lost,
            c.rows_lost_frac,
            c.pods_fill,
            c.full_batch_fill,
            c.retry_time,
            c.overhead_frac * 100.0
        );
    }
    println!(
        "  (down-sampling degrades gracefully: losing rows only matters once \
         a group drops below m survivors; a full-batch consumer cliffs as \
         soon as any row is lost — see docs/DETERMINISM.md for why the \
         schedule is partition-invariant)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance shape: no cliff up to a 10% fault rate with the
    /// default retry budget, while the full-batch proxy collapses.
    #[test]
    fn pods_degrades_gracefully_where_full_batch_cliffs() {
        let hw = HwModel::default();
        let cells = sweep(&hw);
        let cell = |rate: f64, retries: usize| {
            cells
                .iter()
                .find(|c| c.fault_rate == rate && c.max_retries == retries)
                .unwrap()
        };
        let clean = cell(0.0, 2);
        assert_eq!(clean.rows_lost, 0);
        assert_eq!(clean.pods_fill, 1.0);
        assert_eq!(clean.full_batch_fill, 1.0);
        // no cliff: >= 90% of the fault-free selection fill at 10% faults
        assert!(
            cell(0.10, 2).pods_fill >= 0.9 * clean.pods_fill,
            "pods fill cliffed: {}",
            cell(0.10, 2).pods_fill
        );
        // the full-batch consumer cliffs: without retries even a 5% rate
        // loses a row from almost every 64-rollout group, while the PODS
        // fill barely moves (survivors >> m with overwhelming probability)
        assert!(
            cell(0.05, 0).full_batch_fill < 0.2,
            "full-batch proxy should collapse: {}",
            cell(0.05, 0).full_batch_fill
        );
        assert!(
            cell(0.10, 0).pods_fill >= 0.99,
            "pods fill should shrug off retry-less losses: {}",
            cell(0.10, 0).pods_fill
        );
        // and the degradation floor holds at the swept rates
        assert_eq!(cell(0.10, 2).floor_violations, 0);
    }

    /// Retries rescue rows: loss shrinks monotonically in the budget.
    #[test]
    fn retries_shrink_losses_monotonically() {
        let hw = HwModel::default();
        let cells = sweep(&hw);
        for &rate in RATE_SWEEP.iter().filter(|&&r| r > 0.0) {
            let losses: Vec<usize> = RETRY_SWEEP
                .iter()
                .map(|&r| {
                    cells
                        .iter()
                        .find(|c| c.fault_rate == rate && c.max_retries == r)
                        .unwrap()
                        .rows_lost
                })
                .collect();
            for w in losses.windows(2) {
                assert!(w[1] <= w[0], "rate {rate}: retries must not lose more rows {losses:?}");
            }
            assert!(losses[0] > 0, "rate {rate} with no retries must lose rows");
        }
    }

    /// Rate 0.0 is free: no faults, no retry bill, full fills.
    #[test]
    fn zero_rate_cells_are_free() {
        let hw = HwModel::default();
        for &retries in &RETRY_SWEEP {
            let c = eval_cell(&hw, 0.0, retries);
            assert_eq!(c.faults_injected, 0);
            assert_eq!(c.rows_lost, 0);
            assert_eq!(c.retry_time, 0.0);
            assert_eq!(c.overhead_frac, 0.0);
            assert_eq!(c.pods_fill, 1.0);
            assert_eq!(c.full_batch_fill, 1.0);
        }
    }

    /// The sweep is deterministic call-to-call (pure schedule arithmetic).
    #[test]
    fn sweep_is_deterministic() {
        let hw = HwModel::default();
        let a = sweep(&hw);
        let b = sweep(&hw);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.csv_row(), y.csv_row());
        }
    }

    #[test]
    fn fault_cell_csv_shape() {
        let cells = sweep(&HwModel::default());
        let header_cols = FaultCell::csv_header().split(',').count();
        for c in &cells {
            assert_eq!(c.csv_row().split(',').count(), header_cols, "{c:?}");
        }
    }
}
