//! Fig. 4 — effect of rollout size n and update size m on GRPO-PODS
//! (setting (a) analogue). Expected shape: diminishing returns in n with an
//! optimum near n=64; robustness in m until m <= 4.

use super::{peak_accuracy, run_config, CfgBuilder, Scale};
use crate::metrics::{ascii_plot, write_csv_rows};
use crate::metrics::CsvRow;
use anyhow::Result;
use std::path::Path;

#[derive(Debug)]
struct SweepRow {
    sweep: String,
    n: usize,
    m: usize,
    peak_acc: f32,
    final_acc: f32,
    sim_time_total: f64,
    sim_time_per_iter: f64,
}

impl CsvRow for SweepRow {
    fn csv_header() -> &'static str {
        "sweep,n,m,peak_acc,final_acc,sim_time_total,sim_time_per_iter"
    }
    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{}",
            self.sweep, self.n, self.m, self.peak_acc, self.final_acc, self.sim_time_total, self.sim_time_per_iter
        )
    }
}

/// Run the study end-to-end and write its CSV + ASCII preview.
pub fn run(artifacts: &Path, scale: Scale, out_dir: &str) -> Result<()> {
    let base_ckpt =
        super::ensure_base_checkpoint(artifacts, "arith", super::fig3::SFT_STEPS, out_dir)?;
    let iters = scale.iters(40);
    let mut rows = Vec::new();
    let mut n_curve = Vec::new();
    let mut m_curve = Vec::new();

    // n sweep at fixed m = 16
    for n in [16usize, 32, 64, 128] {
        let tr = run_one(artifacts, &base_ckpt, n, 16.min(n), iters, out_dir, "n_sweep")?;
        let peak = peak_accuracy(&tr.recorder.evals);
        let t = tr.clock.now();
        rows.push(SweepRow {
            sweep: "n".into(),
            n,
            m: 16.min(n),
            peak_acc: peak,
            final_acc: tr.recorder.last_eval_accuracy("test").unwrap_or(0.0),
            sim_time_total: t,
            sim_time_per_iter: t / iters.max(1) as f64,
        });
        n_curve.push(((n as f64).log2(), peak as f64));
    }
    // m sweep at fixed n = 64
    for m in [2usize, 4, 8, 16, 32, 64] {
        let tr = run_one(artifacts, &base_ckpt, 64, m, iters, out_dir, "m_sweep")?;
        let peak = peak_accuracy(&tr.recorder.evals);
        let t = tr.clock.now();
        rows.push(SweepRow {
            sweep: "m".into(),
            n: 64,
            m,
            peak_acc: peak,
            final_acc: tr.recorder.last_eval_accuracy("test").unwrap_or(0.0),
            sim_time_total: t,
            sim_time_per_iter: t / iters.max(1) as f64,
        });
        m_curve.push(((m as f64).log2(), peak as f64));
    }
    write_csv_rows(Path::new(&format!("{out_dir}/fig4.csv")), &rows)?;
    println!("Fig.4 left: peak acc vs log2(n) at m=16");
    println!("{}", ascii_plot(&[("peak", &n_curve)], 56, 10));
    println!("Fig.4 right: peak acc vs log2(m) at n=64");
    println!("{}", ascii_plot(&[("peak", &m_curve)], 56, 10));
    Ok(())
}

fn run_one(
    artifacts: &Path,
    base_ckpt: &str,
    n: usize,
    m: usize,
    iters: usize,
    out_dir: &str,
    sweep: &str,
) -> Result<crate::coordinator::scheduler::Trainer> {
    let cfg = CfgBuilder {
        name: format!("fig4_{sweep}_n{n}_m{m}"),
        profile: "lora".into(),
        task: "arith".into(),
        iterations: iters,
        eval_every: 5,
        eval_problems: 48,
        out_dir: out_dir.into(),
        base_checkpoint: Some(base_ckpt.into()),
        kind: if m < n { "pods".into() } else { "ga".into() },
        n,
        m: if m < n { Some(m) } else { None },
        lr: 3e-3,
        ..Default::default()
    }
    .build()?;
    run_config(artifacts, cfg)
}
