//! Experiment harness: one driver per paper table/figure (DESIGN.md §4).
//!
//! Each driver assembles the right [`RunConfig`]s, runs the trainer(s), and
//! writes `results/<figure>*.csv` plus an ASCII preview plot. Absolute
//! numbers live on the hwsim clock; what must reproduce is the *shape*
//! (who wins, by what factor, where crossovers fall).

pub mod budget;
pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod faults;
pub mod fleet;
pub mod kv;
pub mod prune;
pub mod reuse;
pub mod sched;
pub mod shard;
pub mod table3;

use crate::config::{
    AlgoSection, BudgetSection, CkptSection, ReplaySection, RolloutSection, RunConfig, RunSection,
    SftSection, UpdateSection,
};
use crate::hwsim::{FaultSection, FleetSection, HwModel};
use anyhow::Result;
use std::path::Path;

/// Scale knob for experiment drivers: `quick` shrinks iteration counts ~8x
/// for smoke runs; `full` is the EXPERIMENTS.md configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Smoke-run scale (~8x fewer iterations).
    Quick,
    /// The EXPERIMENTS.md configuration.
    Full,
}

impl Scale {
    /// Scale an iteration count.
    pub fn iters(self, full: usize) -> usize {
        match self {
            Scale::Quick => (full / 8).max(3),
            Scale::Full => full,
        }
    }

    /// Scale an eval problem count.
    pub fn eval_problems(self, full: usize) -> usize {
        match self {
            Scale::Quick => (full / 2).max(16),
            Scale::Full => full,
        }
    }
}

/// Programmatic [`RunConfig`] builder used by every experiment driver.
/// Fields mirror the TOML keys of the same names (see `docs/CONFIG.md`).
#[derive(Debug, Clone)]
pub struct CfgBuilder {
    /// `run.name`.
    pub name: String,
    /// `run.profile`.
    pub profile: String,
    /// `run.task`.
    pub task: String,
    /// `run.seed`.
    pub seed: u64,
    /// `run.iterations`.
    pub iterations: usize,
    /// `run.prompts_per_iter`.
    pub prompts_per_iter: usize,
    /// `run.eval_every`.
    pub eval_every: usize,
    /// `run.eval_problems`.
    pub eval_problems: usize,
    /// `run.out_dir`.
    pub out_dir: String,
    /// `run.base_checkpoint`.
    pub base_checkpoint: Option<String>,
    /// `run.save_checkpoint`.
    pub save_checkpoint: Option<String>,
    /// `algo.kind`.
    pub kind: String,
    /// `algo.n`.
    pub n: usize,
    /// `algo.m`.
    pub m: Option<usize>,
    /// `algo.rule` (selection pipeline spec).
    pub rule: String,
    /// `algo.adv_norm`.
    pub adv_norm: String,
    /// `algo.kl_coef`.
    pub kl_coef: f64,
    /// `algo.lr`.
    pub lr: f64,
    /// `algo.temperature`.
    pub temperature: f64,
    /// `hwsim.workers`.
    pub workers: usize,
    /// Override the hwsim per-device memory ceiling (None = default 32).
    pub mem_capacity: Option<usize>,
    /// Executor schedule: "sync" | "pipelined" (hwsim.schedule).
    pub schedule: String,
    /// Tokens per decode_chunk call (rollout.decode_chunk).
    pub decode_chunk: usize,
    /// Slot-refill policy: "continuous" | "batch" (rollout.refill).
    pub refill: String,
    /// Online selection-aware pruning (rollout.online_prune).
    pub online_prune: bool,
    /// Group-shared prompt prefill (rollout.share_prompt_kv).
    pub share_prompt_kv: bool,
    /// Override the paged KV-pool capacity (hwsim.kv_pool_bytes);
    /// None = default (0 = unbounded).
    pub kv_pool_bytes: Option<u64>,
    /// Simulated update shards (update.shards).
    pub upd_shards: usize,
    /// Rows per update micro-batch, 0 = profile B_u (update.micro_batch).
    pub upd_micro_batch: usize,
    /// Cross-iteration replay (replay.enabled).
    pub replay_enabled: bool,
    /// Replay quota as a fraction of fresh rows (replay.mix_fraction).
    pub replay_mix_fraction: f64,
    /// Replay staleness bound in iterations (replay.staleness).
    pub replay_staleness: usize,
    /// Replay store capacity per prompt (replay.capacity_per_prompt).
    pub replay_capacity: usize,
    /// Replay importance-ratio clip (replay.rho_max).
    pub replay_rho_max: f64,
    /// Adaptive per-prompt rollout budget (budget.enabled).
    pub budget_enabled: bool,
    /// Probe rollouts per prompt before reallocation (budget.n_probe).
    pub budget_n_probe: usize,
    /// Hard per-prompt rollout ceiling (budget.max_per_prompt).
    pub budget_max_per_prompt: usize,
    /// Reward-bracket width below which a group is saturated
    /// (budget.width_threshold).
    pub budget_width_threshold: f64,
    /// The whole `[faults]` section (fault injection is off by default).
    pub faults: FaultSection,
    /// The whole `[fleet]` section (defaults reproduce the legacy
    /// single-box schedules).
    pub fleet: FleetSection,
    /// The whole `[ckpt]` section (resume snapshots are off by default).
    pub ckpt: CkptSection,
    /// `sft.steps` (0 = no SFT warm-up section).
    pub sft_steps: usize,
    /// `sft.lr`.
    pub sft_lr: f64,
    /// `sft.pool`.
    pub sft_pool: usize,
}

impl Default for CfgBuilder {
    fn default() -> Self {
        Self {
            name: "run".into(),
            profile: "base".into(),
            task: "arith".into(),
            seed: 0,
            iterations: 40,
            prompts_per_iter: 2,
            eval_every: 5,
            eval_problems: 48,
            out_dir: "results".into(),
            base_checkpoint: None,
            save_checkpoint: None,
            kind: "pods".into(),
            n: 64,
            m: Some(16),
            rule: "max_variance".into(),
            adv_norm: "after".into(),
            kl_coef: 0.0,
            lr: 2e-4,
            temperature: 1.0,
            workers: 1,
            mem_capacity: None,
            schedule: "sync".into(),
            decode_chunk: RolloutSection::default().decode_chunk,
            refill: "continuous".into(),
            online_prune: RolloutSection::default().online_prune,
            share_prompt_kv: RolloutSection::default().share_prompt_kv,
            kv_pool_bytes: None,
            upd_shards: UpdateSection::default().shards,
            upd_micro_batch: UpdateSection::default().micro_batch,
            replay_enabled: ReplaySection::default().enabled,
            replay_mix_fraction: ReplaySection::default().mix_fraction,
            replay_staleness: ReplaySection::default().staleness,
            replay_capacity: ReplaySection::default().capacity_per_prompt,
            replay_rho_max: ReplaySection::default().rho_max,
            budget_enabled: BudgetSection::default().enabled,
            budget_n_probe: BudgetSection::default().n_probe,
            budget_max_per_prompt: BudgetSection::default().max_per_prompt,
            budget_width_threshold: BudgetSection::default().width_threshold,
            faults: FaultSection::default(),
            fleet: FleetSection::default(),
            ckpt: CkptSection::default(),
            sft_steps: 0,
            sft_lr: 2e-3,
            sft_pool: 512,
        }
    }
}

impl CfgBuilder {
    /// Assemble and validate the [`RunConfig`].
    pub fn build(&self) -> Result<RunConfig> {
        let cfg = RunConfig {
            run: RunSection {
                name: self.name.clone(),
                profile: self.profile.clone(),
                task: self.task.clone(),
                seed: self.seed,
                iterations: self.iterations,
                prompts_per_iter: self.prompts_per_iter,
                eval_every: self.eval_every,
                eval_problems: self.eval_problems,
                out_dir: self.out_dir.clone(),
                base_checkpoint: self.base_checkpoint.clone(),
                save_checkpoint: self.save_checkpoint.clone(),
            },
            algo: AlgoSection {
                kind: self.kind.clone(),
                n: self.n,
                m: self.m,
                rule: self.rule.clone(),
                adv_norm: self.adv_norm.clone(),
                kl_coef: self.kl_coef,
                lr: self.lr,
                temperature: self.temperature,
            },
            hwsim: HwModel {
                workers: self.workers,
                mem_capacity_rollouts: self.mem_capacity.unwrap_or(HwModel::default().mem_capacity_rollouts),
                schedule: crate::hwsim::Schedule::parse(&self.schedule)?,
                kv_pool_bytes: self.kv_pool_bytes.unwrap_or(HwModel::default().kv_pool_bytes),
                ..Default::default()
            },
            rollout: RolloutSection {
                decode_chunk: self.decode_chunk,
                refill: crate::rollout::RefillMode::parse(&self.refill)?,
                online_prune: self.online_prune,
                share_prompt_kv: self.share_prompt_kv,
            },
            update: UpdateSection { shards: self.upd_shards, micro_batch: self.upd_micro_batch },
            replay: ReplaySection {
                enabled: self.replay_enabled,
                mix_fraction: self.replay_mix_fraction,
                staleness: self.replay_staleness,
                capacity_per_prompt: self.replay_capacity,
                rho_max: self.replay_rho_max,
            },
            budget: BudgetSection {
                enabled: self.budget_enabled,
                n_probe: self.budget_n_probe,
                max_per_prompt: self.budget_max_per_prompt,
                width_threshold: self.budget_width_threshold,
            },
            faults: self.faults.clone(),
            fleet: self.fleet.clone(),
            ckpt: self.ckpt.clone(),
            sft: if self.sft_steps > 0 {
                Some(SftSection {
                    steps: self.sft_steps,
                    lr: self.sft_lr,
                    log_every: 100,
                    pool: self.sft_pool,
                })
            } else {
                None
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Ensure a task-specific SFT'd base checkpoint exists (the stand-in for
/// "start from an instruct model"); returns its path. Shared by every
/// driver so the expensive SFT runs once per task.
pub fn ensure_base_checkpoint(
    artifacts: &Path,
    task: &str,
    sft_steps: usize,
    out_dir: &str,
) -> Result<String> {
    std::fs::create_dir_all(out_dir)?;
    let path = format!("{out_dir}/base_{task}_{sft_steps}.ckpt");
    if Path::new(&path).exists() {
        return Ok(path);
    }
    eprintln!("[exp] building base checkpoint {path} ({sft_steps} SFT steps)");
    let cfg = CfgBuilder {
        name: format!("sft_{task}"),
        task: task.into(),
        iterations: 0, // SFT only: no RL before the checkpoint is saved
        kind: "grpo".into(),
        n: 16,
        m: None,
        sft_steps,
        save_checkpoint: Some(path.clone()),
        out_dir: out_dir.into(),
        ..Default::default()
    }
    .build()?;
    run_config(artifacts, cfg)?;
    Ok(path)
}

/// Run one config end-to-end and return the trainer (for CSV access).
pub fn run_config(
    artifacts: &Path,
    cfg: RunConfig,
) -> Result<crate::coordinator::scheduler::Trainer> {
    let mut tr = crate::coordinator::scheduler::Trainer::new(artifacts, cfg)?;
    tr.run()?;
    Ok(tr)
}

/// Time (sim seconds) at which a run first reaches `target` test accuracy;
/// None if never. Used by Table 3 (speed-up ratio).
pub fn time_to_accuracy(evals: &[crate::metrics::EvalRow], target: f32) -> Option<f64> {
    evals
        .iter()
        .filter(|e| e.split == "test")
        .find(|e| e.accuracy >= target)
        .map(|e| e.sim_time)
}

/// Peak test accuracy of a run.
pub fn peak_accuracy(evals: &[crate::metrics::EvalRow]) -> f32 {
    evals
        .iter()
        .filter(|e| e.split == "test")
        .map(|e| e.accuracy)
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EvalRow;

    fn row(iter: usize, t: f64, acc: f32) -> EvalRow {
        EvalRow {
            iter,
            sim_time: t,
            real_time: 0.0,
            split: "test".into(),
            accuracy: acc,
            format_rate: 0.0,
            mean_reward: 0.0,
            mean_len: 0.0,
            problems: 1,
        }
    }

    #[test]
    fn time_to_accuracy_finds_first_crossing() {
        let evals = vec![row(0, 0.0, 0.1), row(1, 10.0, 0.5), row(2, 20.0, 0.4), row(3, 30.0, 0.6)];
        assert_eq!(time_to_accuracy(&evals, 0.45), Some(10.0));
        assert_eq!(time_to_accuracy(&evals, 0.9), None);
        assert_eq!(peak_accuracy(&evals), 0.6);
    }

    #[test]
    fn scale_shrinks_quick() {
        assert_eq!(Scale::Quick.iters(80), 10);
        assert_eq!(Scale::Full.iters(80), 80);
    }
}
