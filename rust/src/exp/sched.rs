//! Schedule study — the sync vs pipelined executor on one PODS setting.
//!
//! Not a paper figure: this driver quantifies what the staged executor
//! buys on top of down-sampling. Both arms run the identical PODS config
//! for the same iteration count; the pipelined arm overlaps generation of
//! iteration t+1 with the update of iteration t, so its simulated
//! wall-clock is strictly lower whenever both phases have non-zero cost
//! (`min(inference_{t+1}, update_t)` is hidden per boundary). The CSV
//! records both trajectories; the ASCII preview plots train reward
//! against the simulated clock, where the pipelined curve shifts left.

use super::{run_config, CfgBuilder, Scale};
use crate::metrics::{ascii_plot, write_csv_rows, CsvRow};
use anyhow::Result;
use std::path::Path;

#[derive(Debug)]
struct SchedRow {
    schedule: String,
    iterations: usize,
    sim_total: f64,
    sim_inference_total: f64,
    sim_update_total: f64,
    overlap_saved: f64,
    final_train_reward: f32,
}

impl CsvRow for SchedRow {
    fn csv_header() -> &'static str {
        "schedule,iterations,sim_total,sim_inference_total,sim_update_total,\
         overlap_saved,final_train_reward"
    }
    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{}",
            self.schedule,
            self.iterations,
            self.sim_total,
            self.sim_inference_total,
            self.sim_update_total,
            self.overlap_saved,
            self.final_train_reward
        )
    }
}

/// Run both schedule arms and write `sched.csv` + the ASCII preview.
pub fn run(artifacts: &Path, scale: Scale, out_dir: &str) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let iters = scale.iters(24);
    let mut rows: Vec<SchedRow> = Vec::new();
    let mut curves: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for sched in ["sync", "pipelined"] {
        let cfg = CfgBuilder {
            name: format!("sched_{sched}"),
            iterations: iters,
            prompts_per_iter: 2,
            eval_every: iters.max(1),
            eval_problems: 16,
            n: 32,
            m: Some(8),
            schedule: sched.into(),
            out_dir: out_dir.into(),
            ..Default::default()
        }
        .build()?;
        let tr = run_config(artifacts, cfg)?;
        let pts: Vec<(f64, f64)> =
            tr.recorder.iters.iter().map(|r| (r.sim_time, r.train_reward as f64)).collect();
        rows.push(SchedRow {
            schedule: sched.to_string(),
            iterations: iters,
            sim_total: tr.clock.now(),
            sim_inference_total: tr.recorder.iters.iter().map(|r| r.sim_inference_time).sum(),
            sim_update_total: tr.recorder.iters.iter().map(|r| r.sim_update_time).sum(),
            overlap_saved: tr.clock.overlap_saved(),
            final_train_reward: tr.recorder.iters.last().map(|r| r.train_reward).unwrap_or(0.0),
        });
        curves.push((sched.to_string(), pts));
    }
    write_csv_rows(Path::new(&format!("{out_dir}/sched.csv")), &rows)?;

    let series: Vec<(&str, &[(f64, f64)])> =
        curves.iter().map(|(n, p)| (n.as_str(), p.as_slice())).collect();
    println!("Schedule study: train reward vs simulated wall-clock ({iters} iterations each)");
    println!("{}", ascii_plot(&series, 64, 14));
    for r in &rows {
        println!(
            "  {:<10} sim {:>8.1}s (inference {:>7.1}s + update {:>6.1}s, {:>6.1}s hidden)",
            r.schedule, r.sim_total, r.sim_inference_total, r.sim_update_total, r.overlap_saved
        );
    }
    if let [sync, pipe] = &rows[..] {
        println!(
            "  pipelined / sync wall-clock: {:.3}x (same {} iterations)",
            pipe.sim_total / sync.sim_total.max(1e-9),
            iters
        );
    }
    Ok(())
}
