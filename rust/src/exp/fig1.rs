//! Fig. 1 — the inference/update computational asymmetry.
//!
//! Top panel: total step time vs rollouts per device, decomposed into
//! inference and policy-update phases, with the gradient-accumulation cliff
//! at the memory ceiling (32 rollouts/device in the paper).
//! Bottom panel: per-token inference time vs rollout batch (21× batching
//! amortization, saturating at 512).
//!
//! The curves come from the calibrated [`HwModel`] (the substitution for
//! the paper's 8×A100 testbed, DESIGN.md §2); alongside, this driver
//! *measures* the real rollout/grad artifact latencies on this machine at
//! the profile's batch sizes so the asymmetry is also demonstrated on real
//! hardware (one CPU device).

use crate::coordinator::exec::{pack_micro_batch, PackedRow};
use crate::hwsim::HwModel;
use crate::metrics::{ascii_plot, write_csv_rows};
use crate::rollout::prompt_batch;
use crate::runtime::{Engine, ParamStore};
use crate::tasks::{Split, TaskKind};
use crate::metrics::CsvRow;
use anyhow::Result;
use std::path::Path;

#[derive(Debug)]
struct Fig1Row {
    rollouts_per_device: usize,
    per_token_time: f64,
    inference_time: f64,
    update_time: f64,
    micro_steps: usize,
    total_step_time: f64,
}

impl CsvRow for Fig1Row {
    fn csv_header() -> &'static str {
        "rollouts_per_device,per_token_time,inference_time,update_time,micro_steps,total_step_time"
    }
    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{}",
            self.rollouts_per_device,
            self.per_token_time,
            self.inference_time,
            self.update_time,
            self.micro_steps,
            self.total_step_time
        )
    }
}

#[derive(Debug)]
struct Fig1Probe {
    program: String,
    batch: usize,
    seconds_per_call: f64,
    seconds_per_rollout: f64,
}

impl CsvRow for Fig1Probe {
    fn csv_header() -> &'static str {
        "program,batch,seconds_per_call,seconds_per_rollout"
    }
    fn csv_row(&self) -> String {
        format!("{},{},{},{}", self.program, self.batch, self.seconds_per_call, self.seconds_per_rollout)
    }
}

/// Run the Fig. 1 cost-model study; `probe` additionally times the real
/// artifacts for calibration.
pub fn run(artifacts: &Path, out_dir: &str, probe: bool) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let hw = HwModel::default();
    let avg_tokens = 40.0;
    let mut rows = Vec::new();
    for r in [4usize, 8, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512, 768, 1024] {
        rows.push(Fig1Row {
            rollouts_per_device: r,
            per_token_time: hw.per_token_time(r),
            inference_time: hw.inference_time(r, avg_tokens),
            update_time: hw.update_time(r, false),
            micro_steps: hw.forced_micro_steps(r),
            total_step_time: hw.step_time(r, avg_tokens, r, false),
        });
    }
    write_csv_rows(Path::new(&format!("{out_dir}/fig1.csv")), &rows)?;

    let tot: Vec<(f64, f64)> = rows
        .iter()
        .map(|r| ((r.rollouts_per_device as f64).log2(), r.total_step_time))
        .collect();
    let upd: Vec<(f64, f64)> = rows
        .iter()
        .map(|r| ((r.rollouts_per_device as f64).log2(), r.update_time))
        .collect();
    let inf: Vec<(f64, f64)> = rows
        .iter()
        .map(|r| ((r.rollouts_per_device as f64).log2(), r.inference_time))
        .collect();
    println!("Fig.1 (top): step time vs log2(rollouts/device)");
    println!("{}", ascii_plot(&[("total", &tot), ("update", &upd), ("inference", &inf)], 64, 14));
    let ptok: Vec<(f64, f64)> = rows
        .iter()
        .map(|r| ((r.rollouts_per_device as f64).log2(), r.per_token_time * 1e3))
        .collect();
    println!("Fig.1 (bottom): per-token inference ms vs log2(batch)");
    println!("{}", ascii_plot(&[("ms/token", &ptok)], 64, 12));
    println!(
        "amortization ratio batch 8 -> 512: {:.1}x (paper: ~21x); GA cliff at {} rollouts",
        hw.per_token_time(8) / hw.per_token_time(512),
        hw.mem_capacity_rollouts
    );

    if probe {
        probe_real(artifacts, out_dir)?;
    }
    Ok(())
}

/// Measure the real artifact latencies at the profile's batch sizes.
fn probe_real(artifacts: &Path, out_dir: &str) -> Result<()> {
    let engine = Engine::load(artifacts, "base")?;
    let seed = 7u32;
    let params = ParamStore::new(engine.init(seed)?);
    let problem = TaskKind::Arith.generate(Split::Train, 0);
    let (prompts, pads) = prompt_batch(&engine, &problem.prompt)?;
    engine.warmup(&["rollout", "grad"])?;
    let br = engine.meta.config.rollout_batch;
    let bu = engine.meta.config.update_batch;
    let t = engine.meta.config.seq_len;
    let g = engine.meta.gen_len;

    let reps = 3;
    let t0 = std::time::Instant::now();
    let mut out = None;
    for i in 0..reps {
        let seeds: Vec<i32> = (0..br as i32).map(|b| (seed + i) as i32 * 1000 + b).collect();
        out = Some(engine.rollout(&params.params, None, &prompts, &pads, &seeds, 1.0)?);
    }
    let roll_s = t0.elapsed().as_secs_f64() / reps as f64;
    let out = out.unwrap();

    // the shared UpdateEngine micro-batch builder, fed straight from the
    // rollout output — identical padding/layout to the training path
    let zero_ref = vec![0.0f32; g];
    let packed: Vec<PackedRow> = (0..bu)
        .map(|b| PackedRow {
            tokens: &out.tokens.data[b * t..(b + 1) * t],
            pad_len: pads[b],
            gen_mask: &out.gen_mask.data[b * g..(b + 1) * g],
            old_lp: &out.logprobs.data[b * g..(b + 1) * g],
            ref_lp: &zero_ref,
            advantage: 0.5,
        })
        .collect();
    let mb = pack_micro_batch(&packed, bu, g, t)?;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        engine.grad(&params.params, None, &mb, 0.0)?;
    }
    let grad_s = t0.elapsed().as_secs_f64() / reps as f64;

    let probes = vec![
        Fig1Probe {
            program: "rollout".into(),
            batch: br,
            seconds_per_call: roll_s,
            seconds_per_rollout: roll_s / br as f64,
        },
        Fig1Probe {
            program: "grad".into(),
            batch: bu,
            seconds_per_call: grad_s,
            seconds_per_rollout: grad_s / bu as f64,
        },
    ];
    write_csv_rows(Path::new(&format!("{out_dir}/fig1_probe.csv")), &probes)?;
    println!(
        "real probe (base profile, 1 CPU): rollout {:.3}s/call ({:.4}s/rollout, B={br}), grad {:.3}s/call ({:.4}s/rollout, B={bu})",
        roll_s,
        roll_s / br as f64,
        grad_s,
        grad_s / bu as f64,
    );
    Ok(())
}
