//! Fig. 3 — GRPO vs GRPO-PODS test accuracy over wall-clock, settings (a)–(f).
//!
//! Reproduction-scale mapping of Table 1 (DESIGN.md §2):
//!
//! | setting | paper                           | here                                   |
//! |---------|---------------------------------|----------------------------------------|
//! | (a)     | GSM8K, Qwen2.5-3B, LoRA, 1 L40S | arith, LoRA profile, 1 worker          |
//! | (b)     | GSM8K, Llama3.2-3B, LoRA, KL=.04| arith, LoRA, KL=0.04, seed 1, lower lr |
//! | (c)     | MATH, Qwen2.5-3B, LoRA          | poly, LoRA, n=32 m=8                   |
//! | (d)     | Chemistry, Qwen2.5-3B, LoRA     | mcq, LoRA                              |
//! | (e)     | GSM8K, 3B, full-param, 8 H100   | arith, base profile, 8 workers, GA     |
//! | (f)     | GSM8K, 7B, full-param, 8 A100   | arith, base, 8 workers, seed 2, GA     |
//!
//! Single-GPU settings compare PODS(n, m) against vanilla GRPO(n = m);
//! distributed settings compare PODS against GRPO-GA at equal total n
//! (Fig. 2 rows 2 vs 3). Per Table 2 the down-sampling ratio is 4.

use super::{peak_accuracy, run_config, CfgBuilder, Scale};
use crate::metrics::ascii_plot;
use anyhow::Result;
use std::path::Path;

/// (kind, n, m, task, profile, kl, lr, seed, workers)
pub struct Setting {
    /// Setting letter (a-f).
    pub id: &'static str,
    /// Task family.
    pub task: &'static str,
    /// LoRA profile (vs full-parameter base).
    pub lora: bool,
    /// Rollouts generated per prompt.
    pub n: usize,
    /// PODS update size.
    pub m: usize,
    /// KL coefficient.
    pub kl: f64,
    /// Learning rate.
    pub lr: f64,
    /// Run seed.
    pub seed: u64,
    /// Simulated accelerators.
    pub workers: usize,
    /// Iterations at full scale.
    pub iters_full: usize,
}

/// The six reproduction-scale Table 1 settings.
pub fn settings() -> Vec<Setting> {
    vec![
        Setting { id: "a", task: "arith", lora: true, n: 64, m: 16, kl: 0.0, lr: 3e-3, seed: 0, workers: 1, iters_full: 48 },
        Setting { id: "b", task: "arith", lora: true, n: 64, m: 16, kl: 0.04, lr: 2e-3, seed: 1, workers: 1, iters_full: 48 },
        Setting { id: "c", task: "poly", lora: true, n: 32, m: 8, kl: 0.0, lr: 3e-3, seed: 0, workers: 1, iters_full: 48 },
        Setting { id: "d", task: "mcq", lora: true, n: 64, m: 16, kl: 0.0, lr: 3e-3, seed: 0, workers: 1, iters_full: 40 },
        Setting { id: "e", task: "arith", lora: false, n: 64, m: 16, kl: 0.0, lr: 2e-4, seed: 0, workers: 8, iters_full: 48 },
        Setting { id: "f", task: "arith", lora: false, n: 64, m: 16, kl: 0.0, lr: 1.5e-4, seed: 2, workers: 8, iters_full: 48 },
    ]
}

/// SFT warm-up steps shared by every setting's base checkpoint.
pub const SFT_STEPS: usize = 1200;

fn builder_for(s: &Setting, scale: Scale, out_dir: &str, base_ckpt: &str) -> CfgBuilder {
    CfgBuilder {
        task: s.task.into(),
        profile: if s.lora { "lora".into() } else { "base".into() },
        seed: s.seed,
        iterations: scale.iters(s.iters_full),
        eval_every: match scale {
            Scale::Quick => 2,
            Scale::Full => 5,
        },
        eval_problems: scale.eval_problems(48),
        out_dir: out_dir.into(),
        base_checkpoint: Some(base_ckpt.into()),
        kl_coef: s.kl,
        lr: s.lr,
        workers: s.workers,
        // distributed settings: memory ceiling scaled to the reproduction's
        // batch sizes so GA's forced micro-stepping materialises (DESIGN §2)
        mem_capacity: if s.workers > 1 { Some(4) } else { None },
        n: s.n,
        ..Default::default()
    }
}

/// Run one setting: the PODS arm + the matching baseline arm.
pub fn run_setting(artifacts: &Path, id: &str, scale: Scale, out_dir: &str) -> Result<()> {
    let s = settings()
        .into_iter()
        .find(|s| s.id == id)
        .ok_or_else(|| anyhow::anyhow!("unknown setting {id:?} (a-f)"))?;
    let base_ckpt = super::ensure_base_checkpoint(artifacts, s.task, SFT_STEPS, out_dir)?;

    // PODS arm
    let mut b = builder_for(&s, scale, out_dir, &base_ckpt);
    b.name = format!("fig3_{id}_pods");
    b.kind = "pods".into();
    b.m = Some(s.m);
    let pods = run_config(artifacts, b.build()?)?;

    // baseline arm: vanilla GRPO (n = m) on single-GPU settings, GRPO-GA
    // (train on all n) on distributed settings
    let mut b = builder_for(&s, scale, out_dir, &base_ckpt);
    if s.workers > 1 {
        b.name = format!("fig3_{id}_ga");
        b.kind = "ga".into();
        b.m = None;
    } else {
        b.name = format!("fig3_{id}_grpo");
        b.kind = "grpo".into();
        b.n = s.m; // vanilla GRPO: generate exactly what fits in memory
        b.m = None;
    }
    let baseline = run_config(artifacts, b.build()?)?;

    let p: Vec<(f64, f64)> = pods
        .recorder
        .evals
        .iter()
        .filter(|e| e.split == "test")
        .map(|e| (e.sim_time, e.accuracy as f64))
        .collect();
    let q: Vec<(f64, f64)> = baseline
        .recorder
        .evals
        .iter()
        .filter(|e| e.split == "test")
        .map(|e| (e.sim_time, e.accuracy as f64))
        .collect();
    println!("Fig.3({id}): test accuracy vs simulated wall-clock");
    println!("{}", ascii_plot(&[("pods", &p), ("baseline", &q)], 64, 14));
    println!(
        "peaks: pods {:.3}, baseline {:.3}",
        peak_accuracy(&pods.recorder.evals),
        peak_accuracy(&baseline.recorder.evals)
    );
    Ok(())
}

/// Run every setting (the full Fig. 3 grid).
pub fn run_all(artifacts: &Path, scale: Scale, out_dir: &str) -> Result<()> {
    for s in settings() {
        run_setting(artifacts, s.id, scale, out_dir)?;
    }
    Ok(())
}
