//! Budget study — where adaptive per-prompt rollout budgets spend the
//! decode bill, swept over `n_probe × width_threshold`.
//!
//! Not a paper figure: this driver quantifies what the `[budget]`
//! allocator buys. It runs entirely on the cost model (no artifacts):
//! synthetic prompt groups — half *saturated* (constant reward, zero
//! advantage signal) and half *wide* (bimodal solved/unsolved rewards) —
//! probe `n_probe` rollouts each, feed the observed brackets into the
//! real [`BudgetAllocator`], and decode exactly the rows it grants. Every
//! cell spends the same total slot budget as the fixed-`n` baseline
//! (`n × |groups|`), so the comparison isolates *where* the slots went,
//! not how many there were.
//!
//! The shape that must reproduce (asserted by this module's tests):
//! under any positive threshold, saturated groups receive **zero** extra
//! rows — they stop at the probe quota while wide groups absorb the
//! released slots — so the tokens-per-signal-row price (the study's
//! proxy for tokens per accuracy point: only rows in groups with reward
//! variance carry a GRPO gradient) drops below the fixed-`n` baseline.

use crate::coordinator::scheduler::{BudgetAllocator, BudgetSpec};
use crate::hwsim::HwModel;
use crate::metrics::{ascii_plot, write_csv_rows, CsvRow};
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

/// Per-prompt decode budget of the fixed-`n` baseline (the paper's n).
const N: usize = 64;
/// Prompt groups per simulated iteration (half saturated, half wide).
const GROUPS: usize = 8;
/// Generation budget G of the simulated profile (max rollout length).
const G: usize = 64;
/// Decode chunk used to price the bill on the cost model.
const CHUNK: usize = 4;
/// Hard per-prompt cap (probe + extras) of every swept spec.
const MAX_PER_PROMPT: usize = 128;
/// Probe quotas swept (`n_probe = N` is the degenerate fixed-`n` cell).
const PROBE_SWEEP: [usize; 5] = [4, 8, 16, 32, 64];
/// Bracket-width thresholds swept; `0.0` keeps even constant-reward
/// groups in the heap (nothing is ever saturated), isolating the knob.
const THRESH_SWEEP: [f64; 3] = [0.0, 0.25, 1.0];
/// Reward bracket of the rule-based reward model under default weights.
const RMAX: f32 = 3.0;
/// Seed of the deterministic synthetic groups (per-group streams derive
/// from it by XOR with the group index).
const SIM_SEED: u64 = 0xA076_1D64_78BD_642F;

/// One synthetic group: `MAX_PER_PROMPT` candidate rollouts (the probe
/// rows are the prefix; extras continue at `rollout_idx = n_probe..`).
struct SimGroup {
    /// Generated length per candidate rollout (tokens incl. EOS).
    lens: Vec<usize>,
    /// Total reward per candidate rollout.
    rewards: Vec<f32>,
    /// Does the group carry advantage signal (non-constant rewards)?
    wide: bool,
}

/// Deterministic synthetic world: even-indexed groups are saturated
/// (every rollout scores the same — zero bracket, zero advantage), odd
/// ones are wide (alternating solved/unsolved, bracket `RMAX`). Lengths
/// are uniform in `1..=G` either way, so the token price of a slot does
/// not depend on where the allocator sends it.
fn sim_world() -> Vec<SimGroup> {
    (0..GROUPS)
        .map(|g| {
            let mut rng = Rng::seed_from_u64(SIM_SEED ^ g as u64);
            let wide = g % 2 == 1;
            let lens: Vec<usize> = (0..MAX_PER_PROMPT).map(|_| 1 + rng.below(G)).collect();
            let rewards: Vec<f32> = (0..MAX_PER_PROMPT)
                .map(|i| if wide && i % 2 == 1 { RMAX } else { 0.0 })
                .collect();
            SimGroup { lens, rewards, wide }
        })
        .collect()
}

/// One `(n_probe, width_threshold)` cell of the sweep.
#[derive(Debug, Clone)]
pub struct BudgetRow {
    /// Probe quota of the cell.
    pub n_probe: usize,
    /// Saturation threshold of the cell.
    pub width_threshold: f64,
    /// Groups the allocator reported saturated after the probe wave.
    pub saturated_groups: usize,
    /// Extra rows granted past the probe wave (total).
    pub rows_extra: usize,
    /// Extra rows that landed in saturated (constant-reward) groups.
    pub extra_to_saturated: usize,
    /// Rows decoded in saturated groups (probe + extras).
    pub rows_saturated: usize,
    /// Rows decoded in wide groups (probe + extras).
    pub rows_wide: usize,
    /// Total rows decoded — always `N × GROUPS` (budget conservation).
    pub rows_total: usize,
    /// Generated-token bill of the adaptive run.
    pub tokens_total: usize,
    /// Rows carrying advantage signal (decoded rows in wide groups).
    pub signal_rows: usize,
    /// `tokens_total / signal_rows` — the study's cost metric.
    pub tokens_per_signal_row: f64,
    /// Generated-token bill of the fixed-`n` baseline (same slot count).
    pub fixed_tokens: usize,
    /// `fixed_tokens / fixed_signal_rows` for the same world.
    pub fixed_tokens_per_signal_row: f64,
    /// Simulated inference time of the adaptive run (cost model).
    pub sim_time: f64,
    /// Simulated inference time of the fixed-`n` baseline.
    pub fixed_sim_time: f64,
}

impl CsvRow for BudgetRow {
    fn csv_header() -> &'static str {
        "n_probe,width_threshold,saturated_groups,rows_extra,extra_to_saturated,\
         rows_saturated,rows_wide,rows_total,tokens_total,signal_rows,\
         tokens_per_signal_row,fixed_tokens,fixed_tokens_per_signal_row,\
         sim_time,fixed_sim_time"
    }

    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
            self.n_probe,
            self.width_threshold,
            self.saturated_groups,
            self.rows_extra,
            self.extra_to_saturated,
            self.rows_saturated,
            self.rows_wide,
            self.rows_total,
            self.tokens_total,
            self.signal_rows,
            self.tokens_per_signal_row,
            self.fixed_tokens,
            self.fixed_tokens_per_signal_row,
            self.sim_time,
            self.fixed_sim_time
        )
    }
}

/// Run one cell: probe, allocate through the real [`BudgetAllocator`],
/// decode the granted rows, and price both the adaptive and the
/// fixed-`n` bill on the cost model.
fn run_cell(world: &[SimGroup], hw: &HwModel, n_probe: usize, width_threshold: f64) -> BudgetRow {
    let spec = BudgetSpec { n: N, n_probe, max_per_prompt: MAX_PER_PROMPT, width_threshold };
    let mut alloc = BudgetAllocator::new(spec, world.len());
    for (g, grp) in world.iter().enumerate() {
        for &r in &grp.rewards[..n_probe] {
            alloc.observe(g, r);
        }
    }
    let grants = alloc.allocate();
    let saturated_groups = alloc.saturated_groups();

    let mut rows_per_group = vec![n_probe; world.len()];
    let mut extra_to_saturated = 0usize;
    for &(g, _) in &grants {
        rows_per_group[g] += 1;
        if alloc.is_saturated(g) {
            extra_to_saturated += 1;
        }
    }

    let mut lens: Vec<usize> = Vec::new();
    let mut fixed_lens: Vec<usize> = Vec::new();
    let (mut rows_saturated, mut rows_wide, mut signal_rows) = (0usize, 0usize, 0usize);
    for (grp, &rows) in world.iter().zip(&rows_per_group) {
        lens.extend_from_slice(&grp.lens[..rows]);
        fixed_lens.extend_from_slice(&grp.lens[..N]);
        if grp.wide {
            rows_wide += rows;
            signal_rows += rows;
        } else {
            rows_saturated += rows;
        }
    }
    let fixed_signal_rows: usize = world.iter().filter(|g| g.wide).count() * N;
    let tokens_total: usize = lens.iter().sum();
    let fixed_tokens: usize = fixed_lens.iter().sum();
    BudgetRow {
        n_probe,
        width_threshold,
        saturated_groups,
        rows_extra: grants.len(),
        extra_to_saturated,
        rows_saturated,
        rows_wide,
        rows_total: rows_per_group.iter().sum(),
        tokens_total,
        signal_rows,
        tokens_per_signal_row: tokens_total as f64 / signal_rows.max(1) as f64,
        fixed_tokens,
        fixed_tokens_per_signal_row: fixed_tokens as f64 / fixed_signal_rows.max(1) as f64,
        sim_time: hw.chunked_inference_time(&lens, CHUNK),
        fixed_sim_time: hw.chunked_inference_time(&fixed_lens, CHUNK),
    }
}

/// Build the sweep grid from a cost model (row-major: threshold, then
/// `n_probe` ascending). Deterministic: the synthetic world is the same
/// for every cell.
pub fn sweep(hw: &HwModel) -> Vec<BudgetRow> {
    let world = sim_world();
    let mut out = Vec::with_capacity(THRESH_SWEEP.len() * PROBE_SWEEP.len());
    for &t in &THRESH_SWEEP {
        for &p in &PROBE_SWEEP {
            out.push(run_cell(&world, hw, p, t));
        }
    }
    out
}

/// Run the study: write `<out_dir>/budget.csv` and print the
/// tokens-per-signal-row curves (one per threshold, plus the fixed-`n`
/// baseline) over the probe quota.
pub fn run(out_dir: &str) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let hw = HwModel::default();
    let rows = sweep(&hw);
    write_csv_rows(Path::new(&format!("{out_dir}/budget.csv")), &rows)?;

    let mut curves: Vec<(String, Vec<(f64, f64)>)> = THRESH_SWEEP
        .iter()
        .map(|&t| {
            let pts: Vec<(f64, f64)> = rows
                .iter()
                .filter(|r| r.width_threshold == t)
                .map(|r| (r.n_probe as f64, r.tokens_per_signal_row))
                .collect();
            (format!("threshold={t}"), pts)
        })
        .collect();
    let baseline: Vec<(f64, f64)> = rows
        .iter()
        .filter(|r| r.width_threshold == THRESH_SWEEP[0])
        .map(|r| (r.n_probe as f64, r.fixed_tokens_per_signal_row))
        .collect();
    curves.push(("fixed-n".to_string(), baseline));
    let series: Vec<(&str, &[(f64, f64)])> =
        curves.iter().map(|(n, p)| (n.as_str(), p.as_slice())).collect();
    println!(
        "Budget study: generated tokens per signal row vs probe quota \
         (n = {N}, {GROUPS} groups — half saturated, cap {MAX_PER_PROMPT})"
    );
    println!("{}", ascii_plot(&series, 64, 14));
    for r in &rows {
        println!(
            "  probe={:<3} thr={:<5} saturated {}/{} groups | rows sat {:>4} wide {:>4} \
             (extras {:>4}, {} to saturated) | tok/signal {:>7.2} vs fixed {:>7.2}",
            r.n_probe,
            r.width_threshold,
            r.saturated_groups,
            GROUPS,
            r.rows_saturated,
            r.rows_wide,
            r.rows_extra,
            r.extra_to_saturated,
            r.tokens_per_signal_row,
            r.fixed_tokens_per_signal_row
        );
    }
    println!(
        "  (equal total slot budget in every cell: saturated groups stop at the \
         probe quota and wide groups absorb the released slots — see \
         docs/DETERMINISM.md for the allocation-is-history contract)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance shape: at equal total budget, saturated groups
    /// receive fewer rows than the fixed-`n` baseline (and zero extras),
    /// while wide groups absorb the released slots and the per-signal
    /// token price drops.
    #[test]
    fn saturated_groups_release_budget() {
        let rows = sweep(&HwModel::default());
        assert_eq!(rows.len(), THRESH_SWEEP.len() * PROBE_SWEEP.len());
        for r in &rows {
            // budget conservation: every cell spends the fixed-n slot count
            assert_eq!(r.rows_total, N * GROUPS, "{r:?}");
            if r.width_threshold > 0.0 && r.n_probe < N {
                assert_eq!(r.extra_to_saturated, 0, "{r:?}");
                assert_eq!(r.saturated_groups, GROUPS / 2, "{r:?}");
                // saturated groups stop at the probe quota...
                assert_eq!(r.rows_saturated, r.n_probe * (GROUPS / 2), "{r:?}");
                assert!(r.rows_saturated < N * (GROUPS / 2), "{r:?}");
                // ...wide groups absorb the released slots...
                assert!(r.rows_wide > N * (GROUPS / 2), "{r:?}");
                // ...and the signal price beats the fixed-n baseline
                assert!(r.tokens_per_signal_row < r.fixed_tokens_per_signal_row, "{r:?}");
            }
        }
    }

    /// `n_probe = n` is the degenerate cell: the allocator grants
    /// nothing and the bill is bitwise the fixed-`n` baseline's —
    /// the cost-model mirror of the disabled-equals-fixed-`n` golden.
    #[test]
    fn probe_equal_to_n_matches_fixed_baseline() {
        let rows = sweep(&HwModel::default());
        for r in rows.iter().filter(|r| r.n_probe == N) {
            assert_eq!(r.rows_extra, 0, "{r:?}");
            assert_eq!(r.tokens_total, r.fixed_tokens, "{r:?}");
            assert_eq!(r.sim_time, r.fixed_sim_time, "{r:?}");
        }
    }

    #[test]
    fn budget_row_csv_shape() {
        let rows = sweep(&HwModel::default());
        let header_cols = BudgetRow::csv_header().split(',').count();
        for r in &rows {
            assert_eq!(r.csv_row().split(',').count(), header_cols, "{r:?}");
        }
    }
}
