//! Fleet study — the disaggregated two-fleet design space across
//! `R × K × shards`.
//!
//! Not a paper figure: this driver prices the staleness-K async schedule
//! (`[fleet]`) entirely on the cost model, so it runs without artifacts.
//! Every cell runs the *same* update sequence (same batch count, same
//! post-selection m), so wall-clock to finish it is the cost-to-accuracy
//! proxy: the learning curve against the update index is fixed, and only
//! the realized staleness (reported per cell) shifts it. Traffic is the
//! synthetic `[fleet]` model — bursty arrivals, heterogeneous prompt and
//! generation lengths, a backlog priced at batch granularity so millions
//! of queued prompts cost nothing per prompt.
//!
//! Shapes that must reproduce (asserted by this module's tests):
//!
//! * wall-clock is **non-increasing in R** at every (K, shards), and
//!   strictly decreases from R = 1 while generation-bound;
//! * the best async cell strictly beats the legacy pipelined point
//!   (R = 1, K = 1) at the same shard count, which itself strictly beats
//!   sync (K = 0);
//! * realized staleness never exceeds K.

use crate::hwsim::fleet::simulate;
use crate::hwsim::{FleetSection, FleetSpec, HwModel, TrafficModel};
use crate::metrics::{ascii_plot, write_csv_rows, CsvRow};
use anyhow::Result;
use std::path::Path;

/// Inference replica counts swept.
const R_SWEEP: [usize; 4] = [1, 2, 4, 8];
/// Staleness bounds swept (0 = sync, 1 = the legacy pipelined bound).
const K_SWEEP: [usize; 4] = [0, 1, 2, 4];
/// Update-fleet shard counts swept.
const SHARD_SWEEP: [usize; 3] = [1, 2, 4];
/// Generation batches produced (= updates consumed) per cell.
const UPDATES: usize = 64;
/// Rollouts decoded per generation batch (the paper's default n).
const ROWS_PER_BATCH: usize = 64;
/// Prompts drawn from the traffic backlog per batch.
const PROMPTS_PER_BATCH: u64 = 64;
/// Rollouts each update trains on (post-selection m).
const UPDATE_ROLLOUTS: usize = 16;
/// Decode chunk the replicas run.
const DECODE_CHUNK: usize = 16;
/// Traffic-model seed (sampled lengths replay exactly).
const SEED: u64 = 17;

/// One (R, K, shards) cell of the sweep.
#[derive(Debug, Clone)]
pub struct FleetRow {
    /// Inference replicas `R`.
    pub replicas: usize,
    /// Staleness bound `K`.
    pub max_staleness: usize,
    /// Update-fleet shard count.
    pub shards: usize,
    /// Simulated makespan of the fixed update sequence (sim seconds) —
    /// the cost-to-accuracy proxy.
    pub wall_clock: f64,
    /// Makespan of the sync cell (R = 1, K = 0) at the same shard count
    /// divided by this cell's — the speed-up the async schedule buys.
    pub speedup_vs_sync: f64,
    /// Fraction of replica-seconds spent decoding.
    pub inference_util: f64,
    /// Fraction of the makespan the update fleet spent updating.
    pub update_util: f64,
    /// Mean ready-queue depth sampled at admissions.
    pub mean_queue_depth: f64,
    /// Deepest the ready queue ever got.
    pub max_queue_depth: usize,
    /// Total replica-seconds blocked on a full queue.
    pub queue_block_time: f64,
    /// Mean realized staleness over consumed batches.
    pub mean_staleness: f64,
    /// Largest realized staleness (never exceeds K).
    pub max_staleness_seen: usize,
    /// Realized staleness histogram, `;`-joined counts for s = 0..=K.
    pub staleness_hist: String,
}

impl CsvRow for FleetRow {
    fn csv_header() -> &'static str {
        "replicas,max_staleness,shards,wall_clock,speedup_vs_sync,inference_util,update_util,\
         mean_queue_depth,max_queue_depth,queue_block_time,mean_staleness,max_staleness_seen,\
         staleness_hist"
    }

    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{}",
            self.replicas,
            self.max_staleness,
            self.shards,
            self.wall_clock,
            self.speedup_vs_sync,
            self.inference_util,
            self.update_util,
            self.mean_queue_depth,
            self.max_queue_depth,
            self.queue_block_time,
            self.mean_staleness,
            self.max_staleness_seen,
            self.staleness_hist
        )
    }
}

/// The traffic every cell is driven with: the `[fleet]` defaults (bursty
/// arrivals every `traffic_gap` seconds), seeded for exact replay.
fn traffic() -> TrafficModel {
    TrafficModel::new(&FleetSection::default(), SEED)
}

fn cell(k: usize, replicas: usize, shards: usize) -> FleetSpec {
    FleetSpec {
        replicas,
        max_staleness: k,
        queue_capacity: k,
        updates: UPDATES,
        rows_per_batch: ROWS_PER_BATCH,
        prompts_per_batch: PROMPTS_PER_BATCH,
        decode_chunk: DECODE_CHUNK,
        update_rollouts: UPDATE_ROLLOUTS,
        shards,
        micro_batch: 0,
        lora: false,
    }
}

/// Build the sweep grid (row-major: shards, then K, then R).
pub fn sweep(hw: &HwModel) -> Vec<FleetRow> {
    let t = traffic();
    let mut rows = Vec::with_capacity(SHARD_SWEEP.len() * K_SWEEP.len() * R_SWEEP.len());
    for &shards in &SHARD_SWEEP {
        let sync_wall = simulate(hw, &t, &cell(0, 1, shards)).wall_clock;
        for &k in &K_SWEEP {
            for &r in &R_SWEEP {
                let rep = simulate(hw, &t, &cell(k, r, shards));
                let hist: Vec<String> = rep.staleness_hist.iter().map(|c| c.to_string()).collect();
                rows.push(FleetRow {
                    replicas: r,
                    max_staleness: k,
                    shards,
                    wall_clock: rep.wall_clock,
                    speedup_vs_sync: sync_wall / rep.wall_clock.max(1e-12),
                    inference_util: rep.inference_util,
                    update_util: rep.update_util,
                    mean_queue_depth: rep.mean_queue_depth,
                    max_queue_depth: rep.max_queue_depth,
                    queue_block_time: rep.queue_block_time,
                    mean_staleness: rep.mean_staleness,
                    max_staleness_seen: rep.max_staleness_seen,
                    staleness_hist: hist.join(";"),
                });
            }
        }
    }
    rows
}

/// Run the study: write `<out_dir>/fleet.csv` and
/// `<out_dir>/fleet_util.txt` (the utilization plot artifact), and print
/// the makespan and utilization curves.
pub fn run(out_dir: &str) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let hw = HwModel::default();
    let rows = sweep(&hw);
    write_csv_rows(Path::new(&format!("{out_dir}/fleet.csv")), &rows)?;

    let mid_shards = SHARD_SWEEP[1];
    let curve = |k: usize, f: &dyn Fn(&FleetRow) -> f64| -> Vec<(f64, f64)> {
        rows.iter()
            .filter(|r| r.max_staleness == k && r.shards == mid_shards)
            .map(|r| (r.replicas as f64, f(r)))
            .collect()
    };
    let wall_curves: Vec<(String, Vec<(f64, f64)>)> =
        K_SWEEP.iter().map(|&k| (format!("K={k}"), curve(k, &|r| r.wall_clock))).collect();
    let wall_series: Vec<(&str, &[(f64, f64)])> =
        wall_curves.iter().map(|(n, p)| (n.as_str(), p.as_slice())).collect();
    println!(
        "Fleet study: simulated makespan vs inference replicas R \
         (shards = {mid_shards}, {UPDATES} updates, n = {ROWS_PER_BATCH} -> m = {UPDATE_ROLLOUTS})"
    );
    println!("{}", ascii_plot(&wall_series, 64, 14));

    let util_curves: Vec<(String, Vec<(f64, f64)>)> =
        K_SWEEP.iter().map(|&k| (format!("K={k}"), curve(k, &|r| r.inference_util))).collect();
    let util_series: Vec<(&str, &[(f64, f64)])> =
        util_curves.iter().map(|(n, p)| (n.as_str(), p.as_slice())).collect();
    let util_plot = format!(
        "Fleet study: inference-fleet utilization vs replicas R (shards = {mid_shards})\n{}",
        ascii_plot(&util_series, 64, 14)
    );
    println!("{util_plot}");
    std::fs::write(format!("{out_dir}/fleet_util.txt"), &util_plot)?;

    for &k in &K_SWEEP {
        let at = |r: usize| {
            rows.iter()
                .find(|c| c.max_staleness == k && c.replicas == r && c.shards == mid_shards)
                .expect("swept")
        };
        println!(
            "  K={k}: R=1 {:>8.1}s | R=8 {:>8.1}s ({:.2}x vs sync) | \
             queue depth {:.2} | staleness mean {:.2} max {} | hist {}",
            at(1).wall_clock,
            at(8).wall_clock,
            at(8).speedup_vs_sync,
            at(8).mean_queue_depth,
            at(8).mean_staleness,
            at(8).max_staleness_seen,
            at(8).staleness_hist,
        );
    }
    println!(
        "  (replicas buy wall-clock until the staleness window or the \
         update fleet binds — K widens the window, shards shrink the update)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row<'a>(rows: &'a [FleetRow], r: usize, k: usize, s: usize) -> &'a FleetRow {
        rows.iter()
            .find(|c| c.replicas == r && c.max_staleness == k && c.shards == s)
            .expect("cell swept")
    }

    /// Acceptance shapes: wall-clock non-increasing in R everywhere,
    /// strictly decreasing from R = 1 whenever the schedule allows any
    /// overlap (K >= 1), and the staleness contract holds in every cell.
    #[test]
    fn wall_clock_decreases_in_replicas_until_bound() {
        let rows = sweep(&HwModel::default());
        assert_eq!(rows.len(), SHARD_SWEEP.len() * K_SWEEP.len() * R_SWEEP.len());
        for &s in &SHARD_SWEEP {
            for &k in &K_SWEEP {
                let walls: Vec<f64> =
                    R_SWEEP.iter().map(|&r| row(&rows, r, k, s).wall_clock).collect();
                for w in walls.windows(2) {
                    assert!(
                        w[1] <= w[0] + 1e-9,
                        "replicas slowed the fleet down at K={k}, shards={s}: {walls:?}"
                    );
                }
                if k >= 1 {
                    assert!(
                        walls[1] < walls[0],
                        "R=2 must strictly beat R=1 at K={k}, shards={s}: {walls:?}"
                    );
                }
            }
        }
        for c in &rows {
            assert!(c.max_staleness_seen <= c.max_staleness, "staleness contract violated");
            assert!((0.0..=1.0 + 1e-9).contains(&c.inference_util));
            assert!((0.0..=1.0 + 1e-9).contains(&c.update_util));
            assert!(c.queue_block_time >= 0.0 && c.mean_queue_depth >= 0.0);
        }
    }

    /// The schedule ladder at fixed shards: sync (K=0, R=1) is strictly
    /// slowest, legacy pipelined (K=1, R=1) strictly improves on it, and
    /// the deepest async cell strictly improves on pipelined.
    #[test]
    fn async_beats_pipelined_beats_sync() {
        let rows = sweep(&HwModel::default());
        for &s in &SHARD_SWEEP {
            let sync = row(&rows, 1, 0, s).wall_clock;
            let pipelined = row(&rows, 1, 1, s).wall_clock;
            let deep = row(&rows, 8, 4, s).wall_clock;
            assert!(pipelined < sync, "pipelined must beat sync at shards={s}");
            assert!(deep < pipelined, "R=8,K=4 must beat pipelined at shards={s}");
            assert!(row(&rows, 1, 0, s).speedup_vs_sync == 1.0);
            assert!(row(&rows, 8, 4, s).speedup_vs_sync > 1.0);
        }
    }

    /// The CSV schema round-trips with matching column counts, and the
    /// histogram column accounts for every consumed batch.
    #[test]
    fn fleet_row_csv_shape() {
        let rows = sweep(&HwModel::default());
        let header_cols = FleetRow::csv_header().replace(char::is_whitespace, "");
        let n = header_cols.split(',').count();
        for r in &rows {
            assert_eq!(r.csv_row().split(',').count(), n);
            let total: u64 = r.staleness_hist.split(';').map(|c| c.parse::<u64>().unwrap()).sum();
            assert_eq!(total, UPDATES as u64, "histogram loses batches");
            assert_eq!(r.staleness_hist.split(';').count(), r.max_staleness + 1);
        }
    }
}
