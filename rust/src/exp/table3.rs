//! Table 3 (§A.4) — PODS' speed-up ratio over the baseline: the ratio of
//! simulated wall-clock times to reach 0.99× the baseline's peak test
//! accuracy. Computed from the eval CSVs written by the Fig. 3 runs
//! (paper: 1.7×–3.0× across settings).

use crate::metrics::{write_csv_rows, CsvRow};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Minimal eval-CSV reader (schema written by metrics::Recorder).
/// Returns (split, sim_time, accuracy, mean_reward) rows.
pub fn read_eval_csv(path: &Path) -> Result<Vec<(String, f64, f32, f32)>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let mut lines = text.lines();
    let header: Vec<&str> = lines
        .next()
        .ok_or_else(|| anyhow!("empty csv {path:?}"))?
        .split(',')
        .collect();
    let col = |name: &str| {
        header
            .iter()
            .position(|h| *h == name)
            .ok_or_else(|| anyhow!("{path:?} missing column {name}"))
    };
    let (ci_split, ci_time, ci_acc, ci_rew) =
        (col("split")?, col("sim_time")?, col("accuracy")?, col("mean_reward")?);
    let mut out = Vec::new();
    for line in lines {
        if line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split(',').collect();
        out.push((
            f[ci_split].to_string(),
            f[ci_time].parse::<f64>()?,
            f[ci_acc].parse::<f32>()?,
            f[ci_rew].parse::<f32>()?,
        ));
    }
    Ok(out)
}

/// Selection token totals `(kept, dropped)` summed over a run's train CSV.
/// Returns zeros when the CSV predates the selector subsystem's columns.
pub fn read_train_tokens(path: &Path) -> Result<(u64, u64)> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let mut lines = text.lines();
    let header: Vec<&str> = lines
        .next()
        .ok_or_else(|| anyhow!("empty csv {path:?}"))?
        .split(',')
        .collect();
    let (Some(ci_kept), Some(ci_dropped)) = (
        header.iter().position(|h| *h == "sel_tokens_kept"),
        header.iter().position(|h| *h == "sel_tokens_dropped"),
    ) else {
        return Ok((0, 0));
    };
    let (mut kept, mut dropped) = (0u64, 0u64);
    for line in lines {
        if line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split(',').collect();
        // tolerate a truncated trailing line (run killed mid-write):
        // anything short of the full column count is skipped, so a row cut
        // mid-number can't be mistaken for a smaller value
        if f.len() != header.len() {
            continue;
        }
        kept += f[ci_kept].parse::<u64>()?;
        dropped += f[ci_dropped].parse::<u64>()?;
    }
    Ok((kept, dropped))
}

/// Metric selector: 0 = accuracy, 1 = mean total reward.
fn metric(row: &(String, f64, f32, f32), which: usize) -> f32 {
    if which == 0 {
        row.2
    } else {
        row.3
    }
}

/// First crossing strictly after t=0 (the shared starting checkpoint).
fn time_to(rows: &[(String, f64, f32, f32)], which: usize, target: f32) -> Option<f64> {
    rows.iter()
        .filter(|r| r.0 == "test" && r.1 > 0.0)
        .find(|r| metric(r, which) >= target)
        .map(|r| r.1)
}

fn peak(rows: &[(String, f64, f32, f32)], which: usize) -> f32 {
    rows.iter()
        .filter(|r| r.0 == "test" && r.1 > 0.0)
        .map(|r| metric(r, which))
        .fold(0.0, f32::max)
}

#[derive(Debug)]
struct Table3Row {
    setting: String,
    baseline: String,
    metric: String,
    baseline_peak: f32,
    target: f32,
    t_baseline: f64,
    t_pods: f64,
    speedup: f64,
    /// Fraction of PODS' generated tokens its selection pipeline kept for
    /// the update phase (from the train CSV's selection diagnostics; 0
    /// when the columns are absent).
    pods_token_keep_frac: f64,
}

impl CsvRow for Table3Row {
    fn csv_header() -> &'static str {
        "setting,baseline,metric,baseline_peak,target,t_baseline,t_pods,speedup,pods_token_keep_frac"
    }
    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{}",
            self.setting,
            self.baseline,
            self.metric,
            self.baseline_peak,
            self.target,
            self.t_baseline,
            self.t_pods,
            self.speedup,
            self.pods_token_keep_frac
        )
    }
}

/// Compute the speed-up table from `results/fig3_*_{pods,grpo,ga}_eval.csv`.
pub fn run(out_dir: &str) -> Result<()> {
    let mut rows = Vec::new();
    for s in super::fig3::settings() {
        let pods_path = format!("{out_dir}/fig3_{}_pods_eval.csv", s.id);
        let base_name = if s.workers > 1 { "ga" } else { "grpo" };
        let base_path = format!("{out_dir}/fig3_{}_{}_eval.csv", s.id, base_name);
        if !Path::new(&pods_path).exists() || !Path::new(&base_path).exists() {
            eprintln!("[table3] setting ({}) missing runs; run `pods exp fig3` first", s.id);
            continue;
        }
        let pods = read_eval_csv(Path::new(&pods_path))?;
        let base = read_eval_csv(Path::new(&base_path))?;
        let train_path = format!("{out_dir}/fig3_{}_pods_train.csv", s.id);
        let (kept, dropped) = if Path::new(&train_path).exists() {
            read_train_tokens(Path::new(&train_path))?
        } else {
            (0, 0)
        };
        let keep_frac = if kept + dropped > 0 {
            kept as f64 / (kept + dropped) as f64
        } else {
            0.0
        };
        // paper metric: test accuracy; at this reproduction scale the
        // accuracy curve can be flat/noisy, so the composite reward (the
        // objective RL maximises) is reported alongside
        for (which, mname) in [(0usize, "accuracy"), (1, "mean_reward")] {
            let target = 0.99 * peak(&base, which);
            let (Some(tb), Some(tp)) =
                (time_to(&base, which, target), time_to(&pods, which, target))
            else {
                eprintln!(
                    "[table3] setting ({}) {}: target {:.3} unreached by one arm",
                    s.id, mname, target
                );
                continue;
            };
            rows.push(Table3Row {
                setting: s.id.to_string(),
                baseline: base_name.to_string(),
                metric: mname.to_string(),
                baseline_peak: peak(&base, which),
                target,
                t_baseline: tb,
                t_pods: tp,
                speedup: tb / tp.max(1e-9),
                pods_token_keep_frac: keep_frac,
            });
        }
    }
    write_csv_rows(Path::new(&format!("{out_dir}/table3.csv")), &rows)?;
    println!("Table 3: speed-up of GRPO-PODS over the baseline (paper: 1.7x-3.0x on accuracy)");
    println!(
        "{:<8} {:<9} {:<12} {:>9} {:>10} {:>10} {:>8} {:>10}",
        "setting", "baseline", "metric", "peak", "t_base(s)", "t_pods(s)", "speedup", "tok-kept"
    );
    for r in &rows {
        println!(
            "{:<8} {:<9} {:<12} {:>9.3} {:>10.1} {:>10.1} {:>7.2}x {:>9.1}%",
            r.setting,
            r.baseline,
            r.metric,
            r.baseline_peak,
            r.t_baseline,
            r.t_pods,
            r.speedup,
            100.0 * r.pods_token_keep_frac
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_csv_parses() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("e.csv");
        std::fs::write(
            &p,
            "accuracy,format_rate,iter,mean_len,mean_reward,problems,real_time,sim_time,split\n\
             0.5,0.9,10,30,2.0,48,1.0,100.0,test\n\
             0.7,0.9,20,30,2.0,48,2.0,200.0,test\n",
        )
        .unwrap();
        let rows = read_eval_csv(&p).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(peak(&rows, 0), 0.7);
        assert_eq!(time_to(&rows, 0, 0.6), Some(200.0));
        assert_eq!(time_to(&rows, 0, 0.9), None);
        assert_eq!(peak(&rows, 1), 2.0);
    }

    #[test]
    fn train_tokens_sum_and_tolerate_old_schemas() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("t.csv");
        std::fs::write(
            &p,
            "iter,sel_tokens_kept,sel_tokens_dropped\n0,100,300\n1,50,150\n2,9",
        )
        .unwrap();
        // the truncated trailing line is skipped, not a panic
        assert_eq!(read_train_tokens(&p).unwrap(), (150, 450));
        // pre-selector schema: columns absent -> zeros, not an error
        let old = dir.path().join("old.csv");
        std::fs::write(&old, "iter,sim_time\n0,1.0\n").unwrap();
        assert_eq!(read_train_tokens(&old).unwrap(), (0, 0));
    }
}
