//! KV study — prefill calls saved and wall-clock recovered by
//! group-shared prompt KV, swept over `share_prompt_kv × kv_pool_bytes ×
//! decode_chunk`.
//!
//! Not a paper figure: this driver quantifies what `[rollout]
//! share_prompt_kv` buys under the paged KV-memory model. It runs
//! entirely on the cost model (no artifacts): the same deterministic
//! synthetic groups as the prune study ([`crate::exp::prune::sim_group`])
//! are pushed through a simulated slot-based admission loop that mirrors
//! the chunked driver's pool gate — rows admit from the group-major FIFO
//! only when the modeled pool has room, prompt pages are counted once per
//! resident group when sharing, and a refill run of a snapshot-resident
//! group admits without a prefill. Each cell prices its decode with
//! [`HwModel::shared_prefill_inference_time`] at its own prefill-call
//! count, so the shared/unshared arms are an apples-to-apples comparison.
//!
//! Shapes that must reproduce (asserted by this module's tests):
//!
//! * with sharing on and an unbounded pool, prefill calls collapse to
//!   exactly one per group (the tentpole invariant), so
//!   `prefill_calls_saved > 0` and the priced time never exceeds the
//!   unshared arm;
//! * the modeled pool peak never exceeds a bounded `kv_pool_bytes`, and
//!   constraining the pool queues admissions without changing any row's
//!   decoded length (admission schedule is history, not partition —
//!   docs/DETERMINISM.md).

use crate::exp::prune::sim_group;
use crate::hwsim::{HwModel, KvPool};
use crate::metrics::{ascii_plot, write_csv_rows, CsvRow};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::path::Path;

/// Rollouts generated per prompt (the paper's default n).
const N: usize = 64;
/// Prompt groups per simulated iteration.
const GROUPS: usize = 4;
/// Generation budget G of the simulated profile.
const G: usize = 64;
/// Prompt region length P of the simulated profile.
const PROMPT: usize = 32;
/// Decode slots of the simulated device (the profile's B_r).
const SLOTS: usize = 16;
/// Decode chunk sizes swept (the artifact set's lowered programs).
const CHUNK_SWEEP: [usize; 4] = [1, 4, 16, 64];
/// Seed of the deterministic synthetic groups (same stream as the prune
/// study: per-group streams derive by XOR with the group index).
const SIM_SEED: u64 = 0x9E37_79B9_7F4A_7C15;

/// Outcome of simulating one iteration's admission under the paged pool.
#[derive(Debug, Clone)]
pub struct KvSimOut {
    /// Physical prompt-prefill calls (unshared: one per admission event;
    /// shared: one per group run not served by the snapshot).
    pub prefill_calls: usize,
    /// Refill runs served from the resident group snapshot (shared only).
    pub prefill_calls_saved: usize,
    /// Peak bytes resident in the modeled pool.
    pub kv_peak_bytes: u64,
    /// Refill events where the pool gate left the queue head waiting.
    pub admit_stalls: usize,
    /// Per-row decoded lengths, queue order (must be arm-invariant).
    pub decoded_lens: Vec<usize>,
}

/// Simulate one iteration's slot loop against the paged-pool admission
/// gate. `lens` is the group-major row queue as `(group_idx, final_len)`.
/// Mirrors `rollout::chunked`: head-of-line FIFO admission, gen pages
/// reserved at the full budget, prompt pages refcounted per group when
/// sharing (rows + one snapshot hold), everything freed on retire.
pub fn simulate_admission(
    lens: &[(usize, usize)],
    share: bool,
    hw: &HwModel,
    pool_bytes: u64,
    chunk: usize,
) -> Result<KvSimOut> {
    let prompt_need = hw.kv_seg_bytes(PROMPT);
    let gen_need = hw.kv_seg_bytes(G);
    let n_groups = lens.iter().map(|&(g, _)| g + 1).max().unwrap_or(0);
    let mut queue: VecDeque<(usize, usize, usize)> =
        lens.iter().enumerate().map(|(i, &(g, l))| (i, g, l)).collect();
    // slot: (row_idx, group, final_len, decoded, slot_bytes)
    let mut slot: Vec<Option<(usize, usize, usize, usize, u64)>> = vec![None; SLOTS];
    let mut refs = vec![0usize; n_groups];
    let mut pool = KvPool::new(pool_bytes);
    let mut snapshot: Option<usize> = None;
    let mut out = KvSimOut {
        prefill_calls: 0,
        prefill_calls_saved: 0,
        kv_peak_bytes: 0,
        admit_stalls: 0,
        decoded_lens: vec![0; lens.len()],
    };
    let chunk = chunk.max(1);
    let unref = |g: usize, refs: &mut [usize], pool: &mut KvPool| {
        refs[g] -= 1;
        if refs[g] == 0 {
            pool.free(prompt_need);
        }
    };
    loop {
        // ---- refill: admit the queue head while a slot and pages fit ---
        let mut admitted: Vec<usize> = Vec::new();
        for entry in slot.iter_mut() {
            if entry.is_some() {
                continue;
            }
            let Some(&(row, g, fl)) = queue.front() else { break };
            let row_need = |refs: &[usize]| {
                gen_need + if share && refs[g] > 0 { 0 } else { prompt_need }
            };
            let mut need = row_need(&refs);
            if !pool.can_admit(need) {
                // a stale snapshot of another group can never serve this
                // group-major queue again — drop its hold and retry
                if let Some(sg) = snapshot {
                    if share && sg != g {
                        snapshot = None;
                        unref(sg, &mut refs, &mut pool);
                        need = row_need(&refs);
                    }
                }
                if !pool.can_admit(need) {
                    out.admit_stalls += 1;
                    break;
                }
            }
            queue.pop_front();
            pool.alloc(need);
            if share {
                refs[g] += 1;
                *entry = Some((row, g, fl, 0, gen_need));
            } else {
                *entry = Some((row, g, fl, 0, need));
            }
            admitted.push(g);
        }
        if !admitted.is_empty() {
            if share {
                // one prefill per contiguous group run; a run of the
                // snapshot-resident group admits via broadcast instead
                let mut i = 0;
                while i < admitted.len() {
                    let g = admitted[i];
                    while i < admitted.len() && admitted[i] == g {
                        i += 1;
                    }
                    if snapshot == Some(g) {
                        out.prefill_calls_saved += 1;
                    } else {
                        out.prefill_calls += 1;
                        if let Some(sg) = snapshot.take() {
                            unref(sg, &mut refs, &mut pool);
                        }
                        refs[g] += 1; // the new snapshot's hold
                        snapshot = Some(g);
                    }
                }
            } else {
                out.prefill_calls += 1; // one batched prefill per event
            }
        }
        if slot.iter().all(|s| s.is_none()) {
            if queue.is_empty() {
                break;
            }
            bail!(
                "kv_pool_bytes = {pool_bytes} cannot hold a single decode row: \
                 the queue head needs {} bytes",
                gen_need + prompt_need
            );
        }
        // ---- decode one chunk; retire rows reaching their length -------
        for entry in slot.iter_mut() {
            let Some((row, g, fl, mut d, bytes)) = *entry else { continue };
            d = (d + chunk).min(fl.max(1));
            if d >= fl.max(1) {
                out.decoded_lens[row] = fl.max(1);
                pool.free(bytes);
                if share {
                    unref(g, &mut refs, &mut pool);
                }
                *entry = None;
            } else {
                *entry = Some((row, g, fl, d, bytes));
            }
        }
    }
    if let Some(sg) = snapshot.take() {
        unref(sg, &mut refs, &mut pool);
    }
    debug_assert_eq!(pool.allocated(), 0, "pool ledger must drain");
    out.kv_peak_bytes = pool.peak();
    Ok(out)
}

/// One (share, pool, chunk) cell of the sweep.
#[derive(Debug, Clone)]
pub struct KvRow {
    /// Was prompt-KV sharing on for the cell?
    pub share: bool,
    /// Pool capacity of the cell (0 = unbounded).
    pub pool_bytes: u64,
    /// Decode chunk size of the cell.
    pub chunk: usize,
    /// Rollouts simulated (groups × n).
    pub rollouts: usize,
    /// Physical prompt-prefill calls.
    pub prefill_calls: usize,
    /// Refill runs served from the group snapshot.
    pub prefill_calls_saved: usize,
    /// Peak bytes resident in the modeled pool.
    pub kv_peak_bytes: u64,
    /// Refill events the pool gate stalled.
    pub admit_stalls: usize,
    /// Priced inference time (decode + explicit prefill charge).
    pub sim_inference: f64,
    /// Unshared-arm time over this cell's time (1.0 for unshared cells).
    pub speedup: f64,
}

impl CsvRow for KvRow {
    fn csv_header() -> &'static str {
        "share,pool_bytes,chunk,rollouts,prefill_calls,prefill_calls_saved,\
         kv_peak_bytes,admit_stalls,sim_inference,speedup"
    }

    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{},{}",
            self.share,
            self.pool_bytes,
            self.chunk,
            self.rollouts,
            self.prefill_calls,
            self.prefill_calls_saved,
            self.kv_peak_bytes,
            self.admit_stalls,
            self.sim_inference,
            self.speedup
        )
    }
}

/// The group-major row queue shared by every cell (same synthetic groups
/// as the prune study).
fn sim_queue() -> Vec<(usize, usize)> {
    let mut rows = Vec::with_capacity(GROUPS * N);
    for g in 0..GROUPS {
        let mut rng = Rng::seed_from_u64(SIM_SEED ^ g as u64);
        for r in sim_group(&mut rng, N, G) {
            rows.push((g, r.final_len));
        }
    }
    rows
}

/// Pool capacities swept: unbounded, then half and a quarter of the full
/// unshared slot demand (`SLOTS × kv_bytes(P, G)`) — enough to force
/// queuing without starving the head row.
fn pool_sweep(hw: &HwModel) -> [u64; 3] {
    let full = hw.kv_bytes(PROMPT, G) * SLOTS as u64;
    [0, full / 2, full / 4]
}

/// Build the sweep grid (row-major: share, then pool, then chunk
/// ascending). Deterministic: same queue, same pool ledger every run.
pub fn sweep(hw: &HwModel) -> Result<Vec<KvRow>> {
    let queue = sim_queue();
    let mut out = Vec::with_capacity(2 * pool_sweep(hw).len() * CHUNK_SWEEP.len());
    for share in [false, true] {
        for pool_bytes in pool_sweep(hw) {
            for &chunk in &CHUNK_SWEEP {
                let sim = simulate_admission(&queue, share, hw, pool_bytes, chunk)?;
                let sim_inference = hw.shared_prefill_inference_time(
                    &sim.decoded_lens,
                    &[],
                    chunk,
                    sim.prefill_calls,
                    PROMPT,
                );
                out.push(KvRow {
                    share,
                    pool_bytes,
                    chunk,
                    rollouts: queue.len(),
                    prefill_calls: sim.prefill_calls,
                    prefill_calls_saved: sim.prefill_calls_saved,
                    kv_peak_bytes: sim.kv_peak_bytes,
                    admit_stalls: sim.admit_stalls,
                    sim_inference,
                    speedup: 1.0,
                });
            }
        }
    }
    // speedup: the unshared cell with the same (pool, chunk) over this one
    let baseline: Vec<(u64, usize, f64)> = out
        .iter()
        .filter(|r| !r.share)
        .map(|r| (r.pool_bytes, r.chunk, r.sim_inference))
        .collect();
    for r in out.iter_mut().filter(|r| r.share) {
        let base = baseline
            .iter()
            .find(|&&(p, c, _)| p == r.pool_bytes && c == r.chunk)
            .map(|&(_, _, t)| t)
            .unwrap_or(r.sim_inference);
        r.speedup = base / r.sim_inference.max(1e-12);
    }
    Ok(out)
}

/// Run the study: write `<out_dir>/kv.csv` and print the
/// prefill-calls-saved curves (one per pool capacity) plus the cell table.
pub fn run(out_dir: &str) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let hw = HwModel::default();
    let rows = sweep(&hw)?;
    write_csv_rows(Path::new(&format!("{out_dir}/kv.csv")), &rows)?;

    let curves: Vec<(String, Vec<(f64, f64)>)> = pool_sweep(&hw)
        .iter()
        .map(|&pool| {
            let pts: Vec<(f64, f64)> = rows
                .iter()
                .filter(|r| r.share && r.pool_bytes == pool)
                .map(|r| (r.chunk as f64, r.prefill_calls_saved as f64))
                .collect();
            let label = if pool == 0 {
                "pool=unbounded".to_string()
            } else {
                format!("pool={}KiB", pool / 1024)
            };
            (label, pts)
        })
        .collect();
    let series: Vec<(&str, &[(f64, f64)])> =
        curves.iter().map(|(n, p)| (n.as_str(), p.as_slice())).collect();
    println!(
        "KV study: prefill calls saved vs decode chunk \
         (n = {N}, {GROUPS} groups, P = {PROMPT}, G = {G}, B_r = {SLOTS})"
    );
    println!("{}", ascii_plot(&series, 64, 14));
    for r in &rows {
        println!(
            "  share={:<5} pool={:>9}B C={:<3} | prefill {:>3} (saved {:>3}) \
             stalls {:>4} | kv peak {:>9}B | sim {:>8.2}s ({:.2}x)",
            r.share,
            r.pool_bytes,
            r.chunk,
            r.prefill_calls,
            r.prefill_calls_saved,
            r.admit_stalls,
            r.kv_peak_bytes,
            r.sim_inference,
            r.speedup
        );
    }
    println!(
        "  (token streams are bit-identical across every cell; only the \
         admission schedule and the prefill bill move — see \
         docs/DETERMINISM.md)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Acceptance shapes: sharing collapses prefill calls to one per
    /// group under an unbounded pool, saves refill prefills at every
    /// chunk that forces refill, and never loses time to the unshared
    /// arm priced by the same formula.
    #[test]
    fn sweep_shapes_match_the_sharing_contract() {
        let hw = HwModel::default();
        let rows = sweep(&hw).unwrap();
        assert_eq!(rows.len(), 2 * pool_sweep(&hw).len() * CHUNK_SWEEP.len());
        for r in &rows {
            if r.pool_bytes > 0 {
                assert!(
                    r.kv_peak_bytes <= r.pool_bytes,
                    "pool overflow: {r:?}"
                );
                assert!(r.admit_stalls > 0, "a bounded pool must queue: {r:?}");
            }
            if r.share {
                assert!(r.speedup >= 1.0 - 1e-9, "sharing lost time: {r:?}");
                if r.pool_bytes == 0 {
                    assert_eq!(
                        r.prefill_calls, GROUPS,
                        "unbounded shared arm must prefill once per group: {r:?}"
                    );
                    assert!(r.prefill_calls_saved > 0, "{r:?}");
                }
            } else {
                assert_eq!(r.prefill_calls_saved, 0, "{r:?}");
                assert_eq!(r.speedup, 1.0);
                assert!(
                    r.prefill_calls >= GROUPS,
                    "unshared arm refills per event: {r:?}"
                );
            }
        }
        // the shared arm never prefills more than the unshared one in the
        // same (pool, chunk) cell
        for shared in rows.iter().filter(|r| r.share) {
            let unshared = rows
                .iter()
                .find(|r| !r.share && r.pool_bytes == shared.pool_bytes && r.chunk == shared.chunk)
                .unwrap();
            assert!(shared.prefill_calls <= unshared.prefill_calls);
            assert!(shared.kv_peak_bytes <= unshared.kv_peak_bytes);
        }
    }

    /// Decoded lengths are the same in every cell: the pool gate and the
    /// snapshot path move the admission schedule, never the streams.
    #[test]
    fn decoded_lengths_are_arm_invariant() {
        let hw = HwModel::default();
        let queue = sim_queue();
        let reference =
            simulate_admission(&queue, false, &hw, 0, 16).unwrap().decoded_lens;
        for share in [false, true] {
            for pool_bytes in pool_sweep(&hw) {
                for &chunk in &CHUNK_SWEEP {
                    let got = simulate_admission(&queue, share, &hw, pool_bytes, chunk)
                        .unwrap()
                        .decoded_lens;
                    assert_eq!(
                        got, reference,
                        "share={share} pool={pool_bytes} C={chunk} moved a stream"
                    );
                }
            }
        }
    }

    /// A pool too small for one row fails loudly instead of spinning.
    #[test]
    fn starved_pool_bails_with_a_descriptive_error() {
        let hw = HwModel::default();
        let err = simulate_admission(&sim_queue(), true, &hw, 1, 16).unwrap_err();
        assert!(err.to_string().contains("kv_pool_bytes"), "{err}");
    }

    /// The sweep is deterministic call-to-call (same queue, same ledger).
    #[test]
    fn sweep_is_deterministic() {
        let hw = HwModel::default();
        let a = sweep(&hw).unwrap();
        let b = sweep(&hw).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.csv_row(), y.csv_row());
        }
    }

    #[test]
    fn kv_row_csv_shape() {
        let rows = sweep(&HwModel::default()).unwrap();
        let header_cols = KvRow::csv_header().split(',').count();
        for r in &rows {
            assert_eq!(r.csv_row().split(',').count(), header_cols, "{r:?}");
        }
    }
}
