//! Fig. 7 (§A.5) — generalization: models trained on `arith` (settings
//! (a)/(b) analogues) evaluated on the contamination-resistant platinum
//! split and on the cross-task `poly` test set at every eval point.
//! Expected shape: PODS' advantage persists across all test tracks.

use super::{CfgBuilder, Scale};
use crate::coordinator::scheduler::Trainer;
use crate::metrics::ascii_plot;
use crate::tasks::{Split, TaskKind};
use anyhow::Result;
use std::path::Path;

fn with_tracks(artifacts: &Path, cfg: crate::config::RunConfig) -> Result<Trainer> {
    let mut tr = Trainer::new(artifacts, cfg)?;
    tr.extra_evals = vec![
        (TaskKind::Arith, Split::Platinum, "platinum".to_string()),
        (TaskKind::Poly, Split::Test, "poly_test".to_string()),
    ];
    tr.run()?;
    Ok(tr)
}

/// Run the study end-to-end and write its CSV + ASCII preview.
pub fn run(artifacts: &Path, scale: Scale, out_dir: &str) -> Result<()> {
    let base_ckpt =
        super::ensure_base_checkpoint(artifacts, "arith", super::fig3::SFT_STEPS, out_dir)?;
    let iters = scale.iters(48);
    let mk = |name: &str, kind: &str, n: usize, m: Option<usize>, seed: u64, kl: f64| {
        CfgBuilder {
            name: name.into(),
            profile: "lora".into(),
            task: "arith".into(),
            seed,
            iterations: iters,
            eval_every: 4,
            eval_problems: scale.eval_problems(48),
            out_dir: out_dir.into(),
            base_checkpoint: Some(base_ckpt.clone().into()),
            kind: kind.into(),
            n,
            m,
            kl_coef: kl,
            lr: 3e-3,
            ..Default::default()
        }
        .build()
    };
    // settings (a) and (b) analogues, PODS vs vanilla GRPO
    let arms: Vec<(&str, crate::config::RunConfig)> = vec![
        ("a_pods", mk("fig7_a_pods", "pods", 64, Some(16), 0, 0.0)?),
        ("a_grpo", mk("fig7_a_grpo", "grpo", 16, None, 0, 0.0)?),
        ("b_pods", mk("fig7_b_pods", "pods", 64, Some(16), 1, 0.04)?),
        ("b_grpo", mk("fig7_b_grpo", "grpo", 16, None, 1, 0.04)?),
    ];
    let mut results = Vec::new();
    for (label, cfg) in arms {
        let tr = with_tracks(artifacts, cfg)?;
        results.push((label, tr));
    }
    for track in ["test", "platinum", "poly_test"] {
        let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
        for (label, tr) in &results {
            let curve: Vec<(f64, f64)> = tr
                .recorder
                .evals
                .iter()
                .filter(|e| e.split == track)
                .map(|e| (e.sim_time, e.accuracy as f64))
                .collect();
            if !curve.is_empty() {
                series.push((label.to_string(), curve));
            }
        }
        let plots: Vec<(&str, &[(f64, f64)])> =
            series.iter().map(|(n, c)| (n.as_str(), c.as_slice())).collect();
        println!("Fig.7 [{track}]: accuracy vs sim time");
        println!("{}", ascii_plot(&plots, 64, 12));
    }
    Ok(())
}
