//! Shard study — the compute/communication trade-off of the sharded
//! update engine across `shards × m`.
//!
//! Not a paper figure: this driver maps the PODS n→m down-sampling claim
//! onto the update-cost axis the `[update]` section exposes. For every
//! (shards, m) cell it prices one update phase with
//! [`HwModel::update_cost`] — sequential micro-steps on the busiest
//! shard, one ring all-reduce over the simulated gradient bytes, one
//! optimizer apply — entirely from the cost model, so it runs without
//! artifacts.
//!
//! Two shapes must reproduce (asserted by this module's tests):
//!
//! * at fixed shards, simulated update time **strictly decreases** as
//!   selection keeps fewer rollouts (the paper's reason to down-sample);
//! * at fixed m, the communication term **strictly grows** with the
//!   shard count (the reason sharding saturates: `2(S-1)/S` volume plus
//!   per-hop latency).

use crate::hwsim::HwModel;
use crate::metrics::{ascii_plot, write_csv_rows, CsvRow};
use anyhow::Result;
use std::path::Path;

/// Rollouts generated per prompt in the study (the paper's default n).
const N_FULL: usize = 64;
/// Update sizes swept, descending — n (GRPO-GA) down to aggressive PODS.
const M_SWEEP: [usize; 5] = [64, 48, 32, 16, 8];
/// Shard counts swept (1 = the monolithic single-device update).
const SHARD_SWEEP: [usize; 4] = [1, 2, 4, 8];
/// Rows per update micro-batch used for every cell.
const MICRO_BATCH: usize = 8;

/// One (shards, m) cell of the sweep.
#[derive(Debug, Clone)]
pub struct ShardRow {
    /// Simulated data-parallel shard count.
    pub shards: usize,
    /// Rollouts the update trains on.
    pub m: usize,
    /// Rows per micro-batch.
    pub micro_batch: usize,
    /// Micro-steps on the busiest shard.
    pub steps: usize,
    /// Sequential compute on the busiest shard (sim seconds).
    pub upd_compute: f64,
    /// Ring all-reduce time (sim seconds).
    pub upd_comm: f64,
    /// Total phase time incl. optimizer apply (sim seconds).
    pub upd_total: f64,
    /// Peak rollouts resident per shard in one micro-step.
    pub upd_peak_mem: usize,
}

impl CsvRow for ShardRow {
    fn csv_header() -> &'static str {
        "shards,m,micro_batch,steps,upd_compute,upd_comm,upd_total,upd_peak_mem"
    }

    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{}",
            self.shards,
            self.m,
            self.micro_batch,
            self.steps,
            self.upd_compute,
            self.upd_comm,
            self.upd_total,
            self.upd_peak_mem
        )
    }
}

/// Build the sweep grid from a cost model (row-major: shards, then m
/// descending).
pub fn sweep(hw: &HwModel) -> Vec<ShardRow> {
    let mut rows = Vec::with_capacity(SHARD_SWEEP.len() * M_SWEEP.len());
    for &shards in &SHARD_SWEEP {
        for &m in &M_SWEEP {
            let c = hw.update_cost(m, shards, MICRO_BATCH, false);
            rows.push(ShardRow {
                shards,
                m,
                micro_batch: MICRO_BATCH,
                steps: c.steps,
                upd_compute: c.compute,
                upd_comm: c.comm,
                upd_total: c.total,
                upd_peak_mem: c.peak_mem_rollouts,
            });
        }
    }
    rows
}

/// Run the study: write `<out_dir>/shard.csv` and print the trade-off
/// curves (update time vs m, one curve per shard count).
pub fn run(out_dir: &str) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let hw = HwModel::default();
    let rows = sweep(&hw);
    write_csv_rows(Path::new(&format!("{out_dir}/shard.csv")), &rows)?;

    let curves: Vec<(String, Vec<(f64, f64)>)> = SHARD_SWEEP
        .iter()
        .map(|&s| {
            let pts: Vec<(f64, f64)> = rows
                .iter()
                .filter(|r| r.shards == s)
                .map(|r| (r.m as f64, r.upd_total))
                .collect();
            (format!("S={s}"), pts)
        })
        .collect();
    let series: Vec<(&str, &[(f64, f64)])> =
        curves.iter().map(|(n, p)| (n.as_str(), p.as_slice())).collect();
    println!(
        "Shard study: simulated update time vs kept rollouts m \
         (n = {N_FULL}, micro_batch = {MICRO_BATCH})"
    );
    println!("{}", ascii_plot(&series, 64, 14));
    for &s in &SHARD_SWEEP {
        let at =
            |m: usize| rows.iter().find(|r| r.shards == s && r.m == m).expect("swept").upd_total;
        println!(
            "  S={s}: GA m={N_FULL} {:>6.2}s | PODS m=16 {:>6.2}s ({:.2}x) | comm {:>6.3}s",
            at(N_FULL),
            at(16),
            at(N_FULL) / at(16).max(1e-9),
            rows.iter().find(|r| r.shards == s && r.m == 16).expect("swept").upd_comm,
        );
    }
    println!(
        "  (communication grows with shards while compute shrinks — the \
         crossover is why data-parallel updates saturate)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Acceptance: simulated update time strictly decreases in m at fixed
    /// shards, and communication time strictly grows with shards.
    #[test]
    fn sweep_shapes_match_the_papers_claims() {
        let rows = sweep(&HwModel::default());
        assert_eq!(rows.len(), SHARD_SWEEP.len() * M_SWEEP.len());
        for &s in &SHARD_SWEEP {
            let totals: Vec<f64> = rows.iter().filter(|r| r.shards == s).map(|r| r.upd_total).collect();
            // M_SWEEP is descending, so totals must strictly descend too
            for w in totals.windows(2) {
                assert!(
                    w[1] < w[0],
                    "update time not strictly decreasing in m at shards={s}: {totals:?}"
                );
            }
        }
        for &m in &M_SWEEP {
            let comms: Vec<f64> = rows.iter().filter(|r| r.m == m).map(|r| r.upd_comm).collect();
            for w in comms.windows(2) {
                assert!(w[1] > w[0], "comm not strictly growing with shards at m={m}: {comms:?}");
            }
        }
        // the peak-memory column reports the micro-batch (capped by rows)
        for r in &rows {
            assert!(r.upd_peak_mem <= MICRO_BATCH);
            assert!(r.upd_peak_mem >= 1);
        }
    }

    /// The CSV schema round-trips with matching column counts.
    #[test]
    fn shard_row_csv_shape() {
        let rows = sweep(&HwModel::default());
        let header_cols = ShardRow::csv_header().split(',').count();
        for r in &rows {
            assert_eq!(r.csv_row().split(',').count(), header_cols);
        }
    }
}
