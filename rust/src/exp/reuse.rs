//! Reuse study — what cross-iteration rollout replay buys, swept over
//! `mix_fraction × staleness`.
//!
//! Not a paper figure: this driver quantifies the `[replay]` section. It
//! runs entirely on the cost model (no artifacts): the same deterministic
//! synthetic prompt groups as the prune study are selected by the real
//! pipeline, and the real [`ReplayStore`] is driven exactly like the
//! executor drives it (evict → draw → offer, draw-then-offer so every
//! replayed row is at least one iteration stale). Each cell prices the
//! run with [`HwModel`] and reports **generated tokens per accuracy
//! point**: replayed rows add learning signal (staleness-discounted
//! |advantage|, the importance correction biting harder on staler rows)
//! at zero inference cost, so reuse lowers the token bill per point of
//! learning — the headline number `results/reuse.csv` pins against the
//! no-reuse baseline.

use crate::config::ReplaySection;
use crate::coordinator::advantage::NormMode;
use crate::coordinator::group::{build_update_batch, PromptGroup};
use crate::coordinator::replay::ReplayStore;
use crate::coordinator::select::Pipeline;
use crate::exp::prune::sim_group;
use crate::hwsim::HwModel;
use crate::metrics::{ascii_plot, write_csv_rows, CsvRow};
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

/// Rollouts generated per prompt (the paper's default n).
const N: usize = 64;
/// Update size after down-sampling.
const M: usize = 16;
/// Prompt groups per simulated iteration.
const GROUPS: usize = 4;
/// Generation budget G of the simulated profile.
const G: usize = 64;
/// Simulated training iterations per cell.
const ITERS: usize = 12;
/// Decode chunk the inference phase is priced at.
const CHUNK: usize = 16;
/// Replay quotas swept (fraction of fresh update rows).
const MIX_SWEEP: [f64; 3] = [0.125, 0.25, 0.5];
/// Staleness bounds swept (iterations a stored row stays eligible).
const STALENESS_SWEEP: [usize; 3] = [1, 2, 4];
/// Per-iteration learning-signal discount for replayed rows: the
/// truncated importance correction shrinks what a stale row can teach.
const STALE_DECAY: f64 = 0.7;
/// Seed of the deterministic synthetic groups (shared with the prune
/// study so the two cost-model worlds agree).
const SIM_SEED: u64 = 0x9E37_79B9_7F4A_7C15;

/// One `(mix_fraction, staleness)` cell of the sweep. The first CSV row
/// is the no-reuse baseline (`mix_fraction = 0`).
#[derive(Debug, Clone)]
pub struct ReuseRow {
    /// Replay quota as a fraction of fresh rows (0 = baseline).
    pub mix_fraction: f64,
    /// Staleness bound in iterations (0 on the baseline row).
    pub staleness: usize,
    /// Fresh rollouts trained across the run (selection output).
    pub rollouts_fresh: usize,
    /// Stored rows replayed into updates across the run.
    pub rows_replayed: usize,
    /// Replay-store population after the final iteration.
    pub store_size_final: usize,
    /// Generated tokens across the run (identical in every cell: replay
    /// never generates).
    pub gen_tokens: usize,
    /// Simulated inference time across the run.
    pub sim_inference: f64,
    /// Simulated update time across the run (replayed rows charge here
    /// in full).
    pub sim_update: f64,
    /// Accumulated learning signal (|advantage|, staleness-discounted
    /// for replayed rows).
    pub signal: f64,
    /// `gen_tokens / signal` — the headline cost of learning.
    pub tokens_per_point: f64,
    /// `tokens_per_point / baseline tokens_per_point` (1.0 on the
    /// baseline row; `< 1` means reuse beats no-reuse).
    pub vs_baseline: f64,
}

impl CsvRow for ReuseRow {
    fn csv_header() -> &'static str {
        "mix_fraction,staleness,rollouts_fresh,rows_replayed,store_size_final,\
         gen_tokens,sim_inference,sim_update,signal,tokens_per_point,vs_baseline"
    }

    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{},{},{}",
            self.mix_fraction,
            self.staleness,
            self.rollouts_fresh,
            self.rows_replayed,
            self.store_size_final,
            self.gen_tokens,
            self.sim_inference,
            self.sim_update,
            self.signal,
            self.tokens_per_point,
            self.vs_baseline
        )
    }
}

/// The deterministic synthetic groups for one iteration — identical in
/// every cell (seeded by `(iter, group)` only), so cells differ purely
/// in how they reuse, never in what was generated.
fn iter_groups(iter: usize) -> (Vec<PromptGroup>, Vec<usize>) {
    let mut groups = Vec::with_capacity(GROUPS);
    let mut lens = Vec::with_capacity(GROUPS * N);
    for g in 0..GROUPS {
        let mut rng = Rng::seed_from_u64(SIM_SEED ^ (iter as u64 * GROUPS as u64 + g as u64));
        let rows = sim_group(&mut rng, N, G);
        let rewards: Vec<f32> = rows.iter().map(|r| r.final_reward).collect();
        let glens: Vec<i32> = rows.iter().map(|r| r.final_len as i32).collect();
        lens.extend(rows.iter().map(|r| r.final_len));
        groups.push(PromptGroup::synthetic(g as u64, &rewards, Some(&glens)));
    }
    (groups, lens)
}

/// Run one `(mix_fraction, staleness)` cell: `ITERS` iterations of
/// select → evict → draw → offer, priced on the cost model.
fn run_cell(hw: &HwModel, pipeline: &Pipeline, mix_fraction: f64, staleness: usize) -> ReuseRow {
    let cfg = ReplaySection {
        enabled: mix_fraction > 0.0,
        mix_fraction,
        staleness: staleness.max(1),
        capacity_per_prompt: ReplaySection::default().capacity_per_prompt,
        rho_max: ReplaySection::default().rho_max,
    };
    let mut store = ReplayStore::new();
    let mut row = ReuseRow {
        mix_fraction,
        staleness,
        rollouts_fresh: 0,
        rows_replayed: 0,
        store_size_final: 0,
        gen_tokens: 0,
        sim_inference: 0.0,
        sim_update: 0.0,
        signal: 0.0,
        tokens_per_point: 0.0,
        vs_baseline: 1.0,
    };
    for iter in 0..ITERS {
        let (groups, lens) = iter_groups(iter);
        row.gen_tokens += lens.iter().sum::<usize>();
        row.sim_inference += hw.chunked_inference_time(&lens, CHUNK);
        let (selected, _) =
            build_update_batch(&groups, pipeline, Some(M), NormMode::After, 0, iter as u64)
                .expect("synthetic selection");
        // the executor's draw-then-offer ordering (exec::TrainLoop)
        let drawn = if cfg.enabled {
            store.evict_stale(iter as u64, cfg.staleness);
            let quota = ReplayStore::quota(selected.len(), cfg.mix_fraction);
            let drawn = store.draw(quota);
            store.offer(iter as u64, &groups, &selected, &cfg);
            drawn
        } else {
            Vec::new()
        };
        row.rollouts_fresh += selected.len();
        row.rows_replayed += drawn.len();
        for s in &selected {
            row.signal += s.advantage.abs() as f64;
        }
        for d in &drawn {
            let stale = (iter as u64).saturating_sub(d.id.iter);
            row.signal += d.advantage.abs() as f64 * STALE_DECAY.powi(stale as i32);
        }
        // replayed rows generate nothing but pay the update phase in full
        let m = selected.len() + drawn.len();
        row.sim_update += hw.update_cost(m, 1, 8, false).total;
    }
    row.store_size_final = store.len();
    row.tokens_per_point = row.gen_tokens as f64 / row.signal.max(1e-12);
    row
}

/// Build the sweep: the no-reuse baseline row first, then the
/// `mix_fraction × staleness` grid (row-major: mix, then staleness
/// ascending). Deterministic end to end.
pub fn sweep(hw: &HwModel) -> Result<Vec<ReuseRow>> {
    let pipeline = Pipeline::parse_default("max_variance")?;
    let mut baseline = run_cell(hw, &pipeline, 0.0, 0);
    let base_tpp = baseline.tokens_per_point;
    baseline.vs_baseline = 1.0;
    let mut out = vec![baseline];
    for &mix in &MIX_SWEEP {
        for &staleness in &STALENESS_SWEEP {
            let mut cell = run_cell(hw, &pipeline, mix, staleness);
            cell.vs_baseline = cell.tokens_per_point / base_tpp.max(1e-12);
            out.push(cell);
        }
    }
    Ok(out)
}

/// Run the study: write `<out_dir>/reuse.csv` and print the
/// tokens-per-accuracy-point curves (one per staleness bound) plus the
/// per-cell table against the no-reuse baseline.
pub fn run(out_dir: &str) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let hw = HwModel::default();
    let rows = sweep(&hw)?;
    write_csv_rows(Path::new(&format!("{out_dir}/reuse.csv")), &rows)?;

    let curves: Vec<(String, Vec<(f64, f64)>)> = STALENESS_SWEEP
        .iter()
        .map(|&s| {
            let pts: Vec<(f64, f64)> = rows
                .iter()
                .filter(|r| r.staleness == s && r.mix_fraction > 0.0)
                .map(|r| (r.mix_fraction, r.tokens_per_point))
                .collect();
            (format!("staleness={s}"), pts)
        })
        .collect();
    let series: Vec<(&str, &[(f64, f64)])> =
        curves.iter().map(|(n, p)| (n.as_str(), p.as_slice())).collect();
    println!(
        "Reuse study: generated tokens per accuracy point vs replay mix \
         (n = {N} -> m = {M}, {GROUPS} groups, {ITERS} iters)"
    );
    println!("{}", ascii_plot(&series, 64, 14));
    for r in &rows {
        println!(
            "  mix={:<5} staleness={} fresh {:>4} replayed {:>4} | tokens {:>6} \
             | sim inf {:>7.2}s upd {:>6.2}s | tok/pt {:>8.2} ({:.3}x baseline)",
            r.mix_fraction,
            r.staleness,
            r.rollouts_fresh,
            r.rows_replayed,
            r.gen_tokens,
            r.sim_inference,
            r.sim_update,
            r.tokens_per_point,
            r.vs_baseline
        );
    }
    println!(
        "  (replayed rows charge zero inference and full update cost; the \
         store's evolution is schedule-invariant — see docs/DETERMINISM.md)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Acceptance shape: every reuse cell replays rows, and at least one
    /// (in fact every) cell's tokens-per-accuracy-point lands strictly
    /// below the no-reuse baseline.
    #[test]
    fn reuse_beats_the_no_reuse_baseline() {
        let rows = sweep(&HwModel::default()).unwrap();
        assert_eq!(rows.len(), 1 + MIX_SWEEP.len() * STALENESS_SWEEP.len());
        let base = &rows[0];
        assert_eq!(base.mix_fraction, 0.0);
        assert_eq!(base.rows_replayed, 0);
        assert!(base.signal > 0.0, "baseline accumulated no signal");
        assert_eq!(base.vs_baseline, 1.0);
        let mut beat_baseline = 0usize;
        for r in &rows[1..] {
            assert_eq!(r.gen_tokens, base.gen_tokens, "replay must not generate tokens");
            assert!(r.rows_replayed > 0, "cell replayed nothing: {r:?}");
            assert!(r.sim_update > base.sim_update, "replay rows must charge update time");
            if r.tokens_per_point < base.tokens_per_point {
                assert!(r.vs_baseline < 1.0);
                beat_baseline += 1;
            }
        }
        assert_eq!(
            beat_baseline,
            rows.len() - 1,
            "every reuse cell should beat the baseline on tokens/point"
        );
    }

    /// A larger mix quota never replays fewer rows at the same staleness
    /// bound (the store refills to capacity every iteration).
    #[test]
    fn replayed_rows_monotone_in_mix_fraction() {
        let rows = sweep(&HwModel::default()).unwrap();
        for &s in &STALENESS_SWEEP {
            let by_mix: Vec<usize> = MIX_SWEEP
                .iter()
                .map(|&m| {
                    rows.iter()
                        .find(|r| r.mix_fraction == m && r.staleness == s)
                        .unwrap()
                        .rows_replayed
                })
                .collect();
            for w in by_mix.windows(2) {
                assert!(w[1] >= w[0], "staleness {s}: rows_replayed {by_mix:?} not monotone");
            }
        }
    }

    /// The sweep is a pure function: two runs emit identical CSV lines.
    #[test]
    fn sweep_is_deterministic() {
        let hw = HwModel::default();
        let a: Vec<String> = sweep(&hw).unwrap().iter().map(|r| r.csv_row()).collect();
        let b: Vec<String> = sweep(&hw).unwrap().iter().map(|r| r.csv_row()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn reuse_row_csv_shape() {
        let rows = sweep(&HwModel::default()).unwrap();
        let header_cols = ReuseRow::csv_header().split(',').count();
        for r in &rows {
            assert_eq!(r.csv_row().split(',').count(), header_cols, "{r:?}");
        }
    }
}
