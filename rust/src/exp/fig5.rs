//! Fig. 5 — selection-pipeline comparison on setting (a):
//! the paper's four rules (max-variance vs max-reward vs random vs
//! percentile) plus two context-aware pipelines from the selector
//! registry (zero-signal-group filtering and length-aware pruning).
//! Expected shape: max-variance on top throughout; max-reward degrades
//! (no negative feedback); the filtered/pruned pipelines track
//! max-variance while spending fewer update tokens.

use super::{peak_accuracy, run_config, CfgBuilder, Scale};
use crate::metrics::CsvRow;
use crate::metrics::{ascii_plot, write_csv_rows};
use anyhow::Result;
use std::path::Path;

/// The pipelines Fig. 5 compares. The first four are the paper's rules;
/// the last two exercise the composable selector API end-to-end.
pub const SPECS: &[&str] = &[
    "max_variance",
    "max_reward",
    "random",
    "percentile",
    "drop_zero_variance | max_variance",
    "prune(quantile=0.75) | max_variance",
];

/// File-system-safe tag for a pipeline spec (run names, CSV fields).
pub fn spec_slug(spec: &str) -> String {
    let mut out = String::with_capacity(spec.len());
    for c in spec.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c);
        } else if !out.ends_with('_') {
            out.push('_');
        }
    }
    out.trim_matches('_').to_string()
}

#[derive(Debug)]
struct RuleRow {
    rule: String,
    peak_acc: f32,
    final_acc: f32,
    mean_sel_variance: f64,
    /// Fraction of generated tokens that selection dropped before the
    /// update phase (the compute the pipeline saved).
    tokens_dropped_frac: f64,
    /// Total prompt groups dropped as zero-signal over the run.
    groups_dropped: usize,
}

impl CsvRow for RuleRow {
    fn csv_header() -> &'static str {
        "rule,peak_acc,final_acc,mean_sel_variance,tokens_dropped_frac,groups_dropped"
    }
    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{}",
            self.rule,
            self.peak_acc,
            self.final_acc,
            self.mean_sel_variance,
            self.tokens_dropped_frac,
            self.groups_dropped
        )
    }
}

/// Run the study end-to-end and write its CSV + ASCII preview.
pub fn run(artifacts: &Path, scale: Scale, out_dir: &str) -> Result<()> {
    let base_ckpt =
        super::ensure_base_checkpoint(artifacts, "arith", super::fig3::SFT_STEPS, out_dir)?;
    let iters = scale.iters(48);
    let mut rows = Vec::new();
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for spec in SPECS {
        let slug = spec_slug(spec);
        let cfg = CfgBuilder {
            name: format!("fig5_{slug}"),
            profile: "lora".into(),
            task: "arith".into(),
            iterations: iters,
            eval_every: 4,
            eval_problems: scale.eval_problems(48),
            out_dir: out_dir.into(),
            base_checkpoint: Some(base_ckpt.clone()),
            kind: "pods".into(),
            n: 64,
            m: Some(16),
            rule: spec.to_string(),
            lr: 3e-3,
            ..Default::default()
        }
        .build()?;
        let tr = run_config(artifacts, cfg)?;
        let curve: Vec<(f64, f64)> = tr
            .recorder
            .evals
            .iter()
            .filter(|e| e.split == "test")
            .map(|e| (e.sim_time, e.accuracy as f64))
            .collect();
        let iters_n = tr.recorder.iters.len().max(1) as f64;
        let mean_var = tr.recorder.iters.iter().map(|i| i.sel_variance).sum::<f64>() / iters_n;
        let kept: usize = tr.recorder.iters.iter().map(|i| i.sel_tokens_kept).sum();
        let dropped: usize = tr.recorder.iters.iter().map(|i| i.sel_tokens_dropped).sum();
        rows.push(RuleRow {
            rule: slug.clone(),
            peak_acc: peak_accuracy(&tr.recorder.evals),
            final_acc: tr.recorder.last_eval_accuracy("test").unwrap_or(0.0),
            mean_sel_variance: mean_var,
            tokens_dropped_frac: dropped as f64 / (kept + dropped).max(1) as f64,
            groups_dropped: tr.recorder.iters.iter().map(|i| i.sel_groups_dropped).sum(),
        });
        series.push((spec.to_string(), curve));
    }
    write_csv_rows(Path::new(&format!("{out_dir}/fig5.csv")), &rows)?;
    let plots: Vec<(&str, &[(f64, f64)])> =
        series.iter().map(|(n, c)| (n.as_str(), c.as_slice())).collect();
    println!("Fig.5: accuracy vs sim time by selection pipeline");
    println!("{}", ascii_plot(&plots, 64, 14));
    for (spec, r) in SPECS.iter().zip(&rows) {
        println!(
            "  {:<38} peak {:.3} final {:.3} sel-variance {:.3} tokens-dropped {:.1}% groups-dropped {}",
            spec,
            r.peak_acc,
            r.final_acc,
            r.mean_sel_variance,
            100.0 * r.tokens_dropped_frac,
            r.groups_dropped
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slugs_are_fs_safe_and_distinct() {
        let slugs: Vec<String> = SPECS.iter().map(|s| spec_slug(s)).collect();
        for s in &slugs {
            assert!(!s.is_empty());
            assert!(s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'), "{s}");
        }
        let set: std::collections::HashSet<&String> = slugs.iter().collect();
        assert_eq!(set.len(), slugs.len(), "slug collision: {slugs:?}");
        assert_eq!(spec_slug("prune(quantile=0.75) | max_variance"), "prune_quantile_0_75_max_variance");
    }

    #[test]
    fn all_fig5_specs_parse() {
        for spec in SPECS {
            crate::coordinator::select::Pipeline::parse_default(spec)
                .unwrap_or_else(|e| panic!("fig5 spec {spec:?} invalid: {e}"));
        }
    }
}
