//! Fig. 5 — down-sampling rule comparison on setting (a):
//! max-variance vs max-reward vs random vs percentile.
//! Expected shape: max-variance on top throughout; max-reward degrades
//! (no negative feedback).

use super::{peak_accuracy, run_config, CfgBuilder, Scale};
use crate::metrics::{ascii_plot, write_csv_rows};
use crate::metrics::CsvRow;
use anyhow::Result;
use std::path::Path;

#[derive(Debug)]
struct RuleRow {
    rule: String,
    peak_acc: f32,
    final_acc: f32,
    mean_sel_variance: f64,
}

impl CsvRow for RuleRow {
    fn csv_header() -> &'static str {
        "rule,peak_acc,final_acc,mean_sel_variance"
    }
    fn csv_row(&self) -> String {
        format!("{},{},{},{}", self.rule, self.peak_acc, self.final_acc, self.mean_sel_variance)
    }
}

pub fn run(artifacts: &Path, scale: Scale, out_dir: &str) -> Result<()> {
    let base_ckpt =
        super::ensure_base_checkpoint(artifacts, "arith", super::fig3::SFT_STEPS, out_dir)?;
    let iters = scale.iters(48);
    let mut rows = Vec::new();
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for rule in ["max_variance", "max_reward", "random", "percentile"] {
        let cfg = CfgBuilder {
            name: format!("fig5_{rule}"),
            profile: "lora".into(),
            task: "arith".into(),
            iterations: iters,
            eval_every: 4,
            eval_problems: scale.eval_problems(48),
            out_dir: out_dir.into(),
            base_checkpoint: Some(base_ckpt.clone().into()),
            kind: "pods".into(),
            n: 64,
            m: Some(16),
            rule: rule.into(),
            lr: 3e-3,
            ..Default::default()
        }
        .build()?;
        let tr = run_config(artifacts, cfg)?;
        let curve: Vec<(f64, f64)> = tr
            .recorder
            .evals
            .iter()
            .filter(|e| e.split == "test")
            .map(|e| (e.sim_time, e.accuracy as f64))
            .collect();
        let mean_var = tr.recorder.iters.iter().map(|i| i.sel_variance).sum::<f64>()
            / tr.recorder.iters.len().max(1) as f64;
        rows.push(RuleRow {
            rule: rule.into(),
            peak_acc: peak_accuracy(&tr.recorder.evals),
            final_acc: tr.recorder.last_eval_accuracy("test").unwrap_or(0.0),
            mean_sel_variance: mean_var,
        });
        series.push((rule.to_string(), curve));
    }
    write_csv_rows(Path::new(&format!("{out_dir}/fig5.csv")), &rows)?;
    let plots: Vec<(&str, &[(f64, f64)])> =
        series.iter().map(|(n, c)| (n.as_str(), c.as_slice())).collect();
    println!("Fig.5: accuracy vs sim time by down-sampling rule");
    println!("{}", ascii_plot(&plots, 64, 14));
    for r in &rows {
        println!(
            "  {:<13} peak {:.3} final {:.3} mean selected-batch reward variance {:.3}",
            r.rule, r.peak_acc, r.final_acc, r.mean_sel_variance
        );
    }
    Ok(())
}
