//! Fig. 6 (§A.3) — advantage-normalization ablation: statistics computed on
//! the down-sampled batch ("after", the paper's default — every update
//! batch is zero-mean) vs on the full rollout group ("before").

use super::{peak_accuracy, run_config, CfgBuilder, Scale};
use crate::metrics::{ascii_plot, write_csv_rows};
use crate::metrics::CsvRow;
use anyhow::Result;
use std::path::Path;

#[derive(Debug)]
struct NormRow {
    adv_norm: String,
    peak_acc: f32,
    final_acc: f32,
}

impl CsvRow for NormRow {
    fn csv_header() -> &'static str {
        "adv_norm,peak_acc,final_acc"
    }
    fn csv_row(&self) -> String {
        format!("{},{},{}", self.adv_norm, self.peak_acc, self.final_acc)
    }
}

/// Run the study end-to-end and write its CSV + ASCII preview.
pub fn run(artifacts: &Path, scale: Scale, out_dir: &str) -> Result<()> {
    let base_ckpt =
        super::ensure_base_checkpoint(artifacts, "arith", super::fig3::SFT_STEPS, out_dir)?;
    let iters = scale.iters(48);
    let mut rows = Vec::new();
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for mode in ["after", "before"] {
        let cfg = CfgBuilder {
            name: format!("fig6_{mode}"),
            profile: "lora".into(),
            task: "arith".into(),
            iterations: iters,
            eval_every: 4,
            eval_problems: scale.eval_problems(48),
            out_dir: out_dir.into(),
            base_checkpoint: Some(base_ckpt.clone().into()),
            kind: "pods".into(),
            n: 64,
            m: Some(16),
            adv_norm: mode.into(),
            lr: 3e-3,
            ..Default::default()
        }
        .build()?;
        let tr = run_config(artifacts, cfg)?;
        let curve: Vec<(f64, f64)> = tr
            .recorder
            .evals
            .iter()
            .filter(|e| e.split == "test")
            .map(|e| (e.sim_time, e.accuracy as f64))
            .collect();
        rows.push(NormRow {
            adv_norm: mode.into(),
            peak_acc: peak_accuracy(&tr.recorder.evals),
            final_acc: tr.recorder.last_eval_accuracy("test").unwrap_or(0.0),
        });
        series.push((mode.to_string(), curve));
    }
    write_csv_rows(Path::new(&format!("{out_dir}/fig6.csv")), &rows)?;
    let plots: Vec<(&str, &[(f64, f64)])> =
        series.iter().map(|(n, c)| (n.as_str(), c.as_slice())).collect();
    println!("Fig.6: advantage normalization After vs Before");
    println!("{}", ascii_plot(&plots, 64, 12));
    Ok(())
}
