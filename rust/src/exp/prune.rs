//! Prune study — tokens saved and wall-clock recovered by online
//! selection-aware rollout pruning, swept over `decode_chunk × pipeline`.
//!
//! Not a paper figure: this driver quantifies what `[rollout]
//! online_prune` buys. It runs entirely on the cost model (no artifacts):
//! synthetic prompt groups with deterministic reward/length distributions
//! are decoded by a simulated chunk loop that consults the real
//! [`OnlineSelector`] analysis at every boundary — rows it dooms abort
//! with their decoded-so-far length, exactly like the chunked driver. For
//! each cell the study reports the generated-token bill with and without
//! pruning and prices both with [`HwModel::chunked_inference_time`] /
//! [`HwModel::pruned_inference_time`].
//!
//! Two shapes must reproduce (asserted by this module's tests):
//!
//! * pipelines with a token-budget stage (`prune(max_tokens=K) | …`) save
//!   tokens — the doom-only contract still recovers most of the decode
//!   spend on over-long rollouts;
//! * pipelines of only opaque stages save exactly nothing (never prune
//!   speculatively), and the post-hoc selection over the pruned groups is
//!   identical to selection over the fully-decoded ones.

use crate::coordinator::select::online::OnlineSelector;
use crate::coordinator::select::Pipeline;
use crate::hwsim::HwModel;
use crate::metrics::{ascii_plot, write_csv_rows, CsvRow};
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

/// Rollouts generated per prompt (the paper's default n).
const N: usize = 64;
/// Update size after down-sampling.
const M: usize = 16;
/// Prompt groups per simulated iteration.
const GROUPS: usize = 4;
/// Generation budget G of the simulated profile.
const G: usize = 64;
/// Decode chunk sizes swept (the artifact set's lowered programs).
const CHUNK_SWEEP: [usize; 4] = [1, 4, 16, 64];
/// Pipelines swept: token-budget stages at two caps, plus the bare
/// exact stage and an opaque baseline that must never prune.
const PIPELINES: [&str; 4] = [
    "prune(max_tokens=16) | max_variance",
    "prune(max_tokens=32) | max_variance",
    "max_variance",
    "percentile",
];
/// Reward bracket of the rule-based reward model under default weights.
const RMAX: f32 = 3.0;
/// Seed of the deterministic synthetic groups (per-group streams derive
/// from it by XOR with the group index).
const SIM_SEED: u64 = 0x9E37_79B9_7F4A_7C15;

/// One synthetic rollout row: what a full decode would produce.
#[derive(Debug, Clone, Copy)]
pub struct SimRow {
    /// Generated length of the fully-decoded rollout (tokens incl. EOS).
    pub final_len: usize,
    /// Total reward of the fully-decoded rollout (0.25-grid, `[0, 3]`).
    pub final_reward: f32,
}

/// Outcome of simulating one group's generation under online pruning.
#[derive(Debug, Clone)]
pub struct SimGroupOut {
    /// Per row: decoded length when the loop ended (final length for
    /// finished rows, the abort boundary for pruned ones).
    pub decoded_len: Vec<usize>,
    /// Per row: was the row aborted by a doom verdict?
    pub aborted: Vec<bool>,
}

/// Deterministic synthetic group: a mix of short confident finishers and
/// long low-signal tails, rewards on the 0.25 grid with the usual
/// bimodal (solved / unsolved) mass.
pub fn sim_group(rng: &mut Rng, n: usize, budget: usize) -> Vec<SimRow> {
    (0..n)
        .map(|_| {
            let long_tail = rng.gen_bool(0.4);
            let final_len = if long_tail {
                // tail rollouts ramble to (or near) the budget
                (budget / 2 + rng.below(budget / 2 + 1)).min(budget)
            } else {
                1 + rng.below(budget / 4)
            };
            let final_reward = if long_tail {
                // long rollouts rarely score: mostly 0, sometimes partial
                if rng.gen_bool(0.8) { 0.0 } else { 0.25 * (1 + rng.below(4)) as f32 }
            } else if rng.gen_bool(0.5) {
                RMAX // clean solve: accuracy + format + tags
            } else {
                0.25 * rng.below(8) as f32
            };
            SimRow { final_len, final_reward }
        })
        .collect()
}

/// Simulate one group's chunked decode against the online analysis:
/// every live row advances `chunk` tokens per boundary; rows reaching
/// their final length retire (observing their true reward); every
/// boundary the live rows are polled and doomed ones abort.
pub fn simulate_group(rows: &[SimRow], pipeline: &Pipeline, m: usize, chunk: usize) -> SimGroupOut {
    let n = rows.len();
    let mut sel = OnlineSelector::new(pipeline.stage_bounds(), n, m, 0.0, RMAX);
    let mut decoded = vec![0usize; n];
    let mut live = vec![true; n];
    let chunk = chunk.max(1);
    let mut aborted = vec![false; n];
    while live.iter().any(|&l| l) {
        // advance one chunk, retiring rows that reach their final length
        for i in 0..n {
            if !live[i] {
                continue;
            }
            decoded[i] = (decoded[i] + chunk).min(rows[i].final_len.max(1));
            if decoded[i] >= rows[i].final_len.max(1) {
                live[i] = false;
                sel.observe_finished(i, rows[i].final_reward, rows[i].final_len);
            }
        }
        // boundary: poll verdicts, abort doomed rows
        for i in 0..n {
            if !live[i] {
                continue;
            }
            sel.observe_len(i, decoded[i]);
            sel.poll();
            if sel.verdict(i) == crate::coordinator::select::Verdict::Doomed {
                live[i] = false;
                aborted[i] = true;
            }
        }
    }
    SimGroupOut { decoded_len: decoded, aborted }
}

/// One (chunk, pipeline) cell of the sweep.
#[derive(Debug, Clone)]
pub struct PruneRow {
    /// Decode chunk size of the cell.
    pub chunk: usize,
    /// Pipeline spec of the cell.
    pub pipeline: String,
    /// Rollouts simulated (groups × n).
    pub rollouts: usize,
    /// Rollouts aborted by doom verdicts.
    pub rows_pruned: usize,
    /// Generated-token bill without pruning (per-rollout ceil-to-chunk).
    pub gen_tokens_full: usize,
    /// Generated-token bill with pruning (aborted rows at their truncated
    /// lengths).
    pub gen_tokens_pruned_run: usize,
    /// `gen_tokens_full - gen_tokens_pruned_run`.
    pub tokens_saved: usize,
    /// Simulated inference time without pruning.
    pub sim_unpruned: f64,
    /// Simulated inference time with pruning.
    pub sim_pruned: f64,
    /// `sim_unpruned / sim_pruned` (1.0 when nothing was pruned).
    pub speedup: f64,
}

impl CsvRow for PruneRow {
    fn csv_header() -> &'static str {
        "chunk,pipeline,rollouts,rows_pruned,gen_tokens_full,gen_tokens_pruned_run,\
         tokens_saved,sim_unpruned,sim_pruned,speedup"
    }

    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{},{}",
            self.chunk,
            self.pipeline.replace(' ', ""),
            self.rollouts,
            self.rows_pruned,
            self.gen_tokens_full,
            self.gen_tokens_pruned_run,
            self.tokens_saved,
            self.sim_unpruned,
            self.sim_pruned,
            self.speedup
        )
    }
}

/// Ceil-to-chunk token bill for a list of per-rollout lengths.
fn chunked_tokens(lens: &[usize], chunk: usize) -> usize {
    let c = chunk.max(1);
    lens.iter().map(|&t| t.div_ceil(c) * c).sum()
}

/// Build the sweep grid from a cost model (row-major: pipeline, then
/// chunk ascending). Deterministic: the synthetic groups are seeded per
/// cell from the same stream.
pub fn sweep(hw: &HwModel) -> Result<Vec<PruneRow>> {
    let mut out = Vec::with_capacity(PIPELINES.len() * CHUNK_SWEEP.len());
    for spec in PIPELINES {
        let pipeline = Pipeline::parse_default(spec)?;
        for &chunk in &CHUNK_SWEEP {
            // identical groups for every cell: seed by group index only
            let mut full_lens = Vec::new();
            let mut kept_lens = Vec::new();
            let mut pruned_lens = Vec::new();
            let mut rows_pruned = 0usize;
            for g in 0..GROUPS {
                let mut rng = Rng::seed_from_u64(SIM_SEED ^ g as u64);
                let rows = sim_group(&mut rng, N, G);
                let sim = simulate_group(&rows, &pipeline, M, chunk);
                for (i, r) in rows.iter().enumerate() {
                    full_lens.push(r.final_len);
                    if sim.aborted[i] {
                        pruned_lens.push(sim.decoded_len[i]);
                        rows_pruned += 1;
                    } else {
                        kept_lens.push(r.final_len);
                    }
                }
            }
            let gen_tokens_full = chunked_tokens(&full_lens, chunk);
            let gen_tokens_pruned_run =
                chunked_tokens(&kept_lens, chunk) + chunked_tokens(&pruned_lens, chunk);
            let sim_unpruned = hw.chunked_inference_time(&full_lens, chunk);
            let sim_pruned = hw.pruned_inference_time(&kept_lens, &pruned_lens, chunk);
            out.push(PruneRow {
                chunk,
                pipeline: spec.to_string(),
                rollouts: GROUPS * N,
                rows_pruned,
                gen_tokens_full,
                gen_tokens_pruned_run,
                tokens_saved: gen_tokens_full.saturating_sub(gen_tokens_pruned_run),
                sim_unpruned,
                sim_pruned,
                speedup: sim_unpruned / sim_pruned.max(1e-12),
            });
        }
    }
    Ok(out)
}

/// Run the study: write `<out_dir>/prune.csv` and print the tokens-saved
/// curves (one per pipeline) plus the wall-clock recovery table.
pub fn run(out_dir: &str) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let hw = HwModel::default();
    let rows = sweep(&hw)?;
    write_csv_rows(Path::new(&format!("{out_dir}/prune.csv")), &rows)?;

    let curves: Vec<(String, Vec<(f64, f64)>)> = PIPELINES
        .iter()
        .map(|&spec| {
            let pts: Vec<(f64, f64)> = rows
                .iter()
                .filter(|r| r.pipeline == spec)
                .map(|r| (r.chunk as f64, r.tokens_saved as f64))
                .collect();
            (spec.to_string(), pts)
        })
        .collect();
    let series: Vec<(&str, &[(f64, f64)])> =
        curves.iter().map(|(n, p)| (n.as_str(), p.as_slice())).collect();
    println!(
        "Prune study: generated tokens saved vs decode chunk \
         (n = {N} -> m = {M}, {GROUPS} groups, G = {G})"
    );
    println!("{}", ascii_plot(&series, 64, 14));
    for r in &rows {
        println!(
            "  C={:<3} {:<36} pruned {:>3}/{:<3} rows | tokens {:>6} -> {:>6} \
             (saved {:>5}) | sim {:>7.2}s -> {:>7.2}s ({:.2}x)",
            r.chunk,
            r.pipeline,
            r.rows_pruned,
            r.rollouts,
            r.gen_tokens_full,
            r.gen_tokens_pruned_run,
            r.tokens_saved,
            r.sim_unpruned,
            r.sim_pruned,
            r.speedup
        );
    }
    println!(
        "  (doom-only verdicts: opaque pipelines save exactly nothing; the \
         selection over pruned groups is bit-identical to post-hoc — see \
         docs/DETERMINISM.md)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::advantage::NormMode;
    use crate::coordinator::group::{build_update_batch, PromptGroup};

    /// Acceptance shapes: token-budget pipelines save tokens at every
    /// chunk size; opaque pipelines save exactly nothing.
    #[test]
    fn sweep_shapes_match_the_doom_only_contract() {
        let rows = sweep(&HwModel::default()).unwrap();
        assert_eq!(rows.len(), PIPELINES.len() * CHUNK_SWEEP.len());
        for r in &rows {
            assert!(r.sim_pruned <= r.sim_unpruned + 1e-9, "pruning must never cost time");
            assert!(r.gen_tokens_pruned_run <= r.gen_tokens_full);
            if r.pipeline.contains("max_tokens") {
                if r.chunk < G {
                    // a chunk boundary exists before the budget: over-cap
                    // tails must get caught and their decode spend saved
                    assert!(
                        r.rows_pruned > 0 && r.tokens_saved > 0,
                        "token-budget pipeline saved nothing at C={}: {r:?}",
                        r.chunk
                    );
                    assert!(r.speedup > 1.0, "C={} {:?}", r.chunk, r.pipeline);
                } else {
                    // C = G decodes everything in one chunk: no boundary,
                    // nothing can abort — the study shows the trade-off
                    assert_eq!(r.rows_pruned, 0, "no boundary, no pruning");
                }
            }
            if r.pipeline == "percentile" {
                assert_eq!(r.rows_pruned, 0, "opaque pipeline must never prune");
                assert_eq!(r.tokens_saved, 0);
            }
        }
        // a tighter cap saves at least as much as a looser one per chunk
        for &c in &CHUNK_SWEEP {
            let saved = |spec: &str| {
                rows.iter().find(|r| r.chunk == c && r.pipeline == spec).unwrap().tokens_saved
            };
            assert!(
                saved("prune(max_tokens=16) | max_variance")
                    >= saved("prune(max_tokens=32) | max_variance"),
                "cap monotonicity broken at C={c}"
            );
        }
    }

    /// The simulated online world selects identically to post-hoc
    /// selection on the fully-decoded groups — the prune.csv numbers
    /// measure a transformation that provably does not change training.
    #[test]
    fn simulated_selection_matches_post_hoc() {
        let pipeline = Pipeline::parse_default("prune(max_tokens=16) | max_variance").unwrap();
        for g in 0..GROUPS as u64 {
            let mut rng = Rng::seed_from_u64(SIM_SEED ^ g);
            let rows = sim_group(&mut rng, N, G);
            let sim = simulate_group(&rows, &pipeline, M, 4);
            let full_rewards: Vec<f32> = rows.iter().map(|r| r.final_reward).collect();
            let full_lens: Vec<i32> = rows.iter().map(|r| r.final_len as i32).collect();
            // online world: aborted rows carry truncated lengths and a
            // reward the verifier computed on the truncated stream — any
            // bracket value; 0.0 here (garbage scores nothing)
            let online_rewards: Vec<f32> = full_rewards
                .iter()
                .zip(&sim.aborted)
                .map(|(&r, &a)| if a { 0.0 } else { r })
                .collect();
            let online_lens: Vec<i32> = rows
                .iter()
                .zip(&sim.decoded_len)
                .zip(&sim.aborted)
                .map(|((r, &d), &a)| if a { d as i32 } else { r.final_len as i32 })
                .collect();
            let full_group = PromptGroup::synthetic(g, &full_rewards, Some(&full_lens));
            let online_group = PromptGroup::synthetic(g, &online_rewards, Some(&online_lens));
            let (want, _) = build_update_batch(
                std::slice::from_ref(&full_group),
                &pipeline,
                Some(M),
                NormMode::After,
                7,
                g,
            )
            .unwrap();
            let (got, _) = build_update_batch(
                std::slice::from_ref(&online_group),
                &pipeline,
                Some(M),
                NormMode::After,
                7,
                g,
            )
            .unwrap();
            assert_eq!(want.len(), got.len(), "group {g}");
            for (w, o) in want.iter().zip(&got) {
                assert_eq!(w.rollout_idx, o.rollout_idx, "group {g}");
                assert_eq!(w.advantage, o.advantage, "group {g} advantage drifted");
                assert!(!sim.aborted[o.rollout_idx], "group {g} kept an aborted row");
            }
        }
    }

    #[test]
    fn prune_row_csv_shape() {
        let rows = sweep(&HwModel::default()).unwrap();
        let header_cols = PruneRow::csv_header().split(',').count();
        for r in &rows {
            assert_eq!(r.csv_row().split(',').count(), header_cols, "{r:?}");
        }
    }
}
