//! The shared character/tag tokenizer — Rust mirror of python/compile/vocab.py.
//!
//! The table below MUST stay in lockstep with the Python side; the runtime
//! cross-checks it against `meta.json` at engine load (`verify_against_meta`)
//! and an integration test asserts equality, so drift fails loudly.

use anyhow::{anyhow, Result};

/// Padding token id.
pub const PAD: i32 = 0;
/// Beginning-of-sequence token id.
pub const BOS: i32 = 1;
/// End-of-sequence token id.
pub const EOS: i32 = 2;
/// Newline token id.
pub const NL: i32 = 3;
/// `<think>` tag id.
pub const THINK_OPEN: i32 = 4;
/// `</think>` tag id.
pub const THINK_CLOSE: i32 = 5;
/// `<answer>` tag id.
pub const ANSWER_OPEN: i32 = 6;
/// `</answer>` tag id.
pub const ANSWER_CLOSE: i32 = 7;
/// Id of digit `0` (digits 0-9 are contiguous).
pub const DIGIT0: i32 = 8;
/// Total vocabulary size.
pub const VOCAB_SIZE: usize = 48;

/// Display strings, indexed by token id.
pub const TOKENS: &[&str] = &[
    "<pad>", "<bos>", "<eos>", "\n", "<think>", "</think>", "<answer>", "</answer>",
    "0", "1", "2", "3", "4", "5", "6", "7", "8", "9",
    "+", "-", "*", "=", "(", ")", "?", ":", " ",
    "A", "B", "C", "D", "x", "^", "%", ",", ";", ".", "/", "|", "Q",
];

/// Encode plain text (multi-char tags spelled out) into token ids.
/// Digits/operators are one char each; `<think>` etc. must appear verbatim.
pub fn encode(text: &str) -> Result<Vec<i32>> {
    let mut out = Vec::with_capacity(text.len());
    let mut rest = text;
    'outer: while !rest.is_empty() {
        // longest-first match over multi-char tags
        for (id, tok) in TOKENS.iter().enumerate() {
            if tok.len() > 1 && rest.starts_with(tok) {
                out.push(id as i32);
                rest = &rest[tok.len()..];
                continue 'outer;
            }
        }
        let c = &rest[..rest.chars().next().map(|c| c.len_utf8()).unwrap_or(1)];
        let id = TOKENS
            .iter()
            .position(|t| *t == c)
            .ok_or_else(|| anyhow!("unencodable char {c:?} in {text:?}"))?;
        out.push(id as i32);
        rest = &rest[c.len()..];
    }
    Ok(out)
}

/// Decode ids to a display string; PAD renders as nothing, unknown ids as `�`.
pub fn decode(ids: &[i32]) -> String {
    let mut s = String::new();
    for &id in ids {
        if id == PAD {
            continue;
        }
        match TOKENS.get(id as usize) {
            Some(t) => s.push_str(t),
            None => s.push('�'),
        }
    }
    s
}

/// Encode a decimal unsigned integer.
pub fn encode_uint(mut v: u64) -> Vec<i32> {
    if v == 0 {
        return vec![DIGIT0];
    }
    let mut digits = Vec::new();
    while v > 0 {
        digits.push(DIGIT0 + (v % 10) as i32);
        v /= 10;
    }
    digits.reverse();
    digits
}

/// Encode a decimal signed integer ('-' prefix for negatives).
pub fn encode_int(v: i64) -> Vec<i32> {
    if v < 0 {
        let mut out = vec![encode("-").unwrap()[0]];
        out.extend(encode_uint(v.unsigned_abs()));
        out
    } else {
        encode_uint(v as u64)
    }
}

/// Cross-check this mirror against the AOT-emitted vocabulary table.
pub fn verify_against_meta(vm: &crate::runtime::meta::VocabMeta) -> Result<()> {
    if vm.vocab_size != VOCAB_SIZE {
        return Err(anyhow!("vocab size mismatch: rust {VOCAB_SIZE}, meta {}", vm.vocab_size));
    }
    if vm.tokens.len() != TOKENS.len() {
        return Err(anyhow!("token table length mismatch: rust {}, meta {}", TOKENS.len(), vm.tokens.len()));
    }
    for (i, (r, p)) in TOKENS.iter().zip(vm.tokens.iter()).enumerate() {
        if r != p {
            return Err(anyhow!("token {i} mismatch: rust {r:?}, meta {p:?}"));
        }
    }
    for (name, rust, meta) in [
        ("pad", PAD, vm.pad),
        ("bos", BOS, vm.bos),
        ("eos", EOS, vm.eos),
        ("nl", NL, vm.nl),
        ("think_open", THINK_OPEN, vm.think_open),
        ("think_close", THINK_CLOSE, vm.think_close),
        ("answer_open", ANSWER_OPEN, vm.answer_open),
        ("answer_close", ANSWER_CLOSE, vm.answer_close),
        ("digit0", DIGIT0, vm.digit0),
    ] {
        if rust != meta {
            return Err(anyhow!("special token {name} mismatch: rust {rust}, meta {meta}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_plain() {
        let ids = encode("Q:17+25=?").unwrap();
        assert_eq!(decode(&ids), "Q:17+25=?");
    }

    #[test]
    fn roundtrip_tags() {
        let text = "<think>\n1+2=3\n</think>\n<answer>\n3\n</answer>";
        let ids = encode(text).unwrap();
        assert_eq!(ids[0], THINK_OPEN);
        assert_eq!(ids[1], NL);
        assert_eq!(decode(&ids), text);
    }

    #[test]
    fn encode_numbers() {
        assert_eq!(decode(&encode_uint(0)), "0");
        assert_eq!(decode(&encode_uint(907)), "907");
        assert_eq!(decode(&encode_int(-42)), "-42");
    }

    #[test]
    fn rejects_unknown() {
        assert!(encode("hello").is_err()); // lowercase letters not in vocab
    }

    #[test]
    fn pad_decodes_to_nothing() {
        assert_eq!(decode(&[PAD, DIGIT0 + 5, PAD]), "5");
    }

    #[test]
    fn table_is_consistent() {
        assert!(TOKENS.len() <= VOCAB_SIZE);
        assert_eq!(TOKENS[DIGIT0 as usize], "0");
        assert_eq!(TOKENS[(DIGIT0 + 9) as usize], "9");
        // no duplicate tokens
        let mut set = std::collections::HashSet::new();
        for t in TOKENS {
            assert!(set.insert(t), "duplicate token {t:?}");
        }
    }
}
