//! Synthetic verifiable-reasoning task families.
//!
//! These stand in for the paper's benchmarks (DESIGN.md §2): `arith` ≈
//! GSM8K (multi-step integer arithmetic), `poly` ≈ MATH (modular polynomial
//! evaluation), `mcq` ≈ SciKnowEval-Chemistry (4-choice A–D questions).
//!
//! Every problem is generated deterministically from `(task, split, index)`
//! via ChaCha8, giving reproducible train/test/platinum splits with no data
//! files. Each task also emits an *ideal completion* (gold chain-of-thought
//! in the paper's `<think>/<answer>` format) used by the SFT warm-up phase
//! that stands in for "start from an instruct-tuned model".

pub mod tokenizer;

use crate::util::rng::Rng;
use anyhow::Result;

use tokenizer as tok;

/// Data split; disjoint by construction (index spaces are offset).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    /// Training prompts.
    Train,
    /// Held-out evaluation prompts.
    Test,
    /// Contamination-resistant re-generation with a distinct seed space —
    /// the stand-in for GSM8K-Platinum in the Fig. 7 generalization study.
    Platinum,
}

impl Split {
    fn offset(self) -> u64 {
        match self {
            Split::Train => 0,
            Split::Test => 1_000_000_007,
            Split::Platinum => 2_000_000_011,
        }
    }
}

/// One generated problem.
#[derive(Debug, Clone)]
pub struct Problem {
    /// Prompt token ids (unpadded; the batcher left-pads to prompt_len).
    pub prompt: Vec<i32>,
    /// Canonical answer string (as it should appear inside `<answer>`).
    pub answer: String,
    /// Gold response (think + answer, paper format) for SFT.
    pub ideal_response: Vec<i32>,
    /// Deterministic problem id (the generation index).
    pub id: u64,
}

/// Task family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Multi-step integer arithmetic (≈ GSM8K).
    Arith,
    /// Modular polynomial evaluation (≈ MATH).
    Poly,
    /// 4-choice A-D questions (≈ SciKnowEval-Chemistry).
    Mcq,
}

impl TaskKind {
    /// Parse a `[run] task` value (`arith` | `poly` | `mcq`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "arith" => Ok(Self::Arith),
            "poly" => Ok(Self::Poly),
            "mcq" => Ok(Self::Mcq),
            other => Err(anyhow::anyhow!("unknown task {other:?} (arith|poly|mcq)")),
        }
    }

    /// Canonical name used in configs and logs.
    pub fn name(self) -> &'static str {
        match self {
            Self::Arith => "arith",
            Self::Poly => "poly",
            Self::Mcq => "mcq",
        }
    }

    /// Whether answers are compared numerically (vs. literal letter match).
    pub fn numeric_answer(self) -> bool {
        !matches!(self, Self::Mcq)
    }

    fn rng(self, split: Split, index: u64) -> Rng {
        let tag = match self {
            Self::Arith => 0x11u64,
            Self::Poly => 0x22,
            Self::Mcq => 0x33,
        };
        Rng::seed_from_u64(
            tag.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ split.offset().wrapping_add(index).wrapping_mul(0x2545_F491_4F6C_DD1D),
        )
    }

    /// Deterministically generate problem `index` of `split`.
    pub fn generate(self, split: Split, index: u64) -> Problem {
        let mut rng = self.rng(split, index);
        match self {
            Self::Arith => gen_arith(&mut rng, index),
            Self::Poly => gen_poly(&mut rng, index),
            Self::Mcq => gen_mcq(&mut rng, index),
        }
    }

    /// Generate a batch of problems `[start, start+count)`.
    pub fn batch(self, split: Split, start: u64, count: usize) -> Vec<Problem> {
        (0..count as u64).map(|i| self.generate(split, start + i)).collect()
    }
}

fn response_tokens(think: &str, answer: &str) -> Vec<i32> {
    let text = format!("<think>\n{think}\n</think>\n<answer>\n{answer}\n</answer>");
    let mut ids = tok::encode(&text).expect("ideal response must be encodable");
    ids.push(tok::EOS);
    ids
}

/// GSM8K-sim: left-to-right chain of 1–2 (+,-,*) operations over small
/// ints, intermediate values kept in [0, 99] — scaled to what a ~1M-param
/// char-level policy can learn while staying genuinely multi-step.
/// Prompt: `Q:17+25-3=?`
fn gen_arith(rng: &mut Rng, id: u64) -> Problem {
    // difficulty mixture: 45% single-op/single-digit, 35% single-op with a
    // two-digit operand, 20% two-op chains — keeps post-SFT accuracy in the
    // mid-range where GRPO's group variance is maximal
    let roll = rng.f64();
    let (n_ops, lo, hi) = if roll < 0.45 {
        (1, 2, 9)
    } else if roll < 0.80 {
        (1, 2, 29)
    } else {
        (2, 2, 29)
    };
    let n_ops = n_ops as i64;
    let mut acc: i64 = rng.gen_range_inclusive(lo, hi);
    let mut expr = acc.to_string();
    let mut steps: Vec<String> = Vec::new();
    for _ in 0..n_ops {
        // pick an op that keeps the running value in [0, 99]
        let can_add = acc < 99;
        let can_mul = acc >= 2 && acc <= 33;
        let op = loop {
            let o = rng.gen_range_inclusive(0, 2);
            match o {
                0 if can_add => break 0,
                1 => break 1,
                2 if can_mul => break 2,
                _ => continue,
            }
        };
        let cap = hi.min(99 - acc).max(1);
        let (sym, operand, next) = match op {
            0 => {
                let b = rng.gen_range_inclusive(1, cap);
                ('+', b, acc + b)
            }
            1 => {
                let b = rng.gen_range_inclusive(0, acc.min(hi));
                ('-', b, acc - b)
            }
            _ => {
                let mhi = (99 / acc).min(5).max(2);
                let b = rng.gen_range_inclusive(2, mhi);
                ('*', b, acc * b)
            }
        };
        steps.push(format!("{acc}{sym}{operand}={next}"));
        expr.push(sym);
        expr.push_str(&operand.to_string());
        acc = next;
    }
    let answer = acc.to_string();
    let think = steps.join(";");
    let prompt = tok::encode(&format!("Q:{expr}=?")).unwrap();
    Problem { prompt, answer: answer.clone(), ideal_response: response_tokens(&think, &answer), id }
}

/// MATH-sim: evaluate `a*x^2+b*x+c mod p` at a given x.
/// Prompt: `Q:3x^2+2x+1;x=5;%7=?`
fn gen_poly(rng: &mut Rng, id: u64) -> Problem {
    let p: i64 = [5, 7][rng.below(2)];
    let a = rng.gen_range_inclusive(1, 3);
    let b = rng.gen_range_inclusive(0, 5);
    let c = rng.gen_range_inclusive(0, 5);
    let x = rng.gen_range_inclusive(2, 5);
    let x2 = x * x;
    let t1 = a * x2;
    let t2 = b * x;
    let total = t1 + t2 + c;
    let answer = (total % p).to_string();
    let think = format!("{x}^2={x2};{a}*{x2}={t1};{b}*{x}={t2};{t1}+{t2}+{c}={total};{total}%{p}={answer}");
    let prompt = tok::encode(&format!("Q:{a}x^2+{b}x+{c};x={x};%{p}=?")).unwrap();
    Problem { prompt, answer: answer.clone(), ideal_response: response_tokens(&think, &answer), id }
}

/// SciKnowEval-sim: a single-step product fact with 4 candidate answers;
/// answer is the letter. Prompt: `Q:8*7=?A:54B:56C:58D:52`
fn gen_mcq(rng: &mut Rng, id: u64) -> Problem {
    let a = rng.gen_range_inclusive(2, 9);
    let b = rng.gen_range_inclusive(2, 9);
    let correct = a * b;
    let mut options = vec![correct];
    while options.len() < 4 {
        let delta = rng.gen_range_inclusive(1, 6) * if rng.gen_bool(0.5) { 1 } else { -1 };
        let cand = correct + delta;
        if cand > 0 && !options.contains(&cand) {
            options.push(cand);
        }
    }
    // shuffle deterministic
    for i in (1..4).rev() {
        let j = rng.below(i + 1);
        options.swap(i, j);
    }
    let pos = options.iter().position(|&o| o == correct).unwrap();
    let letter = ["A", "B", "C", "D"][pos];
    let prompt_txt = format!(
        "Q:{a}*{b}=?A:{}B:{}C:{}D:{}",
        options[0], options[1], options[2], options[3]
    );
    let think = format!("{a}*{b}={correct};{letter}");
    let prompt = tok::encode(&prompt_txt).unwrap();
    Problem {
        prompt,
        answer: letter.to_string(),
        ideal_response: response_tokens(&think, letter),
        id,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        for kind in [TaskKind::Arith, TaskKind::Poly, TaskKind::Mcq] {
            let a = kind.generate(Split::Train, 5);
            let b = kind.generate(Split::Train, 5);
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.answer, b.answer);
            let c = kind.generate(Split::Train, 6);
            assert!(a.prompt != c.prompt || a.answer != c.answer);
        }
    }

    #[test]
    fn splits_are_disjointly_seeded() {
        let a = TaskKind::Arith.generate(Split::Train, 0);
        let b = TaskKind::Arith.generate(Split::Test, 0);
        let c = TaskKind::Arith.generate(Split::Platinum, 0);
        assert!(a.prompt != b.prompt || a.answer != b.answer);
        assert!(b.prompt != c.prompt || b.answer != c.answer);
    }

    #[test]
    fn arith_answers_verify() {
        for i in 0..200 {
            let p = TaskKind::Arith.generate(Split::Train, i);
            let text = tokenizer::decode(&p.prompt);
            assert!(text.starts_with("Q:") && text.ends_with("=?"), "{text}");
            let expr = &text[2..text.len() - 2];
            // left-to-right evaluation must reproduce the recorded answer
            let mut acc: i64 = 0;
            let mut cur = String::new();
            let mut pending = '+';
            for ch in expr.chars().chain(std::iter::once('\0')) {
                if ch.is_ascii_digit() {
                    cur.push(ch);
                } else {
                    let v: i64 = cur.parse().unwrap();
                    acc = match pending {
                        '+' => acc + v,
                        '-' => acc - v,
                        '*' => acc * v,
                        _ => unreachable!(),
                    };
                    cur.clear();
                    pending = ch;
                }
            }
            assert_eq!(acc.to_string(), p.answer, "expr {expr}");
            assert!((0..=99).contains(&acc), "final value out of range in {expr}");
        }
    }

    #[test]
    fn poly_answers_verify() {
        for i in 0..200 {
            let p = TaskKind::Poly.generate(Split::Test, i);
            let text = tokenizer::decode(&p.prompt);
            // Q:{a}x^2+{b}x+{c};x={x};%{p}=?
            let body = text.strip_prefix("Q:").unwrap().strip_suffix("=?").unwrap();
            let parts: Vec<&str> = body.split(';').collect();
            let poly = parts[0];
            let x: i64 = parts[1].strip_prefix("x=").unwrap().parse().unwrap();
            let pm: i64 = parts[2].strip_prefix('%').unwrap().parse().unwrap();
            let a: i64 = poly.split('x').next().unwrap().parse().unwrap();
            let rest = poly.split_once("x^2+").unwrap().1;
            let b: i64 = rest.split('x').next().unwrap().parse().unwrap();
            let c: i64 = rest.split_once("x+").unwrap().1.parse().unwrap();
            let want = (a * x * x + b * x + c) % pm;
            assert_eq!(want.to_string(), p.answer);
        }
    }

    #[test]
    fn mcq_answers_are_letters_and_unique_options() {
        for i in 0..200 {
            let p = TaskKind::Mcq.generate(Split::Train, i);
            assert!(["A", "B", "C", "D"].contains(&p.answer.as_str()));
        }
    }

    #[test]
    fn prompts_fit_base_profile() {
        for kind in [TaskKind::Arith, TaskKind::Poly, TaskKind::Mcq] {
            for i in 0..500 {
                let p = kind.generate(Split::Train, i);
                assert!(p.prompt.len() <= 32, "{:?} prompt {} tokens", kind, p.prompt.len());
                assert!(
                    p.ideal_response.len() <= 64,
                    "{:?} ideal response {} tokens: {}",
                    kind,
                    p.ideal_response.len(),
                    tokenizer::decode(&p.ideal_response),
                );
            }
        }
    }

    #[test]
    fn ideal_response_is_format_compliant() {
        let p = TaskKind::Arith.generate(Split::Train, 3);
        let text = tokenizer::decode(&p.ideal_response);
        assert!(text.starts_with("<think>\n"));
        assert!(text.contains("\n</think>\n<answer>\n"));
        assert!(text.ends_with("\n</answer><eos>"));
    }
}
