//! Tiny bench harness (criterion is unavailable offline).
//!
//! `cargo bench` runs the `rust/benches/*.rs` mains, which use [`bench`] to
//! time closures: warmup, then timed iterations with mean / median / p95 /
//! min reporting, plus a machine-readable line (`BENCH\t<name>\t<ns>`) that
//! the perf log in EXPERIMENTS.md is built from.
//!
//! [`BenchReport`] additionally collects results into a machine-readable
//! JSON file (e.g. `BENCH_e2e.json` from the e2e_step bench) so the perf
//! trajectory across PRs can be tracked by tooling instead of scraped
//! from logs: per-bench name, iteration count, mean/median/p95/min wall
//! time in seconds, and — where the bench knows it — rollout throughput.

use crate::util::json::{obj, Json};
use std::path::Path;
use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name (stable across runs — the regression-guard key).
    pub name: String,
    /// Timed iterations executed.
    pub iters: usize,
    /// Mean wall time per iteration (nanoseconds).
    pub mean_ns: f64,
    /// Median wall time per iteration (nanoseconds).
    pub median_ns: f64,
    /// 95th-percentile wall time per iteration (nanoseconds).
    pub p95_ns: f64,
    /// Fastest iteration (nanoseconds).
    pub min_ns: f64,
}

/// Time `f` (called once per iteration). Chooses iteration count to hit a
/// target budget unless `iters` is given.
pub fn bench<F: FnMut()>(name: &str, iters: Option<usize>, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let first = t0.elapsed().as_secs_f64();
    let iters = iters.unwrap_or_else(|| {
        let budget = 1.0; // seconds
        ((budget / first.max(1e-9)) as usize).clamp(5, 10_000)
    });
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e9);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let median = samples[samples.len() / 2];
    let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
    let min = samples[0];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        median_ns: median,
        p95_ns: p95,
        min_ns: min,
    };
    println!(
        "{:<44} {:>7} iters  mean {:>12}  median {:>12}  p95 {:>12}  min {:>12}",
        r.name,
        r.iters,
        fmt_ns(r.mean_ns),
        fmt_ns(r.median_ns),
        fmt_ns(r.p95_ns),
        fmt_ns(r.min_ns)
    );
    println!("BENCH\t{}\t{:.1}", r.name, r.median_ns);
    r
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// `black_box` shim: prevents the optimizer from deleting the benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Collects [`BenchResult`]s and writes them as one JSON document.
#[derive(Debug, Default)]
pub struct BenchReport {
    entries: Vec<(BenchResult, Option<f64>)>,
}

impl BenchReport {
    /// An empty report.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a result with no throughput dimension.
    pub fn push(&mut self, r: BenchResult) {
        self.entries.push((r, None));
    }

    /// Record a result alongside its rollout throughput (rollouts/s of
    /// simulated-training work per real second, median-based).
    pub fn push_with_throughput(&mut self, r: BenchResult, rollouts_per_sec: f64) {
        self.entries.push((r, Some(rollouts_per_sec)));
    }

    fn to_json(&self) -> Json {
        let benches: Vec<Json> = self
            .entries
            .iter()
            .map(|(r, tp)| {
                let mut pairs = vec![
                    ("name", Json::Str(r.name.clone())),
                    ("iters", Json::Num(r.iters as f64)),
                    ("mean_s", Json::Num(r.mean_ns / 1e9)),
                    ("median_s", Json::Num(r.median_ns / 1e9)),
                    ("p95_s", Json::Num(r.p95_ns / 1e9)),
                    ("min_s", Json::Num(r.min_ns / 1e9)),
                ];
                if let Some(tp) = tp {
                    pairs.push(("rollouts_per_sec", Json::Num(*tp)));
                }
                obj(pairs)
            })
            .collect();
        obj(vec![("benches", Json::Arr(benches))])
    }

    /// Write the report (e.g. `BENCH_e2e.json`). Parent directories must
    /// exist; the file is overwritten so each run snapshots this host.
    pub fn write_json(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().dump())
            .map_err(|e| anyhow::anyhow!("writing bench report {}: {e}", path.display()))?;
        println!("BENCH_JSON\t{}\t{} benches", path.display(), self.entries.len());
        Ok(())
    }
}

/// Outcome of a bench-regression check: human-readable comparison lines
/// plus the subset that regressed beyond the threshold.
#[derive(Debug, Default)]
pub struct RegressionReport {
    /// Human-readable per-bench comparison lines.
    pub lines: Vec<String>,
    /// The subset of `lines` that regressed beyond the threshold.
    pub regressions: Vec<String>,
    /// Non-fatal conditions the caller should surface loudly (the CLI
    /// prints these as `::warning::` annotations in CI): e.g. a baseline
    /// whose `benches` list is empty, which would otherwise let every
    /// regression pass silently.
    pub warnings: Vec<String>,
}

fn load_throughputs(path: &Path) -> anyhow::Result<Vec<(String, f64)>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading bench report {}: {e}", path.display()))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing bench report {}: {e}", path.display()))?;
    let mut out = Vec::new();
    for b in j.get("benches")?.arr()? {
        let name = b.get("name")?.str()?.to_string();
        if let Some(tp) = b.opt("rollouts_per_sec") {
            out.push((name, tp.f64()?));
        }
    }
    Ok(out)
}

/// Compare the rollout-throughput entries of a fresh `BENCH_e2e.json`
/// against a committed baseline. A bench **regresses** when its fresh
/// throughput drops more than `max_drop` (fraction, e.g. `0.15`) below
/// the baseline. A missing baseline file is not an error — the check
/// reports it and passes, so CI stays green until a baseline is recorded
/// (`cargo bench --bench e2e_step && cp BENCH_e2e.json
/// rust/benches/BENCH_baseline.json`).
///
/// A baseline arm **absent from the fresh run is a hard error**: a
/// renamed or deleted benchmark would otherwise drop out of the guard
/// silently, and an arbitrarily large regression could hide behind the
/// rename. Either restore the arm or re-record the baseline.
pub fn check_regression(
    fresh: &Path,
    baseline: &Path,
    max_drop: f64,
) -> anyhow::Result<RegressionReport> {
    let mut report = RegressionReport::default();
    if !baseline.exists() {
        report.lines.push(format!(
            "no baseline at {} — nothing to compare (record one with \
             `cargo bench --bench e2e_step` and commit BENCH_e2e.json there)",
            baseline.display()
        ));
        return Ok(report);
    }
    let fresh_tp = load_throughputs(fresh)?;
    let base_tp = load_throughputs(baseline)?;
    if base_tp.is_empty() {
        // one warning per check, not one per fresh arm: this fires before
        // the per-arm loop so a 13-arm report doesn't print 13 copies
        let msg = format!(
            "baseline {} carries no throughput entries — the regression guard is \
             checking nothing; re-record it with `cargo bench --bench e2e_step && \
             pods bench-check --bless`",
            baseline.display()
        );
        report.lines.push(msg.clone());
        report.warnings.push(msg);
        return Ok(report);
    }
    let mut missing: Vec<&str> = Vec::new();
    for (name, base) in &base_tp {
        match fresh_tp.iter().find(|(n, _)| n == name) {
            None => missing.push(name),
            Some((_, tp)) => {
                let delta = (tp - base) / base.max(1e-12);
                let line = format!(
                    "{name}: baseline {base:.2} -> fresh {tp:.2} rollouts/s ({:+.1}%)",
                    delta * 100.0
                );
                if *tp < base * (1.0 - max_drop) {
                    report.regressions.push(line.clone());
                }
                report.lines.push(line);
            }
        }
    }
    if !missing.is_empty() {
        anyhow::bail!(
            "baseline {} lists {} bench(es) absent from the fresh run {}: {missing:?} — \
             a renamed/deleted arm would let regressions hide behind the rename; \
             restore the arm or re-record the baseline",
            baseline.display(),
            missing.len(),
            fresh.display()
        );
    }
    Ok(report)
}

/// Regenerate the committed bench baseline in place (`pods bench-check
/// --bless`): validates that the fresh report parses and carries
/// rollout-throughput entries, then copies it to the baseline path
/// byte-for-byte. Refuses empty reports — blessing a run that produced no
/// throughput arms (e.g. benches self-skipped without artifacts) would
/// silently disable the regression guard. The normal check path (and its
/// missing-arm hard failure) is untouched.
pub fn bless_baseline(fresh: &Path, baseline: &Path) -> anyhow::Result<String> {
    let tps = load_throughputs(fresh)?;
    if tps.is_empty() {
        anyhow::bail!(
            "refusing to bless {}: it carries no rollout-throughput entries (did the \
             bench run without artifacts?) — blessing it would disable the guard",
            fresh.display()
        );
    }
    let text = std::fs::read_to_string(fresh)
        .map_err(|e| anyhow::anyhow!("reading bench report {}: {e}", fresh.display()))?;
    std::fs::write(baseline, &text)
        .map_err(|e| anyhow::anyhow!("writing baseline {}: {e}", baseline.display()))?;
    Ok(format!(
        "blessed {} -> {} ({} throughput arm(s))",
        fresh.display(),
        baseline.display(),
        tps.len()
    ))
}

/// Identify a baseline file for CI logs: the git blob hash (what `git
/// ls-files -s` shows for the committed file) when git is runnable, so a
/// bench-check log line can be matched to the exact baseline revision it
/// compared against; an FNV-1a-64 content hash otherwise. Both forms are
/// prefixed so readers can tell which scheme produced them.
pub fn baseline_hash(path: &Path) -> anyhow::Result<String> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("reading baseline {}: {e}", path.display()))?;
    if let Ok(out) = std::process::Command::new("git").arg("hash-object").arg(path).output() {
        if out.status.success() {
            if let Ok(s) = String::from_utf8(out.stdout) {
                let s = s.trim();
                if !s.is_empty() {
                    return Ok(format!("git:{s}"));
                }
            }
        }
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in &bytes {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    Ok(format!("fnv1a64:{h:016x}"))
}

/// Same-run early-exit speedup guard: compares the chunked arm's rollout
/// throughput against the full-G (no early exit) arm **within one bench
/// run**. Absolute rollouts/sec varies across hosts and CI tenancy; the
/// ratio of two arms measured back-to-back on the same host does not, so
/// this assertion is machine-independent. Returns `Ok(None)` (with no
/// failure) when either arm is absent from the report, `Ok(Some(line))`
/// on pass, `Err` when the ratio falls below `min_ratio`.
pub fn check_speedup(
    fresh: &Path,
    fast: &str,
    slow: &str,
    min_ratio: f64,
) -> anyhow::Result<Option<String>> {
    let tps = load_throughputs(fresh)?;
    let find = |name: &str| tps.iter().find(|(n, _)| n == name).map(|(_, t)| *t);
    let (Some(f), Some(s)) = (find(fast), find(slow)) else {
        return Ok(None);
    };
    let ratio = f / s.max(1e-12);
    let line = format!(
        "early-exit speedup: {fast:?} {f:.2} vs {slow:?} {s:.2} rollouts/s = {ratio:.2}x \
         (floor {min_ratio:.2}x)"
    );
    if ratio < min_ratio {
        anyhow::bail!("{line} — chunked early exit lost its edge");
    }
    Ok(Some(line))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_roundtrips_as_json() {
        let mut rep = BenchReport::new();
        rep.push(BenchResult {
            name: "unit".into(),
            iters: 5,
            mean_ns: 2.0e9,
            median_ns: 1.5e9,
            p95_ns: 3.0e9,
            min_ns: 1.0e9,
        });
        rep.push_with_throughput(
            BenchResult {
                name: "e2e step pods".into(),
                iters: 4,
                mean_ns: 4.0e9,
                median_ns: 4.0e9,
                p95_ns: 4.0e9,
                min_ns: 4.0e9,
            },
            16.0,
        );
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("BENCH_e2e.json");
        rep.write_json(&path).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let benches = parsed.get("benches").unwrap().arr().unwrap();
        assert_eq!(benches.len(), 2);
        assert_eq!(benches[0].get("name").unwrap().str().unwrap(), "unit");
        assert_eq!(benches[0].get("mean_s").unwrap().f64().unwrap(), 2.0);
        assert_eq!(benches[0].get("min_s").unwrap().f64().unwrap(), 1.0);
        assert!(benches[0].opt("rollouts_per_sec").is_none());
        assert_eq!(benches[1].get("rollouts_per_sec").unwrap().f64().unwrap(), 16.0);
        assert_eq!(benches[1].get("iters").unwrap().usize().unwrap(), 4);
    }

    fn write_report(path: &Path, entries: &[(&str, f64)]) {
        let mut rep = BenchReport::new();
        for (name, tp) in entries {
            rep.push_with_throughput(
                BenchResult {
                    name: (*name).into(),
                    iters: 1,
                    mean_ns: 1e9,
                    median_ns: 1e9,
                    p95_ns: 1e9,
                    min_ns: 1e9,
                },
                *tp,
            );
        }
        rep.write_json(path).unwrap();
    }

    /// The CI guard: >15% throughput drop fails, anything above passes,
    /// and a missing baseline is a no-op (record mode).
    #[test]
    fn regression_check_flags_only_real_drops() {
        let dir = crate::util::TempDir::new().unwrap();
        let base = dir.path().join("base.json");
        let fresh = dir.path().join("fresh.json");
        write_report(&base, &[("e2e step a", 100.0), ("e2e step b", 50.0)]);
        write_report(&fresh, &[("e2e step a", 86.0), ("e2e step b", 40.0)]);
        let rep = check_regression(&fresh, &base, 0.15).unwrap();
        // a: -14% passes; b: -20% regresses
        assert_eq!(rep.regressions.len(), 1, "{:?}", rep.regressions);
        assert!(rep.regressions[0].contains("e2e step b"));

        // improvements never regress
        write_report(&fresh, &[("e2e step a", 200.0), ("e2e step b", 49.0)]);
        let rep = check_regression(&fresh, &base, 0.15).unwrap();
        assert!(rep.regressions.is_empty());

        // missing baseline: pass with a note
        let rep = check_regression(&fresh, &dir.path().join("absent.json"), 0.15).unwrap();
        assert!(rep.regressions.is_empty());
        assert!(rep.lines[0].contains("no baseline"));
    }

    /// Satellite bugfix: a baseline whose `benches` list is empty used to
    /// pass with one quiet line — the guard was checking nothing and
    /// nobody could tell. It still passes (no false CI failures) but now
    /// carries an explicit warning the CLI surfaces as `::warning::`.
    #[test]
    fn empty_baseline_passes_but_warns_loudly() {
        let dir = crate::util::TempDir::new().unwrap();
        let base = dir.path().join("base.json");
        let fresh = dir.path().join("fresh.json");
        write_report(&base, &[]);
        // several fresh arms on purpose: the warning must be emitted once
        // per check, not once per arm
        write_report(&fresh, &[("e2e step a", 100.0), ("e2e step b", 50.0), ("e2e step c", 2.0)]);
        let rep = check_regression(&fresh, &base, 0.15).unwrap();
        assert!(rep.regressions.is_empty(), "empty baseline must not fail the check");
        assert_eq!(rep.warnings.len(), 1, "exactly one warning, not per-arm: {:?}", rep.warnings);
        assert!(rep.warnings[0].contains("no throughput entries"), "{:?}", rep.warnings);
        assert!(rep.warnings[0].contains("--bless"), "warning must say how to fix it");
        // a populated baseline warns about nothing
        write_report(&base, &[("e2e step a", 100.0)]);
        let rep = check_regression(&fresh, &base, 0.15).unwrap();
        assert!(rep.warnings.is_empty());
    }

    /// Satellite bugfix: a baseline arm missing from the fresh run used to
    /// emit a warning line and pass — a renamed benchmark silently escaped
    /// the guard. It is now a hard, descriptive failure.
    #[test]
    fn missing_baseline_arm_is_a_hard_failure() {
        let dir = crate::util::TempDir::new().unwrap();
        let base = dir.path().join("base.json");
        let fresh = dir.path().join("fresh.json");
        write_report(&base, &[("e2e step a", 100.0), ("gone", 10.0)]);
        write_report(&fresh, &[("e2e step a", 100.0)]);
        let err = check_regression(&fresh, &base, 0.15).unwrap_err().to_string();
        assert!(err.contains("gone"), "error must name the missing arm: {err}");
        assert!(err.contains("re-record"), "error must say how to fix it: {err}");
        // both arms present again: passes
        write_report(&fresh, &[("e2e step a", 100.0), ("gone", 10.0)]);
        assert!(check_regression(&fresh, &base, 0.15).is_ok());
    }

    /// Satellite: `--bless` regenerates the committed baseline from a
    /// fresh report, byte-for-byte, and refuses throughput-less reports.
    #[test]
    fn bless_baseline_copies_fresh_reports_and_rejects_empty_ones() {
        let dir = crate::util::TempDir::new().unwrap();
        let fresh = dir.path().join("fresh.json");
        let base = dir.path().join("base.json");
        write_report(&fresh, &[("e2e step a", 100.0)]);
        let line = bless_baseline(&fresh, &base).unwrap();
        assert!(line.contains("1 throughput arm"), "{line}");
        assert_eq!(
            std::fs::read_to_string(&fresh).unwrap(),
            std::fs::read_to_string(&base).unwrap(),
            "bless must copy byte-for-byte"
        );
        // the blessed baseline immediately passes the regression check
        assert!(check_regression(&fresh, &base, 0.15).unwrap().regressions.is_empty());

        // a throughput-less report (benches self-skipped) is refused
        let empty = dir.path().join("empty.json");
        let mut rep = BenchReport::new();
        rep.push(BenchResult {
            name: "no-throughput".into(),
            iters: 1,
            mean_ns: 1e9,
            median_ns: 1e9,
            p95_ns: 1e9,
            min_ns: 1e9,
        });
        rep.write_json(&empty).unwrap();
        let err = bless_baseline(&empty, &base).unwrap_err().to_string();
        assert!(err.contains("refusing to bless"), "{err}");
        // a missing fresh report is a descriptive error, not a panic
        assert!(bless_baseline(&dir.path().join("absent.json"), &base).is_err());
    }

    /// The same-run speedup guard: ratio below the floor fails, above
    /// passes, and missing arms skip (None) rather than fail.
    #[test]
    fn speedup_check_compares_arms_within_one_run() {
        let dir = crate::util::TempDir::new().unwrap();
        let fresh = dir.path().join("fresh.json");
        write_report(&fresh, &[("chunked", 30.0), ("full-G", 20.0)]);
        let line = check_speedup(&fresh, "chunked", "full-G", 1.2).unwrap();
        assert!(line.unwrap().contains("1.50x"));
        assert!(check_speedup(&fresh, "chunked", "full-G", 1.6).is_err());
        // either arm absent: skip, don't fail
        assert!(check_speedup(&fresh, "chunked", "nope", 1.2).unwrap().is_none());
        assert!(check_speedup(&fresh, "nope", "full-G", 1.2).unwrap().is_none());
    }

    /// The baseline-hash line in bench-check logs: stable for identical
    /// bytes, distinct for different bytes, and always scheme-prefixed so
    /// a log line identifies which baseline revision it compared against.
    #[test]
    fn baseline_hash_is_content_addressed() {
        let dir = crate::util::TempDir::new().unwrap();
        let a = dir.path().join("a.json");
        let b = dir.path().join("b.json");
        let c = dir.path().join("c.json");
        std::fs::write(&a, "same").unwrap();
        std::fs::write(&b, "same").unwrap();
        std::fs::write(&c, "different").unwrap();
        let ha = baseline_hash(&a).unwrap();
        let hb = baseline_hash(&b).unwrap();
        let hc = baseline_hash(&c).unwrap();
        assert_eq!(ha, hb, "identical bytes must hash identically");
        assert_ne!(ha, hc, "different bytes must hash differently");
        assert!(ha.starts_with("git:") || ha.starts_with("fnv1a64:"), "{ha}");
        // a missing file is a descriptive error, not a panic
        assert!(baseline_hash(&dir.path().join("absent.json")).is_err());
    }
}
