//! Tiny bench harness (criterion is unavailable offline).
//!
//! `cargo bench` runs the `rust/benches/*.rs` mains, which use [`bench`] to
//! time closures: warmup, then timed iterations with mean / median / p95 /
//! min reporting, plus a machine-readable line (`BENCH\t<name>\t<ns>`) that
//! the perf log in EXPERIMENTS.md is built from.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

/// Time `f` (called once per iteration). Chooses iteration count to hit a
/// target budget unless `iters` is given.
pub fn bench<F: FnMut()>(name: &str, iters: Option<usize>, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let first = t0.elapsed().as_secs_f64();
    let iters = iters.unwrap_or_else(|| {
        let budget = 1.0; // seconds
        ((budget / first.max(1e-9)) as usize).clamp(5, 10_000)
    });
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e9);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let median = samples[samples.len() / 2];
    let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
    let min = samples[0];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        median_ns: median,
        p95_ns: p95,
        min_ns: min,
    };
    println!(
        "{:<44} {:>7} iters  mean {:>12}  median {:>12}  p95 {:>12}  min {:>12}",
        r.name,
        r.iters,
        fmt_ns(r.mean_ns),
        fmt_ns(r.median_ns),
        fmt_ns(r.p95_ns),
        fmt_ns(r.min_ns)
    );
    println!("BENCH\t{}\t{:.1}", r.name, r.median_ns);
    r
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// `black_box` shim: prevents the optimizer from deleting the benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
