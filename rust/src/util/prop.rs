//! Mini property-testing harness (std-only proptest replacement).
//!
//! [`for_cases`] runs a closure over `n` deterministically-seeded cases;
//! on failure it reports the case seed so the exact input reproduces with
//! `case_rng(seed)`. Shrinking is out of scope — generators here produce
//! small instances by construction.

use super::rng::Rng;

/// Deterministic RNG for one case.
pub fn case_rng(case_seed: u64) -> Rng {
    Rng::seed_from_u64(case_seed ^ 0x505E_C1A1)
}

/// Run `f` over `n` cases; panics with the failing case seed.
pub fn for_cases(n: u64, f: impl Fn(&mut Rng)) {
    for case in 0..n {
        let seed = 0x5EED_0000u64 ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed on case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Random f32 vector with values in [lo, hi).
pub fn vec_f32(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len).map(|_| lo + (hi - lo) * rng.f64() as f32).collect()
}
