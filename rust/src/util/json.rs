//! Minimal JSON parser/serializer (std-only; this environment is offline,
//! so serde is unavailable — DESIGN.md §Substitutions).
//!
//! Supports the full JSON grammar needed by `meta.json`, checkpoints and
//! run manifests: objects, arrays, strings (with escapes), numbers, bools,
//! null. Numbers are kept as f64; integer accessors validate integrality.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (kept as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors ------------------------------------------------

    /// Object field access; errors when absent or not an object.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (wanted key {key:?})"),
        }
    }

    /// Optional object field (absent and `null` both yield None).
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    /// This value as a string.
    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// This value as a number.
    pub fn f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// This value as a non-negative integer.
    pub fn usize(&self) -> Result<usize> {
        let n = self.f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    /// This value as an integer.
    pub fn i64(&self) -> Result<i64> {
        let n = self.f64()?;
        if n.fract() != 0.0 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    /// This value as a bool.
    pub fn bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    /// This value as an array slice.
    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// This value as an object map.
    pub fn obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    // ---- writer ---------------------------------------------------------

    /// Serialize to a compact JSON string.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience object builder from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // copy the full utf-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated utf-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| anyhow!("bad number {s:?}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true, "e": null}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(j.get("a").unwrap().arr().unwrap()[1].f64().unwrap(), 2.5);
        assert_eq!(j.get("b").unwrap().get("c").unwrap().str().unwrap(), "x\ny");
        assert!(j.get("b").unwrap().get("d").unwrap().bool().unwrap());
        assert!(j.get("b").unwrap().opt("e").is_none());
        assert!(j.get("missing").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2,{"x":"a\"b"}],"n":-2.5,"t":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn integers_validate() {
        assert_eq!(Json::parse("42").unwrap().usize().unwrap(), 42);
        assert!(Json::parse("4.5").unwrap().usize().is_err());
        assert!(Json::parse("-1").unwrap().usize().is_err());
        assert_eq!(Json::parse("-7").unwrap().i64().unwrap(), -7);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.str().unwrap(), "Aé");
    }

    #[test]
    fn scientific_notation() {
        assert_eq!(Json::parse("1.5e-3").unwrap().f64().unwrap(), 1.5e-3);
    }
}
