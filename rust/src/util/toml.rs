//! Minimal TOML-subset parser for run configs (std-only, offline env).
//!
//! Supported grammar — exactly what `configs/*.toml` uses:
//! `[section]` headers (one level), `key = value` with string / integer /
//! float / bool values, `#` comments, blank lines. Produces a two-level
//! `section -> key -> value` map the config module consumes.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// One parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// A quoted string.
    Str(String),
    /// An integer.
    Int(i64),
    /// A float.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
}

impl TomlValue {
    /// This value as a string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// This value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    /// This value as a non-negative u64.
    pub fn as_u64(&self) -> Result<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as u64),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    /// This value as a number (ints coerce).
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// This value as a bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// One `[section]`'s key -> value map.
pub type Section = BTreeMap<String, TomlValue>;
/// A parsed document: section -> keys.
pub type TomlDoc = BTreeMap<String, Section>;

/// Parse a TOML-subset document. Keys before the first section header go
/// into the "" section.
pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut current = String::new();
    doc.insert(current.clone(), Section::new());
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .with_context(|| format!("line {}: malformed section header {raw:?}", lineno + 1))?
                .trim();
            if name.is_empty() || name.contains('[') {
                bail!("line {}: bad section name {name:?}", lineno + 1);
            }
            current = name.to_string();
            doc.entry(current.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value, got {raw:?}", lineno + 1))?;
        let key = k.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(v.trim())
            .with_context(|| format!("line {}: bad value for {key:?}", lineno + 1))?;
        doc.get_mut(&current).unwrap().insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').context("unterminated string")?;
        // basic escapes
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => bail!("bad escape \\{other:?}"),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(TomlValue::Str(out));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = s.replace('_', "");
    if !clean.contains(['.', 'e', 'E']) {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("unparseable value {s:?} (strings need quotes)")
}

/// Typed getters over one section with defaulting.
pub struct SectionView<'a> {
    /// Section name (for error messages).
    pub name: &'a str,
    /// The section's map, if the document has it.
    pub sec: Option<&'a Section>,
}

impl<'a> SectionView<'a> {
    /// View over `doc`'s section `name` (absent sections are fine).
    pub fn new(doc: &'a TomlDoc, name: &'a str) -> Self {
        Self { name, sec: doc.get(name) }
    }

    /// `key`'s value, or a descriptive missing-key error.
    pub fn required(&self, key: &str) -> Result<&'a TomlValue> {
        self.sec
            .and_then(|s| s.get(key))
            .with_context(|| format!("config missing [{}] {key}", self.name))
    }

    /// `key`'s value, if present.
    pub fn get(&self, key: &str) -> Option<&'a TomlValue> {
        self.sec.and_then(|s| s.get(key))
    }

    /// `key` as string, defaulting when absent.
    pub fn str_or(&self, key: &str, default: &str) -> Result<String> {
        match self.get(key) {
            Some(v) => Ok(v.as_str()?.to_string()),
            None => Ok(default.to_string()),
        }
    }

    /// `key` as usize, defaulting when absent.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.as_usize(),
            None => Ok(default),
        }
    }

    /// `key` as u64, defaulting when absent.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.as_u64(),
            None => Ok(default),
        }
    }

    /// `key` as f64, defaulting when absent.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.as_f64(),
            None => Ok(default),
        }
    }

    /// `key` as bool, defaulting when absent.
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            Some(v) => v.as_bool(),
            None => Ok(default),
        }
    }

    /// `key` as optional string.
    pub fn opt_str(&self, key: &str) -> Result<Option<String>> {
        match self.get(key) {
            Some(v) => Ok(Some(v.as_str()?.to_string())),
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
        # top comment
        [run]
        name = "setting_a"   # inline comment
        seed = 3
        lr = 5e-6
        big = 1_000_000
        neg = -2.5
        flag = true
        path = "a#b"

        [hwsim]
        workers = 8
    "#;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(DOC).unwrap();
        let run = SectionView::new(&doc, "run");
        assert_eq!(run.required("name").unwrap().as_str().unwrap(), "setting_a");
        assert_eq!(run.required("seed").unwrap().as_usize().unwrap(), 3);
        assert_eq!(run.required("lr").unwrap().as_f64().unwrap(), 5e-6);
        assert_eq!(run.required("big").unwrap().as_usize().unwrap(), 1_000_000);
        assert_eq!(run.required("neg").unwrap().as_f64().unwrap(), -2.5);
        assert!(run.required("flag").unwrap().as_bool().unwrap());
        assert_eq!(run.required("path").unwrap().as_str().unwrap(), "a#b");
        let hw = SectionView::new(&doc, "hwsim");
        assert_eq!(hw.usize_or("workers", 1).unwrap(), 8);
        assert_eq!(hw.usize_or("absent", 7).unwrap(), 7);
    }

    #[test]
    fn missing_section_uses_defaults() {
        let doc = parse("[run]\nname = \"x\"\n").unwrap();
        let sft = SectionView::new(&doc, "sft");
        assert!(sft.sec.is_none());
        assert_eq!(sft.usize_or("steps", 0).unwrap(), 0);
        assert!(sft.required("steps").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[run\nname = 1").is_err());
        assert!(parse("[run]\nname measure").is_err());
        assert!(parse("[run]\nname = unquoted").is_err());
        assert!(parse("[run]\nname = \"open").is_err());
    }

    #[test]
    fn int_vs_float_distinction() {
        let doc = parse("[a]\ni = 3\nf = 3.0\n").unwrap();
        let a = SectionView::new(&doc, "a");
        assert!(matches!(a.required("i").unwrap(), TomlValue::Int(3)));
        assert!(matches!(a.required("f").unwrap(), TomlValue::Float(_)));
        // ints coerce to f64 where a float is wanted
        assert_eq!(a.required("i").unwrap().as_f64().unwrap(), 3.0);
    }
}
