//! Deterministic RNG (xoshiro256++ seeded via splitmix64) — std-only
//! replacement for rand/rand_chacha in this offline environment.
//!
//! Every consumer in the training stack seeds explicitly, so runs are
//! exactly replayable; statistical quality is far beyond what subset
//! sampling and shuffling need.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via the splitmix64 expansion (reference seeding).
    pub fn seed_from_u64(seed: u64) -> Self {
        // splitmix64 expansion, per Blackman & Vigna's reference seeding
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits (high half of `next_u64`).
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] (inclusive). Panics if lo > hi.
    pub fn gen_range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "gen_range: {lo} > {hi}");
        let span = (hi - lo) as u64 + 1;
        // unbiased via rejection on the top partial block
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + (v % span) as i64;
            }
        }
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        self.gen_range_inclusive(0, n as i64 - 1) as usize
    }

    /// Bernoulli draw with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn range_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(0);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range_inclusive(0, 9);
            assert!((0..=9).contains(&v));
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values should be hit");
        // negative ranges
        for _ in 0..100 {
            let v = r.gen_range_inclusive(-5, -2);
            assert!((-5..=-2).contains(&v));
        }
        // degenerate range
        assert_eq!(r.gen_range_inclusive(7, 7), 7);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::seed_from_u64(1);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(2);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "astronomically unlikely identity");
    }
}
