//! Std-only infrastructure substrates (this environment is offline; only
//! `xla` + `anyhow` resolve). DESIGN.md §Substitutions documents each:
//!
//! * [`json`] — JSON parser/writer (serde_json stand-in) for meta.json,
//!   checkpoints, manifests.
//! * [`toml`] — TOML-subset parser (toml crate stand-in) for run configs.
//! * [`rng`] — xoshiro256++ deterministic RNG (rand/rand_chacha stand-in).
//! * [`prop`] — seeded property-testing harness (proptest stand-in).
//! * [`bench`] — timing harness (criterion stand-in) for `cargo bench`.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod toml;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique scratch directory under the system temp dir (tempfile stand-in).
/// Removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh scratch directory.
    pub fn new() -> std::io::Result<Self> {
        let n = TEMP_COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "pods-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.subsec_nanos())
                .unwrap_or(0)
        ));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    /// The directory's path.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_creates_and_cleans() {
        let p;
        {
            let d = TempDir::new().unwrap();
            p = d.path().to_path_buf();
            assert!(p.exists());
            std::fs::write(p.join("x"), b"hi").unwrap();
        }
        assert!(!p.exists());
    }
}
