//! Slot-based continuous-batching decode driver.
//!
//! The monolithic `rollout` program decoded a fixed `G`-step scan for
//! every row of every call — a rollout that finished in 10 tokens still
//! paid `G` attention passes, and a partially-filled batch paid them for
//! filler rows too. This driver rebuilds generation on the split
//! `prefill` / `decode_chunk` programs:
//!
//! * `B_r` **slots** decode in lock-step, `C` tokens per call, with the
//!   KV caches carried across calls as XLA literals;
//! * between chunks, rows that emitted EOS (or hit the budget `G`)
//!   **retire** and queued rows are **admitted** into the freed slots
//!   (prefill on admission, caches merged on device by `admit_merge`);
//! * an optional [`PruneHook`] (online selection-aware pruning, see
//!   [`crate::coordinator::select::online`]) is consulted at the same
//!   boundary: rows it declares doomed are **aborted** exactly like EOS
//!   retirement — slot freed for refill, the released decode budget
//!   counted as `gen_tokens_pruned`;
//! * the loop **exits early** the moment every slot is drained — decode
//!   work is proportional to actual generated tokens rounded up to the
//!   chunk size, not `rows × G`.
//!
//! Per-row RNG makes this sound: each row's token stream is a
//! counter-based function of its own seed, so chunk size, slot
//! assignment and refill order cannot change what any row samples
//! (pinned by `python/tests/test_chunked.py` and the Rust goldens).
//!
//! With a [`KvPolicy`] the driver additionally models KV memory as a
//! first-class resource: a group's prompt prefill runs **once**
//! (`prefill_shared`) and sibling rows are admitted by replicating the
//! group's cached prompt state on device (`admit_share` — no prompt pass,
//! no host round-trip), while a paged [`KvPool`] gates admission
//! vLLM-style — a queued row is admitted only when its modeled pages fit,
//! prompt pages are counted once per resident group, and pages free on
//! retire/abort. Sharing cannot change any stream: prefill is per-row
//! independent, the prompt region of the cache is immutable during
//! decode, and sampling folds `(row_seed, step)` only (pinned by the
//! `kv_golden` suite).
//!
//! Adaptive `[budget]` rollouts compose with all of the above without
//! touching this driver: the rollout engine runs it once for the probe
//! wave, consults the [`crate::coordinator::scheduler::BudgetAllocator`]
//! at the collection barrier, and runs it again for the granted extra
//! rows — each wave is an ordinary row queue here, so pruning, KV
//! admission and refill order apply to extra rows exactly as to probe
//! rows, and per-row RNG keeps every stream independent of which wave
//! decoded it (pinned by the `budget_golden` suite).

use crate::hwsim::{HwModel, KvPool};
use crate::runtime::{DecodeState, Engine, TensorI};
use crate::tasks::{tokenizer as tok, Problem};
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;

/// When freed slots are refilled from the row queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RefillMode {
    /// Admit queued rows into freed slots between chunks (default) — the
    /// batch stays as full as the queue allows.
    #[default]
    Continuous,
    /// Drain the whole batch before admitting the next `B_r` rows — the
    /// legacy call-shaped behaviour, kept as a comparison arm.
    Batch,
}

impl RefillMode {
    /// Parse a `[rollout] refill` value (`continuous` | `batch`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "continuous" => Ok(Self::Continuous),
            "batch" => Ok(Self::Batch),
            other => Err(anyhow!("unknown rollout.refill {other:?} (continuous|batch)")),
        }
    }

    /// Canonical name used in configs and logs.
    pub fn name(self) -> &'static str {
        match self {
            Self::Continuous => "continuous",
            Self::Batch => "batch",
        }
    }
}

/// One queued generation row: which prompt group it belongs to, its index
/// within the group, and its private RNG seed.
#[derive(Debug, Clone, Copy)]
pub struct RowSpec {
    /// Prompt group this row generates for.
    pub group_idx: usize,
    /// Index of this rollout within its group.
    pub rollout_idx: usize,
    /// Private RNG seed of the row's counter-based stream.
    pub seed: i32,
}

/// One finished row, in the same layout the monolithic program produced.
#[derive(Debug, Clone)]
pub struct RowOut {
    /// Prompt group this row generated for.
    pub group_idx: usize,
    /// Index of this rollout within its group.
    pub rollout_idx: usize,
    /// Left-padding length of the prompt region.
    pub pad_len: i32,
    /// i32[T]: prompt + generation, PAD after EOS.
    pub tokens: Vec<i32>,
    /// f32[G]: behaviour log-probs (0 after EOS).
    pub logprobs: Vec<f32>,
    /// f32[G]: 1.0 through EOS, 0.0 after.
    pub gen_mask: Vec<f32>,
    /// Generated tokens incl. EOS.
    pub gen_len: i32,
    /// The row was aborted mid-decode by the prune hook (no EOS;
    /// `gen_len` is the truncated decoded length). Sound by the doom-only
    /// contract: an aborted row can never survive post-hoc selection.
    pub aborted: bool,
}

/// Between-chunk online-pruning hook for the decode driver.
///
/// The driver consults it at every chunk boundary: retirements are
/// reported through [`Self::on_retired`] and every live (or about to be
/// admitted) row is polled through [`Self::should_abort`] — a `true`
/// answer aborts the row at this boundary, freeing its slot. The hook
/// must be **doom-only sound**: it may only abort rows that can never
/// appear in the selected subset (see `docs/DETERMINISM.md`).
pub trait PruneHook {
    /// A row retired normally (EOS or budget); observe its final state.
    fn on_retired(&self, row: &RowOut);

    /// Poll one row: `gen_len` is its generated-token count so far (0 for
    /// a row still queued). Return `true` to abort it at this boundary.
    fn should_abort(&self, group_idx: usize, rollout_idx: usize, gen_len: usize) -> bool;
}

/// Group-shared prompt-KV and paged-pool admission policy for the decode
/// driver. [`Default`] is the legacy behaviour: per-row prompt prefill,
/// zero modeled page sizes, and an unbounded pool (admission never
/// blocks on memory).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvPolicy {
    /// Prefill each group's prompt once and admit sibling rows by
    /// replicating the on-device snapshot (`[rollout] share_prompt_kv`).
    pub share_prompt_kv: bool,
    /// Page-rounded KV bytes of one prompt segment (`P` tokens).
    pub prompt_bytes: u64,
    /// Page-rounded KV bytes of one generation-budget reservation: the
    /// driver reserves the full budget `G` at admission (a row may retire
    /// early, but the reservation keeps admission deterministic).
    pub gen_bytes: u64,
    /// Modeled pool capacity in bytes (`hwsim.kv_pool_bytes`;
    /// 0 = unbounded).
    pub pool_bytes: u64,
}

impl KvPolicy {
    /// Build the policy from the hardware model's paged-KV parameters:
    /// page-rounded prompt/generation segments ([`HwModel::kv_seg_bytes`])
    /// and the configured pool capacity.
    pub fn from_model(hw: &HwModel, share_prompt_kv: bool, prompt_len: usize, gen_len: usize) -> Self {
        Self {
            share_prompt_kv,
            prompt_bytes: hw.kv_seg_bytes(prompt_len),
            gen_bytes: hw.kv_seg_bytes(gen_len),
            pool_bytes: hw.kv_pool_bytes,
        }
    }
}

/// Typed admission dead-end: the modeled KV pool cannot hold even a
/// single decode row, so the driver can never make progress. Raised as a
/// hard error (the loud legacy behaviour); the fault-tolerance layer
/// downcasts it ([`anyhow::Error::downcast_ref`]) and accounts the
/// affected rows as admission faults instead of aborting the run when
/// `[faults]` is enabled.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KvAdmissionError {
    /// Configured pool capacity (`hwsim.kv_pool_bytes`).
    pub capacity: u64,
    /// Prompt group of the queue head that could not be admitted.
    pub group_idx: usize,
    /// Bytes the queue head needed to admit.
    pub needed: u64,
    /// Page-rounded prompt-segment bytes of the request.
    pub prompt_bytes: u64,
    /// Page-rounded generation-reservation bytes of the request.
    pub gen_bytes: u64,
}

impl std::fmt::Display for KvAdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "hwsim.kv_pool_bytes = {} cannot hold a single decode row: the \
             queue head (group {}) needs {} bytes (prompt pages {} + \
             generation reservation {}); raise kv_pool_bytes (0 = unbounded)",
            self.capacity, self.group_idx, self.needed, self.prompt_bytes, self.gen_bytes
        )
    }
}

impl std::error::Error for KvAdmissionError {}

/// Engine-call accounting for one driver run.
#[derive(Debug, Clone, Copy, Default)]
pub struct DecodeStats {
    /// `prefill` program invocations.
    pub prefill_calls: usize,
    /// `decode_chunk` program invocations.
    pub chunk_calls: usize,
    /// On-device slot-admission merges (one per refill event after the
    /// initial fill).
    pub merge_calls: usize,
    /// Decode-step slots actually executed: `B_r × C` per chunk call —
    /// the physical work, including post-EOS and filler slots.
    pub gen_tokens_decoded: usize,
    /// Decode budget released by online pruning: for every aborted row,
    /// the generation budget `G` minus what it had decoded at the abort
    /// boundary (an upper bound on the work saved — the row might have
    /// emitted EOS before `G` on its own).
    pub gen_tokens_pruned: usize,
    /// Rows aborted mid-decode (or pruned before admission) by the hook.
    pub rows_pruned: usize,
    /// Prefill calls avoided by group-shared prompt KV: refill events
    /// served by replicating the group's snapshot (`admit_share`) instead
    /// of running a prompt pass.
    pub prefill_calls_saved: usize,
    /// High-water mark of the modeled KV pool over the run, in bytes
    /// (0 when the policy models no page sizes).
    pub kv_peak_bytes: u64,
}

/// Per-slot bookkeeping for a row mid-decode.
struct Slot {
    row: usize, // index into `rows`
    tokens: Vec<i32>,
    logprobs: Vec<f32>,
    gen_mask: Vec<f32>,
    prompt_row: Vec<i32>,
}

/// Left-pad one prompt to `[P]`.
fn pad_prompt(prompt: &[i32], p: usize) -> Result<(Vec<i32>, i32)> {
    if prompt.len() > p {
        bail!("prompt of {} tokens exceeds prompt_len {p}", prompt.len());
    }
    let pad = p - prompt.len();
    let mut row = vec![tok::PAD; pad];
    row.extend_from_slice(prompt);
    Ok((row, pad as i32))
}

struct Driver<'a> {
    engine: &'a Engine,
    params: &'a [f32],
    lora: Option<&'a [f32]>,
    rows: &'a [RowSpec],
    problems: &'a [Problem],
    hook: Option<&'a dyn PruneHook>,
    b: usize,
    p: usize,
    g: usize,
    queue: VecDeque<usize>,
    slots: Vec<Option<Slot>>,
    // program-visible per-slot state (host mirrors)
    seeds: Vec<i32>,
    step: Vec<i32>,
    done: Vec<i32>,
    pads: Vec<i32>,
    state: Option<DecodeState>,
    outs: Vec<Option<RowOut>>,
    stats: DecodeStats,
    // group-shared prompt KV + paged admission (KvPolicy::default() = off)
    kv: KvPolicy,
    pool: KvPool,
    /// The last prefilled group's on-device prompt snapshot; siblings
    /// admit from it via `admit_share`. The group-major queue guarantees
    /// at most one group ever straddles refill events, so one slot of
    /// history is enough.
    snapshot: Option<(usize, DecodeState)>,
    /// Per-group: are the group's shared prompt pages resident in the pool?
    prompt_resident: Vec<bool>,
    /// Per-group references to the shared prompt pages: resident rows plus
    /// the snapshot hold; pages free when the count drops to zero.
    prompt_refs: Vec<usize>,
    /// Pool bytes owned by each slot's row (freed on retire/abort).
    slot_bytes: Vec<u64>,
}

impl<'a> Driver<'a> {
    /// Pool bytes the queue-head row of group `g` must allocate to admit:
    /// its generation-budget reservation plus — unless the group's shared
    /// prompt pages are already resident — the prompt segment.
    fn admit_need(&self, g: usize) -> u64 {
        if self.kv.share_prompt_kv && self.prompt_resident[g] {
            self.kv.gen_bytes
        } else {
            self.kv.prompt_bytes + self.kv.gen_bytes
        }
    }

    /// Record an admitted row's allocation: the generation reservation is
    /// owned by the slot (freed on retire/abort); under sharing the prompt
    /// segment is owned by the group and freed with its last reference.
    fn alloc_row(&mut self, s: usize, g: usize, need: u64) {
        self.pool.alloc(need);
        if self.kv.share_prompt_kv {
            self.prompt_resident[g] = true;
            self.prompt_refs[g] += 1;
            self.slot_bytes[s] = self.kv.gen_bytes;
        } else {
            self.slot_bytes[s] = need;
        }
    }

    /// Drop one reference to group `g`'s shared prompt pages (a resident
    /// row retired/aborted, or the snapshot hold moved on); the pages
    /// return to the pool when the last reference is gone.
    fn unref_prompt(&mut self, g: usize) {
        if !self.kv.share_prompt_kv {
            return;
        }
        self.prompt_refs[g] -= 1;
        if self.prompt_refs[g] == 0 && self.prompt_resident[g] {
            self.pool.free(self.kv.prompt_bytes);
            self.prompt_resident[g] = false;
        }
    }

    /// Return a retiring/aborting slot's KV pages to the pool.
    fn free_slot(&mut self, s: usize, g: usize) {
        self.pool.free(self.slot_bytes[s]);
        self.slot_bytes[s] = 0;
        if self.kv.share_prompt_kv {
            self.unref_prompt(g);
        }
    }

    /// Admit queued rows into `free` slots. Without prompt sharing: one
    /// prefill call carrying the new prompts in their target slots (other
    /// slots repeat the first new prompt — filler that stays masked done),
    /// then an on-device merge of the admitted slots into the carried
    /// state. With sharing: per-group admission — the group's first
    /// admission runs one `prefill_shared` (every batch slot carries the
    /// group prompt, so every snapshot slot holds the same state) and
    /// later refills replicate the snapshot via `admit_share`. Admission
    /// is gated by the modeled KV pool: the queue head blocks when its
    /// pages don't fit (head-of-line, so the schedule stays deterministic).
    fn admit(&mut self, free: &[usize]) -> Result<()> {
        let mut admitted: Vec<(usize, usize)> = Vec::new(); // (slot, row)
        'slots: for &s in free {
            // rows doomed while still queued are pruned without ever
            // being admitted: no prefill, no decode — the whole budget
            // counts as released
            loop {
                let Some(&r) = self.queue.front() else { break 'slots };
                let spec = self.rows[r];
                if self
                    .hook
                    .is_some_and(|h| h.should_abort(spec.group_idx, spec.rollout_idx, 0))
                {
                    self.queue.pop_front();
                    self.emit_pruned_unadmitted(r)?;
                    continue;
                }
                if !self.pool.can_admit(self.admit_need(spec.group_idx)) {
                    // a snapshot of a *different* group can never serve a
                    // future admission (the group-major queue has moved
                    // past it) — drop its hold before giving up
                    if let Some((sg, _)) = &self.snapshot {
                        if *sg != spec.group_idx {
                            let (sg, _) = self.snapshot.take().expect("checked");
                            self.unref_prompt(sg);
                        }
                    }
                    if !self.pool.can_admit(self.admit_need(spec.group_idx)) {
                        break 'slots;
                    }
                }
                self.queue.pop_front();
                self.alloc_row(s, spec.group_idx, self.admit_need(spec.group_idx));
                admitted.push((s, r));
                break;
            }
        }
        if admitted.is_empty() {
            return Ok(());
        }
        let (b, p) = (self.b, self.p);
        if self.kv.share_prompt_kv {
            // per-group runs in admission order (contiguous for a
            // group-major queue, but correct for any order)
            let mut runs: Vec<(usize, Vec<usize>)> = Vec::new(); // (group, slots)
            for &(s, r) in &admitted {
                let g = self.rows[r].group_idx;
                match runs.last_mut() {
                    Some((rg, slots)) if *rg == g => slots.push(s),
                    _ => runs.push((g, vec![s])),
                }
            }
            for (g, run_slots) in runs {
                let mut mask = vec![0i32; b];
                for &s in &run_slots {
                    mask[s] = 1;
                }
                if self.snapshot.as_ref().is_some_and(|(sg, _)| *sg == g) {
                    // sibling admission: replicate the group's cached
                    // prompt state on device — no prompt pass runs
                    let (sg, snap) = self.snapshot.take().expect("checked");
                    let live =
                        self.state.take().expect("a held snapshot implies a carried state");
                    let (merged, snap) = self.engine.admit_share(live, snap, &mask)?;
                    self.state = Some(merged);
                    self.snapshot = Some((sg, snap));
                    self.stats.merge_calls += 1;
                    self.stats.prefill_calls_saved += 1;
                } else {
                    // first admission of this group: one shared prompt
                    // pass returning the state twice (working + snapshot);
                    // every slot carries the group prompt so every
                    // snapshot slot holds the same prompt state
                    let (prompt_row, pad) = pad_prompt(&self.problems[g].prompt, p)?;
                    let mut batch = vec![tok::PAD; b * p];
                    for s in 0..b {
                        batch[s * p..(s + 1) * p].copy_from_slice(&prompt_row);
                    }
                    let prompts = TensorI::new(batch, &[b, p])?;
                    let (fresh, snap) = self.engine.prefill_shared(
                        self.params,
                        self.lora,
                        &prompts,
                        &vec![pad; b],
                    )?;
                    self.stats.prefill_calls += 1;
                    match self.state.take() {
                        None => self.state = Some(fresh),
                        Some(live) => {
                            self.state = Some(self.engine.admit_merge(live, fresh, &mask)?);
                            self.stats.merge_calls += 1;
                        }
                    }
                    // the snapshot hold moves to this group; the old
                    // group's pages free once its last resident row does
                    if let Some((old, _)) = self.snapshot.take() {
                        self.unref_prompt(old);
                    }
                    self.prompt_refs[g] += 1;
                    self.snapshot = Some((g, snap));
                }
            }
        } else {
            let (filler, filler_pad) =
                pad_prompt(&self.problems[self.rows[admitted[0].1].group_idx].prompt, p)?;
            let mut batch = vec![tok::PAD; b * p];
            let mut batch_pads = vec![filler_pad; b];
            for s in 0..b {
                batch[s * p..(s + 1) * p].copy_from_slice(&filler);
            }
            for &(s, r) in &admitted {
                let (row, pad) = pad_prompt(&self.problems[self.rows[r].group_idx].prompt, p)?;
                batch[s * p..(s + 1) * p].copy_from_slice(&row);
                batch_pads[s] = pad;
            }
            let prompts = TensorI::new(batch, &[b, p])?;
            let fresh = self.engine.prefill(self.params, self.lora, &prompts, &batch_pads)?;
            self.stats.prefill_calls += 1;
            match self.state.take() {
                None => self.state = Some(fresh),
                Some(live) => {
                    // on-device merge: admitted slots take the fresh prefill
                    // state, the rest keep their carried caches — no host
                    // cache round-trip
                    let mut mask = vec![0i32; b];
                    for &(s, _) in &admitted {
                        mask[s] = 1;
                    }
                    self.state = Some(self.engine.admit_merge(live, fresh, &mask)?);
                    self.stats.merge_calls += 1;
                }
            }
        }
        for (s, r) in admitted {
            let (prompt_row, pad) = pad_prompt(&self.problems[self.rows[r].group_idx].prompt, p)?;
            self.seeds[s] = self.rows[r].seed;
            self.step[s] = 0;
            self.done[s] = 0;
            self.pads[s] = pad;
            self.slots[s] = Some(Slot {
                row: r,
                tokens: vec![tok::PAD; self.g],
                logprobs: vec![0.0; self.g],
                gen_mask: vec![0.0; self.g],
                prompt_row,
            });
        }
        Ok(())
    }

    /// A row pruned while still queued: emit an empty aborted record (the
    /// prompt region padded, nothing generated) without prefill or decode.
    fn emit_pruned_unadmitted(&mut self, r: usize) -> Result<()> {
        let spec = self.rows[r];
        let (mut tokens, pad) = pad_prompt(&self.problems[spec.group_idx].prompt, self.p)?;
        tokens.resize(self.p + self.g, tok::PAD);
        self.outs[r] = Some(RowOut {
            group_idx: spec.group_idx,
            rollout_idx: spec.rollout_idx,
            pad_len: pad,
            tokens,
            logprobs: vec![0.0; self.g],
            gen_mask: vec![0.0; self.g],
            gen_len: 0,
            aborted: true,
        });
        self.stats.rows_pruned += 1;
        self.stats.gen_tokens_pruned += self.g;
        Ok(())
    }

    /// Retire finished slots into `outs`; returns how many were freed.
    fn retire(&mut self) -> usize {
        let mut freed = 0;
        for s in 0..self.b {
            let finished = self.slots[s].is_some()
                && (self.done[s] != 0 || self.step[s] >= self.g as i32);
            if finished {
                let slot = self.slots[s].take().expect("checked");
                let spec = self.rows[slot.row];
                let gen_len = slot.gen_mask.iter().sum::<f32>() as i32;
                let mut tokens = slot.prompt_row;
                tokens.extend_from_slice(&slot.tokens);
                let out = RowOut {
                    group_idx: spec.group_idx,
                    rollout_idx: spec.rollout_idx,
                    pad_len: self.pads[s],
                    tokens,
                    logprobs: slot.logprobs,
                    gen_mask: slot.gen_mask,
                    gen_len,
                    aborted: false,
                };
                if let Some(hook) = self.hook {
                    hook.on_retired(&out);
                }
                self.outs[slot.row] = Some(out);
                self.done[s] = 1;
                self.free_slot(s, spec.group_idx);
                freed += 1;
            }
        }
        freed
    }

    /// Abort live rows the hook has declared doomed — exactly like EOS
    /// retirement (the slot frees for refill), but the row is marked
    /// aborted and its remaining decode budget counts as pruned. Returns
    /// how many slots were freed.
    fn abort_doomed(&mut self) -> usize {
        let Some(hook) = self.hook else { return 0 };
        let mut freed = 0;
        for s in 0..self.b {
            let Some(slot_ref) = &self.slots[s] else { continue };
            let spec = self.rows[slot_ref.row];
            // live rows have not passed EOS, so `step` is their generated
            // count so far (monotone across chunks)
            let len = self.step[s].max(0) as usize;
            if !hook.should_abort(spec.group_idx, spec.rollout_idx, len) {
                continue;
            }
            let slot = self.slots[s].take().expect("checked");
            let gen_len = slot.gen_mask.iter().sum::<f32>() as i32;
            let mut tokens = slot.prompt_row;
            tokens.extend_from_slice(&slot.tokens);
            self.outs[slot.row] = Some(RowOut {
                group_idx: spec.group_idx,
                rollout_idx: spec.rollout_idx,
                pad_len: self.pads[s],
                tokens,
                logprobs: slot.logprobs,
                gen_mask: slot.gen_mask,
                gen_len,
                aborted: true,
            });
            self.done[s] = 1;
            self.free_slot(s, spec.group_idx);
            self.stats.rows_pruned += 1;
            self.stats.gen_tokens_pruned += self.g.saturating_sub(gen_len.max(0) as usize);
            freed += 1;
        }
        freed
    }

    /// Admission made no progress while rows remain queued: with every
    /// slot drained and its pages freed, the queue head can never fit —
    /// fail loudly instead of silently under-delivering rows.
    fn check_admission_progress(&self) -> Result<()> {
        if self.slots.iter().all(|s| s.is_none()) {
            if let Some(&r) = self.queue.front() {
                let g = self.rows[r].group_idx;
                return Err(anyhow::Error::new(KvAdmissionError {
                    capacity: self.pool.capacity(),
                    group_idx: g,
                    needed: self.admit_need(g),
                    prompt_bytes: self.kv.prompt_bytes,
                    gen_bytes: self.kv.gen_bytes,
                }));
            }
        }
        Ok(())
    }

    fn run(&mut self, chunk: usize, refill: RefillMode, temperature: f32) -> Result<()> {
        let all: Vec<usize> = (0..self.b).collect();
        self.admit(&all)?;
        self.check_admission_progress()?;
        while self.slots.iter().any(|s| s.is_some()) {
            let st = self.state.take().expect("live slots imply a carried state");
            let prev_step = self.step.clone();
            let (st, out) = self.engine.decode_chunk(
                chunk,
                self.params,
                self.lora,
                st,
                &self.seeds,
                &self.step,
                &self.done,
                &self.pads,
                temperature,
            )?;
            self.state = Some(st);
            self.stats.chunk_calls += 1;
            self.stats.gen_tokens_decoded += self.b * chunk;
            self.step.copy_from_slice(&out.step);
            self.done.copy_from_slice(&out.done);

            // harvest the masked outputs into each live row's stream
            for (s, slot) in self.slots.iter_mut().enumerate() {
                let Some(slot) = slot.as_mut() else { continue };
                for j in 0..chunk {
                    let gi = prev_step[s] as usize + j;
                    if gi >= self.g {
                        break;
                    }
                    if out.mask[s * chunk + j] > 0.0 {
                        slot.tokens[gi] = out.tokens[s * chunk + j];
                        slot.logprobs[gi] = out.logprobs[s * chunk + j];
                        slot.gen_mask[gi] = out.mask[s * chunk + j];
                    }
                }
            }

            let freed = self.retire() + self.abort_doomed();
            // refill freed slots (continuous), or wait for a full drain
            let drained = self.slots.iter().all(|s| s.is_none());
            if freed > 0
                && !self.queue.is_empty()
                && (refill == RefillMode::Continuous || drained)
            {
                let free: Vec<usize> =
                    (0..self.b).filter(|&s| self.slots[s].is_none()).collect();
                self.admit(&free)?;
                self.check_admission_progress()?;
            }
        }
        // release the final snapshot hold so the ledger drains to zero
        if let Some((g, _)) = self.snapshot.take() {
            self.unref_prompt(g);
        }
        self.stats.kv_peak_bytes = self.pool.peak();
        Ok(())
    }
}

/// Decode every row of `rows` (prompts looked up in `problems` via
/// `group_idx`) with `B_r`-slot continuous batching, `chunk` tokens per
/// call. Returns the finished rows **in input order** plus call stats.
pub fn decode_rows(
    engine: &Engine,
    params: &[f32],
    lora: Option<&[f32]>,
    temperature: f32,
    chunk: usize,
    refill: RefillMode,
    rows: &[RowSpec],
    problems: &[Problem],
) -> Result<(Vec<RowOut>, DecodeStats)> {
    decode_rows_hooked(engine, params, lora, temperature, chunk, refill, rows, problems, None)
}

/// [`decode_rows`] with an online-pruning hook: the driver polls it at
/// every chunk boundary and aborts rows it declares doomed (see
/// [`PruneHook`]). `hook = None` is exactly [`decode_rows`].
#[allow(clippy::too_many_arguments)]
pub fn decode_rows_hooked(
    engine: &Engine,
    params: &[f32],
    lora: Option<&[f32]>,
    temperature: f32,
    chunk: usize,
    refill: RefillMode,
    rows: &[RowSpec],
    problems: &[Problem],
    hook: Option<&dyn PruneHook>,
) -> Result<(Vec<RowOut>, DecodeStats)> {
    decode_rows_kv(
        engine,
        params,
        lora,
        temperature,
        chunk,
        refill,
        rows,
        problems,
        hook,
        KvPolicy::default(),
    )
}

/// [`decode_rows_hooked`] with an explicit [`KvPolicy`]: group-shared
/// prompt prefill and paged-pool admission gating.
/// `KvPolicy::default()` reproduces [`decode_rows_hooked`] exactly; with
/// `share_prompt_kv` the emitted rows are bit-identical either way (the
/// `kv_golden` suite pins this) — only the engine-call mix, the pool
/// telemetry, and the wall-clock change.
#[allow(clippy::too_many_arguments)]
pub fn decode_rows_kv(
    engine: &Engine,
    params: &[f32],
    lora: Option<&[f32]>,
    temperature: f32,
    chunk: usize,
    refill: RefillMode,
    rows: &[RowSpec],
    problems: &[Problem],
    hook: Option<&dyn PruneHook>,
    kv: KvPolicy,
) -> Result<(Vec<RowOut>, DecodeStats)> {
    let meta = &engine.meta;
    if meta.decode_chunks.is_empty() {
        bail!(
            "profile {} has no decode_chunk programs — artifacts predate the \
             chunked decode path; re-run `make artifacts`",
            meta.profile
        );
    }
    if !meta.decode_chunks.contains(&chunk) {
        bail!(
            "rollout.decode_chunk = {chunk} is not lowered for profile {} \
             (available: {:?})",
            meta.profile,
            meta.decode_chunks
        );
    }
    if rows.is_empty() {
        return Ok((Vec::new(), DecodeStats::default()));
    }
    let b = meta.config.rollout_batch;
    let mut driver = Driver {
        engine,
        params,
        lora,
        rows,
        problems,
        hook,
        b,
        p: meta.config.prompt_len,
        g: meta.gen_len,
        queue: (0..rows.len()).collect(),
        slots: (0..b).map(|_| None).collect(),
        seeds: vec![0; b],
        step: vec![0; b],
        done: vec![1; b], // empty slots stay done
        pads: vec![meta.config.prompt_len as i32; b],
        state: None,
        outs: (0..rows.len()).map(|_| None).collect(),
        stats: DecodeStats::default(),
        kv,
        pool: KvPool::new(kv.pool_bytes),
        snapshot: None,
        prompt_resident: vec![false; problems.len()],
        prompt_refs: vec![0; problems.len()],
        slot_bytes: vec![0; b],
    };
    driver.run(chunk, refill, temperature)?;
    let mut finished = Vec::with_capacity(rows.len());
    for (i, o) in driver.outs.into_iter().enumerate() {
        finished.push(o.ok_or_else(|| anyhow!("row {i} never retired (driver bug)"))?);
    }
    Ok((finished, driver.stats))
}
