//! Slot-based continuous-batching decode driver.
//!
//! The monolithic `rollout` program decoded a fixed `G`-step scan for
//! every row of every call — a rollout that finished in 10 tokens still
//! paid `G` attention passes, and a partially-filled batch paid them for
//! filler rows too. This driver rebuilds generation on the split
//! `prefill` / `decode_chunk` programs:
//!
//! * `B_r` **slots** decode in lock-step, `C` tokens per call, with the
//!   KV caches carried across calls as XLA literals;
//! * between chunks, rows that emitted EOS (or hit the budget `G`)
//!   **retire** and queued rows are **admitted** into the freed slots
//!   (prefill on admission, caches merged on device by `admit_merge`);
//! * an optional [`PruneHook`] (online selection-aware pruning, see
//!   [`crate::coordinator::select::online`]) is consulted at the same
//!   boundary: rows it declares doomed are **aborted** exactly like EOS
//!   retirement — slot freed for refill, the released decode budget
//!   counted as `gen_tokens_pruned`;
//! * the loop **exits early** the moment every slot is drained — decode
//!   work is proportional to actual generated tokens rounded up to the
//!   chunk size, not `rows × G`.
//!
//! Per-row RNG makes this sound: each row's token stream is a
//! counter-based function of its own seed, so chunk size, slot
//! assignment and refill order cannot change what any row samples
//! (pinned by `python/tests/test_chunked.py` and the Rust goldens).

use crate::runtime::{DecodeState, Engine, TensorI};
use crate::tasks::{tokenizer as tok, Problem};
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;

/// When freed slots are refilled from the row queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RefillMode {
    /// Admit queued rows into freed slots between chunks (default) — the
    /// batch stays as full as the queue allows.
    #[default]
    Continuous,
    /// Drain the whole batch before admitting the next `B_r` rows — the
    /// legacy call-shaped behaviour, kept as a comparison arm.
    Batch,
}

impl RefillMode {
    /// Parse a `[rollout] refill` value (`continuous` | `batch`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "continuous" => Ok(Self::Continuous),
            "batch" => Ok(Self::Batch),
            other => Err(anyhow!("unknown rollout.refill {other:?} (continuous|batch)")),
        }
    }

    /// Canonical name used in configs and logs.
    pub fn name(self) -> &'static str {
        match self {
            Self::Continuous => "continuous",
            Self::Batch => "batch",
        }
    }
}

/// One queued generation row: which prompt group it belongs to, its index
/// within the group, and its private RNG seed.
#[derive(Debug, Clone, Copy)]
pub struct RowSpec {
    /// Prompt group this row generates for.
    pub group_idx: usize,
    /// Index of this rollout within its group.
    pub rollout_idx: usize,
    /// Private RNG seed of the row's counter-based stream.
    pub seed: i32,
}

/// One finished row, in the same layout the monolithic program produced.
#[derive(Debug, Clone)]
pub struct RowOut {
    /// Prompt group this row generated for.
    pub group_idx: usize,
    /// Index of this rollout within its group.
    pub rollout_idx: usize,
    /// Left-padding length of the prompt region.
    pub pad_len: i32,
    /// i32[T]: prompt + generation, PAD after EOS.
    pub tokens: Vec<i32>,
    /// f32[G]: behaviour log-probs (0 after EOS).
    pub logprobs: Vec<f32>,
    /// f32[G]: 1.0 through EOS, 0.0 after.
    pub gen_mask: Vec<f32>,
    /// Generated tokens incl. EOS.
    pub gen_len: i32,
    /// The row was aborted mid-decode by the prune hook (no EOS;
    /// `gen_len` is the truncated decoded length). Sound by the doom-only
    /// contract: an aborted row can never survive post-hoc selection.
    pub aborted: bool,
}

/// Between-chunk online-pruning hook for the decode driver.
///
/// The driver consults it at every chunk boundary: retirements are
/// reported through [`Self::on_retired`] and every live (or about to be
/// admitted) row is polled through [`Self::should_abort`] — a `true`
/// answer aborts the row at this boundary, freeing its slot. The hook
/// must be **doom-only sound**: it may only abort rows that can never
/// appear in the selected subset (see `docs/DETERMINISM.md`).
pub trait PruneHook {
    /// A row retired normally (EOS or budget); observe its final state.
    fn on_retired(&self, row: &RowOut);

    /// Poll one row: `gen_len` is its generated-token count so far (0 for
    /// a row still queued). Return `true` to abort it at this boundary.
    fn should_abort(&self, group_idx: usize, rollout_idx: usize, gen_len: usize) -> bool;
}

/// Engine-call accounting for one driver run.
#[derive(Debug, Clone, Copy, Default)]
pub struct DecodeStats {
    /// `prefill` program invocations.
    pub prefill_calls: usize,
    /// `decode_chunk` program invocations.
    pub chunk_calls: usize,
    /// On-device slot-admission merges (one per refill event after the
    /// initial fill).
    pub merge_calls: usize,
    /// Decode-step slots actually executed: `B_r × C` per chunk call —
    /// the physical work, including post-EOS and filler slots.
    pub gen_tokens_decoded: usize,
    /// Decode budget released by online pruning: for every aborted row,
    /// the generation budget `G` minus what it had decoded at the abort
    /// boundary (an upper bound on the work saved — the row might have
    /// emitted EOS before `G` on its own).
    pub gen_tokens_pruned: usize,
    /// Rows aborted mid-decode (or pruned before admission) by the hook.
    pub rows_pruned: usize,
}

/// Per-slot bookkeeping for a row mid-decode.
struct Slot {
    row: usize, // index into `rows`
    tokens: Vec<i32>,
    logprobs: Vec<f32>,
    gen_mask: Vec<f32>,
    prompt_row: Vec<i32>,
}

/// Left-pad one prompt to `[P]`.
fn pad_prompt(prompt: &[i32], p: usize) -> Result<(Vec<i32>, i32)> {
    if prompt.len() > p {
        bail!("prompt of {} tokens exceeds prompt_len {p}", prompt.len());
    }
    let pad = p - prompt.len();
    let mut row = vec![tok::PAD; pad];
    row.extend_from_slice(prompt);
    Ok((row, pad as i32))
}

struct Driver<'a> {
    engine: &'a Engine,
    params: &'a [f32],
    lora: Option<&'a [f32]>,
    rows: &'a [RowSpec],
    problems: &'a [Problem],
    hook: Option<&'a dyn PruneHook>,
    b: usize,
    p: usize,
    g: usize,
    queue: VecDeque<usize>,
    slots: Vec<Option<Slot>>,
    // program-visible per-slot state (host mirrors)
    seeds: Vec<i32>,
    step: Vec<i32>,
    done: Vec<i32>,
    pads: Vec<i32>,
    state: Option<DecodeState>,
    outs: Vec<Option<RowOut>>,
    stats: DecodeStats,
}

impl<'a> Driver<'a> {
    /// Admit queued rows into `free` slots: one prefill call carrying the
    /// new prompts in their target slots (other slots repeat the first new
    /// prompt — filler that stays masked done), then merge the admitted
    /// slots' cache blocks and logits rows into the carried state.
    fn admit(&mut self, free: &[usize]) -> Result<()> {
        let mut admitted: Vec<(usize, usize)> = Vec::new(); // (slot, row)
        for &s in free {
            // rows doomed while still queued are pruned without ever
            // being admitted: no prefill, no decode — the whole budget
            // counts as released
            loop {
                let Some(r) = self.queue.pop_front() else { break };
                let spec = self.rows[r];
                if self
                    .hook
                    .is_some_and(|h| h.should_abort(spec.group_idx, spec.rollout_idx, 0))
                {
                    self.emit_pruned_unadmitted(r)?;
                    continue;
                }
                admitted.push((s, r));
                break;
            }
        }
        if admitted.is_empty() {
            return Ok(());
        }
        let (b, p) = (self.b, self.p);
        let (filler, filler_pad) =
            pad_prompt(&self.problems[self.rows[admitted[0].1].group_idx].prompt, p)?;
        let mut batch = vec![tok::PAD; b * p];
        let mut batch_pads = vec![filler_pad; b];
        for s in 0..b {
            batch[s * p..(s + 1) * p].copy_from_slice(&filler);
        }
        let mut slot_rows: Vec<(Vec<i32>, i32)> = Vec::with_capacity(admitted.len());
        for &(s, r) in &admitted {
            let (row, pad) = pad_prompt(&self.problems[self.rows[r].group_idx].prompt, p)?;
            batch[s * p..(s + 1) * p].copy_from_slice(&row);
            batch_pads[s] = pad;
            slot_rows.push((row, pad));
        }
        let prompts = TensorI::new(batch, &[b, p])?;
        let fresh = self.engine.prefill(self.params, self.lora, &prompts, &batch_pads)?;
        self.stats.prefill_calls += 1;
        match self.state.take() {
            None => self.state = Some(fresh),
            Some(live) => {
                // on-device merge: admitted slots take the fresh prefill
                // state, the rest keep their carried caches — no host
                // cache round-trip
                let mut mask = vec![0i32; b];
                for &(s, _) in &admitted {
                    mask[s] = 1;
                }
                self.state = Some(self.engine.admit_merge(live, fresh, &mask)?);
                self.stats.merge_calls += 1;
            }
        }
        for ((s, r), (prompt_row, pad)) in admitted.into_iter().zip(slot_rows) {
            self.seeds[s] = self.rows[r].seed;
            self.step[s] = 0;
            self.done[s] = 0;
            self.pads[s] = pad;
            self.slots[s] = Some(Slot {
                row: r,
                tokens: vec![tok::PAD; self.g],
                logprobs: vec![0.0; self.g],
                gen_mask: vec![0.0; self.g],
                prompt_row,
            });
        }
        Ok(())
    }

    /// A row pruned while still queued: emit an empty aborted record (the
    /// prompt region padded, nothing generated) without prefill or decode.
    fn emit_pruned_unadmitted(&mut self, r: usize) -> Result<()> {
        let spec = self.rows[r];
        let (mut tokens, pad) = pad_prompt(&self.problems[spec.group_idx].prompt, self.p)?;
        tokens.resize(self.p + self.g, tok::PAD);
        self.outs[r] = Some(RowOut {
            group_idx: spec.group_idx,
            rollout_idx: spec.rollout_idx,
            pad_len: pad,
            tokens,
            logprobs: vec![0.0; self.g],
            gen_mask: vec![0.0; self.g],
            gen_len: 0,
            aborted: true,
        });
        self.stats.rows_pruned += 1;
        self.stats.gen_tokens_pruned += self.g;
        Ok(())
    }

    /// Retire finished slots into `outs`; returns how many were freed.
    fn retire(&mut self) -> usize {
        let mut freed = 0;
        for s in 0..self.b {
            let finished = self.slots[s].is_some()
                && (self.done[s] != 0 || self.step[s] >= self.g as i32);
            if finished {
                let slot = self.slots[s].take().expect("checked");
                let spec = self.rows[slot.row];
                let gen_len = slot.gen_mask.iter().sum::<f32>() as i32;
                let mut tokens = slot.prompt_row;
                tokens.extend_from_slice(&slot.tokens);
                let out = RowOut {
                    group_idx: spec.group_idx,
                    rollout_idx: spec.rollout_idx,
                    pad_len: self.pads[s],
                    tokens,
                    logprobs: slot.logprobs,
                    gen_mask: slot.gen_mask,
                    gen_len,
                    aborted: false,
                };
                if let Some(hook) = self.hook {
                    hook.on_retired(&out);
                }
                self.outs[slot.row] = Some(out);
                self.done[s] = 1;
                freed += 1;
            }
        }
        freed
    }

    /// Abort live rows the hook has declared doomed — exactly like EOS
    /// retirement (the slot frees for refill), but the row is marked
    /// aborted and its remaining decode budget counts as pruned. Returns
    /// how many slots were freed.
    fn abort_doomed(&mut self) -> usize {
        let Some(hook) = self.hook else { return 0 };
        let mut freed = 0;
        for s in 0..self.b {
            let Some(slot_ref) = &self.slots[s] else { continue };
            let spec = self.rows[slot_ref.row];
            // live rows have not passed EOS, so `step` is their generated
            // count so far (monotone across chunks)
            let len = self.step[s].max(0) as usize;
            if !hook.should_abort(spec.group_idx, spec.rollout_idx, len) {
                continue;
            }
            let slot = self.slots[s].take().expect("checked");
            let gen_len = slot.gen_mask.iter().sum::<f32>() as i32;
            let mut tokens = slot.prompt_row;
            tokens.extend_from_slice(&slot.tokens);
            self.outs[slot.row] = Some(RowOut {
                group_idx: spec.group_idx,
                rollout_idx: spec.rollout_idx,
                pad_len: self.pads[s],
                tokens,
                logprobs: slot.logprobs,
                gen_mask: slot.gen_mask,
                gen_len,
                aborted: true,
            });
            self.done[s] = 1;
            self.stats.rows_pruned += 1;
            self.stats.gen_tokens_pruned += self.g.saturating_sub(gen_len.max(0) as usize);
            freed += 1;
        }
        freed
    }

    fn run(&mut self, chunk: usize, refill: RefillMode, temperature: f32) -> Result<()> {
        let all: Vec<usize> = (0..self.b).collect();
        self.admit(&all)?;
        while self.slots.iter().any(|s| s.is_some()) {
            let st = self.state.take().expect("live slots imply a carried state");
            let prev_step = self.step.clone();
            let (st, out) = self.engine.decode_chunk(
                chunk,
                self.params,
                self.lora,
                st,
                &self.seeds,
                &self.step,
                &self.done,
                &self.pads,
                temperature,
            )?;
            self.state = Some(st);
            self.stats.chunk_calls += 1;
            self.stats.gen_tokens_decoded += self.b * chunk;
            self.step.copy_from_slice(&out.step);
            self.done.copy_from_slice(&out.done);

            // harvest the masked outputs into each live row's stream
            for (s, slot) in self.slots.iter_mut().enumerate() {
                let Some(slot) = slot.as_mut() else { continue };
                for j in 0..chunk {
                    let gi = prev_step[s] as usize + j;
                    if gi >= self.g {
                        break;
                    }
                    if out.mask[s * chunk + j] > 0.0 {
                        slot.tokens[gi] = out.tokens[s * chunk + j];
                        slot.logprobs[gi] = out.logprobs[s * chunk + j];
                        slot.gen_mask[gi] = out.mask[s * chunk + j];
                    }
                }
            }

            let freed = self.retire() + self.abort_doomed();
            // refill freed slots (continuous), or wait for a full drain
            let drained = self.slots.iter().all(|s| s.is_none());
            if freed > 0
                && !self.queue.is_empty()
                && (refill == RefillMode::Continuous || drained)
            {
                let free: Vec<usize> =
                    (0..self.b).filter(|&s| self.slots[s].is_none()).collect();
                self.admit(&free)?;
            }
        }
        Ok(())
    }
}

/// Decode every row of `rows` (prompts looked up in `problems` via
/// `group_idx`) with `B_r`-slot continuous batching, `chunk` tokens per
/// call. Returns the finished rows **in input order** plus call stats.
pub fn decode_rows(
    engine: &Engine,
    params: &[f32],
    lora: Option<&[f32]>,
    temperature: f32,
    chunk: usize,
    refill: RefillMode,
    rows: &[RowSpec],
    problems: &[Problem],
) -> Result<(Vec<RowOut>, DecodeStats)> {
    decode_rows_hooked(engine, params, lora, temperature, chunk, refill, rows, problems, None)
}

/// [`decode_rows`] with an online-pruning hook: the driver polls it at
/// every chunk boundary and aborts rows it declares doomed (see
/// [`PruneHook`]). `hook = None` is exactly [`decode_rows`].
#[allow(clippy::too_many_arguments)]
pub fn decode_rows_hooked(
    engine: &Engine,
    params: &[f32],
    lora: Option<&[f32]>,
    temperature: f32,
    chunk: usize,
    refill: RefillMode,
    rows: &[RowSpec],
    problems: &[Problem],
    hook: Option<&dyn PruneHook>,
) -> Result<(Vec<RowOut>, DecodeStats)> {
    let meta = &engine.meta;
    if meta.decode_chunks.is_empty() {
        bail!(
            "profile {} has no decode_chunk programs — artifacts predate the \
             chunked decode path; re-run `make artifacts`",
            meta.profile
        );
    }
    if !meta.decode_chunks.contains(&chunk) {
        bail!(
            "rollout.decode_chunk = {chunk} is not lowered for profile {} \
             (available: {:?})",
            meta.profile,
            meta.decode_chunks
        );
    }
    if rows.is_empty() {
        return Ok((Vec::new(), DecodeStats::default()));
    }
    let b = meta.config.rollout_batch;
    let mut driver = Driver {
        engine,
        params,
        lora,
        rows,
        problems,
        hook,
        b,
        p: meta.config.prompt_len,
        g: meta.gen_len,
        queue: (0..rows.len()).collect(),
        slots: (0..b).map(|_| None).collect(),
        seeds: vec![0; b],
        step: vec![0; b],
        done: vec![1; b], // empty slots stay done
        pads: vec![meta.config.prompt_len as i32; b],
        state: None,
        outs: (0..rows.len()).map(|_| None).collect(),
        stats: DecodeStats::default(),
    };
    driver.run(chunk, refill, temperature)?;
    let mut finished = Vec::with_capacity(rows.len());
    for (i, o) in driver.outs.into_iter().enumerate() {
        finished.push(o.ok_or_else(|| anyhow!("row {i} never retired (driver bug)"))?);
    }
    Ok((finished, driver.stats))
}
