//! Inference-phase orchestration: batched rollout generation.
//!
//! The rollout artifact samples a fixed batch of `B_r` rollouts per call;
//! this module assembles prompt batches (left-padded, per the model's
//! sequence layout), plans the calls an iteration needs ([`plan_calls`]),
//! executes one call ([`execute_call`]) — sampling, optional reference
//! scoring for the KL term, and rule-based reward verification — and
//! returns per-row [`RolloutRecord`]s tagged with their prompt group.
//!
//! **Cross-group packing**: a prompt whose `n` is not a multiple of `B_r`
//! used to pay a full under-filled call for its remainder rows. The plan
//! instead packs remainder rows from *different* prompts into shared
//! mixed-prompt calls, so every batch the accelerator sees is as full as
//! the iteration allows (the Fig. 1 amortization the hwsim charges for).
//! Full per-prompt calls and single-prompt remainder calls keep the exact
//! seed derivation of the original per-group path —
//! `hash(run_seed, iter, prompt_id, call)` — so those calls replay the
//! seed trainer bit-for-bit; only genuinely packed multi-prompt calls
//! (first prompt's id and call index) sample a different stream.

use crate::coordinator::group::{PromptGroup, RolloutRecord};
use crate::reward::{score_rollout, RewardWeights};
use crate::runtime::{Engine, TensorI};
use crate::tasks::{tokenizer as tok, Problem, TaskKind};
use anyhow::{anyhow, Result};

/// Statistics of one group's inference phase (drives hwsim charging).
#[derive(Debug, Clone, Copy, Default)]
pub struct InferenceStats {
    pub calls: usize,
    pub total_gen_tokens: usize,
    pub rollouts: usize,
}

/// Deterministic seed mixer (splitmix64 finalizer).
pub fn mix_seed(run_seed: u64, iter: u64, prompt: u64, call: u64) -> u32 {
    let mut z = run_seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(iter.wrapping_mul(0xBF58_476D_1CE4_E5B9))
        .wrapping_add(prompt.wrapping_mul(0x94D0_49BB_1331_11EB))
        .wrapping_add(call.wrapping_add(1));
    z ^= z >> 30;
    z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    z as u32
}

/// Left-pad `prompt` into a `[B_r, P]` batch of identical rows.
/// Returns (prompts tensor, pad_len vector).
pub fn prompt_batch(engine: &Engine, prompt: &[i32]) -> Result<(TensorI, Vec<i32>)> {
    let br = engine.meta.config.rollout_batch;
    let p = engine.meta.config.prompt_len;
    if prompt.len() > p {
        return Err(anyhow!("prompt of {} tokens exceeds prompt_len {p}", prompt.len()));
    }
    let pad = p - prompt.len();
    let mut row = vec![tok::PAD; pad];
    row.extend_from_slice(prompt);
    let mut data = Vec::with_capacity(br * p);
    for _ in 0..br {
        data.extend_from_slice(&row);
    }
    Ok((TensorI::new(data, &[br, p])?, vec![pad as i32; br]))
}

/// Left-pad *distinct* prompts into a `[B_r, P]` batch (eval path).
/// Unused rows are filled with the last prompt (results discarded).
pub fn mixed_prompt_batch(engine: &Engine, prompts: &[&[i32]]) -> Result<(TensorI, Vec<i32>)> {
    let br = engine.meta.config.rollout_batch;
    let p = engine.meta.config.prompt_len;
    if prompts.is_empty() || prompts.len() > br {
        return Err(anyhow!("need 1..={br} prompts, got {}", prompts.len()));
    }
    let mut data = Vec::with_capacity(br * p);
    let mut pads = Vec::with_capacity(br);
    for i in 0..br {
        let pr = prompts[i.min(prompts.len() - 1)];
        if pr.len() > p {
            return Err(anyhow!("prompt of {} tokens exceeds prompt_len {p}", pr.len()));
        }
        let pad = p - pr.len();
        data.extend(std::iter::repeat(tok::PAD).take(pad));
        data.extend_from_slice(pr);
        pads.push(pad as i32);
    }
    Ok((TensorI::new(data, &[br, p])?, pads))
}

/// One planned engine call: up to `B_r` rollout rows, each tagged with the
/// index (into the iteration's problem list) of the prompt group it
/// belongs to. Rows beyond `rows.len()` in the physical batch are filler
/// and discarded.
#[derive(Debug, Clone)]
pub struct PlannedCall {
    /// Sampling seed for the whole call (one seed per rollout invocation).
    pub seed: u32,
    /// Group index per kept row; `rows.len() <= B_r`.
    pub rows: Vec<usize>,
}

impl PlannedCall {
    /// True when every row belongs to one prompt group — such calls are
    /// built with [`prompt_batch`] and replay the per-group path exactly.
    pub fn single_group(&self) -> bool {
        self.rows.windows(2).all(|w| w[0] == w[1])
    }
}

/// Plan the engine calls for `n` rollouts of each of `problems`.
///
/// Per group: `n / br` full calls seeded `mix_seed(run_seed, iter, id, c)`
/// — identical to the sequential per-group path. The `n % br` remainder
/// rows of all groups are then packed greedily (group order) into shared
/// calls; a packed call is seeded by its *first* group's id at that
/// group's next call index, so a call whose rows all come from one group
/// degenerates to exactly the sequential remainder call.
pub fn plan_calls(
    problems: &[Problem],
    n: usize,
    br: usize,
    run_seed: u64,
    iter: u64,
) -> Vec<PlannedCall> {
    assert!(br >= 1, "rollout batch must be >= 1");
    let full_calls = n / br;
    let rem = n % br;
    let mut plan = Vec::with_capacity(problems.len() * full_calls.max(1));
    for (g, problem) in problems.iter().enumerate() {
        for c in 0..full_calls {
            plan.push(PlannedCall {
                seed: mix_seed(run_seed, iter, problem.id, c as u64),
                rows: vec![g; br],
            });
        }
    }
    if rem > 0 {
        // remainder queue: (group, rows still needed), group order
        let mut queue: std::collections::VecDeque<(usize, usize)> =
            (0..problems.len()).map(|g| (g, rem)).collect();
        while let Some(&(first, _)) = queue.front() {
            let seed = mix_seed(run_seed, iter, problems[first].id, full_calls as u64);
            let mut rows = Vec::with_capacity(br);
            while rows.len() < br {
                let Some((g, need)) = queue.front_mut() else { break };
                let take = (*need).min(br - rows.len());
                rows.extend(std::iter::repeat(*g).take(take));
                *need -= take;
                if *need == 0 {
                    queue.pop_front();
                }
            }
            plan.push(PlannedCall { seed, rows });
        }
    }
    plan
}

/// One rollout produced by [`execute_call`], tagged with its group.
#[derive(Debug, Clone)]
pub struct CallRollout {
    pub group_idx: usize,
    pub record: RolloutRecord,
}

/// Execute one planned call on `engine`: build the prompt batch (pure
/// per-group, or mixed across groups for packed calls), sample, optionally
/// score under the reference policy for the KL term, verify rewards, and
/// return the kept rows in plan order plus their generated-token count.
#[allow(clippy::too_many_arguments)]
pub fn execute_call(
    engine: &Engine,
    params: &[f32],
    lora: Option<&[f32]>,
    ref_params: Option<&[f32]>,
    ref_lora: Option<&[f32]>,
    temperature: f32,
    call: &PlannedCall,
    problems: &[Problem],
    task: TaskKind,
    weights: &RewardWeights,
) -> Result<(Vec<CallRollout>, usize)> {
    if call.rows.is_empty() {
        return Ok((Vec::new(), 0));
    }
    let t = engine.meta.config.seq_len;
    let g = engine.meta.gen_len;
    let p = engine.meta.config.prompt_len;
    let (prompts, pads) = if call.single_group() {
        prompt_batch(engine, &problems[call.rows[0]].prompt)?
    } else {
        let refs: Vec<&[i32]> =
            call.rows.iter().map(|&gi| problems[gi].prompt.as_slice()).collect();
        mixed_prompt_batch(engine, &refs)?
    };
    let out = engine.rollout(params, lora, &prompts, &pads, call.seed, temperature)?;
    let ref_lp_all = match ref_params {
        Some(rp) => Some(engine.score(rp, ref_lora, &out.tokens, &pads)?),
        None => None,
    };
    let mut kept = Vec::with_capacity(call.rows.len());
    let mut gen_tokens = 0usize;
    for (b, &gi) in call.rows.iter().enumerate() {
        let tokens: Vec<i32> = out.tokens.data[b * t..(b + 1) * t].to_vec();
        let gen_mask: Vec<f32> = out.gen_mask.data[b * g..(b + 1) * g].to_vec();
        let old_lp: Vec<f32> = out.logprobs.data[b * g..(b + 1) * g].to_vec();
        let ref_lp: Vec<f32> = match &ref_lp_all {
            Some(r) => r.data[b * g..(b + 1) * g].to_vec(),
            None => vec![0.0; g],
        };
        let gen_len = out.gen_len[b];
        gen_tokens += gen_len as usize;
        let reward = score_rollout(&tokens, p, task, &problems[gi]);
        let total_reward = reward.total(weights);
        kept.push(CallRollout {
            group_idx: gi,
            record: RolloutRecord {
                tokens,
                pad_len: pads[b],
                gen_mask,
                old_lp,
                ref_lp,
                gen_len,
                reward,
                total_reward,
            },
        });
    }
    Ok((kept, gen_tokens))
}

/// Parameters of one group-generation request.
pub struct GenRequest<'a> {
    pub params: &'a [f32],
    pub lora: Option<&'a [f32]>,
    /// Score rollouts under these reference parameters for the KL term
    /// (full-parameter vector; lora taken from `ref_lora`).
    pub ref_params: Option<&'a [f32]>,
    pub ref_lora: Option<&'a [f32]>,
    pub n: usize,
    pub temperature: f32,
    pub run_seed: u64,
    pub iter: u64,
    pub weights: RewardWeights,
}

/// Generate `n` rollouts for `problem`, score them, and assemble the group.
///
/// Single-group convenience over [`plan_calls`] + [`execute_call`]; for a
/// lone problem the plan degenerates to the original sequential call
/// structure, so this replays the seed path exactly.
pub fn generate_group(
    engine: &Engine,
    req: &GenRequest,
    task: TaskKind,
    problem: &Problem,
) -> Result<(PromptGroup, InferenceStats)> {
    let br = engine.meta.config.rollout_batch;
    let problems = std::slice::from_ref(problem);
    let plan = plan_calls(problems, req.n, br, req.run_seed, req.iter);
    let mut rollouts = Vec::with_capacity(req.n);
    let mut stats = InferenceStats::default();
    for call in &plan {
        let (kept, gen_tokens) = execute_call(
            engine,
            req.params,
            req.lora,
            req.ref_params,
            req.ref_lora,
            req.temperature,
            call,
            problems,
            task,
            &req.weights,
        )?;
        stats.calls += 1;
        stats.total_gen_tokens += gen_tokens;
        rollouts.extend(kept.into_iter().map(|c| c.record));
    }
    stats.rollouts = rollouts.len();
    Ok((PromptGroup { problem: problem.clone(), rollouts }, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_mixer_decorrelates() {
        let a = mix_seed(0, 0, 0, 0);
        let b = mix_seed(0, 0, 0, 1);
        let c = mix_seed(0, 0, 1, 0);
        let d = mix_seed(0, 1, 0, 0);
        let e = mix_seed(1, 0, 0, 0);
        let set: std::collections::HashSet<u32> = [a, b, c, d, e].into_iter().collect();
        assert_eq!(set.len(), 5, "seed collisions: {:?}", [a, b, c, d, e]);
    }

    #[test]
    fn seed_mixer_deterministic() {
        assert_eq!(mix_seed(7, 3, 9, 2), mix_seed(7, 3, 9, 2));
    }

    fn problems(k: usize) -> Vec<Problem> {
        (0..k as u64).map(|i| TaskKind::Arith.generate(crate::tasks::Split::Train, i)).collect()
    }

    /// n a multiple of B_r: the plan is exactly the sequential per-group
    /// call structure — same group-major order, same seeds, full rows.
    #[test]
    fn plan_matches_sequential_structure_when_batches_divide() {
        let ps = problems(3);
        let plan = plan_calls(&ps, 16, 8, 7, 5);
        assert_eq!(plan.len(), 6);
        for (g, p) in ps.iter().enumerate() {
            for c in 0..2usize {
                let call = &plan[g * 2 + c];
                assert_eq!(call.rows, vec![g; 8]);
                assert!(call.single_group());
                assert_eq!(call.seed, mix_seed(7, 5, p.id, c as u64));
            }
        }
    }

    /// A lone group's remainder call keeps the sequential seed index, so
    /// `generate_group` over the plan replays the seed path bit-for-bit.
    #[test]
    fn plan_single_group_remainder_keeps_sequential_seed() {
        let ps = problems(1);
        let plan = plan_calls(&ps, 13, 8, 3, 2);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].rows, vec![0; 8]);
        assert_eq!(plan[0].seed, mix_seed(3, 2, ps[0].id, 0));
        assert_eq!(plan[1].rows, vec![0; 5]);
        assert!(plan[1].single_group());
        // remainder call = sequential call index 1
        assert_eq!(plan[1].seed, mix_seed(3, 2, ps[0].id, 1));
    }

    /// Remainders from different groups share packed calls: 3 groups with
    /// 5 leftover rows each fill toward B_r=8 instead of paying three
    /// under-filled calls.
    #[test]
    fn plan_packs_remainders_across_groups() {
        let ps = problems(3);
        let plan = plan_calls(&ps, 5, 8, 0, 0);
        // 15 remainder rows -> 2 calls (8 + 7) instead of 3 under-filled
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].rows, vec![0, 0, 0, 0, 0, 1, 1, 1]);
        assert!(!plan[0].single_group());
        assert_eq!(plan[0].seed, mix_seed(0, 0, ps[0].id, 0));
        assert_eq!(plan[1].rows, vec![1, 1, 2, 2, 2, 2, 2]);
        assert_eq!(plan[1].seed, mix_seed(0, 0, ps[1].id, 0));
        // every group got exactly n rows across the plan
        for g in 0..3 {
            let total: usize =
                plan.iter().map(|c| c.rows.iter().filter(|&&r| r == g).count()).sum();
            assert_eq!(total, 5);
        }
    }

    /// Property: the plan always delivers exactly n rows per group, never
    /// overfills a call, and keeps rows of one group contiguous per call.
    #[test]
    fn plan_rows_partition_exactly() {
        use crate::util::prop::for_cases;
        for_cases(200, |rng| {
            let k = rng.gen_range_inclusive(1, 6) as usize;
            let n = rng.gen_range_inclusive(1, 40) as usize;
            let br = rng.gen_range_inclusive(1, 16) as usize;
            let ps = problems(k);
            let plan = plan_calls(&ps, n, br, rng.next_u64(), rng.next_u64());
            let mut per_group = vec![0usize; k];
            for call in &plan {
                assert!(!call.rows.is_empty() && call.rows.len() <= br);
                for &g in &call.rows {
                    per_group[g] += 1;
                }
            }
            assert_eq!(per_group, vec![n; k]);
        });
    }
}
