//! Inference-phase orchestration: batched rollout generation for a prompt.
//!
//! The rollout artifact samples a fixed batch of `B_r` rollouts per call;
//! this module assembles prompt batches (left-padded, per the model's
//! sequence layout), shards the `n` requested rollouts over as many calls
//! as needed with decorrelated seeds, verifies each rollout with the
//! rule-based reward model, and returns a [`PromptGroup`].
//!
//! Seeds are derived as `hash(run_seed, iter, prompt_id, call)` so runs are
//! exactly replayable and calls are decorrelated across all axes.

use crate::coordinator::group::{PromptGroup, RolloutRecord};
use crate::reward::{score_rollout, RewardWeights};
use crate::runtime::{Engine, TensorI};
use crate::tasks::{tokenizer as tok, Problem, TaskKind};
use anyhow::{anyhow, Result};

/// Statistics of one group's inference phase (drives hwsim charging).
#[derive(Debug, Clone, Copy, Default)]
pub struct InferenceStats {
    pub calls: usize,
    pub total_gen_tokens: usize,
    pub rollouts: usize,
}

/// Deterministic seed mixer (splitmix64 finalizer).
pub fn mix_seed(run_seed: u64, iter: u64, prompt: u64, call: u64) -> u32 {
    let mut z = run_seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(iter.wrapping_mul(0xBF58_476D_1CE4_E5B9))
        .wrapping_add(prompt.wrapping_mul(0x94D0_49BB_1331_11EB))
        .wrapping_add(call.wrapping_add(1));
    z ^= z >> 30;
    z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    z as u32
}

/// Left-pad `prompt` into a `[B_r, P]` batch of identical rows.
/// Returns (prompts tensor, pad_len vector).
pub fn prompt_batch(engine: &Engine, prompt: &[i32]) -> Result<(TensorI, Vec<i32>)> {
    let br = engine.meta.config.rollout_batch;
    let p = engine.meta.config.prompt_len;
    if prompt.len() > p {
        return Err(anyhow!("prompt of {} tokens exceeds prompt_len {p}", prompt.len()));
    }
    let pad = p - prompt.len();
    let mut row = vec![tok::PAD; pad];
    row.extend_from_slice(prompt);
    let mut data = Vec::with_capacity(br * p);
    for _ in 0..br {
        data.extend_from_slice(&row);
    }
    Ok((TensorI::new(data, &[br, p])?, vec![pad as i32; br]))
}

/// Left-pad *distinct* prompts into a `[B_r, P]` batch (eval path).
/// Unused rows are filled with the last prompt (results discarded).
pub fn mixed_prompt_batch(engine: &Engine, prompts: &[&[i32]]) -> Result<(TensorI, Vec<i32>)> {
    let br = engine.meta.config.rollout_batch;
    let p = engine.meta.config.prompt_len;
    if prompts.is_empty() || prompts.len() > br {
        return Err(anyhow!("need 1..={br} prompts, got {}", prompts.len()));
    }
    let mut data = Vec::with_capacity(br * p);
    let mut pads = Vec::with_capacity(br);
    for i in 0..br {
        let pr = prompts[i.min(prompts.len() - 1)];
        if pr.len() > p {
            return Err(anyhow!("prompt of {} tokens exceeds prompt_len {p}", pr.len()));
        }
        let pad = p - pr.len();
        data.extend(std::iter::repeat(tok::PAD).take(pad));
        data.extend_from_slice(pr);
        pads.push(pad as i32);
    }
    Ok((TensorI::new(data, &[br, p])?, pads))
}

/// Parameters of one group-generation request.
pub struct GenRequest<'a> {
    pub params: &'a [f32],
    pub lora: Option<&'a [f32]>,
    /// Score rollouts under these reference parameters for the KL term
    /// (full-parameter vector; lora taken from `ref_lora`).
    pub ref_params: Option<&'a [f32]>,
    pub ref_lora: Option<&'a [f32]>,
    pub n: usize,
    pub temperature: f32,
    pub run_seed: u64,
    pub iter: u64,
    pub weights: RewardWeights,
}

/// Generate `n` rollouts for `problem`, score them, and assemble the group.
pub fn generate_group(
    engine: &Engine,
    req: &GenRequest,
    task: TaskKind,
    problem: &Problem,
) -> Result<(PromptGroup, InferenceStats)> {
    let br = engine.meta.config.rollout_batch;
    let t = engine.meta.config.seq_len;
    let g = engine.meta.gen_len;
    let p = engine.meta.config.prompt_len;
    let (prompts, pads) = prompt_batch(engine, &problem.prompt)?;
    let calls = req.n.div_ceil(br);
    let mut rollouts = Vec::with_capacity(req.n);
    let mut stats = InferenceStats::default();
    for c in 0..calls {
        let seed = mix_seed(req.run_seed, req.iter, problem.id, c as u64);
        let out = engine.rollout(req.params, req.lora, &prompts, &pads, seed, req.temperature)?;
        // reference log-probs for the KL term, if requested
        let ref_lp_all = match req.ref_params {
            Some(rp) => Some(engine.score(rp, req.ref_lora, &out.tokens, &pads)?),
            None => None,
        };
        stats.calls += 1;
        for b in 0..br {
            if rollouts.len() >= req.n {
                break;
            }
            let tokens: Vec<i32> = out.tokens.data[b * t..(b + 1) * t].to_vec();
            let gen_mask: Vec<f32> = out.gen_mask.data[b * g..(b + 1) * g].to_vec();
            let old_lp: Vec<f32> = out.logprobs.data[b * g..(b + 1) * g].to_vec();
            let ref_lp: Vec<f32> = match &ref_lp_all {
                Some(r) => r.data[b * g..(b + 1) * g].to_vec(),
                None => vec![0.0; g],
            };
            let gen_len = out.gen_len[b];
            stats.total_gen_tokens += gen_len as usize;
            let reward = score_rollout(&tokens, p, task, problem);
            let total_reward = reward.total(&req.weights);
            rollouts.push(RolloutRecord {
                tokens,
                pad_len: pads[b],
                gen_mask,
                old_lp,
                ref_lp,
                gen_len,
                reward,
                total_reward,
            });
        }
    }
    stats.rollouts = rollouts.len();
    Ok((PromptGroup { problem: problem.clone(), rollouts }, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_mixer_decorrelates() {
        let a = mix_seed(0, 0, 0, 0);
        let b = mix_seed(0, 0, 0, 1);
        let c = mix_seed(0, 0, 1, 0);
        let d = mix_seed(0, 1, 0, 0);
        let e = mix_seed(1, 0, 0, 0);
        let set: std::collections::HashSet<u32> = [a, b, c, d, e].into_iter().collect();
        assert_eq!(set.len(), 5, "seed collisions: {:?}", [a, b, c, d, e]);
    }

    #[test]
    fn seed_mixer_deterministic() {
        assert_eq!(mix_seed(7, 3, 9, 2), mix_seed(7, 3, 9, 2));
    }
}
