//! Inference-phase orchestration: batched rollout generation.
//!
//! An iteration's generation is planned as a **refill queue of rows**
//! ([`plan_rows`]): one [`RowSpec`] per rollout, tagged with its prompt
//! group and carrying a private RNG seed derived from
//! `(run_seed, iter, prompt_id, rollout_idx)`. The [`chunked`] driver
//! feeds those rows through the `prefill` / `decode_chunk` programs as a
//! slot-based continuous batcher: rows that emit EOS retire between
//! chunks, queued rows are admitted into the freed slots, and decoding
//! stops the moment the queue drains — so decode work tracks actual
//! generated tokens (rounded up to the chunk size), not `rows × G`.
//!
//! **Seed ownership**: because every row folds its own counter-based
//! stream, sampled tokens are bit-invariant to chunk size, slot
//! assignment, refill order, worker-pool partitioning and batch
//! composition. Packing decisions are pure throughput decisions; they can
//! never change what gets sampled.
//!
//! [`execute_rows`] wraps the driver with reward verification and the
//! optional reference-policy scoring for the KL term;
//! [`generate_group`] is the single-prompt convenience used by tests and
//! benches.

pub mod chunked;

pub use chunked::{
    decode_rows, decode_rows_hooked, decode_rows_kv, DecodeStats, KvAdmissionError, KvPolicy,
    PruneHook, RefillMode, RowOut, RowSpec,
};

use crate::coordinator::group::{PromptGroup, RolloutRecord};
use crate::coordinator::select::online::GroupVerdicts;
use crate::reward::{score_rollout, RewardWeights};
use crate::runtime::{Engine, TensorI};
use crate::tasks::{tokenizer as tok, Problem, TaskKind};
use anyhow::{anyhow, Result};

/// Statistics of one generation phase (drives hwsim charging and the
/// decoded/wasted telemetry columns).
#[derive(Debug, Clone, Copy, Default)]
pub struct InferenceStats {
    /// Engine invocations: prefill + decode_chunk + reference-score calls.
    pub calls: usize,
    /// Useful generated tokens (through EOS) across all rollouts.
    pub total_gen_tokens: usize,
    /// Finished rollouts.
    pub rollouts: usize,
    /// Decode-step slots physically executed (`B_r × C` per chunk call) —
    /// post-EOS slots and batch filler included.
    pub gen_tokens_decoded: usize,
    /// `gen_tokens_decoded - total_gen_tokens`: decode work that produced
    /// no trainable token.
    pub gen_tokens_wasted: usize,
    /// Decode budget released by online pruning (per aborted row: the
    /// generation budget `G` minus its decoded length at the abort).
    pub gen_tokens_pruned: usize,
    /// Rollouts aborted mid-decode by the online pruning verdicts.
    pub rows_pruned: usize,
    /// Prompt prefill calls the decode driver executed.
    pub prefill_calls: usize,
    /// Prefill calls avoided by group-shared prompt KV (refill events
    /// served from the group's on-device snapshot).
    pub prefill_calls_saved: usize,
    /// High-water mark of the modeled KV pool, in bytes. Per-device:
    /// worker shards hold independent pools, so merging takes the max.
    pub kv_peak_bytes: u64,
    /// Injected fault events (crash / transient / admission-OOM draws that
    /// fired, one per faulted row-attempt).
    pub faults_injected: usize,
    /// Physical retry jobs submitted for failed rows.
    pub shard_retries: usize,
    /// Rows lost permanently after exhausting `faults.max_retries`.
    pub rows_lost: usize,
    /// Simulated retry-backoff seconds accumulated by failed row-attempts
    /// that were retried.
    pub fault_backoff_time: f64,
    /// Decode tokens wasted by crashed attempts (the generation budget of
    /// each crashed row-attempt — work done, then lost).
    pub fault_wasted_tokens: usize,
    /// Chunk-rounded generated tokens of straggler rows; the clock charges
    /// them an extra `(straggler_factor - 1) ×` slowdown.
    pub straggler_tokens: usize,
    /// Extra rollout rows the budget allocator streamed to wide-bracket
    /// groups past the probe quota (0 with `[budget]` disabled).
    pub budget_extra_rows: usize,
    /// Groups whose probe bracket was already narrower than
    /// `budget.width_threshold` — they received no extra rows.
    pub budget_saturated_groups: usize,
}

impl InferenceStats {
    /// Merge another phase's stats into this one: field-wise sums, except
    /// `kv_peak_bytes` — each worker's pool is a separate device memory,
    /// so the merged peak is the busiest device's, not the fleet total.
    pub fn absorb(&mut self, other: &InferenceStats) {
        self.calls += other.calls;
        self.total_gen_tokens += other.total_gen_tokens;
        self.rollouts += other.rollouts;
        self.gen_tokens_decoded += other.gen_tokens_decoded;
        self.gen_tokens_wasted += other.gen_tokens_wasted;
        self.gen_tokens_pruned += other.gen_tokens_pruned;
        self.rows_pruned += other.rows_pruned;
        self.prefill_calls += other.prefill_calls;
        self.prefill_calls_saved += other.prefill_calls_saved;
        self.kv_peak_bytes = self.kv_peak_bytes.max(other.kv_peak_bytes);
        self.faults_injected += other.faults_injected;
        self.shard_retries += other.shard_retries;
        self.rows_lost += other.rows_lost;
        self.fault_backoff_time += other.fault_backoff_time;
        self.fault_wasted_tokens += other.fault_wasted_tokens;
        self.straggler_tokens += other.straggler_tokens;
        self.budget_extra_rows += other.budget_extra_rows;
        self.budget_saturated_groups += other.budget_saturated_groups;
    }
}

/// Retired-row handoff gate for the cross-iteration replay store: a
/// finished rollout may be admitted only if its behaviour log-prob stream
/// covers the full generation. Rows aborted mid-decode by online pruning
/// carry truncated `old_lp`/`gen_mask` streams, so replaying them would
/// feed the GRPO ratio term garbage; empty generations carry no trainable
/// tokens at all.
pub fn replay_handoff_eligible(record: &RolloutRecord) -> bool {
    !record.pruned && record.gen_len > 0
}

/// Deterministic seed mixer (splitmix64 finalizer).
pub fn mix_seed(run_seed: u64, iter: u64, prompt: u64, call: u64) -> u32 {
    let mut z = run_seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(iter.wrapping_mul(0xBF58_476D_1CE4_E5B9))
        .wrapping_add(prompt.wrapping_mul(0x94D0_49BB_1331_11EB))
        .wrapping_add(call.wrapping_add(1));
    z ^= z >> 30;
    z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    z as u32
}

/// Per-row RNG seed: the root of rollout `rollout_idx` of prompt
/// `prompt_id`'s counter-based sample stream. Independent of batching
/// entirely — the program folds `(seed, step)` per sampled token.
pub fn row_seed(run_seed: u64, iter: u64, prompt_id: u64, rollout_idx: u64) -> i32 {
    mix_seed(run_seed, iter, prompt_id, rollout_idx) as i32
}

/// Left-pad `prompt` into a `[B_r, P]` batch of identical rows.
/// Returns (prompts tensor, pad_len vector). Used by the monolithic
/// `rollout` program (oracle/bench path).
pub fn prompt_batch(engine: &Engine, prompt: &[i32]) -> Result<(TensorI, Vec<i32>)> {
    let br = engine.meta.config.rollout_batch;
    let p = engine.meta.config.prompt_len;
    if prompt.len() > p {
        return Err(anyhow!("prompt of {} tokens exceeds prompt_len {p}", prompt.len()));
    }
    let pad = p - prompt.len();
    let mut row = vec![tok::PAD; pad];
    row.extend_from_slice(prompt);
    let mut data = Vec::with_capacity(br * p);
    for _ in 0..br {
        data.extend_from_slice(&row);
    }
    Ok((TensorI::new(data, &[br, p])?, vec![pad as i32; br]))
}

/// Plan the refill queue for `n` rollouts of each of `problems`:
/// group-major row order, one private seed per row. Any contiguous
/// partition of this queue (worker shards) or slot/refill schedule
/// produces identical per-row streams.
///
/// Group-major order is also what makes prompt-KV sharing pay off: all
/// `n` siblings of a group sit adjacent in the queue, so at most one
/// group ever straddles a refill event and the driver's single prompt
/// snapshot serves every sibling admission (`share_prompt_kv` — sharing
/// stays *correct* under any order, but adjacency is what lets
/// `prefill_calls` collapse to one per group).
pub fn plan_rows(problems: &[Problem], n: usize, run_seed: u64, iter: u64) -> Vec<RowSpec> {
    let mut rows = Vec::with_capacity(problems.len() * n);
    for (g, problem) in problems.iter().enumerate() {
        for j in 0..n {
            rows.push(RowSpec {
                group_idx: g,
                rollout_idx: j,
                seed: row_seed(run_seed, iter, problem.id, j as u64),
            });
        }
    }
    rows
}

/// One rollout produced by [`execute_rows`], tagged with its group.
#[derive(Debug, Clone)]
pub struct CallRollout {
    /// Prompt group the rollout belongs to.
    pub group_idx: usize,
    /// Index of the rollout within its group (its `RowSpec.rollout_idx`).
    /// Lets the assembler restore canonical group order when rollouts
    /// arrive out of order (retried shards complete whenever they do).
    pub rollout_idx: usize,
    /// The finished rollout, update-phase ready.
    pub record: RolloutRecord,
}

/// [`PruneHook`] gluing the decode driver to the shared per-group verdict
/// state: retired rows are scored with the run's reward model and fed to
/// the [`GroupVerdicts`] aggregator; live rows are polled against it.
struct VerdictHook<'a> {
    verdicts: &'a GroupVerdicts,
    problems: &'a [Problem],
    task: TaskKind,
    weights: &'a RewardWeights,
    prompt_len: usize,
}

impl PruneHook for VerdictHook<'_> {
    fn on_retired(&self, row: &RowOut) {
        let reward = score_rollout(
            &row.tokens,
            self.prompt_len,
            self.task,
            &self.problems[row.group_idx],
        )
        .total(self.weights);
        self.verdicts.observe_finished(
            row.group_idx,
            row.rollout_idx,
            reward,
            row.gen_len.max(0) as usize,
        );
    }

    fn should_abort(&self, group_idx: usize, rollout_idx: usize, gen_len: usize) -> bool {
        self.verdicts.poll_doomed(group_idx, rollout_idx, gen_len)
    }
}

/// Run `rows` through the continuous-batching driver, then verify rewards
/// and (optionally) score the generations under the reference policy for
/// the KL term. Returns the finished rollouts in row order plus stats.
///
/// With `online = Some(v)`, the driver additionally reports retirements to
/// the shared verdict state and aborts rows it declares doomed — the
/// online selection-aware pruning path (`[rollout] online_prune`).
///
/// `kv` selects group-shared prompt prefill and paged-pool admission;
/// [`KvPolicy::default()`] is the legacy per-row-prefill behaviour.
#[allow(clippy::too_many_arguments)]
pub fn execute_rows(
    engine: &Engine,
    params: &[f32],
    lora: Option<&[f32]>,
    ref_params: Option<&[f32]>,
    ref_lora: Option<&[f32]>,
    temperature: f32,
    decode_chunk: usize,
    refill: RefillMode,
    rows: &[RowSpec],
    problems: &[Problem],
    task: TaskKind,
    weights: &RewardWeights,
    online: Option<&GroupVerdicts>,
    kv: KvPolicy,
) -> Result<(Vec<CallRollout>, InferenceStats)> {
    let hook_state = online.map(|verdicts| VerdictHook {
        verdicts,
        problems,
        task,
        weights,
        prompt_len: engine.meta.config.prompt_len,
    });
    let hook = hook_state.as_ref().map(|h| h as &dyn PruneHook);
    let (row_outs, dstats) = decode_rows_kv(
        engine,
        params,
        lora,
        temperature,
        decode_chunk,
        refill,
        rows,
        problems,
        hook,
        kv,
    )?;
    let t = engine.meta.config.seq_len;
    let g = engine.meta.gen_len;
    let p = engine.meta.config.prompt_len;
    let br = engine.meta.config.rollout_batch;

    // Reference-policy log-probs for the KL term: teacher-forced scoring
    // is per-row work, so finished rows are packed into full `[B_r, T]`
    // batches (tail filled by repeating the last row, results discarded).
    let mut score_calls = 0usize;
    let ref_lps: Option<Vec<Vec<f32>>> = match ref_params {
        None => None,
        Some(rp) => {
            let mut all = Vec::with_capacity(row_outs.len());
            for batch in row_outs.chunks(br) {
                let mut data = Vec::with_capacity(br * t);
                let mut pads = Vec::with_capacity(br);
                for i in 0..br {
                    let r = &batch[i.min(batch.len() - 1)];
                    data.extend_from_slice(&r.tokens);
                    pads.push(r.pad_len);
                }
                let tokens = TensorI::new(data, &[br, t])?;
                let lp = engine.score(rp, ref_lora, &tokens, &pads)?;
                score_calls += 1;
                for i in 0..batch.len() {
                    all.push(lp.data[i * g..(i + 1) * g].to_vec());
                }
            }
            Some(all)
        }
    };

    let mut kept = Vec::with_capacity(rows.len());
    let mut stats = InferenceStats {
        calls: dstats.prefill_calls + dstats.chunk_calls + dstats.merge_calls + score_calls,
        gen_tokens_decoded: dstats.gen_tokens_decoded,
        gen_tokens_pruned: dstats.gen_tokens_pruned,
        rows_pruned: dstats.rows_pruned,
        prefill_calls: dstats.prefill_calls,
        prefill_calls_saved: dstats.prefill_calls_saved,
        kv_peak_bytes: dstats.kv_peak_bytes,
        ..Default::default()
    };
    for (i, r) in row_outs.into_iter().enumerate() {
        stats.total_gen_tokens += r.gen_len as usize;
        let reward = score_rollout(&r.tokens, p, task, &problems[r.group_idx]);
        let total_reward = reward.total(weights);
        kept.push(CallRollout {
            group_idx: r.group_idx,
            rollout_idx: r.rollout_idx,
            record: RolloutRecord {
                pad_len: r.pad_len,
                gen_mask: r.gen_mask,
                old_lp: r.logprobs,
                ref_lp: match &ref_lps {
                    Some(all) => all[i].clone(),
                    None => vec![0.0; g],
                },
                gen_len: r.gen_len,
                tokens: r.tokens,
                reward,
                total_reward,
                pruned: r.aborted,
            },
        });
    }
    stats.rollouts = kept.len();
    stats.gen_tokens_wasted = stats.gen_tokens_decoded.saturating_sub(stats.total_gen_tokens);
    Ok((kept, stats))
}

/// Parameters of one group-generation request.
pub struct GenRequest<'a> {
    /// Full-parameter vector to decode with.
    pub params: &'a [f32],
    /// Trainable adapter vector (LoRA profiles).
    pub lora: Option<&'a [f32]>,
    /// Score rollouts under these reference parameters for the KL term
    /// (full-parameter vector; lora taken from `ref_lora`).
    pub ref_params: Option<&'a [f32]>,
    /// Reference-policy adapter (LoRA profiles with KL).
    pub ref_lora: Option<&'a [f32]>,
    /// Rollouts to generate for the prompt.
    pub n: usize,
    /// Sampling temperature.
    pub temperature: f32,
    /// Run seed — one axis of every row's stream seed.
    pub run_seed: u64,
    /// Training iteration the request belongs to.
    pub iter: u64,
    /// Reward component weights.
    pub weights: RewardWeights,
    /// Tokens decoded per `decode_chunk` call.
    pub decode_chunk: usize,
    /// Slot-refill policy between chunks.
    pub refill: RefillMode,
    /// Group-shared prompt KV and paged-pool admission policy
    /// ([`KvPolicy::default()`] = legacy per-row prefill).
    pub kv: KvPolicy,
}

/// Generate `n` rollouts for `problem`, score them, and assemble the
/// group. Single-group convenience over [`plan_rows`] + [`execute_rows`];
/// per-row seeds make it produce the exact streams of any multi-group
/// plan containing the same prompt.
pub fn generate_group(
    engine: &Engine,
    req: &GenRequest,
    task: TaskKind,
    problem: &Problem,
) -> Result<(PromptGroup, InferenceStats)> {
    let problems = std::slice::from_ref(problem);
    let rows = plan_rows(problems, req.n, req.run_seed, req.iter);
    let (kept, stats) = execute_rows(
        engine,
        req.params,
        req.lora,
        req.ref_params,
        req.ref_lora,
        req.temperature,
        req.decode_chunk,
        req.refill,
        &rows,
        problems,
        task,
        &req.weights,
        None,
        req.kv,
    )?;
    let rollouts = kept.into_iter().map(|c| c.record).collect();
    Ok((PromptGroup { problem: problem.clone(), rollouts }, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::TaskKind;

    #[test]
    fn seed_mixer_decorrelates() {
        let a = mix_seed(0, 0, 0, 0);
        let b = mix_seed(0, 0, 0, 1);
        let c = mix_seed(0, 0, 1, 0);
        let d = mix_seed(0, 1, 0, 0);
        let e = mix_seed(1, 0, 0, 0);
        let set: std::collections::HashSet<u32> = [a, b, c, d, e].into_iter().collect();
        assert_eq!(set.len(), 5, "seed collisions: {:?}", [a, b, c, d, e]);
    }

    #[test]
    fn seed_mixer_deterministic() {
        assert_eq!(mix_seed(7, 3, 9, 2), mix_seed(7, 3, 9, 2));
    }

    /// Pruned (aborted) rows and empty generations never reach the replay
    /// store — their stored log-prob streams are not update-ready.
    #[test]
    fn replay_handoff_rejects_pruned_and_empty_rows() {
        let g = crate::coordinator::group::PromptGroup::synthetic(0, &[1.0, 2.0], None);
        let mut r = g.rollouts[0].clone();
        assert!(replay_handoff_eligible(&r));
        r.pruned = true;
        assert!(!replay_handoff_eligible(&r));
        r.pruned = false;
        r.gen_len = 0;
        assert!(!replay_handoff_eligible(&r));
    }

    fn problems(k: usize) -> Vec<Problem> {
        (0..k as u64).map(|i| TaskKind::Arith.generate(crate::tasks::Split::Train, i)).collect()
    }

    /// The plan is a group-major queue with one row per rollout, each
    /// carrying its own seed keyed by (run_seed, iter, prompt, idx).
    #[test]
    fn plan_rows_group_major_with_private_seeds() {
        let ps = problems(3);
        let rows = plan_rows(&ps, 5, 7, 2);
        assert_eq!(rows.len(), 15);
        for (g, p) in ps.iter().enumerate() {
            for j in 0..5usize {
                let r = &rows[g * 5 + j];
                assert_eq!(r.group_idx, g);
                assert_eq!(r.rollout_idx, j);
                assert_eq!(r.seed, row_seed(7, 2, p.id, j as u64));
            }
        }
    }

    /// Row seeds are invariant to which other prompts share the iteration
    /// — the property that makes any partition/refill order sound.
    #[test]
    fn row_seeds_independent_of_batch_composition() {
        let ps3 = problems(3);
        let ps1 = vec![ps3[1].clone()];
        let all = plan_rows(&ps3, 4, 9, 1);
        let solo = plan_rows(&ps1, 4, 9, 1);
        for j in 0..4 {
            assert_eq!(all[4 + j].seed, solo[j].seed);
        }
    }

    #[test]
    fn row_seeds_decorrelate_across_rollouts() {
        let ps = problems(1);
        let rows = plan_rows(&ps, 32, 0, 0);
        let set: std::collections::HashSet<i32> = rows.iter().map(|r| r.seed).collect();
        assert_eq!(set.len(), 32, "rollout seeds collided");
    }

    #[test]
    fn refill_mode_parses() {
        assert_eq!(RefillMode::parse("continuous").unwrap(), RefillMode::Continuous);
        assert_eq!(RefillMode::parse("batch").unwrap(), RefillMode::Batch);
        assert!(RefillMode::parse("eager").is_err());
        assert_eq!(RefillMode::default(), RefillMode::Continuous);
        assert_eq!(RefillMode::Batch.name(), "batch");
    }

    #[test]
    fn inference_stats_absorb_sums_fields() {
        let mut a = InferenceStats {
            calls: 2,
            total_gen_tokens: 10,
            rollouts: 4,
            gen_tokens_decoded: 32,
            gen_tokens_wasted: 22,
            gen_tokens_pruned: 7,
            rows_pruned: 1,
            prefill_calls: 3,
            prefill_calls_saved: 2,
            kv_peak_bytes: 4096,
            faults_injected: 2,
            shard_retries: 1,
            rows_lost: 1,
            fault_backoff_time: 0.5,
            fault_wasted_tokens: 64,
            straggler_tokens: 32,
            budget_extra_rows: 5,
            budget_saturated_groups: 2,
        };
        let b = InferenceStats {
            calls: 1,
            total_gen_tokens: 5,
            rollouts: 2,
            gen_tokens_decoded: 16,
            gen_tokens_wasted: 11,
            gen_tokens_pruned: 3,
            rows_pruned: 2,
            prefill_calls: 1,
            prefill_calls_saved: 4,
            kv_peak_bytes: 1024,
            faults_injected: 3,
            shard_retries: 2,
            rows_lost: 0,
            fault_backoff_time: 1.5,
            fault_wasted_tokens: 16,
            straggler_tokens: 8,
            budget_extra_rows: 3,
            budget_saturated_groups: 1,
        };
        a.absorb(&b);
        assert_eq!(a.calls, 3);
        assert_eq!(a.total_gen_tokens, 15);
        assert_eq!(a.rollouts, 6);
        assert_eq!(a.gen_tokens_decoded, 48);
        assert_eq!(a.gen_tokens_wasted, 33);
        assert_eq!(a.gen_tokens_pruned, 10);
        assert_eq!(a.rows_pruned, 3);
        assert_eq!(a.prefill_calls, 4);
        assert_eq!(a.prefill_calls_saved, 6);
        // per-device pools: the merged peak is the busiest device's
        assert_eq!(a.kv_peak_bytes, 4096);
        // fault accounting sums across shards
        assert_eq!(a.faults_injected, 5);
        assert_eq!(a.shard_retries, 3);
        assert_eq!(a.rows_lost, 1);
        assert!((a.fault_backoff_time - 2.0).abs() < 1e-12);
        assert_eq!(a.fault_wasted_tokens, 80);
        assert_eq!(a.straggler_tokens, 40);
        assert_eq!(a.budget_extra_rows, 8);
        assert_eq!(a.budget_saturated_groups, 3);
    }

    /// Prompt-KV sharing relies on group siblings being adjacent in the
    /// refill queue: each group's rows must form exactly one contiguous
    /// block (so at most one group straddles any refill event).
    #[test]
    fn plan_rows_keeps_group_siblings_adjacent() {
        use crate::util::prop::for_cases;
        for_cases(200, |rng| {
            let k = rng.gen_range_inclusive(1, 8) as usize;
            let n = rng.gen_range_inclusive(1, 24) as usize;
            let ps = problems(k);
            let rows = plan_rows(&ps, n, rng.next_u64(), rng.next_u64());
            let mut seen: Vec<usize> = Vec::new();
            for r in &rows {
                match seen.last() {
                    Some(&g) if g == r.group_idx => {}
                    _ => {
                        assert!(
                            !seen.contains(&r.group_idx),
                            "group {} split into non-adjacent blocks",
                            r.group_idx
                        );
                        seen.push(r.group_idx);
                    }
                }
            }
            assert_eq!(seen.len(), k, "every group must appear exactly once");
        });
    }

    /// Property: the queue always delivers exactly n rows per group in
    /// group-major order, whatever (n, k).
    #[test]
    fn plan_rows_partition_exactly() {
        use crate::util::prop::for_cases;
        for_cases(200, |rng| {
            let k = rng.gen_range_inclusive(1, 6) as usize;
            let n = rng.gen_range_inclusive(1, 40) as usize;
            let ps = problems(k);
            let rows = plan_rows(&ps, n, rng.next_u64(), rng.next_u64());
            assert_eq!(rows.len(), k * n);
            let mut per_group = vec![0usize; k];
            let mut last_group = 0usize;
            for r in &rows {
                assert!(r.group_idx >= last_group, "queue must be group-major");
                last_group = r.group_idx;
                per_group[r.group_idx] += 1;
            }
            assert_eq!(per_group, vec![n; k]);
        });
    }
}
