//! `pods` — the leader binary: train / eval / experiment drivers.
//!
//! ```text
//! pods train --config configs/setting_a.toml [--iterations N]
//! pods eval  --ckpt results/base_arith_300.ckpt --task arith --split test --chunk 16
//! pods exp   fig1|fig3|fig4|fig5|fig6|fig7|sched|shard|fleet|prune|budget|reuse|kv|faults|table3|all [--setting a] [--quick] [--probe]
//! pods info  --profile base
//! pods bench-check [--fresh BENCH_e2e.json] [--baseline rust/benches/BENCH_baseline.json] [--bless] [--require-baseline]
//! pods config-docs [--check] [--out docs/CONFIG.md]
//! ```
//!
//! (CLI is hand-rolled over std::env::args — clap is unavailable in this
//! offline environment; DESIGN.md §Substitutions.)

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::PathBuf;

use pods::config::RunConfig;
use pods::coordinator::scheduler::Trainer;
use pods::exp::{self, Scale};
use pods::reward::RewardWeights;
use pods::runtime::{params as ckpt, Engine};
use pods::tasks::{Split, TaskKind};

const USAGE: &str = "\
pods — Policy Optimization with Down-Sampling (paper reproduction)

USAGE:
  pods train --config <path> [--iterations N] [--artifacts DIR] [--resume]
             --resume continues from the [ckpt] resume file when present
             (crash recovery; bit-identical to the uninterrupted run)
  pods eval  --ckpt <path> [--task arith|poly|mcq] [--split train|test|platinum]
             [--profile NAME] [--problems N] [--chunk C]
  pods exp   <fig1|fig3|fig4|fig5|fig6|fig7|sched|shard|fleet|prune|budget|reuse|kv|faults|table3|all>
             [--setting a-f] [--quick] [--out-dir DIR] [--probe]
  pods info  [--profile NAME]
  pods bench-check [--fresh PATH] [--baseline PATH] [--max-regression FRAC]
             [--min-speedup RATIO] [--min-prune-speedup RATIO]
             [--min-replay-speedup RATIO] [--min-kv-speedup RATIO]
             [--min-fleet-speedup RATIO] [--bless] [--require-baseline]
             --bless regenerates the committed baseline from the fresh
             report instead of checking against it
             --require-baseline makes a missing or entry-less baseline a
             hard failure instead of a passing warning
  pods config-docs [--check] [--out PATH]
             generate docs/CONFIG.md from the config structs;
             --check fails when the committed file is stale (CI)
";

/// Tiny flag parser: positionals + `--key value` + boolean `--key`.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

const BOOL_FLAGS: &[&str] =
    &["quick", "probe", "help", "check", "bless", "resume", "require-baseline"];

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if BOOL_FLAGS.contains(&name) {
                    flags.insert(name.to_string(), "true".to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    flags.insert(name.to_string(), v.clone());
                }
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Self { positional, flags })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

fn split_of(s: &str) -> Result<Split> {
    match s {
        "train" => Ok(Split::Train),
        "test" => Ok(Split::Test),
        "platinum" => Ok(Split::Platinum),
        other => Err(anyhow!("unknown split {other:?}")),
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..])?;
    if args.has("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let artifacts: PathBuf = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(pods::default_artifacts_dir);

    match cmd.as_str() {
        "train" => {
            let config = args.get("config").ok_or_else(|| anyhow!("train needs --config"))?;
            let mut cfg = RunConfig::from_path(std::path::Path::new(config))?;
            if let Some(it) = args.get("iterations") {
                cfg.run.iterations = it.parse()?;
            }
            let resume = args.has("resume");
            let resume_path = cfg.ckpt.resume_path(&cfg.run.out_dir, &cfg.run.name);
            let mut tr = Trainer::new(&artifacts, cfg)?;
            if resume {
                let path = std::path::Path::new(&resume_path);
                if path.exists() {
                    tr.resume_from(path)?;
                } else {
                    eprintln!(
                        "[train] --resume: no resume state at {resume_path}; starting fresh"
                    );
                }
            }
            tr.run()?;
        }
        "eval" => {
            let ckpt_path = args.get("ckpt").ok_or_else(|| anyhow!("eval needs --ckpt"))?;
            let profile = args.get_or("profile", "base");
            let engine = Engine::load(&artifacts, &profile)?;
            let (_, store, base) = ckpt::load_store(std::path::Path::new(ckpt_path))?;
            let task = TaskKind::parse(&args.get_or("task", "arith"))?;
            let split = split_of(&args.get_or("split", "test"))?;
            let problems: usize = args.get_or("problems", "64").parse()?;
            let (params, lora): (&[f32], Option<&[f32]>) = match &base {
                Some(b) => (b, Some(&store.params)),
                None => (&store.params, None),
            };
            let chunk = match args.get("chunk") {
                Some(c) => c.parse()?,
                None => engine.meta.default_decode_chunk().ok_or_else(|| {
                    anyhow!(
                        "profile {} has no decode_chunk programs; re-run `make artifacts`",
                        engine.meta.profile
                    )
                })?,
            };
            let stats = pods::eval::evaluate(
                &engine,
                params,
                if engine.meta.is_lora() { lora } else { None },
                task,
                split,
                problems,
                &RewardWeights::default(),
                chunk,
            )?;
            println!(
                "task {} split {:?}: accuracy {:.3} format {:.3} reward {:.3} len {:.1} over {} problems",
                task.name(),
                split,
                stats.accuracy,
                stats.format_rate,
                stats.mean_reward,
                stats.mean_len,
                stats.problems
            );
        }
        "exp" => {
            let which = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("exp needs a figure name"))?
                .clone();
            let scale = if args.has("quick") { Scale::Quick } else { Scale::Full };
            let out_dir = args.get_or("out-dir", "results");
            let probe = args.has("probe");
            match which.as_str() {
                "fig1" => exp::fig1::run(&artifacts, &out_dir, probe)?,
                "fig3" => match args.get("setting") {
                    Some(s) => exp::fig3::run_setting(&artifacts, s, scale, &out_dir)?,
                    None => exp::fig3::run_all(&artifacts, scale, &out_dir)?,
                },
                "fig4" => exp::fig4::run(&artifacts, scale, &out_dir)?,
                "fig5" => exp::fig5::run(&artifacts, scale, &out_dir)?,
                "fig6" => exp::fig6::run(&artifacts, scale, &out_dir)?,
                "fig7" => exp::fig7::run(&artifacts, scale, &out_dir)?,
                "sched" => exp::sched::run(&artifacts, scale, &out_dir)?,
                "shard" => exp::shard::run(&out_dir)?,
                "fleet" => exp::fleet::run(&out_dir)?,
                "prune" => exp::prune::run(&out_dir)?,
                "budget" => exp::budget::run(&out_dir)?,
                "reuse" => exp::reuse::run(&out_dir)?,
                "kv" => exp::kv::run(&out_dir)?,
                "faults" => exp::faults::run(&out_dir)?,
                "table3" => exp::table3::run(&out_dir)?,
                "all" => {
                    exp::fig1::run(&artifacts, &out_dir, probe)?;
                    exp::fig3::run_all(&artifacts, scale, &out_dir)?;
                    exp::fig4::run(&artifacts, scale, &out_dir)?;
                    exp::fig5::run(&artifacts, scale, &out_dir)?;
                    exp::fig6::run(&artifacts, scale, &out_dir)?;
                    exp::fig7::run(&artifacts, scale, &out_dir)?;
                    exp::sched::run(&artifacts, scale, &out_dir)?;
                    exp::shard::run(&out_dir)?;
                    exp::fleet::run(&out_dir)?;
                    exp::prune::run(&out_dir)?;
                    exp::budget::run(&out_dir)?;
                    exp::reuse::run(&out_dir)?;
                    exp::kv::run(&out_dir)?;
                    exp::faults::run(&out_dir)?;
                    exp::table3::run(&out_dir)?;
                }
                other => bail!("unknown experiment {other:?}"),
            }
        }
        "info" => {
            let profile = args.get_or("profile", "base");
            let engine = Engine::load(&artifacts, &profile)?;
            let m = &engine.meta;
            println!("profile {}", m.profile);
            println!(
                "  model: d={} L={} H={} dff={} vocab={} T={} P={} G={}",
                m.config.d_model,
                m.config.layers,
                m.config.heads,
                m.config.d_ff,
                m.config.vocab,
                m.config.seq_len,
                m.config.prompt_len,
                m.gen_len
            );
            println!(
                "  params: {} (trainable {}, lora rank {})",
                m.param_count, m.trainable_count, m.config.lora_rank
            );
            println!(
                "  batches: rollout {} update {}",
                m.config.rollout_batch, m.config.update_batch
            );
            let mut names: Vec<&String> = m.programs.keys().collect();
            names.sort();
            for name in names {
                let sig = &m.programs[name];
                println!(
                    "  program {name}: {} inputs -> {} outputs",
                    sig.inputs.len(),
                    sig.outputs.len()
                );
            }
        }
        "bench-check" => {
            let fresh = args.get_or("fresh", "BENCH_e2e.json");
            let baseline = args.get_or("baseline", "rust/benches/BENCH_baseline.json");
            if args.has("bless") {
                // legitimate baseline refresh: regenerate the committed
                // JSON from the fresh run instead of hand-editing it
                let line = pods::util::bench::bless_baseline(
                    std::path::Path::new(&fresh),
                    std::path::Path::new(&baseline),
                )?;
                println!("{line}");
                // print the blessed file's content hash so the commit that
                // records it can be matched to later bench-check logs
                let h = pods::util::bench::baseline_hash(std::path::Path::new(&baseline))?;
                println!("baseline hash: {h}");
                return Ok(());
            }
            let max_reg: f64 = args.get_or("max-regression", "0.15").parse()?;
            let require_baseline = args.has("require-baseline");
            if require_baseline && !std::path::Path::new(&baseline).exists() {
                bail!("--require-baseline: no baseline at {baseline} (record one with --bless)");
            }
            if std::path::Path::new(&baseline).exists() {
                // identify which baseline revision this log compared
                // against (the git blob hash of the committed file)
                let h = pods::util::bench::baseline_hash(std::path::Path::new(&baseline))?;
                println!("baseline {baseline} hash: {h}");
            }
            let report = pods::util::bench::check_regression(
                std::path::Path::new(&fresh),
                std::path::Path::new(&baseline),
                max_reg,
            )?;
            for line in &report.lines {
                println!("{line}");
            }
            for w in &report.warnings {
                eprintln!("WARNING: {w}");
                // GitHub Actions annotation — visible on the workflow
                // summary instead of buried in the job log
                println!("::warning::{w}");
            }
            if require_baseline && !report.warnings.is_empty() {
                // the empty-baseline state is a documented no-op by
                // default; this flag is the opt-in that refuses to call
                // a guard that guards nothing "passing"
                bail!(
                    "--require-baseline: {} warning(s) degrade the regression guard \
                     to a no-op (bless a real baseline to clear them)",
                    report.warnings.len()
                );
            }
            if !report.regressions.is_empty() {
                for r in &report.regressions {
                    eprintln!("REGRESSION: {r}");
                }
                bail!(
                    "{} bench(es) regressed more than {:.0}% vs {baseline}",
                    report.regressions.len(),
                    max_reg * 100.0
                );
            }
            // machine-independent guard: the chunked arm must keep beating
            // the full-G (no early exit) arm within this same run
            let min_speedup: f64 = args.get_or("min-speedup", "1.1").parse()?;
            match pods::util::bench::check_speedup(
                std::path::Path::new(&fresh),
                "e2e step pods (n=64 -> m=16)",
                "e2e step pods full-G batch (no early exit)",
                min_speedup,
            )? {
                Some(line) => println!("{line}"),
                None => println!("speedup guard: comparison arms absent from {fresh} — skipped"),
            }
            // same-run floor of online pruning over the identical pipeline
            // without it (only meaningful when the rule carries a
            // token-budget stage, which the bench arm does)
            let min_prune: f64 = args.get_or("min-prune-speedup", "1.0").parse()?;
            match pods::util::bench::check_speedup(
                std::path::Path::new(&fresh),
                "e2e step pods online-prune (same rule)",
                "e2e step pods prune-rule (online off)",
                min_prune,
            )? {
                Some(line) => println!("{line}"),
                None => {
                    println!("prune speedup guard: comparison arms absent from {fresh} — skipped")
                }
            }
            // same-run floor for replay mixing: stored rows skip inference
            // entirely, so the replay arm must not cost step wall-clock
            // (small tolerance for the extra update rows it trains)
            let min_replay: f64 = args.get_or("min-replay-speedup", "0.9").parse()?;
            match pods::util::bench::check_speedup(
                std::path::Path::new(&fresh),
                "e2e step pods + replay (mix=0.25)",
                "e2e step pods (n=64 -> m=16)",
                min_replay,
            )? {
                Some(line) => println!("{line}"),
                None => {
                    println!("replay speedup guard: comparison arms absent from {fresh} — skipped")
                }
            }
            // same-run floor for group-shared prompt KV: sibling rows admit
            // from the group snapshot instead of re-running prefill, so the
            // shared arm must not cost step wall-clock against the per-row
            // arm of the identical workload
            let min_kv: f64 = args.get_or("min-kv-speedup", "1.0").parse()?;
            match pods::util::bench::check_speedup(
                std::path::Path::new(&fresh),
                "e2e step pods shared-kv (n=64, m=8)",
                "e2e step pods per-row-prefill (n=64, m=8)",
                min_kv,
            )? {
                Some(line) => println!("{line}"),
                None => {
                    println!("kv speedup guard: comparison arms absent from {fresh} — skipped")
                }
            }
            // same-run floor for the staleness-K fleet schedule: the R>1
            // arm keeps two generation batches in flight, so the worker
            // pool rides through each batch's straggler tail and must not
            // fall behind the depth-1 pipelined arm of the same workload
            let min_fleet: f64 = args.get_or("min-fleet-speedup", "1.0").parse()?;
            match pods::util::bench::check_speedup(
                std::path::Path::new(&fresh),
                "e2e step pods fleet (r=2, k=2, 4w)",
                "e2e step pods pipelined (4w)",
                min_fleet,
            )? {
                Some(line) => println!("{line}"),
                None => {
                    println!("fleet speedup guard: comparison arms absent from {fresh} — skipped")
                }
            }
        }
        "config-docs" => {
            let out = args.get_or("out", "docs/CONFIG.md");
            let path = std::path::Path::new(&out);
            if args.has("check") {
                pods::config::docs::check(path)?;
                println!("{out} is up to date");
            } else {
                std::fs::write(path, pods::config::docs::render())
                    .map_err(|e| anyhow!("writing {out}: {e}"))?;
                println!("wrote {out}");
            }
        }
        other => {
            eprint!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
