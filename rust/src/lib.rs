//! # PODS — Policy Optimization with Down-Sampling
//!
//! A full-stack reproduction of *"Not All Rollouts are Useful: Down-Sampling
//! Rollouts in LLM Reinforcement Learning"* (Xu, Savani, Fang, Kolter, 2025).
//!
//! Architecture (three layers, Python only at build time):
//!
//! * **L1 — Pallas kernels** (`python/compile/kernels/`): fused attention,
//!   token log-prob, GRPO surrogate and AdamW kernels.
//! * **L2 — JAX model** (`python/compile/model.py`): the policy transformer,
//!   rollout sampling with a KV cache, GRPO loss fwd/bwd — AOT-lowered to
//!   HLO text artifacts by `python/compile/aot.py`.
//! * **L3 — this crate**: the Rust coordinator owning the training loop,
//!   rollout scheduling, **down-sampling** (the paper's contribution),
//!   gradient accumulation, the simulated multi-worker topology, rewards,
//!   evaluation and the experiment harness. Executes the artifacts through
//!   PJRT (`runtime`).
//!
//! Start at [`coordinator::scheduler::Trainer`] for the training step state
//! machine, and [`coordinator::downsample`] for the paper's Algorithm 2.

pub mod config;
pub mod coordinator;
pub mod eval;
pub mod exp;
pub mod hwsim;
pub mod metrics;
pub mod reward;
pub mod rollout;
pub mod runtime;
pub mod tasks;
pub mod util;

/// Default artifacts directory (relative to the crate root at dev time;
/// override with `--artifacts` or `PODS_ARTIFACTS`).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PODS_ARTIFACTS") {
        return p.into();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
