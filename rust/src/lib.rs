//! # PODS — Policy Optimization with Down-Sampling
//!
//! A full-stack reproduction of *"Not All Rollouts are Useful: Down-Sampling
//! Rollouts in LLM Reinforcement Learning"* (Xu, Savani, Fang, Kolter, 2025).
//!
//! ## Architecture (three layers, Python only at build time)
//!
//! * **L1 — Pallas kernels** (`python/compile/kernels/`): fused attention,
//!   token log-prob, GRPO surrogate and AdamW kernels.
//! * **L2 — JAX model** (`python/compile/model.py`): the policy transformer,
//!   rollout sampling with a KV cache, GRPO loss fwd/bwd — AOT-lowered to
//!   HLO text artifacts by `python/compile/aot.py`.
//! * **L3 — this crate**: the Rust coordinator owning the training loop and
//!   executing the artifacts through PJRT ([`runtime`]).
//!
//! ## The L3 training loop: a staged executor
//!
//! One iteration is driven by [`coordinator::exec::TrainLoop`], which
//! composes two engines under a config-selected schedule
//! (`[hwsim] schedule = "sync" | "pipelined"`):
//!
//! ```text
//!            coordinator::exec::RolloutEngine      ◄── hwsim.workers
//!    (REAL thread pool: one PJRT engine replica per worker;
//!     rollout::plan_rows builds the iteration's refill queue)
//!                         │
//!  tasks ──► rollout::chunked (slot-based continuous batching:
//!            prefill ──► decode_chunk × ceil(tokens/C) ──► early exit)
//!                         │
//!            reward ──► coordinator::group (PromptGroup)
//!                                        │
//!                       coordinator::select  ◄── config `algo.rule` spec
//!                (Selector pipelines: registry-resolved,
//!                 per-group deterministic RNG, diagnostics)
//!                                        │
//!       coordinator::advantage ──► coordinator::exec::UpdateEngine
//!                 (micro-batch packing ──► accum ──► runtime)
//!                                        │
//!          hwsim clock (overlap-aware) ──► metrics CSVs ──► exp figures
//! ```
//!
//! **Decode path.** Generation runs on two AOT programs instead of one
//! monolithic `G`-step scan: `prefill` seeds the KV caches from the
//! prompts, and `decode_chunk<C>` advances every slot `C` tokens with the
//! caches carried across calls. The [`rollout::chunked`] driver retires
//! rows at EOS between chunks, admits queued rows into the freed slots
//! (`[rollout] refill = "continuous"`), and stops as soon as the queue
//! drains — decode work tracks actual generated tokens (ceil-to-chunk),
//! not `rows × G`. RNG is **per-row and counter-based**
//! (`fold_in(key(row_seed), step)` with `row_seed` keyed by
//! `(run_seed, iter, prompt, rollout_idx)`), so sampled streams are
//! bit-invariant to chunk size, slot assignment, refill order and worker
//! sharding — packing is purely a throughput decision. The hwsim clock
//! charges the same shape ([`hwsim::HwModel::chunked_inference_time`]),
//! and the train CSV reports `gen_tokens_decoded` / `gen_tokens_wasted`.
//!
//! **Schedules.** `sync` runs the phases back-to-back and replays the
//! sequential reference exactly (golden-tested). `pipelined`
//! prefetches generation of iteration *t+1* on the rollout pool — against
//! the pre-update policy, one-step off-policy, sound because the GRPO
//! loss ratios use stored behaviour log-probs — while the main thread
//! updates; the simulated clock then charges `max(inference, update)`
//! for the overlapped portion and records the hidden time per iteration
//! (`sim_overlap_saved` in the train CSV).
//!
//! **Rollout selection** — the paper's contribution — is a first-class,
//! extensible subsystem: [`coordinator::select`] defines a `Selector`
//! trait over a `SelectionContext` (the full rollout group with rewards,
//! generation lengths and log-probs, plus `n`, `m`, the iteration and a
//! per-group deterministic RNG), a spec grammar
//! (`"drop_zero_variance | max_variance"`,
//! `"prune(max_tokens=4096) | percentile"`) and a registry that embedders
//! extend without touching this crate. The numeric kernels — including
//! Algorithm 2, max-variance down-sampling in `O(n log n)` — live in
//! [`coordinator::downsample`].
//!
//! Key modules:
//!
//! * [`config`] — TOML run configs (Table 1/2 settings under `configs/`).
//! * [`coordinator::exec`] — the staged executor: rollout thread pool,
//!   update engine, schedule-aware driver.
//! * [`coordinator::scheduler`] — the GRPO / GRPO-GA / GRPO-PODS trainer
//!   façade ([`coordinator::scheduler::Trainer`]) over the executor.
//! * [`coordinator::select`] — the pluggable selection subsystem.
//! * [`hwsim`] — calibrated accelerator-cost model, the executor
//!   [`hwsim::Schedule`], and the overlap-aware simulated clock all
//!   figures plot against.
//! * [`tasks`] / [`reward`] / [`eval`] — synthetic verifiable-reasoning
//!   task families, rule-based rewards, evaluation tracks.
//! * [`exp`] — one driver per paper figure/table (plus the sync-vs-
//!   pipelined schedule study); [`metrics`] — the CSV schema they
//!   consume.
//!
//! Start at [`coordinator::scheduler::Trainer`] for the training step,
//! [`coordinator::exec`] for the executor, and [`coordinator::select`]
//! for the selection API.

pub mod config;
pub mod coordinator;
pub mod eval;
pub mod exp;
pub mod hwsim;
pub mod metrics;
pub mod reward;
pub mod rollout;
pub mod runtime;
pub mod tasks;
pub mod util;

/// Default artifacts directory (relative to the crate root at dev time;
/// override with `--artifacts` or `PODS_ARTIFACTS`).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PODS_ARTIFACTS") {
        return p.into();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
