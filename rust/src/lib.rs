//! # PODS — Policy Optimization with Down-Sampling
//!
//! A full-stack reproduction of *"Not All Rollouts are Useful:
//! Down-Sampling Rollouts in LLM Reinforcement Learning"* (Xu, Savani,
//! Fang, Kolter, 2025): Pallas kernels (L1) and a JAX policy model (L2)
//! are AOT-lowered to HLO artifacts at build time, and this crate (L3)
//! owns the training loop, executing the artifacts through PJRT.
//!
//! The long-form architecture documentation lives under `docs/` in the
//! repository root — start there:
//!
//! * `docs/ARCHITECTURE.md` — the module map and the dataflow of one
//!   training iteration (rollout → select → update).
//! * `docs/DETERMINISM.md` — the RNG stream contract: per-row decode
//!   streams, per-group selection seeds, and the update engine's
//!   shard-invariance guarantees.
//! * `docs/CONFIG.md` — the generated run-configuration reference
//!   (`pods config-docs`; CI fails when it is stale).
//!
//! In one paragraph: a training iteration generates `n` rollouts per
//! prompt on [`coordinator::exec::RolloutEngine`] (a real thread pool
//! driving the chunked early-exit continuous batcher in
//! [`rollout::chunked`], optionally aborting rollouts mid-decode the
//! moment [`coordinator::select::online`] proves they cannot survive
//! selection), selects `m` of them through the pluggable
//! pipeline in [`coordinator::select`], and trains on the keepers with
//! [`coordinator::exec::UpdateEngine`] — a sharded data-parallel update
//! engine (micro-batch packing, canonical-order gradient accumulation, a
//! simulated ring all-reduce, fused AdamW). The [`hwsim`] cost model
//! prices both phases on a simulated accelerator fleet (the paper's
//! 8×A100 Fig. 1 shape, including the communication model behind
//! `[update] shards`), [`metrics`] records every iteration to CSV, and
//! [`exp`] regenerates each paper figure plus the `sched` and `shard`
//! studies from those CSVs.
//!
//! Entry points: [`coordinator::scheduler::Trainer`] for the training
//! loop, [`coordinator::exec`] for the executor, and
//! [`coordinator::select`] for the selection API.

#![warn(missing_docs)]

pub mod config;
pub mod coordinator;
pub mod eval;
pub mod exp;
pub mod hwsim;
pub mod metrics;
pub mod reward;
pub mod rollout;
pub mod runtime;
pub mod tasks;
pub mod util;

/// Default artifacts directory (relative to the crate root at dev time;
/// override with `--artifacts` or `PODS_ARTIFACTS`).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PODS_ARTIFACTS") {
        return p.into();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
