//! Rule-based reward models (paper §A.1), operating on raw token streams.
//!
//! Three components, summed into a discrete but non-binary reward:
//!
//! * **accuracy** (1/0) — the `<answer>` content matches the ground truth:
//!   numeric equivalence for arith/poly (so `07`, ` 7`, `7` all count),
//!   exact letter for mcq.
//! * **format** (1/0) — the response follows the exact XML pattern
//!   `<think>\n…\n</think>\n<answer>\n…\n</answer>` (checked structurally on
//!   the token stream, the analogue of the paper's regex).
//! * **tag count** (0..1 partial credit, 0.25 per tag) — correct placement
//!   of `<think>\n`, `\n</think>\n`, `\n<answer>\n` and `\n</answer>`.
//!   (The paper's text lists three 0.25 tags; we score the natural four so
//!   the component spans 0..1 as its heading states.)

use crate::tasks::tokenizer as tok;
use crate::tasks::{Problem, TaskKind};

/// Per-component reward breakdown for one rollout.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RewardBreakdown {
    /// 1.0 when the answer matches ground truth.
    pub accuracy: f32,
    /// 1.0 when the response follows the exact XML pattern.
    pub format: f32,
    /// 0..1 partial credit, 0.25 per correctly-placed tag.
    pub tag_count: f32,
}

impl RewardBreakdown {
    /// Weighted sum of the components.
    pub fn total(&self, w: &RewardWeights) -> f32 {
        w.accuracy * self.accuracy + w.format * self.format + w.tags * self.tag_count
    }
}

/// Component weights (all 1.0 in the paper; configurable for ablations).
#[derive(Debug, Clone, Copy)]
pub struct RewardWeights {
    /// Weight of the accuracy component.
    pub accuracy: f32,
    /// Weight of the format component.
    pub format: f32,
    /// Weight of the tag-count component.
    pub tags: f32,
}

impl Default for RewardWeights {
    fn default() -> Self {
        Self { accuracy: 1.0, format: 1.0, tags: 1.0 }
    }
}

/// Extract the generated region of a rollout row: tokens after the prompt,
/// up to (excluding) EOS / first PAD.
pub fn generated_region(row: &[i32], prompt_len: usize) -> &[i32] {
    let gen = &row[prompt_len.min(row.len())..];
    let end = gen
        .iter()
        .position(|&t| t == tok::EOS || t == tok::PAD)
        .unwrap_or(gen.len());
    &gen[..end]
}

/// Find the content between the first `<answer>` and `</answer>` tokens.
fn answer_span(gen: &[i32]) -> Option<&[i32]> {
    let start = gen.iter().position(|&t| t == tok::ANSWER_OPEN)? + 1;
    let len = gen[start..].iter().position(|&t| t == tok::ANSWER_CLOSE)?;
    Some(&gen[start..start + len])
}

/// Numeric-equivalence comparison (trims whitespace/newlines, parses i64).
fn numeric_eq(content: &str, truth: &str) -> bool {
    let c = content.trim().trim_matches('\n').trim();
    match (c.parse::<i64>(), truth.trim().parse::<i64>()) {
        (Ok(a), Ok(b)) => a == b,
        _ => c == truth.trim(),
    }
}

/// Accuracy component.
pub fn accuracy(gen: &[i32], task: TaskKind, problem: &Problem) -> f32 {
    let Some(span) = answer_span(gen) else { return 0.0 };
    let content = tok::decode(span);
    let ok = if task.numeric_answer() {
        numeric_eq(&content, &problem.answer)
    } else {
        content.trim().trim_matches('\n').trim() == problem.answer
    };
    if ok {
        1.0
    } else {
        0.0
    }
}

/// Format component: the exact structural pattern
/// `<think> NL … NL </think> NL <answer> NL … NL </answer>` with no stray
/// tag tokens, matching the paper's `<think>\n...\n</think>\n<answer>\n...\n</answer>`.
pub fn format_compliant(gen: &[i32]) -> f32 {
    // locate the four tags, in order, each appearing exactly once
    let tags = [tok::THINK_OPEN, tok::THINK_CLOSE, tok::ANSWER_OPEN, tok::ANSWER_CLOSE];
    let mut pos = [0usize; 4];
    for (i, &t) in tags.iter().enumerate() {
        let occurrences: Vec<usize> = gen
            .iter()
            .enumerate()
            .filter_map(|(j, &g)| (g == t).then_some(j))
            .collect();
        if occurrences.len() != 1 {
            return 0.0;
        }
        pos[i] = occurrences[0];
    }
    let [to, tc, ao, ac] = pos;
    let ok = to == 0
        && to < tc
        && tc < ao
        && ao < ac
        && ac == gen.len() - 1
        // <think>\n ... \n</think>
        && gen.get(to + 1) == Some(&tok::NL)
        && tc >= 1 && gen[tc - 1] == tok::NL
        // </think>\n<answer>
        && ao == tc + 2 && gen[tc + 1] == tok::NL
        // <answer>\n ... \n</answer>
        && gen.get(ao + 1) == Some(&tok::NL)
        && ac >= 1 && gen[ac - 1] == tok::NL
        // non-empty think and answer bodies
        && tc > to + 2
        && ac > ao + 2;
    if ok {
        1.0
    } else {
        0.0
    }
}

/// Tag-count component: 0.25 per correctly placed tag pattern.
pub fn tag_count(gen: &[i32]) -> f32 {
    let count = |pat: &[i32]| gen.windows(pat.len()).filter(|w| *w == pat).count();
    let mut score = 0.0;
    // <think>\n at the start
    if gen.len() >= 2 && gen[0] == tok::THINK_OPEN && gen[1] == tok::NL {
        score += 0.25;
    }
    // \n</think>\n exactly once
    if count(&[tok::NL, tok::THINK_CLOSE, tok::NL]) == 1 {
        score += 0.25;
    }
    // \n<answer>\n exactly once
    if count(&[tok::NL, tok::ANSWER_OPEN, tok::NL]) == 1 {
        score += 0.25;
    }
    // \n</answer> at the very end
    if gen.len() >= 2 && gen[gen.len() - 1] == tok::ANSWER_CLOSE && gen[gen.len() - 2] == tok::NL {
        score += 0.25;
    }
    score
}

/// Score one rollout row (full sequence incl. prompt).
pub fn score_rollout(row: &[i32], prompt_len: usize, task: TaskKind, problem: &Problem) -> RewardBreakdown {
    let gen = generated_region(row, prompt_len);
    RewardBreakdown {
        accuracy: accuracy(gen, task, problem),
        format: format_compliant(gen),
        tag_count: tag_count(gen),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::Split;

    fn ideal(task: TaskKind, i: u64) -> (Problem, Vec<i32>) {
        let p = task.generate(Split::Train, i);
        let mut row = p.prompt.clone();
        row.extend(&p.ideal_response);
        (p, row)
    }

    #[test]
    fn ideal_responses_score_max() {
        for task in [TaskKind::Arith, TaskKind::Poly, TaskKind::Mcq] {
            for i in 0..50 {
                let (p, row) = ideal(task, i);
                let r = score_rollout(&row, p.prompt.len(), task, &p);
                assert_eq!(r.accuracy, 1.0, "{task:?} #{i}: {}", tok::decode(&row));
                assert_eq!(r.format, 1.0, "{task:?} #{i}");
                assert_eq!(r.tag_count, 1.0, "{task:?} #{i}");
                assert_eq!(r.total(&RewardWeights::default()), 3.0);
            }
        }
    }

    #[test]
    fn wrong_answer_still_gets_format_credit() {
        let p = TaskKind::Arith.generate(Split::Train, 1);
        let resp = tok::encode("<think>\n1+1=2\n</think>\n<answer>\n999999\n</answer>").unwrap();
        let mut row = p.prompt.clone();
        row.extend(&resp);
        row.push(tok::EOS);
        let r = score_rollout(&row, p.prompt.len(), TaskKind::Arith, &p);
        assert_eq!(r.accuracy, 0.0);
        assert_eq!(r.format, 1.0);
        assert_eq!(r.tag_count, 1.0);
    }

    #[test]
    fn numeric_equivalence_tolerates_leading_zeros() {
        let p = TaskKind::Arith.generate(Split::Train, 2);
        let padded = format!("0{}", p.answer);
        let resp = tok::encode(&format!("<think>\nx\n</think>\n<answer>\n{padded}\n</answer>")).unwrap();
        let mut row = p.prompt.clone();
        row.extend(&resp);
        let r = score_rollout(&row, p.prompt.len(), TaskKind::Arith, &p);
        assert_eq!(r.accuracy, 1.0);
    }

    #[test]
    fn mcq_requires_exact_letter() {
        let p = TaskKind::Mcq.generate(Split::Train, 3);
        let wrong = if p.answer == "A" { "B" } else { "A" };
        let resp = tok::encode(&format!("<think>\nx\n</think>\n<answer>\n{wrong}\n</answer>")).unwrap();
        let mut row = p.prompt.clone();
        row.extend(&resp);
        let r = score_rollout(&row, p.prompt.len(), TaskKind::Mcq, &p);
        assert_eq!(r.accuracy, 0.0);
    }

    #[test]
    fn garbage_scores_zero() {
        let p = TaskKind::Arith.generate(Split::Train, 4);
        let mut row = p.prompt.clone();
        row.extend(tok::encode("12345").unwrap());
        let r = score_rollout(&row, p.prompt.len(), TaskKind::Arith, &p);
        assert_eq!(r.total(&RewardWeights::default()), 0.0);
    }

    #[test]
    fn partial_tags_get_partial_credit() {
        let p = TaskKind::Arith.generate(Split::Train, 5);
        // think block well-formed, answer block missing entirely
        let resp = tok::encode("<think>\n1+1=2\n</think>\n7").unwrap();
        let mut row = p.prompt.clone();
        row.extend(&resp);
        let r = score_rollout(&row, p.prompt.len(), TaskKind::Arith, &p);
        assert_eq!(r.format, 0.0);
        assert_eq!(r.tag_count, 0.5); // <think>\n and \n</think>\n
        assert_eq!(r.accuracy, 0.0);
    }

    #[test]
    fn duplicate_tags_break_format() {
        let p = TaskKind::Arith.generate(Split::Train, 6);
        let resp = tok::encode(&format!(
            "<think>\nx\n</think>\n<answer>\n{}\n</answer>\n<answer>\n3\n</answer>",
            p.answer
        ))
        .unwrap();
        let mut row = p.prompt.clone();
        row.extend(&resp);
        let r = score_rollout(&row, p.prompt.len(), TaskKind::Arith, &p);
        assert_eq!(r.format, 0.0);
        // accuracy still reads the FIRST answer span
        assert_eq!(r.accuracy, 1.0);
    }

    #[test]
    fn generated_region_stops_at_eos() {
        let row = vec![9, 9, tok::ANSWER_OPEN, tok::EOS, 9, 9];
        assert_eq!(generated_region(&row, 2), &[tok::ANSWER_OPEN]);
    }
}
