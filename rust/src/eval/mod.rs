//! Test-set evaluation: greedy decode + rule-based verification.
//!
//! Runs on the chunked early-exit driver ([`crate::rollout::decode_rows`])
//! with temperature 0 (argmax decode): one row per problem, `B_r` slots
//! decoding concurrently with continuous refill, so eval — which used to
//! pay the full `G`-step monolithic scan per batch — stops decoding each
//! problem at its EOS. Greedy decode is RNG-free, so the chunked outputs
//! are identical to the monolithic program's (pinned by
//! `rust/tests/decode_golden.rs`). Used for the accuracy curves of
//! Figs. 3–7 and the generalization study (test vs platinum vs cross-task
//! splits).

use crate::reward::{score_rollout, RewardWeights};
use crate::rollout::{decode_rows, RefillMode, RowSpec};
use crate::runtime::Engine;
use crate::tasks::{Split, TaskKind};
use anyhow::Result;

/// Aggregate evaluation result.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvalStats {
    /// Exact-answer accuracy over the evaluated problems.
    pub accuracy: f32,
    /// Fraction of completions with well-formed answer tags.
    pub format_rate: f32,
    /// Mean total reward.
    pub mean_reward: f32,
    /// Mean generated length (tokens incl. EOS).
    pub mean_len: f32,
    /// Number of problems evaluated.
    pub problems: usize,
    /// Decode-step slots physically executed (early exit makes this track
    /// actual generated tokens, not problems × G).
    pub gen_tokens_decoded: usize,
}

/// Evaluate `count` problems of `task`/`split` with greedy decode,
/// `decode_chunk` tokens per decode call.
#[allow(clippy::too_many_arguments)]
pub fn evaluate(
    engine: &Engine,
    params: &[f32],
    lora: Option<&[f32]>,
    task: TaskKind,
    split: Split,
    count: usize,
    weights: &RewardWeights,
    decode_chunk: usize,
) -> Result<EvalStats> {
    let problems = task.batch(split, 0, count);
    // one greedy row per problem; seeds are irrelevant at temperature 0
    let rows: Vec<RowSpec> = (0..problems.len())
        .map(|i| RowSpec { group_idx: i, rollout_idx: 0, seed: 0 })
        .collect();
    let (outs, dstats) = decode_rows(
        engine,
        params,
        lora,
        0.0,
        decode_chunk,
        RefillMode::Continuous,
        &rows,
        &problems,
    )?;
    let p = engine.meta.config.prompt_len;
    let mut acc = 0f64;
    let mut fmt = 0f64;
    let mut rew = 0f64;
    let mut len = 0f64;
    for out in &outs {
        let r = score_rollout(&out.tokens, p, task, &problems[out.group_idx]);
        acc += r.accuracy as f64;
        fmt += r.format as f64;
        rew += r.total(weights) as f64;
        len += out.gen_len as f64;
    }
    let n = outs.len().max(1) as f64;
    Ok(EvalStats {
        accuracy: (acc / n) as f32,
        format_rate: (fmt / n) as f32,
        mean_reward: (rew / n) as f32,
        mean_len: (len / n) as f32,
        problems: outs.len(),
        gen_tokens_decoded: dstats.gen_tokens_decoded,
    })
}
