//! Test-set evaluation: greedy decode + rule-based verification.
//!
//! Reuses the rollout artifact with temperature 0 (argmax decode), batching
//! distinct problems per call. Used for the accuracy curves of Figs. 3–7
//! and the generalization study (test vs platinum vs cross-task splits).

use crate::reward::{score_rollout, RewardWeights};
use crate::rollout::mixed_prompt_batch;
use crate::runtime::Engine;
use crate::tasks::{Split, TaskKind};
use anyhow::Result;

/// Aggregate evaluation result.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvalStats {
    pub accuracy: f32,
    pub format_rate: f32,
    pub mean_reward: f32,
    pub mean_len: f32,
    pub problems: usize,
}

/// Evaluate `count` problems of `task`/`split` with greedy decode.
pub fn evaluate(
    engine: &Engine,
    params: &[f32],
    lora: Option<&[f32]>,
    task: TaskKind,
    split: Split,
    count: usize,
    weights: &RewardWeights,
) -> Result<EvalStats> {
    let br = engine.meta.config.rollout_batch;
    let t = engine.meta.config.seq_len;
    let p = engine.meta.config.prompt_len;
    let problems = task.batch(split, 0, count);
    let mut acc = 0f64;
    let mut fmt = 0f64;
    let mut rew = 0f64;
    let mut len = 0f64;
    let mut done = 0usize;
    for chunk in problems.chunks(br) {
        let prompts: Vec<&[i32]> = chunk.iter().map(|pr| pr.prompt.as_slice()).collect();
        let (batch, pads) = mixed_prompt_batch(engine, &prompts)?;
        let out = engine.rollout(params, lora, &batch, &pads, 0, 0.0)?;
        for (b, problem) in chunk.iter().enumerate() {
            let row = &out.tokens.data[b * t..(b + 1) * t];
            let r = score_rollout(row, p, task, problem);
            acc += r.accuracy as f64;
            fmt += r.format as f64;
            rew += r.total(weights) as f64;
            len += out.gen_len[b] as f64;
            done += 1;
        }
    }
    let n = done.max(1) as f64;
    Ok(EvalStats {
        accuracy: (acc / n) as f32,
        format_rate: (fmt / n) as f32,
        mean_reward: (rew / n) as f32,
        mean_len: (len / n) as f32,
        problems: done,
    })
}
