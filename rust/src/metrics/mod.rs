//! Training telemetry: per-iteration rows, CSV sinks, run manifests.
//!
//! Every experiment figure is regenerated from these CSVs (exp module), so
//! the schema is stable and explicit: one row per training iteration plus
//! interleaved evaluation snapshots. CSV serialization is a tiny trait
//! (std-only environment; DESIGN.md §Substitutions).

use anyhow::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// A struct that knows how to print itself as one CSV line.
pub trait CsvRow {
    /// The header line (column names, comma-separated; embedded
    /// whitespace is stripped at write time).
    fn csv_header() -> &'static str;
    /// This record as one comma-separated row matching the header.
    fn csv_row(&self) -> String;
}

/// One training-iteration record.
#[derive(Debug, Clone, Default)]
pub struct IterRow {
    /// Training iteration index (0-based).
    pub iter: usize,
    /// Simulated wall-clock (hwsim) — the x-axis of the paper's figures.
    pub sim_time: f64,
    /// Real CPU wall-clock consumed by this process so far.
    pub real_time: f64,
    /// Simulated cost of this iteration's inference phase.
    pub sim_inference_time: f64,
    /// Simulated cost of this iteration's update phase (incl. comm).
    pub sim_update_time: f64,
    /// Mean total reward over all generated rollouts this iteration.
    pub train_reward: f32,
    /// Mean accuracy-component over all generated rollouts.
    pub train_acc: f32,
    /// Mean generated length (tokens incl. EOS) — Figs. 8–10.
    pub completion_len: f32,
    /// Reward variance of the *selected* update batch.
    pub sel_variance: f64,
    /// Generated tokens in the rollouts kept by selection this iteration.
    pub sel_tokens_kept: usize,
    /// Generated tokens in the rollouts selection dropped (inference spend
    /// the update phase does not pay for again).
    pub sel_tokens_dropped: usize,
    /// Prompt groups whose selection came back empty (e.g. zero-signal
    /// groups removed by `drop_zero_variance`).
    pub sel_groups_dropped: usize,
    /// Mean update loss over trained rollouts.
    pub loss: f32,
    /// Mean clipped-ratio fraction over trained rollouts.
    pub clip_frac: f32,
    /// Mean KL-to-reference over trained rollouts.
    pub kl: f32,
    /// Physical `grad` calls the update executed.
    pub micro_steps: usize,
    /// Rollouts generated this iteration.
    pub rollouts_generated: usize,
    /// Rollouts the update trained on (after selection).
    pub rollouts_trained: usize,
    /// What the simulated clock actually advanced during this iteration —
    /// `sim_inference_time + sim_update_time` under the sync schedule,
    /// less when the pipelined executor hid generation behind an update.
    pub sim_step_time: f64,
    /// Simulated time hidden by inference/update overlap this iteration
    /// (zero under the sync schedule).
    pub sim_overlap_saved: f64,
    /// Executor schedule the run used (`sync` | `pipelined`). New columns
    /// append at the end: figure readers resolve columns by header name.
    pub schedule: String,
    /// Decode-step slots the chunked driver physically executed this
    /// iteration (`B_r × C` per chunk call, post-EOS + filler included).
    pub gen_tokens_decoded: usize,
    /// `gen_tokens_decoded` minus the useful generated tokens
    /// (`total_gen_tokens`) — decode spend that produced nothing
    /// trainable. The monolithic decoder wasted `rollouts × G - useful`.
    pub gen_tokens_wasted: usize,
    /// Simulated data-parallel shards the update phase was split over
    /// (`[update] shards`).
    pub upd_shards: usize,
    /// Simulated ring all-reduce time inside `sim_update_time` (zero for
    /// a single shard) — the communication axis of the `exp shard` study.
    pub upd_comm_time: f64,
    /// Peak rollouts resident per shard in one update micro-step — the
    /// unit the Fig. 1 memory ceiling (`hwsim.mem_capacity_rollouts`) is
    /// denominated in.
    pub upd_peak_mem: usize,
    /// Decode budget released by online pruning this iteration
    /// (`[rollout] online_prune`): per aborted rollout, the generation
    /// budget `G` minus what it had decoded at the abort boundary. Zero
    /// when pruning is off or nothing was provably doomed.
    pub gen_tokens_pruned: usize,
    /// Rollouts aborted mid-decode by online pruning this iteration.
    pub rows_pruned_online: usize,
    /// Stored rows the replay store mixed into this update (`[replay]`;
    /// zero when disabled or the store was empty).
    pub replay_rows_used: usize,
    /// Rows resident in the replay store after this iteration's
    /// admissions and evictions.
    pub replay_store_size: usize,
    /// Mean staleness in iterations of the rows replayed this update
    /// (zero when none were).
    pub replay_mean_staleness: f64,
    /// Physical prompt-prefill calls the decode drivers executed this
    /// iteration (`[rollout] share_prompt_kv`: at most one per admitted
    /// group per worker shard; off: one per admission event).
    pub prefill_calls: usize,
    /// Refill admissions served from a resident group-prompt snapshot
    /// instead of a fresh prefill (zero with sharing off).
    pub prefill_calls_saved: usize,
    /// Peak bytes resident in the modeled paged KV pool (max over worker
    /// shards — pools are per simulated device).
    pub kv_peak_bytes: u64,
    /// Row-attempt faults injected by the `[faults]` schedule this
    /// iteration (zero with the section disabled).
    pub faults_injected: usize,
    /// Physical shard retry jobs submitted this iteration (a partition
    /// detail like call counts — may vary with worker count).
    pub shard_retries: usize,
    /// Rollout rows lost after exhausting `faults.max_retries`.
    pub rows_lost: usize,
    /// Simulated time spent on fault handling (retry backoff + crashed
    /// attempts' wasted decode + straggler slowdown); included in
    /// `sim_inference_time`.
    pub retry_time: f64,
    /// Extra rollout rows the `[budget]` allocator streamed to
    /// wide-bracket groups past the probe quota (zero when disabled).
    pub budget_extra_rows: usize,
    /// Groups whose probe reward bracket was already narrower than
    /// `budget.width_threshold` (zero when disabled).
    pub budget_saturated_groups: usize,
    /// Inference replicas the `[fleet]` schedule ran with (1 = the legacy
    /// single-pool schedules).
    pub fleet_replicas: usize,
    /// Realized staleness of the batch this update consumed — its target
    /// iteration minus the iteration whose policy generated it. 0 under
    /// sync, ≤ 1 under legacy pipelined, ≤ `fleet.max_staleness` always.
    pub fleet_staleness: usize,
    /// Running mean of `fleet_staleness` over iterations so far
    /// (recomputed from recorded rows, so resume reproduces it bitwise).
    pub fleet_mean_staleness: f64,
    /// Running max of `fleet_staleness` over iterations so far.
    pub fleet_max_staleness: usize,
    /// Ready-batch queue depth after this iteration's refill.
    pub fleet_queue_depth: usize,
    /// Simulated time producers spent blocked on queue admission. Always
    /// zero in the training executor (its refill is demand-driven); the
    /// `exp fleet` cost model reports non-zero blocking under bursty
    /// traffic.
    pub fleet_queue_block_time: f64,
    /// Inference-fleet utilization this iteration:
    /// `sim_inference_time / (replicas × sim_step_time)`.
    pub fleet_inf_util: f64,
    /// Update-fleet utilization this iteration:
    /// `sim_update_time / sim_step_time`.
    pub fleet_upd_util: f64,
}

impl CsvRow for IterRow {
    fn csv_header() -> &'static str {
        "iter,sim_time,real_time,sim_inference_time,sim_update_time,train_reward,train_acc,\
         completion_len,sel_variance,sel_tokens_kept,sel_tokens_dropped,sel_groups_dropped,\
         loss,clip_frac,kl,micro_steps,rollouts_generated,rollouts_trained,\
         sim_step_time,sim_overlap_saved,schedule,gen_tokens_decoded,gen_tokens_wasted,\
         upd_shards,upd_comm_time,upd_peak_mem,gen_tokens_pruned,rows_pruned_online,\
         replay_rows_used,replay_store_size,replay_mean_staleness,\
         prefill_calls,prefill_calls_saved,kv_peak_bytes,\
         faults_injected,shard_retries,rows_lost,retry_time,\
         budget_extra_rows,budget_saturated_groups,\
         fleet_replicas,fleet_staleness,fleet_mean_staleness,fleet_max_staleness,\
         fleet_queue_depth,fleet_queue_block_time,fleet_inf_util,fleet_upd_util"
    }

    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},\
             {},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
            self.iter,
            self.sim_time,
            self.real_time,
            self.sim_inference_time,
            self.sim_update_time,
            self.train_reward,
            self.train_acc,
            self.completion_len,
            self.sel_variance,
            self.sel_tokens_kept,
            self.sel_tokens_dropped,
            self.sel_groups_dropped,
            self.loss,
            self.clip_frac,
            self.kl,
            self.micro_steps,
            self.rollouts_generated,
            self.rollouts_trained,
            self.sim_step_time,
            self.sim_overlap_saved,
            self.schedule,
            self.gen_tokens_decoded,
            self.gen_tokens_wasted,
            self.upd_shards,
            self.upd_comm_time,
            self.upd_peak_mem,
            self.gen_tokens_pruned,
            self.rows_pruned_online,
            self.replay_rows_used,
            self.replay_store_size,
            self.replay_mean_staleness,
            self.prefill_calls,
            self.prefill_calls_saved,
            self.kv_peak_bytes,
            self.faults_injected,
            self.shard_retries,
            self.rows_lost,
            self.retry_time,
            self.budget_extra_rows,
            self.budget_saturated_groups,
            self.fleet_replicas,
            self.fleet_staleness,
            self.fleet_mean_staleness,
            self.fleet_max_staleness,
            self.fleet_queue_depth,
            self.fleet_queue_block_time,
            self.fleet_inf_util,
            self.fleet_upd_util
        )
    }
}

impl IterRow {
    /// Parse one `csv_row()` line back into a row (checkpoint/resume
    /// restores the recorder from serialized lines). Rust's shortest-
    /// roundtrip float formatting makes `parse ∘ format` the identity, so
    /// a resumed run's CSV is byte-identical to the uninterrupted one.
    pub fn from_csv_row(line: &str) -> Result<Self> {
        let f = line.split(',').collect::<Vec<_>>();
        let n = Self::csv_header().replace(char::is_whitespace, "").split(',').count();
        anyhow::ensure!(f.len() == n, "iter row has {} fields, expected {n}: {line:?}", f.len());
        macro_rules! p {
            ($i:expr) => {
                f[$i].parse().with_context(|| format!("iter row field {}: {:?}", $i, f[$i]))?
            };
        }
        Ok(Self {
            iter: p!(0),
            sim_time: p!(1),
            real_time: p!(2),
            sim_inference_time: p!(3),
            sim_update_time: p!(4),
            train_reward: p!(5),
            train_acc: p!(6),
            completion_len: p!(7),
            sel_variance: p!(8),
            sel_tokens_kept: p!(9),
            sel_tokens_dropped: p!(10),
            sel_groups_dropped: p!(11),
            loss: p!(12),
            clip_frac: p!(13),
            kl: p!(14),
            micro_steps: p!(15),
            rollouts_generated: p!(16),
            rollouts_trained: p!(17),
            sim_step_time: p!(18),
            sim_overlap_saved: p!(19),
            schedule: f[20].to_string(),
            gen_tokens_decoded: p!(21),
            gen_tokens_wasted: p!(22),
            upd_shards: p!(23),
            upd_comm_time: p!(24),
            upd_peak_mem: p!(25),
            gen_tokens_pruned: p!(26),
            rows_pruned_online: p!(27),
            replay_rows_used: p!(28),
            replay_store_size: p!(29),
            replay_mean_staleness: p!(30),
            prefill_calls: p!(31),
            prefill_calls_saved: p!(32),
            kv_peak_bytes: p!(33),
            faults_injected: p!(34),
            shard_retries: p!(35),
            rows_lost: p!(36),
            retry_time: p!(37),
            budget_extra_rows: p!(38),
            budget_saturated_groups: p!(39),
            fleet_replicas: p!(40),
            fleet_staleness: p!(41),
            fleet_mean_staleness: p!(42),
            fleet_max_staleness: p!(43),
            fleet_queue_depth: p!(44),
            fleet_queue_block_time: p!(45),
            fleet_inf_util: p!(46),
            fleet_upd_util: p!(47),
        })
    }
}

/// One evaluation snapshot.
#[derive(Debug, Clone)]
pub struct EvalRow {
    /// Training iteration the snapshot was taken after.
    pub iter: usize,
    /// Simulated wall-clock at snapshot time.
    pub sim_time: f64,
    /// Real wall-clock at snapshot time.
    pub real_time: f64,
    /// Evaluation track label (`test`, `platinum`, cross-task labels).
    pub split: String,
    /// Exact-answer accuracy over the evaluated problems.
    pub accuracy: f32,
    /// Fraction of completions with well-formed answer tags.
    pub format_rate: f32,
    /// Mean total reward over the evaluated problems.
    pub mean_reward: f32,
    /// Mean generated length (tokens incl. EOS).
    pub mean_len: f32,
    /// Number of problems evaluated.
    pub problems: usize,
}

impl CsvRow for EvalRow {
    fn csv_header() -> &'static str {
        "iter,sim_time,real_time,split,accuracy,format_rate,mean_reward,mean_len,problems"
    }

    fn csv_row(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{}",
            self.iter,
            self.sim_time,
            self.real_time,
            self.split,
            self.accuracy,
            self.format_rate,
            self.mean_reward,
            self.mean_len,
            self.problems
        )
    }
}

impl EvalRow {
    /// Parse one `csv_row()` line back (checkpoint/resume counterpart of
    /// [`IterRow::from_csv_row`]).
    pub fn from_csv_row(line: &str) -> Result<Self> {
        let f = line.split(',').collect::<Vec<_>>();
        anyhow::ensure!(f.len() == 9, "eval row has {} fields, expected 9: {line:?}", f.len());
        macro_rules! p {
            ($i:expr) => {
                f[$i].parse().with_context(|| format!("eval row field {}: {:?}", $i, f[$i]))?
            };
        }
        Ok(Self {
            iter: p!(0),
            sim_time: p!(1),
            real_time: p!(2),
            split: f[3].to_string(),
            accuracy: p!(4),
            format_rate: p!(5),
            mean_reward: p!(6),
            mean_len: p!(7),
            problems: p!(8),
        })
    }
}

/// In-memory recorder; flushed to `<dir>/<run>_train.csv` and `_eval.csv`.
#[derive(Debug, Default)]
pub struct Recorder {
    /// Per-training-iteration rows, in iteration order.
    pub iters: Vec<IterRow>,
    /// Interleaved evaluation snapshots.
    pub evals: Vec<EvalRow>,
}

impl Recorder {
    /// An empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one training-iteration row.
    pub fn push_iter(&mut self, row: IterRow) {
        self.iters.push(row);
    }

    /// Append one evaluation snapshot.
    pub fn push_eval(&mut self, row: EvalRow) {
        self.evals.push(row);
    }

    /// Most recent accuracy recorded for the given eval track.
    pub fn last_eval_accuracy(&self, split: &str) -> Option<f32> {
        self.evals.iter().rev().find(|e| e.split == split).map(|e| e.accuracy)
    }

    /// Write both CSVs. Returns the paths written.
    pub fn write_csv(&self, dir: &Path, run_name: &str) -> Result<Vec<PathBuf>> {
        std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
        let train = dir.join(format!("{run_name}_train.csv"));
        write_csv_rows(&train, &self.iters)?;
        let eval = dir.join(format!("{run_name}_eval.csv"));
        write_csv_rows(&eval, &self.evals)?;
        Ok(vec![train, eval])
    }
}

/// Write a header + rows CSV file.
pub fn write_csv_rows<T: CsvRow>(path: &Path, rows: &[T]) -> Result<()> {
    let mut f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    writeln!(f, "{}", T::csv_header().replace(char::is_whitespace, ""))?;
    for row in rows {
        writeln!(f, "{}", row.csv_row())?;
    }
    Ok(())
}

/// ASCII line plot for terminal-friendly figure previews.
pub fn ascii_plot(series: &[(&str, &[(f64, f64)])], width: usize, height: usize) -> String {
    let all: Vec<(f64, f64)> = series.iter().flat_map(|(_, pts)| pts.iter().copied()).collect();
    if all.is_empty() {
        return String::from("(no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let marks = ['*', 'o', '+', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        for &(x, y) in pts.iter() {
            let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    out.push_str(&format!("y: [{y0:.3}, {y1:.3}]\n"));
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.extend(std::iter::repeat('-').take(width));
    out.push('\n');
    out.push_str(&format!("x: [{x0:.1}, {x1:.1}]  "));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("{}={} ", marks[si % marks.len()], name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let dir = crate::util::TempDir::new().unwrap();
        let mut rec = Recorder::new();
        rec.push_iter(IterRow { iter: 0, sim_time: 1.0, train_acc: 0.5, ..Default::default() });
        rec.push_iter(IterRow { iter: 1, sim_time: 2.0, train_acc: 0.6, ..Default::default() });
        rec.push_eval(EvalRow {
            iter: 1,
            sim_time: 2.0,
            real_time: 0.1,
            split: "test".into(),
            accuracy: 0.7,
            format_rate: 0.9,
            mean_reward: 2.0,
            mean_len: 30.0,
            problems: 64,
        });
        let paths = rec.write_csv(dir.path(), "t").unwrap();
        let train = std::fs::read_to_string(&paths[0]).unwrap();
        assert_eq!(train.lines().count(), 3); // header + 2 rows
        let header = train.lines().next().unwrap();
        assert!(header.contains("sim_time"));
        assert_eq!(
            header.split(',').count(),
            train.lines().nth(1).unwrap().split(',').count(),
            "header/row column mismatch"
        );
        let eval = std::fs::read_to_string(&paths[1]).unwrap();
        assert!(eval.contains("test"));
        assert_eq!(rec.last_eval_accuracy("test"), Some(0.7));
        assert_eq!(rec.last_eval_accuracy("platinum"), None);
    }

    /// Golden: the exact train-CSV schema the figure scripts consume.
    /// Changing columns must be a conscious act — update this test AND
    /// every header-name-based reader (exp::table3, figure scripts)
    /// together.
    #[test]
    fn iter_row_header_is_golden() {
        let header = IterRow::csv_header().replace(char::is_whitespace, "");
        assert_eq!(
            header,
            "iter,sim_time,real_time,sim_inference_time,sim_update_time,train_reward,train_acc,\
             completion_len,sel_variance,sel_tokens_kept,sel_tokens_dropped,sel_groups_dropped,\
             loss,clip_frac,kl,micro_steps,rollouts_generated,rollouts_trained,\
             sim_step_time,sim_overlap_saved,schedule,gen_tokens_decoded,gen_tokens_wasted,\
             upd_shards,upd_comm_time,upd_peak_mem,gen_tokens_pruned,rows_pruned_online,\
             replay_rows_used,replay_store_size,replay_mean_staleness,\
             prefill_calls,prefill_calls_saved,kv_peak_bytes,\
             faults_injected,shard_retries,rows_lost,retry_time,\
             budget_extra_rows,budget_saturated_groups,\
             fleet_replicas,fleet_staleness,fleet_mean_staleness,fleet_max_staleness,\
             fleet_queue_depth,fleet_queue_block_time,fleet_inf_util,fleet_upd_util"
                .replace(char::is_whitespace, "")
        );
        // new columns append at the end, so CSVs from older runs stay
        // parseable by position-tolerant readers
        let cols: Vec<&str> = header.split(',').collect();
        assert_eq!(cols.len(), 48);
        assert_eq!(
            cols[cols.len() - 27..].to_vec(),
            vec![
                "gen_tokens_decoded",
                "gen_tokens_wasted",
                "upd_shards",
                "upd_comm_time",
                "upd_peak_mem",
                "gen_tokens_pruned",
                "rows_pruned_online",
                "replay_rows_used",
                "replay_store_size",
                "replay_mean_staleness",
                "prefill_calls",
                "prefill_calls_saved",
                "kv_peak_bytes",
                "faults_injected",
                "shard_retries",
                "rows_lost",
                "retry_time",
                "budget_extra_rows",
                "budget_saturated_groups",
                "fleet_replicas",
                "fleet_staleness",
                "fleet_mean_staleness",
                "fleet_max_staleness",
                "fleet_queue_depth",
                "fleet_queue_block_time",
                "fleet_inf_util",
                "fleet_upd_util"
            ]
        );
    }

    /// Golden: one fully-populated row round-trips, header and row column
    /// counts agree, and every value lands under its own header name.
    #[test]
    fn iter_row_roundtrips_with_overlap_columns() {
        let row = IterRow {
            iter: 3,
            sim_time: 12.5,
            real_time: 0.25,
            sim_inference_time: 8.0,
            sim_update_time: 4.5,
            train_reward: 1.5,
            train_acc: 0.5,
            completion_len: 24.0,
            sel_variance: 0.75,
            sel_tokens_kept: 96,
            sel_tokens_dropped: 32,
            sel_groups_dropped: 1,
            loss: -0.125,
            clip_frac: 0.0625,
            kl: 0.03125,
            micro_steps: 2,
            rollouts_generated: 64,
            rollouts_trained: 16,
            sim_step_time: 9.5,
            sim_overlap_saved: 3.0,
            schedule: "pipelined".into(),
            gen_tokens_decoded: 1536,
            gen_tokens_wasted: 512,
            upd_shards: 4,
            upd_comm_time: 0.75,
            upd_peak_mem: 8,
            gen_tokens_pruned: 640,
            rows_pruned_online: 12,
            replay_rows_used: 4,
            replay_store_size: 20,
            replay_mean_staleness: 1.5,
            prefill_calls: 6,
            prefill_calls_saved: 10,
            kv_peak_bytes: 262144,
            faults_injected: 5,
            shard_retries: 2,
            rows_lost: 1,
            retry_time: 1.25,
            budget_extra_rows: 24,
            budget_saturated_groups: 3,
            fleet_replicas: 2,
            fleet_staleness: 2,
            fleet_mean_staleness: 1.25,
            fleet_max_staleness: 2,
            fleet_queue_depth: 3,
            fleet_queue_block_time: 0.5,
            fleet_inf_util: 0.421875,
            fleet_upd_util: 0.473684,
        };
        let header = IterRow::csv_header().replace(char::is_whitespace, "");
        let line = row.csv_row();
        let names: Vec<&str> = header.split(',').collect();
        let vals: Vec<&str> = line.split(',').collect();
        assert_eq!(names.len(), vals.len(), "header/row column mismatch");
        let get = |name: &str| vals[names.iter().position(|n| *n == name).unwrap()];
        assert_eq!(get("iter"), "3");
        assert_eq!(get("sim_inference_time"), "8");
        assert_eq!(get("sim_update_time"), "4.5");
        assert_eq!(get("sim_step_time"), "9.5");
        assert_eq!(get("sim_overlap_saved"), "3");
        assert_eq!(get("schedule"), "pipelined");
        assert_eq!(get("rollouts_trained"), "16");
        assert_eq!(get("gen_tokens_decoded"), "1536");
        assert_eq!(get("gen_tokens_wasted"), "512");
        assert_eq!(get("upd_shards"), "4");
        assert_eq!(get("upd_comm_time"), "0.75");
        assert_eq!(get("upd_peak_mem"), "8");
        assert_eq!(get("gen_tokens_pruned"), "640");
        assert_eq!(get("rows_pruned_online"), "12");
        assert_eq!(get("replay_rows_used"), "4");
        assert_eq!(get("replay_store_size"), "20");
        assert_eq!(get("replay_mean_staleness"), "1.5");
        assert_eq!(get("prefill_calls"), "6");
        assert_eq!(get("prefill_calls_saved"), "10");
        assert_eq!(get("kv_peak_bytes"), "262144");
        assert_eq!(get("faults_injected"), "5");
        assert_eq!(get("shard_retries"), "2");
        assert_eq!(get("rows_lost"), "1");
        assert_eq!(get("retry_time"), "1.25");
        assert_eq!(get("budget_extra_rows"), "24");
        assert_eq!(get("budget_saturated_groups"), "3");
        assert_eq!(get("fleet_replicas"), "2");
        assert_eq!(get("fleet_staleness"), "2");
        assert_eq!(get("fleet_mean_staleness"), "1.25");
        assert_eq!(get("fleet_max_staleness"), "2");
        assert_eq!(get("fleet_queue_depth"), "3");
        assert_eq!(get("fleet_queue_block_time"), "0.5");
        assert_eq!(get("fleet_inf_util"), "0.421875");
        assert_eq!(get("fleet_upd_util"), "0.473684");
        // the overlap identity the exec layer maintains:
        // step + saved == inference + update
        let step: f64 = get("sim_step_time").parse().unwrap();
        let saved: f64 = get("sim_overlap_saved").parse().unwrap();
        assert_eq!(step + saved, 8.0 + 4.5);
        // written CSV keeps the schema
        let dir = crate::util::TempDir::new().unwrap();
        let mut rec = Recorder::new();
        rec.push_iter(row);
        let paths = rec.write_csv(dir.path(), "golden").unwrap();
        let text = std::fs::read_to_string(&paths[0]).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), header);
        assert_eq!(lines.next().unwrap(), line);
    }

    /// Resume contract: `from_csv_row ∘ csv_row` is the identity at the
    /// text level — a recorder restored from serialized lines re-emits the
    /// exact bytes the killed run would have written.
    #[test]
    fn csv_rows_parse_back_bitwise() {
        let row = IterRow {
            iter: 7,
            sim_time: 123.456789012345,
            real_time: 0.1,
            sim_inference_time: 1.0 / 3.0,
            train_reward: 1.5,
            sel_variance: 2.0_f64 / 7.0,
            schedule: "pipelined".into(),
            retry_time: 0.7,
            kv_peak_bytes: 1 << 40,
            fleet_mean_staleness: 1.0 / 7.0,
            fleet_inf_util: 2.0 / 3.0,
            ..Default::default()
        };
        let line = row.csv_row();
        let parsed = IterRow::from_csv_row(&line).unwrap();
        assert_eq!(parsed.csv_row(), line);
        let ev = EvalRow {
            iter: 3,
            sim_time: 9.25,
            real_time: 0.5,
            split: "platinum".into(),
            accuracy: 0.625,
            format_rate: 1.0 / 3.0,
            mean_reward: 2.5,
            mean_len: 30.0,
            problems: 64,
        };
        let eline = ev.csv_row();
        assert_eq!(EvalRow::from_csv_row(&eline).unwrap().csv_row(), eline);
        // malformed lines fail loudly, not silently
        assert!(IterRow::from_csv_row("1,2,3").is_err());
        assert!(EvalRow::from_csv_row("").is_err());
    }

    #[test]
    fn ascii_plot_renders() {
        let pts: Vec<(f64, f64)> = (0..20).map(|i| (i as f64, (i * i) as f64)).collect();
        let s = ascii_plot(&[("quad", &pts)], 40, 10);
        assert!(s.contains('*'));
        assert!(s.lines().count() > 10);
    }
}
