//! Deterministic fault injection — the seeded fault schedule for the
//! fault-tolerance layer (`[faults]` config section).
//!
//! A [`FaultPlan`] is a pure function of `(run_seed, iter, prompt_id,
//! rollout_idx, attempt)`: every row-attempt's fate (healthy, worker
//! crash, transient call failure, KV-admission OOM) and every row's
//! straggler status are drawn from private counter-based streams, exactly
//! like the sampling RNG in `rollout::mix_seed`. Two consequences:
//!
//! * **The fault schedule is history, not partition.** Faults key on row
//!   identity, never on which physical shard or worker executed the row,
//!   so the set of injected faults — and therefore the set of rows lost
//!   after retries — is bit-identical across worker-pool sizes, shard
//!   layouts and refill orders (pinned by `fault_golden`).
//! * **Replays are free.** A given `(seed, rates)` pair replays the same
//!   schedule forever; rate `0.0` draws nothing and the training path is
//!   bit-identical to a build without the fault layer.
//!
//! A row is **lost** only when it faults at attempt `0` *and* every one of
//! its `max_retries` retry attempts — each attempt re-draws from the
//! attempt-indexed stream, so retries genuinely re-roll the dice.

use anyhow::{bail, Result};

/// What the fault schedule injected for one row-attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Simulated worker crash mid-decode: the attempt's generation budget
    /// is charged as wasted work (the tokens decoded before the crash are
    /// unrecoverable) and the row is retried.
    Crash,
    /// Transient engine-call failure (PJRT launch error, network blip):
    /// fails fast, charges only the retry backoff.
    Transient,
    /// KV-pool admission rejection: the row could not be admitted into a
    /// decode slot this attempt. Retried — pool pressure is transient.
    AdmissionOom,
}

impl FaultKind {
    /// Canonical name used in logs.
    pub fn name(self) -> &'static str {
        match self {
            Self::Crash => "crash",
            Self::Transient => "transient",
            Self::AdmissionOom => "admission-oom",
        }
    }
}

/// `[faults]` — deterministic fault injection (off by default).
///
/// All rates are per **row-attempt** probabilities in `0.0..=1.0`; the
/// three fault rates are mutually exclusive outcomes of one draw, so their
/// sum must not exceed `1.0`. With `enabled = false` (default) no plan is
/// built and the executor path is bit-identical to a faultless build.
#[derive(Debug, Clone)]
pub struct FaultSection {
    /// Master switch. `false` (default) injects nothing.
    pub enabled: bool,
    /// Worker-crash probability per row-attempt (wasted-work charge).
    pub crash_rate: f64,
    /// Transient call-failure probability per row-attempt (fails fast).
    pub transient_rate: f64,
    /// KV-admission OOM probability per row-attempt.
    pub oom_rate: f64,
    /// Straggler probability per row (successful rows only): the row's
    /// decode is charged `straggler_factor ×` its solo decode time.
    pub straggler_rate: f64,
    /// Slowdown multiplier for straggler rows (`>= 1`).
    pub straggler_factor: f64,
    /// Retry attempts per failed row before it is declared lost.
    pub max_retries: usize,
    /// Simulated backoff before the first retry, in seconds.
    pub backoff_base: f64,
    /// Exponential backoff growth per subsequent retry (`>= 1`).
    pub backoff_factor: f64,
    /// Hard degradation floor: the iteration fails loudly when any prompt
    /// group retains fewer than this many rollouts after losses.
    pub min_group_survivors: usize,
}

impl Default for FaultSection {
    fn default() -> Self {
        Self {
            enabled: false,
            crash_rate: 0.0,
            transient_rate: 0.0,
            oom_rate: 0.0,
            straggler_rate: 0.0,
            straggler_factor: 4.0,
            max_retries: 2,
            backoff_base: 0.5,
            backoff_factor: 2.0,
            min_group_survivors: 1,
        }
    }
}

impl FaultSection {
    /// Parse from a `[faults]` config section; absent keys keep defaults.
    pub fn from_section(sec: &crate::util::toml::SectionView) -> Result<Self> {
        let d = Self::default();
        let f = Self {
            enabled: sec.bool_or("enabled", d.enabled)?,
            crash_rate: sec.f64_or("crash_rate", d.crash_rate)?,
            transient_rate: sec.f64_or("transient_rate", d.transient_rate)?,
            oom_rate: sec.f64_or("oom_rate", d.oom_rate)?,
            straggler_rate: sec.f64_or("straggler_rate", d.straggler_rate)?,
            straggler_factor: sec.f64_or("straggler_factor", d.straggler_factor)?,
            max_retries: sec.usize_or("max_retries", d.max_retries)?,
            backoff_base: sec.f64_or("backoff_base", d.backoff_base)?,
            backoff_factor: sec.f64_or("backoff_factor", d.backoff_factor)?,
            min_group_survivors: sec.usize_or("min_group_survivors", d.min_group_survivors)?,
        };
        f.validate()?;
        Ok(f)
    }

    /// Reject degenerate fault policies at parse time.
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("crash_rate", self.crash_rate),
            ("transient_rate", self.transient_rate),
            ("oom_rate", self.oom_rate),
            ("straggler_rate", self.straggler_rate),
        ] {
            if !(0.0..=1.0).contains(&v) {
                bail!("faults.{name} must be in 0.0..=1.0 (a per-row-attempt probability; got {v})");
            }
        }
        let sum = self.crash_rate + self.transient_rate + self.oom_rate;
        if sum > 1.0 {
            bail!(
                "faults.crash_rate + faults.transient_rate + faults.oom_rate must not \
                 exceed 1.0 (they are mutually exclusive outcomes of one draw; got {sum})"
            );
        }
        if self.straggler_factor < 1.0 {
            bail!(
                "faults.straggler_factor must be >= 1.0 (a slowdown multiplier; got {})",
                self.straggler_factor
            );
        }
        if self.backoff_base < 0.0 {
            bail!("faults.backoff_base must be non-negative (got {})", self.backoff_base);
        }
        if self.backoff_factor < 1.0 {
            bail!(
                "faults.backoff_factor must be >= 1.0 (exponential backoff growth; got {})",
                self.backoff_factor
            );
        }
        if self.min_group_survivors == 0 {
            bail!(
                "faults.min_group_survivors must be >= 1 (a group with zero surviving \
                 rollouts contributes nothing to the update; the degenerate-m clamp \
                 needs at least one row)"
            );
        }
        Ok(())
    }

    /// Build the seeded fault schedule, or `None` when injection is off.
    pub fn plan(&self, run_seed: u64) -> Option<FaultPlan> {
        self.enabled.then(|| FaultPlan::new(run_seed, self.clone()))
    }
}

/// Stream tags keeping the fault draw and the straggler draw statistically
/// independent of each other and of the sampling RNG.
const STREAM_FAULT: u64 = 0xFA01;
const STREAM_STRAGGLER: u64 = 0xFA02;

/// The seeded fault schedule: pure counter-based draws, no mutable state.
/// Cheap to clone and safe to share across worker threads.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    seed: u64,
    /// The rates and retry policy the plan draws against.
    pub cfg: FaultSection,
}

impl FaultPlan {
    /// A plan over `cfg`'s rates, keyed by the run seed.
    pub fn new(run_seed: u64, cfg: FaultSection) -> Self {
        Self { seed: run_seed, cfg }
    }

    /// splitmix64-style finalizer over the row-attempt coordinates. The
    /// multipliers differ from `rollout::mix_seed`'s field order, and the
    /// stream tag separates fault draws from straggler draws, so the fault
    /// schedule never correlates with the token-sampling streams.
    fn mix(&self, tag: u64, iter: u64, prompt: u64, idx: u64, attempt: u64) -> u64 {
        let mut z = self
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(tag.wrapping_mul(0xD6E8_FEB8_6659_FD93))
            .wrapping_add(iter.wrapping_mul(0xBF58_476D_1CE4_E5B9))
            .wrapping_add(prompt.wrapping_mul(0x94D0_49BB_1331_11EB))
            .wrapping_add(idx.wrapping_mul(0x2545_F491_4F6C_DD1D))
            .wrapping_add(attempt.wrapping_add(1));
        z ^= z >> 30;
        z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z ^= z >> 27;
        z = z.wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        z
    }

    /// Uniform draw in `[0, 1)` from the tagged stream (53-bit mantissa).
    fn uniform(&self, tag: u64, iter: u64, prompt: u64, idx: u64, attempt: u64) -> f64 {
        (self.mix(tag, iter, prompt, idx, attempt) >> 11) as f64 / (1u64 << 53) as f64
    }

    /// The fate of row `(iter, prompt_id, rollout_idx)` at `attempt`
    /// (attempt 0 = first execution, 1.. = retries). One draw decides
    /// between the three fault kinds by cumulative rate thresholds.
    pub fn row_fault(
        &self,
        iter: u64,
        prompt_id: u64,
        rollout_idx: u64,
        attempt: usize,
    ) -> Option<FaultKind> {
        let u = self.uniform(STREAM_FAULT, iter, prompt_id, rollout_idx, attempt as u64);
        if u < self.cfg.crash_rate {
            Some(FaultKind::Crash)
        } else if u < self.cfg.crash_rate + self.cfg.transient_rate {
            Some(FaultKind::Transient)
        } else if u < self.cfg.crash_rate + self.cfg.transient_rate + self.cfg.oom_rate {
            Some(FaultKind::AdmissionOom)
        } else {
            None
        }
    }

    /// Is this row a straggler (charged `straggler_factor ×` its solo
    /// decode time)? Drawn once per row — stragglers are slow, not failed,
    /// so the attempt axis does not apply.
    pub fn row_straggler(&self, iter: u64, prompt_id: u64, rollout_idx: u64) -> bool {
        self.cfg.straggler_rate > 0.0
            && self.uniform(STREAM_STRAGGLER, iter, prompt_id, rollout_idx, 0)
                < self.cfg.straggler_rate
    }

    /// Simulated backoff charged before retry `attempt + 1` of a row that
    /// failed at `attempt`: `base × factor^attempt` seconds.
    pub fn backoff(&self, attempt: usize) -> f64 {
        self.cfg.backoff_base * self.cfg.backoff_factor.powi(attempt as i32)
    }

    /// Is the row lost — i.e. does it fault at attempt 0 *and* every one
    /// of its `max_retries` retries? Pure schedule arithmetic; the
    /// executor reaches the same verdict by physically retrying.
    pub fn row_lost(&self, iter: u64, prompt_id: u64, rollout_idx: u64) -> bool {
        (0..=self.cfg.max_retries)
            .all(|a| self.row_fault(iter, prompt_id, rollout_idx, a).is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(rates: (f64, f64, f64), retries: usize) -> FaultPlan {
        FaultPlan::new(
            7,
            FaultSection {
                enabled: true,
                crash_rate: rates.0,
                transient_rate: rates.1,
                oom_rate: rates.2,
                max_retries: retries,
                ..Default::default()
            },
        )
    }

    /// The schedule is a pure function: same coordinates, same verdicts.
    #[test]
    fn plan_is_deterministic() {
        let p = plan((0.1, 0.1, 0.1), 2);
        for it in 0..4u64 {
            for pid in 0..8u64 {
                for idx in 0..8u64 {
                    for a in 0..3usize {
                        assert_eq!(
                            p.row_fault(it, pid, idx, a),
                            p.row_fault(it, pid, idx, a)
                        );
                    }
                    assert_eq!(
                        p.row_straggler(it, pid, idx),
                        p.row_straggler(it, pid, idx)
                    );
                }
            }
        }
    }

    /// Rate 0.0 injects nothing, ever — the bit-identity-to-main contract.
    #[test]
    fn zero_rates_inject_nothing() {
        let p = plan((0.0, 0.0, 0.0), 2);
        for it in 0..8u64 {
            for pid in 0..32u64 {
                for idx in 0..16u64 {
                    assert_eq!(p.row_fault(it, pid, idx, 0), None);
                    assert!(!p.row_straggler(it, pid, idx));
                    assert!(!p.row_lost(it, pid, idx));
                }
            }
        }
    }

    /// Empirical rates track the configured rates, and the three fault
    /// kinds partition the draw by cumulative thresholds.
    #[test]
    fn empirical_rates_track_configured_rates() {
        let p = plan((0.1, 0.15, 0.05), 2);
        let mut counts = [0usize; 4]; // none, crash, transient, oom
        let total = 20_000u64;
        for i in 0..total {
            match p.row_fault(0, i, 0, 0) {
                None => counts[0] += 1,
                Some(FaultKind::Crash) => counts[1] += 1,
                Some(FaultKind::Transient) => counts[2] += 1,
                Some(FaultKind::AdmissionOom) => counts[3] += 1,
            }
        }
        let frac = |c: usize| c as f64 / total as f64;
        assert!((frac(counts[1]) - 0.10).abs() < 0.02, "crash {}", frac(counts[1]));
        assert!((frac(counts[2]) - 0.15).abs() < 0.02, "transient {}", frac(counts[2]));
        assert!((frac(counts[3]) - 0.05).abs() < 0.02, "oom {}", frac(counts[3]));
    }

    /// Retries re-roll: with rate p and r retries, loss ≈ p^(r+1).
    #[test]
    fn retries_rescue_rows() {
        let p0 = plan((0.2, 0.0, 0.0), 0);
        let p2 = plan((0.2, 0.0, 0.0), 2);
        let total = 20_000u64;
        let lost = |p: &FaultPlan| (0..total).filter(|&i| p.row_lost(0, i, 0)).count();
        let l0 = lost(&p0) as f64 / total as f64;
        let l2 = lost(&p2) as f64 / total as f64;
        assert!((l0 - 0.2).abs() < 0.02, "no-retry loss {l0}");
        assert!(l2 < 0.03, "2-retry loss {l2} should be ~0.2^3");
    }

    /// Fault and straggler streams are independent of the sampling RNG and
    /// of each other (no coordinate aliasing across tags).
    #[test]
    fn streams_decorrelate() {
        let p = plan((0.5, 0.0, 0.0), 2);
        let a: Vec<bool> = (0..64).map(|i| p.row_fault(0, i, 0, 0).is_some()).collect();
        let b: Vec<bool> = (0..64).map(|i| p.row_straggler(0, i, 0)).collect();
        assert_ne!(a, b, "fault and straggler draws must not alias");
        // attempt axis decorrelates too
        let a1: Vec<bool> = (0..64).map(|i| p.row_fault(0, i, 0, 1).is_some()).collect();
        assert_ne!(a, a1, "retry draws must re-roll");
    }

    #[test]
    fn backoff_grows_exponentially() {
        let p = plan((0.1, 0.0, 0.0), 3);
        assert_eq!(p.backoff(0), 0.5);
        assert_eq!(p.backoff(1), 1.0);
        assert_eq!(p.backoff(2), 2.0);
    }

    #[test]
    fn section_validation_rejects_degenerate_values() {
        let mut f = FaultSection::default();
        f.validate().unwrap();
        f.crash_rate = 1.5;
        assert!(f.validate().unwrap_err().to_string().contains("faults.crash_rate"));
        f.crash_rate = 0.6;
        f.transient_rate = 0.6;
        assert!(f.validate().unwrap_err().to_string().contains("exceed 1.0"));
        f.transient_rate = 0.0;
        f.straggler_factor = 0.5;
        assert!(f.validate().unwrap_err().to_string().contains("straggler_factor"));
        f.straggler_factor = 4.0;
        f.backoff_factor = 0.9;
        assert!(f.validate().unwrap_err().to_string().contains("backoff_factor"));
        f.backoff_factor = 2.0;
        f.min_group_survivors = 0;
        assert!(f.validate().unwrap_err().to_string().contains("min_group_survivors"));
    }

    /// `plan()` is gated on the master switch.
    #[test]
    fn plan_requires_enabled() {
        let mut f = FaultSection::default();
        assert!(f.plan(0).is_none());
        f.enabled = true;
        assert!(f.plan(0).is_some());
    }
}
