//! Hardware cost model — the simulated substrate for the paper's systems
//! claims (DESIGN.md §2).
//!
//! The paper's wall-clock figures are driven by one asymmetry (its Fig. 1,
//! measured on 8×A100 with Qwen2.5-3B):
//!
//! * **Inference** is embarrassingly parallel and memory-light: per-token
//!   time drops ~21× as the rollout batch grows from 8 to 512, saturating
//!   beyond 512.
//! * **Policy updates** are memory-bound: beyond ~32 rollouts per device the
//!   update OOMs and must fall back to gradient accumulation — extra
//!   *sequential* micro-steps, each paying a gradient all-reduce and
//!   full-precision optimizer traffic.
//!
//! [`HwModel`] reproduces that shape with interpretable parameters
//! (defaults calibrated to Fig. 1's curves); [`SimClock`] integrates phase
//! times into the simulated wall-clock that the experiment figures use as
//! their x-axis. Real CPU time is logged alongside — see metrics.
//!
//! The `[hwsim]` section also selects the executor [`Schedule`]: `"sync"`
//! runs the two phases back-to-back (Algorithm 1 as written), while
//! `"pipelined"` overlaps generation of iteration *t+1* with the policy
//! update of iteration *t* (one-step off-policy; sound because the loss
//! uses stored behaviour log-probs). Under overlap the clock charges
//! `max(inference, update)` instead of the sum — [`SimClock`] tracks the
//! hidden time as `overlap_saved`.
//!
//! Both schedules are special cases of the staleness-K disaggregated
//! two-fleet model in [`fleet`]: `R` inference replicas feed the sharded
//! update fleet through a bounded ready-batch queue, and a batch
//! generated under `params(t)` may be consumed by `update(t')` only when
//! `t' − t <= K` (`sync` ≡ K=0, `pipelined` ≡ K=1 with R=1).

pub mod faults;
pub mod fleet;

pub use faults::{FaultKind, FaultPlan, FaultSection};
pub use fleet::{FleetReport, FleetSection, FleetSpec, ReadyQueue, TrafficModel};

use anyhow::{anyhow, Result};

/// Executor schedule: how the inference and update phases interleave
/// across iterations (see [`crate::coordinator::exec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Schedule {
    /// Generate, select, update, strictly in sequence — the paper's
    /// Algorithm 1 and the seed trainer's behaviour.
    #[default]
    Sync,
    /// Overlap generation of iteration t+1 with the update of iteration t
    /// (one-step off-policy). Simulated step time becomes
    /// `max(inference, update)` for the overlapped portion.
    Pipelined,
}

impl Schedule {
    /// Parse a `[hwsim] schedule` value (`sync` | `pipelined`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "sync" => Ok(Self::Sync),
            "pipelined" => Ok(Self::Pipelined),
            other => Err(anyhow!("unknown hwsim.schedule {other:?} (sync|pipelined)")),
        }
    }

    /// Canonical name used in configs, logs and the train CSV.
    pub fn name(self) -> &'static str {
        match self {
            Self::Sync => "sync",
            Self::Pipelined => "pipelined",
        }
    }
}

/// Calibrated cost model. All times in (simulated) seconds.
#[derive(Debug, Clone)]
pub struct HwModel {
    /// Number of simulated accelerators (1 = single-GPU settings a–d).
    pub workers: usize,
    /// Per-token decode time at rollout batch 1 on one device.
    pub tok_time_b1: f64,
    /// Saturated per-token time (Fig. 1: ~21× below `tok_time_b1`).
    pub tok_time_floor: f64,
    /// Batch size at which amortization is halfway to the floor.
    pub batch_half: f64,
    /// Rollout batch size beyond which throughput stops improving.
    pub batch_saturation: f64,
    /// **Update-phase** memory ceiling: max rollouts in one update
    /// micro-batch without gradient accumulation (Fig. 1: 32). This caps
    /// only the policy-update micro-batch; the rollout-side memory ceiling
    /// is the paged KV pool (`kv_pool_bytes`).
    pub mem_capacity_rollouts: usize,
    /// Modeled KV-cache bytes per token per resident row (all layers; a
    /// 3B-class model in bf16 carries ~64 KiB of K+V per token).
    pub kv_bytes_per_token: u64,
    /// Tokens per KV page: slot allocations round up to whole pages
    /// (vLLM-style paging), so short prompts still pin a full page.
    pub kv_page_tokens: usize,
    /// Rollout-side memory ceiling: capacity of the modeled KV pool in
    /// bytes. A queued row is admitted into a decode slot only when its
    /// pages fit; `0` = unbounded (admission never blocks on memory).
    pub kv_pool_bytes: u64,
    /// Fixed per-micro-step overhead (kernel launches, activation reload,
    /// ZeRO state gather) — what makes the GA cliff a cliff.
    pub microbatch_fixed: f64,
    /// fwd+bwd time for one full-size update micro-batch on one device,
    /// scaled by how full the micro-batch is.
    pub microbatch_time: f64,
    /// Gradient all-reduce + sync cost per micro-step (scales with a
    /// log2(workers) tree factor; zero for 1 worker).
    pub comm_base: f64,
    /// Optimizer apply (full-precision state streams) per update.
    pub optimizer_time: f64,
    /// LoRA update discount: optimizer/comm touch only adapter weights.
    pub lora_update_scale: f64,
    /// Bytes per gradient element on the wire (4 = f32 gradients; 2 would
    /// model bf16 gradient compression).
    pub bytes_per_param: f64,
    /// Point-to-point interconnect bandwidth between update shards, in
    /// gigabits per second (default shaped to NVLink-class links).
    pub interconnect_gbps: f64,
    /// Per-hop collective latency in seconds (ring step launch + sync).
    pub comm_latency: f64,
    /// Parameter count of the *simulated* policy (the cost model prices
    /// Fig. 1's Qwen2.5-3B, not the toy artifact executed on CPU); sizes
    /// the gradient all-reduce volume.
    pub sim_model_params: f64,
    /// Executor schedule: `sync` (phases back-to-back) or `pipelined`
    /// (generation of t+1 overlaps the update of t).
    pub schedule: Schedule,
}

impl Default for HwModel {
    fn default() -> Self {
        // Shaped to the paper's Fig. 1 (Qwen2.5-3B): at batch 8 per-token
        // time ≈ 21× the saturated value; update micro-step O(seconds);
        // comm a significant fraction of a micro-step on 8 devices.
        Self {
            workers: 1,
            tok_time_b1: 0.050,
            tok_time_floor: 0.0004,
            batch_half: 10.0,
            batch_saturation: 512.0,
            mem_capacity_rollouts: 32,
            kv_bytes_per_token: 65_536,
            kv_page_tokens: 16,
            kv_pool_bytes: 0,
            microbatch_fixed: 0.8,
            microbatch_time: 1.2,
            comm_base: 0.55,
            optimizer_time: 0.35,
            lora_update_scale: 0.25,
            bytes_per_param: 4.0,
            interconnect_gbps: 300.0,
            comm_latency: 3e-5,
            sim_model_params: 3e9,
            schedule: Schedule::Sync,
        }
    }
}

impl HwModel {
    /// Parse from a `[hwsim]` config section; absent keys keep defaults.
    /// Validation happens here, so a bad `[hwsim]` fails at config parse
    /// with a descriptive error instead of tripping downstream asserts.
    pub fn from_section(sec: &crate::util::toml::SectionView) -> anyhow::Result<Self> {
        let d = Self::default();
        let hw = Self {
            workers: sec.usize_or("workers", d.workers)?,
            tok_time_b1: sec.f64_or("tok_time_b1", d.tok_time_b1)?,
            tok_time_floor: sec.f64_or("tok_time_floor", d.tok_time_floor)?,
            batch_half: sec.f64_or("batch_half", d.batch_half)?,
            batch_saturation: sec.f64_or("batch_saturation", d.batch_saturation)?,
            mem_capacity_rollouts: sec.usize_or("mem_capacity_rollouts", d.mem_capacity_rollouts)?,
            kv_bytes_per_token: sec.u64_or("kv_bytes_per_token", d.kv_bytes_per_token)?,
            kv_page_tokens: sec.usize_or("kv_page_tokens", d.kv_page_tokens)?,
            kv_pool_bytes: sec.u64_or("kv_pool_bytes", d.kv_pool_bytes)?,
            microbatch_fixed: sec.f64_or("microbatch_fixed", d.microbatch_fixed)?,
            microbatch_time: sec.f64_or("microbatch_time", d.microbatch_time)?,
            comm_base: sec.f64_or("comm_base", d.comm_base)?,
            optimizer_time: sec.f64_or("optimizer_time", d.optimizer_time)?,
            lora_update_scale: sec.f64_or("lora_update_scale", d.lora_update_scale)?,
            bytes_per_param: sec.f64_or("bytes_per_param", d.bytes_per_param)?,
            interconnect_gbps: sec.f64_or("interconnect_gbps", d.interconnect_gbps)?,
            comm_latency: sec.f64_or("comm_latency", d.comm_latency)?,
            sim_model_params: sec.f64_or("sim_model_params", d.sim_model_params)?,
            schedule: Schedule::parse(&sec.str_or("schedule", d.schedule.name())?)?,
        };
        hw.validate()?;
        Ok(hw)
    }

    /// Reject configurations that would only fail deep inside the trainer
    /// (`workers = 0` used to survive parsing and die on a downstream
    /// assert / get silently clamped by `max(1)`).
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.workers == 0 {
            anyhow::bail!(
                "hwsim.workers must be >= 1 (0 workers cannot generate rollouts; \
                 use workers = 1 for the single-accelerator settings)"
            );
        }
        if self.mem_capacity_rollouts == 0 {
            anyhow::bail!(
                "hwsim.mem_capacity_rollouts must be >= 1 (it caps only the \
                 update micro-batch; the rollout-side memory ceiling is \
                 hwsim.kv_pool_bytes)"
            );
        }
        if self.kv_bytes_per_token == 0 {
            anyhow::bail!(
                "hwsim.kv_bytes_per_token must be >= 1 (every resident token \
                 occupies KV-cache memory; it sizes kv_pool_bytes admission)"
            );
        }
        if self.kv_page_tokens == 0 {
            anyhow::bail!(
                "hwsim.kv_page_tokens must be >= 1 (KV allocations round up to \
                 whole pages; use 1 for token-granular accounting)"
            );
        }
        if self.batch_saturation < 1.0 || self.batch_half <= 0.0 {
            anyhow::bail!(
                "hwsim.batch_saturation must be >= 1 and hwsim.batch_half > 0 \
                 (got saturation={}, half={})",
                self.batch_saturation,
                self.batch_half
            );
        }
        for (name, v) in [
            ("tok_time_b1", self.tok_time_b1),
            ("tok_time_floor", self.tok_time_floor),
            ("microbatch_fixed", self.microbatch_fixed),
            ("microbatch_time", self.microbatch_time),
            ("comm_base", self.comm_base),
            ("optimizer_time", self.optimizer_time),
            ("lora_update_scale", self.lora_update_scale),
            ("bytes_per_param", self.bytes_per_param),
            ("comm_latency", self.comm_latency),
            ("sim_model_params", self.sim_model_params),
        ] {
            if v < 0.0 {
                anyhow::bail!("hwsim.{name} must be non-negative (got {v})");
            }
        }
        if self.interconnect_gbps <= 0.0 {
            anyhow::bail!(
                "hwsim.interconnect_gbps must be positive (got {}): the ring \
                 all-reduce divides by the interconnect bandwidth",
                self.interconnect_gbps
            );
        }
        Ok(())
    }

    /// Per-token decode time at a given per-device rollout batch size
    /// (hyperbolic amortization with a floor, flat beyond saturation).
    pub fn per_token_time(&self, batch: usize) -> f64 {
        let b = (batch.max(1) as f64).min(self.batch_saturation);
        self.tok_time_floor + (self.tok_time_b1 - self.tok_time_floor) / (1.0 + b / self.batch_half)
    }

    /// Inference-phase time: `n` rollouts of `avg_tokens` generated tokens,
    /// sharded round-robin over the workers, each worker decoding its shard
    /// as one batch. Phase time = slowest worker (they run in parallel).
    pub fn inference_time(&self, n: usize, avg_tokens: f64) -> f64 {
        let shard = n.div_ceil(self.workers.max(1));
        shard as f64 * avg_tokens * self.per_token_time(shard)
    }

    /// Chunk-granular inference time: the chunked decode driver runs a
    /// chunk to completion even when a row finishes mid-chunk, so **each
    /// rollout's** generated-token count rounds up to a multiple of
    /// `chunk` before the batch-amortized per-token price applies
    /// (ceil-to-chunk, per rollout — a 2-token and a 30-token rollout at
    /// chunk 16 charge 16 + 32, not 2 × 16). `gen_lens` are the
    /// per-rollout generated lengths; the worker model matches
    /// [`Self::inference_time`].
    pub fn chunked_inference_time(&self, gen_lens: &[usize], chunk: usize) -> f64 {
        let n = gen_lens.len();
        if n == 0 {
            return 0.0;
        }
        let c = chunk.max(1) as f64;
        let total: f64 = gen_lens.iter().map(|&t| (t as f64 / c).ceil() * c).sum();
        let shard = n.div_ceil(self.workers.max(1));
        shard as f64 * (total / n as f64) * self.per_token_time(shard)
    }

    /// Chunk-granular inference time under **online pruning**: finished
    /// rollouts (`gen_lens`) charge exactly like
    /// [`Self::chunked_inference_time`], while rollouts aborted mid-decode
    /// (`pruned_lens`, their decoded-so-far lengths) charge only the
    /// tokens that were actually decoded before the abort — the whole
    /// point of pruning is that the remaining budget is never paid.
    /// Identical to `chunked_inference_time` over the concatenated length
    /// list, and therefore equal to it when `pruned_lens` is empty; it
    /// only ever undercuts charging the aborted rows at longer lengths.
    pub fn pruned_inference_time(
        &self,
        gen_lens: &[usize],
        pruned_lens: &[usize],
        chunk: usize,
    ) -> f64 {
        let n = gen_lens.len() + pruned_lens.len();
        if n == 0 {
            return 0.0;
        }
        let c = chunk.max(1) as f64;
        let total: f64 = gen_lens
            .iter()
            .chain(pruned_lens.iter())
            .map(|&t| (t as f64 / c).ceil() * c)
            .sum();
        let shard = n.div_ceil(self.workers.max(1));
        shard as f64 * (total / n as f64) * self.per_token_time(shard)
    }

    /// Bytes of one KV page (`kv_page_tokens × kv_bytes_per_token`).
    pub fn kv_page_bytes(&self) -> u64 {
        self.kv_page_tokens as u64 * self.kv_bytes_per_token
    }

    /// Page-rounded KV bytes of one cache segment holding `tokens` tokens
    /// (prompt region or generation budget): `ceil(tokens / page) × page`
    /// in bytes. Zero tokens pin zero pages.
    pub fn kv_seg_bytes(&self, tokens: usize) -> u64 {
        (tokens as u64).div_ceil(self.kv_page_tokens.max(1) as u64) * self.kv_page_bytes()
    }

    /// Modeled KV footprint of one decode slot: the prompt segment plus
    /// the generation budget, each rounded to whole pages. When prompt KV
    /// is group-shared the prompt segment is counted **once per resident
    /// group**, not per row — the slot batcher does that split itself via
    /// [`Self::kv_seg_bytes`]; this is the private-prompt (unshared) cost.
    pub fn kv_bytes(&self, prompt_len: usize, gen_len: usize) -> u64 {
        self.kv_seg_bytes(prompt_len) + self.kv_seg_bytes(gen_len)
    }

    /// A fresh admission ledger over this model's `kv_pool_bytes`.
    pub fn kv_pool(&self) -> KvPool {
        KvPool::new(self.kv_pool_bytes)
    }

    /// Inference time under **group-shared prompt prefill**: the decode
    /// charge of [`Self::pruned_inference_time`] plus an explicit prefill
    /// charge — each of the driver's `prefill_calls` prices one batched
    /// prompt pass of `prompt_len` positions at the saturated per-token
    /// floor (a prompt pass is one parallel forward, fully amortized),
    /// with calls spread across the workers. The legacy charges fold
    /// prefill into the per-token amortization; pricing calls explicitly
    /// is what makes the sharing saving visible to the cost model —
    /// sharing collapses `prefill_calls` from one per refill event to one
    /// per admitted group.
    pub fn shared_prefill_inference_time(
        &self,
        gen_lens: &[usize],
        pruned_lens: &[usize],
        chunk: usize,
        prefill_calls: usize,
        prompt_len: usize,
    ) -> f64 {
        let calls_per_worker = prefill_calls.div_ceil(self.workers.max(1));
        self.pruned_inference_time(gen_lens, pruned_lens, chunk)
            + calls_per_worker as f64 * prompt_len as f64 * self.tok_time_floor
    }

    /// Number of gradient-accumulation micro-steps forced by the memory
    /// ceiling for an update on `m` rollouts sharded over workers.
    pub fn forced_micro_steps(&self, m: usize) -> usize {
        let shard = m.div_ceil(self.workers.max(1));
        shard.div_ceil(self.mem_capacity_rollouts).max(1)
    }

    /// Update-phase time for `m` rollouts: sequential micro-steps, each a
    /// fwd+bwd (scaled by how full the micro-batch is) plus a collective;
    /// one optimizer apply at the end. `lora` applies the adapter discount
    /// to optimizer/communication traffic (not the fwd+bwd).
    pub fn update_time(&self, m: usize, lora: bool) -> f64 {
        let steps = self.forced_micro_steps(m);
        let shard = m.div_ceil(self.workers.max(1));
        let per_step_rows = shard.div_ceil(steps).min(self.mem_capacity_rollouts);
        let fill = per_step_rows as f64 / self.mem_capacity_rollouts as f64;
        let comm_scale = if self.workers > 1 {
            (self.workers as f64).log2().max(1.0)
        } else {
            0.0
        };
        let state_scale = if lora { self.lora_update_scale } else { 1.0 };
        let per_step = self.microbatch_fixed
            + self.microbatch_time * fill
            + self.comm_base * comm_scale * state_scale;
        steps as f64 * per_step + self.optimizer_time * state_scale
    }

    /// Ring all-reduce time for `bytes` of gradient over `shards` devices:
    /// `2(S-1)` ring steps, each paying the per-hop latency, each moving
    /// `bytes / S` through the interconnect —
    ///
    /// ```text
    ///   t = 2(S-1)·α + (2(S-1)/S) · bytes / BW
    /// ```
    ///
    /// with `α = comm_latency` and `BW = interconnect_gbps / 8 · 1e9`
    /// bytes/s. Zero for a single shard (nothing to reduce). Strictly
    /// increasing in `shards`: both the latency term and the `2(S-1)/S`
    /// volume factor grow with the ring size.
    pub fn allreduce_time(&self, bytes: f64, shards: usize) -> f64 {
        if shards <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        let bw = self.interconnect_gbps * 1e9 / 8.0;
        let hops = 2.0 * (shards as f64 - 1.0);
        hops * self.comm_latency + (hops / shards as f64) * bytes / bw
    }

    /// Gradient bytes one update's all-reduce moves: the simulated model's
    /// parameter count times the wire width, discounted to the adapter
    /// fraction for LoRA runs (only adapter gradients travel).
    pub fn grad_bytes(&self, lora: bool) -> f64 {
        let scale = if lora { self.lora_update_scale } else { 1.0 };
        self.sim_model_params * self.bytes_per_param * scale
    }

    /// Price one sharded update phase: `m` kept rollouts split over
    /// `shards` data-parallel devices, each device running micro-batches
    /// of `micro_batch` rows (0 = the memory ceiling, i.e. the largest
    /// micro-batch that fits). The phase costs
    ///
    /// ```text
    ///   total = max_shard(compute) + allreduce(grad_bytes, shards) + optimizer
    /// ```
    ///
    /// — shards run their sequential micro-steps in parallel, gradients
    /// all-reduce **once** per optimizer step (DDP `no_sync` accumulation
    /// semantics), and the optimizer applies once. Compute per shard sums
    /// per-micro-step costs (`microbatch_fixed` + fill-scaled
    /// `microbatch_time`), so at fixed shards the busiest shard's cost is
    /// strictly increasing in its row count.
    pub fn update_cost(
        &self,
        m: usize,
        shards: usize,
        micro_batch: usize,
        lora: bool,
    ) -> UpdateCost {
        if m == 0 {
            return UpdateCost::default();
        }
        // every rank joins the collective even when m < shards leaves some
        // ranks without rows (zero-gradient participants, as in real DDP)
        let shards = shards.max(1);
        // busiest shard: balanced contiguous split of the kept rollouts
        let shard_rows = m.div_ceil(shards);
        let cap = self.mem_capacity_rollouts.max(1);
        let configured = if micro_batch == 0 { cap } else { micro_batch.min(cap) };
        let rows_per_step = configured.min(shard_rows).max(1);
        let steps = shard_rows.div_ceil(rows_per_step);
        let full = shard_rows / rows_per_step;
        let rem = shard_rows % rows_per_step;
        let per_step = |rows: usize| {
            self.microbatch_fixed + self.microbatch_time * (rows as f64 / cap as f64)
        };
        let mut compute = full as f64 * per_step(rows_per_step);
        if rem > 0 {
            compute += per_step(rem);
        }
        let comm = self.allreduce_time(self.grad_bytes(lora), shards);
        let state_scale = if lora { self.lora_update_scale } else { 1.0 };
        let optimizer = self.optimizer_time * state_scale;
        UpdateCost {
            compute,
            comm,
            optimizer,
            total: compute + comm + optimizer,
            steps,
            peak_mem_rollouts: rows_per_step,
        }
    }

    /// Full-step time (the quantity Fig. 1 top panel plots).
    pub fn step_time(&self, n_rollouts: usize, avg_tokens: f64, m_update: usize, lora: bool) -> f64 {
        self.inference_time(n_rollouts, avg_tokens) + self.update_time(m_update, lora)
    }

    /// Steady-state step time when generation of the next iteration runs
    /// concurrently with the current update: the slower phase bounds the
    /// step, the faster one is hidden.
    pub fn overlapped_step_time(&self, inference: f64, update: f64) -> f64 {
        inference.max(update)
    }
}

/// Itemized cost of one sharded update phase (see
/// [`HwModel::update_cost`]). All times in simulated seconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct UpdateCost {
    /// Sequential micro-step time on the busiest shard (shards run in
    /// parallel; the slowest bounds the phase).
    pub compute: f64,
    /// Ring all-reduce over the gradient bytes, paid once per optimizer
    /// step (`no_sync`-style accumulation between micro-steps).
    pub comm: f64,
    /// Optimizer apply (full-precision state streams).
    pub optimizer: f64,
    /// `compute + comm + optimizer`.
    pub total: f64,
    /// Micro-steps the busiest shard executes.
    pub steps: usize,
    /// Peak rollouts resident per shard in one micro-step — the unit the
    /// paper's Fig. 1 memory ceiling (`mem_capacity_rollouts`) is
    /// denominated in.
    pub peak_mem_rollouts: usize,
}

/// Deterministic paged KV-memory ledger — the modeled resource that gates
/// decode-slot admission (vLLM-style): the slot batcher allocates a row's
/// pages before admitting it, blocks the queue head when they don't fit,
/// and frees them on retire/abort. Prompt pages are allocated once per
/// resident group when prompt KV is shared, once per row otherwise.
///
/// The ledger is bytes-in/bytes-out bookkeeping, not an allocator: `peak`
/// is the high-water mark the train CSV reports as `kv_peak_bytes`, and
/// `capacity = 0` means unbounded (admission never blocks).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvPool {
    capacity: u64,
    allocated: u64,
    peak: u64,
}

impl KvPool {
    /// An empty pool of `capacity` bytes (`0` = unbounded).
    pub fn new(capacity: u64) -> Self {
        Self { capacity, allocated: 0, peak: 0 }
    }

    /// Pool capacity in bytes (`0` = unbounded).
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Would an allocation of `bytes` fit right now?
    pub fn can_admit(&self, bytes: u64) -> bool {
        self.capacity == 0 || self.allocated + bytes <= self.capacity
    }

    /// Allocate `bytes` unconditionally (callers gate on
    /// [`Self::can_admit`]); advances the high-water mark.
    pub fn alloc(&mut self, bytes: u64) {
        self.allocated += bytes;
        self.peak = self.peak.max(self.allocated);
    }

    /// Return `bytes` to the pool (retire/abort). Saturates at zero so a
    /// double-free is an accounting error, not a panic.
    pub fn free(&mut self, bytes: u64) {
        self.allocated = self.allocated.saturating_sub(bytes);
    }

    /// Bytes currently allocated.
    pub fn allocated(&self) -> u64 {
        self.allocated
    }

    /// High-water mark of [`Self::allocated`] over the pool's lifetime.
    pub fn peak(&self) -> u64 {
        self.peak
    }
}

/// Simulated wall clock with overlap accounting.
///
/// Phases that run concurrently with already-charged work advance the
/// clock only by the portion that sticks out past the concurrent phase
/// ([`Self::advance_hidden`]); the hidden remainder accumulates in
/// [`Self::overlap_saved`], so `sync_total == now() + overlap_saved()`
/// always holds for a pipelined run.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    now: f64,
    overlap_saved: f64,
}

impl SimClock {
    /// A clock at t = 0 with no overlap recorded.
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge a phase that ran exclusively (no concurrent work).
    pub fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative time step {dt}");
        self.now += dt;
    }

    /// Charge a phase of `cost` seconds that ran concurrently with
    /// `concurrent` seconds of already-charged work: the clock advances by
    /// `max(cost - concurrent, 0)` and the hidden `min(cost, concurrent)`
    /// is recorded as overlap savings. Returns the amount actually charged.
    pub fn advance_hidden(&mut self, cost: f64, concurrent: f64) -> f64 {
        debug_assert!(cost >= 0.0 && concurrent >= 0.0, "negative phase time");
        let charged = (cost - concurrent).max(0.0);
        self.overlap_saved += cost.min(concurrent);
        self.now += charged;
        charged
    }

    /// Current simulated time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Total simulated time hidden by phase overlap so far (zero for a
    /// purely sequential run).
    pub fn overlap_saved(&self) -> f64 {
        self.overlap_saved
    }

    /// Rebuild a clock at a saved position (checkpoint restore — the
    /// resumed run's timeline continues exactly where the killed run's
    /// stopped).
    pub fn restore(now: f64, overlap_saved: f64) -> Self {
        Self { now, overlap_saved }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_cases;

    /// Fig. 1 bottom: per-token time is non-increasing in batch size.
    #[test]
    fn per_token_monotone() {
        for_cases(200, |rng| {
            let hw = HwModel::default();
            let b1 = rng.gen_range_inclusive(1, 2048) as usize;
            let b2 = rng.gen_range_inclusive(1, 2048) as usize;
            let (lo, hi) = (b1.min(b2), b1.max(b2));
            assert!(hw.per_token_time(lo) >= hw.per_token_time(hi) - 1e-12);
        });
    }

    /// Fig. 1 top: update time is non-decreasing in m and jumps when the
    /// memory ceiling forces extra micro-steps.
    #[test]
    fn update_time_monotone() {
        for_cases(200, |rng| {
            let hw = HwModel::default();
            let m1 = rng.gen_range_inclusive(1, 512) as usize;
            let m2 = rng.gen_range_inclusive(1, 512) as usize;
            let (lo, hi) = (m1.min(m2), m1.max(m2));
            assert!(hw.update_time(lo, false) <= hw.update_time(hi, false) + 1e-9);
        });
    }

    /// More workers never slow inference down.
    #[test]
    fn workers_speed_up_inference() {
        for_cases(200, |rng| {
            let n = rng.gen_range_inclusive(1, 512) as usize;
            let w = rng.gen_range_inclusive(2, 16) as usize;
            let one = HwModel { workers: 1, ..Default::default() };
            let many = HwModel { workers: w, ..Default::default() };
            assert!(many.inference_time(n, 40.0) <= one.inference_time(n, 40.0) + 1e-9);
        });
    }

    /// Ceil-to-chunk: the chunked charge rounds each rollout up
    /// individually, never undercuts the raw charge, and equals it when
    /// every length divides the chunk.
    #[test]
    fn chunked_inference_time_rounds_each_rollout_up() {
        let hw = HwModel::default();
        // exact multiples: no rounding penalty
        let lens = vec![32usize; 16];
        assert!((hw.chunked_inference_time(&lens, 16) - hw.inference_time(16, 32.0)).abs() < 1e-12);
        // heterogeneous lengths round per rollout, not on the mean:
        // (2, 30) at chunk 16 -> 16 + 32 = 48 total, even though the mean
        // (16) divides the chunk exactly
        assert!(
            (hw.chunked_inference_time(&[2, 30], 16) - hw.inference_time(2, 24.0)).abs() < 1e-12
        );
        assert_eq!(hw.chunked_inference_time(&[], 16), 0.0);
        for_cases(200, |rng| {
            let hw = HwModel::default();
            let n = rng.gen_range_inclusive(1, 64) as usize;
            let chunk = rng.gen_range_inclusive(1, 64) as usize;
            let lens: Vec<usize> =
                (0..n).map(|_| rng.gen_range_inclusive(1, 64) as usize).collect();
            let avg = lens.iter().sum::<usize>() as f64 / n as f64;
            let chunked = hw.chunked_inference_time(&lens, chunk);
            assert!(chunked >= hw.inference_time(n, avg) - 1e-9, "ceil-to-chunk undercut");
            // rounding waste is bounded by one chunk per rollout
            let bound = hw.inference_time(n, avg + chunk as f64);
            assert!(chunked <= bound + 1e-9);
        });
    }

    /// Online pruning charges only decoded tokens: no pruned rows ⇒
    /// exactly the chunked charge; pruned rows charge their truncated
    /// lengths, strictly below charging them at any longer length.
    #[test]
    fn pruned_inference_time_charges_only_decoded_tokens() {
        let hw = HwModel::default();
        // no pruned rows: bitwise-identical arithmetic to the chunked path
        let lens = vec![7usize, 30, 2, 16];
        assert_eq!(
            hw.pruned_inference_time(&lens, &[], 16),
            hw.chunked_inference_time(&lens, 16)
        );
        // pruned rows at their decoded lengths == one concatenated list
        let full = vec![32usize, 8];
        let pruned = vec![16usize, 4];
        let concat = vec![32usize, 8, 16, 4];
        assert_eq!(
            hw.pruned_inference_time(&full, &pruned, 4),
            hw.chunked_inference_time(&concat, 4)
        );
        // empty everything is free
        assert_eq!(hw.pruned_inference_time(&[], &[], 16), 0.0);
        // a never-admitted pruned row (0 decoded tokens) adds no token
        // cost, and savings are monotone: aborting earlier never costs more
        for_cases(200, |rng| {
            let hw = HwModel::default();
            let chunk = rng.gen_range_inclusive(1, 32) as usize;
            let n_full = rng.gen_range_inclusive(1, 16) as usize;
            let n_pruned = rng.gen_range_inclusive(1, 16) as usize;
            let full: Vec<usize> =
                (0..n_full).map(|_| rng.gen_range_inclusive(1, 64) as usize).collect();
            let cut: Vec<usize> =
                (0..n_pruned).map(|_| rng.gen_range_inclusive(0, 32) as usize).collect();
            let later: Vec<usize> = cut.iter().map(|&t| t + chunk).collect();
            let early = hw.pruned_inference_time(&full, &cut, chunk);
            let late = hw.pruned_inference_time(&full, &later, chunk);
            assert!(early <= late + 1e-12, "earlier aborts must never charge more");
            // and pruning undercuts decoding those rows to the full budget
            let mut all = full.clone();
            all.extend(cut.iter().map(|&t| t.max(1) + 64));
            assert!(early <= hw.chunked_inference_time(&all, chunk) + 1e-12);
        });
    }

    #[test]
    fn fig1_amortization_ratio_close_to_paper() {
        // paper: per-token time decreases ~21x from batch 8 to batch 512
        let hw = HwModel::default();
        let ratio = hw.per_token_time(8) / hw.per_token_time(512);
        assert!(
            (15.0..30.0).contains(&ratio),
            "amortization ratio {ratio:.1} out of Fig.1 range"
        );
        // saturating beyond 512
        assert!((hw.per_token_time(512) - hw.per_token_time(1024)).abs() < 1e-12);
    }

    #[test]
    fn memory_ceiling_forces_ga() {
        let hw = HwModel::default();
        assert_eq!(hw.forced_micro_steps(32), 1);
        assert_eq!(hw.forced_micro_steps(33), 2);
        assert_eq!(hw.forced_micro_steps(512), 16);
        // the GA cliff: 33 rollouts cost visibly more than 32
        assert!(hw.update_time(33, false) > hw.update_time(32, false) * 1.2);
    }

    #[test]
    fn distributed_update_pays_communication() {
        let single = HwModel { workers: 1, ..Default::default() };
        let multi = HwModel { workers: 8, ..Default::default() };
        // same total rollouts: multi shards fwd+bwd but pays collectives
        let s = single.update_time(32, false);
        let m = multi.update_time(256, false);
        assert!(m > 0.0 && s > 0.0);
        // PODS' claim: fewer micro-steps beat more micro-steps at fixed n
        let pods = multi.update_time(128, false); // m=128 selected
        let ga = multi.update_time(512, false); // train on all 512
        assert!(ga > 2.0 * pods, "GA {ga:.2}s vs PODS {pods:.2}s");
    }

    /// Satellite: the ring all-reduce formula pinned against hand-computed
    /// values — `2(S-1)·α + (2(S-1)/S)·bytes/BW` with BW in bytes/s.
    #[test]
    fn allreduce_time_matches_hand_computed_values() {
        let hw = HwModel {
            interconnect_gbps: 100.0, // -> 12.5e9 bytes/s
            comm_latency: 1e-4,
            ..Default::default()
        };
        let bytes = 1e9;
        // S=1: nothing to reduce
        assert_eq!(hw.allreduce_time(bytes, 1), 0.0);
        // S=2: 2 hops -> 2e-4 latency; volume (2/2)·1e9/12.5e9 = 0.08
        assert!((hw.allreduce_time(bytes, 2) - 0.0802).abs() < 1e-12);
        // S=4: 6 hops -> 6e-4; volume (6/4)·0.08 = 0.12
        assert!((hw.allreduce_time(bytes, 4) - 0.1206).abs() < 1e-12);
        // S=8: 14 hops -> 1.4e-3; volume (14/8)·0.08 = 0.14
        assert!((hw.allreduce_time(bytes, 8) - 0.1414).abs() < 1e-12);
        // zero bytes costs nothing regardless of ring size
        assert_eq!(hw.allreduce_time(0.0, 8), 0.0);
        // strictly increasing in the ring size
        for s in 2..16usize {
            assert!(hw.allreduce_time(bytes, s + 1) > hw.allreduce_time(bytes, s));
        }
    }

    #[test]
    fn grad_bytes_scales_with_model_and_lora() {
        let hw = HwModel::default();
        assert_eq!(hw.grad_bytes(false), 3e9 * 4.0);
        assert_eq!(hw.grad_bytes(true), 3e9 * 4.0 * 0.25);
    }

    /// The PODS update-cost axis: at fixed shards the phase is strictly
    /// cheaper for smaller m, and the communication term strictly grows
    /// with the shard count.
    #[test]
    fn update_cost_monotone_in_m_and_comm_grows_with_shards() {
        let hw = HwModel::default();
        for shards in [1usize, 2, 4, 8] {
            let mut last = f64::INFINITY;
            for m in [64usize, 48, 32, 16, 8] {
                let c = hw.update_cost(m, shards, 8, false);
                assert!(
                    c.total < last,
                    "update_cost not strictly decreasing: m={m} shards={shards} \
                     total={} last={last}",
                    c.total
                );
                assert!((c.total - (c.compute + c.comm + c.optimizer)).abs() < 1e-12);
                last = c.total;
            }
        }
        let mut last_comm = -1.0;
        for shards in [1usize, 2, 4, 8] {
            let c = hw.update_cost(64, shards, 8, false);
            assert!(c.comm > last_comm, "comm must grow with shards");
            last_comm = c.comm;
        }
    }

    /// Hand-computed sharded update costs on the default model
    /// (cap=32, fixed=0.8, time=1.2, optimizer=0.35).
    #[test]
    fn update_cost_hand_computed() {
        let hw = HwModel::default();
        // monolithic, auto micro-batch: 64 rows -> 2 full 32-row steps
        let c = hw.update_cost(64, 1, 0, false);
        assert_eq!(c.steps, 2);
        assert_eq!(c.peak_mem_rollouts, 32);
        assert!((c.compute - 4.0).abs() < 1e-12);
        assert_eq!(c.comm, 0.0);
        assert!((c.total - 4.35).abs() < 1e-12);
        // two shards halve the sequential compute but pay the collective
        let c2 = hw.update_cost(64, 2, 0, false);
        assert_eq!(c2.steps, 1);
        assert!((c2.compute - 2.0).abs() < 1e-12);
        let want_comm = hw.allreduce_time(hw.grad_bytes(false), 2);
        assert!((c2.comm - want_comm).abs() < 1e-12);
        // explicit micro-batch smaller than the ceiling: more, cheaper steps
        let c3 = hw.update_cost(64, 2, 8, false);
        assert_eq!(c3.steps, 4);
        assert_eq!(c3.peak_mem_rollouts, 8);
        assert!((c3.compute - 4.0 * (0.8 + 1.2 * 8.0 / 32.0)).abs() < 1e-12);
        // micro_batch above the memory ceiling is capped by it
        let c4 = hw.update_cost(64, 1, 64, false);
        assert_eq!(c4.peak_mem_rollouts, 32);
        // m = 0: nothing runs, nothing is charged
        assert_eq!(hw.update_cost(0, 4, 8, false), UpdateCost::default());
    }

    #[test]
    fn lora_discount_applies() {
        let hw = HwModel { workers: 8, ..Default::default() };
        assert!(hw.update_time(64, true) < hw.update_time(64, false));
    }

    #[test]
    fn clock_accumulates() {
        let mut c = SimClock::new();
        c.advance(1.5);
        c.advance(2.5);
        assert_eq!(c.now(), 4.0);
        assert_eq!(c.overlap_saved(), 0.0);
    }

    #[test]
    fn overlap_charges_max_and_tracks_savings() {
        // inference 3s fully hidden behind a 5s update: nothing charged
        let mut c = SimClock::new();
        assert_eq!(c.advance_hidden(3.0, 5.0), 0.0);
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.overlap_saved(), 3.0);
        // inference 7s behind a 5s update: only the 2s overhang is charged
        assert_eq!(c.advance_hidden(7.0, 5.0), 2.0);
        assert_eq!(c.now(), 2.0);
        assert_eq!(c.overlap_saved(), 8.0);
        // charged + saved always reconstructs the sequential total
        assert_eq!(c.now() + c.overlap_saved(), 3.0 + 7.0);
    }

    /// `advance(update) + advance_hidden(inference, update)` together charge
    /// exactly `max(inference, update)` — the pipelined steady-state step.
    #[test]
    fn overlap_accounting_matches_overlapped_step_time() {
        let hw = HwModel::default();
        for_cases(200, |rng| {
            let inf = rng.gen_range_inclusive(0, 400) as f64 / 10.0;
            let upd = rng.gen_range_inclusive(0, 400) as f64 / 10.0;
            let mut c = SimClock::new();
            c.advance_hidden(inf, upd);
            c.advance(upd);
            assert!((c.now() - hw.overlapped_step_time(inf, upd)).abs() < 1e-12);
            assert!((c.now() + c.overlap_saved() - (inf + upd)).abs() < 1e-12);
        });
    }

    #[test]
    fn schedule_parses_and_rejects_unknown() {
        assert_eq!(Schedule::parse("sync").unwrap(), Schedule::Sync);
        assert_eq!(Schedule::parse("pipelined").unwrap(), Schedule::Pipelined);
        assert!(Schedule::parse("async").is_err());
        assert_eq!(Schedule::default(), Schedule::Sync);
        assert_eq!(Schedule::Pipelined.name(), "pipelined");
    }

    /// Page math: segments round up to whole pages, zero tokens pin zero
    /// pages, and the per-slot footprint is the sum of its two segments.
    #[test]
    fn kv_bytes_rounds_to_pages() {
        let hw = HwModel { kv_bytes_per_token: 1024, kv_page_tokens: 16, ..Default::default() };
        assert_eq!(hw.kv_page_bytes(), 16 * 1024);
        assert_eq!(hw.kv_seg_bytes(0), 0);
        assert_eq!(hw.kv_seg_bytes(1), 16 * 1024);
        assert_eq!(hw.kv_seg_bytes(16), 16 * 1024);
        assert_eq!(hw.kv_seg_bytes(17), 32 * 1024);
        assert_eq!(hw.kv_bytes(32, 40), hw.kv_seg_bytes(32) + hw.kv_seg_bytes(40));
        for_cases(200, |rng| {
            let hw = HwModel {
                kv_bytes_per_token: rng.gen_range_inclusive(1, 1 << 20),
                kv_page_tokens: rng.gen_range_inclusive(1, 64) as usize,
                ..Default::default()
            };
            let t = rng.gen_range_inclusive(0, 512) as usize;
            let b = hw.kv_seg_bytes(t);
            assert_eq!(b % hw.kv_page_bytes(), 0, "not page-aligned");
            assert!(b >= t as u64 * hw.kv_bytes_per_token, "rounded below the raw bytes");
            assert!(b < (t as u64 + hw.kv_page_tokens as u64) * hw.kv_bytes_per_token);
        });
    }

    /// Pool accounting: admission blocks when full, retire/abort frees the
    /// pages, capacity 0 never blocks.
    #[test]
    fn kv_pool_blocks_when_full_and_frees_on_retire() {
        let mut pool = KvPool::new(100);
        assert!(pool.can_admit(60));
        pool.alloc(60);
        assert!(pool.can_admit(40));
        assert!(!pool.can_admit(41), "over-capacity admission must block");
        pool.alloc(40);
        assert_eq!(pool.allocated(), 100);
        assert!(!pool.can_admit(1));
        pool.free(60); // retire/abort returns the row's pages
        assert!(pool.can_admit(60));
        assert_eq!(pool.allocated(), 40);
        assert_eq!(pool.peak(), 100);
        // unbounded pool never blocks
        let unbounded = KvPool::new(0);
        assert!(unbounded.can_admit(u64::MAX / 2));
    }

    /// The high-water mark is order-invariant: allocating one batch of
    /// rows in any permutation (frees only afterwards) peaks at the sum.
    #[test]
    fn kv_pool_peak_order_invariant() {
        for_cases(200, |rng| {
            let n = rng.gen_range_inclusive(1, 12) as usize;
            let sizes: Vec<u64> = (0..n).map(|_| rng.gen_range_inclusive(1, 1000)).collect();
            // two random admission orders of the same row set
            let mut a = sizes.clone();
            let mut b = sizes.clone();
            for i in (1..n).rev() {
                a.swap(i, rng.gen_range_inclusive(0, i as u64) as usize);
                b.swap(i, rng.gen_range_inclusive(0, i as u64) as usize);
            }
            let run = |order: &[u64]| {
                let mut pool = KvPool::new(0);
                for &s in order {
                    pool.alloc(s);
                }
                for &s in order {
                    pool.free(s);
                }
                assert_eq!(pool.allocated(), 0);
                pool.peak()
            };
            assert_eq!(run(&a), run(&b), "peak depends on admission order");
            assert_eq!(run(&a), sizes.iter().sum::<u64>());
        });
    }

    /// The shared-prefill charge is the pruned/chunked decode charge plus
    /// an explicit per-call prompt-pass term: zero calls collapse to the
    /// decode charge, and fewer prefill calls never cost more — the axis
    /// the sharing saving moves along.
    #[test]
    fn shared_prefill_charge_prices_prefill_calls() {
        let hw = HwModel::default();
        let lens = vec![7usize, 30, 2, 16];
        assert_eq!(
            hw.shared_prefill_inference_time(&lens, &[], 16, 0, 32),
            hw.pruned_inference_time(&lens, &[], 16)
        );
        // one call charges exactly one prompt pass at the floor
        let one = hw.shared_prefill_inference_time(&lens, &[], 16, 1, 32);
        assert!((one - hw.pruned_inference_time(&lens, &[], 16) - 32.0 * hw.tok_time_floor).abs() < 1e-12);
        for_cases(200, |rng| {
            let hw = HwModel {
                workers: rng.gen_range_inclusive(1, 8) as usize,
                ..Default::default()
            };
            let p = rng.gen_range_inclusive(1, 64) as usize;
            let chunk = rng.gen_range_inclusive(1, 32) as usize;
            let lens: Vec<usize> =
                (0..rng.gen_range_inclusive(1, 16)).map(|_| rng.gen_range_inclusive(1, 64) as usize).collect();
            let c1 = rng.gen_range_inclusive(0, 64) as usize;
            let c2 = rng.gen_range_inclusive(0, 64) as usize;
            let (lo, hi) = (c1.min(c2), c1.max(c2));
            let t_lo = hw.shared_prefill_inference_time(&lens, &[], chunk, lo, p);
            let t_hi = hw.shared_prefill_inference_time(&lens, &[], chunk, hi, p);
            assert!(t_lo <= t_hi + 1e-12, "saved prefill calls must never cost more");
        });
    }

    #[test]
    fn hwmodel_validation_rejects_degenerate_sections() {
        let mut hw = HwModel::default();
        hw.validate().unwrap();
        hw.workers = 0;
        let err = hw.validate().unwrap_err().to_string();
        assert!(err.contains("hwsim.workers"), "undescriptive error: {err}");
        hw.workers = 1;
        hw.mem_capacity_rollouts = 0;
        let err = hw.validate().unwrap_err().to_string();
        assert!(err.contains("update micro-batch"), "message must scope the ceiling: {err}");
        assert!(err.contains("kv_pool_bytes"), "message must name the rollout-side limit: {err}");
        hw.mem_capacity_rollouts = 32;
        hw.kv_bytes_per_token = 0;
        assert!(hw.validate().unwrap_err().to_string().contains("kv_bytes_per_token"));
        hw.kv_bytes_per_token = 65_536;
        hw.kv_page_tokens = 0;
        assert!(hw.validate().unwrap_err().to_string().contains("kv_page_tokens"));
        hw.kv_page_tokens = 16;
        hw.tok_time_b1 = -1.0;
        assert!(hw.validate().unwrap_err().to_string().contains("tok_time_b1"));
    }
}
