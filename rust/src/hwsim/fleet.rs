//! Disaggregated two-fleet execution model (`[fleet]`).
//!
//! The paper's core asymmetry — rollout generation is embarrassingly
//! parallel and memory-light while policy updates are communication-heavy
//! — argues for *disaggregated* deployment: an elastic fleet of `R`
//! inference replicas (each a worker-pool box running the chunked/pruned
//! decode driver) feeding one small sharded update fleet through a
//! bounded ready-batch queue. The binary `sync | pipelined` schedule is
//! the degenerate case of a **staleness-K** contract:
//!
//! * a batch generated under `params(t)` may only be consumed by
//!   `update(t')` when `t' − t <= K`;
//! * admission blocks the producing replica's clock while the queue is
//!   full;
//! * `K = 0` is the sync schedule (generation waits for every prior
//!   update) and `K = 1` with `R = 1` is the pipelined schedule (exactly
//!   one batch in flight) — the executor reproduces both **bitwise**
//!   (see `docs/DETERMINISM.md` and `rust/tests/fleet_golden.rs`).
//!
//! This module holds the `[fleet]` config section, the bounded
//! [`ReadyQueue`] with depth/block telemetry, a deterministic synthetic
//! [`TrafficModel`] (bursty arrivals, heterogeneous prompt/gen lengths,
//! millions of queued prompts at batch-granular cost), and [`simulate`] —
//! a discrete-event two-fleet simulator with per-replica [`SimClock`]s
//! that prices an R × K × shards cell entirely on the cost model
//! (`pods exp fleet` sweeps it; no artifacts needed).

use super::{HwModel, Schedule, SimClock};
use crate::util::rng::Rng;
use crate::util::toml::SectionView;
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;

/// `[fleet]` — disaggregated two-fleet execution and its traffic model.
///
/// `inference_replicas` and `max_staleness` shape the *executor* (how
/// many generation batches may be in flight, and how stale a consumed
/// batch may be); the `traffic_*` keys shape only the synthetic traffic
/// the cost-model-only fleet simulator is driven with.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSection {
    /// Inference replicas `R` feeding the update fleet. Each replica is a
    /// worker-pool box running the chunked decode driver; the executor
    /// assigns generation batch `t` to replica `t mod R`.
    pub inference_replicas: usize,
    /// Staleness bound `K`: a batch generated under `params(t)` may only
    /// be consumed by `update(t')` when `t' − t <= K`. Absent, the bound
    /// is derived from `hwsim.schedule` (`sync` → 0, `pipelined` → 1);
    /// present, it must agree with the schedule (`sync` requires 0,
    /// `pipelined` requires >= 1).
    pub max_staleness: Option<usize>,
    /// Ready-batch queue capacity; admission blocks the producing
    /// replica while the queue holds this many unconsumed batches.
    /// `0` (default) derives the capacity from the staleness bound.
    pub queue_capacity: usize,
    /// Backlog size of the synthetic traffic model: prompts queued for
    /// processing. Batch-granular simulation keeps millions cheap.
    pub traffic_prompts: u64,
    /// Prompts arriving per burst (arrivals are bursty, not smooth).
    pub traffic_burst: usize,
    /// Simulated seconds between bursts.
    pub traffic_gap: f64,
    /// Minimum sampled prompt length (tokens).
    pub traffic_prompt_len_min: usize,
    /// Maximum sampled prompt length (tokens).
    pub traffic_prompt_len_max: usize,
    /// Minimum sampled generated length (tokens).
    pub traffic_gen_len_min: usize,
    /// Maximum sampled generated length (tokens).
    pub traffic_gen_len_max: usize,
}

impl Default for FleetSection {
    fn default() -> Self {
        Self {
            inference_replicas: 1,
            max_staleness: None,
            queue_capacity: 0,
            traffic_prompts: 1_000_000,
            traffic_burst: 256,
            traffic_gap: 4.0,
            traffic_prompt_len_min: 16,
            traffic_prompt_len_max: 64,
            traffic_gen_len_min: 8,
            traffic_gen_len_max: 64,
        }
    }
}

impl FleetSection {
    /// Parse from a `[fleet]` config section; absent keys keep defaults.
    pub fn from_section(sec: &SectionView) -> Result<Self> {
        let d = Self::default();
        let max_staleness = match sec.get("max_staleness") {
            Some(v) => Some(v.as_usize().map_err(|e| anyhow!("fleet.max_staleness: {e}"))?),
            None => None,
        };
        let fl = Self {
            inference_replicas: sec.usize_or("inference_replicas", d.inference_replicas)?,
            max_staleness,
            queue_capacity: sec.usize_or("queue_capacity", d.queue_capacity)?,
            traffic_prompts: sec.u64_or("traffic_prompts", d.traffic_prompts)?,
            traffic_burst: sec.usize_or("traffic_burst", d.traffic_burst)?,
            traffic_gap: sec.f64_or("traffic_gap", d.traffic_gap)?,
            traffic_prompt_len_min: sec
                .usize_or("traffic_prompt_len_min", d.traffic_prompt_len_min)?,
            traffic_prompt_len_max: sec
                .usize_or("traffic_prompt_len_max", d.traffic_prompt_len_max)?,
            traffic_gen_len_min: sec.usize_or("traffic_gen_len_min", d.traffic_gen_len_min)?,
            traffic_gen_len_max: sec.usize_or("traffic_gen_len_max", d.traffic_gen_len_max)?,
        };
        fl.validate()?;
        Ok(fl)
    }

    /// Reject degenerate sections at parse time (the cross-check against
    /// `hwsim.schedule` lives in `RunConfig::validate`, which sees both
    /// sections).
    pub fn validate(&self) -> Result<()> {
        if self.inference_replicas == 0 {
            bail!(
                "fleet.inference_replicas must be >= 1 (0 replicas cannot \
                 generate; use 1 for the single-box schedules)"
            );
        }
        if self.traffic_prompts == 0 {
            bail!("fleet.traffic_prompts must be >= 1 (an empty backlog drives nothing)");
        }
        if self.traffic_burst == 0 {
            bail!("fleet.traffic_burst must be >= 1 (arrivals come in bursts of at least one)");
        }
        if !(self.traffic_gap >= 0.0 && self.traffic_gap.is_finite()) {
            bail!("fleet.traffic_gap must be finite and >= 0 (got {})", self.traffic_gap);
        }
        if self.traffic_prompt_len_min == 0
            || self.traffic_prompt_len_min > self.traffic_prompt_len_max
        {
            bail!(
                "fleet.traffic_prompt_len_min must be >= 1 and <= traffic_prompt_len_max \
                 (got {}..={})",
                self.traffic_prompt_len_min,
                self.traffic_prompt_len_max
            );
        }
        if self.traffic_gen_len_min == 0 || self.traffic_gen_len_min > self.traffic_gen_len_max {
            bail!(
                "fleet.traffic_gen_len_min must be >= 1 and <= traffic_gen_len_max \
                 (got {}..={})",
                self.traffic_gen_len_min,
                self.traffic_gen_len_max
            );
        }
        Ok(())
    }

    /// The effective staleness bound `K` under `schedule`: the explicit
    /// `max_staleness` when set, else the schedule's legacy bound
    /// (`sync` → 0, `pipelined` → 1). The executor's prefetch depth and
    /// the off-policy floor both key off this value.
    pub fn effective_staleness(&self, schedule: Schedule) -> usize {
        self.max_staleness.unwrap_or(match schedule {
            Schedule::Sync => 0,
            Schedule::Pipelined => 1,
        })
    }

    /// The effective ready-queue capacity under `schedule`: the explicit
    /// `queue_capacity` when set, else the staleness bound (a deeper
    /// queue than `K` could only hold batches that expire before they
    /// are eligible).
    pub fn effective_queue_capacity(&self, schedule: Schedule) -> usize {
        if self.queue_capacity == 0 {
            self.effective_staleness(schedule)
        } else {
            self.queue_capacity
        }
    }
}

/// One entry of a [`ReadyQueue`]: the payload plus the params version it
/// was generated under (the origin iteration `t` of the staleness
/// contract).
#[derive(Debug, Clone)]
pub struct QueueEntry<T> {
    /// Params version / iteration the batch was generated under.
    pub origin: u64,
    /// The queued payload (a ready generation batch).
    pub item: T,
}

/// Bounded FIFO of ready generation batches with staleness-gated
/// consumption and depth/block telemetry.
///
/// Producers [`push`](Self::push) completed batches tagged with the
/// params version they were generated under; the consumer
/// [`pop_eligible`](Self::pop_eligible)s the *oldest* entry, and only
/// when its realized staleness at the consuming version is within the
/// bound. Consumption order is therefore a pure function of generation
/// history — never of which replica produced a batch or how the worker
/// pool was partitioned.
#[derive(Debug, Clone)]
pub struct ReadyQueue<T> {
    capacity: usize,
    entries: VecDeque<QueueEntry<T>>,
    pushes: u64,
    depth_sum: u64,
    max_depth: usize,
    block_time: f64,
}

impl<T> ReadyQueue<T> {
    /// An empty queue of `capacity` batches (`0` = unbounded).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            entries: VecDeque::new(),
            pushes: 0,
            depth_sum: 0,
            max_depth: 0,
            block_time: 0.0,
        }
    }

    /// Batches currently queued.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// True when an admission would exceed the capacity (never for an
    /// unbounded queue).
    pub fn is_full(&self) -> bool {
        self.capacity != 0 && self.entries.len() >= self.capacity
    }

    /// Admit a completed batch generated under params version `origin`.
    /// Callers gate on [`Self::is_full`]; admission past capacity is an
    /// accounting bug.
    pub fn push(&mut self, origin: u64, item: T) {
        debug_assert!(!self.is_full(), "ReadyQueue admission past capacity");
        self.entries.push_back(QueueEntry { origin, item });
        self.pushes += 1;
        self.depth_sum += self.entries.len() as u64;
        self.max_depth = self.max_depth.max(self.entries.len());
    }

    /// Record simulated seconds a producer spent blocked on a full queue.
    pub fn record_block(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative block time {dt}");
        self.block_time += dt;
    }

    /// Origin version of the oldest queued batch, if any.
    pub fn front_origin(&self) -> Option<u64> {
        self.entries.front().map(|e| e.origin)
    }

    /// Consume the oldest batch iff its realized staleness at
    /// `consume_version` is within `k` (`consume_version − origin <= k`).
    /// Returns `None` when the queue is empty or the head is not yet
    /// eligible under the contract.
    pub fn pop_eligible(&mut self, consume_version: u64, k: usize) -> Option<QueueEntry<T>> {
        let head = self.entries.front()?;
        if consume_version.saturating_sub(head.origin) > k as u64 {
            return None;
        }
        self.entries.pop_front()
    }

    /// Mean queue depth sampled at admission events.
    pub fn depth_mean(&self) -> f64 {
        if self.pushes == 0 {
            0.0
        } else {
            self.depth_sum as f64 / self.pushes as f64
        }
    }

    /// Deepest the queue ever got.
    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    /// Total simulated seconds producers spent blocked on a full queue.
    pub fn block_time(&self) -> f64 {
        self.block_time
    }

    /// Total admissions over the queue's lifetime.
    pub fn pushes(&self) -> u64 {
        self.pushes
    }
}

/// Deterministic synthetic traffic: a backlog of prompts arriving in
/// bursts, with per-row prompt/gen lengths sampled from a batch-keyed
/// stream. All quantities are closed-form or batch-granular, so a
/// backlog of millions of prompts costs nothing per prompt.
#[derive(Debug, Clone)]
pub struct TrafficModel {
    burst: usize,
    gap: f64,
    prompt_len: (usize, usize),
    gen_len: (usize, usize),
    seed: u64,
}

impl TrafficModel {
    /// Build the traffic model a `[fleet]` section describes, seeded so
    /// sampled lengths replay exactly.
    pub fn new(fleet: &FleetSection, seed: u64) -> Self {
        Self {
            burst: fleet.traffic_burst.max(1),
            gap: fleet.traffic_gap,
            prompt_len: (fleet.traffic_prompt_len_min, fleet.traffic_prompt_len_max),
            gen_len: (fleet.traffic_gen_len_min, fleet.traffic_gen_len_max),
            seed,
        }
    }

    /// Arrival time of prompt `index` (0-based): bursts of `burst`
    /// prompts land together every `gap` seconds, starting at t = 0.
    pub fn arrival_time(&self, index: u64) -> f64 {
        (index / self.burst as u64) as f64 * self.gap
    }

    /// Arrival time of the *last* prompt of a contiguous batch
    /// (`count >= 1` prompts starting at `first`) — when the whole batch
    /// is present and generation may start.
    pub fn batch_arrival(&self, first: u64, count: u64) -> f64 {
        self.arrival_time(first + count.max(1) - 1)
    }

    /// Batch-keyed RNG stream: batch `b` always samples the same
    /// lengths, independent of every other batch.
    fn batch_rng(&self, batch: u64) -> Rng {
        Rng::seed_from_u64(self.seed ^ batch.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Per-rollout generated lengths for batch `batch` (`rows` rollouts),
    /// uniform in the configured range.
    pub fn gen_lens(&self, batch: u64, rows: usize) -> Vec<usize> {
        let mut rng = self.batch_rng(batch);
        (0..rows)
            .map(|_| rng.gen_range_inclusive(self.gen_len.0 as i64, self.gen_len.1 as i64) as usize)
            .collect()
    }

    /// Total prompt tokens of batch `batch` (`prompts` heterogeneous
    /// prompts), sampled from a stream disjoint from [`Self::gen_lens`].
    pub fn prompt_tokens(&self, batch: u64, prompts: usize) -> usize {
        let mut rng = self.batch_rng(batch ^ 0x5151_5151_5151_5151);
        (0..prompts)
            .map(|_| {
                rng.gen_range_inclusive(self.prompt_len.0 as i64, self.prompt_len.1 as i64) as usize
            })
            .sum()
    }
}

/// One cell of the two-fleet design space [`simulate`] prices.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    /// Inference replicas `R` (each a worker-pool box).
    pub replicas: usize,
    /// Staleness bound `K`.
    pub max_staleness: usize,
    /// Ready-queue capacity (`0` = unbounded).
    pub queue_capacity: usize,
    /// Updates to run (= generation batches to consume).
    pub updates: usize,
    /// Rollouts decoded per generation batch.
    pub rows_per_batch: usize,
    /// Prompts drawn from the traffic backlog per batch.
    pub prompts_per_batch: u64,
    /// Decode chunk the replicas run.
    pub decode_chunk: usize,
    /// Rollouts each update trains on (post-selection).
    pub update_rollouts: usize,
    /// Data-parallel shards of the update fleet.
    pub shards: usize,
    /// Rows per update micro-batch (0 = memory ceiling).
    pub micro_batch: usize,
    /// LoRA update discount on optimizer/comm traffic.
    pub lora: bool,
}

/// What one [`simulate`] run measured.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Simulated makespan: when the last update finished.
    pub wall_clock: f64,
    /// Fraction of replica-seconds spent decoding (vs idle/blocked).
    pub inference_util: f64,
    /// Fraction of the makespan the update fleet spent updating.
    pub update_util: f64,
    /// Mean ready-queue depth sampled at admissions.
    pub mean_queue_depth: f64,
    /// Deepest the ready queue ever got.
    pub max_queue_depth: usize,
    /// Total replica-seconds blocked on a full queue.
    pub queue_block_time: f64,
    /// `staleness_hist[s]` = batches consumed at realized staleness `s`.
    pub staleness_hist: Vec<u64>,
    /// Mean realized staleness over all consumed batches.
    pub mean_staleness: f64,
    /// Largest realized staleness (never exceeds the bound).
    pub max_staleness_seen: usize,
    /// Prompts drained from the traffic backlog.
    pub prompts_drained: u64,
}

/// Price a two-fleet cell on the cost model alone.
///
/// Discrete-event simulation in batch production order: batch `i` is
/// generated on replica `i mod R` (its own [`SimClock`]), may only
/// *start* once at most `K` earlier batches remain unconsumed (that is
/// the staleness contract enforced at the producer: the batch will be
/// consumed as update `i`, under a version at least `i − K`), waits for
/// its prompts to arrive, blocks on a full ready queue, and is consumed
/// FIFO by the sequential sharded update fleet. Realized staleness of
/// batch `i` is `i` minus the updates finished when its generation
/// started; the simulator asserts it never exceeds `K`.
pub fn simulate(hw: &HwModel, traffic: &TrafficModel, spec: &FleetSpec) -> FleetReport {
    let r = spec.replicas.max(1);
    let k = spec.max_staleness;
    let cap = spec.queue_capacity;
    let upd = hw
        .update_cost(spec.update_rollouts, spec.shards, spec.micro_batch, spec.lora)
        .total;
    let mut replicas: Vec<SimClock> = (0..r).map(|_| SimClock::new()).collect();
    let mut busy = vec![0.0f64; r];
    let mut queue: ReadyQueue<usize> = ReadyQueue::new(cap);
    // FIFO consumption order == production order, so per-batch times are
    // computable in one forward pass.
    let mut upd_start = vec![0.0f64; spec.updates];
    let mut upd_finish = vec![0.0f64; spec.updates];
    let mut hist = vec![0u64; k + 1];
    let mut staleness_sum = 0u64;
    let mut max_seen = 0usize;
    let mut gen_total = 0.0f64;
    for i in 0..spec.updates {
        let rep = i % r;
        let first_prompt = i as u64 * spec.prompts_per_batch;
        let arrival = traffic.batch_arrival(first_prompt, spec.prompts_per_batch);
        // staleness throttle: batch i is consumed as update i under
        // version i, generated under version v >= i − K ⟺ update
        // i − K − 1 has finished before generation starts
        let throttle = if i > k { upd_finish[i - k - 1] } else { 0.0 };
        let free = replicas[rep].now();
        let start = free.max(arrival).max(throttle);
        replicas[rep].advance(start - free); // idle: waiting on arrival/throttle
        let lens = traffic.gen_lens(i as u64, spec.rows_per_batch);
        let prompt_tokens =
            traffic.prompt_tokens(i as u64, (spec.prompts_per_batch as usize).max(1));
        // one batched prompt pass at the saturated floor + chunked decode
        let gen = hw.chunked_inference_time(&lens, spec.decode_chunk)
            + prompt_tokens as f64 * hw.tok_time_floor / hw.workers.max(1) as f64;
        replicas[rep].advance(gen);
        busy[rep] += gen;
        gen_total += gen;
        let done = replicas[rep].now();
        // queue admission: space opens when update i − cap pops its batch
        let admit_at = if cap > 0 && i >= cap { upd_start[i - cap] } else { 0.0 };
        let ready = done.max(admit_at);
        queue.record_block(ready - done);
        replicas[rep].advance(ready - done);
        // drain entries the update fleet consumed before this admission,
        // then admit — keeps the queue's depth telemetry honest
        while queue
            .front_origin()
            .is_some_and(|o| upd_start[o as usize] <= ready && (o as usize) < i)
        {
            let popped = queue.pop_eligible(u64::MAX, usize::MAX);
            debug_assert!(popped.is_some());
        }
        queue.push(i as u64, i);
        // sequential update fleet consumes FIFO
        let prev_finish = if i > 0 { upd_finish[i - 1] } else { 0.0 };
        upd_start[i] = ready.max(prev_finish);
        upd_finish[i] = upd_start[i] + upd;
        // realized staleness: updates finished before generation started
        let v = upd_finish[..i].partition_point(|&f| f <= start);
        let s = i - v;
        assert!(s <= k, "staleness contract violated: batch {i} consumed at staleness {s} > {k}");
        hist[s] += 1;
        staleness_sum += s as u64;
        max_seen = max_seen.max(s);
    }
    let wall = if spec.updates > 0 { upd_finish[spec.updates - 1] } else { 0.0 };
    let batches = spec.updates.max(1) as f64;
    FleetReport {
        wall_clock: wall,
        inference_util: if wall > 0.0 { gen_total / (r as f64 * wall) } else { 0.0 },
        update_util: if wall > 0.0 { spec.updates as f64 * upd / wall } else { 0.0 },
        mean_queue_depth: queue.depth_mean(),
        max_queue_depth: queue.max_depth(),
        queue_block_time: queue.block_time(),
        staleness_hist: hist,
        mean_staleness: staleness_sum as f64 / batches,
        max_staleness_seen: max_seen,
        prompts_drained: spec.updates as u64 * spec.prompts_per_batch,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_cases;

    fn flat_traffic() -> TrafficModel {
        // degenerate ranges + one giant instantaneous burst: constant
        // per-batch cost, arrivals never limit
        TrafficModel {
            burst: usize::MAX / 2,
            gap: 0.0,
            prompt_len: (32, 32),
            gen_len: (32, 32),
            seed: 7,
        }
    }

    fn spec(replicas: usize, k: usize) -> FleetSpec {
        FleetSpec {
            replicas,
            max_staleness: k,
            queue_capacity: k,
            updates: 12,
            rows_per_batch: 64,
            prompts_per_batch: 1,
            decode_chunk: 16,
            update_rollouts: 16,
            shards: 2,
            micro_batch: 0,
            lora: false,
        }
    }

    #[test]
    fn section_defaults_and_effective_bounds() {
        let fl = FleetSection::default();
        fl.validate().unwrap();
        assert_eq!(fl.inference_replicas, 1);
        assert_eq!(fl.max_staleness, None);
        // schedule-derived bounds: the legacy schedules are the special
        // cases K=0 and K=1
        assert_eq!(fl.effective_staleness(Schedule::Sync), 0);
        assert_eq!(fl.effective_staleness(Schedule::Pipelined), 1);
        assert_eq!(fl.effective_queue_capacity(Schedule::Sync), 0);
        assert_eq!(fl.effective_queue_capacity(Schedule::Pipelined), 1);
        let deep = FleetSection { max_staleness: Some(3), ..FleetSection::default() };
        assert_eq!(deep.effective_staleness(Schedule::Pipelined), 3);
        assert_eq!(deep.effective_queue_capacity(Schedule::Pipelined), 3);
        let capped = FleetSection {
            max_staleness: Some(3),
            queue_capacity: 2,
            ..FleetSection::default()
        };
        assert_eq!(capped.effective_queue_capacity(Schedule::Pipelined), 2);
    }

    #[test]
    fn section_validation_rejects_degenerate() {
        let cases: [(FleetSection, &str); 5] = [
            (
                FleetSection { inference_replicas: 0, ..Default::default() },
                "fleet.inference_replicas",
            ),
            (FleetSection { traffic_burst: 0, ..Default::default() }, "fleet.traffic_burst"),
            (FleetSection { traffic_gap: f64::NAN, ..Default::default() }, "fleet.traffic_gap"),
            (
                FleetSection { traffic_prompt_len_min: 0, ..Default::default() },
                "fleet.traffic_prompt_len_min",
            ),
            (
                FleetSection { traffic_gen_len_min: 65, ..Default::default() },
                "fleet.traffic_gen_len_min",
            ),
        ];
        for (fl, want) in cases {
            let err = fl.validate().unwrap_err().to_string();
            assert!(err.contains(want), "undescriptive error: {err}");
        }
    }

    #[test]
    fn ready_queue_gates_on_staleness_and_tracks_telemetry() {
        let mut q: ReadyQueue<&str> = ReadyQueue::new(2);
        assert!(q.is_empty() && !q.is_full());
        q.push(0, "a");
        q.push(1, "b");
        assert!(q.is_full());
        assert_eq!(q.front_origin(), Some(0));
        // consuming at version 2 with K=1 leaves the origin-0 head stale
        assert!(q.pop_eligible(2, 1).is_none());
        // within the bound the oldest entry pops first
        let e = q.pop_eligible(1, 1).unwrap();
        assert_eq!((e.origin, e.item), (0, "a"));
        assert_eq!(q.pop_eligible(1, 0).unwrap().item, "b");
        assert!(q.pop_eligible(0, 9).is_none(), "empty queue pops nothing");
        // telemetry: two admissions at depths 1 and 2
        assert_eq!(q.pushes(), 2);
        assert_eq!(q.max_depth(), 2);
        assert!((q.depth_mean() - 1.5).abs() < 1e-12);
        q.record_block(0.25);
        q.record_block(0.5);
        assert!((q.block_time() - 0.75).abs() < 1e-12);
        // unbounded queue never fills
        let mut u: ReadyQueue<u32> = ReadyQueue::new(0);
        for i in 0..64 {
            u.push(i, 0);
        }
        assert!(!u.is_full());
    }

    #[test]
    fn traffic_arrivals_are_bursty_and_lengths_deterministic() {
        let fl = FleetSection {
            traffic_burst: 4,
            traffic_gap: 2.0,
            ..FleetSection::default()
        };
        let t = TrafficModel::new(&fl, 0);
        // burst arithmetic: prompts 0..=3 land at t=0, 4..=7 at t=2, ...
        assert_eq!(t.arrival_time(0), 0.0);
        assert_eq!(t.arrival_time(3), 0.0);
        assert_eq!(t.arrival_time(4), 2.0);
        assert_eq!(t.arrival_time(11), 4.0);
        // a batch is present when its last prompt lands
        assert_eq!(t.batch_arrival(0, 4), 0.0);
        assert_eq!(t.batch_arrival(0, 5), 2.0);
        // closed form handles backlog-scale indices without iteration
        assert_eq!(t.arrival_time(4_000_000_000), 2_000_000_000.0);
        // sampled lengths: deterministic per batch, in range, batch-keyed
        let a = t.gen_lens(3, 32);
        assert_eq!(a, t.gen_lens(3, 32));
        assert!(a.iter().all(|&l| (8..=64).contains(&l)));
        assert_ne!(a, t.gen_lens(4, 32), "batches must sample disjoint streams");
        let p = t.prompt_tokens(3, 8);
        assert_eq!(p, t.prompt_tokens(3, 8));
        assert!((8 * 16..=8 * 64).contains(&p));
    }

    /// K=0 with one replica is the sync schedule: every batch waits for
    /// every prior update, so the makespan is the exact serial sum. K=1
    /// matches the pipelined steady state: first generation exposed,
    /// then `max(gen, upd)` per step, then the last update.
    #[test]
    fn sim_reproduces_sync_and_pipelined_closed_forms() {
        let hw = HwModel::default();
        let t = flat_traffic();
        let s0 = spec(1, 0);
        let gen = hw.chunked_inference_time(&t.gen_lens(0, s0.rows_per_batch), s0.decode_chunk)
            + t.prompt_tokens(0, 1) as f64 * hw.tok_time_floor;
        let upd = hw.update_cost(s0.update_rollouts, s0.shards, 0, false).total;
        let r0 = simulate(&hw, &t, &s0);
        assert!((r0.wall_clock - s0.updates as f64 * (gen + upd)).abs() < 1e-9);
        assert_eq!(r0.max_staleness_seen, 0);
        assert_eq!(r0.staleness_hist, vec![s0.updates as u64]);
        assert_eq!(r0.queue_block_time, 0.0);
        let s1 = spec(1, 1);
        let r1 = simulate(&hw, &t, &s1);
        let want = gen + (s1.updates - 1) as f64 * gen.max(upd) + upd;
        assert!((r1.wall_clock - want).abs() < 1e-9, "pipelined {} vs {want}", r1.wall_clock);
        assert!(r1.wall_clock < r0.wall_clock);
        assert!(r1.max_staleness_seen <= 1);
    }

    /// The acceptance shape: wall-clock is non-increasing in R and
    /// strictly decreases until the update fleet is the bottleneck.
    #[test]
    fn wall_clock_decreases_in_replicas_until_update_bound() {
        let hw = HwModel::default();
        let t = flat_traffic();
        let mut last = f64::INFINITY;
        let mut walls = Vec::new();
        for r in [1usize, 2, 4, 8] {
            let mut s = spec(r, 4);
            s.queue_capacity = 4;
            s.updates = 24;
            let rep = simulate(&hw, &t, &s);
            assert!(rep.wall_clock <= last + 1e-9, "R={r} slowed the fleet down");
            // never below the update-fleet lower bound
            let upd = hw.update_cost(s.update_rollouts, s.shards, 0, false).total;
            assert!(rep.wall_clock >= s.updates as f64 * upd - 1e-9);
            last = rep.wall_clock;
            walls.push(rep.wall_clock);
        }
        assert!(walls[1] < walls[0], "R=2 must strictly beat R=1 while generation-bound");
    }

    /// Realized staleness never exceeds K, utilizations stay in [0, 1],
    /// and the histogram accounts for every batch — across random cells.
    #[test]
    fn staleness_bound_holds_across_random_cells() {
        for_cases(60, |rng| {
            let hw = HwModel::default();
            let fl = FleetSection {
                traffic_burst: rng.gen_range_inclusive(1, 64) as usize,
                traffic_gap: rng.gen_range_inclusive(0, 40) as f64 / 10.0,
                ..FleetSection::default()
            };
            let t = TrafficModel::new(&fl, rng.next_u64());
            let s = FleetSpec {
                replicas: rng.gen_range_inclusive(1, 6) as usize,
                max_staleness: rng.gen_range_inclusive(0, 4) as usize,
                queue_capacity: rng.gen_range_inclusive(0, 4) as usize,
                updates: rng.gen_range_inclusive(1, 20) as usize,
                rows_per_batch: rng.gen_range_inclusive(1, 32) as usize,
                prompts_per_batch: rng.gen_range_inclusive(1, 4) as u64,
                decode_chunk: 16,
                update_rollouts: rng.gen_range_inclusive(1, 32) as usize,
                shards: rng.gen_range_inclusive(1, 4) as usize,
                micro_batch: 0,
                lora: false,
            };
            let rep = simulate(&hw, &t, &s);
            assert!(rep.max_staleness_seen <= s.max_staleness);
            assert!(rep.staleness_hist.iter().sum::<u64>() == s.updates as u64);
            assert!((0.0..=1.0 + 1e-9).contains(&rep.inference_util));
            assert!((0.0..=1.0 + 1e-9).contains(&rep.update_util));
            assert!(rep.wall_clock >= 0.0 && rep.queue_block_time >= 0.0);
        });
    }
}
