//! Generated configuration reference (`pods config-docs`).
//!
//! [`render`] produces `docs/CONFIG.md` from the config structs: every
//! `[section]` key with its type, default, validation rule and meaning.
//! Defaults are read from the same `Default` impls / parse fallbacks the
//! parser uses, so the document cannot drift from the code silently —
//! and CI runs [`check`] (`pods config-docs --check`) to fail when the
//! committed file is stale.

use super::{BudgetSection, CkptSection, ReplaySection, RolloutSection, UpdateSection};
use crate::hwsim::{FaultSection, FleetSection, HwModel};
use anyhow::{anyhow, Result};
use std::path::Path;

/// Documentation for one config key.
#[derive(Debug, Clone)]
pub struct KeyDoc {
    /// TOML key name.
    pub key: &'static str,
    /// Value type as the parser accepts it.
    pub typ: &'static str,
    /// Default value (`required` / `—` for keys without one).
    pub default: String,
    /// Validation rule enforced at parse time.
    pub validation: &'static str,
    /// What the key means.
    pub doc: &'static str,
}

impl KeyDoc {
    fn new(
        key: &'static str,
        typ: &'static str,
        default: impl Into<String>,
        validation: &'static str,
        doc: &'static str,
    ) -> Self {
        Self { key, typ, default: default.into(), validation, doc }
    }
}

/// Documentation for one `[section]`.
#[derive(Debug, Clone)]
pub struct SectionDoc {
    /// Section name as written in the TOML (`run`, `algo`, ...).
    pub name: &'static str,
    /// One-paragraph section summary.
    pub intro: &'static str,
    /// Every key the parser reads from this section.
    pub keys: Vec<KeyDoc>,
}

/// The full config schema, in the order sections appear in shipped
/// configs. Defaults are pulled from the live `Default` impls.
pub fn sections() -> Vec<SectionDoc> {
    let hw = HwModel::default();
    let ro = RolloutSection::default();
    let up = UpdateSection::default();
    let rp = ReplaySection::default();
    let bu = BudgetSection::default();
    let fa = FaultSection::default();
    let fl = FleetSection::default();
    let ck = CkptSection::default();
    vec![
        SectionDoc {
            name: "run",
            intro: "Run identity, scale and I/O locations.",
            keys: vec![
                KeyDoc::new("name", "string", "required", "non-empty", "Run name; prefixes the output CSV files."),
                KeyDoc::new("profile", "string", "required", "must exist under `artifacts/`", "Artifact profile (micro \\| base \\| lora \\| big)."),
                KeyDoc::new("task", "string", "required", "arith \\| poly \\| mcq", "Task family generating prompts and verifying answers."),
                KeyDoc::new("seed", "int", "0", ">= 0", "Master RNG seed every per-row / per-group stream derives from."),
                KeyDoc::new("iterations", "int", "required", ">= 0 (0 = SFT-only run)", "RL training iterations."),
                KeyDoc::new("prompts_per_iter", "int", "2", ">= 1", "Prompts (groups) per training iteration."),
                KeyDoc::new("eval_every", "int", "10", "—", "Evaluate every this many iterations."),
                KeyDoc::new("eval_problems", "int", "64", "—", "Problems per evaluation snapshot."),
                KeyDoc::new("out_dir", "string", "\"results\"", "—", "Where CSVs and checkpoints go."),
                KeyDoc::new("base_checkpoint", "string", "—", "required for LoRA profiles", "Pre-trained base checkpoint to start from."),
                KeyDoc::new("save_checkpoint", "string", "—", "—", "Save a checkpoint here at the end of the run."),
            ],
        },
        SectionDoc {
            name: "algo",
            intro: "Training schedule, rollout/update sizes (n, m), the \
                    rollout-selection pipeline and optimizer knobs.",
            keys: vec![
                KeyDoc::new("kind", "string", "required", "grpo \\| ga \\| pods", "Schedule: vanilla GRPO (m = n), GRPO-GA (train on all n via accumulation), GRPO-PODS (down-sample to m)."),
                KeyDoc::new("n", "int", "required", ">= 1", "Rollouts generated per prompt per iteration."),
                KeyDoc::new("m", "int", "required for pods", "1..=n", "Update size after down-sampling (ignored for grpo/ga)."),
                KeyDoc::new("rule", "string", "\"max_variance\"", "must parse against the selector registry", "Selection pipeline spec, e.g. `\"drop_zero_variance \\| max_variance\"`."),
                KeyDoc::new("adv_norm", "string", "\"after\"", "after \\| before", "Advantage normalization mode (paper §A.3)."),
                KeyDoc::new("kl_coef", "float", "0", ">= 0 (0 disables the reference)", "KL-to-reference coefficient."),
                KeyDoc::new("lr", "float", "required", "> 0", "AdamW learning rate for the policy update."),
                KeyDoc::new("temperature", "float", "1", "—", "Sampling temperature for rollout generation."),
            ],
        },
        SectionDoc {
            name: "rollout",
            intro: "The chunked early-exit decode driver (slot-based \
                    continuous batching).",
            keys: vec![
                KeyDoc::new("decode_chunk", "int", ro.decode_chunk.to_string(), ">= 1; must match a lowered program ({1, 4, 16, G})", "Tokens decoded per `decode_chunk` call."),
                KeyDoc::new("refill", "string", format!("\"{}\"", ro.refill.name()), "continuous \\| batch", "Slot-refill policy between chunks: admit queued rows into freed slots, or drain the whole batch first."),
                KeyDoc::new("online_prune", "bool", ro.online_prune.to_string(), "requires `algo.adv_norm = \"after\"`", "Abort rollouts at chunk boundaries once they provably cannot survive the selection pipeline (doom-only verdicts; see docs/DETERMINISM.md)."),
                KeyDoc::new("share_prompt_kv", "bool", ro.share_prompt_kv.to_string(), "—", "Prefill each prompt group once and admit sibling rows from the group's on-device snapshot; token streams are bit-identical either way (docs/DETERMINISM.md)."),
            ],
        },
        SectionDoc {
            name: "update",
            intro: "The sharded data-parallel update engine. Shards and \
                    micro-batching move only simulated cost (compute, ring \
                    all-reduce, peak memory) — trained parameters are \
                    bit-identical for any shard count (docs/DETERMINISM.md).",
            keys: vec![
                KeyDoc::new("shards", "int", up.shards.to_string(), ">= 1", "Simulated data-parallel device shards the update batch is split over."),
                KeyDoc::new("micro_batch", "int", up.micro_batch.to_string(), "0..=B_u (0 = the profile's full B_u)", "Rows per update micro-batch; the hwsim memory ceiling still caps the effective size."),
            ],
        },
        SectionDoc {
            name: "replay",
            intro: "Cross-iteration rollout replay: dropped-but-eligible \
                    rollouts enter a staleness-bounded store and are mixed \
                    back into later updates with truncated \
                    importance-weight correction. Off by default; disabled \
                    runs are bit-identical to a build without the section \
                    (docs/DETERMINISM.md).",
            keys: vec![
                KeyDoc::new("enabled", "bool", rp.enabled.to_string(), "requires `algo.adv_norm = \"after\"`", "Turn replay on."),
                KeyDoc::new("mix_fraction", "float", rp.mix_fraction.to_string(), "0.0..=1.0", "Replay quota per update as a fraction of the fresh selected rows (`floor(mix_fraction * m)`)."),
                KeyDoc::new("staleness", "int", rp.staleness.to_string(), ">= 1", "Iterations a stored row stays eligible; older rows evict deterministically."),
                KeyDoc::new("capacity_per_prompt", "int", rp.capacity_per_prompt.to_string(), ">= 1", "Stored rows kept per prompt (eviction: staleness, then admission score, ties by row id)."),
                KeyDoc::new("rho_max", "float", rp.rho_max.to_string(), ">= 1", "Per-token importance-ratio ceiling for replayed rows (stored `old_lp` floors at `-ln(rho_max)`)."),
            ],
        },
        SectionDoc {
            name: "budget",
            intro: "Adaptive per-prompt rollout budgets: decode `n_probe` \
                    rollouts per prompt, then stream the released \
                    `(n - n_probe) x |groups|` slots to the groups whose \
                    observed reward bracket is still wide. The allocation \
                    is a pure function of observed probe history — never \
                    of worker-pool partition or refill order — so trained \
                    parameters are bit-invariant to pool and chunk sizes, \
                    and disabled budgeting is bit-identical to the \
                    fixed-n path (docs/DETERMINISM.md).",
            keys: vec![
                KeyDoc::new("enabled", "bool", bu.enabled.to_string(), "requires `algo.kind = \"pods\"` and `algo.adv_norm = \"after\"`", "Turn adaptive budgets on."),
                KeyDoc::new("n_probe", "int", bu.n_probe.to_string(), ">= 1; <= algo.n", "Probe quota: rollouts decoded per prompt before any reallocation."),
                KeyDoc::new("max_per_prompt", "int", bu.max_per_prompt.to_string(), ">= n_probe", "Hard per-prompt cap on total rollouts (probe + extras); may exceed `algo.n`."),
                KeyDoc::new("width_threshold", "float", bu.width_threshold.to_string(), "finite, >= 0", "Observed reward-bracket width (max - min over finished, unpruned probe rollouts) below which a group is saturated and receives no extras."),
            ],
        },
        SectionDoc {
            name: "hwsim",
            intro: "Calibrated accelerator cost model (defaults shaped to \
                    the paper's Fig. 1: 8xA100, Qwen2.5-3B) and the \
                    executor schedule.",
            keys: vec![
                KeyDoc::new("workers", "int", hw.workers.to_string(), ">= 1", "Simulated accelerators; also sizes the REAL rollout thread pool."),
                KeyDoc::new("tok_time_b1", "float", hw.tok_time_b1.to_string(), ">= 0", "Per-token decode time at rollout batch 1 on one device."),
                KeyDoc::new("tok_time_floor", "float", hw.tok_time_floor.to_string(), ">= 0", "Saturated per-token time (Fig. 1: ~21x below `tok_time_b1`)."),
                KeyDoc::new("batch_half", "float", hw.batch_half.to_string(), "> 0", "Batch size at which amortization is halfway to the floor."),
                KeyDoc::new("batch_saturation", "float", hw.batch_saturation.to_string(), ">= 1", "Rollout batch size beyond which throughput stops improving."),
                KeyDoc::new("mem_capacity_rollouts", "int", hw.mem_capacity_rollouts.to_string(), ">= 1", "Update-phase memory ceiling: max rollouts in one update micro-batch. Caps only the update; the rollout-side ceiling is `kv_pool_bytes`."),
                KeyDoc::new("kv_bytes_per_token", "int", hw.kv_bytes_per_token.to_string(), ">= 1", "Modeled KV-cache bytes per resident token (sizes the paged pool)."),
                KeyDoc::new("kv_page_tokens", "int", hw.kv_page_tokens.to_string(), ">= 1", "Tokens per KV page; slot allocations round up to whole pages."),
                KeyDoc::new("kv_pool_bytes", "int", hw.kv_pool_bytes.to_string(), "0 = unbounded", "Rollout-side memory ceiling: KV-pool capacity gating decode-slot admission (vLLM-style queuing when full)."),
                KeyDoc::new("microbatch_fixed", "float", hw.microbatch_fixed.to_string(), ">= 0", "Fixed per-micro-step overhead (kernel launches, activation reload)."),
                KeyDoc::new("microbatch_time", "float", hw.microbatch_time.to_string(), ">= 0", "fwd+bwd time for one full update micro-batch, scaled by fill."),
                KeyDoc::new("comm_base", "float", hw.comm_base.to_string(), ">= 0", "Legacy per-micro-step collective cost (the workers-based `update_time` model)."),
                KeyDoc::new("optimizer_time", "float", hw.optimizer_time.to_string(), ">= 0", "Optimizer apply (full-precision state streams) per update."),
                KeyDoc::new("lora_update_scale", "float", hw.lora_update_scale.to_string(), ">= 0", "LoRA discount: optimizer/communication touch only adapter weights."),
                KeyDoc::new("bytes_per_param", "float", hw.bytes_per_param.to_string(), ">= 0", "Bytes per gradient element on the wire (4 = f32, 2 = bf16)."),
                KeyDoc::new("interconnect_gbps", "float", hw.interconnect_gbps.to_string(), "> 0", "Interconnect bandwidth between update shards, gigabits/s."),
                KeyDoc::new("comm_latency", "float", hw.comm_latency.to_string(), ">= 0", "Per-hop ring all-reduce latency in seconds."),
                KeyDoc::new("sim_model_params", "float", hw.sim_model_params.to_string(), ">= 0", "Parameter count of the simulated policy; sizes the all-reduce volume."),
                KeyDoc::new("schedule", "string", format!("\"{}\"", hw.schedule.name()), "sync \\| pipelined", "Executor schedule: phases back-to-back, or generation of t+1 overlapping the update of t."),
            ],
        },
        SectionDoc {
            name: "faults",
            intro: "Deterministic fault injection and the shard retry \
                    policy (off by default). The schedule is a pure \
                    function of `(run.seed, iter, prompt_id, rollout_idx, \
                    attempt)` — faults are history, not partition, so the \
                    set of rows lost after retries is bit-identical across \
                    worker-pool sizes and shard layouts, and `enabled = \
                    false` (or all-zero rates) is bit-identical to a build \
                    without the section (docs/DETERMINISM.md).",
            keys: vec![
                KeyDoc::new("enabled", "bool", fa.enabled.to_string(), "—", "Master switch; `false` injects nothing and builds no fault plan."),
                KeyDoc::new("crash_rate", "float", fa.crash_rate.to_string(), "0.0..=1.0; the three fault rates sum to <= 1.0", "Worker-crash probability per row-attempt (the attempt's generation budget is charged as wasted work)."),
                KeyDoc::new("transient_rate", "float", fa.transient_rate.to_string(), "0.0..=1.0; the three fault rates sum to <= 1.0", "Transient call-failure probability per row-attempt (fails fast; charges only the retry backoff)."),
                KeyDoc::new("oom_rate", "float", fa.oom_rate.to_string(), "0.0..=1.0; the three fault rates sum to <= 1.0", "KV-admission OOM probability per row-attempt."),
                KeyDoc::new("straggler_rate", "float", fa.straggler_rate.to_string(), "0.0..=1.0", "Straggler probability per successful row (slow, not failed)."),
                KeyDoc::new("straggler_factor", "float", fa.straggler_factor.to_string(), ">= 1", "Slowdown multiplier charged to a straggler row's solo decode time."),
                KeyDoc::new("max_retries", "int", fa.max_retries.to_string(), "—", "Retry attempts per failed row before it is declared lost; each retry re-draws from the attempt-indexed stream."),
                KeyDoc::new("backoff_base", "float", fa.backoff_base.to_string(), ">= 0", "Simulated backoff charged before the first retry, in seconds."),
                KeyDoc::new("backoff_factor", "float", fa.backoff_factor.to_string(), ">= 1", "Exponential backoff growth per subsequent retry (`base * factor^attempt`)."),
                KeyDoc::new("min_group_survivors", "int", fa.min_group_survivors.to_string(), ">= 1", "Hard degradation floor: the iteration fails loudly when any prompt group retains fewer rollouts after losses."),
            ],
        },
        SectionDoc {
            name: "fleet",
            intro: "Disaggregated two-fleet execution: `R` elastic \
                    inference replicas feed the sharded update fleet \
                    through a staleness-K bounded ready-batch queue. The \
                    defaults reproduce the legacy single-box schedules \
                    bit-for-bit (`sync` is the K = 0 special case, \
                    `pipelined` is K = 1 with R = 1 — see \
                    docs/DETERMINISM.md); the `traffic_*` keys shape only \
                    the synthetic traffic the cost-model-only fleet \
                    simulator is driven with (`pods exp fleet`).",
            keys: vec![
                KeyDoc::new("inference_replicas", "int", fl.inference_replicas.to_string(), ">= 1", "Inference replicas `R` feeding the update fleet; generation batch `t` runs on replica `t mod R`."),
                KeyDoc::new("max_staleness", "int", "—", "sync: 0; pipelined: >= 1 (absent: derived from the schedule)", "Staleness bound `K`: a batch generated under `params(t)` may be consumed by `update(t')` only while `t' − t <= K`."),
                KeyDoc::new("queue_capacity", "int", fl.queue_capacity.to_string(), "0 = derived from the staleness bound", "Ready-batch queue capacity; admission blocks the producing replica while this many batches wait unconsumed."),
                KeyDoc::new("traffic_prompts", "int", fl.traffic_prompts.to_string(), ">= 1", "Backlog size of the synthetic traffic model (batch-granular simulation keeps millions of queued prompts cheap)."),
                KeyDoc::new("traffic_burst", "int", fl.traffic_burst.to_string(), ">= 1", "Prompts arriving per burst (arrivals are bursty, not smooth)."),
                KeyDoc::new("traffic_gap", "float", fl.traffic_gap.to_string(), "finite, >= 0", "Simulated seconds between bursts."),
                KeyDoc::new("traffic_prompt_len_min", "int", fl.traffic_prompt_len_min.to_string(), ">= 1; <= traffic_prompt_len_max", "Minimum sampled prompt length (tokens)."),
                KeyDoc::new("traffic_prompt_len_max", "int", fl.traffic_prompt_len_max.to_string(), "—", "Maximum sampled prompt length (tokens)."),
                KeyDoc::new("traffic_gen_len_min", "int", fl.traffic_gen_len_min.to_string(), ">= 1; <= traffic_gen_len_max", "Minimum sampled generated length (tokens)."),
                KeyDoc::new("traffic_gen_len_max", "int", fl.traffic_gen_len_max.to_string(), "—", "Maximum sampled generated length (tokens)."),
            ],
        },
        SectionDoc {
            name: "ckpt",
            intro: "Crash-consistent resume snapshots (off by default). \
                    Snapshots capture everything the next iteration reads \
                    (params, optimizer state, sim clock, replay store, CSV \
                    rows, in-flight pipelined prefetch) and are written \
                    atomically — temp file, FNV-1a checksum, rename — so a \
                    kill mid-write never corrupts the previous snapshot. \
                    `pods train --resume` continues bit-identically to the \
                    uninterrupted run (docs/DETERMINISM.md).",
            keys: vec![
                KeyDoc::new("every", "int", ck.every.to_string(), "0 = no snapshots", "Write a resume snapshot every this many completed iterations."),
                KeyDoc::new("path", "string", "—", "—", "Snapshot location; defaults to `<run.out_dir>/<run.name>.resume`."),
            ],
        },
        SectionDoc {
            name: "sft",
            intro: "Optional supervised warm-up before RL (the stand-in \
                    for starting from an instruct model). The section is \
                    skipped entirely when absent.",
            keys: vec![
                KeyDoc::new("steps", "int", "0", "full-parameter profiles only", "Teacher-forced SFT steps (0 = skip)."),
                KeyDoc::new("lr", "float", "0.002", "—", "SFT learning rate."),
                KeyDoc::new("log_every", "int", "50", "—", "Log the SFT loss every this many steps."),
                KeyDoc::new("pool", "int", "512", "0 = unbounded fresh problems", "Size of the cycled problem pool."),
            ],
        },
    ]
}

/// Render the full reference as markdown (the exact content of
/// `docs/CONFIG.md`).
pub fn render() -> String {
    let mut out = String::new();
    out.push_str(
        "<!-- GENERATED FILE - do not edit by hand.\n     \
         Regenerate with `pods config-docs`; CI fails when stale\n     \
         (`pods config-docs --check`). -->\n\n",
    );
    out.push_str("# Run-configuration reference\n\n");
    out.push_str(
        "A `RunConfig` TOML fully determines one training run. Sections \
         and keys below are everything the parser reads; unknown keys are \
         ignored, absent keys take the listed default, and every \
         validation rule fails with a descriptive error before any \
         training work starts — at parse time, or at trainer construction \
         for the rules that need the artifact profile (such as \
         `update.micro_batch <= B_u`). Shipped examples live under \
         `configs/`.\n",
    );
    for sec in sections() {
        out.push_str(&format!("\n## `[{}]`\n\n{}\n\n", sec.name, sec.intro));
        out.push_str("| key | type | default | validation | meaning |\n");
        out.push_str("|-----|------|---------|------------|---------|\n");
        for k in &sec.keys {
            out.push_str(&format!(
                "| `{}` | {} | `{}` | {} | {} |\n",
                k.key, k.typ, k.default, k.validation, k.doc
            ));
        }
    }
    out
}

/// Fail when `path` does not hold exactly [`render`]'s output — the CI
/// staleness gate for `docs/CONFIG.md`.
pub fn check(path: &Path) -> Result<()> {
    let want = render();
    let got = std::fs::read_to_string(path).map_err(|e| {
        anyhow!(
            "cannot read {}: {e} — generate it with `pods config-docs`",
            path.display()
        )
    })?;
    if got == want {
        return Ok(());
    }
    let diff_line = want
        .lines()
        .zip(got.lines())
        .position(|(w, g)| w != g)
        .map(|i| i + 1)
        .unwrap_or_else(|| want.lines().count().min(got.lines().count()) + 1);
    Err(anyhow!(
        "{} is stale: first difference at line {diff_line} (committed file vs \
         the schema in the config structs) — regenerate it with `pods config-docs` \
         and commit the result",
        path.display()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    const MINIMAL: &str = r#"
        [run]
        name = "t"
        profile = "base"
        task = "arith"
        iterations = 1

        [algo]
        kind = "grpo"
        n = 4
        lr = 1e-4
    "#;

    fn key<'a>(secs: &'a [SectionDoc], sec: &str, key: &str) -> &'a KeyDoc {
        secs.iter()
            .find(|s| s.name == sec)
            .unwrap_or_else(|| panic!("section {sec} undocumented"))
            .keys
            .iter()
            .find(|k| k.key == key)
            .unwrap_or_else(|| panic!("key [{sec}] {key} undocumented"))
    }

    /// Every defaulted key's documented default matches what the parser
    /// actually produces for a config that omits it — the anti-drift core
    /// of the generated reference.
    #[test]
    fn documented_defaults_match_parsed_defaults() {
        let cfg = RunConfig::from_str_validated(MINIMAL).unwrap();
        let secs = sections();
        // [update]
        assert_eq!(key(&secs, "update", "shards").default, cfg.update.shards.to_string());
        assert_eq!(key(&secs, "update", "micro_batch").default, cfg.update.micro_batch.to_string());
        // [rollout]
        assert_eq!(
            key(&secs, "rollout", "decode_chunk").default,
            cfg.rollout.decode_chunk.to_string()
        );
        assert_eq!(
            key(&secs, "rollout", "refill").default,
            format!("\"{}\"", cfg.rollout.refill.name())
        );
        assert_eq!(
            key(&secs, "rollout", "online_prune").default,
            cfg.rollout.online_prune.to_string()
        );
        assert_eq!(
            key(&secs, "rollout", "share_prompt_kv").default,
            cfg.rollout.share_prompt_kv.to_string()
        );
        // [hwsim] — every key present and matching the parsed default
        let hw = &cfg.hwsim;
        for (k, v) in [
            ("workers", hw.workers.to_string()),
            ("tok_time_b1", hw.tok_time_b1.to_string()),
            ("tok_time_floor", hw.tok_time_floor.to_string()),
            ("batch_half", hw.batch_half.to_string()),
            ("batch_saturation", hw.batch_saturation.to_string()),
            ("mem_capacity_rollouts", hw.mem_capacity_rollouts.to_string()),
            ("kv_bytes_per_token", hw.kv_bytes_per_token.to_string()),
            ("kv_page_tokens", hw.kv_page_tokens.to_string()),
            ("kv_pool_bytes", hw.kv_pool_bytes.to_string()),
            ("microbatch_fixed", hw.microbatch_fixed.to_string()),
            ("microbatch_time", hw.microbatch_time.to_string()),
            ("comm_base", hw.comm_base.to_string()),
            ("optimizer_time", hw.optimizer_time.to_string()),
            ("lora_update_scale", hw.lora_update_scale.to_string()),
            ("bytes_per_param", hw.bytes_per_param.to_string()),
            ("interconnect_gbps", hw.interconnect_gbps.to_string()),
            ("comm_latency", hw.comm_latency.to_string()),
            ("sim_model_params", hw.sim_model_params.to_string()),
            ("schedule", format!("\"{}\"", hw.schedule.name())),
        ] {
            assert_eq!(key(&secs, "hwsim", k).default, v, "hwsim.{k} default drifted");
        }
        // [replay] — defaults of the off-by-default section
        let rp = &cfg.replay;
        assert_eq!(key(&secs, "replay", "enabled").default, rp.enabled.to_string());
        assert_eq!(key(&secs, "replay", "mix_fraction").default, rp.mix_fraction.to_string());
        assert_eq!(key(&secs, "replay", "staleness").default, rp.staleness.to_string());
        assert_eq!(
            key(&secs, "replay", "capacity_per_prompt").default,
            rp.capacity_per_prompt.to_string()
        );
        assert_eq!(key(&secs, "replay", "rho_max").default, rp.rho_max.to_string());
        // [budget] — defaults of the off-by-default section
        let bu = &cfg.budget;
        assert_eq!(key(&secs, "budget", "enabled").default, bu.enabled.to_string());
        assert_eq!(key(&secs, "budget", "n_probe").default, bu.n_probe.to_string());
        assert_eq!(
            key(&secs, "budget", "max_per_prompt").default,
            bu.max_per_prompt.to_string()
        );
        assert_eq!(
            key(&secs, "budget", "width_threshold").default,
            bu.width_threshold.to_string()
        );
        // [faults] — defaults of the off-by-default section
        let fa = &cfg.faults;
        assert_eq!(key(&secs, "faults", "enabled").default, fa.enabled.to_string());
        assert_eq!(key(&secs, "faults", "crash_rate").default, fa.crash_rate.to_string());
        assert_eq!(
            key(&secs, "faults", "transient_rate").default,
            fa.transient_rate.to_string()
        );
        assert_eq!(key(&secs, "faults", "oom_rate").default, fa.oom_rate.to_string());
        assert_eq!(
            key(&secs, "faults", "straggler_rate").default,
            fa.straggler_rate.to_string()
        );
        assert_eq!(
            key(&secs, "faults", "straggler_factor").default,
            fa.straggler_factor.to_string()
        );
        assert_eq!(key(&secs, "faults", "max_retries").default, fa.max_retries.to_string());
        assert_eq!(key(&secs, "faults", "backoff_base").default, fa.backoff_base.to_string());
        assert_eq!(
            key(&secs, "faults", "backoff_factor").default,
            fa.backoff_factor.to_string()
        );
        assert_eq!(
            key(&secs, "faults", "min_group_survivors").default,
            fa.min_group_survivors.to_string()
        );
        // [fleet] — defaults reproduce the legacy single-box schedules
        let fl = &cfg.fleet;
        assert_eq!(
            key(&secs, "fleet", "inference_replicas").default,
            fl.inference_replicas.to_string()
        );
        assert_eq!(key(&secs, "fleet", "max_staleness").default, "—");
        assert_eq!(key(&secs, "fleet", "queue_capacity").default, fl.queue_capacity.to_string());
        assert_eq!(
            key(&secs, "fleet", "traffic_prompts").default,
            fl.traffic_prompts.to_string()
        );
        assert_eq!(key(&secs, "fleet", "traffic_burst").default, fl.traffic_burst.to_string());
        assert_eq!(key(&secs, "fleet", "traffic_gap").default, fl.traffic_gap.to_string());
        assert_eq!(
            key(&secs, "fleet", "traffic_prompt_len_min").default,
            fl.traffic_prompt_len_min.to_string()
        );
        assert_eq!(
            key(&secs, "fleet", "traffic_prompt_len_max").default,
            fl.traffic_prompt_len_max.to_string()
        );
        assert_eq!(
            key(&secs, "fleet", "traffic_gen_len_min").default,
            fl.traffic_gen_len_min.to_string()
        );
        assert_eq!(
            key(&secs, "fleet", "traffic_gen_len_max").default,
            fl.traffic_gen_len_max.to_string()
        );
        // [ckpt]
        assert_eq!(key(&secs, "ckpt", "every").default, cfg.ckpt.every.to_string());
        // [run]/[algo] parse-fallback defaults
        assert_eq!(key(&secs, "run", "seed").default, cfg.run.seed.to_string());
        assert_eq!(
            key(&secs, "run", "prompts_per_iter").default,
            cfg.run.prompts_per_iter.to_string()
        );
        assert_eq!(key(&secs, "run", "eval_every").default, cfg.run.eval_every.to_string());
        assert_eq!(key(&secs, "run", "eval_problems").default, cfg.run.eval_problems.to_string());
        assert_eq!(key(&secs, "run", "out_dir").default, format!("\"{}\"", cfg.run.out_dir));
        assert_eq!(key(&secs, "algo", "rule").default, format!("\"{}\"", cfg.algo.rule));
        assert_eq!(key(&secs, "algo", "adv_norm").default, format!("\"{}\"", cfg.algo.adv_norm));
        assert_eq!(key(&secs, "algo", "kl_coef").default, cfg.algo.kl_coef.to_string());
        assert_eq!(key(&secs, "algo", "temperature").default, cfg.algo.temperature.to_string());
        // [sft] parse-fallback defaults
        let sft_cfg = format!("{MINIMAL}\n[sft]\n");
        let sft = RunConfig::from_str_validated(&sft_cfg).unwrap().sft.unwrap();
        assert_eq!(key(&secs, "sft", "steps").default, sft.steps.to_string());
        assert_eq!(key(&secs, "sft", "lr").default, sft.lr.to_string());
        assert_eq!(key(&secs, "sft", "log_every").default, sft.log_every.to_string());
        assert_eq!(key(&secs, "sft", "pool").default, sft.pool.to_string());
    }

    /// The rendered document carries every section and a staleness
    /// banner, and `check` accepts exactly the rendered bytes.
    #[test]
    fn render_and_check_roundtrip() {
        let text = render();
        for sec in [
            "[run]", "[algo]", "[rollout]", "[update]", "[replay]", "[budget]", "[hwsim]",
            "[faults]", "[fleet]", "[ckpt]", "[sft]",
        ] {
            assert!(text.contains(sec), "missing section {sec}");
        }
        assert!(text.starts_with("<!-- GENERATED FILE"));
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("CONFIG.md");
        // absent file: descriptive error
        let err = check(&path).unwrap_err().to_string();
        assert!(err.contains("config-docs"), "undescriptive: {err}");
        // fresh file: passes
        std::fs::write(&path, &text).unwrap();
        check(&path).unwrap();
        // stale file: fails pointing at the first differing line
        std::fs::write(&path, text.replace("# Run-configuration", "# Stale")).unwrap();
        let err = check(&path).unwrap_err().to_string();
        assert!(err.contains("stale"), "undescriptive: {err}");
    }
}
