//! TOML run-configuration system — Table 1 / Table 2 as shipped configs.
//!
//! A [`RunConfig`] fully determines one training run: artifact profile,
//! task, algorithm schedule (GRPO / GRPO-GA / GRPO-PODS), rollout-selection
//! pipeline, (n, m), optimizer hyperparameters, hwsim calibration and SFT
//! warm-up. `configs/setting_{a..f}.toml` mirror the paper's Table 1/2
//! settings at reproduction scale. Parsed with the std-only TOML-subset
//! parser in `util::toml`.
//!
//! `algo.rule` is a selector pipeline spec (see
//! [`crate::coordinator::select::spec`]); the four legacy rule names are
//! valid one-stage specs, so existing TOML files keep working unchanged.

pub mod docs;

use crate::coordinator::advantage::NormMode;
use crate::coordinator::select::Pipeline;
use crate::hwsim::HwModel;
use crate::rollout::RefillMode;
use crate::tasks::TaskKind;
use crate::util::toml::{parse as toml_parse, SectionView};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// `[run]` — run identity, scale and I/O locations.
#[derive(Debug, Clone)]
pub struct RunSection {
    /// Run name; prefixes the output CSV files.
    pub name: String,
    /// Artifact profile under `artifacts/` (micro | base | lora | big).
    pub profile: String,
    /// Task family: arith | poly | mcq.
    pub task: String,
    /// Master RNG seed every per-row / per-group stream derives from.
    pub seed: u64,
    /// RL training iterations (0 = SFT-only checkpoint-producing run).
    pub iterations: usize,
    /// Prompts (groups) per training iteration.
    pub prompts_per_iter: usize,
    /// Evaluate every this many iterations.
    pub eval_every: usize,
    /// Problems per evaluation snapshot.
    pub eval_problems: usize,
    /// Where CSVs/checkpoints go (default `results/`).
    pub out_dir: String,
    /// Pre-trained base checkpoint (required for LoRA profiles; produced by
    /// the SFT phase of a full-parameter run).
    pub base_checkpoint: Option<String>,
    /// Save a checkpoint at the end of the run.
    pub save_checkpoint: Option<String>,
}

/// Which training schedule (Fig. 2's three rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgoKind {
    /// Vanilla GRPO: generate n = m, train on all.
    Grpo,
    /// GRPO-GA: generate n, train on all n via gradient accumulation.
    GrpoGa,
    /// GRPO-PODS: generate n, down-sample to m, train on m.
    GrpoPods,
}

impl AlgoKind {
    /// Parse a `[algo] kind` value (`grpo` | `ga` | `pods`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "grpo" => Ok(Self::Grpo),
            "ga" | "grpo-ga" => Ok(Self::GrpoGa),
            "pods" | "grpo-pods" => Ok(Self::GrpoPods),
            other => Err(anyhow!("unknown algo {other:?} (grpo|ga|pods)")),
        }
    }

    /// Canonical name used in logs and CSVs.
    pub fn name(self) -> &'static str {
        match self {
            Self::Grpo => "grpo",
            Self::GrpoGa => "grpo-ga",
            Self::GrpoPods => "grpo-pods",
        }
    }
}

/// `[algo]` — schedule kind, (n, m), selection spec and optimizer knobs.
#[derive(Debug, Clone)]
pub struct AlgoSection {
    /// grpo | ga | pods
    pub kind: String,
    /// Rollouts generated per prompt per iteration.
    pub n: usize,
    /// Update size after down-sampling (ignored for grpo/ga: m = n).
    pub m: Option<usize>,
    /// Selector pipeline spec, e.g. `"max_variance"` or
    /// `"drop_zero_variance | prune(max_tokens=4096) | percentile"`.
    pub rule: String,
    /// Advantage normalization mode: `"after"` (§A.3) or `"before"`.
    pub adv_norm: String,
    /// KL-to-reference coefficient (0 disables the reference policy).
    pub kl_coef: f64,
    /// AdamW learning rate for the policy update.
    pub lr: f64,
    /// Sampling temperature for rollout generation.
    pub temperature: f64,
}

/// `[rollout]` — the chunked early-exit decode driver.
#[derive(Debug, Clone)]
pub struct RolloutSection {
    /// Tokens decoded per `decode_chunk` call. Must match a lowered
    /// program (`meta.json` `decode_chunks`; profiles ship {1, 4, 16, G}).
    /// Smaller chunks exit earlier after EOS but pay more call overhead.
    pub decode_chunk: usize,
    /// Slot-refill policy between chunks: `"continuous"` (default) admits
    /// queued rows into freed slots; `"batch"` drains the whole batch
    /// first (the legacy call-shaped schedule, kept as a comparison arm).
    pub refill: RefillMode,
    /// Online selection-aware pruning: abort rollouts at chunk boundaries
    /// once they provably cannot survive the selection pipeline (doom-only
    /// verdicts — see `docs/DETERMINISM.md`). Only active for PODS runs
    /// (`algo.m` set) with `adv_norm = "after"`; pipelines without a
    /// bounded stage (e.g. no `prune(max_tokens=…)` / `max_variance`)
    /// never abort anything.
    pub online_prune: bool,
    /// Group-shared prompt KV: prefill each prompt group **once** and
    /// admit sibling rows by replicating the group's cached prompt state
    /// on device (`prefill_shared`/`admit_share`). Token streams are
    /// bit-identical either way (pinned by the `kv_golden` suite); only
    /// the engine-call mix and the wall-clock change. Opt-in.
    pub share_prompt_kv: bool,
}

impl Default for RolloutSection {
    fn default() -> Self {
        Self {
            decode_chunk: 16,
            refill: RefillMode::Continuous,
            online_prune: false,
            share_prompt_kv: false,
        }
    }
}

impl RolloutSection {
    fn from_section(sec: &SectionView) -> Result<Self> {
        let d = Self::default();
        let r = Self {
            decode_chunk: sec.usize_or("decode_chunk", d.decode_chunk)?,
            refill: RefillMode::parse(&sec.str_or("refill", d.refill.name())?)?,
            online_prune: sec.bool_or("online_prune", d.online_prune)?,
            share_prompt_kv: sec.bool_or("share_prompt_kv", d.share_prompt_kv)?,
        };
        r.validate()?;
        Ok(r)
    }

    /// Reject degenerate chunk sizes at parse time.
    pub fn validate(&self) -> Result<()> {
        if self.decode_chunk == 0 {
            return Err(anyhow!(
                "rollout.decode_chunk must be >= 1 (tokens decoded per chunk call; \
                 the artifact set lowers {{1, 4, 16, G}})"
            ));
        }
        Ok(())
    }
}

/// `[update]` — the sharded data-parallel policy-update engine.
///
/// The update phase runs on a simulated data-parallel topology: the kept
/// rollouts are packed into micro-batches of `micro_batch` rows (padded
/// into the profile's fixed `B_u`-shaped `grad` program) and the
/// micro-batch sequence is split into `shards` contiguous device shards.
/// Gradients reduce in **canonical global micro-batch order** regardless
/// of topology, so trained parameters are bit-identical for any shard
/// count (see `docs/DETERMINISM.md`); shards and micro-batching feed the
/// hwsim cost model (per-shard compute, ring all-reduce, peak memory).
#[derive(Debug, Clone)]
pub struct UpdateSection {
    /// Simulated data-parallel device shards the update batch is split
    /// over. Compute parallelizes across shards; each optimizer step pays
    /// one ring all-reduce over the gradient bytes.
    pub shards: usize,
    /// Rows per update micro-batch (DeepSpeed-style micro-batch size).
    /// `0` (default) uses the profile's full update batch `B_u`; values
    /// above `B_u` are rejected when the engine runs (the AOT `grad`
    /// program has a fixed shape). The hwsim memory ceiling
    /// (`hwsim.mem_capacity_rollouts`) still caps the effective size.
    pub micro_batch: usize,
}

impl Default for UpdateSection {
    fn default() -> Self {
        Self { shards: 1, micro_batch: 0 }
    }
}

impl UpdateSection {
    fn from_section(sec: &SectionView) -> Result<Self> {
        let d = Self::default();
        let u = Self {
            shards: sec.usize_or("shards", d.shards)?,
            micro_batch: sec.usize_or("micro_batch", d.micro_batch)?,
        };
        u.validate()?;
        Ok(u)
    }

    /// Reject degenerate topologies at parse time.
    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            return Err(anyhow!(
                "update.shards must be >= 1 (the number of simulated data-parallel \
                 devices the update batch is split over; use shards = 1 for the \
                 single-device settings)"
            ));
        }
        Ok(())
    }

    /// Effective rows per micro-batch for a profile whose AOT `grad`
    /// program is shaped for `bu` rows: `micro_batch = 0` means "use the
    /// full `B_u`", anything larger than `B_u` cannot be packed.
    pub fn rows_per_call(&self, bu: usize) -> Result<usize> {
        match self.micro_batch {
            0 => Ok(bu),
            mb if mb > bu => Err(anyhow!(
                "update.micro_batch = {mb} exceeds the profile's update batch B_u = {bu} \
                 (the AOT grad program has a fixed shape; choose micro_batch in 1..={bu} \
                 or 0 for the full batch)"
            )),
            mb => Ok(mb),
        }
    }
}

/// `[replay]` — cross-iteration rollout replay (the `coordinator::replay`
/// subsystem).
///
/// When enabled, rollouts the selection pipeline drops are admitted into a
/// [`crate::coordinator::replay::ReplayStore`] and mixed back into later
/// update batches with their stored behaviour log-probs, so the GRPO ratio
/// term applies the importance-sampling correction. Replayed rows charge
/// zero inference time (they were decoded in their admission iteration)
/// but full update cost. Off by default; with the store empty or the
/// section disabled the training path is bit-identical to no-replay runs.
#[derive(Debug, Clone)]
pub struct ReplaySection {
    /// Master switch. `false` (default) keeps the training path
    /// bit-identical to a build without the replay subsystem.
    pub enabled: bool,
    /// Replay quota per update as a fraction of the fresh update size:
    /// up to `floor(mix_fraction * fresh_rows)` stored rows are appended
    /// to each update batch.
    pub mix_fraction: f64,
    /// Staleness bound in iterations: a row admitted at iteration `s` is
    /// eligible at iterations `s+1 ..= s+staleness` and evicted after.
    pub staleness: usize,
    /// Stored rows kept per prompt; excess admissions evict
    /// deterministically (staleness-then-score, ties by stable row id).
    pub capacity_per_prompt: usize,
    /// Truncated importance-sampling clip: stored per-token behaviour
    /// log-probs are floored at `-ln(rho_max)`, bounding every replayed
    /// token's ratio `exp(lp - old_lp)` by `rho_max` (log-probs are <= 0).
    pub rho_max: f64,
}

impl Default for ReplaySection {
    fn default() -> Self {
        Self {
            enabled: false,
            mix_fraction: 0.25,
            staleness: 2,
            capacity_per_prompt: 4,
            rho_max: 2.0,
        }
    }
}

impl ReplaySection {
    fn from_section(sec: &SectionView) -> Result<Self> {
        let d = Self::default();
        let r = Self {
            enabled: sec.bool_or("enabled", d.enabled)?,
            mix_fraction: sec.f64_or("mix_fraction", d.mix_fraction)?,
            staleness: sec.usize_or("staleness", d.staleness)?,
            capacity_per_prompt: sec.usize_or("capacity_per_prompt", d.capacity_per_prompt)?,
            rho_max: sec.f64_or("rho_max", d.rho_max)?,
        };
        r.validate()?;
        Ok(r)
    }

    /// Reject degenerate replay policies at parse time.
    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.mix_fraction) {
            return Err(anyhow!(
                "replay.mix_fraction must be in 0.0..=1.0 (replayed rows per update \
                 as a fraction of the fresh update size; got {})",
                self.mix_fraction
            ));
        }
        if self.staleness == 0 {
            return Err(anyhow!(
                "replay.staleness must be >= 1 (iterations a stored row stays \
                 eligible; replay is cross-iteration, so 0 would admit nothing)"
            ));
        }
        if self.capacity_per_prompt == 0 {
            return Err(anyhow!(
                "replay.capacity_per_prompt must be >= 1 (stored rows kept per prompt)"
            ));
        }
        if self.rho_max < 1.0 {
            return Err(anyhow!(
                "replay.rho_max must be >= 1.0 (truncated importance-sampling clip; \
                 values below 1 would truncate on-policy rows with ratio exactly 1)"
            ));
        }
        Ok(())
    }
}

/// `[budget]` — adaptive per-prompt rollout budgets (the
/// `coordinator::scheduler::BudgetAllocator`).
///
/// When enabled, each iteration decodes only a probe quota of `n_probe`
/// rollouts per prompt first, then redistributes the remaining
/// `(n − n_probe) × |groups|` slots to the groups whose observed reward
/// bracket is still wider than `width_threshold` — saturated groups
/// release their budget to high-variance ones. The allocation sequence is
/// a pure function of observed probe history (never of worker-pool
/// partition or refill order — see docs/DETERMINISM.md), so trained
/// parameters stay bit-invariant to pool and chunk sizes. Off by default;
/// disabled budget is bit-identical to the fixed-`n` path.
#[derive(Debug, Clone)]
pub struct BudgetSection {
    /// Master switch. `false` (default) keeps the fixed-`n` decode
    /// schedule bit-identical to a build without the allocator.
    pub enabled: bool,
    /// Probe quota: rollouts decoded per prompt before any reallocation.
    pub n_probe: usize,
    /// Hard per-prompt cap on total rollouts (probe + extras). May exceed
    /// `algo.n`: a high-variance group can absorb budget that saturated
    /// groups released.
    pub max_per_prompt: usize,
    /// A group whose observed reward bracket (max − min over finished,
    /// unpruned probe rollouts) is below this width is **saturated** and
    /// receives no extra rollouts.
    pub width_threshold: f64,
}

impl Default for BudgetSection {
    fn default() -> Self {
        Self { enabled: false, n_probe: 8, max_per_prompt: 128, width_threshold: 0.25 }
    }
}

impl BudgetSection {
    fn from_section(sec: &SectionView) -> Result<Self> {
        let d = Self::default();
        let b = Self {
            enabled: sec.bool_or("enabled", d.enabled)?,
            n_probe: sec.usize_or("n_probe", d.n_probe)?,
            max_per_prompt: sec.usize_or("max_per_prompt", d.max_per_prompt)?,
            width_threshold: sec.f64_or("width_threshold", d.width_threshold)?,
        };
        b.validate()?;
        Ok(b)
    }

    /// Reject degenerate budget policies at parse time.
    pub fn validate(&self) -> Result<()> {
        if self.n_probe == 0 {
            return Err(anyhow!(
                "budget.n_probe must be >= 1 (rollouts decoded per prompt before \
                 the allocator redistributes anything; the bracket of a group \
                 with zero observations is unknowable)"
            ));
        }
        if self.max_per_prompt < self.n_probe {
            return Err(anyhow!(
                "budget.max_per_prompt must be >= budget.n_probe (got max_per_prompt={}, \
                 n_probe={}): the probe quota itself would already violate the cap",
                self.max_per_prompt,
                self.n_probe
            ));
        }
        if !self.width_threshold.is_finite() || self.width_threshold < 0.0 {
            return Err(anyhow!(
                "budget.width_threshold must be a finite value >= 0.0 (observed \
                 reward-bracket width below which a group is saturated; got {})",
                self.width_threshold
            ));
        }
        Ok(())
    }
}

/// `[ckpt]` — crash-consistent checkpoint/resume (the `coordinator::ckpt`
/// subsystem).
///
/// When `every > 0` the trainer snapshots its full mutable state (params,
/// optimizer moments, RNG cursors, replay store, metrics rows, sim clock)
/// every `every` iterations via atomic write-temp-then-rename with a
/// checksum, and `pods train --resume` continues bit-identically to an
/// uninterrupted run (see docs/DETERMINISM.md). Off by default.
#[derive(Debug, Clone)]
pub struct CkptSection {
    /// Snapshot the resume state every this many iterations (0 = never).
    pub every: usize,
    /// Resume-state file path; default `<out_dir>/<run.name>.resume`.
    pub path: Option<String>,
}

impl Default for CkptSection {
    fn default() -> Self {
        Self { every: 0, path: None }
    }
}

impl CkptSection {
    fn from_section(sec: &SectionView) -> Result<Self> {
        let d = Self::default();
        Ok(Self {
            every: sec.usize_or("every", d.every)?,
            path: sec.opt_str("path")?,
        })
    }

    /// The resume-state path for a run (explicit `ckpt.path` or the
    /// default `<out_dir>/<name>.resume`).
    pub fn resume_path(&self, out_dir: &str, name: &str) -> String {
        self.path.clone().unwrap_or_else(|| format!("{out_dir}/{name}.resume"))
    }
}

/// `[sft]` — optional supervised warm-up before RL.
#[derive(Debug, Clone, Default)]
pub struct SftSection {
    /// Teacher-forced SFT steps (0 = skip the warm-up).
    pub steps: usize,
    /// SFT learning rate.
    pub lr: f64,
    /// Log the SFT loss every this many steps.
    pub log_every: usize,
    /// Size of the cycled problem pool (0 = unbounded fresh problems).
    pub pool: usize,
}

/// One fully-validated run configuration (every `[section]` of the TOML).
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// `[run]` — identity, scale, I/O.
    pub run: RunSection,
    /// `[algo]` — schedule, (n, m), selection spec, optimizer knobs.
    pub algo: AlgoSection,
    /// `[hwsim]` — accelerator cost model + executor schedule.
    pub hwsim: HwModel,
    /// `[rollout]` — chunked early-exit decode driver.
    pub rollout: RolloutSection,
    /// `[update]` — sharded data-parallel update engine.
    pub update: UpdateSection,
    /// `[replay]` — cross-iteration rollout replay (off by default).
    pub replay: ReplaySection,
    /// `[budget]` — adaptive per-prompt rollout budgets (off by default).
    pub budget: BudgetSection,
    /// `[faults]` — deterministic fault injection (off by default).
    pub faults: crate::hwsim::FaultSection,
    /// `[fleet]` — disaggregated two-fleet execution + traffic model
    /// (defaults reproduce the legacy single-box schedules).
    pub fleet: crate::hwsim::FleetSection,
    /// `[ckpt]` — crash-consistent checkpoint/resume (off by default).
    pub ckpt: CkptSection,
    /// `[sft]` — optional supervised warm-up.
    pub sft: Option<SftSection>,
}

impl RunConfig {
    /// Read and validate a TOML run config from disk.
    pub fn from_path(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Self::from_str_validated(&text).with_context(|| format!("parsing {path:?}"))
    }

    /// Parse and validate a TOML run config from a string.
    pub fn from_str_validated(text: &str) -> Result<Self> {
        let doc = toml_parse(text)?;
        let run = SectionView::new(&doc, "run");
        let algo = SectionView::new(&doc, "algo");
        let hw = SectionView::new(&doc, "hwsim");
        let rollout = SectionView::new(&doc, "rollout");
        let update = SectionView::new(&doc, "update");
        let replay = SectionView::new(&doc, "replay");
        let budget = SectionView::new(&doc, "budget");
        let faults = SectionView::new(&doc, "faults");
        let fleet = SectionView::new(&doc, "fleet");
        let ckpt = SectionView::new(&doc, "ckpt");
        let sft = SectionView::new(&doc, "sft");

        let cfg = RunConfig {
            run: RunSection {
                name: run.required("name")?.as_str()?.to_string(),
                profile: run.required("profile")?.as_str()?.to_string(),
                task: run.required("task")?.as_str()?.to_string(),
                seed: run.u64_or("seed", 0)?,
                iterations: run.required("iterations")?.as_usize()?,
                prompts_per_iter: run.usize_or("prompts_per_iter", 2)?,
                eval_every: run.usize_or("eval_every", 10)?,
                eval_problems: run.usize_or("eval_problems", 64)?,
                out_dir: run.str_or("out_dir", "results")?,
                base_checkpoint: run.opt_str("base_checkpoint")?,
                save_checkpoint: run.opt_str("save_checkpoint")?,
            },
            algo: AlgoSection {
                kind: algo.required("kind")?.as_str()?.to_string(),
                n: algo.required("n")?.as_usize()?,
                m: match algo.get("m") {
                    Some(v) => Some(v.as_usize()?),
                    None => None,
                },
                rule: algo.str_or("rule", "max_variance")?,
                adv_norm: algo.str_or("adv_norm", "after")?,
                kl_coef: algo.f64_or("kl_coef", 0.0)?,
                lr: algo.required("lr")?.as_f64()?,
                temperature: algo.f64_or("temperature", 1.0)?,
            },
            hwsim: HwModel::from_section(&hw)?,
            rollout: RolloutSection::from_section(&rollout)?,
            update: UpdateSection::from_section(&update)?,
            replay: ReplaySection::from_section(&replay)?,
            budget: BudgetSection::from_section(&budget)?,
            faults: crate::hwsim::FaultSection::from_section(&faults)?,
            fleet: crate::hwsim::FleetSection::from_section(&fleet)?,
            ckpt: CkptSection::from_section(&ckpt)?,
            sft: if sft.sec.is_some() {
                Some(SftSection {
                    steps: sft.usize_or("steps", 0)?,
                    lr: sft.f64_or("lr", 2e-3)?,
                    log_every: sft.usize_or("log_every", 50)?,
                    pool: sft.usize_or("pool", 512)?,
                })
            } else {
                None
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The parsed `[algo] kind` (infallible on a validated config).
    pub fn algo_kind(&self) -> AlgoKind {
        AlgoKind::parse(&self.algo.kind).expect("validated")
    }

    /// Build the selection pipeline from the `algo.rule` spec (resolved
    /// against the built-in registry; validated at parse time).
    pub fn selector(&self) -> Pipeline {
        Pipeline::parse_default(&self.algo.rule).expect("validated")
    }

    /// The parsed `[algo] adv_norm` mode (infallible on a validated config).
    pub fn norm_mode(&self) -> NormMode {
        NormMode::parse(&self.algo.adv_norm).expect("validated")
    }

    /// The parsed `[run] task` family (infallible on a validated config).
    pub fn task_kind(&self) -> TaskKind {
        TaskKind::parse(&self.run.task).expect("validated")
    }

    /// Effective update size per prompt group.
    pub fn effective_m(&self) -> usize {
        match self.algo_kind() {
            AlgoKind::Grpo | AlgoKind::GrpoGa => self.algo.n,
            AlgoKind::GrpoPods => self.algo.m.unwrap_or(self.algo.n),
        }
    }

    /// Full cross-section validation — also applied to programmatically
    /// built configs that bypassed `from_str_validated`.
    pub fn validate(&self) -> Result<()> {
        let kind = AlgoKind::parse(&self.algo.kind)?;
        Pipeline::parse_default(&self.algo.rule)?;
        NormMode::parse(&self.algo.adv_norm)?;
        TaskKind::parse(&self.run.task)?;
        if self.algo.n == 0 {
            return Err(anyhow!(
                "algo.n must be >= 1 (rollouts generated per prompt; \
                 the paper's settings use n in 16..=64)"
            ));
        }
        if let Some(m) = self.algo.m {
            if m == 0 || m > self.algo.n {
                return Err(anyhow!("algo.m must be in 1..=n (got m={m}, n={})", self.algo.n));
            }
        }
        if kind == AlgoKind::GrpoPods && self.algo.m.is_none() {
            return Err(anyhow!("algo.kind=pods requires algo.m"));
        }
        if self.algo.lr <= 0.0 {
            return Err(anyhow!("algo.lr must be positive"));
        }
        // iterations == 0 is allowed: SFT-only runs that just produce a
        // base checkpoint (exp::ensure_base_checkpoint).
        if self.run.prompts_per_iter == 0 {
            return Err(anyhow!("run.prompts_per_iter must be positive"));
        }
        // the full [hwsim]/[rollout]/[update] validation (workers >= 1,
        // positive cost-model times, schedule, chunk size, shards >= 1) —
        // also applied to programmatically-built configs that bypass
        // from_section
        self.hwsim.validate()?;
        self.rollout.validate()?;
        self.update.validate()?;
        self.replay.validate()?;
        self.budget.validate()?;
        self.faults.validate()?;
        self.fleet.validate()?;
        // an explicit staleness bound must agree with the executor
        // schedule — the legacy schedules are its K=0 / K>=1 special
        // cases, and a contradictory pair would silently change which
        // schedule the goldens pinned
        if let Some(k) = self.fleet.max_staleness {
            match self.hwsim.schedule {
                crate::hwsim::Schedule::Sync if k != 0 => {
                    return Err(anyhow!(
                        "fleet.max_staleness = {k} contradicts hwsim.schedule = \"sync\": \
                         the sync schedule is the K = 0 special case (every batch is \
                         consumed under the params it was generated with); use \
                         schedule = \"pipelined\" for K >= 1"
                    ));
                }
                crate::hwsim::Schedule::Pipelined if k == 0 => {
                    return Err(anyhow!(
                        "fleet.max_staleness = 0 contradicts hwsim.schedule = \
                         \"pipelined\": the pipelined schedule overlaps generation \
                         with the previous update, which requires K >= 1; use \
                         schedule = \"sync\" for K = 0"
                    ));
                }
                _ => {}
            }
        }
        // replayed rows reuse the advantage convention of the selected
        // subset ("after" statistics); "before" normalizes over the full
        // generation group, which no longer exists at replay time
        if self.replay.enabled && self.norm_mode() == NormMode::Before {
            return Err(anyhow!(
                "replay.enabled requires algo.adv_norm = \"after\": replayed rows \
                 are normalized against their admission iteration's kept-subset \
                 statistics, which only matches the \"after\" convention (see \
                 docs/DETERMINISM.md)"
            ));
        }
        // the allocator only pays off when a selection pipeline discards
        // rows (PODS), and variable per-group n only composes with the
        // "after" normalization convention: "before" normalizes over the
        // whole generated group, so group size itself becomes a training
        // signal and the disabled-equals-fixed-n contract would not hold
        if self.budget.enabled {
            if kind != AlgoKind::GrpoPods {
                return Err(anyhow!(
                    "budget.enabled requires algo.kind = \"pods\": adaptive rollout \
                     budgets reinvest decode spend that down-sampling discards; \
                     grpo/ga train on every generated rollout, so there is no \
                     budget to reallocate"
                ));
            }
            if self.norm_mode() == NormMode::Before {
                return Err(anyhow!(
                    "budget.enabled requires algo.adv_norm = \"after\": the \
                     \"before\" mode normalizes advantages over the whole \
                     generated group, so a variable per-group rollout count \
                     would itself perturb the statistics (see docs/DETERMINISM.md)"
                ));
            }
            if self.budget.n_probe > self.algo.n {
                return Err(anyhow!(
                    "budget.n_probe must be <= algo.n (got n_probe={}, n={}): the \
                     probe quota alone would exceed the per-iteration decode \
                     budget of n rollouts per prompt",
                    self.budget.n_probe,
                    self.algo.n
                ));
            }
        }
        // online pruning is only sound when advantages normalize on the
        // selected subset: "before" reads every rollout's reward, which an
        // aborted (truncated) stream would perturb
        if self.rollout.online_prune && self.norm_mode() == NormMode::Before {
            return Err(anyhow!(
                "rollout.online_prune requires algo.adv_norm = \"after\": the \
                 \"before\" mode normalizes advantages over every generated \
                 rollout's reward, including the ones selection drops, so \
                 aborting a doomed rollout mid-decode would change the \
                 normalization statistics (see docs/DETERMINISM.md)"
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINIMAL: &str = r#"
        [run]
        name = "t"
        profile = "base"
        task = "arith"
        iterations = 10

        [algo]
        kind = "pods"
        n = 64
        m = 16
        lr = 1e-4
    "#;

    #[test]
    fn parses_minimal_with_defaults() {
        let cfg = RunConfig::from_str_validated(MINIMAL).unwrap();
        assert_eq!(cfg.algo_kind(), AlgoKind::GrpoPods);
        assert_eq!(cfg.selector().stage_names(), vec!["max_variance"]);
        assert_eq!(cfg.norm_mode(), NormMode::After);
        assert_eq!(cfg.effective_m(), 16);
        assert_eq!(cfg.hwsim.workers, 1);
        assert_eq!(cfg.run.eval_every, 10);
        assert!(cfg.sft.is_none());
    }

    #[test]
    fn ga_trains_on_all() {
        let text = MINIMAL.replace("kind = \"pods\"", "kind = \"ga\"");
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert_eq!(cfg.effective_m(), 64);
    }

    #[test]
    fn rejects_m_above_n() {
        let text = MINIMAL.replace("m = 16", "m = 128");
        assert!(RunConfig::from_str_validated(&text).is_err());
    }

    #[test]
    fn rejects_pods_without_m() {
        let text = MINIMAL.replace("m = 16\n", "");
        assert!(RunConfig::from_str_validated(&text).is_err());
    }

    #[test]
    fn rejects_unknown_rule() {
        let text = format!("{MINIMAL}\nrule = \"best_ever\"");
        assert!(RunConfig::from_str_validated(&text).is_err());
    }

    #[test]
    fn composed_pipeline_specs_parse() {
        let text =
            MINIMAL.replace("lr = 1e-4", "lr = 1e-4\nrule = \"drop_zero_variance | max_variance\"");
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert_eq!(cfg.selector().stage_names(), vec!["drop_zero_variance", "max_variance"]);

        let text = MINIMAL
            .replace("lr = 1e-4", "lr = 1e-4\nrule = \"prune(max_tokens=4096) | percentile\"");
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert_eq!(cfg.selector().stage_names(), vec!["prune", "percentile"]);

        // malformed stage args fail validation, not training
        let text = MINIMAL.replace("lr = 1e-4", "lr = 1e-4\nrule = \"prune(quantile=2)\"");
        assert!(RunConfig::from_str_validated(&text).is_err());
    }

    #[test]
    fn hwsim_overrides_parse() {
        let text = format!("{MINIMAL}\n[hwsim]\nworkers = 8\nmem_capacity_rollouts = 16\n");
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert_eq!(cfg.hwsim.workers, 8);
        assert_eq!(cfg.hwsim.mem_capacity_rollouts, 16);
        // non-overridden fields keep defaults
        assert!(cfg.hwsim.tok_time_b1 > 0.0);
        assert_eq!(cfg.hwsim.schedule, crate::hwsim::Schedule::Sync);
    }

    #[test]
    fn schedule_parses_from_hwsim_section() {
        let text = format!("{MINIMAL}\n[hwsim]\nschedule = \"pipelined\"\n");
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert_eq!(cfg.hwsim.schedule, crate::hwsim::Schedule::Pipelined);
        let text = format!("{MINIMAL}\n[hwsim]\nschedule = \"warp-speed\"\n");
        let err = RunConfig::from_str_validated(&text).unwrap_err();
        assert!(format!("{err:#}").contains("schedule"), "undescriptive: {err:#}");
    }

    /// Satellite: degenerate `[hwsim]` / `[algo]` values fail at parse
    /// time with descriptive errors instead of tripping downstream
    /// asserts or being silently clamped.
    #[test]
    fn zero_workers_and_zero_n_fail_at_parse_with_descriptive_errors() {
        let text = format!("{MINIMAL}\n[hwsim]\nworkers = 0\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("hwsim.workers"), "undescriptive: {err}");
        assert!(err.contains(">= 1"), "undescriptive: {err}");

        let text = MINIMAL.replace("n = 64", "n = 0").replace("m = 16\n", "");
        let text = text.replace("kind = \"pods\"", "kind = \"grpo\"");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("algo.n"), "undescriptive: {err}");

        let text = format!("{MINIMAL}\n[hwsim]\nmem_capacity_rollouts = 0\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("mem_capacity_rollouts"), "undescriptive: {err}");
    }

    #[test]
    fn rollout_section_defaults_and_overrides() {
        let cfg = RunConfig::from_str_validated(MINIMAL).unwrap();
        assert_eq!(cfg.rollout.decode_chunk, 16);
        assert_eq!(cfg.rollout.refill, crate::rollout::RefillMode::Continuous);

        let text = format!("{MINIMAL}\n[rollout]\ndecode_chunk = 4\nrefill = \"batch\"\n");
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert_eq!(cfg.rollout.decode_chunk, 4);
        assert_eq!(cfg.rollout.refill, crate::rollout::RefillMode::Batch);
    }

    #[test]
    fn share_prompt_kv_parses_and_is_opt_in() {
        let cfg = RunConfig::from_str_validated(MINIMAL).unwrap();
        assert!(!cfg.rollout.share_prompt_kv, "prompt-KV sharing must be opt-in");

        let text = format!("{MINIMAL}\n[rollout]\nshare_prompt_kv = true\n");
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert!(cfg.rollout.share_prompt_kv);

        // non-bool values are rejected
        let text = format!("{MINIMAL}\n[rollout]\nshare_prompt_kv = 1\n");
        assert!(RunConfig::from_str_validated(&text).is_err());
    }

    #[test]
    fn kv_pool_keys_parse_and_validate() {
        let cfg = RunConfig::from_str_validated(MINIMAL).unwrap();
        assert_eq!(cfg.hwsim.kv_bytes_per_token, 65_536);
        assert_eq!(cfg.hwsim.kv_page_tokens, 16);
        assert_eq!(cfg.hwsim.kv_pool_bytes, 0, "pool must default to unbounded");

        let text = format!(
            "{MINIMAL}\n[hwsim]\nkv_bytes_per_token = 1024\nkv_page_tokens = 8\n\
             kv_pool_bytes = 1048576\n"
        );
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert_eq!(cfg.hwsim.kv_bytes_per_token, 1024);
        assert_eq!(cfg.hwsim.kv_page_tokens, 8);
        assert_eq!(cfg.hwsim.kv_pool_bytes, 1_048_576);

        let text = format!("{MINIMAL}\n[hwsim]\nkv_page_tokens = 0\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("kv_page_tokens"), "undescriptive: {err}");
    }

    #[test]
    fn online_prune_parses_and_requires_after_normalization() {
        let cfg = RunConfig::from_str_validated(MINIMAL).unwrap();
        assert!(!cfg.rollout.online_prune, "online pruning must be opt-in");

        let text = format!("{MINIMAL}\n[rollout]\nonline_prune = true\n");
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert!(cfg.rollout.online_prune);

        // the unsound combination fails at parse with a descriptive error
        let text = format!(
            "{}\n[rollout]\nonline_prune = true\n",
            MINIMAL.replace("lr = 1e-4", "lr = 1e-4\nadv_norm = \"before\"")
        );
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("online_prune"), "undescriptive: {err}");
        assert!(err.contains("adv_norm"), "undescriptive: {err}");

        // non-bool values are rejected
        let text = format!("{MINIMAL}\n[rollout]\nonline_prune = 1\n");
        assert!(RunConfig::from_str_validated(&text).is_err());
    }

    #[test]
    fn rollout_section_rejects_degenerate_values() {
        let text = format!("{MINIMAL}\n[rollout]\ndecode_chunk = 0\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("rollout.decode_chunk"), "undescriptive: {err}");

        let text = format!("{MINIMAL}\n[rollout]\nrefill = \"eager\"\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("refill"), "undescriptive: {err}");
    }

    #[test]
    fn update_section_defaults_and_overrides() {
        let cfg = RunConfig::from_str_validated(MINIMAL).unwrap();
        assert_eq!(cfg.update.shards, 1);
        assert_eq!(cfg.update.micro_batch, 0);
        // micro_batch = 0 resolves to the profile's B_u
        assert_eq!(cfg.update.rows_per_call(8).unwrap(), 8);

        let text = format!("{MINIMAL}\n[update]\nshards = 4\nmicro_batch = 2\n");
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert_eq!(cfg.update.shards, 4);
        assert_eq!(cfg.update.micro_batch, 2);
        assert_eq!(cfg.update.rows_per_call(8).unwrap(), 2);
    }

    #[test]
    fn update_section_rejects_degenerate_values() {
        let text = format!("{MINIMAL}\n[update]\nshards = 0\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("update.shards"), "undescriptive: {err}");
        assert!(err.contains(">= 1"), "undescriptive: {err}");

        // micro_batch above the profile's B_u fails where B_u is known
        let cfg = RunConfig::from_str_validated(MINIMAL).unwrap();
        let upd = UpdateSection { micro_batch: 16, ..cfg.update };
        let err = format!("{:#}", upd.rows_per_call(8).unwrap_err());
        assert!(err.contains("micro_batch"), "undescriptive: {err}");
        assert!(err.contains("B_u"), "undescriptive: {err}");
    }

    #[test]
    fn replay_section_defaults_and_overrides() {
        let cfg = RunConfig::from_str_validated(MINIMAL).unwrap();
        assert!(!cfg.replay.enabled, "replay must be opt-in");
        assert!((cfg.replay.mix_fraction - 0.25).abs() < 1e-12);
        assert_eq!(cfg.replay.staleness, 2);
        assert_eq!(cfg.replay.capacity_per_prompt, 4);
        assert!((cfg.replay.rho_max - 2.0).abs() < 1e-12);

        let text = format!(
            "{MINIMAL}\n[replay]\nenabled = true\nmix_fraction = 0.5\n\
             staleness = 3\ncapacity_per_prompt = 8\nrho_max = 4.0\n"
        );
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert!(cfg.replay.enabled);
        assert!((cfg.replay.mix_fraction - 0.5).abs() < 1e-12);
        assert_eq!(cfg.replay.staleness, 3);
        assert_eq!(cfg.replay.capacity_per_prompt, 8);
        assert!((cfg.replay.rho_max - 4.0).abs() < 1e-12);
    }

    #[test]
    fn replay_section_rejects_degenerate_values() {
        let text = format!("{MINIMAL}\n[replay]\nmix_fraction = 1.5\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("replay.mix_fraction"), "undescriptive: {err}");

        let text = format!("{MINIMAL}\n[replay]\nstaleness = 0\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("replay.staleness"), "undescriptive: {err}");

        let text = format!("{MINIMAL}\n[replay]\ncapacity_per_prompt = 0\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("replay.capacity_per_prompt"), "undescriptive: {err}");

        let text = format!("{MINIMAL}\n[replay]\nrho_max = 0.5\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("replay.rho_max"), "undescriptive: {err}");
    }

    #[test]
    fn replay_requires_after_normalization() {
        let text = format!(
            "{}\n[replay]\nenabled = true\n",
            MINIMAL.replace("lr = 1e-4", "lr = 1e-4\nadv_norm = \"before\"")
        );
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("replay.enabled"), "undescriptive: {err}");
        assert!(err.contains("adv_norm"), "undescriptive: {err}");

        // disabled replay with "before" normalization stays legal
        let text = MINIMAL.replace("lr = 1e-4", "lr = 1e-4\nadv_norm = \"before\"");
        assert!(RunConfig::from_str_validated(&text).is_ok());
    }

    #[test]
    fn faults_section_defaults_and_overrides() {
        let cfg = RunConfig::from_str_validated(MINIMAL).unwrap();
        assert!(!cfg.faults.enabled, "fault injection must be opt-in");
        assert_eq!(cfg.faults.crash_rate, 0.0);
        assert_eq!(cfg.faults.max_retries, 2);
        assert_eq!(cfg.faults.min_group_survivors, 1);

        let text = format!(
            "{MINIMAL}\n[faults]\nenabled = true\ncrash_rate = 0.05\n\
             transient_rate = 0.1\noom_rate = 0.02\nstraggler_rate = 0.1\n\
             straggler_factor = 3.0\nmax_retries = 3\nbackoff_base = 0.25\n\
             backoff_factor = 1.5\nmin_group_survivors = 4\n"
        );
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert!(cfg.faults.enabled);
        assert!((cfg.faults.crash_rate - 0.05).abs() < 1e-12);
        assert!((cfg.faults.transient_rate - 0.1).abs() < 1e-12);
        assert!((cfg.faults.oom_rate - 0.02).abs() < 1e-12);
        assert!((cfg.faults.straggler_factor - 3.0).abs() < 1e-12);
        assert_eq!(cfg.faults.max_retries, 3);
        assert_eq!(cfg.faults.min_group_survivors, 4);
    }

    #[test]
    fn faults_section_rejects_degenerate_values() {
        let text = format!("{MINIMAL}\n[faults]\ncrash_rate = 1.5\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("faults.crash_rate"), "undescriptive: {err}");

        let text = format!("{MINIMAL}\n[faults]\ncrash_rate = 0.6\ntransient_rate = 0.6\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("exceed 1.0"), "undescriptive: {err}");

        let text = format!("{MINIMAL}\n[faults]\nmin_group_survivors = 0\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("faults.min_group_survivors"), "undescriptive: {err}");

        let text = format!("{MINIMAL}\n[faults]\nbackoff_factor = 0.5\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("faults.backoff_factor"), "undescriptive: {err}");
    }

    #[test]
    fn fleet_section_defaults_and_overrides() {
        let cfg = RunConfig::from_str_validated(MINIMAL).unwrap();
        assert_eq!(cfg.fleet.inference_replicas, 1);
        assert_eq!(cfg.fleet.max_staleness, None, "staleness must default to the schedule");
        assert_eq!(cfg.fleet.queue_capacity, 0);
        assert_eq!(cfg.fleet.traffic_burst, 256);

        let text = format!(
            "{MINIMAL}\n[hwsim]\nschedule = \"pipelined\"\n\n[fleet]\n\
             inference_replicas = 4\nmax_staleness = 3\nqueue_capacity = 2\n\
             traffic_burst = 64\ntraffic_gap = 1.5\n"
        );
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert_eq!(cfg.fleet.inference_replicas, 4);
        assert_eq!(cfg.fleet.max_staleness, Some(3));
        assert_eq!(cfg.fleet.queue_capacity, 2);
        assert_eq!(cfg.fleet.traffic_burst, 64);
        assert!((cfg.fleet.traffic_gap - 1.5).abs() < 1e-12);
        assert_eq!(cfg.fleet.effective_staleness(cfg.hwsim.schedule), 3);
        assert_eq!(cfg.fleet.effective_queue_capacity(cfg.hwsim.schedule), 2);
    }

    #[test]
    fn fleet_section_rejects_degenerate_and_contradictory_values() {
        let text = format!("{MINIMAL}\n[fleet]\ninference_replicas = 0\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("fleet.inference_replicas"), "undescriptive: {err}");

        // sync is the K = 0 special case; an explicit K >= 1 contradicts it
        let text = format!("{MINIMAL}\n[fleet]\nmax_staleness = 2\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("fleet.max_staleness"), "undescriptive: {err}");
        assert!(err.contains("sync"), "undescriptive: {err}");

        // pipelined overlaps generation with the previous update: K >= 1
        let text = format!(
            "{MINIMAL}\n[hwsim]\nschedule = \"pipelined\"\n\n[fleet]\nmax_staleness = 0\n"
        );
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("pipelined"), "undescriptive: {err}");

        // explicit K = 0 agrees with sync; absent K composes with both
        // schedules, and extra replicas are legal under either
        let text = format!("{MINIMAL}\n[fleet]\nmax_staleness = 0\n");
        assert!(RunConfig::from_str_validated(&text).is_ok());
        let text = format!("{MINIMAL}\n[fleet]\ninference_replicas = 4\n");
        assert!(RunConfig::from_str_validated(&text).is_ok());
    }

    #[test]
    fn budget_section_defaults_and_overrides() {
        let cfg = RunConfig::from_str_validated(MINIMAL).unwrap();
        assert!(!cfg.budget.enabled, "adaptive budgets must be opt-in");
        assert_eq!(cfg.budget.n_probe, 8);
        assert_eq!(cfg.budget.max_per_prompt, 128);
        assert!((cfg.budget.width_threshold - 0.25).abs() < 1e-12);

        let text = format!(
            "{MINIMAL}\n[budget]\nenabled = true\nn_probe = 4\n\
             max_per_prompt = 32\nwidth_threshold = 0.5\n"
        );
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert!(cfg.budget.enabled);
        assert_eq!(cfg.budget.n_probe, 4);
        assert_eq!(cfg.budget.max_per_prompt, 32);
        assert!((cfg.budget.width_threshold - 0.5).abs() < 1e-12);
    }

    #[test]
    fn budget_section_rejects_degenerate_values() {
        let text = format!("{MINIMAL}\n[budget]\nn_probe = 0\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("budget.n_probe"), "undescriptive: {err}");

        let text = format!("{MINIMAL}\n[budget]\nn_probe = 8\nmax_per_prompt = 4\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("budget.max_per_prompt"), "undescriptive: {err}");

        let text = format!("{MINIMAL}\n[budget]\nwidth_threshold = -0.5\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("budget.width_threshold"), "undescriptive: {err}");

        // probe quota above n is a cross-section failure (enabled only)
        let text = format!("{MINIMAL}\n[budget]\nenabled = true\nn_probe = 128\n");
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("n_probe"), "undescriptive: {err}");
        assert!(err.contains("algo.n"), "undescriptive: {err}");
        let text = format!("{MINIMAL}\n[budget]\nn_probe = 128\nmax_per_prompt = 256\n");
        assert!(
            RunConfig::from_str_validated(&text).is_ok(),
            "disabled budget must not gate on algo.n"
        );
    }

    #[test]
    fn budget_requires_pods_and_after_normalization() {
        let text = format!(
            "{}\n[budget]\nenabled = true\n",
            MINIMAL.replace("kind = \"pods\"", "kind = \"grpo\"").replace("m = 16\n", "")
        );
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("budget.enabled"), "undescriptive: {err}");
        assert!(err.contains("pods"), "undescriptive: {err}");

        let text = format!(
            "{}\n[budget]\nenabled = true\n",
            MINIMAL.replace("lr = 1e-4", "lr = 1e-4\nadv_norm = \"before\"")
        );
        let err = format!("{:#}", RunConfig::from_str_validated(&text).unwrap_err());
        assert!(err.contains("budget.enabled"), "undescriptive: {err}");
        assert!(err.contains("adv_norm"), "undescriptive: {err}");

        // disabled budget composes with either, like the other gated sections
        let text = MINIMAL.replace("lr = 1e-4", "lr = 1e-4\nadv_norm = \"before\"");
        assert!(RunConfig::from_str_validated(&text).is_ok());
    }

    #[test]
    fn ckpt_section_defaults_and_path_resolution() {
        let cfg = RunConfig::from_str_validated(MINIMAL).unwrap();
        assert_eq!(cfg.ckpt.every, 0, "checkpointing must be opt-in");
        assert_eq!(cfg.ckpt.resume_path("results", "t"), "results/t.resume");

        let text = format!("{MINIMAL}\n[ckpt]\nevery = 5\npath = \"results/custom.resume\"\n");
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        assert_eq!(cfg.ckpt.every, 5);
        assert_eq!(cfg.ckpt.resume_path("results", "t"), "results/custom.resume");
    }

    #[test]
    fn sft_section_parses() {
        let text = format!("{MINIMAL}\n[sft]\nsteps = 100\nlr = 3e-3\n");
        let cfg = RunConfig::from_str_validated(&text).unwrap();
        let sft = cfg.sft.unwrap();
        assert_eq!(sft.steps, 100);
        assert_eq!(sft.lr, 3e-3);
    }
}
