//! Gradient accumulation — the mechanism GRPO-GA pays for and PODS avoids.
//!
//! The `grad` artifact computes the *mean* objective over its fixed
//! micro-batch of `B_u` rollouts (padded rows carry zero advantage and
//! contribute exactly zero gradient). To recover the mean over the `M` real
//! rollouts of the full update batch, each micro-gradient is accumulated
//! with weight `B_u` and the sum divided by `M`:
//!
//!   g = (Σ_mb B_u · g_mb) / M      since  g_mb = (1/B_u) Σ_{real rows} ∂obj
//!
//! The accumulator also mirrors what a DeepSpeed-style GA engine does
//! between collectives: hold a full-width f32 buffer, add in place, scale
//! once at the end — allocation-free across iterations (`reset` keeps the
//! buffer).

/// Accumulates weighted gradient vectors.
#[derive(Debug, Clone)]
pub struct GradAccumulator {
    sum: Vec<f32>,
    weight: f64,
    micro_steps: usize,
}

impl GradAccumulator {
    /// A zeroed accumulator for gradient vectors of width `n`.
    pub fn new(n: usize) -> Self {
        Self { sum: vec![0.0; n], weight: 0.0, micro_steps: 0 }
    }

    /// Clear for the next iteration without reallocating.
    pub fn reset(&mut self) {
        self.sum.iter_mut().for_each(|x| *x = 0.0);
        self.weight = 0.0;
        self.micro_steps = 0;
    }

    /// Add one micro-batch gradient with the given weight (its number of
    /// rollout slots, real + padded).
    pub fn add(&mut self, grads: &[f32], weight: f64) {
        assert_eq!(grads.len(), self.sum.len(), "gradient width mismatch");
        let w = weight as f32;
        for (s, g) in self.sum.iter_mut().zip(grads) {
            *s += w * g;
        }
        self.weight += weight;
        self.micro_steps += 1;
    }

    /// Number of micro-batches accumulated so far.
    pub fn micro_steps(&self) -> usize {
        self.micro_steps
    }

    /// Sum of the weights accumulated so far (real + padded slots).
    pub fn total_weight(&self) -> f64 {
        self.weight
    }

    /// Finalize: divide by the number of *real* rollouts and return the
    /// mean gradient (buffer is left dirty; call `reset` before reuse).
    pub fn mean(&self, real_rows: usize) -> Vec<f32> {
        assert!(real_rows > 0, "mean over zero rollouts");
        let inv = 1.0 / real_rows as f32;
        self.sum.iter().map(|s| s * inv).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{for_cases, vec_f32};

    /// Accumulating per-row gradients in chunks with padding weights
    /// reproduces the full-batch mean exactly (up to f32 round-off).
    #[test]
    fn chunked_mean_matches_full_mean() {
        for_cases(300, |rng| {
            let width = 4;
            let total = rng.gen_range_inclusive(1, 19) as usize;
            let bu = rng.gen_range_inclusive(1, 4) as usize;
            let rows: Vec<Vec<f32>> = (0..total).map(|_| vec_f32(rng, width, -2.0, 2.0)).collect();
            // "micro-batch gradient" = mean over B_u slots, padded rows = 0
            let mut acc = GradAccumulator::new(width);
            for chunk in rows.chunks(bu) {
                let mut mb = vec![0.0f32; width];
                for r in chunk {
                    for (m, v) in mb.iter_mut().zip(r) {
                        *m += v;
                    }
                }
                for m in mb.iter_mut() {
                    *m /= bu as f32;
                }
                acc.add(&mb, bu as f64);
            }
            let got = acc.mean(total);
            let mut want = vec![0.0f32; width];
            for r in &rows {
                for (w, v) in want.iter_mut().zip(r) {
                    *w += v;
                }
            }
            for w in want.iter_mut() {
                *w /= total as f32;
            }
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "{g} vs {w}");
            }
        });
    }

    #[test]
    fn reset_preserves_capacity_and_zeroes() {
        let mut acc = GradAccumulator::new(3);
        acc.add(&[1.0, 2.0, 3.0], 2.0);
        assert_eq!(acc.micro_steps(), 1);
        acc.reset();
        assert_eq!(acc.micro_steps(), 0);
        assert_eq!(acc.total_weight(), 0.0);
        acc.add(&[1.0, 1.0, 1.0], 1.0);
        assert_eq!(acc.mean(1), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "gradient width mismatch")]
    fn width_mismatch_panics() {
        let mut acc = GradAccumulator::new(2);
        acc.add(&[1.0, 2.0, 3.0], 1.0);
    }
}
