//! Crash-consistent training checkpoint/resume (`[ckpt]`).
//!
//! [`save`] snapshots everything a [`crate::coordinator::scheduler::Trainer`]
//! needs to continue a killed run **bit-identically** (pinned by
//! `rust/tests/fault_golden.rs`): parameters + optimizer moments, the
//! frozen base and KL-reference vectors, the simulated clock, the
//! replay store, both recorder CSVs, and the executor's **ready-batch
//! queue** — for every prefetched generation in flight at the snapshot,
//! its target iteration, origin policy version, accrued overlap credit
//! and the behaviour parameters it was decoding with, in queue order, so
//! resume can regenerate the exact same off-policy rollouts (per-row
//! counter RNG makes regeneration bit-exact) and charge the exact same
//! hidden time. The legacy pipelined prefetch is the one-entry case.
//!
//! Crash consistency: the state serializes to a temp file that is
//! atomically renamed over the target, and the payload carries an
//! FNV-1a-64 checksum trailer — a torn or corrupted file fails [`load`]
//! loudly instead of resuming from garbage. Recorder rows serialize as
//! their CSV text: Rust's shortest-roundtrip float formatting makes
//! `parse ∘ format` the identity, so the resumed run's CSVs are
//! byte-identical to the uninterrupted run's.

use crate::coordinator::replay::{RowId, StoredRow};
use crate::coordinator::group::RolloutRecord;
use crate::metrics::{CsvRow, EvalRow, IterRow};
use crate::reward::RewardBreakdown;
use crate::runtime::ParamStore;
use anyhow::{bail, Context, Result};
use std::path::Path;

const MAGIC: &[u8; 8] = b"PODSRSM1";
const VERSION: u32 = 2;

/// One in-flight prefetched generation at snapshot time: the iteration
/// it generates, the policy version and behaviour snapshot (pre-update
/// policy) it decodes under, and the overlap credit it has accrued.
#[derive(Debug, Clone)]
pub struct InflightGen {
    /// Iteration the prefetch generates rollouts for.
    pub iter: usize,
    /// Policy version (origin iteration) the behaviour snapshot belongs
    /// to — realized staleness at consumption is `iter − born`.
    pub born: usize,
    /// Simulated update time that elapsed while a replica decoded this
    /// batch (the clock's concurrency credit at consumption).
    pub overlap: f64,
    /// Full-parameter behaviour vector (the frozen base in LoRA mode).
    pub params: Vec<f32>,
    /// Behaviour adapter vector (LoRA profiles only).
    pub lora: Option<Vec<f32>>,
}

/// The complete resumable state of a training run at an iteration
/// boundary ("iterations `0..next_iter` are done, evals included").
#[derive(Debug, Clone)]
pub struct ResumeState {
    /// Artifact profile the run trains (resume sanity check).
    pub profile: String,
    /// Run name (resume sanity check).
    pub run_name: String,
    /// Master seed (resume sanity check — a different seed would silently
    /// splice two unrelated histories).
    pub run_seed: u64,
    /// First iteration the resumed run executes.
    pub next_iter: usize,
    /// Logical prompt cursor at the boundary — `next_iter ×
    /// prompts_per_iter`, **before** any prefetch advance (restore
    /// re-applies it when rebuilding the in-flight batch).
    pub prompt_cursor: u64,
    /// Simulated clock position.
    pub clock_now: f64,
    /// Accumulated overlap savings of the simulated clock.
    pub clock_overlap_saved: f64,
    /// Trainable parameters + Adam moments + step counter.
    pub store: ParamStore,
    /// Frozen full-parameter base (LoRA profiles only).
    pub base: Option<Vec<f32>>,
    /// KL-reference parameters (when `algo.kl_coef > 0`).
    pub ref_params: Option<Vec<f32>>,
    /// KL-reference adapter vector.
    pub ref_lora: Option<Vec<f32>>,
    /// The executor's ready-batch queue at snapshot time, oldest first
    /// (empty under the sync schedule; one entry under the legacy
    /// pipelined prefetch; up to the fleet depth otherwise). Restore
    /// resubmits the entries in this order — queue order is part of the
    /// determinism contract.
    pub queued: Vec<InflightGen>,
    /// Replay-store contents in canonical `RowId` order.
    pub replay_rows: Vec<StoredRow>,
    /// Recorder training rows (serialized as CSV text).
    pub iter_rows: Vec<IterRow>,
    /// Recorder eval rows (serialized as CSV text).
    pub eval_rows: Vec<EvalRow>,
}

// ---- byte-stream primitives -------------------------------------------

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn vec_f32(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f32(x);
        }
    }
    fn vec_i32(&mut self, v: &[i32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.i32(x);
        }
    }
    fn opt_vec_f32(&mut self, v: Option<&[f32]>) {
        match v {
            Some(v) => {
                self.u8(1);
                self.vec_f32(v);
            }
            None => self.u8(0),
        }
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("resume file truncated at byte {} (wanted {n} more)", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn len(&mut self) -> Result<usize> {
        let n = self.u64()?;
        // a length can never exceed what's left in the file — rejects
        // corrupt lengths before they turn into giant allocations
        if n > (self.buf.len() - self.pos) as u64 {
            bail!("resume file corrupt: length {n} exceeds remaining payload");
        }
        Ok(n as usize)
    }
    fn str(&mut self) -> Result<String> {
        let n = self.len()?;
        Ok(std::str::from_utf8(self.take(n)?).context("resume string not UTF-8")?.to_string())
    }
    fn vec_f32(&mut self) -> Result<Vec<f32>> {
        let n = self.len()?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }
    fn vec_i32(&mut self) -> Result<Vec<i32>> {
        let n = self.len()?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.i32()?);
        }
        Ok(v)
    }
    fn opt_vec_f32(&mut self) -> Result<Option<Vec<f32>>> {
        Ok(match self.u8()? {
            0 => None,
            _ => Some(self.vec_f32()?),
        })
    }
}

fn put_stored_row(e: &mut Enc, r: &StoredRow) {
    e.u64(r.id.iter);
    e.u64(r.id.prompt_id);
    e.u32(r.id.rollout_idx);
    e.f32(r.score);
    e.f32(r.advantage);
    put_record(e, &r.record);
}

fn put_record(e: &mut Enc, r: &RolloutRecord) {
    e.vec_i32(&r.tokens);
    e.i32(r.pad_len);
    e.vec_f32(&r.gen_mask);
    e.vec_f32(&r.old_lp);
    e.vec_f32(&r.ref_lp);
    e.i32(r.gen_len);
    e.f32(r.reward.accuracy);
    e.f32(r.reward.format);
    e.f32(r.reward.tag_count);
    e.f32(r.total_reward);
    e.u8(u8::from(r.pruned));
}

fn get_stored_row(d: &mut Dec) -> Result<StoredRow> {
    Ok(StoredRow {
        id: RowId { iter: d.u64()?, prompt_id: d.u64()?, rollout_idx: d.u32()? },
        score: d.f32()?,
        advantage: d.f32()?,
        record: get_record(d)?,
    })
}

fn get_record(d: &mut Dec) -> Result<RolloutRecord> {
    Ok(RolloutRecord {
        tokens: d.vec_i32()?,
        pad_len: d.i32()?,
        gen_mask: d.vec_f32()?,
        old_lp: d.vec_f32()?,
        ref_lp: d.vec_f32()?,
        gen_len: d.i32()?,
        reward: RewardBreakdown {
            accuracy: d.f32()?,
            format: d.f32()?,
            tag_count: d.f32()?,
        },
        total_reward: d.f32()?,
        pruned: d.u8()? != 0,
    })
}

// ---- save / load -------------------------------------------------------

/// Serialize `st` to `path` crash-consistently: write-temp, fsync via the
/// file close, then atomic rename. The payload ends with an FNV-1a-64
/// checksum so partial or bit-rotted files are rejected on load.
pub fn save(path: &Path, st: &ResumeState) -> Result<()> {
    let mut e = Enc::default();
    e.u32(VERSION);
    e.str(&st.profile);
    e.str(&st.run_name);
    e.u64(st.run_seed);
    e.u64(st.next_iter as u64);
    e.u64(st.prompt_cursor);
    e.f64(st.clock_now);
    e.f64(st.clock_overlap_saved);
    e.i32(st.store.step);
    e.vec_f32(&st.store.params);
    e.vec_f32(&st.store.m);
    e.vec_f32(&st.store.v);
    e.opt_vec_f32(st.base.as_deref());
    e.opt_vec_f32(st.ref_params.as_deref());
    e.opt_vec_f32(st.ref_lora.as_deref());
    e.u64(st.queued.len() as u64);
    for q in &st.queued {
        e.u64(q.iter as u64);
        e.u64(q.born as u64);
        e.f64(q.overlap);
        e.vec_f32(&q.params);
        e.opt_vec_f32(q.lora.as_deref());
    }
    e.u64(st.replay_rows.len() as u64);
    for r in &st.replay_rows {
        put_stored_row(&mut e, r);
    }
    e.u64(st.iter_rows.len() as u64);
    for r in &st.iter_rows {
        e.str(&r.csv_row());
    }
    e.u64(st.eval_rows.len() as u64);
    for r in &st.eval_rows {
        e.str(&r.csv_row());
    }
    let checksum = fnv1a(&e.buf);
    let mut out = Vec::with_capacity(MAGIC.len() + e.buf.len() + 8);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&e.buf);
    out.extend_from_slice(&checksum.to_le_bytes());
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
        }
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &out).with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// Load and verify a resume file written by [`save`].
pub fn load(path: &Path) -> Result<ResumeState> {
    let bytes = std::fs::read(path).with_context(|| format!("reading resume file {path:?}"))?;
    if bytes.len() < MAGIC.len() + 8 || &bytes[..MAGIC.len()] != MAGIC {
        bail!("{path:?} is not a pods resume file");
    }
    let payload = &bytes[MAGIC.len()..bytes.len() - 8];
    let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    let computed = fnv1a(payload);
    if stored != computed {
        bail!(
            "resume file {path:?} failed its checksum \
             (stored {stored:#018x}, computed {computed:#018x}) — torn write or corruption"
        );
    }
    let mut d = Dec { buf: payload, pos: 0 };
    let version = d.u32()?;
    if version != VERSION {
        bail!("resume file version {version} unsupported (expected {VERSION})");
    }
    let profile = d.str()?;
    let run_name = d.str()?;
    let run_seed = d.u64()?;
    let next_iter = d.u64()? as usize;
    let prompt_cursor = d.u64()?;
    let clock_now = d.f64()?;
    let clock_overlap_saved = d.f64()?;
    let step = d.i32()?;
    let params = d.vec_f32()?;
    let m = d.vec_f32()?;
    let v = d.vec_f32()?;
    let store = ParamStore { params, m, v, step };
    let base = d.opt_vec_f32()?;
    let ref_params = d.opt_vec_f32()?;
    let ref_lora = d.opt_vec_f32()?;
    let n_queued = d.len()?;
    let mut queued = Vec::with_capacity(n_queued);
    for _ in 0..n_queued {
        queued.push(InflightGen {
            iter: d.u64()? as usize,
            born: d.u64()? as usize,
            overlap: d.f64()?,
            params: d.vec_f32()?,
            lora: d.opt_vec_f32()?,
        });
    }
    let n_replay = d.len()?;
    let mut replay_rows = Vec::with_capacity(n_replay);
    for _ in 0..n_replay {
        replay_rows.push(get_stored_row(&mut d)?);
    }
    let n_iter = d.len()?;
    let mut iter_rows = Vec::with_capacity(n_iter);
    for _ in 0..n_iter {
        iter_rows.push(IterRow::from_csv_row(&d.str()?)?);
    }
    let n_eval = d.len()?;
    let mut eval_rows = Vec::with_capacity(n_eval);
    for _ in 0..n_eval {
        eval_rows.push(EvalRow::from_csv_row(&d.str()?)?);
    }
    if d.pos != d.buf.len() {
        bail!("resume file has {} trailing bytes after the payload", d.buf.len() - d.pos);
    }
    Ok(ResumeState {
        profile,
        run_name,
        run_seed,
        next_iter,
        prompt_cursor,
        clock_now,
        clock_overlap_saved,
        store,
        base,
        ref_params,
        ref_lora,
        queued,
        replay_rows,
        iter_rows,
        eval_rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> ResumeState {
        let rec = RolloutRecord {
            tokens: vec![1, 2, 3, 4],
            pad_len: 1,
            gen_mask: vec![1.0, 1.0, 0.0],
            old_lp: vec![-0.5, -0.25, 0.0],
            ref_lp: vec![0.0; 3],
            gen_len: 2,
            reward: RewardBreakdown { accuracy: 1.0, format: 0.5, tag_count: 0.25 },
            total_reward: 1.75,
            pruned: false,
        };
        ResumeState {
            profile: "micro".into(),
            run_name: "t".into(),
            run_seed: 42,
            next_iter: 5,
            prompt_cursor: 40,
            clock_now: 123.456,
            clock_overlap_saved: 7.5,
            store: ParamStore {
                params: vec![1.0, -2.5, 0.125],
                m: vec![0.5; 3],
                v: vec![0.25; 3],
                step: 5,
            },
            base: Some(vec![9.0, 8.0]),
            ref_params: Some(vec![1.5; 3]),
            ref_lora: None,
            queued: vec![InflightGen {
                iter: 5,
                born: 4,
                overlap: 2.25,
                params: vec![0.5, 0.75],
                lora: None,
            }],
            replay_rows: vec![StoredRow {
                id: RowId { iter: 3, prompt_id: 17, rollout_idx: 2 },
                score: 0.5,
                advantage: -1.25,
                record: rec,
            }],
            iter_rows: vec![IterRow {
                iter: 4,
                sim_time: 100.0 / 3.0,
                schedule: "pipelined".into(),
                ..Default::default()
            }],
            eval_rows: vec![EvalRow {
                iter: 4,
                sim_time: 100.0 / 3.0,
                real_time: 0.25,
                split: "test".into(),
                accuracy: 0.625,
                format_rate: 1.0,
                mean_reward: 2.0,
                mean_len: 30.0,
                problems: 64,
            }],
        }
    }

    #[test]
    fn roundtrips_bitwise() {
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("t.resume");
        let st = sample_state();
        save(&path, &st).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.profile, st.profile);
        assert_eq!(back.run_seed, st.run_seed);
        assert_eq!(back.next_iter, st.next_iter);
        assert_eq!(back.prompt_cursor, st.prompt_cursor);
        assert_eq!(back.clock_now.to_bits(), st.clock_now.to_bits());
        assert_eq!(back.clock_overlap_saved.to_bits(), st.clock_overlap_saved.to_bits());
        assert_eq!(back.store.params, st.store.params);
        assert_eq!(back.store.m, st.store.m);
        assert_eq!(back.store.v, st.store.v);
        assert_eq!(back.store.step, st.store.step);
        assert_eq!(back.base, st.base);
        assert_eq!(back.ref_params, st.ref_params);
        assert_eq!(back.ref_lora, st.ref_lora);
        assert_eq!(back.queued.len(), 1);
        assert_eq!(back.queued[0].iter, 5);
        assert_eq!(back.queued[0].born, 4);
        assert_eq!(back.queued[0].overlap.to_bits(), 2.25f64.to_bits());
        assert_eq!(back.queued[0].params, vec![0.5, 0.75]);
        assert_eq!(back.replay_rows.len(), 1);
        assert_eq!(back.replay_rows[0].id, st.replay_rows[0].id);
        assert_eq!(back.replay_rows[0].record.tokens, st.replay_rows[0].record.tokens);
        assert_eq!(back.replay_rows[0].record.old_lp, st.replay_rows[0].record.old_lp);
        // CSV rows re-emit the exact lines the killed run would have
        assert_eq!(back.iter_rows[0].csv_row(), st.iter_rows[0].csv_row());
        assert_eq!(back.eval_rows[0].csv_row(), st.eval_rows[0].csv_row());
    }

    #[test]
    fn rejects_corruption_and_truncation() {
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("t.resume");
        save(&path, &sample_state()).unwrap();
        let good = std::fs::read(&path).unwrap();

        // flip one payload bit -> checksum failure
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("checksum"), "unexpected error: {err}");

        // torn write (file cut short) -> rejected, never a partial resume
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(load(&path).is_err());

        // wrong magic
        std::fs::write(&path, b"not a resume file at all............").unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("not a pods resume file"), "unexpected error: {err}");
    }
}
