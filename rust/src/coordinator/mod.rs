//! The L3 coordination layer — the paper's system contribution.
//!
//! * [`select`] — the pluggable rollout-selection subsystem: `Selector`
//!   trait, spec registry, composable pipelines.
//! * [`downsample`] — the numeric down-sampling kernels, incl. Algorithm 2
//!   (max-variance in `O(n log n)`), which the built-in selectors wrap.
//! * [`advantage`] — subset advantage normalization (§A.3 After/Before).
//! * [`group`] — per-prompt rollout groups and update-batch assembly.
//! * [`accum`] — the gradient-accumulation engine (what GRPO-GA pays for).
//! * [`worker`] — simulated multi-accelerator topology.
//! * [`scheduler`] — the GRPO / GRPO-GA / GRPO-PODS training loop.

pub mod accum;
pub mod advantage;
pub mod downsample;
pub mod group;
pub mod scheduler;
pub mod select;
pub mod worker;
