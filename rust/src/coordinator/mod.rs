//! The L3 coordination layer — the paper's system contribution.
//!
//! * [`select`] — the pluggable rollout-selection subsystem: `Selector`
//!   trait, spec registry, composable pipelines.
//! * [`downsample`] — the numeric down-sampling kernels, incl. Algorithm 2
//!   (max-variance in `O(n log n)`), which the built-in selectors wrap.
//! * [`advantage`] — subset advantage normalization (§A.3 After/Before).
//! * [`group`] — per-prompt rollout groups and update-batch assembly.
//! * [`accum`] — the gradient-accumulation engine (what GRPO-GA pays for).
//! * [`exec`] — the staged training executor: real multi-threaded rollout
//!   generation ([`exec::RolloutEngine`]), the update phase
//!   ([`exec::UpdateEngine`]), and the schedule-aware driver
//!   ([`exec::TrainLoop`], `sync` | `pipelined`).
//! * [`replay`] — cross-iteration rollout replay: the staleness-bounded
//!   [`replay::ReplayStore`] that retains dropped-but-informative
//!   rollouts and mixes them back into later updates with
//!   importance-weight correction.
//! * [`worker`] — simulated multi-accelerator topology (shard math the
//!   hwsim charges with; `exec` provides the real threads).
//! * [`scheduler`] — the GRPO / GRPO-GA / GRPO-PODS trainer façade over
//!   [`exec`].

pub mod accum;
pub mod advantage;
pub mod ckpt;
pub mod downsample;
pub mod exec;
pub mod group;
pub mod replay;
pub mod scheduler;
pub mod select;
pub mod worker;
