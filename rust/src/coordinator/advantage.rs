//! Advantage normalization (paper §3.1, §3.2, ablation §A.3 / Fig. 6).
//!
//! GRPO advantages are the group-standardised rewards `a_i = (r_i - μ)/σ`.
//! PODS introduces a design choice the paper ablates: compute `(μ, σ)` on
//! the **down-sampled subset** ("After" — the paper's default, keeps every
//! update batch zero-mean) or on the **full rollout group before
//! down-sampling** ("Before").

/// When the normalization statistics are computed relative to down-sampling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormMode {
    /// Statistics over the selected subset (paper default, §A.3 "After").
    After,
    /// Statistics over the full rollout group ("Before").
    Before,
}

impl NormMode {
    /// Parse an `[algo] adv_norm` value (`after` | `before`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "after" => Ok(Self::After),
            "before" => Ok(Self::Before),
            other => Err(anyhow::anyhow!("unknown adv_norm {other:?} (after|before)")),
        }
    }

    /// Canonical name used in configs and logs.
    pub fn name(self) -> &'static str {
        match self {
            Self::After => "after",
            Self::Before => "before",
        }
    }
}

/// σ floor: degenerate groups (all rewards equal) get zero advantages
/// rather than a division blow-up — matching TRL's GRPO implementation.
pub const SIGMA_EPS: f64 = 1e-6;

fn mean_std(values: impl Iterator<Item = f64> + Clone) -> (f64, f64) {
    let n = values.clone().count().max(1) as f64;
    let mean = values.clone().sum::<f64>() / n;
    let var = values.map(|v| (v - mean).powi(2)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Normalized advantages for the selected subset of one rollout group.
///
/// `rewards` are the full group's rewards; `subset` the selected indices.
/// Returns one advantage per subset element (same order as `subset`).
pub fn subset_advantages(rewards: &[f32], subset: &[usize], mode: NormMode) -> Vec<f32> {
    let (mean, std) = match mode {
        NormMode::After => mean_std(subset.iter().map(|&i| rewards[i] as f64)),
        NormMode::Before => mean_std(rewards.iter().map(|&r| r as f64)),
    };
    subset
        .iter()
        .map(|&i| ((rewards[i] as f64 - mean) / (std + SIGMA_EPS)) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{for_cases, vec_f32};

    /// "After" mode: every update batch has total advantage ~0 and unit
    /// σ (unless degenerate) — the property §A.3 argues matters.
    #[test]
    fn after_mode_is_standardised() {
        for_cases(300, |rng| {
            let n = rng.gen_range_inclusive(2, 39) as usize;
            let rewards = vec_f32(rng, n, -4.0, 4.0);
            let m = (rng.gen_range_inclusive(2, 19) as usize).min(n);
            let subset: Vec<usize> = (0..m).collect();
            let adv = subset_advantages(&rewards, &subset, NormMode::After);
            let sum: f32 = adv.iter().sum();
            assert!(sum.abs() < 1e-3, "sum {sum}");
            let var: f32 = adv.iter().map(|a| a * a).sum::<f32>() / m as f32;
            let subset_rewards: Vec<f64> = subset.iter().map(|&i| rewards[i] as f64).collect();
            let mean = subset_rewards.iter().sum::<f64>() / m as f64;
            let rvar = subset_rewards.iter().map(|r| (r - mean).powi(2)).sum::<f64>() / m as f64;
            if rvar > 1e-6 {
                assert!((var - 1.0).abs() < 1e-2, "var {var}");
            } else {
                assert!(var < 1e-3);
            }
        });
    }

    /// Degenerate groups give exactly-zero advantages in both modes.
    #[test]
    fn constant_rewards_zero_advantages() {
        for_cases(100, |rng| {
            let v = (rng.f64() * 10.0 - 5.0) as f32;
            let n = rng.gen_range_inclusive(2, 15) as usize;
            let rewards = vec![v; n];
            let subset: Vec<usize> = (0..n / 2).collect();
            for mode in [NormMode::After, NormMode::Before] {
                let adv = subset_advantages(&rewards, &subset, mode);
                assert!(adv.iter().all(|a| a.abs() < 1e-4), "{mode:?}");
            }
        });
    }

    /// Order preservation: higher reward -> strictly higher advantage.
    #[test]
    fn monotone_in_reward() {
        for_cases(200, |rng| {
            let n = rng.gen_range_inclusive(3, 29) as usize;
            let rewards = vec_f32(rng, n, -4.0, 4.0);
            let subset: Vec<usize> = (0..n).collect();
            for mode in [NormMode::After, NormMode::Before] {
                let adv = subset_advantages(&rewards, &subset, mode);
                for i in 0..n {
                    for j in 0..n {
                        if rewards[i] > rewards[j] + 1e-4 {
                            assert!(adv[i] > adv[j], "{mode:?}");
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn before_mode_uses_full_group_stats() {
        // group = {0, 10}, subset = {10}: Before centres on 5, After on 10.
        let rewards = vec![0.0f32, 10.0];
        let after = subset_advantages(&rewards, &[1], NormMode::After);
        let before = subset_advantages(&rewards, &[1], NormMode::Before);
        assert!(after[0].abs() < 1e-4); // singleton subset: σ=0 -> 0
        assert!((before[0] - 1.0).abs() < 1e-4); // (10-5)/5
    }

    #[test]
    fn modes_agree_when_subset_is_everything() {
        let rewards = vec![1.0f32, 2.0, 4.0, -1.0];
        let all: Vec<usize> = (0..4).collect();
        let a = subset_advantages(&rewards, &all, NormMode::After);
        let b = subset_advantages(&rewards, &all, NormMode::Before);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}
