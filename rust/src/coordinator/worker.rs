//! Simulated worker topology: leader-driven shard assignment.
//!
//! The paper's distributed settings (e)–(f) run 8 accelerators under
//! DeepSpeed ZeRO-2: every device generates a shard of the rollouts, then
//! the update phase proceeds in lock-step micro-batches with a gradient
//! all-reduce per micro-step. The hwsim clock charges the phases as if
//! the workers ran concurrently (inference: max over workers) or in
//! lock-step (updates: micro-steps × (compute + collective)); this module
//! provides the shard math that charging is built on.
//!
//! Since the staged-executor refactor the *inference* phase is also
//! genuinely parallel: [`crate::coordinator::exec::RolloutEngine`] runs
//! `workers` real OS threads (one PJRT engine replica each, capped at
//! host parallelism) pulling rollout calls off a shared queue. The update
//! phase still executes on the leader thread — exactly the asymmetry the
//! paper exploits (generation scales out, updates are memory-bound and
//! sequential).

/// A leader's view of `w` logical workers.
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    /// Logical worker count (>= 1).
    pub workers: usize,
}

impl WorkerPool {
    /// A pool of `workers` logical workers. Panics on 0.
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1);
        Self { workers }
    }

    /// Partition `items` round-robin; returns per-worker index lists.
    pub fn shard(&self, items: usize) -> Vec<Vec<usize>> {
        let mut shards = vec![Vec::new(); self.workers];
        for i in 0..items {
            shards[i % self.workers].push(i);
        }
        shards
    }

    /// Largest shard size (the straggler that bounds parallel phase time).
    pub fn max_shard(&self, items: usize) -> usize {
        items.div_ceil(self.workers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_cases;

    #[test]
    fn shards_partition_exactly() {
        for_cases(300, |rng| {
            let items = rng.gen_range_inclusive(0, 199) as usize;
            let w = rng.gen_range_inclusive(1, 15) as usize;
            let pool = WorkerPool::new(w);
            let shards = pool.shard(items);
            assert_eq!(shards.len(), w);
            let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
            all.sort_unstable();
            let want: Vec<usize> = (0..items).collect();
            assert_eq!(all, want);
            let max = shards.iter().map(|s| s.len()).max().unwrap_or(0);
            if items > 0 {
                assert_eq!(max, pool.max_shard(items));
            }
            // balance: no worker exceeds another by more than 1
            let min = shards.iter().map(|s| s.len()).min().unwrap_or(0);
            assert!(max - min <= 1);
        });
    }
}
