//! Per-prompt rollout groups and update-batch assembly.
//!
//! GRPO operates on *groups*: all `n` rollouts of one prompt share the
//! advantage-normalization statistics. PODS applies the selection pipeline
//! **within each prompt group** and then concatenates the selected rollouts
//! across prompts into the update batch (paper §3.2, Algorithm 1).
//!
//! Selection is delegated to a [`Pipeline`] from
//! [`crate::coordinator::select`]; each group gets a [`SelectionContext`]
//! carrying its rollouts, the target `m` and a deterministic per-group RNG
//! seed, so the assembled batch does not depend on group iteration order.

use crate::coordinator::advantage::{subset_advantages, NormMode};
use crate::coordinator::select::{Pipeline, SelectionContext, SelectionDiag};
use crate::reward::RewardBreakdown;
use crate::tasks::Problem;
use anyhow::Result;

/// One sampled rollout with everything the update phase needs.
#[derive(Debug, Clone)]
pub struct RolloutRecord {
    /// Full token row [T] (left-padded prompt + generation).
    pub tokens: Vec<i32>,
    /// Left-padding length of the prompt region.
    pub pad_len: i32,
    /// [G] 1.0 through EOS.
    pub gen_mask: Vec<f32>,
    /// [G] behaviour log-probs (π_fixed).
    pub old_lp: Vec<f32>,
    /// [G] reference-policy log-probs (zeros when KL is off).
    pub ref_lp: Vec<f32>,
    /// Generated tokens incl. EOS.
    pub gen_len: i32,
    /// Per-component reward breakdown.
    pub reward: RewardBreakdown,
    /// Weighted total reward.
    pub total_reward: f32,
    /// The rollout was aborted mid-decode by online pruning (`gen_len`,
    /// tokens and reward reflect the truncated stream). The doom-only
    /// contract guarantees selection never keeps a pruned rollout.
    pub pruned: bool,
}

/// All rollouts generated for one prompt in one iteration.
#[derive(Debug, Clone)]
pub struct PromptGroup {
    /// The prompt every rollout in the group answered.
    pub problem: Problem,
    /// The group's `n` rollouts, in rollout-index order.
    pub rollouts: Vec<RolloutRecord>,
}

impl PromptGroup {
    /// Synthetic group for tests, benches and examples: zeroed token
    /// tensors, the given rewards, and optional per-rollout generated
    /// lengths (default 4).
    pub fn synthetic(problem_idx: u64, rewards: &[f32], gen_lens: Option<&[i32]>) -> Self {
        let problem = crate::tasks::TaskKind::Arith.generate(crate::tasks::Split::Train, problem_idx);
        let rollouts = rewards
            .iter()
            .enumerate()
            .map(|(i, &r)| RolloutRecord {
                tokens: vec![0; 4],
                pad_len: 0,
                gen_mask: vec![1.0; 4],
                old_lp: vec![0.0; 4],
                ref_lp: vec![0.0; 4],
                gen_len: gen_lens.map_or(4, |l| l[i]),
                reward: RewardBreakdown { accuracy: 0.0, format: 0.0, tag_count: 0.0 },
                total_reward: r,
                pruned: false,
            })
            .collect();
        PromptGroup { problem, rollouts }
    }

    /// Total rewards, one per rollout.
    pub fn rewards(&self) -> Vec<f32> {
        self.rollouts.iter().map(|r| r.total_reward).collect()
    }

    /// Mean total reward (0 for an empty group).
    pub fn mean_reward(&self) -> f32 {
        if self.rollouts.is_empty() {
            return 0.0;
        }
        self.rewards().iter().sum::<f32>() / self.rollouts.len() as f32
    }

    /// Mean accuracy component (0 for an empty group).
    pub fn mean_accuracy(&self) -> f32 {
        if self.rollouts.is_empty() {
            return 0.0;
        }
        self.rollouts.iter().map(|r| r.reward.accuracy).sum::<f32>() / self.rollouts.len() as f32
    }

    /// Mean generated length (0 for an empty group).
    pub fn mean_gen_len(&self) -> f32 {
        if self.rollouts.is_empty() {
            return 0.0;
        }
        self.rollouts.iter().map(|r| r.gen_len as f32).sum::<f32>() / self.rollouts.len() as f32
    }
}

/// One selected rollout with its normalized advantage — the unit the
/// micro-batcher packs into `grad` calls.
#[derive(Debug, Clone)]
pub struct SelectedRollout {
    /// Index of the rollout's group in the iteration's batch.
    pub group_idx: usize,
    /// Index of the rollout within its group.
    pub rollout_idx: usize,
    /// Normalized advantage (see `coordinator::advantage`).
    pub advantage: f32,
}

/// Batch-level selection telemetry, aggregated over the iteration's groups
/// from the per-group [`SelectionDiag`]s. Recorded into the train CSV.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchSelectionStats {
    /// Non-empty groups seen.
    pub groups: usize,
    /// Groups whose selection came back empty (e.g. zero-signal groups
    /// removed by `drop_zero_variance`) — they contribute nothing to the
    /// update.
    pub groups_dropped: usize,
    /// Generated tokens in kept rollouts (update-phase token budget).
    pub tokens_kept: usize,
    /// Generated tokens in dropped rollouts.
    pub tokens_dropped: usize,
}

/// Run the selection pipeline within each group, normalize advantages per
/// `mode`, and concatenate across groups (Algorithm 1 for a multi-prompt
/// batch).
///
/// `m = None` selects every rollout without invoking the pipeline — the
/// vanilla GRPO / GRPO-GA schedules. With `m = Some(_)` (GRPO-PODS) the
/// pipeline always runs, even when `m >= n`: exact stages then keep
/// everything, but filter stages (`drop_zero_variance`, `prune`) still
/// apply. `run_seed` and `iter` seed each group's selection RNG from
/// `(run_seed, iter, prompt_id)`, so stochastic selectors are replayable
/// independent of group order.
pub fn build_update_batch(
    groups: &[PromptGroup],
    pipeline: &Pipeline,
    m: Option<usize>,
    mode: NormMode,
    run_seed: u64,
    iter: u64,
) -> Result<(Vec<SelectedRollout>, BatchSelectionStats)> {
    let mut out = Vec::new();
    let mut stats = BatchSelectionStats::default();
    for (gi, group) in groups.iter().enumerate() {
        let n = group.rollouts.len();
        if n == 0 {
            continue;
        }
        stats.groups += 1;
        let rewards = group.rewards();
        let (subset, diag) = match m {
            Some(mm) => {
                let ctx = SelectionContext::new(group, mm, run_seed, iter);
                let sel = pipeline.select(&ctx)?;
                (sel.kept, sel.diag)
            }
            None => {
                let all: Vec<usize> = (0..n).collect();
                let diag = SelectionDiag::for_kept(group, &all);
                (all, diag)
            }
        };
        stats.tokens_kept += diag.tokens_kept;
        stats.tokens_dropped += diag.tokens_dropped;
        if subset.is_empty() {
            stats.groups_dropped += 1;
            continue;
        }
        let advs = subset_advantages(&rewards, &subset, mode);
        for (ri, adv) in subset.into_iter().zip(advs) {
            out.push(SelectedRollout { group_idx: gi, rollout_idx: ri, advantage: adv });
        }
    }
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_group(problem_idx: u64, rewards: &[f32]) -> PromptGroup {
        PromptGroup::synthetic(problem_idx, rewards, None)
    }

    fn max_variance() -> Pipeline {
        Pipeline::parse_default("max_variance").unwrap()
    }

    #[test]
    fn selects_m_per_group_and_concatenates() {
        let groups =
            vec![fake_group(0, &[0.0, 1.0, 2.0, 3.0]), fake_group(1, &[5.0, 5.0, 0.0, 1.0])];
        let (batch, stats) =
            build_update_batch(&groups, &max_variance(), Some(2), NormMode::After, 0, 0).unwrap();
        assert_eq!(batch.len(), 4);
        assert!(batch.iter().take(2).all(|s| s.group_idx == 0));
        assert!(batch.iter().skip(2).all(|s| s.group_idx == 1));
        // max-variance with m=2 on [0,1,2,3] picks 0 and 3
        let picked: Vec<usize> = batch.iter().take(2).map(|s| s.rollout_idx).collect();
        assert!(picked.contains(&0) && picked.contains(&3));
        assert_eq!(stats.groups, 2);
        assert_eq!(stats.groups_dropped, 0);
        assert_eq!(stats.tokens_kept, 16);
        assert_eq!(stats.tokens_dropped, 16);
    }

    #[test]
    fn m_none_selects_all_with_group_normalization() {
        let groups = vec![fake_group(0, &[1.0, 3.0])];
        let (batch, stats) =
            build_update_batch(&groups, &max_variance(), None, NormMode::After, 0, 0).unwrap();
        assert_eq!(batch.len(), 2);
        let sum: f32 = batch.iter().map(|s| s.advantage).sum();
        assert!(sum.abs() < 1e-4);
        assert!(batch[1].advantage > batch[0].advantage);
        assert_eq!(stats.tokens_dropped, 0);
    }

    #[test]
    fn advantages_normalized_within_group_not_across() {
        // two groups with very different reward scales: each must be
        // standardized on its own
        let groups = vec![fake_group(0, &[0.0, 1.0]), fake_group(1, &[100.0, 200.0])];
        let (batch, _) =
            build_update_batch(&groups, &max_variance(), None, NormMode::After, 0, 0).unwrap();
        let g0: Vec<f32> = batch.iter().filter(|s| s.group_idx == 0).map(|s| s.advantage).collect();
        let g1: Vec<f32> = batch.iter().filter(|s| s.group_idx == 1).map(|s| s.advantage).collect();
        for (a, b) in g0.iter().zip(&g1) {
            assert!((a - b).abs() < 1e-3, "per-group standardization should equalize: {a} vs {b}");
        }
    }

    /// Satellite: stochastic selection is seeded per group from
    /// `(run_seed, iter, prompt_id)` — permuting the group order must not
    /// change what each prompt's group keeps.
    #[test]
    fn random_selection_is_group_order_independent() {
        let a = fake_group(10, &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        let b = fake_group(11, &[7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0, 0.0]);
        let pipeline = Pipeline::parse_default("random").unwrap();
        let kept_by_id = |groups: &[PromptGroup]| {
            let (batch, _) =
                build_update_batch(groups, &pipeline, Some(3), NormMode::After, 7, 5).unwrap();
            let mut map: std::collections::BTreeMap<u64, Vec<usize>> = Default::default();
            for s in batch {
                map.entry(groups[s.group_idx].problem.id).or_default().push(s.rollout_idx);
            }
            map
        };
        let ab = kept_by_id(&[a.clone(), b.clone()]);
        let ba = kept_by_id(&[b, a]);
        assert_eq!(ab, ba, "selection must not depend on group iteration order");
    }

    /// Filter stages apply whenever `m` is set — including `m == n`,
    /// where exact stages alone would keep everything.
    #[test]
    fn filters_apply_even_when_m_equals_n() {
        let groups = vec![fake_group(0, &[2.0, 2.0, 2.0, 2.0]), fake_group(1, &[0.0, 1.0, 2.0, 3.0])];
        let pipeline = Pipeline::parse_default("drop_zero_variance | max_variance").unwrap();
        let (batch, stats) =
            build_update_batch(&groups, &pipeline, Some(4), NormMode::After, 0, 0).unwrap();
        assert_eq!(stats.groups_dropped, 1, "zero-signal group filtered at m == n");
        assert_eq!(batch.len(), 4);
        assert!(batch.iter().all(|s| s.group_idx == 1));
    }

    #[test]
    fn zero_variance_groups_are_dropped_from_the_batch() {
        let groups = vec![fake_group(0, &[2.0, 2.0, 2.0, 2.0]), fake_group(1, &[0.0, 1.0, 2.0, 3.0])];
        let pipeline = Pipeline::parse_default("drop_zero_variance | max_variance").unwrap();
        let (batch, stats) =
            build_update_batch(&groups, &pipeline, Some(2), NormMode::After, 0, 0).unwrap();
        assert_eq!(stats.groups, 2);
        assert_eq!(stats.groups_dropped, 1);
        assert_eq!(batch.len(), 2);
        assert!(batch.iter().all(|s| s.group_idx == 1), "only the informative group trains");
        assert_eq!(stats.tokens_kept, 8);
        assert_eq!(stats.tokens_dropped, 24);
    }
}
