//! Per-prompt rollout groups and update-batch assembly.
//!
//! GRPO operates on *groups*: all `n` rollouts of one prompt share the
//! advantage-normalization statistics. PODS applies the down-sampling rule
//! **within each prompt group** and then concatenates the selected rollouts
//! across prompts into the update batch (paper §3.2, Algorithm 1).

use crate::coordinator::advantage::{subset_advantages, NormMode};
use crate::coordinator::downsample::Rule;
use crate::reward::RewardBreakdown;
use crate::tasks::Problem;
use crate::util::rng::Rng;

/// One sampled rollout with everything the update phase needs.
#[derive(Debug, Clone)]
pub struct RolloutRecord {
    /// Full token row [T] (left-padded prompt + generation).
    pub tokens: Vec<i32>,
    pub pad_len: i32,
    /// [G] 1.0 through EOS.
    pub gen_mask: Vec<f32>,
    /// [G] behaviour log-probs (π_fixed).
    pub old_lp: Vec<f32>,
    /// [G] reference-policy log-probs (zeros when KL is off).
    pub ref_lp: Vec<f32>,
    pub gen_len: i32,
    pub reward: RewardBreakdown,
    pub total_reward: f32,
}

/// All rollouts generated for one prompt in one iteration.
#[derive(Debug, Clone)]
pub struct PromptGroup {
    pub problem: Problem,
    pub rollouts: Vec<RolloutRecord>,
}

impl PromptGroup {
    pub fn rewards(&self) -> Vec<f32> {
        self.rollouts.iter().map(|r| r.total_reward).collect()
    }

    pub fn mean_reward(&self) -> f32 {
        if self.rollouts.is_empty() {
            return 0.0;
        }
        self.rewards().iter().sum::<f32>() / self.rollouts.len() as f32
    }

    pub fn mean_accuracy(&self) -> f32 {
        if self.rollouts.is_empty() {
            return 0.0;
        }
        self.rollouts.iter().map(|r| r.reward.accuracy).sum::<f32>() / self.rollouts.len() as f32
    }

    pub fn mean_gen_len(&self) -> f32 {
        if self.rollouts.is_empty() {
            return 0.0;
        }
        self.rollouts.iter().map(|r| r.gen_len as f32).sum::<f32>() / self.rollouts.len() as f32
    }
}

/// One selected rollout with its normalized advantage — the unit the
/// micro-batcher packs into `grad` calls.
#[derive(Debug, Clone)]
pub struct SelectedRollout {
    pub group_idx: usize,
    pub rollout_idx: usize,
    pub advantage: f32,
}

/// Apply `rule` within each group, normalize advantages per `mode`, and
/// concatenate across groups (Algorithm 1 for a multi-prompt batch).
///
/// `m = None` selects every rollout (vanilla GRPO / GRPO-GA schedules).
pub fn build_update_batch(
    groups: &[PromptGroup],
    rule: Rule,
    m: Option<usize>,
    mode: NormMode,
    rng: &mut Rng,
) -> Vec<SelectedRollout> {
    let mut out = Vec::new();
    for (gi, group) in groups.iter().enumerate() {
        let rewards = group.rewards();
        let n = rewards.len();
        if n == 0 {
            continue;
        }
        let subset: Vec<usize> = match m {
            Some(m) if m < n => rule.select(&rewards, m, rng),
            _ => (0..n).collect(),
        };
        let advs = subset_advantages(&rewards, &subset, mode);
        for (ri, adv) in subset.into_iter().zip(advs) {
            out.push(SelectedRollout { group_idx: gi, rollout_idx: ri, advantage: adv });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::{Split, TaskKind};

    fn fake_group(rewards: &[f32]) -> PromptGroup {
        let problem = TaskKind::Arith.generate(Split::Train, 0);
        let rollouts = rewards
            .iter()
            .map(|&r| RolloutRecord {
                tokens: vec![0; 8],
                pad_len: 0,
                gen_mask: vec![1.0; 4],
                old_lp: vec![0.0; 4],
                ref_lp: vec![0.0; 4],
                gen_len: 4,
                reward: RewardBreakdown { accuracy: 0.0, format: 0.0, tag_count: 0.0 },
                total_reward: r,
            })
            .collect();
        PromptGroup { problem, rollouts }
    }

    #[test]
    fn selects_m_per_group_and_concatenates() {
        let groups = vec![fake_group(&[0.0, 1.0, 2.0, 3.0]), fake_group(&[5.0, 5.0, 0.0, 1.0])];
        let mut rng = Rng::seed_from_u64(0);
        let batch = build_update_batch(&groups, Rule::MaxVariance, Some(2), NormMode::After, &mut rng);
        assert_eq!(batch.len(), 4);
        assert!(batch.iter().take(2).all(|s| s.group_idx == 0));
        assert!(batch.iter().skip(2).all(|s| s.group_idx == 1));
        // max-variance with m=2 on [0,1,2,3] picks 0 and 3
        let picked: Vec<usize> = batch.iter().take(2).map(|s| s.rollout_idx).collect();
        assert!(picked.contains(&0) && picked.contains(&3));
    }

    #[test]
    fn m_none_selects_all_with_group_normalization() {
        let groups = vec![fake_group(&[1.0, 3.0])];
        let mut rng = Rng::seed_from_u64(0);
        let batch = build_update_batch(&groups, Rule::MaxVariance, None, NormMode::After, &mut rng);
        assert_eq!(batch.len(), 2);
        let sum: f32 = batch.iter().map(|s| s.advantage).sum();
        assert!(sum.abs() < 1e-4);
        assert!(batch[1].advantage > batch[0].advantage);
    }

    #[test]
    fn advantages_normalized_within_group_not_across() {
        // two groups with very different reward scales: each must be
        // standardized on its own
        let groups = vec![fake_group(&[0.0, 1.0]), fake_group(&[100.0, 200.0])];
        let mut rng = Rng::seed_from_u64(0);
        let batch = build_update_batch(&groups, Rule::MaxVariance, None, NormMode::After, &mut rng);
        let g0: Vec<f32> = batch.iter().filter(|s| s.group_idx == 0).map(|s| s.advantage).collect();
        let g1: Vec<f32> = batch.iter().filter(|s| s.group_idx == 1).map(|s| s.advantage).collect();
        for (a, b) in g0.iter().zip(&g1) {
            assert!((a - b).abs() < 1e-3, "per-group standardization should equalize: {a} vs {b}");
        }
    }
}
