//! Down-sampling kernels (paper §3.2–3.3) — the core algorithmic
//! contribution, exposed to the training loop through the pluggable
//! [`crate::coordinator::select`] subsystem (the old closed `Rule` enum
//! was replaced by selector pipelines; the config strings
//! `max_variance` / `max_reward` / `random` / `percentile` still resolve
//! to these exact functions).
//!
//! Given `n` rollout rewards and an update size `m`, each kernel returns
//! the indices to keep for the policy update:
//!
//! * [`max_variance`] — Algorithm 2: by Lemma 3.1 the variance-maximising
//!   subset is always the `m-k` lowest + `k` highest rewards of the sorted
//!   order for some `k`, so scanning all `m+1` splits with prefix sums gives
//!   the exact optimum in `O(n log n)` (sort) + `O(m)` (scan).
//! * [`max_reward`] — top-`m` rewards (§3.2, shown harmful in Fig. 5).
//! * [`random`] — uniform without replacement (unbiased GRPO-on-`m`).
//! * [`percentile`] — the `(i+0.5)/m` quantiles of the reward distribution.
//!
//! All kernels are deterministic given their inputs (ties broken by index;
//! `random` takes an explicit RNG), which makes experiments replayable.
//! Degenerate sizes (`m == 0` or `m > n`) are errors, not UB or panics —
//! the selector layer clamps before calling, so a kernel error always
//! indicates a caller bug.
//!
//! An exhaustive `O(C(n, m))` oracle lives in the test module; proptest
//! verifies `max_variance` against it for all small instances.

use crate::util::rng::Rng;
use anyhow::{ensure, Result};

/// Indices of rewards sorted ascending, ties broken by original index
/// (deterministic, and matches the stable-argsort the paper's code uses).
fn argsort(rewards: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..rewards.len()).collect();
    idx.sort_by(|&a, &b| {
        rewards[a]
            .partial_cmp(&rewards[b])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx
}

/// Variance of `rewards[idx]` over a prefix/suffix split: the `lo` smallest
/// plus the `hi` largest, via precomputed prefix sums. Population variance.
#[inline]
fn split_variance(pre_s: &[f64], pre_s2: &[f64], n: usize, lo: usize, hi: usize) -> f64 {
    let m = (lo + hi) as f64;
    let s = pre_s[lo] + (pre_s[n] - pre_s[n - hi]);
    let s2 = pre_s2[lo] + (pre_s2[n] - pre_s2[n - hi]);
    s2 / m - (s / m) * (s / m)
}

/// **Algorithm 2** — max-variance down-sampling in `O(n log n)`.
///
/// Returns the indices (ascending by reward, lowest block then highest
/// block) of the size-`m` subset maximising empirical reward variance.
/// Errors unless `0 < m <= n`.
pub fn max_variance(rewards: &[f32], m: usize) -> Result<Vec<usize>> {
    let n = rewards.len();
    ensure!(m > 0 && m <= n, "max_variance: m must be in 1..=n (got m={m}, n={n})");
    let order = argsort(rewards);
    // prefix sums over the sorted rewards
    let mut pre_s = vec![0f64; n + 1];
    let mut pre_s2 = vec![0f64; n + 1];
    for (i, &oi) in order.iter().enumerate() {
        let r = rewards[oi] as f64;
        pre_s[i + 1] = pre_s[i] + r;
        pre_s2[i + 1] = pre_s2[i] + r * r;
    }
    // scan k = number of elements taken from the top
    let mut best_k = 0usize;
    let mut best_var = f64::NEG_INFINITY;
    for k in 0..=m {
        let lo = m - k;
        // prefix and suffix must not overlap
        if lo + k > n {
            continue;
        }
        let var = split_variance(&pre_s, &pre_s2, n, lo, k);
        if var > best_var + 1e-12 {
            best_var = var;
            best_k = k;
        }
    }
    let lo = m - best_k;
    let mut out: Vec<usize> = order[..lo].to_vec();
    out.extend_from_slice(&order[n - best_k..]);
    Ok(out)
}

/// Max-reward down-sampling: the `m` highest rewards.
/// Errors unless `0 < m <= n`.
pub fn max_reward(rewards: &[f32], m: usize) -> Result<Vec<usize>> {
    let n = rewards.len();
    ensure!(m > 0 && m <= n, "max_reward: m must be in 1..=n (got m={m}, n={n})");
    let order = argsort(rewards);
    Ok(order[n - m..].to_vec())
}

/// Random down-sampling: uniform `m`-subset without replacement.
/// Errors unless `0 < m <= n`.
pub fn random(n: usize, m: usize, rng: &mut Rng) -> Result<Vec<usize>> {
    ensure!(m > 0 && m <= n, "random: m must be in 1..=n (got m={m}, n={n})");
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    idx.truncate(m);
    idx.sort_unstable();
    Ok(idx)
}

/// Percentile down-sampling: the `(i + 0.5)/m` quantiles of the reward
/// distribution, i.e. sorted positions `floor((i + 0.5) * n / m)`.
/// Errors unless `0 < m <= n`.
pub fn percentile(rewards: &[f32], m: usize) -> Result<Vec<usize>> {
    let n = rewards.len();
    ensure!(m > 0 && m <= n, "percentile: m must be in 1..=n (got m={m}, n={n})");
    let order = argsort(rewards);
    let mut out = Vec::with_capacity(m);
    let mut last = usize::MAX;
    for i in 0..m {
        let mut pos = ((i as f64 + 0.5) * n as f64 / m as f64).floor() as usize;
        pos = pos.min(n - 1);
        // guarantee m distinct picks even when quantiles collide
        if last != usize::MAX && pos <= last {
            pos = (last + 1).min(n - 1);
        }
        out.push(order[pos]);
        last = pos;
    }
    // if clamping at the top collided, backfill from unused sorted slots
    out.dedup();
    if out.len() < m {
        let used: std::collections::HashSet<usize> = out.iter().copied().collect();
        for &o in order.iter().rev() {
            if out.len() == m {
                break;
            }
            if !used.contains(&o) {
                out.push(o);
            }
        }
    }
    Ok(out)
}

/// Population variance of the selected rewards (used by tests/benches and
/// the scheduler's telemetry).
pub fn subset_variance(rewards: &[f32], subset: &[usize]) -> f64 {
    let m = subset.len() as f64;
    if subset.is_empty() {
        return 0.0;
    }
    let s: f64 = subset.iter().map(|&i| rewards[i] as f64).sum();
    let s2: f64 = subset.iter().map(|&i| (rewards[i] as f64).powi(2)).sum();
    s2 / m - (s / m) * (s / m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{for_cases, vec_f32};
    use crate::util::rng::Rng;

    /// Exhaustive O(C(n, m)) oracle.
    fn oracle_max_variance(rewards: &[f32], m: usize) -> f64 {
        fn rec(rewards: &[f32], start: usize, left: usize, cur: &mut Vec<usize>, best: &mut f64) {
            if left == 0 {
                let v = subset_variance(rewards, cur);
                if v > *best {
                    *best = v;
                }
                return;
            }
            if rewards.len() - start < left {
                return;
            }
            for i in start..rewards.len() {
                cur.push(i);
                rec(rewards, i + 1, left - 1, cur, best);
                cur.pop();
            }
        }
        let mut best = f64::NEG_INFINITY;
        rec(rewards, 0, m, &mut Vec::new(), &mut best);
        best
    }

    /// Theorem 1: Algorithm 2 is exactly optimal (all n <= 10, any m).
    #[test]
    fn max_variance_matches_oracle() {
        for_cases(300, |rng| {
            let n = rng.gen_range_inclusive(1, 9) as usize;
            let rewards = vec_f32(rng, n, -5.0, 5.0);
            let m = rng.gen_range_inclusive(1, n as i64) as usize;
            let got = max_variance(&rewards, m).unwrap();
            assert_eq!(got.len(), m);
            let set: std::collections::HashSet<_> = got.iter().collect();
            assert_eq!(set.len(), m, "duplicates in {got:?}");
            let got_var = subset_variance(&rewards, &got);
            let want = oracle_max_variance(&rewards, m);
            assert!((got_var - want).abs() < 1e-9, "got {got_var}, oracle {want} for {rewards:?} m={m}");
        });
    }

    /// Lemma 3.1: the selection is a prefix + suffix of the sorted order.
    #[test]
    fn max_variance_is_prefix_suffix() {
        for_cases(300, |rng| {
            let n = rng.gen_range_inclusive(2, 49) as usize;
            let rewards = vec_f32(rng, n, -100.0, 100.0);
            let m = rng.gen_range_inclusive(1, n as i64) as usize;
            let got = max_variance(&rewards, m).unwrap();
            let order = argsort(&rewards);
            let rank: std::collections::HashMap<usize, usize> =
                order.iter().enumerate().map(|(r, &i)| (i, r)).collect();
            let mut ranks: Vec<usize> = got.iter().map(|i| rank[i]).collect();
            ranks.sort_unstable();
            // ranks must form {0..split-1} ∪ {n-(m-split)..n-1}
            let mut split = ranks.len();
            for (j, &r) in ranks.iter().enumerate() {
                if r != j {
                    split = j;
                    break;
                }
            }
            for (j, &r) in ranks.iter().enumerate().skip(split) {
                assert_eq!(r, n - (m - j), "not a prefix+suffix: {ranks:?} (n={n}, m={m})");
            }
        });
    }

    /// Theorem 2: binary rewards -> the k-split the theorem prescribes.
    #[test]
    fn binary_rewards_half_split() {
        for_cases(300, |rng| {
            let n = rng.gen_range_inclusive(4, 39) as usize;
            let rewards: Vec<f32> = (0..n).map(|_| if rng.gen_bool(0.5) { 1.0 } else { 0.0 }).collect();
            let m_half = rng.gen_range_inclusive(1, 9) as usize;
            let m = (2 * m_half).min(n - (n % 2));
            if m == 0 {
                return;
            }
            let got = max_variance(&rewards, m).unwrap();
            let pos = rewards.iter().filter(|&&r| r > 0.5).count();
            let neg = n - pos;
            // Theorem 2's optimal count of ones in the subset
            let opt_k = (m / 2).max(m.saturating_sub(neg)).min(pos);
            let opt_var = {
                let ones = opt_k as f64;
                let zeros = (m - opt_k) as f64;
                let mean = ones / m as f64;
                (ones * (1.0 - mean).powi(2) + zeros * mean.powi(2)) / m as f64
            };
            assert!(
                (subset_variance(&rewards, &got) - opt_var).abs() < 1e-9,
                "pos={pos} neg={neg} m={m}"
            );
        });
    }

    /// All kernels return m distinct valid indices on valid inputs.
    #[test]
    fn all_kernels_return_valid_subsets() {
        for_cases(300, |rng| {
            let n = rng.gen_range_inclusive(1, 63) as usize;
            let rewards = vec_f32(rng, n, -3.0, 3.0);
            let m = rng.gen_range_inclusive(1, n as i64) as usize;
            let mut sel_rng = Rng::seed_from_u64(rng.next_u64());
            let all = [
                max_variance(&rewards, m).unwrap(),
                max_reward(&rewards, m).unwrap(),
                random(n, m, &mut sel_rng).unwrap(),
                percentile(&rewards, m).unwrap(),
            ];
            for got in all {
                assert_eq!(got.len(), m);
                let set: std::collections::HashSet<_> = got.iter().collect();
                assert_eq!(set.len(), m, "dup in {got:?}");
                assert!(got.iter().all(|&i| i < n), "oob in {got:?}");
            }
        });
    }

    /// Satellite: degenerate `m == 0` and `m > n` are proper errors on
    /// every kernel (the seed implementation panicked via assert!).
    #[test]
    fn m_zero_is_an_error() {
        let r = vec![1.0f32, 2.0, 3.0];
        let mut rng = Rng::seed_from_u64(0);
        assert!(max_variance(&r, 0).is_err());
        assert!(max_reward(&r, 0).is_err());
        assert!(random(r.len(), 0, &mut rng).is_err());
        assert!(percentile(&r, 0).is_err());
    }

    #[test]
    fn m_above_n_is_an_error() {
        let r = vec![1.0f32, 2.0, 3.0];
        let mut rng = Rng::seed_from_u64(0);
        assert!(max_variance(&r, 4).is_err());
        assert!(max_reward(&r, 4).is_err());
        assert!(random(r.len(), 4, &mut rng).is_err());
        assert!(percentile(&r, 4).is_err());
        // empty input: every m is degenerate
        assert!(max_variance(&[], 1).is_err());
        assert!(percentile(&[], 1).is_err());
        // and the error message names the bounds
        let msg = max_variance(&r, 9).unwrap_err().to_string();
        assert!(msg.contains("m=9") && msg.contains("n=3"), "{msg}");
    }

    #[test]
    fn max_reward_picks_top() {
        let r = vec![0.1, 3.0, 2.0, -1.0, 2.5];
        let mut got = max_reward(&r, 2).unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![1, 4]);
    }

    #[test]
    fn percentile_m_eq_n_selects_everything() {
        let r = vec![5.0, 1.0, 3.0, 2.0];
        let mut got = percentile(&r, 4).unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn percentile_spreads_over_spectrum() {
        let r: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let got = percentile(&r, 4).unwrap();
        let mut vals: Vec<f32> = got.iter().map(|&i| r[i]).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(vals, vec![12.0, 37.0, 62.0, 87.0]);
    }

    #[test]
    fn random_m_eq_n_is_identity_set() {
        let mut rng = Rng::seed_from_u64(0);
        let got = random(6, 6, &mut rng).unwrap();
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn max_variance_binary_even_split() {
        // 6 ones, 6 zeros, m=4 -> 2+2
        let mut r = vec![1.0f32; 6];
        r.extend(vec![0.0f32; 6]);
        let got = max_variance(&r, 4).unwrap();
        let ones = got.iter().filter(|&&i| r[i] > 0.5).count();
        assert_eq!(ones, 2);
        assert!((subset_variance(&r, &got) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn max_variance_all_equal_rewards() {
        let r = vec![2.0f32; 8];
        let got = max_variance(&r, 3).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(subset_variance(&r, &got), 0.0);
    }

    #[test]
    fn max_variance_m_eq_n() {
        let r = vec![1.0, 2.0, 3.0];
        let mut got = max_variance(&r, 3).unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2]);
    }

    #[test]
    fn deterministic_under_ties() {
        let r = vec![1.0f32, 1.0, 0.0, 0.0, 1.0, 0.0];
        let a = max_variance(&r, 4).unwrap();
        let b = max_variance(&r, 4).unwrap();
        assert_eq!(a, b);
    }
}
