//! Pluggable rollout-selection subsystem — the open successor of the old
//! closed `Rule` enum.
//!
//! PODS' core contribution is *which rollouts to train on*. The seed tree
//! hard-coded that decision as an enum over bare reward scalars; every new
//! selection idea (token-cost-aware pruning, zero-signal-group filtering,
//! …) meant editing the enum, the batch assembler and every experiment.
//! This module makes selection a first-class API instead:
//!
//! * [`SelectionContext`] — what a selector may look at: the full
//!   [`PromptGroup`] (rewards, generation lengths, log-probs), the target
//!   update size `m`, the iteration number, and a **per-group
//!   deterministic RNG** derived from `(run_seed, iter, prompt_id)` so
//!   stochastic selectors replay identically regardless of the order in
//!   which groups are processed.
//! * [`Selector`] — one selection stage. [`StageKind::Exact`] stages cut
//!   the candidate set to exactly `min(m, candidates)`; [`StageKind::Filter`]
//!   stages may drop any number of candidates (including all of them,
//!   which drops the whole group from the update).
//! * [`Pipeline`] — a `|`-composed chain of stages parsed from a config
//!   spec string, e.g. `"drop_zero_variance | max_variance"` or
//!   `"prune(max_tokens=4096) | percentile"`. See [`spec`] for the
//!   grammar and the [`Registry`] that maps names to factories.
//! * [`Selection`] — the kept indices plus per-group
//!   [`SelectionDiag`] diagnostics (achieved reward variance, token
//!   budget spent/saved) that the metrics layer records every iteration.
//!
//! The four legacy rules (`max_variance`, `max_reward`, `random`,
//! `percentile`) are registered as built-in selectors and produce
//! selections identical to the seed implementation (golden-tested in
//! `rust/tests/selector_golden.rs`); the numeric kernels themselves still
//! live in [`crate::coordinator::downsample`].

pub mod filters;
pub mod legacy;
pub mod online;
pub mod spec;

pub use online::{GroupVerdicts, OnlineSelector, StageBound, Verdict};
pub use spec::{default_registry, Registry, SpecArgs};

use crate::coordinator::downsample::subset_variance;
use crate::coordinator::group::PromptGroup;
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Everything a selector may condition on for one prompt group.
#[derive(Debug, Clone, Copy)]
pub struct SelectionContext<'a> {
    /// The full group: rewards, generation lengths, behaviour log-probs.
    pub group: &'a PromptGroup,
    /// Target update size (the paper's `m`). Stages clamp to the candidate
    /// count, so `m > n` selects everything rather than erroring.
    pub m: usize,
    /// Run seed — one axis of the per-group RNG derivation.
    pub run_seed: u64,
    /// Training iteration — second axis of the per-group RNG derivation.
    pub iter: u64,
    /// Position of the current stage in its pipeline (set by
    /// [`Pipeline::select`]). Folded into [`Self::rng`] so two stochastic
    /// stages in one pipeline draw decorrelated streams; stage 0 keeps
    /// the bare `group_seed`, matching the documented seeding.
    pub stage: u64,
}

impl<'a> SelectionContext<'a> {
    /// Context for the first stage of a pipeline over `group`.
    pub fn new(group: &'a PromptGroup, m: usize, run_seed: u64, iter: u64) -> Self {
        Self { group, m, run_seed, iter, stage: 0 }
    }

    /// Number of rollouts in the group (the paper's `n`).
    pub fn n(&self) -> usize {
        self.group.rollouts.len()
    }

    /// Deterministic id of the group's prompt.
    pub fn prompt_id(&self) -> u64 {
        self.group.problem.id
    }

    /// Total rewards, one per rollout.
    pub fn rewards(&self) -> Vec<f32> {
        self.group.rewards()
    }

    /// Generated lengths (tokens incl. EOS), one per rollout.
    pub fn gen_lens(&self) -> Vec<usize> {
        self.group.rollouts.iter().map(|r| r.gen_len.max(0) as usize).collect()
    }

    /// Per-group deterministic RNG, seeded from
    /// `(run_seed, iter, prompt_id)` — plus the stage position for stages
    /// past the first, so stochastic stages in one pipeline are mutually
    /// decorrelated. Two calls return identically-seeded generators, and
    /// the stream does not depend on how many groups were processed
    /// before this one — stochastic selections are replayable independent
    /// of group iteration order.
    pub fn rng(&self) -> Rng {
        let mut seed = group_seed(self.run_seed, self.iter, self.prompt_id());
        if self.stage > 0 {
            seed = group_seed(seed, self.stage, 0x57A6E);
        }
        Rng::seed_from_u64(seed)
    }
}

/// Deterministic per-group selection seed (splitmix64-style finalizer over
/// the three axes plus a domain salt so selection never shares a stream
/// with rollout sampling).
pub fn group_seed(run_seed: u64, iter: u64, prompt_id: u64) -> u64 {
    let mut z = run_seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(iter.wrapping_mul(0xBF58_476D_1CE4_E5B9))
        .wrapping_add(prompt_id.wrapping_mul(0x94D0_49BB_1331_11EB))
        .wrapping_add(0x5E1E_C70A_0000_0001); // selection-domain salt
    z ^= z >> 30;
    z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// What a stage guarantees about its output size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    /// May drop any number of candidates (including all — dropping the
    /// whole group); never guarantees reaching `m`.
    Filter,
    /// Returns exactly `min(m, candidates.len())` indices.
    Exact,
}

/// One selection stage. Implementations must return a subset of
/// `candidates` (distinct, in-range indices into `ctx.group.rollouts`);
/// the [`Pipeline`] validates this after every stage.
pub trait Selector: std::fmt::Debug + Send + Sync {
    /// Registry name (what the spec grammar calls this stage).
    fn name(&self) -> &str;

    /// Output-size contract; see [`StageKind`].
    fn kind(&self) -> StageKind;

    /// Reduce `candidates` to the indices to keep. `candidates` is always
    /// distinct and in-range; the first stage of a pipeline receives
    /// `0..n`.
    fn select(&self, ctx: &SelectionContext, candidates: &[usize]) -> Result<Vec<usize>>;

    /// What this stage can soundly guarantee about rows *mid-generation*
    /// for online pruning (see [`online`]). The default — no bound — is
    /// always sound: opaque stages never cause an abort. Implementations
    /// must only return a stronger bound when the stage's drop decision is
    /// provable from reward brackets and monotone lengths alone.
    fn online_bound(&self) -> online::StageBound {
        online::StageBound::Opaque
    }
}

/// Per-group selection diagnostics, recorded every iteration.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SelectionDiag {
    /// Rollouts the pipeline saw (the group's `n`).
    pub candidates: usize,
    /// Rollouts kept for the update.
    pub kept: usize,
    /// Mean total reward of the kept rollouts.
    pub reward_mean: f64,
    /// Population reward variance of the kept rollouts — the quantity
    /// Algorithm 2 maximises.
    pub reward_variance: f64,
    /// Generated tokens in the kept rollouts (update-phase token budget).
    pub tokens_kept: usize,
    /// Generated tokens in the dropped rollouts (inference spend that the
    /// update phase does not pay for again).
    pub tokens_dropped: usize,
}

impl SelectionDiag {
    /// Compute diagnostics for `kept` indices of `group`.
    pub fn for_kept(group: &PromptGroup, kept: &[usize]) -> Self {
        let rewards = group.rewards();
        let total_tokens: usize =
            group.rollouts.iter().map(|r| r.gen_len.max(0) as usize).sum();
        let tokens_kept: usize =
            kept.iter().map(|&i| group.rollouts[i].gen_len.max(0) as usize).sum();
        let reward_mean = if kept.is_empty() {
            0.0
        } else {
            kept.iter().map(|&i| rewards[i] as f64).sum::<f64>() / kept.len() as f64
        };
        Self {
            candidates: group.rollouts.len(),
            kept: kept.len(),
            reward_mean,
            reward_variance: subset_variance(&rewards, kept),
            tokens_kept,
            tokens_dropped: total_tokens - tokens_kept,
        }
    }
}

/// Result of running a pipeline on one group.
#[derive(Debug, Clone, Default)]
pub struct Selection {
    /// Indices into `group.rollouts` to train on. Order is
    /// selector-defined (e.g. `max_variance` returns the low block then
    /// the high block); empty means the group is dropped from the update.
    pub kept: Vec<usize>,
    /// Diagnostics of this selection.
    pub diag: SelectionDiag,
}

/// A `|`-composed chain of selection stages.
///
/// Stages run left to right; each receives the survivors of the previous
/// one. After the last stage the kept set is clamped to `m` by truncation
/// in stage-output order (only reachable when the final stage is a
/// [`StageKind::Filter`] — `Exact` stages already cut to `min(m, ·)`).
#[derive(Debug)]
pub struct Pipeline {
    spec: String,
    stages: Vec<Box<dyn Selector>>,
}

impl Pipeline {
    /// Parse a spec string against a registry. Grammar in [`spec`].
    pub fn parse(text: &str, registry: &Registry) -> Result<Self> {
        let stages = registry.parse_pipeline(text)?;
        Ok(Self { spec: text.trim().to_string(), stages })
    }

    /// Parse against the built-in [`default_registry`].
    pub fn parse_default(text: &str) -> Result<Self> {
        Self::parse(text, default_registry())
    }

    /// Build directly from stages (for programmatic composition).
    pub fn from_stages(spec: impl Into<String>, stages: Vec<Box<dyn Selector>>) -> Result<Self> {
        if stages.is_empty() {
            bail!("selector pipeline needs at least one stage");
        }
        Ok(Self { spec: spec.into(), stages })
    }

    /// The spec string this pipeline was parsed from.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// Stage names, pipeline order.
    pub fn stage_names(&self) -> Vec<&str> {
        self.stages.iter().map(|s| s.name()).collect()
    }

    /// Per-stage online-pruning bounds, pipeline order (what the
    /// [`online::OnlineSelector`] analysis walks).
    pub fn stage_bounds(&self) -> Vec<online::StageBound> {
        self.stages.iter().map(|s| s.online_bound()).collect()
    }

    /// Run the pipeline over the whole group.
    ///
    /// Degenerate targets are clamped, not errors: `m == 0` yields an
    /// empty selection, `m >= n` lets `Exact` stages keep everything.
    pub fn select(&self, ctx: &SelectionContext) -> Result<Selection> {
        let n = ctx.n();
        let mut kept: Vec<usize> = (0..n).collect();
        if ctx.m == 0 {
            kept.clear();
        }
        for (si, stage) in self.stages.iter().enumerate() {
            if kept.is_empty() {
                break;
            }
            let stage_ctx = SelectionContext { stage: si as u64, ..*ctx };
            let next = stage.select(&stage_ctx, &kept)?;
            check_stage_output(stage.name(), n, &kept, &next)?;
            kept = next;
        }
        kept.truncate(ctx.m);
        Ok(Selection { diag: SelectionDiag::for_kept(ctx.group, &kept), kept })
    }
}

/// Validate a stage's output: a distinct subset of the candidates it was
/// given (guards registry-loaded custom selectors).
fn check_stage_output(stage: &str, n: usize, prev: &[usize], out: &[usize]) -> Result<()> {
    let mut allowed = vec![false; n];
    for &i in prev {
        allowed[i] = true;
    }
    for &i in out {
        if i >= n {
            bail!("selector {stage:?} returned out-of-range index {i} (n={n})");
        }
        if !allowed[i] {
            bail!("selector {stage:?} returned index {i} twice or outside its candidate set");
        }
        allowed[i] = false; // consumed: also catches duplicates
    }
    Ok(())
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::coordinator::group::PromptGroup;

    /// Synthetic group: rewards plus (optionally) per-rollout gen lengths.
    pub fn fake_group(problem_idx: u64, rewards: &[f32], lens: Option<&[i32]>) -> PromptGroup {
        PromptGroup::synthetic(problem_idx, rewards, lens)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::fake_group;
    use super::*;
    use crate::util::prop::{for_cases, vec_f32};

    #[test]
    fn group_seed_is_deterministic_and_decorrelated() {
        assert_eq!(group_seed(1, 2, 3), group_seed(1, 2, 3));
        let seeds = [
            group_seed(0, 0, 0),
            group_seed(1, 0, 0),
            group_seed(0, 1, 0),
            group_seed(0, 0, 1),
        ];
        let set: std::collections::HashSet<u64> = seeds.iter().copied().collect();
        assert_eq!(set.len(), seeds.len(), "seed collisions: {seeds:?}");
    }

    #[test]
    fn context_rng_ignores_group_order() {
        let a = fake_group(0, &[1.0, 2.0], None);
        let b = fake_group(1, &[3.0, 4.0], None);
        let ra = SelectionContext::new(&a, 1, 7, 5).rng().next_u64();
        // processing b in between must not perturb a's stream
        let _ = SelectionContext::new(&b, 1, 7, 5).rng().next_u64();
        let ra2 = SelectionContext::new(&a, 1, 7, 5).rng().next_u64();
        assert_eq!(ra, ra2);
    }

    #[test]
    fn later_stages_draw_decorrelated_streams() {
        let g = fake_group(0, &[1.0, 2.0], None);
        let base = SelectionContext::new(&g, 1, 7, 5);
        let s1 = SelectionContext { stage: 1, ..base };
        let s2 = SelectionContext { stage: 2, ..base };
        let (r0, r1, r2) = (base.rng().next_u64(), s1.rng().next_u64(), s2.rng().next_u64());
        assert_ne!(r0, r1, "stage 1 must not replay stage 0's stream");
        assert_ne!(r1, r2);
        // stage 0 keeps the bare group seed (golden-tested seeding)
        assert_eq!(r0, crate::util::rng::Rng::seed_from_u64(group_seed(7, 5, g.problem.id)).next_u64());
    }

    #[test]
    fn pipeline_m_zero_is_empty_and_m_above_n_keeps_all() {
        let g = fake_group(0, &[1.0, 3.0, 2.0], None);
        let p = Pipeline::parse_default("max_variance").unwrap();
        let none = p.select(&SelectionContext::new(&g, 0, 0, 0)).unwrap();
        assert!(none.kept.is_empty());
        assert_eq!(none.diag.kept, 0);
        let all = p.select(&SelectionContext::new(&g, 10, 0, 0)).unwrap();
        let mut kept = all.kept.clone();
        kept.sort_unstable();
        assert_eq!(kept, vec![0, 1, 2]);
    }

    #[test]
    fn filter_final_pipeline_is_clamped_to_m() {
        // drop_zero_variance keeps everything on a non-degenerate group;
        // the pipeline clamp then truncates to m in candidate order.
        let g = fake_group(0, &[1.0, 2.0, 3.0, 4.0], None);
        let p = Pipeline::parse_default("drop_zero_variance").unwrap();
        let sel = p.select(&SelectionContext::new(&g, 2, 0, 0)).unwrap();
        assert_eq!(sel.kept, vec![0, 1]);
        assert_eq!(sel.diag.candidates, 4);
        assert_eq!(sel.diag.kept, 2);
    }

    #[test]
    fn diag_accounts_tokens_and_variance() {
        let g = fake_group(0, &[0.0, 1.0, 2.0, 3.0], Some(&[10, 20, 30, 40]));
        let p = Pipeline::parse_default("max_variance").unwrap();
        let sel = p.select(&SelectionContext::new(&g, 2, 0, 0)).unwrap();
        // m=2 on [0,1,2,3] picks the extremes 0 and 3
        let mut kept = sel.kept.clone();
        kept.sort_unstable();
        assert_eq!(kept, vec![0, 3]);
        assert_eq!(sel.diag.tokens_kept, 50);
        assert_eq!(sel.diag.tokens_dropped, 50);
        assert!((sel.diag.reward_mean - 1.5).abs() < 1e-12);
        assert!((sel.diag.reward_variance - 2.25).abs() < 1e-12);
    }

    /// Satellite invariant: every registered selector, run as a one-stage
    /// pipeline, returns distinct in-range indices; `Exact` stages return
    /// exactly `min(m, n)` of them.
    #[test]
    fn every_registered_selector_returns_valid_subsets() {
        let reg = default_registry();
        for_cases(120, |rng| {
            let n = rng.gen_range_inclusive(1, 24) as usize;
            let rewards = vec_f32(rng, n, -3.0, 3.0);
            let lens: Vec<i32> = (0..n).map(|_| rng.gen_range_inclusive(1, 64) as i32).collect();
            let g = fake_group(rng.next_u64() % 1000, &rewards, Some(&lens));
            let m = rng.gen_range_inclusive(1, n as i64) as usize;
            let ctx = SelectionContext::new(&g, m, rng.next_u64(), rng.next_u64());
            for name in reg.names() {
                let stage = reg.build_stage(name).unwrap();
                let kind = stage.kind();
                let p = Pipeline::from_stages(name.to_string(), vec![stage]).unwrap();
                let sel = p.select(&ctx).unwrap();
                let set: std::collections::HashSet<usize> = sel.kept.iter().copied().collect();
                assert_eq!(set.len(), sel.kept.len(), "{name}: duplicates {:?}", sel.kept);
                assert!(sel.kept.iter().all(|&i| i < n), "{name}: oob {:?}", sel.kept);
                match kind {
                    StageKind::Exact => {
                        assert_eq!(sel.kept.len(), m.min(n), "{name}: not exact")
                    }
                    StageKind::Filter => assert!(sel.kept.len() <= m.min(n), "{name}"),
                }
            }
        });
    }

    /// Satellite invariant: percentile tie-breaking is deterministic — on
    /// tie-heavy discrete rewards the selector output is reproducible and
    /// matches the seed kernel exactly.
    #[test]
    fn percentile_tie_breaking_is_deterministic() {
        let p = Pipeline::parse_default("percentile").unwrap();
        for_cases(200, |rng| {
            let n = rng.gen_range_inclusive(1, 32) as usize;
            let rewards: Vec<f32> =
                (0..n).map(|_| [0.0, 1.0][rng.below(2)]).collect();
            let m = rng.gen_range_inclusive(1, n as i64) as usize;
            let g = fake_group(0, &rewards, None);
            let ctx = SelectionContext::new(&g, m, 0, 0);
            let a = p.select(&ctx).unwrap().kept;
            let b = p.select(&ctx).unwrap().kept;
            assert_eq!(a, b);
            let want = crate::coordinator::downsample::percentile(&rewards, m).unwrap();
            assert_eq!(a, want);
        });
        // all-ties golden: argsort tie-break by index makes the picks the
        // canonical sorted positions 1 and 3
        let g = fake_group(0, &[1.0, 1.0, 1.0, 1.0], None);
        let sel = p.select(&SelectionContext::new(&g, 2, 0, 0)).unwrap();
        assert_eq!(sel.kept, vec![1, 3]);
    }

    #[test]
    fn stage_output_validation_catches_bad_selectors() {
        #[derive(Debug)]
        struct Broken;
        impl Selector for Broken {
            fn name(&self) -> &str {
                "broken"
            }
            fn kind(&self) -> StageKind {
                StageKind::Filter
            }
            fn select(&self, _: &SelectionContext, _: &[usize]) -> Result<Vec<usize>> {
                Ok(vec![0, 0, 99])
            }
        }
        let g = fake_group(0, &[1.0, 2.0], None);
        let p = Pipeline::from_stages("broken", vec![Box::new(Broken)]).unwrap();
        assert!(p.select(&SelectionContext::new(&g, 2, 0, 0)).is_err());

        // a stage resurrecting an index a previous stage dropped is caught
        #[derive(Debug)]
        struct Resurrect;
        impl Selector for Resurrect {
            fn name(&self) -> &str {
                "resurrect"
            }
            fn kind(&self) -> StageKind {
                StageKind::Filter
            }
            fn select(&self, ctx: &SelectionContext, _: &[usize]) -> Result<Vec<usize>> {
                Ok((0..ctx.n()).collect())
            }
        }
        let g = fake_group(0, &[1.0, 2.0, 3.0, 4.0], None);
        let p = Pipeline::from_stages(
            "max_variance | resurrect",
            vec![Box::new(legacy::MaxVariance), Box::new(Resurrect)],
        )
        .unwrap();
        assert!(p.select(&SelectionContext::new(&g, 2, 0, 0)).is_err());
    }
}
