//! Online selection-aware rollout pruning — doom-only verdicts during
//! generation.
//!
//! PODS as published pays for every rollout twice: all `n` rollouts are
//! decoded to completion, and only then does the selection pipeline drop
//! `n - m` of them. This module moves the selection decision *into* the
//! decode loop: given the rewards of already-finished rows and
//! conservative bounds on unfinished ones (a pending row's reward is
//! bracketed by the reward model's attainable range; its generated length
//! only grows), a row may be declared [`Verdict::Doomed`] the moment it
//! **cannot appear in the selected subset under any completion of the
//! group**. The chunked decode driver then aborts doomed rows at the next
//! chunk boundary exactly like EOS retirement, freeing their slots for
//! refill.
//!
//! The load-bearing invariant (pinned by `rust/tests/prune_golden.rs` and
//! documented in `docs/DETERMINISM.md`): because only provably-doomed rows
//! are ever cut, the final selection — kept indices, advantages, and hence
//! the trained parameters — is **bit-identical** to post-hoc selection on
//! fully-decoded rollouts. Stages without a sound bound report
//! [`StageBound::Opaque`] and never cause an abort; a pipeline of only
//! opaque stages prunes nothing.
//!
//! Two stage bounds ship today:
//!
//! * [`StageBound::LengthCap`] — `prune(max_tokens=K)` (and no other
//!   criteria) drops exactly the rows whose generated length exceeds `K`,
//!   so a row is doomed the moment its length crosses `K` — once a
//!   certificate row (a guaranteed candidate finished within the cap)
//!   rules out the stage's never-starve guard.
//! * [`StageBound::MaxVariance`] — Algorithm 2's kept set is always a
//!   prefix + suffix of the reward-sorted order, so a row with at least
//!   `m` guaranteed candidates sorting strictly below it *and* at least
//!   `m` sorting strictly above it under every completion can never be
//!   kept, regardless of pending outcomes.

use super::Pipeline;
use crate::reward::RewardWeights;
use std::sync::Mutex;

/// Online verdict for one rollout row mid-generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// The row may still end up in the selected subset — keep decoding.
    Unknown,
    /// The row provably cannot survive selection under any completion of
    /// its group — abort it at the next chunk boundary.
    Doomed,
}

/// What one selection stage can soundly guarantee about rows
/// mid-generation (declared via [`super::Selector::online_bound`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageBound {
    /// No sound bound: the stage's output may depend on pending rewards or
    /// lengths in ways the online analysis cannot bracket. Never dooms;
    /// rows surviving an opaque stage are treated as unknowable candidates
    /// for every later stage.
    Opaque,
    /// The stage drops exactly the candidates whose generated length
    /// exceeds `max_tokens` (the `prune(max_tokens=K)` filter with no
    /// quantile/budget criteria), modulo its never-starve guard.
    LengthCap {
        /// The stage's absolute generated-length cap.
        max_tokens: usize,
    },
    /// The exact max-variance stage (Algorithm 2): its kept set is a
    /// prefix + suffix of the reward-sorted candidate order, enabling the
    /// `m`-below / `m`-above exclusion certificate.
    MaxVariance,
}

/// Observation state of one rollout row during generation.
#[derive(Debug, Clone, Copy)]
enum RowObs {
    /// Still decoding; `len` is the generated-token count so far
    /// (monotone — only ever raised).
    Pending { len: usize },
    /// Finished (EOS or budget): final reward and generated length.
    Finished { reward: f32, len: usize },
    /// A doom verdict was issued; `len` freezes at the abort point.
    Doomed { len: usize },
}

impl RowObs {
    fn len(&self) -> usize {
        match *self {
            RowObs::Pending { len } | RowObs::Finished { len, .. } | RowObs::Doomed { len } => len,
        }
    }
}

/// Candidate state of a row while walking the pipeline's stage bounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Cand {
    /// Guaranteed to be a candidate at this point under every completion.
    In,
    /// May or may not be a candidate — usable for nothing.
    Maybe,
    /// Guaranteed to have been dropped by some stage under every
    /// completion — the row can never be selected.
    Out,
}

/// Incremental online selector for **one prompt group**.
///
/// Feed it observations as rows finish ([`Self::observe_finished`]) and as
/// pending rows grow ([`Self::observe_len`]); [`Self::poll`] re-runs the
/// conservative pipeline analysis and returns newly-doomed rows. Verdicts
/// are monotone: a doomed row stays doomed.
#[derive(Debug)]
pub struct OnlineSelector {
    bounds: Vec<StageBound>,
    m: usize,
    rmin: f32,
    rmax: f32,
    rows: Vec<RowObs>,
    /// Observations changed since the last [`Self::poll`] analysis. The
    /// analysis is a pure function of the observations, so a clean state
    /// cannot doom anything new — `poll` in the decode hot loop is O(1)
    /// until something is actually observed.
    dirty: bool,
}

impl OnlineSelector {
    /// Selector for a group of `n` rollouts selected down to `m`, with
    /// pending rewards bracketed in `[rmin, rmax]` and the given per-stage
    /// bounds (pipeline order).
    pub fn new(bounds: Vec<StageBound>, n: usize, m: usize, rmin: f32, rmax: f32) -> Self {
        Self { bounds, m, rmin, rmax, rows: vec![RowObs::Pending { len: 0 }; n], dirty: true }
    }

    /// Number of rows in the group.
    pub fn n(&self) -> usize {
        self.rows.len()
    }

    /// Append `additional` fresh [`RowObs::Pending`] rows to the group
    /// (the budget allocator grew the group past its probe quota).
    ///
    /// Soundness: every verdict this selector issues is a *doom-only*
    /// certificate — "row `i` is dropped under every completion of the
    /// group". Both shipping certificates stay valid when candidates are
    /// added: a `LengthCap` doom depends only on the doomed row's own
    /// length plus the existence of one finished-under-cap candidate
    /// (adding rows cannot remove that certificate row), and a
    /// `MaxVariance` doom counts guaranteed candidates forced strictly
    /// below/above the doomed row (new pending rows only ever *add*
    /// candidates, and the `>= m` thresholds are monotone in candidate
    /// count). Growing a group therefore never invalidates an
    /// already-issued doom.
    pub fn grow(&mut self, additional: usize) {
        if additional == 0 {
            return;
        }
        let target = self.rows.len() + additional;
        self.rows.resize(target, RowObs::Pending { len: 0 });
        self.dirty = true;
    }

    /// Record that `row` finished with the given total reward and final
    /// generated length. Ignored for rows already finished or doomed.
    pub fn observe_finished(&mut self, row: usize, reward: f32, gen_len: usize) {
        let Some(slot) = self.rows.get_mut(row) else { return };
        if let RowObs::Pending { .. } = slot {
            *slot = RowObs::Finished { reward, len: gen_len };
            // the bracket is derived from the reward model's attainable
            // range; widen defensively so certificates stay sound even if
            // an observed reward escapes it
            debug_assert!(
                (self.rmin..=self.rmax).contains(&reward),
                "observed reward {reward} outside bracket [{}, {}]",
                self.rmin,
                self.rmax
            );
            self.rmin = self.rmin.min(reward);
            self.rmax = self.rmax.max(reward);
            self.dirty = true;
        }
    }

    /// Raise a pending row's generated-length watermark (lengths are
    /// monotone; lower observations are ignored).
    pub fn observe_len(&mut self, row: usize, gen_len: usize) {
        let Some(slot) = self.rows.get_mut(row) else { return };
        if let RowObs::Pending { len } = slot {
            if gen_len > *len {
                *len = gen_len;
                self.dirty = true;
            }
        }
    }

    /// Current verdict for `row`.
    pub fn verdict(&self, row: usize) -> Verdict {
        match self.rows.get(row) {
            Some(RowObs::Doomed { .. }) => Verdict::Doomed,
            _ => Verdict::Unknown,
        }
    }

    /// Rows doomed so far.
    pub fn doomed_count(&self) -> usize {
        self.rows.iter().filter(|r| matches!(r, RowObs::Doomed { .. })).count()
    }

    /// Re-run the conservative analysis and issue verdicts: every pending
    /// row that is provably dropped by the pipeline under **every**
    /// completion of the group becomes [`Verdict::Doomed`]. Returns the
    /// newly-doomed row indices (ascending). No-op (and O(1)) when
    /// nothing was observed since the last poll.
    pub fn poll(&mut self) -> Vec<usize> {
        if !self.dirty {
            return Vec::new();
        }
        self.dirty = false;
        let cand = self.analyze();
        let mut newly = Vec::new();
        for (i, c) in cand.iter().enumerate() {
            if *c == Cand::Out {
                if let RowObs::Pending { len } = self.rows[i] {
                    self.rows[i] = RowObs::Doomed { len };
                    newly.push(i);
                }
            }
        }
        newly
    }

    /// Reward bracket of one row: a point for finished rows, the model's
    /// attainable range for pending (or already-doomed) rows.
    fn bracket(&self, i: usize) -> (f32, f32) {
        match self.rows[i] {
            RowObs::Finished { reward, .. } => (reward, reward),
            _ => (self.rmin, self.rmax),
        }
    }

    /// Walk the stage bounds left to right, tracking for every row whether
    /// it is a guaranteed candidate (`In`), guaranteed dropped (`Out`), or
    /// unknowable (`Maybe`) at each point — under every completion of the
    /// group. Rows ending `Out` can never be selected: stages only shrink
    /// candidate sets, so a guaranteed drop anywhere is terminal.
    fn analyze(&self) -> Vec<Cand> {
        let n = self.rows.len();
        let mut cand = vec![Cand::In; n];
        for bound in &self.bounds {
            match *bound {
                StageBound::Opaque => {
                    for c in cand.iter_mut() {
                        if *c != Cand::Out {
                            *c = Cand::Maybe;
                        }
                    }
                }
                StageBound::LengthCap { max_tokens } => {
                    // Certificate against the stage's never-starve guard:
                    // a guaranteed candidate that already finished within
                    // the cap keeps the stage's output non-empty, so the
                    // guard can never resurrect an over-cap row.
                    let cert = cand.iter().zip(&self.rows).any(|(c, r)| {
                        *c == Cand::In
                            && matches!(r, RowObs::Finished { len, .. } if *len <= max_tokens)
                    });
                    for (c, r) in cand.iter_mut().zip(&self.rows) {
                        if *c == Cand::Out {
                            continue;
                        }
                        if r.len() > max_tokens {
                            // over the cap already (lengths only grow):
                            // dropped if it reaches this stage, already
                            // dropped if it does not
                            *c = if cert { Cand::Out } else { Cand::Maybe };
                        } else if !(*c == Cand::In && matches!(r, RowObs::Finished { .. })) {
                            // pending rows may still cross the cap; rows
                            // that were only Maybe stay Maybe
                            *c = Cand::Maybe;
                        }
                    }
                }
                StageBound::MaxVariance => {
                    let next: Vec<Cand> = (0..n)
                        .map(|i| {
                            if cand[i] == Cand::Out {
                                return Cand::Out;
                            }
                            let (lo_i, hi_i) = self.bracket(i);
                            let mut below = 0usize;
                            let mut above = 0usize;
                            for j in 0..n {
                                if j == i || cand[j] != Cand::In {
                                    continue;
                                }
                                let (lo_j, hi_j) = self.bracket(j);
                                // strict sorted-order relations under every
                                // completion (argsort ties break by index)
                                if hi_j < lo_i || (hi_j == lo_i && j < i) {
                                    below += 1;
                                }
                                if lo_j > hi_i || (lo_j == hi_i && j > i) {
                                    above += 1;
                                }
                            }
                            // Lemma 3.1: the kept set is a prefix + suffix
                            // of the sorted order with at most m on each
                            // side — a row with >= m guaranteed candidates
                            // strictly below AND strictly above it is in
                            // neither block under any completion.
                            if below >= self.m && above >= self.m {
                                Cand::Out
                            } else {
                                Cand::Maybe
                            }
                        })
                        .collect();
                    cand = next;
                }
            }
        }
        cand
    }
}

/// Shared per-group verdict state for one generation batch, aggregated
/// across worker shards.
///
/// The rollout thread pool decodes contiguous row shards concurrently and
/// a prompt group's rows can span shards, so the per-group
/// [`OnlineSelector`]s live behind mutexes in one `Arc`-shared registry:
/// every worker reports retirements and polls verdicts against the same
/// state, whatever shard the row landed on. Lock poisoning (a sibling
/// worker panicked) degrades to "never abort" — pruning is an
/// optimization, not a correctness dependency.
#[derive(Debug)]
pub struct GroupVerdicts {
    groups: Vec<Mutex<OnlineSelector>>,
}

impl GroupVerdicts {
    /// Verdict state for `groups` prompt groups of `n` rollouts each,
    /// selected down to `m` by `pipeline`. The pending-reward bracket is
    /// the reward model's attainable range under `weights` (components
    /// are each in `[0, 1]`).
    pub fn new(
        pipeline: &Pipeline,
        groups: usize,
        n: usize,
        m: usize,
        weights: &RewardWeights,
    ) -> Self {
        let bounds = pipeline.stage_bounds();
        let rmin = 0.0f32;
        let rmax = weights.accuracy.max(0.0) + weights.format.max(0.0) + weights.tags.max(0.0);
        Self {
            groups: (0..groups)
                .map(|_| Mutex::new(OnlineSelector::new(bounds.clone(), n, m, rmin, rmax)))
                .collect(),
        }
    }

    /// Report a finished row's total reward and final generated length.
    pub fn observe_finished(&self, group: usize, rollout: usize, reward: f32, gen_len: usize) {
        let Some(slot) = self.groups.get(group) else { return };
        let Ok(mut sel) = slot.lock() else { return };
        sel.observe_finished(rollout, reward, gen_len);
    }

    /// Update a live row's generated length, re-run the analysis, and
    /// report whether the row is doomed (the chunked driver aborts it at
    /// this boundary when `true`).
    pub fn poll_doomed(&self, group: usize, rollout: usize, gen_len: usize) -> bool {
        let Some(slot) = self.groups.get(group) else { return false };
        let Ok(mut sel) = slot.lock() else { return false };
        sel.observe_len(rollout, gen_len);
        sel.poll();
        sel.verdict(rollout) == Verdict::Doomed
    }

    /// Grow `group` by `additional` pending rows (budget allocator issued
    /// extra rollouts past the probe quota). Doom-only verdicts already
    /// issued stay sound — see [`OnlineSelector::grow`].
    pub fn grow_group(&self, group: usize, additional: usize) {
        let Some(slot) = self.groups.get(group) else { return };
        let Ok(mut sel) = slot.lock() else { return };
        sel.grow(additional);
    }

    /// Total rows doomed so far across all groups.
    pub fn doomed_count(&self) -> usize {
        self.groups
            .iter()
            .map(|g| g.lock().map_or(0, |s| s.doomed_count()))
            .sum()
    }
}

/// Distance from a finished row's reward bracket (the point `(r, r)` —
/// see [`OnlineSelector`]'s bracket analysis) to a set of kept rewards:
/// `min_k |r - k|`, or `0.0` when `kept` is empty.
///
/// The cross-iteration replay store scores dropped rollouts with this:
/// a dropped row whose reward coincides with a kept row's is redundant
/// (score 0); one far from every kept reward carries signal the selected
/// subset lost.
pub fn bracket_distance(reward: f32, kept: &[f32]) -> f32 {
    if kept.is_empty() {
        return 0.0;
    }
    kept.iter().map(|k| (reward - k).abs()).fold(f32::INFINITY, f32::min)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::select::Pipeline;

    fn cap_mv(k: usize, m: usize, n: usize) -> OnlineSelector {
        OnlineSelector::new(
            vec![StageBound::LengthCap { max_tokens: k }, StageBound::MaxVariance],
            n,
            m,
            0.0,
            3.0,
        )
    }

    #[test]
    fn pipeline_reports_stage_bounds() {
        let p = Pipeline::parse_default("prune(max_tokens=32) | max_variance").unwrap();
        assert_eq!(
            p.stage_bounds(),
            vec![StageBound::LengthCap { max_tokens: 32 }, StageBound::MaxVariance]
        );
        // quantile/budget criteria make the cap data-dependent: opaque
        let p = Pipeline::parse_default("prune(quantile=0.75) | percentile").unwrap();
        assert_eq!(p.stage_bounds(), vec![StageBound::Opaque, StageBound::Opaque]);
        let p = Pipeline::parse_default("prune(max_tokens=32, budget=99) | random").unwrap();
        assert_eq!(p.stage_bounds(), vec![StageBound::Opaque, StageBound::Opaque]);
        let p = Pipeline::parse_default("drop_zero_variance | max_reward").unwrap();
        assert_eq!(p.stage_bounds(), vec![StageBound::Opaque, StageBound::Opaque]);
    }

    /// A row over the cap is doomed only once a finished row within the
    /// cap certifies the never-starve guard cannot trigger.
    #[test]
    fn length_cap_requires_a_survivor_certificate() {
        let mut sel = cap_mv(10, 2, 4);
        sel.observe_len(0, 11);
        assert!(sel.poll().is_empty(), "no finished-under-cap row: no doom");
        assert_eq!(sel.verdict(0), Verdict::Unknown);
        // a finished row within the cap flips the certificate
        sel.observe_finished(1, 1.0, 8);
        assert_eq!(sel.poll(), vec![0]);
        assert_eq!(sel.verdict(0), Verdict::Doomed);
        // verdicts are monotone and not re-issued
        assert!(sel.poll().is_empty());
        assert_eq!(sel.verdict(0), Verdict::Doomed);
        // rows within the cap are never doomed by the cap
        sel.observe_len(2, 10);
        assert!(sel.poll().is_empty());
        assert_eq!(sel.verdict(2), Verdict::Unknown);
    }

    /// A finished row over the cap does not certify (it is itself dropped,
    /// so it cannot keep the stage's output non-empty).
    #[test]
    fn over_cap_finisher_is_no_certificate() {
        let mut sel = cap_mv(10, 2, 3);
        sel.observe_finished(0, 1.0, 20);
        sel.observe_len(1, 15);
        assert!(sel.poll().is_empty(), "only over-cap rows finished: guard may fire");
    }

    /// The max-variance certificate: a pending row with `m` guaranteed
    /// candidates forced strictly below it and `m` forced strictly above
    /// it (reward bracket + index tie-break) can never enter the
    /// prefix+suffix kept set.
    #[test]
    fn max_variance_dooms_bracket_excluded_pending_rows() {
        let mut sel =
            OnlineSelector::new(vec![StageBound::MaxVariance], 3, 1, 0.0, 3.0);
        // idx0 finished at the bracket floor below the pending row, idx2
        // finished at the ceiling above it
        sel.observe_finished(0, 0.0, 4);
        sel.observe_finished(2, 3.0, 4);
        assert_eq!(sel.poll(), vec![1]);
        assert_eq!(sel.verdict(1), Verdict::Doomed);
    }

    #[test]
    fn max_variance_needs_both_sides() {
        let mut sel = OnlineSelector::new(vec![StageBound::MaxVariance], 3, 1, 0.0, 3.0);
        sel.observe_finished(0, 0.0, 4);
        // nothing forced above the pending row: it could be the maximum
        assert!(sel.poll().is_empty());

        // index tie-break matters: a ceiling finisher at a LOWER index than
        // the pending row does not sort above it when the pending row also
        // reaches the ceiling
        let mut sel = OnlineSelector::new(vec![StageBound::MaxVariance], 3, 1, 0.0, 3.0);
        sel.observe_finished(0, 3.0, 4); // ceiling, but idx 0 < 2
        sel.observe_finished(1, 0.0, 4);
        sel.observe_len(2, 1);
        assert!(sel.poll().is_empty(), "idx2 at the ceiling would sort above idx0");
    }

    /// Opaque stages poison everything after them: no dooms from a
    /// max-variance stage behind an opaque filter.
    #[test]
    fn opaque_prefix_disables_later_bounds() {
        let mut sel = OnlineSelector::new(
            vec![StageBound::Opaque, StageBound::MaxVariance],
            3,
            1,
            0.0,
            3.0,
        );
        sel.observe_finished(0, 0.0, 4);
        sel.observe_finished(2, 3.0, 4);
        assert!(sel.poll().is_empty(), "opaque stage makes candidacy unknowable");
    }

    /// An all-opaque pipeline never dooms anything, whatever it observes.
    #[test]
    fn opaque_only_pipelines_never_doom() {
        for spec in ["percentile", "random", "drop_zero_variance | percentile", "first"] {
            let p = Pipeline::parse_default(spec).unwrap();
            let mut sel = OnlineSelector::new(p.stage_bounds(), 6, 2, 0.0, 3.0);
            for i in 0..4 {
                sel.observe_finished(i, (i as f32) * 0.75, 100 + i);
            }
            sel.observe_len(4, 10_000);
            assert!(sel.poll().is_empty(), "{spec:?} doomed a row");
            for i in 0..6 {
                assert_eq!(sel.verdict(i), Verdict::Unknown, "{spec:?} row {i}");
            }
        }
    }

    /// GroupVerdicts shares state across observers and counts dooms.
    #[test]
    fn group_verdicts_aggregate_per_group() {
        let p = Pipeline::parse_default("prune(max_tokens=8) | max_variance").unwrap();
        let v = GroupVerdicts::new(&p, 2, 4, 2, &RewardWeights::default());
        assert_eq!(v.doomed_count(), 0);
        // group 0: certificate + an over-cap live row
        v.observe_finished(0, 1, 2.0, 6);
        assert!(!v.poll_doomed(0, 0, 8), "at the cap is within the cap");
        assert!(v.poll_doomed(0, 0, 9));
        assert_eq!(v.doomed_count(), 1);
        // group 1 is independent state: same shape, no certificate yet
        assert!(!v.poll_doomed(1, 0, 9));
        // out-of-range queries are inert
        assert!(!v.poll_doomed(7, 0, 9));
        v.observe_finished(7, 0, 1.0, 1);
    }

    /// Growing a group adds live pending rows without disturbing verdicts
    /// already issued (dooms are monotone under candidate addition).
    #[test]
    fn grow_adds_pending_rows_and_preserves_dooms() {
        let mut sel = cap_mv(10, 2, 3);
        sel.observe_finished(0, 1.0, 8);
        sel.observe_len(1, 11);
        assert_eq!(sel.poll(), vec![1]);
        sel.grow(2);
        assert_eq!(sel.n(), 5);
        assert_eq!(sel.verdict(1), Verdict::Doomed, "doom survives growth");
        assert_eq!(sel.verdict(3), Verdict::Unknown);
        // the grown rows are live: one can be doomed by the same cap
        sel.observe_len(4, 11);
        assert_eq!(sel.poll(), vec![4]);
        // GroupVerdicts wrapper routes growth to the right group
        let p = Pipeline::parse_default("prune(max_tokens=8) | max_variance").unwrap();
        let v = GroupVerdicts::new(&p, 2, 2, 1, &RewardWeights::default());
        v.grow_group(1, 3);
        v.observe_finished(1, 0, 1.0, 4);
        assert!(v.poll_doomed(1, 4, 9), "grown row index is addressable");
        v.grow_group(9, 1); // out-of-range growth is inert
    }

    /// The bracket ceiling follows the reward weights.
    #[test]
    fn bracket_tracks_reward_weights() {
        let p = Pipeline::parse_default("max_variance").unwrap();
        let w = RewardWeights { accuracy: 1.0, format: 0.0, tags: 0.0 };
        let v = GroupVerdicts::new(&p, 1, 3, 1, &w);
        // with rmax = 1.0, a finisher at 1.0 above and 0.0 below dooms the
        // middle pending row
        v.observe_finished(0, 0, 0.0, 4);
        v.observe_finished(0, 2, 1.0, 4);
        assert!(v.poll_doomed(0, 1, 0));
    }

    /// `bracket_distance` is the replay store's admission score: zero on
    /// or inside the kept set's reward points, the gap to the nearest
    /// kept reward otherwise, and zero against an empty kept set.
    #[test]
    fn bracket_distance_measures_gap_to_nearest_kept_reward() {
        assert_eq!(bracket_distance(1.0, &[]), 0.0);
        assert_eq!(bracket_distance(1.0, &[1.0, 3.0]), 0.0);
        assert!((bracket_distance(2.0, &[1.0, 3.0]) - 1.0).abs() < 1e-6);
        assert!((bracket_distance(-1.0, &[1.0, 3.0]) - 2.0).abs() < 1e-6);
        assert!((bracket_distance(3.5, &[1.0, 3.0]) - 0.5).abs() < 1e-6);
    }
}
