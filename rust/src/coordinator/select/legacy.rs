//! The paper's four down-sampling rules as selector stages, plus the
//! `first` truncation baseline.
//!
//! Each stage runs the corresponding numeric kernel from
//! [`crate::coordinator::downsample`] over the *candidate subset* and maps
//! the result back to original rollout indices. With the full candidate
//! set (a one-stage pipeline) the output is identical to the seed
//! implementation — golden-tested in `rust/tests/selector_golden.rs`.
//! `random` draws from the context's per-group RNG
//! ([`SelectionContext::rng`]), so its choice depends only on
//! `(run_seed, iter, prompt_id)` — not on how many groups were selected
//! before it.

use super::{SelectionContext, Selector, SpecArgs, StageKind};
use crate::coordinator::downsample as ds;
use anyhow::Result;

/// Target size for a stage: the context `m` clamped to the candidates.
fn target(ctx: &SelectionContext, candidates: &[usize]) -> usize {
    ctx.m.min(candidates.len())
}

/// Rewards of the candidate subset, candidate order.
fn sub_rewards(ctx: &SelectionContext, candidates: &[usize]) -> Vec<f32> {
    candidates.iter().map(|&i| ctx.group.rollouts[i].total_reward).collect()
}

/// Map kernel output (positions into the candidate slice) back to rollout
/// indices, preserving the kernel's output order.
fn map_back(candidates: &[usize], picked: Vec<usize>) -> Vec<usize> {
    picked.into_iter().map(|p| candidates[p]).collect()
}

macro_rules! no_arg_factory {
    ($fname:ident, $ty:ident) => {
        pub fn $fname(args: &SpecArgs) -> Result<Box<dyn Selector>> {
            args.expect_known(&[])?;
            Ok(Box::new($ty))
        }
    };
}

/// `max_variance` — Algorithm 2: the variance-maximising `m`-subset.
#[derive(Debug, Clone, Copy)]
pub struct MaxVariance;

impl Selector for MaxVariance {
    fn name(&self) -> &str {
        "max_variance"
    }
    fn kind(&self) -> StageKind {
        StageKind::Exact
    }
    fn online_bound(&self) -> super::online::StageBound {
        // Lemma 3.1: the kept set is a prefix + suffix of the sorted
        // order, which the online analysis can exclude rows from via
        // reward brackets (see select::online).
        super::online::StageBound::MaxVariance
    }
    fn select(&self, ctx: &SelectionContext, candidates: &[usize]) -> Result<Vec<usize>> {
        let m = target(ctx, candidates);
        if m == 0 {
            return Ok(Vec::new());
        }
        Ok(map_back(candidates, ds::max_variance(&sub_rewards(ctx, candidates), m)?))
    }
}

no_arg_factory!(max_variance_factory, MaxVariance);

/// `max_reward` — the `m` highest rewards (§3.2, shown harmful in Fig. 5).
#[derive(Debug, Clone, Copy)]
pub struct MaxReward;

impl Selector for MaxReward {
    fn name(&self) -> &str {
        "max_reward"
    }
    fn kind(&self) -> StageKind {
        StageKind::Exact
    }
    fn select(&self, ctx: &SelectionContext, candidates: &[usize]) -> Result<Vec<usize>> {
        let m = target(ctx, candidates);
        if m == 0 {
            return Ok(Vec::new());
        }
        Ok(map_back(candidates, ds::max_reward(&sub_rewards(ctx, candidates), m)?))
    }
}

no_arg_factory!(max_reward_factory, MaxReward);

/// `random` — uniform `m`-subset without replacement, drawn from the
/// per-group deterministic RNG.
#[derive(Debug, Clone, Copy)]
pub struct Random;

impl Selector for Random {
    fn name(&self) -> &str {
        "random"
    }
    fn kind(&self) -> StageKind {
        StageKind::Exact
    }
    fn select(&self, ctx: &SelectionContext, candidates: &[usize]) -> Result<Vec<usize>> {
        let m = target(ctx, candidates);
        if m == 0 {
            return Ok(Vec::new());
        }
        let mut rng = ctx.rng();
        Ok(map_back(candidates, ds::random(candidates.len(), m, &mut rng)?))
    }
}

no_arg_factory!(random_factory, Random);

/// `percentile` — the `(i+0.5)/m` quantiles of the reward distribution.
#[derive(Debug, Clone, Copy)]
pub struct Percentile;

impl Selector for Percentile {
    fn name(&self) -> &str {
        "percentile"
    }
    fn kind(&self) -> StageKind {
        StageKind::Exact
    }
    fn select(&self, ctx: &SelectionContext, candidates: &[usize]) -> Result<Vec<usize>> {
        let m = target(ctx, candidates);
        if m == 0 {
            return Ok(Vec::new());
        }
        Ok(map_back(candidates, ds::percentile(&sub_rewards(ctx, candidates), m)?))
    }
}

no_arg_factory!(percentile_factory, Percentile);

/// `first` — keep the first `m` candidates in index order: the
/// "no selection" baseline (equivalent to truncating generation at `m`),
/// and the explicit form of the pipeline's trailing clamp.
#[derive(Debug, Clone, Copy)]
pub struct First;

impl Selector for First {
    fn name(&self) -> &str {
        "first"
    }
    fn kind(&self) -> StageKind {
        StageKind::Exact
    }
    fn select(&self, ctx: &SelectionContext, candidates: &[usize]) -> Result<Vec<usize>> {
        Ok(candidates[..target(ctx, candidates)].to_vec())
    }
}

no_arg_factory!(first_factory, First);

#[cfg(test)]
mod tests {
    use super::super::testutil::fake_group;
    use super::super::{Pipeline, SelectionContext};
    use crate::coordinator::downsample as ds;
    use crate::util::prop::{for_cases, vec_f32};

    /// As pipeline stages over a filtered candidate set, the legacy
    /// kernels see only the surviving rewards: a stage fed the prefix
    /// candidates equals the kernel run on the prefix rewards.
    #[test]
    fn stages_operate_on_the_candidate_subset() {
        use super::super::Selector;
        for_cases(150, |rng| {
            let n = rng.gen_range_inclusive(2, 20) as usize;
            let rewards = vec_f32(rng, n, -2.0, 2.0);
            let keep = rng.gen_range_inclusive(1, n as i64) as usize;
            let m = rng.gen_range_inclusive(1, keep as i64) as usize;
            let g = fake_group(0, &rewards, None);
            let ctx = SelectionContext::new(&g, m, 0, 0);
            let candidates: Vec<usize> = (0..keep).collect();
            let prefix = &rewards[..keep];
            let got = super::MaxVariance.select(&ctx, &candidates).unwrap();
            assert_eq!(got, ds::max_variance(prefix, m).unwrap());
            let got = super::MaxReward.select(&ctx, &candidates).unwrap();
            assert_eq!(got, ds::max_reward(prefix, m).unwrap());
            let got = super::Percentile.select(&ctx, &candidates).unwrap();
            assert_eq!(got, ds::percentile(prefix, m).unwrap());
        });
    }

    #[test]
    fn random_is_replayable_from_context_only() {
        let g = fake_group(3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], None);
        let p = Pipeline::parse_default("random").unwrap();
        let a = p.select(&SelectionContext::new(&g, 3, 11, 4)).unwrap().kept;
        let b = p.select(&SelectionContext::new(&g, 3, 11, 4)).unwrap().kept;
        assert_eq!(a, b, "same (seed, iter, prompt) must replay identically");
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn first_keeps_prefix() {
        let g = fake_group(0, &[5.0, 1.0, 4.0, 2.0], None);
        let p = Pipeline::parse_default("first").unwrap();
        assert_eq!(p.select(&SelectionContext::new(&g, 2, 0, 0)).unwrap().kept, vec![0, 1]);
    }
}
