//! Context-aware filter stages motivated by the post-PODS literature.
//!
//! * [`DropZeroVariance`] — filter out whole groups whose rewards carry no
//!   learning signal (all equal ⇒ every GRPO advantage is ~0), following
//!   *"RLVR without Ineffective Samples: Group Prioritized Off-Policy
//!   Optimization for LLM Reasoning"*: all-correct / all-wrong groups are
//!   ineffective samples and their update compute is wasted.
//! * [`Prune`] — token-cost-aware pruning of over-long rollouts, following
//!   *"Prune as You Generate: Online Rollout Pruning for Faster and Better
//!   RLVR"*: the longest tail of a group dominates the update-phase token
//!   bill (and padding) while contributing the least reward signal per
//!   token.
//!
//! Both are [`StageKind::Filter`]s: they shrink the candidate set and are
//! typically composed before an exact rule, e.g.
//! `"drop_zero_variance | max_variance"` or
//! `"prune(max_tokens=4096) | percentile"`.

use super::{SelectionContext, Selector, SpecArgs, StageKind};
use crate::coordinator::downsample::subset_variance;
use anyhow::{bail, Result};

/// Drop the whole group when the candidate rewards are (near-)constant.
///
/// Returns the candidates unchanged when their population reward variance
/// exceeds `eps`, and an empty set (group dropped from the update batch)
/// otherwise. Groups a later exact stage would select from anyway are
/// untouched — this stage only decides group-level life or death.
#[derive(Debug, Clone, Copy)]
pub struct DropZeroVariance {
    /// Variance threshold below which the group counts as zero-signal.
    pub eps: f64,
}

/// Default `eps` of [`DropZeroVariance`] (matches the advantage-kernel
/// sigma floor).
pub const DEFAULT_ZERO_VARIANCE_EPS: f64 = 1e-6;

impl Selector for DropZeroVariance {
    fn name(&self) -> &str {
        "drop_zero_variance"
    }
    fn kind(&self) -> StageKind {
        StageKind::Filter
    }
    fn select(&self, ctx: &SelectionContext, candidates: &[usize]) -> Result<Vec<usize>> {
        let rewards = ctx.rewards();
        if subset_variance(&rewards, candidates) <= self.eps {
            Ok(Vec::new())
        } else {
            Ok(candidates.to_vec())
        }
    }
}

/// Registry factory for `drop_zero_variance(eps=..)`.
pub fn drop_zero_variance_factory(args: &SpecArgs) -> Result<Box<dyn Selector>> {
    args.expect_known(&["eps"])?;
    let eps = args.f64("eps")?.unwrap_or(DEFAULT_ZERO_VARIANCE_EPS);
    if eps.is_nan() || eps < 0.0 {
        bail!("drop_zero_variance: eps must be >= 0 (got {eps})");
    }
    Ok(Box::new(DropZeroVariance { eps }))
}

/// Token-budget / length-aware pruning of candidates.
///
/// Three composable criteria (any combination; each omitted one is off):
///
/// * `max_tokens=K` — drop rollouts whose generated length exceeds `K`
///   tokens (absolute cap).
/// * `quantile=Q` — drop rollouts longer than the nearest-rank `Q`-quantile
///   of the candidate lengths (scale-free cap; `0 < Q <= 1`).
/// * `budget=B` — keep rollouts shortest-first (ties by index) while the
///   cumulative generated-token count stays within `B` (total update-phase
///   token budget).
///
/// With no arguments, defaults to `quantile=0.75` (drop the longest
/// quartile). If every candidate violates the caps, the single shortest
/// one is kept instead of starving the group — a length cap should shape
/// the update, not silently drop prompts.
#[derive(Debug, Clone, Copy)]
pub struct Prune {
    /// Absolute generated-length cap.
    pub max_tokens: Option<usize>,
    /// Nearest-rank length-quantile cap (`0 < Q <= 1`).
    pub quantile: Option<f64>,
    /// Total generated-token budget, admitted shortest-first.
    pub budget: Option<usize>,
}

/// Default quantile when `prune` is given no arguments.
pub const DEFAULT_PRUNE_QUANTILE: f64 = 0.75;

impl Selector for Prune {
    fn name(&self) -> &str {
        "prune"
    }
    fn kind(&self) -> StageKind {
        StageKind::Filter
    }
    fn online_bound(&self) -> super::online::StageBound {
        // Only the bare absolute cap is bracketable online: the quantile
        // and budget criteria depend on the other candidates' (possibly
        // pending) lengths, so any combination involving them is opaque.
        match (self.max_tokens, self.quantile, self.budget) {
            (Some(k), None, None) => super::online::StageBound::LengthCap { max_tokens: k },
            _ => super::online::StageBound::Opaque,
        }
    }

    fn select(&self, ctx: &SelectionContext, candidates: &[usize]) -> Result<Vec<usize>> {
        let lens = ctx.gen_lens();
        // effective per-rollout cap: the tightest of the provided caps
        let mut cap = self.max_tokens;
        if let Some(q) = self.quantile {
            let mut sorted: Vec<usize> = candidates.iter().map(|&i| lens[i]).collect();
            sorted.sort_unstable();
            // nearest-rank quantile over the candidate lengths
            let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
            let qcap = sorted[rank - 1];
            cap = Some(cap.map_or(qcap, |c| c.min(qcap)));
        }
        let mut kept: Vec<usize> = match cap {
            Some(c) => candidates.iter().copied().filter(|&i| lens[i] <= c).collect(),
            None => candidates.to_vec(),
        };
        if let Some(budget) = self.budget {
            // admit shortest-first (ties by index), then restore candidate order
            let mut by_len: Vec<usize> = kept.clone();
            by_len.sort_by_key(|&i| (lens[i], i));
            let mut admitted = std::collections::HashSet::new();
            let mut spent = 0usize;
            for i in by_len {
                if spent + lens[i] > budget {
                    continue;
                }
                spent += lens[i];
                admitted.insert(i);
            }
            kept.retain(|i| admitted.contains(i));
        }
        if kept.is_empty() && !candidates.is_empty() {
            // guard: never starve the group on a length cap alone
            let shortest =
                candidates.iter().copied().min_by_key(|&i| (lens[i], i)).expect("non-empty");
            kept.push(shortest);
        }
        Ok(kept)
    }
}

/// Registry factory for `prune(max_tokens=.., quantile=.., budget=..)`.
pub fn prune_factory(args: &SpecArgs) -> Result<Box<dyn Selector>> {
    args.expect_known(&["max_tokens", "quantile", "budget"])?;
    let max_tokens = args.usize("max_tokens")?;
    let quantile = args.f64("quantile")?;
    let budget = args.usize("budget")?;
    if let Some(q) = quantile {
        if q.is_nan() || q <= 0.0 || q > 1.0 {
            bail!("prune: quantile must be in (0, 1] (got {q})");
        }
    }
    let quantile = if max_tokens.is_none() && quantile.is_none() && budget.is_none() {
        Some(DEFAULT_PRUNE_QUANTILE)
    } else {
        quantile
    };
    Ok(Box::new(Prune { max_tokens, quantile, budget }))
}

#[cfg(test)]
mod tests {
    use super::super::testutil::fake_group;
    use super::super::{Pipeline, SelectionContext};

    fn ctx_m(m: usize) -> (usize, u64, u64) {
        (m, 0, 0)
    }

    #[test]
    fn zero_variance_group_is_dropped() {
        let flat = fake_group(0, &[2.0, 2.0, 2.0, 2.0], None);
        let p = Pipeline::parse_default("drop_zero_variance | max_variance").unwrap();
        let (m, s, i) = ctx_m(2);
        let sel = p.select(&SelectionContext::new(&flat, m, s, i)).unwrap();
        assert!(sel.kept.is_empty(), "all-equal rewards carry no GRPO signal");
        assert_eq!(sel.diag.kept, 0);
        assert_eq!(sel.diag.tokens_dropped, 16);

        let mixed = fake_group(0, &[2.0, 2.0, 0.0, 2.0], None);
        let sel = p.select(&SelectionContext::new(&mixed, m, s, i)).unwrap();
        assert_eq!(sel.kept.len(), 2, "informative group passes through");
    }

    #[test]
    fn zero_variance_eps_is_tunable() {
        // variance of [0, 0.01, 0, 0.01] is 2.5e-5: dropped at eps=1e-3,
        // kept at the default 1e-6
        let g = fake_group(0, &[0.0, 0.01, 0.0, 0.01], None);
        let loose = Pipeline::parse_default("drop_zero_variance(eps=1e-3) | first").unwrap();
        let tight = Pipeline::parse_default("drop_zero_variance | first").unwrap();
        let ctx = SelectionContext::new(&g, 2, 0, 0);
        assert!(loose.select(&ctx).unwrap().kept.is_empty());
        assert_eq!(tight.select(&ctx).unwrap().kept.len(), 2);
    }

    #[test]
    fn prune_max_tokens_drops_long_rollouts() {
        let g = fake_group(0, &[3.0, 0.0, 2.0, 1.0], Some(&[10, 50, 20, 40]));
        let p = Pipeline::parse_default("prune(max_tokens=32) | max_reward").unwrap();
        let sel = p.select(&SelectionContext::new(&g, 2, 0, 0)).unwrap();
        let mut kept = sel.kept.clone();
        kept.sort_unstable();
        // candidates after prune: {0, 2}; max_reward keeps both (m=2)
        assert_eq!(kept, vec![0, 2]);
        assert_eq!(sel.diag.tokens_kept, 30);
        assert_eq!(sel.diag.tokens_dropped, 90);
    }

    #[test]
    fn prune_quantile_drops_longest_tail() {
        let g = fake_group(0, &[1.0, 2.0, 3.0, 4.0], Some(&[10, 20, 30, 1000]));
        let p = Pipeline::parse_default("prune(quantile=0.75) | first").unwrap();
        let sel = p.select(&SelectionContext::new(&g, 4, 0, 0)).unwrap();
        assert_eq!(sel.kept, vec![0, 1, 2], "75th-percentile cap cuts the outlier");
    }

    #[test]
    fn prune_budget_admits_shortest_first() {
        let g = fake_group(0, &[1.0, 2.0, 3.0, 4.0], Some(&[30, 10, 20, 25]));
        let p = Pipeline::parse_default("prune(budget=55) | first").unwrap();
        let sel = p.select(&SelectionContext::new(&g, 4, 0, 0)).unwrap();
        // shortest-first admission: 10 + 20 + 25 = 55 fits; 30 does not
        assert_eq!(sel.kept, vec![1, 2, 3], "candidate order restored after admission");
    }

    #[test]
    fn prune_never_starves_a_group() {
        let g = fake_group(0, &[1.0, 2.0], Some(&[80, 90]));
        let p = Pipeline::parse_default("prune(max_tokens=10) | max_variance").unwrap();
        let sel = p.select(&SelectionContext::new(&g, 1, 0, 0)).unwrap();
        assert_eq!(sel.kept, vec![0], "shortest survivor kept despite the cap");
    }

    #[test]
    fn prune_default_is_quantile() {
        let g = fake_group(0, &[1.0; 8], Some(&[1, 2, 3, 4, 5, 6, 7, 100]));
        let p = Pipeline::parse_default("prune | first").unwrap();
        let sel = p.select(&SelectionContext::new(&g, 8, 0, 0)).unwrap();
        assert_eq!(sel.kept.len(), 6, "default quantile=0.75 keeps the shortest 6");
    }

    #[test]
    fn prune_rejects_bad_quantile() {
        assert!(Pipeline::parse_default("prune(quantile=0)").is_err());
        assert!(Pipeline::parse_default("prune(quantile=1.5)").is_err());
        assert!(Pipeline::parse_default("drop_zero_variance(eps=-1)").is_err());
    }
}
