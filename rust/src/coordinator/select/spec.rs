//! Selector spec grammar and registry.
//!
//! A pipeline spec is a `|`-separated chain of stages; each stage is a
//! registered name with optional `key=value` arguments:
//!
//! ```text
//! spec  := stage ( "|" stage )*
//! stage := name [ "(" [ arg ("," arg)* ] ")" ]
//! arg   := key "=" value
//! name  := [A-Za-z_][A-Za-z0-9_]*
//! ```
//!
//! Examples (all valid `algo.rule` config values):
//!
//! ```text
//! rule = "max_variance"
//! rule = "drop_zero_variance | max_variance"
//! rule = "prune(max_tokens=4096) | percentile"
//! rule = "drop_zero_variance(eps=1e-4) | prune(quantile=0.75) | random"
//! ```
//!
//! The [`Registry`] maps names to factories. [`default_registry`] carries
//! the built-ins; embedders extend selection by building their own
//! registry (`Registry::with_builtins()` + [`Registry::register`]) and
//! parsing pipelines against it — no enum to edit.

use super::{filters, legacy, Selector};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::sync::OnceLock;

/// Parsed `key=value` arguments of one stage, with typed accessors.
#[derive(Debug, Clone)]
pub struct SpecArgs {
    stage: String,
    args: Vec<(String, String)>,
}

impl SpecArgs {
    /// Args for `stage` (factories receive these at pipeline parse).
    pub fn new(stage: impl Into<String>, args: Vec<(String, String)>) -> Self {
        Self { stage: stage.into(), args }
    }

    /// Stage name these args belong to (for error messages).
    pub fn stage(&self) -> &str {
        &self.stage
    }

    /// Raw value of `key`, if provided.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.args.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Typed accessor: `key` as f64 (None when absent, Err on non-number).
    pub fn f64(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| anyhow!("{}: {key}={v:?} is not a number", self.stage)),
        }
    }

    /// Typed accessor: `key` as usize (None when absent, Err otherwise).
    pub fn usize(&self, key: &str) -> Result<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| anyhow!("{}: {key}={v:?} is not a non-negative integer", self.stage)),
        }
    }

    /// Reject typos: every provided key must be in `known`.
    pub fn expect_known(&self, known: &[&str]) -> Result<()> {
        for (k, _) in &self.args {
            if !known.contains(&k.as_str()) {
                bail!(
                    "{}: unknown argument {k:?} (accepted: {})",
                    self.stage,
                    if known.is_empty() { "none".to_string() } else { known.join(", ") }
                );
            }
        }
        Ok(())
    }
}

/// Builds one configured stage from its parsed arguments.
pub type Factory = fn(&SpecArgs) -> Result<Box<dyn Selector>>;

/// Name → factory table the spec parser resolves stages against.
#[derive(Debug, Default, Clone)]
pub struct Registry {
    factories: BTreeMap<String, Factory>,
}

impl Registry {
    /// An empty registry (embedders compose their own selector set).
    pub fn empty() -> Self {
        Self::default()
    }

    /// All built-in selectors: the four legacy rules, the `first`
    /// truncation baseline, and the two context-aware filters.
    pub fn with_builtins() -> Self {
        let mut r = Self::empty();
        r.register("max_variance", legacy::max_variance_factory);
        r.register("max_reward", legacy::max_reward_factory);
        r.register("random", legacy::random_factory);
        r.register("percentile", legacy::percentile_factory);
        r.register("first", legacy::first_factory);
        r.register("drop_zero_variance", filters::drop_zero_variance_factory);
        r.register("prune", filters::prune_factory);
        r
    }

    /// Register (or replace) a selector factory under `name`.
    pub fn register(&mut self, name: &str, factory: Factory) {
        debug_assert!(is_valid_name(name), "invalid selector name {name:?}");
        self.factories.insert(name.to_string(), factory);
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.factories.keys().map(|s| s.as_str()).collect()
    }

    /// Parse and build one stage, e.g. `"prune(max_tokens=4096)"`.
    pub fn build_stage(&self, stage: &str) -> Result<Box<dyn Selector>> {
        let (name, args) = parse_stage(stage)?;
        let factory = self.factories.get(&name).ok_or_else(|| {
            anyhow!("unknown selector {name:?} (registered: {})", self.names().join("|"))
        })?;
        factory(&args)
    }

    /// Parse a full `|`-composed pipeline spec into its stages.
    pub fn parse_pipeline(&self, spec: &str) -> Result<Vec<Box<dyn Selector>>> {
        if spec.trim().is_empty() {
            bail!("empty selector spec");
        }
        spec.split('|').map(|stage| self.build_stage(stage)).collect()
    }
}

/// The process-wide registry of built-in selectors (what config strings
/// resolve against).
pub fn default_registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::with_builtins)
}

fn is_valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Parse `name` or `name(k=v, ...)` into the name and its arguments.
fn parse_stage(stage: &str) -> Result<(String, SpecArgs)> {
    let s = stage.trim();
    if s.is_empty() {
        bail!("empty selector stage (stray '|'?)");
    }
    let (name, inner) = match s.find('(') {
        None => (s, None),
        Some(i) => {
            let Some(inner) = s[i + 1..].strip_suffix(')') else {
                bail!("stage {s:?}: missing closing ')'");
            };
            (s[..i].trim_end(), Some(inner))
        }
    };
    if !is_valid_name(name) {
        bail!("bad selector name {name:?} in stage {s:?}");
    }
    let mut args = Vec::new();
    if let Some(inner) = inner {
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                if inner.trim().is_empty() && args.is_empty() {
                    break; // `name()` — empty arg list
                }
                bail!("stage {s:?}: empty argument");
            }
            let Some((k, v)) = part.split_once('=') else {
                bail!("stage {s:?}: argument {part:?} is not key=value");
            };
            let (k, v) = (k.trim(), v.trim());
            if k.is_empty() || v.is_empty() {
                bail!("stage {s:?}: argument {part:?} has an empty key or value");
            }
            args.push((k.to_string(), v.to_string()));
        }
    }
    Ok((name.to_string(), SpecArgs::new(name, args)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::select::Pipeline;

    #[test]
    fn parses_bare_and_argful_stages() {
        let (name, args) = parse_stage(" max_variance ").unwrap();
        assert_eq!(name, "max_variance");
        assert!(args.get("x").is_none());

        let (name, args) = parse_stage("prune(max_tokens=4096, quantile=0.9)").unwrap();
        assert_eq!(name, "prune");
        assert_eq!(args.usize("max_tokens").unwrap(), Some(4096));
        assert_eq!(args.f64("quantile").unwrap(), Some(0.9));
        assert_eq!(args.usize("budget").unwrap(), None);

        let (name, args) = parse_stage("random()").unwrap();
        assert_eq!(name, "random");
        assert!(args.expect_known(&[]).is_ok());
    }

    #[test]
    fn rejects_malformed_stages() {
        for bad in [
            "",
            "  ",
            "9lives",
            "prune(",
            "prune(max_tokens)",
            "prune(=3)",
            "prune(max_tokens=)",
            "pr une",
            "a | | b",
        ] {
            assert!(
                default_registry().parse_pipeline(bad).is_err(),
                "{bad:?} should not parse"
            );
        }
    }

    #[test]
    fn unknown_name_lists_registered() {
        let err = default_registry().build_stage("best_ever").unwrap_err().to_string();
        assert!(err.contains("max_variance"), "{err}");
    }

    #[test]
    fn typoed_argument_is_rejected() {
        assert!(default_registry().build_stage("drop_zero_variance(epss=1.0)").is_err());
        assert!(default_registry().build_stage("max_variance(m=3)").is_err());
    }

    #[test]
    fn pipeline_composes_stages_in_order() {
        let p = Pipeline::parse_default("drop_zero_variance | prune(quantile=0.75) | percentile")
            .unwrap();
        assert_eq!(p.stage_names(), vec!["drop_zero_variance", "prune", "percentile"]);
        assert_eq!(p.spec(), "drop_zero_variance | prune(quantile=0.75) | percentile");
    }

    #[test]
    fn custom_registry_extends_selection() {
        use crate::coordinator::select::{SelectionContext, Selector, StageKind};
        #[derive(Debug)]
        struct Evens;
        impl Selector for Evens {
            fn name(&self) -> &str {
                "evens"
            }
            fn kind(&self) -> StageKind {
                StageKind::Filter
            }
            fn select(&self, _: &SelectionContext, c: &[usize]) -> Result<Vec<usize>> {
                Ok(c.iter().copied().filter(|i| i % 2 == 0).collect())
            }
        }
        fn evens_factory(args: &SpecArgs) -> Result<Box<dyn Selector>> {
            args.expect_known(&[])?;
            Ok(Box::new(Evens))
        }
        let mut reg = Registry::with_builtins();
        reg.register("evens", evens_factory);
        let p = Pipeline::parse("evens | max_variance", &reg).unwrap();
        let g = crate::coordinator::select::testutil::fake_group(
            0,
            &[0.0, 9.0, 1.0, 9.0, 2.0, 9.0],
            None,
        );
        let sel = p.select(&SelectionContext::new(&g, 2, 0, 0)).unwrap();
        let mut kept = sel.kept.clone();
        kept.sort_unstable();
        assert_eq!(kept, vec![0, 4], "extremes of the even-indexed rewards");
    }
}
