//! The trainer façade — GRPO / GRPO-GA / GRPO-PODS over the staged
//! executor.
//!
//! One [`Trainer::train_iteration`] implements Algorithm 1 over a batch of
//! prompts by driving [`crate::coordinator::exec::TrainLoop`]:
//!
//! 1. **Inference phase** — `n` rollouts per prompt via the
//!    [`crate::coordinator::exec::RolloutEngine`] (real thread pool sized
//!    by `hwsim.workers`, cross-group call packing), verified with the
//!    rule-based reward model.
//! 2. **Select** — run the configured selector pipeline within each prompt
//!    group (`m = n` for the GRPO/GA baselines), normalize advantages
//!    (§A.3 mode), and record the per-iteration selection diagnostics.
//! 3. **Policy-update phase** — the
//!    [`crate::coordinator::exec::UpdateEngine`]: fixed-size micro-batches
//!    through the `grad` artifact, gradient accumulation, all-reduce
//!    (simulated), fused AdamW.
//!
//! Under `hwsim.schedule = "pipelined"` the executor additionally starts
//! generating iteration *t+1* (on the rollout pool, against the
//! pre-update policy) while phase 3 of iteration *t* runs on this thread;
//! the hwsim clock then charges `max(inference, update)` for the
//! overlapped portion. With `[fleet]` configured, both schedules are
//! special cases of the staleness-K two-fleet model (see
//! [`crate::coordinator::exec`]): up to `max_staleness` batches queue
//! ahead, and the recorder logs per-iteration staleness, queue depth and
//! fleet utilization alongside the overlap savings so every figure can be
//! regenerated from the CSVs.

use crate::config::RunConfig;
use crate::coordinator::ckpt as resume;
use crate::coordinator::exec::{build_gen_batch, StepCtx, TrainLoop};
use crate::coordinator::replay::ReplayStore;
use crate::coordinator::select::Pipeline;
use crate::eval;
use crate::hwsim::SimClock;
use crate::metrics::{EvalRow, IterRow, Recorder};
use crate::reward::RewardWeights;
use crate::runtime::{params as ckpt, Engine, ParamStore, TensorF, TensorI};
use crate::tasks::{Split, TaskKind};
use anyhow::{anyhow, Result};
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

/// The `[budget]` knobs one iteration's allocator runs under, resolved
/// against `algo.n`. Carried by the generation batch so the rollout
/// engine can split decoding into the probe wave and the reallocated
/// extra wave (see [`BudgetAllocator`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BudgetSpec {
    /// Per-prompt decode budget of the fixed-`n` baseline (`algo.n`); the
    /// allocator redistributes `(n − n_probe) × |groups|` slots in total.
    pub n: usize,
    /// Rollouts decoded per prompt before any reallocation.
    pub n_probe: usize,
    /// Hard per-prompt cap on total rollouts (probe + extras).
    pub max_per_prompt: usize,
    /// Observed reward-bracket width below which a group is saturated.
    pub width_threshold: f64,
}

impl BudgetSpec {
    /// The spec for a validated config — `None` when `[budget]` is
    /// disabled, so the rollout engine takes the fixed-`n` path untouched.
    pub fn from_config(cfg: &RunConfig) -> Option<Self> {
        if !cfg.budget.enabled {
            return None;
        }
        Some(Self {
            n: cfg.algo.n,
            n_probe: cfg.budget.n_probe,
            max_per_prompt: cfg.budget.max_per_prompt,
            width_threshold: cfg.budget.width_threshold,
        })
    }
}

/// Adaptive per-prompt rollout-budget allocator.
///
/// Each iteration decodes a probe quota of `n_probe` rollouts per prompt
/// first; the allocator then streams the remaining `(n − n_probe) ×
/// |groups|` slots to the groups whose **observed reward bracket** — the
/// min/max over finished, unpruned probe rewards, the same per-group
/// state the online [`crate::coordinator::select::online::GroupVerdicts`]
/// analysis tracks — is still at least `width_threshold` wide. Groups
/// below the threshold are *saturated* (selection would discard their
/// near-identical rollouts anyway) and release their budget.
///
/// **Allocation is history, not partition** (docs/DETERMINISM.md): the
/// inputs are the canonically-assembled probe outcomes, never the worker
/// shard layout, slot order or chunk interleaving that produced them, and
/// the priority rule below is a pure function of those observations. The
/// allocation sequence — and therefore every extra row's
/// [`crate::rollout::row_seed`]-derived token stream — is bit-invariant
/// to worker-pool size and decode-chunk size.
///
/// The group-major row queue becomes a dynamic priority queue here: slots
/// are assigned one at a time to the eligible group with the fewest
/// rollouts so far (ties: wider bracket first, then lower group index),
/// so still-wide groups share the released budget evenly instead of the
/// widest group monopolizing it.
#[derive(Debug, Clone)]
pub struct BudgetAllocator {
    spec: BudgetSpec,
    /// Per-group (min, max) over observed finished probe rewards.
    obs: Vec<Option<(f32, f32)>>,
}

impl BudgetAllocator {
    /// An allocator for one iteration over `n_groups` prompt groups, with
    /// no observations yet.
    pub fn new(spec: BudgetSpec, n_groups: usize) -> Self {
        Self { spec, obs: vec![None; n_groups] }
    }

    /// The spec this allocator runs under.
    pub fn spec(&self) -> &BudgetSpec {
        &self.spec
    }

    /// Fold one finished (unpruned) probe rollout's reward into the
    /// group's observed bracket. Call in canonical (group, rollout_idx)
    /// order — though min/max folding makes the result order-invariant.
    pub fn observe(&mut self, group: usize, reward: f32) {
        let e = &mut self.obs[group];
        *e = Some(match *e {
            None => (reward, reward),
            Some((lo, hi)) => (lo.min(reward), hi.max(reward)),
        });
    }

    /// Observed reward-bracket width of a group: `max − min` over its
    /// finished probe rewards, `0.0` with fewer than two observations (an
    /// unobservable group cannot justify extra decode spend).
    pub fn width(&self, group: usize) -> f64 {
        match self.obs[group] {
            Some((lo, hi)) => (hi - lo) as f64,
            None => 0.0,
        }
    }

    /// Is the group saturated? True when the observed bracket is narrower
    /// than the threshold, and always for a group with no observations at
    /// all (every probe row lost or pruned) — even at `width_threshold =
    /// 0`, an unobservable group cannot justify extra decode spend.
    pub fn is_saturated(&self, group: usize) -> bool {
        self.obs[group].is_none() || self.width(group) < self.spec.width_threshold
    }

    /// Number of saturated groups under the current observations — the
    /// `budget_saturated_groups` train-CSV column.
    pub fn saturated_groups(&self) -> usize {
        (0..self.obs.len()).filter(|&g| self.is_saturated(g)).count()
    }

    /// Stream the extra slots: returns the allocation sequence as
    /// `(group_idx, rollout_idx)` pairs with `rollout_idx >= n_probe`, at
    /// most `(n − n_probe) × |groups|` total and at most `max_per_prompt −
    /// n_probe` per group. Deterministic: a [`BinaryHeap`] keyed on
    /// (rollouts-so-far asc, bracket width desc, group index asc) pops the
    /// same sequence for the same observations, whatever schedule produced
    /// them.
    pub fn allocate(&self) -> Vec<(usize, u32)> {
        let groups = self.obs.len();
        let slots = (self.spec.n - self.spec.n_probe.min(self.spec.n)) * groups;
        let mut out = Vec::with_capacity(slots);
        // max-heap of Reverse(key): pop order = fewest-rollouts-first,
        // ties by widest bracket (f64 >= 0, so the bit pattern orders
        // monotonically), then lowest group index
        let mut heap: BinaryHeap<Reverse<(usize, Reverse<u64>, usize)>> = (0..groups)
            .filter(|&g| !self.is_saturated(g))
            .map(|g| Reverse((self.spec.n_probe, Reverse(self.width(g).to_bits()), g)))
            .collect();
        while out.len() < slots {
            let Some(Reverse((count, w, g))) = heap.pop() else {
                break; // every still-wide group hit max_per_prompt
            };
            if count >= self.spec.max_per_prompt {
                continue;
            }
            out.push((g, count as u32));
            heap.push(Reverse((count + 1, w, g)));
        }
        out
    }
}

/// Per-iteration summary returned by [`Trainer::train_iteration`].
#[derive(Debug, Clone, Copy, Default)]
pub struct IterStats {
    /// Mean total reward over all generated rollouts.
    pub train_reward: f32,
    /// Mean accuracy-component over all generated rollouts.
    pub train_acc: f32,
    /// Mean generated length (tokens incl. EOS).
    pub completion_len: f32,
    /// Mean update loss over trained rollouts.
    pub loss: f32,
    /// Mean clipped-ratio fraction over trained rollouts.
    pub clip_frac: f32,
    /// Mean KL-to-reference over trained rollouts.
    pub kl: f32,
    /// Physical `grad` calls the update executed.
    pub micro_steps: usize,
    /// Rollouts generated this iteration.
    pub rollouts_generated: usize,
    /// Rollouts the update trained on (after selection).
    pub rollouts_trained: usize,
    /// Simulated device shards the update was split over.
    pub upd_shards: usize,
    /// Ring all-reduce portion of `sim_update` (0 for one shard).
    pub upd_comm_time: f64,
    /// Peak rollouts resident per shard in one update micro-step.
    pub upd_peak_mem: usize,
    /// Decode-step slots the chunked driver physically executed.
    pub gen_tokens_decoded: usize,
    /// Decoded slots that produced no trainable token.
    pub gen_tokens_wasted: usize,
    /// Decode budget released by online pruning (`[rollout] online_prune`).
    pub gen_tokens_pruned: usize,
    /// Rollouts aborted mid-decode by online pruning.
    pub rows_pruned_online: usize,
    /// Stored rows replayed into this update (`[replay]`).
    pub replay_rows_used: usize,
    /// Rows resident in the replay store after this iteration.
    pub replay_store_size: usize,
    /// Mean staleness (iterations) of the rows replayed this update.
    pub replay_mean_staleness: f64,
    /// Physical prompt-prefill calls the decode drivers executed.
    pub prefill_calls: usize,
    /// Refill admissions served from a group snapshot instead of a fresh
    /// prefill (`[rollout] share_prompt_kv`).
    pub prefill_calls_saved: usize,
    /// Peak bytes resident in the modeled paged KV pool (max over shards).
    pub kv_peak_bytes: u64,
    /// Faults the schedule injected across this iteration's row-attempts.
    pub faults_injected: usize,
    /// Physical shard retries the rollout pool executed.
    pub shard_retries: usize,
    /// Rows lost after exhausting the retry budget (graceful degradation).
    pub rows_lost: usize,
    /// Simulated retry bill (backoff + wasted/straggler work), included in
    /// `sim_inference`.
    pub retry_time: f64,
    /// Extra rollouts the budget allocator streamed to still-wide groups
    /// (`[budget]`; 0 when disabled).
    pub budget_extra_rows: usize,
    /// Groups the allocator classified saturated after the probe wave.
    pub budget_saturated_groups: usize,
    /// Simulated cost of the inference phase.
    pub sim_inference: f64,
    /// Simulated cost of the update phase (incl. communication).
    pub sim_update: f64,
    /// What the simulated clock actually advanced during this step (less
    /// than `sim_inference + sim_update` when phases overlapped).
    pub sim_step: f64,
    /// Simulated time hidden by overlapping this iteration's generation
    /// with the previous update (zero under the sync schedule).
    pub sim_overlap_saved: f64,
    /// Realized staleness of the consumed batch (iter − born); 0 under
    /// the sync schedule and ≤ `fleet.max_staleness` by construction.
    pub fleet_staleness: usize,
    /// Ready-batch queue depth after this step's refill.
    pub fleet_queue_depth: usize,
}

/// The leader: owns engine, parameters, clock, metrics and the RL loop.
pub struct Trainer {
    /// The PJRT engine for the run's artifact profile.
    pub engine: Engine,
    /// The run's validated configuration.
    pub cfg: RunConfig,
    /// Optimized vector (full params, or LoRA adapters in LoRA profiles).
    pub store: ParamStore,
    /// Frozen full-parameter base (LoRA profiles only).
    pub base: Option<Vec<f32>>,
    /// Reference-policy snapshot for the KL term (when kl_coef > 0).
    /// Arc-shared: generation snapshots clone the handle, not the vector.
    pub ref_params: Option<std::sync::Arc<Vec<f32>>>,
    /// Reference-policy adapter snapshot (LoRA profiles with KL).
    pub ref_lora: Option<std::sync::Arc<Vec<f32>>>,
    /// The run's simulated wall clock.
    pub clock: SimClock,
    /// Per-iteration and per-eval telemetry, flushed to CSVs at the end.
    pub recorder: Recorder,
    /// Task family generating prompts and verifying answers.
    pub task: TaskKind,
    /// Additional evaluation tracks run at every eval point — (task, split,
    /// label). Used by the Fig. 7 generalization study (platinum /
    /// cross-task test sets).
    pub extra_evals: Vec<(TaskKind, Split, String)>,
    /// The rollout-selection pipeline built from `algo.rule`. Stochastic
    /// stages reseed per group from `(run_seed, iter, prompt_id)`, so no
    /// trainer-level RNG is involved in selection.
    pipeline: Pipeline,
    /// The staged executor: rollout thread pool, update engine, schedule
    /// state (pipelined prefetch + overlap accounting).
    pub exec: TrainLoop,
    prompt_cursor: u64,
    started: Instant,
    /// First iteration [`Self::run`] executes — 0 for a fresh run, the
    /// checkpoint's `next_iter` after [`Self::resume_from`].
    start_iter: usize,
}

impl Trainer {
    /// Build a trainer from a validated config. Loads the artifact profile,
    /// initializes (or loads) parameters, and snapshots the KL reference.
    pub fn new(artifacts_dir: &std::path::Path, cfg: RunConfig) -> Result<Self> {
        let engine = Engine::load(artifacts_dir, &cfg.run.profile)?;
        crate::tasks::tokenizer::verify_against_meta(&engine.meta.vocab)?;
        // config validation that needs the artifact profile: reject
        // update.micro_batch > B_u here, before any SFT/rollout work runs,
        // instead of erroring mid-iteration in the update phase
        cfg.update.rows_per_call(engine.meta.config.update_batch)?;
        let task = cfg.task_kind();

        let (store, base) = if engine.meta.is_lora() {
            let ckpt_path = cfg.run.base_checkpoint.as_ref().ok_or_else(|| {
                anyhow!("LoRA profile {:?} requires run.base_checkpoint", cfg.run.profile)
            })?;
            let (_, base_store, _) = ckpt::load_store(std::path::Path::new(ckpt_path))?;
            if base_store.params.len() != engine.meta.param_count {
                return Err(anyhow!(
                    "base checkpoint has {} params, profile expects {}",
                    base_store.params.len(),
                    engine.meta.param_count
                ));
            }
            let lora0 = engine.init(cfg.run.seed as u32)?;
            (ParamStore::new(lora0), Some(base_store.params))
        } else if let Some(ckpt_path) = &cfg.run.base_checkpoint {
            // full-parameter RL warm-started from an SFT'd checkpoint
            let (_, mut base_store, _) = ckpt::load_store(std::path::Path::new(ckpt_path))?;
            if base_store.params.len() != engine.meta.param_count {
                return Err(anyhow!(
                    "checkpoint has {} params, profile expects {}",
                    base_store.params.len(),
                    engine.meta.param_count
                ));
            }
            // fresh optimizer state for the RL phase
            base_store.m.iter_mut().for_each(|x| *x = 0.0);
            base_store.v.iter_mut().for_each(|x| *x = 0.0);
            base_store.step = 0;
            (base_store, None)
        } else {
            let p0 = engine.init(cfg.run.seed as u32)?;
            (ParamStore::new(p0), None)
        };

        let exec = TrainLoop::new(
            artifacts_dir.to_path_buf(),
            &cfg.run.profile,
            cfg.hwsim.workers,
            cfg.hwsim.schedule,
            store.len(),
        );
        let pipeline = cfg.selector();
        Ok(Self {
            engine,
            cfg,
            store,
            base,
            ref_params: None,
            ref_lora: None,
            clock: SimClock::new(),
            recorder: Recorder::new(),
            task,
            extra_evals: Vec::new(),
            pipeline,
            exec,
            prompt_cursor: 0,
            started: Instant::now(),
            start_iter: 0,
        })
    }

    /// The full-parameter vector used for rollouts/eval (base in LoRA mode).
    fn full_params(&self) -> &[f32] {
        match &self.base {
            Some(b) => b,
            None => &self.store.params,
        }
    }

    /// The LoRA vector passed alongside (None in full-parameter mode).
    fn lora_vec(&self) -> Option<&[f32]> {
        if self.engine.meta.is_lora() {
            Some(&self.store.params)
        } else {
            None
        }
    }

    /// Snapshot the current policy as the KL reference (call after SFT /
    /// before RL). No-op if kl_coef == 0.
    pub fn snapshot_reference(&mut self) {
        if self.cfg.algo.kl_coef > 0.0 {
            self.ref_params = Some(std::sync::Arc::new(self.full_params().to_vec()));
            self.ref_lora = self.lora_vec().map(|l| std::sync::Arc::new(l.to_vec()));
        }
    }

    /// SFT warm-up: teacher-forced cross-entropy on gold responses — the
    /// stand-in for starting from an instruct-tuned checkpoint. Only valid
    /// in full-parameter profiles (the base is what gets pre-trained).
    pub fn sft_warmup(&mut self) -> Result<()> {
        let Some(sft) = self.cfg.sft.clone() else {
            return Ok(());
        };
        if sft.steps == 0 {
            return Ok(());
        }
        if self.engine.meta.is_lora() {
            return Err(anyhow!("SFT warm-up requires a full-parameter profile"));
        }
        let bu = self.engine.meta.config.update_batch;
        let t = self.engine.meta.config.seq_len;
        let p = self.engine.meta.config.prompt_len;
        let log_every = if sft.log_every == 0 { 50 } else { sft.log_every };
        let pool = if sft.pool == 0 { u64::MAX } else { sft.pool as u64 };
        for step in 0..sft.steps {
            // cycle a bounded problem pool: multiple epochs over the same
            // examples is what lets the small policy generalise
            let start = (step as u64 * bu as u64) % pool;
            let problems = self.task.batch(Split::Train, start, bu);
            let mut tokens = vec![crate::tasks::tokenizer::PAD; bu * t];
            let mut mask = vec![0.0f32; bu * t];
            let mut pads = vec![0i32; bu];
            for (b, pr) in problems.iter().enumerate() {
                let pad = p - pr.prompt.len();
                pads[b] = pad as i32;
                for (j, &tk) in pr.prompt.iter().enumerate() {
                    tokens[b * t + pad + j] = tk;
                }
                for (j, &tk) in pr.ideal_response.iter().take(t - p).enumerate() {
                    tokens[b * t + p + j] = tk;
                    mask[b * t + p + j] = 1.0;
                }
            }
            let tokens = TensorI::new(tokens, &[bu, t])?;
            let mask = TensorF::new(mask, &[bu, t])?;
            let loss = self
                .engine
                .sft_step(&mut self.store, &tokens, &pads, &mask, sft.lr as f32)?;
            if step % log_every == 0 || step + 1 == sft.steps {
                eprintln!("[sft] step {step}/{} loss {loss:.4}", sft.steps);
            }
        }
        self.prompt_cursor = 0; // RL re-walks the train split from the start
        Ok(())
    }

    /// One full Algorithm-1 iteration over `prompts_per_iter` prompts.
    ///
    /// Under the pipelined schedule this also prefetches generation of
    /// `iter + 1` (unless `iter` is the run's final iteration), so the
    /// rollout pool works while the update runs here.
    pub fn train_iteration(&mut self, iter: usize) -> Result<IterStats> {
        let prefetch_next = iter + 1 < self.cfg.run.iterations;
        self.step(iter, prefetch_next)
    }

    /// One executor step with explicit prefetch control (drivers that
    /// know their horizon — benches, sweeps — call this directly).
    pub fn step(&mut self, iter: usize, prefetch_next: bool) -> Result<IterStats> {
        let ctx = StepCtx {
            engine: &self.engine,
            store: &mut self.store,
            base: self.base.as_deref(),
            ref_params: self.ref_params.clone(),
            ref_lora: self.ref_lora.clone(),
            cfg: &self.cfg,
            pipeline: &self.pipeline,
            task: self.task,
            clock: &mut self.clock,
            prompt_cursor: &mut self.prompt_cursor,
        };
        let r = self.exec.step(ctx, iter, prefetch_next)?;

        let stats = IterStats {
            train_reward: r.train_reward,
            train_acc: r.train_acc,
            completion_len: r.completion_len,
            loss: r.loss,
            clip_frac: r.clip_frac,
            kl: r.kl,
            micro_steps: r.micro_steps,
            rollouts_generated: r.rollouts_generated,
            rollouts_trained: r.rollouts_trained,
            upd_shards: r.upd_shards,
            upd_comm_time: r.upd_comm_time,
            upd_peak_mem: r.upd_peak_mem,
            gen_tokens_decoded: r.gen_tokens_decoded,
            gen_tokens_wasted: r.gen_tokens_wasted,
            gen_tokens_pruned: r.gen_tokens_pruned,
            rows_pruned_online: r.rows_pruned_online,
            replay_rows_used: r.replay_rows_used,
            replay_store_size: r.replay_store_size,
            replay_mean_staleness: r.replay_mean_staleness,
            prefill_calls: r.prefill_calls,
            prefill_calls_saved: r.prefill_calls_saved,
            kv_peak_bytes: r.kv_peak_bytes,
            faults_injected: r.faults_injected,
            shard_retries: r.shard_retries,
            rows_lost: r.rows_lost,
            retry_time: r.retry_time,
            budget_extra_rows: r.budget_extra_rows,
            budget_saturated_groups: r.budget_saturated_groups,
            sim_inference: r.sim_inference,
            sim_update: r.sim_update,
            sim_step: r.sim_step,
            sim_overlap_saved: r.sim_overlap_saved,
            fleet_staleness: r.fleet_staleness,
            fleet_queue_depth: r.fleet_queue_depth,
        };
        // running staleness statistics, recomputed from the recorded rows
        // via integer sums so a resumed run reproduces them bit-exactly
        let fleet_replicas = self.cfg.fleet.inference_replicas.max(1);
        let mut prior_sum = 0usize;
        let mut prior_max = 0usize;
        for row in &self.recorder.iters {
            prior_sum += row.fleet_staleness;
            prior_max = prior_max.max(row.fleet_staleness);
        }
        let n_rows = self.recorder.iters.len() + 1;
        let fleet_mean_staleness = (prior_sum + r.fleet_staleness) as f64 / n_rows as f64;
        let fleet_max_staleness = prior_max.max(r.fleet_staleness);
        let fleet_inf_util = if r.sim_step > 0.0 {
            r.sim_inference / (fleet_replicas as f64 * r.sim_step)
        } else {
            0.0
        };
        let fleet_upd_util = if r.sim_step > 0.0 { r.sim_update / r.sim_step } else { 0.0 };
        self.recorder.push_iter(IterRow {
            iter,
            sim_time: self.clock.now(),
            real_time: self.started.elapsed().as_secs_f64(),
            sim_inference_time: r.sim_inference,
            sim_update_time: r.sim_update,
            train_reward: stats.train_reward,
            train_acc: stats.train_acc,
            completion_len: stats.completion_len,
            sel_variance: r.sel_variance,
            sel_tokens_kept: r.sel_stats.tokens_kept,
            sel_tokens_dropped: r.sel_stats.tokens_dropped,
            sel_groups_dropped: r.sel_stats.groups_dropped,
            loss: stats.loss,
            clip_frac: stats.clip_frac,
            kl: stats.kl,
            micro_steps: r.micro_steps,
            rollouts_generated: r.rollouts_generated,
            rollouts_trained: r.rollouts_trained,
            sim_step_time: r.sim_step,
            sim_overlap_saved: r.sim_overlap_saved,
            schedule: self.cfg.hwsim.schedule.name().to_string(),
            gen_tokens_decoded: r.gen_tokens_decoded,
            gen_tokens_wasted: r.gen_tokens_wasted,
            upd_shards: r.upd_shards,
            upd_comm_time: r.upd_comm_time,
            upd_peak_mem: r.upd_peak_mem,
            gen_tokens_pruned: r.gen_tokens_pruned,
            rows_pruned_online: r.rows_pruned_online,
            replay_rows_used: r.replay_rows_used,
            replay_store_size: r.replay_store_size,
            replay_mean_staleness: r.replay_mean_staleness,
            prefill_calls: r.prefill_calls,
            prefill_calls_saved: r.prefill_calls_saved,
            kv_peak_bytes: r.kv_peak_bytes,
            faults_injected: r.faults_injected,
            shard_retries: r.shard_retries,
            rows_lost: r.rows_lost,
            retry_time: r.retry_time,
            budget_extra_rows: r.budget_extra_rows,
            budget_saturated_groups: r.budget_saturated_groups,
            fleet_replicas,
            fleet_staleness: r.fleet_staleness,
            fleet_mean_staleness,
            fleet_max_staleness,
            fleet_queue_depth: r.fleet_queue_depth,
            fleet_queue_block_time: 0.0,
            fleet_inf_util,
            fleet_upd_util,
        });
        Ok(stats)
    }

    /// Evaluate on a split of the training task and record the snapshot.
    pub fn evaluate(&mut self, iter: usize, split: Split, label: &str) -> Result<f32> {
        self.evaluate_task(iter, self.task, split, label)
    }

    /// Evaluate on an arbitrary (task, split) track — the Fig. 7 path.
    pub fn evaluate_task(
        &mut self,
        iter: usize,
        task: TaskKind,
        split: Split,
        label: &str,
    ) -> Result<f32> {
        let stats = eval::evaluate(
            &self.engine,
            self.full_params(),
            self.lora_vec(),
            task,
            split,
            self.cfg.run.eval_problems,
            &RewardWeights::default(),
            self.cfg.rollout.decode_chunk,
        )?;
        self.recorder.push_eval(EvalRow {
            iter,
            sim_time: self.clock.now(),
            real_time: self.started.elapsed().as_secs_f64(),
            split: label.to_string(),
            accuracy: stats.accuracy,
            format_rate: stats.format_rate,
            mean_reward: stats.mean_reward,
            mean_len: stats.mean_len,
            problems: stats.problems,
        });
        Ok(stats.accuracy)
    }

    /// Full run: SFT warm-up (if configured), KL snapshot, RL iterations
    /// with periodic eval (and, with `[ckpt] every > 0`, periodic
    /// crash-consistent resume snapshots), CSV dump, optional checkpoint.
    ///
    /// After [`Self::resume_from`] the warm-up, reference snapshot and
    /// initial eval are skipped — they are part of the restored state —
    /// and iterations continue from the checkpoint bit-identically to the
    /// uninterrupted run (`rust/tests/fault_golden.rs`).
    pub fn run(&mut self) -> Result<()> {
        self.run_span(self.cfg.run.iterations)?;
        if self.clock.overlap_saved() > 0.0 {
            eprintln!(
                "[train {}] schedule {}: sim {:.1}s total, {:.1}s hidden by overlap",
                self.cfg.run.name,
                self.cfg.hwsim.schedule.name(),
                self.clock.now(),
                self.clock.overlap_saved(),
            );
        }
        let out_dir = std::path::Path::new(&self.cfg.run.out_dir);
        self.recorder.write_csv(out_dir, &self.cfg.run.name)?;
        if let Some(path) = self.cfg.run.save_checkpoint.clone() {
            ckpt::save_store(
                std::path::Path::new(&path),
                &self.cfg.run.profile,
                &self.store,
                self.base.as_deref(),
            )?;
            eprintln!("[train {}] checkpoint -> {path}", self.cfg.run.name);
        }
        Ok(())
    }

    /// Run iterations `start_iter..upto` with periodic eval and resume
    /// snapshots. `upto < run.iterations` is the kill-at-k harness the
    /// resume goldens use: prefetch decisions still use the configured
    /// horizon, so stopping early leaves the same state a crash at that
    /// boundary would (an in-flight prefetch is simply dropped, exactly
    /// like a killed process's).
    pub fn run_span(&mut self, upto: usize) -> Result<()> {
        let iters = self.cfg.run.iterations;
        let eval_every = self.cfg.run.eval_every.max(1);
        if self.start_iter == 0 {
            self.sft_warmup()?;
            self.snapshot_reference();
            let acc0 = self.evaluate(0, Split::Test, "test")?;
            eprintln!("[train {}] start: test acc {acc0:.3}", self.cfg.run.name);
        }
        let resume_every = self.cfg.ckpt.every;
        for it in self.start_iter..upto {
            let stats = self.train_iteration(it)?;
            if (it + 1) % eval_every == 0 || it + 1 == iters {
                let acc = self.evaluate(it + 1, Split::Test, "test")?;
                let extra = self.extra_evals.clone();
                for (task, split, label) in extra {
                    self.evaluate_task(it + 1, task, split, &label)?;
                }
                eprintln!(
                    "[train {}] iter {:>4} sim {:>8.1}s acc {:.3} trainR {:.2} len {:.1} clip {:.3}",
                    self.cfg.run.name,
                    it + 1,
                    self.clock.now(),
                    acc,
                    stats.train_reward,
                    stats.completion_len,
                    stats.clip_frac,
                );
            }
            // snapshot AFTER the evals: the saved boundary means
            // "iterations 0..=it done, including their eval rows"
            if resume_every > 0 && (it + 1) % resume_every == 0 {
                let path = self.cfg.ckpt.resume_path(&self.cfg.run.out_dir, &self.cfg.run.name);
                resume::save(std::path::Path::new(&path), &self.resume_state(it + 1))?;
                eprintln!("[train {}] resume state -> {path}", self.cfg.run.name);
            }
        }
        Ok(())
    }

    /// Capture the complete resumable state at the iteration boundary
    /// "iterations `0..next_iter` complete (evals included)".
    pub fn resume_state(&self, next_iter: usize) -> resume::ResumeState {
        let ppi = self.cfg.run.prompts_per_iter as u64;
        resume::ResumeState {
            profile: self.cfg.run.profile.clone(),
            run_name: self.cfg.run.name.clone(),
            run_seed: self.cfg.run.seed,
            next_iter,
            // logical (pre-prefetch) cursor: restore re-applies the
            // prefetch advance when it rebuilds the in-flight batch
            prompt_cursor: next_iter as u64 * ppi,
            clock_now: self.clock.now(),
            clock_overlap_saved: self.clock.overlap_saved(),
            store: self.store.clone(),
            base: self.base.clone(),
            ref_params: self.ref_params.as_deref().cloned(),
            ref_lora: self.ref_lora.as_deref().cloned(),
            queued: self
                .exec
                .queued_info()
                .into_iter()
                .map(|(i, born, overlap, b)| resume::InflightGen {
                    iter: i,
                    born,
                    overlap,
                    params: (*b.params).clone(),
                    lora: b.lora.as_deref().cloned(),
                })
                .collect(),
            replay_rows: self.exec.replay_store().contents().to_vec(),
            iter_rows: self.recorder.iters.clone(),
            eval_rows: self.recorder.evals.clone(),
        }
    }

    /// Restore a run from a resume file written by a previous (possibly
    /// killed) process. The trainer must have been built from the same
    /// config; continuing via [`Self::run`] is then bit-identical to the
    /// run that was never interrupted.
    pub fn resume_from(&mut self, path: &std::path::Path) -> Result<()> {
        let st = resume::load(path)?;
        if st.profile != self.cfg.run.profile
            || st.run_name != self.cfg.run.name
            || st.run_seed != self.cfg.run.seed
        {
            return Err(anyhow!(
                "resume file {path:?} is for run {:?} (profile {:?}, seed {}), \
                 config says {:?} (profile {:?}, seed {})",
                st.run_name,
                st.profile,
                st.run_seed,
                self.cfg.run.name,
                self.cfg.run.profile,
                self.cfg.run.seed
            ));
        }
        if st.store.params.len() != self.store.params.len() {
            return Err(anyhow!(
                "resume file has {} trainable params, profile expects {}",
                st.store.params.len(),
                self.store.params.len()
            ));
        }
        self.store = st.store;
        self.base = st.base;
        self.ref_params = st.ref_params.map(std::sync::Arc::new);
        self.ref_lora = st.ref_lora.map(std::sync::Arc::new);
        self.clock = SimClock::restore(st.clock_now, st.clock_overlap_saved);
        self.exec.set_replay(ReplayStore::from_rows(st.replay_rows));
        self.recorder = Recorder { iters: st.iter_rows, evals: st.eval_rows };
        self.prompt_cursor = st.prompt_cursor;
        self.start_iter = st.next_iter;
        for inf in st.queued {
            // rebuild the killed run's ready-batch queue in order from the
            // saved behaviour snapshots — regeneration replays the
            // identical off-policy rollouts (per-row counter RNG) and the
            // saved overlap credit charges the identical hidden time
            let batch = build_gen_batch(
                &self.cfg,
                &self.engine,
                &self.pipeline,
                self.task,
                self.ref_params.clone(),
                self.ref_lora.clone(),
                std::sync::Arc::new(inf.params),
                inf.lora.map(std::sync::Arc::new),
                self.prompt_cursor,
                inf.iter,
            );
            self.prompt_cursor += self.cfg.run.prompts_per_iter as u64;
            let br = self.engine.meta.config.rollout_batch;
            self.exec.restore_queued(inf.iter, inf.born, inf.overlap, br, batch)?;
        }
        eprintln!(
            "[train {}] resumed from {path:?} at iteration {}",
            self.cfg.run.name, self.start_iter
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_cases;

    fn spec(n: usize, n_probe: usize, max_per_prompt: usize, width_threshold: f64) -> BudgetSpec {
        BudgetSpec { n, n_probe, max_per_prompt, width_threshold }
    }

    /// Saturated groups release their budget: a group whose probe rewards
    /// collapse to a point gets zero extras, and the released slots flow
    /// to the still-wide groups.
    #[test]
    fn saturated_groups_release_budget_to_wide_ones() {
        let mut a = BudgetAllocator::new(spec(8, 2, 64, 0.25), 3);
        // group 0: saturated (all probes identical); 1 and 2: wide
        for _ in 0..2 {
            a.observe(0, 1.0);
        }
        a.observe(1, 0.0);
        a.observe(1, 3.0);
        a.observe(2, 0.5);
        a.observe(2, 2.5);
        assert!(a.is_saturated(0));
        assert_eq!(a.saturated_groups(), 1);
        let seq = a.allocate();
        // all (8 - 2) * 3 = 18 slots go somewhere: nothing is wasted while
        // eligible groups have headroom
        assert_eq!(seq.len(), 18);
        assert!(seq.iter().all(|&(g, _)| g != 0), "saturated group received extras: {seq:?}");
        let count = |g: usize| seq.iter().filter(|&&(gg, _)| gg == g).count();
        // fewest-rollouts-first streaming shares the budget evenly
        assert_eq!(count(1), 9);
        assert_eq!(count(2), 9);
        // rollout indices continue the probe numbering per group
        assert_eq!(seq.iter().filter(|&&(g, _)| g == 1).map(|&(_, r)| r).min(), Some(2));
        assert_eq!(seq.iter().filter(|&&(g, _)| g == 1).map(|&(_, r)| r).max(), Some(10));
    }

    /// Disabled-equals-fixed-n at the allocator level: with `n_probe = n`
    /// there are zero slots to stream, whatever the observations say.
    #[test]
    fn probe_equal_to_n_allocates_nothing() {
        let mut a = BudgetAllocator::new(spec(8, 8, 64, 0.0), 4);
        for g in 0..4 {
            a.observe(g, 0.0);
            a.observe(g, 3.0);
        }
        assert!(a.allocate().is_empty());
    }

    /// An unobserved group (every probe row lost or pruned) has width 0:
    /// it can never justify extra decode spend.
    #[test]
    fn unobserved_groups_are_saturated() {
        let a = BudgetAllocator::new(spec(4, 2, 8, 0.0), 2);
        assert!(a.is_saturated(0), "width_threshold = 0 still saturates unobserved groups");
        assert_eq!(a.width(0), 0.0);
        assert!(a.allocate().is_empty());
    }

    /// Budget-conservation property over random draws: the allocation
    /// never exceeds `(n − n_probe) × |groups|` slots in total nor
    /// `max_per_prompt` rollouts per prompt, rollout indices are dense per
    /// group starting at `n_probe`, and the sequence is a pure function of
    /// the observations (replaying them — in any order — reproduces it).
    #[test]
    fn allocation_conserves_budget_and_is_history_pure() {
        for_cases(200, |rng| {
            let groups = 1 + rng.below(6);
            let n = 2 + rng.below(16);
            let n_probe = 1 + rng.below(n);
            let max_per_prompt = n_probe + rng.below(2 * n);
            let width_threshold = 0.25 * rng.below(8) as f64;
            let s = spec(n, n_probe, max_per_prompt, width_threshold);
            let mut a = BudgetAllocator::new(s, groups);
            let mut observations: Vec<(usize, f32)> = Vec::new();
            for g in 0..groups {
                for _ in 0..rng.below(n_probe + 1) {
                    let reward = 0.25 * rng.below(13) as f32;
                    observations.push((g, reward));
                }
            }
            for &(g, r) in &observations {
                a.observe(g, r);
            }
            let seq = a.allocate();
            assert!(seq.len() <= (n - n_probe) * groups, "total budget exceeded");
            for g in 0..groups {
                let mut rows: Vec<u32> =
                    seq.iter().filter(|&&(gg, _)| gg == g).map(|&(_, r)| r).collect();
                assert!(
                    n_probe + rows.len() <= max_per_prompt,
                    "per-prompt cap exceeded: group {g} got {} extras (n_probe {n_probe}, \
                     cap {max_per_prompt})",
                    rows.len()
                );
                if a.is_saturated(g) {
                    assert!(rows.is_empty(), "saturated group {g} received extras");
                }
                rows.sort_unstable();
                for (i, &r) in rows.iter().enumerate() {
                    assert_eq!(r as usize, n_probe + i, "group {g} rollout indices not dense");
                }
            }
            // history purity: replaying the observations in reverse order
            // lands on the identical allocation sequence
            let mut b = BudgetAllocator::new(s, groups);
            for &(g, r) in observations.iter().rev() {
                b.observe(g, r);
            }
            assert_eq!(seq, b.allocate(), "allocation depends on observation order");
        });
    }
}
