//! The training-step state machine — GRPO / GRPO-GA / GRPO-PODS schedules.
//!
//! One [`Trainer::train_iteration`] implements Algorithm 1 over a batch of
//! prompts:
//!
//! 1. **Inference phase** — generate `n` rollouts per prompt (sharded over
//!    the simulated workers), verify them with the rule-based reward model.
//! 2. **Select** — run the configured selector pipeline within each prompt
//!    group (`m = n` for the GRPO/GA baselines), normalize advantages
//!    (§A.3 mode), and record the per-iteration selection diagnostics.
//! 3. **Policy-update phase** — pack the selected rollouts into fixed-size
//!    micro-batches, run the `grad` artifact per micro-batch, accumulate
//!    (the GA engine), all-reduce (simulated), apply fused AdamW.
//!
//! The hwsim clock charges each phase per the calibrated cost model; the
//! recorder logs both simulated and real time so every figure can be
//! regenerated from the CSVs.

use crate::config::{AlgoKind, RunConfig};
use crate::coordinator::accum::GradAccumulator;
use crate::coordinator::group::{build_update_batch, PromptGroup};
use crate::coordinator::select::Pipeline;
use crate::eval;
use crate::hwsim::SimClock;
use crate::metrics::{EvalRow, IterRow, Recorder};
use crate::reward::RewardWeights;
use crate::rollout::{generate_group, GenRequest};
use crate::runtime::{params as ckpt, Engine, MicroBatch, ParamStore, TensorF, TensorI};
use crate::tasks::{Split, TaskKind};
use anyhow::{anyhow, Result};
use std::time::Instant;

/// Per-iteration summary returned by [`Trainer::train_iteration`].
#[derive(Debug, Clone, Copy, Default)]
pub struct IterStats {
    pub train_reward: f32,
    pub train_acc: f32,
    pub completion_len: f32,
    pub loss: f32,
    pub clip_frac: f32,
    pub kl: f32,
    pub micro_steps: usize,
    pub rollouts_generated: usize,
    pub rollouts_trained: usize,
    pub sim_inference: f64,
    pub sim_update: f64,
}

/// The leader: owns engine, parameters, clock, metrics and the RL loop.
pub struct Trainer {
    pub engine: Engine,
    pub cfg: RunConfig,
    /// Optimized vector (full params, or LoRA adapters in LoRA profiles).
    pub store: ParamStore,
    /// Frozen full-parameter base (LoRA profiles only).
    pub base: Option<Vec<f32>>,
    /// Reference-policy snapshot for the KL term (when kl_coef > 0).
    pub ref_params: Option<Vec<f32>>,
    pub ref_lora: Option<Vec<f32>>,
    pub clock: SimClock,
    pub recorder: Recorder,
    pub task: TaskKind,
    /// Additional evaluation tracks run at every eval point — (task, split,
    /// label). Used by the Fig. 7 generalization study (platinum /
    /// cross-task test sets).
    pub extra_evals: Vec<(TaskKind, Split, String)>,
    /// The rollout-selection pipeline built from `algo.rule`. Stochastic
    /// stages reseed per group from `(run_seed, iter, prompt_id)`, so no
    /// trainer-level RNG is involved in selection.
    pipeline: Pipeline,
    accum: GradAccumulator,
    prompt_cursor: u64,
    started: Instant,
}

impl Trainer {
    /// Build a trainer from a validated config. Loads the artifact profile,
    /// initializes (or loads) parameters, and snapshots the KL reference.
    pub fn new(artifacts_dir: &std::path::Path, cfg: RunConfig) -> Result<Self> {
        let engine = Engine::load(artifacts_dir, &cfg.run.profile)?;
        crate::tasks::tokenizer::verify_against_meta(&engine.meta.vocab)?;
        let task = cfg.task_kind();

        let (store, base) = if engine.meta.is_lora() {
            let ckpt_path = cfg.run.base_checkpoint.as_ref().ok_or_else(|| {
                anyhow!("LoRA profile {:?} requires run.base_checkpoint", cfg.run.profile)
            })?;
            let (_, base_store, _) = ckpt::load_store(std::path::Path::new(ckpt_path))?;
            if base_store.params.len() != engine.meta.param_count {
                return Err(anyhow!(
                    "base checkpoint has {} params, profile expects {}",
                    base_store.params.len(),
                    engine.meta.param_count
                ));
            }
            let lora0 = engine.init(cfg.run.seed as u32)?;
            (ParamStore::new(lora0), Some(base_store.params))
        } else if let Some(ckpt_path) = &cfg.run.base_checkpoint {
            // full-parameter RL warm-started from an SFT'd checkpoint
            let (_, mut base_store, _) = ckpt::load_store(std::path::Path::new(ckpt_path))?;
            if base_store.params.len() != engine.meta.param_count {
                return Err(anyhow!(
                    "checkpoint has {} params, profile expects {}",
                    base_store.params.len(),
                    engine.meta.param_count
                ));
            }
            // fresh optimizer state for the RL phase
            base_store.m.iter_mut().for_each(|x| *x = 0.0);
            base_store.v.iter_mut().for_each(|x| *x = 0.0);
            base_store.step = 0;
            (base_store, None)
        } else {
            let p0 = engine.init(cfg.run.seed as u32)?;
            (ParamStore::new(p0), None)
        };

        let accum = GradAccumulator::new(store.len());
        let pipeline = cfg.selector();
        Ok(Self {
            engine,
            cfg,
            store,
            base,
            ref_params: None,
            ref_lora: None,
            clock: SimClock::new(),
            recorder: Recorder::new(),
            task,
            extra_evals: Vec::new(),
            pipeline,
            accum,
            prompt_cursor: 0,
            started: Instant::now(),
        })
    }

    /// The full-parameter vector used for rollouts/eval (base in LoRA mode).
    fn full_params(&self) -> &[f32] {
        match &self.base {
            Some(b) => b,
            None => &self.store.params,
        }
    }

    /// The LoRA vector passed alongside (None in full-parameter mode).
    fn lora_vec(&self) -> Option<&[f32]> {
        if self.engine.meta.is_lora() {
            Some(&self.store.params)
        } else {
            None
        }
    }

    /// Snapshot the current policy as the KL reference (call after SFT /
    /// before RL). No-op if kl_coef == 0.
    pub fn snapshot_reference(&mut self) {
        if self.cfg.algo.kl_coef > 0.0 {
            self.ref_params = Some(self.full_params().to_vec());
            self.ref_lora = self.lora_vec().map(|l| l.to_vec());
        }
    }

    /// SFT warm-up: teacher-forced cross-entropy on gold responses — the
    /// stand-in for starting from an instruct-tuned checkpoint. Only valid
    /// in full-parameter profiles (the base is what gets pre-trained).
    pub fn sft_warmup(&mut self) -> Result<()> {
        let Some(sft) = self.cfg.sft.clone() else {
            return Ok(());
        };
        if sft.steps == 0 {
            return Ok(());
        }
        if self.engine.meta.is_lora() {
            return Err(anyhow!("SFT warm-up requires a full-parameter profile"));
        }
        let bu = self.engine.meta.config.update_batch;
        let t = self.engine.meta.config.seq_len;
        let p = self.engine.meta.config.prompt_len;
        let log_every = if sft.log_every == 0 { 50 } else { sft.log_every };
        let pool = if sft.pool == 0 { u64::MAX } else { sft.pool as u64 };
        for step in 0..sft.steps {
            // cycle a bounded problem pool: multiple epochs over the same
            // examples is what lets the small policy generalise
            let start = (step as u64 * bu as u64) % pool;
            let problems = self.task.batch(Split::Train, start, bu);
            let mut tokens = vec![crate::tasks::tokenizer::PAD; bu * t];
            let mut mask = vec![0.0f32; bu * t];
            let mut pads = vec![0i32; bu];
            for (b, pr) in problems.iter().enumerate() {
                let pad = p - pr.prompt.len();
                pads[b] = pad as i32;
                for (j, &tk) in pr.prompt.iter().enumerate() {
                    tokens[b * t + pad + j] = tk;
                }
                for (j, &tk) in pr.ideal_response.iter().take(t - p).enumerate() {
                    tokens[b * t + p + j] = tk;
                    mask[b * t + p + j] = 1.0;
                }
            }
            let tokens = TensorI::new(tokens, &[bu, t])?;
            let mask = TensorF::new(mask, &[bu, t])?;
            let loss = self
                .engine
                .sft_step(&mut self.store, &tokens, &pads, &mask, sft.lr as f32)?;
            if step % log_every == 0 || step + 1 == sft.steps {
                eprintln!("[sft] step {step}/{} loss {loss:.4}", sft.steps);
            }
        }
        self.prompt_cursor = 0; // RL re-walks the train split from the start
        Ok(())
    }

    /// One full Algorithm-1 iteration over `prompts_per_iter` prompts.
    pub fn train_iteration(&mut self, iter: usize) -> Result<IterStats> {
        let cfg = &self.cfg;
        let n = cfg.algo.n;
        let m = match cfg.algo_kind() {
            AlgoKind::GrpoPods => cfg.algo.m,
            _ => None,
        };
        let bu = self.engine.meta.config.update_batch;
        let g = self.engine.meta.gen_len;
        let t = self.engine.meta.config.seq_len;
        let weights = RewardWeights::default();

        // ---- Phase 1: inference ------------------------------------------
        let problems = self
            .task
            .batch(Split::Train, self.prompt_cursor, cfg.run.prompts_per_iter);
        self.prompt_cursor += cfg.run.prompts_per_iter as u64;

        let mut groups: Vec<PromptGroup> = Vec::with_capacity(problems.len());
        let mut total_gen_tokens = 0usize;
        for problem in &problems {
            let req = GenRequest {
                params: self.full_params(),
                lora: self.lora_vec(),
                ref_params: self.ref_params.as_deref(),
                ref_lora: self.ref_lora.as_deref(),
                n,
                temperature: cfg.algo.temperature as f32,
                run_seed: cfg.run.seed,
                iter: iter as u64,
                weights,
            };
            let (group, stats) = generate_group(&self.engine, &req, self.task, problem)?;
            total_gen_tokens += stats.total_gen_tokens;
            groups.push(group);
        }
        let rollouts_generated = groups.iter().map(|gr| gr.rollouts.len()).sum::<usize>();
        let avg_tokens = total_gen_tokens as f64 / rollouts_generated.max(1) as f64;
        let sim_inference = cfg.hwsim.inference_time(rollouts_generated, avg_tokens);

        // ---- Phase 2: select + advantages --------------------------------
        let (selected, sel_stats) = build_update_batch(
            &groups,
            &self.pipeline,
            m,
            cfg.norm_mode(),
            cfg.run.seed,
            iter as u64,
        )?;
        let rollouts_trained = selected.len();
        let sel_rewards: Vec<f32> = selected
            .iter()
            .map(|s| groups[s.group_idx].rollouts[s.rollout_idx].total_reward)
            .collect();
        let sel_idx: Vec<usize> = (0..sel_rewards.len()).collect();
        let sel_variance =
            crate::coordinator::downsample::subset_variance(&sel_rewards, &sel_idx);

        // ---- Phase 3: micro-batched update (the GA engine) ---------------
        self.accum.reset();
        let mut loss_sum = 0f64;
        let mut clip_sum = 0f64;
        let mut kl_sum = 0f64;
        for chunk in selected.chunks(bu) {
            let mut tokens = vec![crate::tasks::tokenizer::PAD; bu * t];
            let mut pads = vec![0i32; bu];
            let mut gen_mask = vec![0.0f32; bu * g];
            let mut old_lp = vec![0.0f32; bu * g];
            let mut ref_lp = vec![0.0f32; bu * g];
            let mut adv = vec![0.0f32; bu];
            for (b, sel) in chunk.iter().enumerate() {
                let r = &groups[sel.group_idx].rollouts[sel.rollout_idx];
                tokens[b * t..(b + 1) * t].copy_from_slice(&r.tokens);
                pads[b] = r.pad_len;
                gen_mask[b * g..(b + 1) * g].copy_from_slice(&r.gen_mask);
                old_lp[b * g..(b + 1) * g].copy_from_slice(&r.old_lp);
                ref_lp[b * g..(b + 1) * g].copy_from_slice(&r.ref_lp);
                adv[b] = sel.advantage;
            }
            let mb = MicroBatch {
                tokens: TensorI::new(tokens, &[bu, t])?,
                pad_len: pads,
                gen_mask: TensorF::new(gen_mask, &[bu, g])?,
                old_lp: TensorF::new(old_lp, &[bu, g])?,
                adv,
                ref_lp: TensorF::new(ref_lp, &[bu, g])?,
            };
            let out = self
                .engine
                .grad(&self.store.params, self.base.as_deref(), &mb, cfg.algo.kl_coef as f32)?;
            self.accum.add(&out.grads, bu as f64);
            loss_sum += out.loss as f64 * chunk.len() as f64;
            clip_sum += out.clip_frac as f64 * chunk.len() as f64;
            kl_sum += out.kl as f64 * chunk.len() as f64;
        }
        let micro_steps = self.accum.micro_steps();
        // an iteration whose selection dropped every group (all groups
        // zero-signal) performs no update and must not be charged for one
        let sim_update = if rollouts_trained > 0 {
            cfg.hwsim.update_time(rollouts_trained, self.engine.meta.is_lora())
        } else {
            0.0
        };

        if rollouts_trained > 0 {
            let grads = self.accum.mean(rollouts_trained);
            self.engine.update(&mut self.store, &grads, cfg.algo.lr as f32)?;
        }

        self.clock.advance(sim_inference + sim_update);

        let stats = IterStats {
            train_reward: groups.iter().map(|gr| gr.mean_reward()).sum::<f32>()
                / groups.len().max(1) as f32,
            train_acc: groups.iter().map(|gr| gr.mean_accuracy()).sum::<f32>()
                / groups.len().max(1) as f32,
            completion_len: groups.iter().map(|gr| gr.mean_gen_len()).sum::<f32>()
                / groups.len().max(1) as f32,
            loss: (loss_sum / rollouts_trained.max(1) as f64) as f32,
            clip_frac: (clip_sum / rollouts_trained.max(1) as f64) as f32,
            kl: (kl_sum / rollouts_trained.max(1) as f64) as f32,
            micro_steps,
            rollouts_generated,
            rollouts_trained,
            sim_inference,
            sim_update,
        };
        self.recorder.push_iter(IterRow {
            iter,
            sim_time: self.clock.now(),
            real_time: self.started.elapsed().as_secs_f64(),
            sim_inference_time: sim_inference,
            sim_update_time: sim_update,
            train_reward: stats.train_reward,
            train_acc: stats.train_acc,
            completion_len: stats.completion_len,
            sel_variance,
            sel_tokens_kept: sel_stats.tokens_kept,
            sel_tokens_dropped: sel_stats.tokens_dropped,
            sel_groups_dropped: sel_stats.groups_dropped,
            loss: stats.loss,
            clip_frac: stats.clip_frac,
            kl: stats.kl,
            micro_steps,
            rollouts_generated,
            rollouts_trained,
        });
        Ok(stats)
    }

    /// Evaluate on a split of the training task and record the snapshot.
    pub fn evaluate(&mut self, iter: usize, split: Split, label: &str) -> Result<f32> {
        self.evaluate_task(iter, self.task, split, label)
    }

    /// Evaluate on an arbitrary (task, split) track — the Fig. 7 path.
    pub fn evaluate_task(
        &mut self,
        iter: usize,
        task: TaskKind,
        split: Split,
        label: &str,
    ) -> Result<f32> {
        let stats = eval::evaluate(
            &self.engine,
            self.full_params(),
            self.lora_vec(),
            task,
            split,
            self.cfg.run.eval_problems,
            &RewardWeights::default(),
        )?;
        self.recorder.push_eval(EvalRow {
            iter,
            sim_time: self.clock.now(),
            real_time: self.started.elapsed().as_secs_f64(),
            split: label.to_string(),
            accuracy: stats.accuracy,
            format_rate: stats.format_rate,
            mean_reward: stats.mean_reward,
            mean_len: stats.mean_len,
            problems: stats.problems,
        });
        Ok(stats.accuracy)
    }

    /// Full run: SFT warm-up (if configured), KL snapshot, RL iterations
    /// with periodic eval, CSV dump, optional checkpoint.
    pub fn run(&mut self) -> Result<()> {
        self.sft_warmup()?;
        self.snapshot_reference();
        let iters = self.cfg.run.iterations;
        let eval_every = self.cfg.run.eval_every.max(1);
        let acc0 = self.evaluate(0, Split::Test, "test")?;
        eprintln!(
            "[train {}] start: test acc {acc0:.3}",
            self.cfg.run.name
        );
        for it in 0..iters {
            let stats = self.train_iteration(it)?;
            if (it + 1) % eval_every == 0 || it + 1 == iters {
                let acc = self.evaluate(it + 1, Split::Test, "test")?;
                let extra = self.extra_evals.clone();
                for (task, split, label) in extra {
                    self.evaluate_task(it + 1, task, split, &label)?;
                }
                eprintln!(
                    "[train {}] iter {:>4} sim {:>8.1}s acc {:.3} trainR {:.2} len {:.1} clip {:.3}",
                    self.cfg.run.name,
                    it + 1,
                    self.clock.now(),
                    acc,
                    stats.train_reward,
                    stats.completion_len,
                    stats.clip_frac,
                );
            }
        }
        let out_dir = std::path::Path::new(&self.cfg.run.out_dir);
        self.recorder.write_csv(out_dir, &self.cfg.run.name)?;
        if let Some(path) = self.cfg.run.save_checkpoint.clone() {
            ckpt::save_store(
                std::path::Path::new(&path),
                &self.cfg.run.profile,
                &self.store,
                self.base.as_deref(),
            )?;
            eprintln!("[train {}] checkpoint -> {path}", self.cfg.run.name);
        }
        Ok(())
    }
}
