//! Real multi-threaded rollout generation.
//!
//! The hwsim clock always *simulated* `hwsim.workers` parallel devices,
//! but the seed trainer generated groups prompt-by-prompt on one thread —
//! the worker parallelism existed only on paper. [`RolloutEngine`] makes
//! it real: an iteration's rollout calls (planned by
//! [`crate::rollout::plan_calls`], which also packs partial batches across
//! prompt groups) are fanned over a pool of OS threads via a shared work
//! queue, so generation saturates however many cores the host has.
//!
//! The PJRT [`Engine`] is not `Send`/`Sync` (single-threaded client,
//! `Rc`-cached executables), so the pool cannot share the trainer's
//! engine. Instead **each worker thread lazily loads its own engine
//! replica** of the same artifact profile — the replica compiles the
//! rollout program once on first use and is reused for the rest of the
//! run. Inputs cross the thread boundary as [`GenBatch`] snapshots
//! (`Arc`-shared parameter vectors + problems), which is exactly the
//! snapshot semantics the pipelined schedule needs anyway: generation of
//! iteration *t+1* runs against the pre-update policy while the main
//! thread updates.
//!
//! Determinism: every call carries its own seed from the plan, and
//! results are reassembled in plan order regardless of which worker
//! finished first — `workers = 16` produces bit-identical rollouts to
//! `workers = 1`.

use crate::coordinator::group::PromptGroup;
use crate::reward::RewardWeights;
use crate::rollout::{execute_call, plan_calls, CallRollout, InferenceStats, PlannedCall};
use crate::runtime::Engine;
use crate::tasks::{Problem, TaskKind};
use anyhow::{anyhow, bail, Context, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Everything one iteration's generation needs, snapshotted so worker
/// threads (and the pipelined schedule) can run it independently of the
/// trainer's live parameter store.
#[derive(Debug, Clone)]
pub struct GenBatch {
    /// Full-parameter vector rollouts decode with (the frozen base in
    /// LoRA profiles).
    pub params: Arc<Vec<f32>>,
    /// Trainable adapter vector (LoRA profiles only).
    pub lora: Option<Arc<Vec<f32>>>,
    /// Reference-policy parameters for the KL term (when kl_coef > 0).
    pub ref_params: Option<Arc<Vec<f32>>>,
    pub ref_lora: Option<Arc<Vec<f32>>>,
    /// The iteration's prompt batch, one group per problem.
    pub problems: Arc<Vec<Problem>>,
    /// Rollouts per prompt (the paper's `n`).
    pub n: usize,
    pub temperature: f32,
    pub run_seed: u64,
    pub iter: u64,
    pub task: TaskKind,
    pub weights: RewardWeights,
}

/// One queued rollout call for a worker thread.
struct Job {
    batch_id: u64,
    call_idx: usize,
    call: PlannedCall,
    batch: Arc<GenBatch>,
}

type CallOut = (Vec<CallRollout>, usize);
type CallResult = (u64, usize, Result<CallOut>);

struct Pool {
    job_tx: mpsc::Sender<Job>,
    result_rx: mpsc::Receiver<CallResult>,
    handles: Vec<JoinHandle<()>>,
}

/// Handle to an in-flight generation batch (pipelined prefetch). Redeem
/// with [`RolloutEngine::collect`].
pub struct PendingGen {
    batch_id: u64,
    plan: Vec<PlannedCall>,
    batch: Arc<GenBatch>,
}

/// A pool of rollout worker threads, each owning an engine replica.
///
/// With `workers <= 1`, [`Self::generate`] runs inline on the trainer's
/// engine (no replica, no thread hop) — byte-identical to the sequential
/// path and free of the second compile. [`Self::submit`] always uses the
/// pool: a dedicated thread is what lets generation overlap the
/// main-thread update even with one simulated worker.
pub struct RolloutEngine {
    artifacts: PathBuf,
    profile: String,
    pub workers: usize,
    pool: Option<Pool>,
    next_batch_id: u64,
    in_flight: bool,
}

impl RolloutEngine {
    pub fn new(artifacts: PathBuf, profile: impl Into<String>, workers: usize) -> Self {
        Self {
            artifacts,
            profile: profile.into(),
            workers,
            pool: None,
            next_batch_id: 0,
            in_flight: false,
        }
    }

    /// Spawn the worker threads on first use (engine replicas load lazily
    /// inside each thread, on its first job). The real thread count is
    /// capped at the host's parallelism — simulating 8 accelerators on a
    /// 4-core laptop must not oversubscribe it with 8 engine replicas;
    /// results are bit-identical for any pool size.
    fn ensure_pool(&mut self) -> Result<&Pool> {
        if self.pool.is_none() {
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            let threads = self.workers.clamp(1, cores.max(1));
            let (job_tx, job_rx) = mpsc::channel::<Job>();
            let job_rx = Arc::new(Mutex::new(job_rx));
            let (res_tx, result_rx) = mpsc::channel::<CallResult>();
            let mut handles = Vec::with_capacity(threads);
            for w in 0..threads {
                let rx = Arc::clone(&job_rx);
                let tx = res_tx.clone();
                let artifacts = self.artifacts.clone();
                let profile = self.profile.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("rollout-worker-{w}"))
                    .spawn(move || worker_main(artifacts, profile, rx, tx))
                    .with_context(|| format!("spawning rollout worker {w}"))?;
                handles.push(handle);
            }
            self.pool = Some(Pool { job_tx, result_rx, handles });
        }
        Ok(self.pool.as_ref().expect("just ensured"))
    }

    /// Generate every group of `batch` synchronously and return them in
    /// prompt order with the aggregated inference stats.
    pub fn generate(
        &mut self,
        engine: &Engine,
        batch: GenBatch,
    ) -> Result<(Vec<PromptGroup>, InferenceStats)> {
        let br = engine.meta.config.rollout_batch;
        let plan = plan_calls(&batch.problems, batch.n, br, batch.run_seed, batch.iter);
        if self.workers <= 1 {
            let mut outs = Vec::with_capacity(plan.len());
            for call in &plan {
                outs.push(run_call(engine, &batch, call)?);
            }
            return Ok(assemble(&batch, &plan, outs));
        }
        let pending = self.submit_plan(plan, Arc::new(batch))?;
        self.collect(pending)
    }

    /// Start generating `batch` on the pool and return immediately — the
    /// pipelined schedule's prefetch. `br` is the profile's rollout batch
    /// size (`engine.meta.config.rollout_batch`). At most one batch may be
    /// in flight.
    pub fn submit(&mut self, br: usize, batch: GenBatch) -> Result<PendingGen> {
        let plan = plan_calls(&batch.problems, batch.n, br, batch.run_seed, batch.iter);
        self.submit_plan(plan, Arc::new(batch))
    }

    fn submit_plan(&mut self, plan: Vec<PlannedCall>, batch: Arc<GenBatch>) -> Result<PendingGen> {
        if self.in_flight {
            bail!("a rollout generation batch is already in flight");
        }
        let batch_id = self.next_batch_id;
        self.next_batch_id += 1;
        let pool = self.ensure_pool()?;
        for (call_idx, call) in plan.iter().enumerate() {
            pool.job_tx
                .send(Job { batch_id, call_idx, call: call.clone(), batch: Arc::clone(&batch) })
                .map_err(|_| anyhow!("rollout worker threads exited; pool is gone"))?;
        }
        self.in_flight = true;
        Ok(PendingGen { batch_id, plan, batch })
    }

    /// Block until every call of `pending` finished and assemble the
    /// groups in plan order (independent of worker completion order).
    pub fn collect(&mut self, pending: PendingGen) -> Result<(Vec<PromptGroup>, InferenceStats)> {
        // collect() consumes the in-flight batch whatever happens next —
        // a broken pool must surface its own error on later submits, not
        // a misleading "already in flight".
        self.in_flight = false;
        let pool = self
            .pool
            .as_ref()
            .ok_or_else(|| anyhow!("collect without a running pool"))?;
        let mut slots: Vec<Option<Result<CallOut>>> =
            (0..pending.plan.len()).map(|_| None).collect();
        let mut got = 0;
        while got < pending.plan.len() {
            let (bid, idx, res) = pool
                .result_rx
                .recv()
                .map_err(|_| anyhow!("rollout workers hung up mid-batch"))?;
            if bid != pending.batch_id {
                continue; // stragglers of a discarded batch
            }
            slots[idx] = Some(res);
            got += 1;
        }
        let mut outs = Vec::with_capacity(slots.len());
        for s in slots {
            outs.push(s.expect("all slots filled")?);
        }
        Ok(assemble(&pending.batch, &pending.plan, outs))
    }
}

impl Drop for RolloutEngine {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            drop(pool.job_tx); // workers exit when the job channel closes
            drop(pool.result_rx);
            for h in pool.handles {
                let _ = h.join();
            }
        }
    }
}

/// Execute one planned call against an engine (worker replica or the
/// trainer's own engine on the inline path).
fn run_call(engine: &Engine, batch: &GenBatch, call: &PlannedCall) -> Result<CallOut> {
    execute_call(
        engine,
        &batch.params,
        batch.lora.as_deref().map(|v| v.as_slice()),
        batch.ref_params.as_deref().map(|v| v.as_slice()),
        batch.ref_lora.as_deref().map(|v| v.as_slice()),
        batch.temperature,
        call,
        &batch.problems,
        batch.task,
        &batch.weights,
    )
}

/// Reassemble per-call outputs (plan order) into per-prompt groups. Each
/// group's rollout order matches the sequential path: full calls first,
/// remainder rows after.
fn assemble(
    batch: &GenBatch,
    plan: &[PlannedCall],
    outs: Vec<CallOut>,
) -> (Vec<PromptGroup>, InferenceStats) {
    debug_assert_eq!(plan.len(), outs.len());
    let mut groups: Vec<PromptGroup> = batch
        .problems
        .iter()
        .map(|p| PromptGroup { problem: p.clone(), rollouts: Vec::with_capacity(batch.n) })
        .collect();
    let mut stats = InferenceStats::default();
    for (kept, gen_tokens) in outs {
        stats.calls += 1;
        stats.total_gen_tokens += gen_tokens;
        for cr in kept {
            groups[cr.group_idx].rollouts.push(cr.record);
        }
    }
    stats.rollouts = groups.iter().map(|g| g.rollouts.len()).sum();
    (groups, stats)
}

/// Worker thread body: pull calls off the shared queue until the channel
/// closes. The engine replica is loaded on the first job so idle pools
/// (e.g. sync schedule with one worker) never pay a compile.
fn worker_main(
    artifacts: PathBuf,
    profile: String,
    jobs: Arc<Mutex<mpsc::Receiver<Job>>>,
    results: mpsc::Sender<CallResult>,
) {
    let mut engine: Option<Engine> = None;
    loop {
        // Holding the lock only while blocked in recv: exactly one idle
        // worker waits inside recv at a time; the others queue on the
        // mutex and all of them *process* jobs concurrently.
        let job = match jobs.lock() {
            Ok(rx) => rx.recv(),
            Err(_) => return, // poisoned: a sibling panicked
        };
        let Ok(job) = job else { return }; // channel closed: shutdown
        if engine.is_none() {
            match Engine::load(&artifacts, &profile) {
                Ok(mut e) => {
                    e.quiet = true;
                    engine = Some(e);
                }
                Err(e) => {
                    let msg = anyhow!("rollout worker failed to load engine replica: {e}");
                    let _ = results.send((job.batch_id, job.call_idx, Err(msg)));
                    continue;
                }
            }
        }
        // A panicking call must still produce a CallResult — otherwise
        // collect() would wait forever for the missing slot. The replica
        // is discarded after a panic (its internal state is suspect).
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_call(engine.as_ref().expect("loaded above"), &job.batch, &job.call)
        }));
        let res = match caught {
            Ok(r) => r,
            Err(panic) => {
                engine = None;
                let what = panic
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                Err(anyhow!("rollout worker panicked executing call: {what}"))
            }
        };
        if results.send((job.batch_id, job.call_idx, res)).is_err() {
            return; // receiver gone: engine shut down
        }
    }
}
