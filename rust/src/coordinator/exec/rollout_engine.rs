//! Real multi-threaded rollout generation over the chunked decode driver.
//!
//! An iteration's generation is planned as a refill queue of rows
//! ([`crate::rollout::plan_rows`] — one row per rollout, each with a
//! private RNG seed) and fanned over a pool of OS threads as contiguous
//! **row shards**: every worker runs its own slot-based continuous
//! batcher ([`crate::rollout::decode_rows`]) over its shard — retiring
//! rows at EOS, admitting queued rows into freed slots, exiting early
//! when its shard drains.
//!
//! The PJRT [`Engine`] is not `Send`/`Sync` (single-threaded client,
//! `Rc`-cached executables), so the pool cannot share the trainer's
//! engine. Instead **each worker thread lazily loads its own engine
//! replica** of the same artifact profile — the replica compiles the
//! decode programs once on first use and is reused for the rest of the
//! run. Inputs cross the thread boundary as [`GenBatch`] snapshots
//! (`Arc`-shared parameter vectors + problems), which is exactly the
//! snapshot semantics the pipelined schedule needs anyway: generation of
//! iteration *t+1* runs against the pre-update policy while the main
//! thread updates.
//!
//! Determinism: every row's token stream is a counter-based function of
//! its own seed, so sharding — like chunking and refill order — cannot
//! change what any rollout samples. `workers = 16` produces bit-identical
//! rollouts to `workers = 1`; only the call-count/decoded-token telemetry
//! (how the physical work was batched) varies with the partition.

use crate::coordinator::group::PromptGroup;
use crate::coordinator::select::online::GroupVerdicts;
use crate::reward::RewardWeights;
use crate::rollout::{
    execute_rows, plan_rows, CallRollout, InferenceStats, KvPolicy, RefillMode, RowSpec,
};
use crate::runtime::Engine;
use crate::tasks::{Problem, TaskKind};
use anyhow::{anyhow, bail, Context, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Everything one iteration's generation needs, snapshotted so worker
/// threads (and the pipelined schedule) can run it independently of the
/// trainer's live parameter store.
#[derive(Debug, Clone)]
pub struct GenBatch {
    /// Full-parameter vector rollouts decode with (the frozen base in
    /// LoRA profiles).
    pub params: Arc<Vec<f32>>,
    /// Trainable adapter vector (LoRA profiles only).
    pub lora: Option<Arc<Vec<f32>>>,
    /// Reference-policy parameters for the KL term (when kl_coef > 0).
    pub ref_params: Option<Arc<Vec<f32>>>,
    /// Reference-policy adapter vector (LoRA profiles with KL).
    pub ref_lora: Option<Arc<Vec<f32>>>,
    /// The iteration's prompt batch, one group per problem.
    pub problems: Arc<Vec<Problem>>,
    /// Rollouts per prompt (the paper's `n`).
    pub n: usize,
    /// Sampling temperature.
    pub temperature: f32,
    /// Run seed — one axis of every row's private stream seed.
    pub run_seed: u64,
    /// Training iteration this generation belongs to.
    pub iter: u64,
    /// Task family verifying the generated answers.
    pub task: TaskKind,
    /// Reward component weights.
    pub weights: RewardWeights,
    /// Tokens decoded per `decode_chunk` call (`[rollout] decode_chunk`).
    pub decode_chunk: usize,
    /// Slot-refill policy (`[rollout] refill`).
    pub refill: RefillMode,
    /// Shared per-group online-pruning verdict state for this batch
    /// (`[rollout] online_prune`). One aggregator serves every worker
    /// shard — a group's rows can span shards, and all of them observe
    /// and poll the same state. `None` disables pruning.
    pub online: Option<Arc<GroupVerdicts>>,
    /// KV accounting policy (`[rollout] share_prompt_kv` plus the hwsim
    /// paged-pool model). Each worker shard runs its own pool ledger;
    /// `KvPolicy::default()` is the legacy per-row-prefill path.
    pub kv: KvPolicy,
}

/// One queued shard of generation rows for a worker thread.
struct Job {
    batch_id: u64,
    shard_idx: usize,
    rows: Vec<RowSpec>,
    batch: Arc<GenBatch>,
}

type ShardOut = (Vec<CallRollout>, InferenceStats);
type ShardResult = (u64, usize, Result<ShardOut>);

struct Pool {
    job_tx: mpsc::Sender<Job>,
    result_rx: mpsc::Receiver<ShardResult>,
    handles: Vec<JoinHandle<()>>,
}

/// Handle to an in-flight generation batch (pipelined prefetch). Redeem
/// with [`RolloutEngine::collect`].
pub struct PendingGen {
    batch_id: u64,
    shards: usize,
    batch: Arc<GenBatch>,
}

/// A pool of rollout worker threads, each owning an engine replica.
///
/// With `workers <= 1`, [`Self::generate`] runs inline on the trainer's
/// engine (no replica, no thread hop) with a single refill queue — the
/// maximum continuous-batching benefit. [`Self::submit`] always uses the
/// pool: a dedicated thread is what lets generation overlap the
/// main-thread update even with one simulated worker.
pub struct RolloutEngine {
    artifacts: PathBuf,
    profile: String,
    /// Configured pool size (`hwsim.workers`); the real thread count is
    /// capped at host parallelism.
    pub workers: usize,
    pool: Option<Pool>,
    next_batch_id: u64,
    in_flight: bool,
}

/// Split the row queue into contiguous, size-balanced shards: at most
/// one per worker, but never more than `ceil(rows / B_r)` — a shard
/// smaller than the rollout batch decodes mostly filler slots, so spare
/// workers are better left idle than fed under-full batches. Empty
/// shards are never produced.
fn shard_rows(rows: &[RowSpec], workers: usize, br: usize) -> Vec<Vec<RowSpec>> {
    let full_batches = rows.len().div_ceil(br.max(1));
    let shards = workers.min(full_batches).clamp(1, rows.len().max(1));
    let base = rows.len() / shards;
    let extra = rows.len() % shards;
    let mut out = Vec::with_capacity(shards);
    let mut off = 0;
    for s in 0..shards {
        let len = base + usize::from(s < extra);
        if len == 0 {
            continue;
        }
        out.push(rows[off..off + len].to_vec());
        off += len;
    }
    out
}

impl RolloutEngine {
    /// An engine over `profile`'s artifacts with a pool of `workers`
    /// threads (spawned lazily on first use).
    pub fn new(artifacts: PathBuf, profile: impl Into<String>, workers: usize) -> Self {
        Self {
            artifacts,
            profile: profile.into(),
            workers,
            pool: None,
            next_batch_id: 0,
            in_flight: false,
        }
    }

    /// Spawn the worker threads on first use (engine replicas load lazily
    /// inside each thread, on its first job). The real thread count is
    /// capped at the host's parallelism — simulating 8 accelerators on a
    /// 4-core laptop must not oversubscribe it with 8 engine replicas;
    /// results are bit-identical for any pool size.
    fn ensure_pool(&mut self) -> Result<&Pool> {
        if self.pool.is_none() {
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            let threads = self.workers.clamp(1, cores.max(1));
            let (job_tx, job_rx) = mpsc::channel::<Job>();
            let job_rx = Arc::new(Mutex::new(job_rx));
            let (res_tx, result_rx) = mpsc::channel::<ShardResult>();
            let mut handles = Vec::with_capacity(threads);
            for w in 0..threads {
                let rx = Arc::clone(&job_rx);
                let tx = res_tx.clone();
                let artifacts = self.artifacts.clone();
                let profile = self.profile.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("rollout-worker-{w}"))
                    .spawn(move || worker_main(artifacts, profile, rx, tx))
                    .with_context(|| format!("spawning rollout worker {w}"))?;
                handles.push(handle);
            }
            self.pool = Some(Pool { job_tx, result_rx, handles });
        }
        Ok(self.pool.as_ref().expect("just ensured"))
    }

    /// Generate every group of `batch` synchronously and return them in
    /// prompt order with the aggregated inference stats.
    pub fn generate(
        &mut self,
        engine: &Engine,
        batch: GenBatch,
    ) -> Result<(Vec<PromptGroup>, InferenceStats)> {
        let rows = plan_rows(&batch.problems, batch.n, batch.run_seed, batch.iter);
        if self.workers <= 1 {
            // inline: one continuous queue over all rows — no replica, no
            // thread hop, maximal refill packing
            let out = run_shard(engine, &batch, &rows)?;
            return Ok(assemble(&batch, vec![out]));
        }
        let br = engine.meta.config.rollout_batch;
        let pending = self.submit_rows(rows, Arc::new(batch), br)?;
        self.collect(pending)
    }

    /// Start generating `batch` on the pool and return immediately — the
    /// pipelined schedule's prefetch. `br` is the profile's rollout batch
    /// size (`engine.meta.config.rollout_batch`), which bounds how finely
    /// the rows are sharded. At most one batch may be in flight.
    pub fn submit(&mut self, br: usize, batch: GenBatch) -> Result<PendingGen> {
        let rows = plan_rows(&batch.problems, batch.n, batch.run_seed, batch.iter);
        self.submit_rows(rows, Arc::new(batch), br)
    }

    fn submit_rows(
        &mut self,
        rows: Vec<RowSpec>,
        batch: Arc<GenBatch>,
        br: usize,
    ) -> Result<PendingGen> {
        if self.in_flight {
            bail!("a rollout generation batch is already in flight");
        }
        let batch_id = self.next_batch_id;
        self.next_batch_id += 1;
        let shards = shard_rows(&rows, self.workers.max(1), br);
        let n_shards = shards.len();
        let pool = self.ensure_pool()?;
        for (shard_idx, rows) in shards.into_iter().enumerate() {
            pool.job_tx
                .send(Job { batch_id, shard_idx, rows, batch: Arc::clone(&batch) })
                .map_err(|_| anyhow!("rollout worker threads exited; pool is gone"))?;
        }
        self.in_flight = true;
        Ok(PendingGen { batch_id, shards: n_shards, batch })
    }

    /// Block until every shard of `pending` finished and assemble the
    /// groups in plan order (independent of worker completion order).
    pub fn collect(&mut self, pending: PendingGen) -> Result<(Vec<PromptGroup>, InferenceStats)> {
        // collect() consumes the in-flight batch whatever happens next —
        // a broken pool must surface its own error on later submits, not
        // a misleading "already in flight".
        self.in_flight = false;
        let pool = self
            .pool
            .as_ref()
            .ok_or_else(|| anyhow!("collect without a running pool"))?;
        let mut slots: Vec<Option<Result<ShardOut>>> =
            (0..pending.shards).map(|_| None).collect();
        let mut got = 0;
        while got < pending.shards {
            let (bid, idx, res) = pool
                .result_rx
                .recv()
                .map_err(|_| anyhow!("rollout workers hung up mid-batch"))?;
            if bid != pending.batch_id {
                continue; // stragglers of a discarded batch
            }
            slots[idx] = Some(res);
            got += 1;
        }
        let mut outs = Vec::with_capacity(slots.len());
        for s in slots {
            outs.push(s.expect("all slots filled")?);
        }
        Ok(assemble(&pending.batch, outs))
    }
}

impl Drop for RolloutEngine {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            drop(pool.job_tx); // workers exit when the job channel closes
            drop(pool.result_rx);
            for h in pool.handles {
                let _ = h.join();
            }
        }
    }
}

/// Execute one row shard against an engine (worker replica or the
/// trainer's own engine on the inline path).
fn run_shard(engine: &Engine, batch: &GenBatch, rows: &[RowSpec]) -> Result<ShardOut> {
    execute_rows(
        engine,
        &batch.params,
        batch.lora.as_deref().map(|v| v.as_slice()),
        batch.ref_params.as_deref().map(|v| v.as_slice()),
        batch.ref_lora.as_deref().map(|v| v.as_slice()),
        batch.temperature,
        batch.decode_chunk,
        batch.refill,
        rows,
        &batch.problems,
        batch.task,
        &batch.weights,
        batch.online.as_deref(),
        batch.kv,
    )
}

/// Reassemble per-shard outputs (shard order) into per-prompt groups.
/// Shards are contiguous cuts of the group-major row queue, so appending
/// in shard order preserves each group's rollout order.
fn assemble(batch: &GenBatch, outs: Vec<ShardOut>) -> (Vec<PromptGroup>, InferenceStats) {
    let mut groups: Vec<PromptGroup> = batch
        .problems
        .iter()
        .map(|p| PromptGroup { problem: p.clone(), rollouts: Vec::with_capacity(batch.n) })
        .collect();
    let mut stats = InferenceStats::default();
    for (kept, shard_stats) in outs {
        stats.absorb(&shard_stats);
        for cr in kept {
            groups[cr.group_idx].rollouts.push(cr.record);
        }
    }
    stats.rollouts = groups.iter().map(|g| g.rollouts.len()).sum();
    (groups, stats)
}

/// Worker thread body: pull shards off the shared queue until the channel
/// closes. The engine replica is loaded on the first job so idle pools
/// (e.g. sync schedule with one worker) never pay a compile.
fn worker_main(
    artifacts: PathBuf,
    profile: String,
    jobs: Arc<Mutex<mpsc::Receiver<Job>>>,
    results: mpsc::Sender<ShardResult>,
) {
    let mut engine: Option<Engine> = None;
    loop {
        // Holding the lock only while blocked in recv: exactly one idle
        // worker waits inside recv at a time; the others queue on the
        // mutex and all of them *process* jobs concurrently.
        let job = match jobs.lock() {
            Ok(rx) => rx.recv(),
            Err(_) => return, // poisoned: a sibling panicked
        };
        let Ok(job) = job else { return }; // channel closed: shutdown
        if engine.is_none() {
            match Engine::load(&artifacts, &profile) {
                Ok(mut e) => {
                    e.quiet = true;
                    engine = Some(e);
                }
                Err(e) => {
                    let msg = anyhow!("rollout worker failed to load engine replica: {e}");
                    let _ = results.send((job.batch_id, job.shard_idx, Err(msg)));
                    continue;
                }
            }
        }
        // A panicking shard must still produce a ShardResult — otherwise
        // collect() would wait forever for the missing slot. The replica
        // is discarded after a panic (its internal state is suspect).
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_shard(engine.as_ref().expect("loaded above"), &job.batch, &job.rows)
        }));
        let res = match caught {
            Ok(r) => r,
            Err(panic) => {
                engine = None;
                let what = panic
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                Err(anyhow!("rollout worker panicked executing shard: {what}"))
            }
        };
        if results.send((job.batch_id, job.shard_idx, res)).is_err() {
            return; // receiver gone: engine shut down
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(n: usize) -> Vec<RowSpec> {
        (0..n).map(|i| RowSpec { group_idx: i / 4, rollout_idx: i % 4, seed: i as i32 }).collect()
    }

    /// Sharding is contiguous, balanced, covers every row exactly once,
    /// never emits empty shards, and never splits finer than the rollout
    /// batch allows (under-full decode batches waste slots on filler).
    #[test]
    fn shard_rows_partitions_contiguously() {
        for (n, w, br) in [
            (12usize, 4usize, 4usize),
            (13, 4, 4),
            (3, 8, 4),
            (1, 1, 4),
            (16, 1, 4),
            (64, 8, 16),
        ] {
            let all = rows(n);
            let shards = shard_rows(&all, w, br);
            assert!(shards.len() <= w.max(1));
            assert!(shards.len() <= n.div_ceil(br).max(1), "over-sharded at n={n} w={w}");
            assert!(shards.iter().all(|s| !s.is_empty()));
            let flat: Vec<i32> = shards.iter().flatten().map(|r| r.seed).collect();
            let want: Vec<i32> = all.iter().map(|r| r.seed).collect();
            assert_eq!(flat, want, "sharding reordered rows at n={n} w={w}");
            let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced shards {sizes:?}");
        }
        // 64 rows, 8 workers, B_r=16: only 4 shards — each worker batch full
        assert_eq!(shard_rows(&rows(64), 8, 16).len(), 4);
        // 3 rows on 8 workers collapse to one shard
        assert_eq!(shard_rows(&rows(3), 8, 4).len(), 1);
    }
}
